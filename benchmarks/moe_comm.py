"""Shared MoE dispatch-benchmark substrate (the LM-side of amg_comm).

Token -> expert dispatch is the canonical irregular exchange of the
assigned LM pool; this module benchmarks it through the same planning
stack the AMG levels use.  A batch's routing pattern is synthesized as a
``CommPattern`` (push-side sparse dynamic data exchange,
``models.moe.dispatch_pattern``), planned with all three strategies
(standard / partial / full == a2a / hier / hier_dedup), and scored with
the locality-aware max-rate model — message counts/bytes are EXACT plan
quantities, network times for paper-scale EP groups are MODELED (this
container has no network).  :func:`measured_moe_dispatch` additionally
times the *real* jitted shard_map dispatch (through the plan/executor
cache) on however many host-platform devices are available — measured,
not modeled — and reports the capacity-health ``dropped_fraction``
alongside.
"""
from __future__ import annotations

import time
from types import SimpleNamespace
from typing import List, Tuple

import numpy as np

from repro.core import TPU_V5E, build_plan, plan_time
from repro.models.moe import (
    STRATEGY_OF_MODE,
    dispatch_pattern,
    dispatch_topology,
    make_moe_plan,
    moe_plan_for,
    select_moe_mode,
)

TRANSPORT_MODES = ("a2a", "hier", "hier_dedup")


def _geometry_cfg(n_experts: int, top_k: int, d_model: int):
    """Minimal ArchConfig stand-in: make_moe_plan only reads these."""
    from repro.models.common import ArchConfig

    return ArchConfig(
        name=f"moe-bench-e{n_experts}k{top_k}", family="moe", n_layers=1,
        d_model=d_model, n_heads=1, n_kv_heads=1, d_ff=0, vocab=1,
        n_experts=n_experts, top_k=top_k, d_ff_expert=d_model,
    )


def _fake_mesh(pods: int, lanes_per_pod: int):
    """Axis-shape stand-in for paper-scale EP groups (no devices needed:
    make_moe_plan only reads axis_names and devices.shape)."""
    if pods > 1:
        return SimpleNamespace(axis_names=("pod", "data", "model"),
                               devices=np.empty((pods, 1, lanes_per_pod)))
    return SimpleNamespace(axis_names=("data", "model"),
                           devices=np.empty((1, lanes_per_pod)))


def dispatch_plan(
    tokens_per_lane: int = 1024,
    n_experts: int = 8,
    top_k: int = 2,
    pods: int = 4,
    lanes_per_pod: int = 16,
    d_model: int = 4096,
    cap_factor: float = 1.25,
):
    """Dispatch geometry for a (modeled) EP group of pods x lanes devices."""
    cfg = _geometry_cfg(n_experts, top_k, d_model)
    return make_moe_plan(cfg, _fake_mesh(pods, lanes_per_pod),
                         tokens_per_lane, mode="a2a", cap_factor=cap_factor)


def modeled_dispatch_rows(
    tokens_per_lane: int = 1024,
    n_experts: int = 8,
    top_k: int = 2,
    pods: int = 4,
    lanes_per_pod: int = 16,
    d_model: int = 4096,
    value_bytes: int | None = None,
    params=TPU_V5E,
) -> List[Tuple[str, float, str]]:
    """Per-mode modeled dispatch exchange + the Section-5 selector's pick.

    One value on the wire is a full hidden-state row (``d_model`` bf16
    entries unless ``value_bytes`` overrides); message counts are exact
    plan quantities over the synthesized routing pattern.  A trailing
    ``discovery`` row accounts the sparse-dynamic-exchange partner
    discovery (allreduce ints) that a *non*-persistent dispatch would pay
    every batch — the cost the plan cache amortizes away.
    """
    plan = dispatch_plan(tokens_per_lane, n_experts, top_k, pods,
                         lanes_per_pod, d_model)
    vb = value_bytes if value_bytes is not None else d_model * 2
    pattern, stats, fp = dispatch_pattern(plan, tokens_per_lane)
    topo = dispatch_topology(plan)
    out = []
    for mode in TRANSPORT_MODES:
        cplan = build_plan(pattern, topo, STRATEGY_OF_MODE[mode],
                           value_bytes=vb)
        t = plan_time(cplan, params)
        tt = cplan.stats.totals()
        out.append((
            f"moe_comm/modeled/{mode}",
            t * 1e6,
            f"kind=modeled-{params.name}|ep={plan.ep_size}"
            f"|tokens={tokens_per_lane}|topk={top_k}"
            f"|inter_msgs={tt['inter_msgs']}|inter_bytes={tt['inter_bytes']}"
            f"|intra_msgs={tt['intra_msgs']}",
        ))
    chosen, report = select_moe_mode(plan, tokens_per_lane, vb, params)
    out.append((
        "moe_comm/selected",
        report.modeled_times[STRATEGY_OF_MODE[chosen]] * 1e6,
        f"kind=modeled-{params.name}|mode={chosen}"
        f"|fingerprint={fp[:12]}",
    ))
    out.append((
        "moe_comm/discovery",
        0.0,
        f"kind=exact-plan|allreduce_ints={stats.allreduce_ints}"
        f"|request_ints={stats.request_ints}"
        f"|max_serve_partners={stats.max_serve_partners}",
    ))
    return out


def measured_moe_dispatch(
    iters: int = 5,
    warmup: int = 2,
    batch: int = 4,
    seq: int = 8,
    params=TPU_V5E,
    tracer=None,
) -> List[Tuple[str, float, str]]:
    """MEASURED jitted dispatch on the local host-platform mesh.

    Runs the reduced-Mixtral MoE layer under every transport (and under
    ``auto``) through the plan/executor cache, timing steady-state calls —
    the executor is built once per mode and reused, exactly the serving
    path.  Requires >= 2 devices for a meaningful exchange; on 8 devices a
    (pod=2, data=2, model=2) mesh exercises the inter-pod hierarchy.

    ``tracer`` (a ``repro.profile.TraceRecorder``) records each mode's
    per-call wall time against its dispatch plan with
    ``pure_exchange=False`` — the timing includes expert compute, so these
    samples inform reporting but are excluded from rate fitting.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import reduced
    from repro.core import default_plan_cache
    from repro.models.common import Initializer
    from repro.models.moe import init_moe, moe_layer, moe_param_specs

    n_dev = jax.device_count()
    if n_dev >= 8:
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        batch_axes: Tuple[str, ...] = ("pod", "data")
    else:
        mesh = jax.make_mesh((1, n_dev), ("data", "model"))
        batch_axes = ("data",)
    cfg0 = reduced("mixtral-8x7b")
    cfg = cfg0.__class__(**{**cfg0.__dict__, "dtype": jnp.float32})
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(batch, seq, cfg.d_model))
                    .astype(np.float32))
    x_spec = P(batch_axes if len(batch_axes) > 1 else batch_axes[0],
               None, None)
    x = jax.device_put(x, NamedSharding(mesh, x_spec))
    cache = default_plan_cache()

    out = []
    pin = None
    for mode in TRANSPORT_MODES + ("auto",):
        plan = moe_plan_for(cfg, mesh, tokens_per_lane=batch * seq,
                            mode=mode, cap_factor=2.0, params=params,
                            cache=cache)
        if pin is None:  # e_phys is mode-independent: one param set
            init = Initializer(3, jnp.float32)
            host = {k: v[0] for k, v in
                    init_moe(init, cfg, 1, plan.e_phys).items()}
            specs = {k: P(*s[1:]) for k, s in
                     moe_param_specs(cfg, plan).items()}
            pin = {k: jax.device_put(v, NamedSharding(mesh, specs[k]))
                   for k, v in host.items() if k in specs}

        def step():
            y, _aux, drop = moe_layer(x, pin, plan, cfg, mesh, batch_axes,
                                      cache=cache)
            return jax.block_until_ready(y), drop

        for _ in range(warmup):
            _y, drop = step()
        t0 = time.perf_counter()
        for _ in range(iters):
            _y, drop = step()
        secs = (time.perf_counter() - t0) / iters
        if tracer is not None:
            pattern, _st, _fp = dispatch_pattern(plan, batch * seq)
            cplan = build_plan(
                pattern, dispatch_topology(plan),
                STRATEGY_OF_MODE[plan.mode],
                value_bytes=cfg.d_model * 4,  # f32 hidden rows on the wire
            )
            tracer.record_plan(cplan, secs, label=f"moe/{mode}",
                               pure_exchange=False)
        label = f"moe_comm/measured/{mode}"
        resolved = f"|resolved={plan.mode}" if mode == "auto" else ""
        out.append((
            label, secs * 1e6,
            f"kind=measured-device|devices={n_dev}{resolved}"
            f"|dropped_fraction={float(drop):.4f}",
        ))
    s = cache.stats()
    out.append((
        "moe_comm/plan_cache",
        0.0,
        f"kind=exact-plan|hits={s['hits']}|misses={s['misses']}"
        f"|exec_hits={s['exec_hits']}|exec_misses={s['exec_misses']}",
    ))
    return out
