"""Shared AMG communication-benchmark substrate.

Builds the paper's workload once per process: rotated anisotropic diffusion
(theta=45deg, eps=1e-3) -> classical AMG hierarchy -> per-level SpMV
communication patterns for a given process count -> plans for every
strategy.  Message counts/bytes are EXACT plan quantities; network *times*
are modeled (locality-aware max-rate, core.costmodel) because this
container has no network — both are labeled in the output.
"""
from __future__ import annotations

import functools
import time
from typing import Dict, List, Tuple

import numpy as np

from repro.amg import build_hierarchy, diffusion_2d
from repro.core import (
    LASSEN,
    Topology,
    build_plan,
    plan_time,
)
from repro.core.costmodel import step_time
from repro.sparse import partition_csr

PROCS_PER_REGION = 16          # paper: 16 cores/CPU used per Lassen node
VALUE_BYTES = 8                # double-precision vector entries

STRATEGIES = ("standard", "partial", "full")


@functools.lru_cache(maxsize=8)
def hierarchy_for(rows: int):
    ny, nx = _grid(rows)
    A = diffusion_2d(ny, nx)
    return build_hierarchy(A)


def _grid(rows: int) -> Tuple[int, int]:
    nx = 1 << int(np.ceil(np.log2(np.sqrt(rows))))
    ny = max(1, rows // nx)
    return ny, nx


@functools.lru_cache(maxsize=64)
def level_patterns(rows: int, n_procs: int):
    """[(pattern, n_level_rows)] per AMG level with >= n_procs rows."""
    h = hierarchy_for(rows)
    out = []
    for lvl in h.levels:
        if lvl.A.nrows < n_procs:
            break
        part = partition_csr(lvl.A, n_procs)
        out.append((part.pattern, lvl.A.nrows))
    return out


@functools.lru_cache(maxsize=256)
def level_plans(rows: int, n_procs: int):
    """{strategy: [(plan, build_seconds)] per level}."""
    topo = Topology(n_procs, min(PROCS_PER_REGION, n_procs))
    pats = level_patterns(rows, n_procs)
    out: Dict[str, List] = {}
    for strat in STRATEGIES:
        rows_out = []
        for pattern, _n in pats:
            t0 = time.perf_counter()
            plan = build_plan(pattern, topo, strat, value_bytes=VALUE_BYTES)
            rows_out.append((plan, time.perf_counter() - t0))
        out[strat] = rows_out
    return out


def modeled_level_times(rows: int, n_procs: int, params=LASSEN):
    """{strategy: [seconds per level]} (modeled)."""
    plans = level_plans(rows, n_procs)
    return {
        s: [plan_time(p, params) for p, _ in plans[s]]
        for s in STRATEGIES
    }
