"""Shared AMG communication-benchmark substrate.

Builds the paper's workload once per process: rotated anisotropic diffusion
(theta=45deg, eps=1e-3) -> classical AMG hierarchy -> per-level SpMV
communication patterns for a given process count -> plans for every
strategy.  Message counts/bytes are EXACT plan quantities; network *times*
for paper-scale process counts are modeled (locality-aware max-rate,
core.costmodel) because this container has no network — both are labeled in
the output.  In addition, :func:`level_selection` reports the Section-5
selector's per-level choice, and :func:`measured_device_exchange` times the
*real* jitted device executor on however many host-platform devices are
available (run under ``test.sh`` / ``XLA_FLAGS=--xla_force_host_platform_
device_count=8`` for a meaningful mesh) — measured, not modeled.
"""
from __future__ import annotations

import functools
import time
from typing import Dict, List, Tuple

import numpy as np

from repro.amg import build_hierarchy, diffusion_2d
from repro.core import (
    LASSEN,
    SelectionReport,
    Topology,
    build_plan,
    default_plan_cache,
    plan_time,
    select_plan,
)
from repro.core.costmodel import step_time
from repro.sparse import partition_csr

PROCS_PER_REGION = 16          # paper: 16 cores/CPU used per Lassen node
VALUE_BYTES = 8                # double-precision vector entries

STRATEGIES = ("standard", "partial", "full")


@functools.lru_cache(maxsize=8)
def hierarchy_for(rows: int):
    ny, nx = _grid(rows)
    A = diffusion_2d(ny, nx)
    return build_hierarchy(A)


def _grid(rows: int) -> Tuple[int, int]:
    nx = 1 << int(np.ceil(np.log2(np.sqrt(rows))))
    ny = max(1, rows // nx)
    return ny, nx


@functools.lru_cache(maxsize=64)
def level_patterns(rows: int, n_procs: int):
    """[(pattern, n_level_rows)] per AMG level with >= n_procs rows."""
    h = hierarchy_for(rows)
    out = []
    for lvl in h.levels:
        if lvl.A.nrows < n_procs:
            break
        part = partition_csr(lvl.A, n_procs)
        out.append((part.pattern, lvl.A.nrows))
    return out


@functools.lru_cache(maxsize=256)
def level_plans(rows: int, n_procs: int):
    """{strategy: [(plan, build_seconds)] per level}."""
    topo = Topology(n_procs, min(PROCS_PER_REGION, n_procs))
    pats = level_patterns(rows, n_procs)
    out: Dict[str, List] = {}
    for strat in STRATEGIES:
        rows_out = []
        for pattern, _n in pats:
            t0 = time.perf_counter()
            plan = build_plan(pattern, topo, strat, value_bytes=VALUE_BYTES)
            rows_out.append((plan, time.perf_counter() - t0))
        out[strat] = rows_out
    return out


def modeled_level_times(rows: int, n_procs: int, params=LASSEN):
    """{strategy: [seconds per level]} (modeled)."""
    plans = level_plans(rows, n_procs)
    return {
        s: [plan_time(p, params) for p, _ in plans[s]]
        for s in STRATEGIES
    }


def bench_topology(n_procs: int, procs_per_region: int | None = None) -> Topology:
    """Paper's region size where possible; on small device counts fall back
    to >= 2 regions so locality-aware strategies remain meaningful.  An
    explicitly passed ``procs_per_region`` is honored verbatim (Topology
    validates divisibility)."""
    if procs_per_region is not None:
        return Topology(n_procs, procs_per_region)
    ppr = min(PROCS_PER_REGION, n_procs)
    if ppr == n_procs and n_procs > 1:
        ppr = max(1, n_procs // 2)
    while n_procs % ppr:
        ppr -= 1
    return Topology(n_procs, ppr)


def level_selection(
    rows: int, n_procs: int, params=LASSEN,
    procs_per_region: int | None = None,
) -> List[Tuple[int, str, SelectionReport]]:
    """Section-5 dynamic selector per level: [(level, chosen, report)]."""
    out = []
    topo = bench_topology(n_procs, procs_per_region)
    for lvl, (pattern, _n) in enumerate(level_patterns(rows, n_procs)):
        _plan, report = select_plan(
            pattern, topo, params, value_bytes=VALUE_BYTES
        )
        out.append((lvl, report.chosen, report))
    return out


@functools.lru_cache(maxsize=8)
def setup_records(rows: int, n_procs: int, procs_per_region: int | None = None):
    """Distributed-setup run on the paper problem: exchange records + topo.

    Runs ``amg.distributed_setup.distributed_build_hierarchy`` once (through
    the process-wide plan cache) and returns its per-exchange accounting —
    the setup-phase analogue of :func:`level_patterns`.
    """
    from repro.amg import distributed_build_hierarchy, partition_fine_matrix

    ny, nx = _grid(rows)
    A = diffusion_2d(ny, nx)
    blocks, off = partition_fine_matrix(A, n_procs)
    topo = bench_topology(n_procs, procs_per_region)
    ds = distributed_build_hierarchy(
        blocks, off, topo, cache=default_plan_cache(),
        strategy="standard", value_bytes=VALUE_BYTES,
    )
    return ds, topo


def setup_exchange_rows(rows: int, n_procs: int, params=LASSEN):
    """Setup-phase SpGEMM exchange comparison: standard vs aggregated.

    For every Galerkin gather of the distributed setup (remote ``A`` rows,
    then remote ``P`` rows, per level) the payload pattern is planned both
    ways; message counts/bytes are exact plan quantities, times are modeled
    (max-rate, ``params``).  A trailing ``total/<phase>`` row aggregates the
    sparse-dynamic-exchange discovery cost (allreduce ints) per phase.
    """
    ds, topo = setup_records(rows, n_procs, None)
    out = []
    for rec in ds.records:
        if rec.phase not in ("gather_A", "gather_P") or rec.pattern is None:
            continue
        if rec.pattern.total_ghosts() == 0:
            continue
        for strat in ("standard", "full"):
            plan = build_plan(
                rec.pattern, topo, strat, value_bytes=VALUE_BYTES
            )
            t = plan_time(plan, params)
            tt = plan.stats.totals()
            out.append((
                f"setup_exchange/L{rec.level}/{rec.phase}/{strat}",
                t * 1e6,
                f"kind=modeled-lassen|values={rec.values}"
                f"|inter_msgs={tt['inter_msgs']}"
                f"|inter_bytes={tt['inter_bytes']}",
            ))
    for phase, d in sorted(ds.exchange_summary().items()):
        out.append((
            f"setup_exchange/total/{phase}",
            0.0,
            f"kind=exact-plan|values={d['values']}"
            f"|exchanges={d['exchanges']}"
            f"|allreduce_ints={d['allreduce_ints']}",
        ))
    return out


def measured_setup_exchange(
    rows: int,
    n_procs: int | None = None,
    procs_per_region: int | None = None,
    strategy: str = "auto",
    params=LASSEN,
    iters: int = 10,
    warmup: int = 2,
    tracer=None,
) -> List[Tuple[str, str, float]]:
    """MEASURED device execution of the setup-phase gather exchanges.

    Binds the jitted executor of every Galerkin payload pattern on the
    local mesh (same protocol as :func:`measured_device_exchange`) and
    times it; returns [(label, strategy, seconds)].  ``tracer`` (a
    ``repro.profile.TraceRecorder``) records each timing against its plan
    for the calibration flow.
    """
    import jax

    from repro.core import time_executor

    n_procs = n_procs or jax.device_count()
    if jax.device_count() < n_procs:
        raise RuntimeError(
            f"need {n_procs} devices, have {jax.device_count()} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count (see test.sh)"
        )
    mesh = jax.make_mesh((n_procs,), ("proc",))
    ds, topo = setup_records(rows, n_procs, procs_per_region)
    cache = default_plan_cache()
    out = []
    for rec in ds.records:
        if rec.phase not in ("gather_A", "gather_P") or rec.pattern is None:
            continue
        if rec.pattern.total_ghosts() == 0:
            continue
        coll = cache.collective(
            rec.pattern, topo, strategy, value_bytes=VALUE_BYTES, params=params
        )
        exchange = cache.executor(
            rec.pattern, topo, mesh, "proc", strategy,
            value_bytes=VALUE_BYTES, params=params,
        )
        secs = time_executor(
            exchange, n_procs, int(rec.pattern.n_local.max()),
            dtype=np.float64, iters=iters, warmup=warmup,
        )
        if tracer is not None:
            tracer.record_plan(coll.plan, secs,
                               label=f"setup/L{rec.level}/{rec.phase}",
                               pure_exchange=True)
        out.append(
            (f"L{rec.level}/{rec.phase}", coll.strategy, secs)
        )
    return out


def measured_device_exchange(
    rows: int,
    n_procs: int | None = None,
    procs_per_region: int | None = None,
    strategy: str = "auto",
    params=LASSEN,
    iters: int = 30,
    warmup: int = 5,
    tracer=None,
) -> List[Tuple[int, str, float]]:
    """MEASURED per-level device exchange wall time on the local mesh.

    Builds each level's persistent collective (through the process-wide plan
    cache), binds its executor on a 1-D mesh over the available devices, and
    times it with the shared ``core.collectives.time_executor`` protocol in
    float64 — the same value width the plans and the cost model assume
    (VALUE_BYTES=8), so measured and modeled numbers describe the same wire
    volume.  ``params`` drives the ``auto`` selector; keep it equal to the
    one given to :func:`level_selection` when comparing the two.  Returns
    [(level, strategy, seconds_per_exchange)]; levels without ghosts report
    0.0.  Requires ``n_procs`` (default: all host devices) devices visible.
    """
    import jax

    from repro.core import time_executor

    n_procs = n_procs or jax.device_count()
    if jax.device_count() < n_procs:
        raise RuntimeError(
            f"need {n_procs} devices, have {jax.device_count()} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count (see test.sh)"
        )
    mesh = jax.make_mesh((n_procs,), ("proc",))
    topo = bench_topology(n_procs, procs_per_region)
    cache = default_plan_cache()
    out = []
    assert VALUE_BYTES == 8  # float64 wire values, matching the model
    for lvl, (pattern, _n) in enumerate(level_patterns(rows, n_procs)):
        coll = cache.collective(pattern, topo, strategy,
                                value_bytes=VALUE_BYTES, params=params)
        if pattern.total_ghosts() == 0:
            out.append((lvl, coll.strategy, 0.0))
            continue
        exchange = cache.executor(pattern, topo, mesh, "proc", strategy,
                                  value_bytes=VALUE_BYTES, params=params)
        secs = time_executor(
            exchange, n_procs, int(pattern.n_local.max()),
            dtype=np.float64, iters=iters, warmup=warmup,
        )
        if tracer is not None:
            tracer.record_plan(coll.plan, secs, label=f"amg/L{lvl}",
                               pure_exchange=True)
        out.append((lvl, coll.strategy, secs))
    return out
