"""Elastic re-plan cost: cold setup vs shrink vs warm grow-back.

One AMG hierarchy is driven through the failure-recovery sequence the
runtime layer implements (see ``repro.runtime.controller``):

    cold setup on N devices -> shrink to N/2 ("heartbeat") ->
    grow back to N ("requested") -> straggler rebalance ("rebalance")

through a single private ``PlanCache``.  Two row families come out:

* ``elastic/replan_seconds/*`` — MEASURED host-side wall time of each
  rebuild (plan construction + executor binding; kind=measured-host).
  The headline is the ratio grow_warm/cold: growing back to a seen
  geometry is pure cache traffic.
* ``elastic/plan_misses/*`` — the plan-cache miss/hit delta of each
  rebuild, which is exact plan-geometry arithmetic for a fixed
  (rows, device count): kind=exact-plan, gated by benchmarks.compare.
  ``grow_warm`` must report 0 misses — the warm-resize contract the
  8-device integration test asserts, kept under the perf gate here.
"""
from __future__ import annotations


def elastic_rows(rows: int):
    import time

    import jax

    # match the measured sections: 8-byte values end to end
    jax.config.update("jax_enable_x64", True)

    import numpy as np

    from repro.amg import DistributedHierarchy, build_hierarchy, diffusion_2d
    from repro.core.cache import PlanCache

    n_dev = jax.device_count()
    small = max(1, n_dev // 2)
    nx = int(np.sqrt(min(rows, 65_536)))
    A = diffusion_2d(nx, nx)
    h = build_hierarchy(A)
    cache = PlanCache()   # private: counters start at zero for exact rows

    def mesh_n(n):
        return jax.sharding.Mesh(np.array(jax.devices()[:n]), ("proc",))

    def miss_row(tag, ev, extra=""):
        return (
            f"elastic/plan_misses/{tag}", float(ev.plan_misses),
            f"kind=exact-plan|hits={ev.plan_hits}"
            f"|exec_misses={ev.exec_misses}|exec_hits={ev.exec_hits}"
            f"|procs={ev.old_n}->{ev.new_n}{extra}|",
        )

    def time_row(tag, secs, n):
        return (
            f"elastic/replan_seconds/{tag}", secs * 1e6,
            f"kind=measured-host|n_procs={n}|levels={len(h.levels)}|",
        )

    out = []

    # ---- cold: first setup ever on the full device set -------------------
    from repro.runtime.controller import cache_delta_event

    before = cache.counters()
    t0 = time.perf_counter()
    dh = DistributedHierarchy.setup(h, mesh_n(n_dev), "proc", cache=cache)
    cold_secs = time.perf_counter() - t0
    ev_cold = cache_delta_event(cache, before, "cold", n_dev, n_dev,
                                cold_secs)
    out.append(time_row("cold", cold_secs, n_dev))
    out.append(miss_row("cold", ev_cold))

    # ---- shrink: half the devices "time out" -----------------------------
    dh_small = dh.repartition(mesh_n(small), reason="heartbeat")
    ev = dh_small.last_resize
    out.append(time_row("shrink", ev.replan_seconds, small))
    out.append(miss_row("shrink", ev))

    # ---- grow back: every pattern must come out of the cache -------------
    dh_back = dh_small.repartition(mesh_n(n_dev), reason="requested")
    ev = dh_back.last_resize
    out.append(time_row("grow_warm", ev.replan_seconds, n_dev))
    out.append(miss_row("grow_warm", ev,
                        extra=f"|warm={'yes' if ev.warm else 'no'}"))

    # ---- straggler rebalance: skewed row blocks are a NEW geometry -------
    # fixed synthetic EWMA weights (host 1 measured 3x slow) so the
    # resulting offsets — hence the miss count — are deterministic
    weights = np.full(n_dev, 0.010)
    if n_dev > 1:
        weights[1] *= 3.0
    dh_reb = dh_back.repartition(row_weights=weights, reason="rebalance")
    ev = dh_reb.last_resize
    out.append(time_row("rebalance", ev.replan_seconds, n_dev))
    out.append(miss_row("rebalance", ev))

    return out
