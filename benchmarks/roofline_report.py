"""Aggregate dry-run cell JSONs into the roofline table (EXPERIMENTS.md)."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def load_cells(include_variants: bool = True) -> List[Dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        base = os.path.basename(path)[:-5]
        parts = base.split("__")
        is_variant = len(parts) > 3
        if is_variant and not include_variants:
            continue
        with open(path) as f:
            d = json.load(f)
        d["_variant"] = "__".join(parts[3:]) if is_variant else ""
        cells.append(d)
    return cells


def rows():
    out = []
    for c in load_cells():
        tag = f"{c.get('arch')}/{c.get('shape')}/{c.get('mesh')}"
        if c.get("_variant"):
            tag += f"/{c['_variant']}"
        if c.get("status") == "skipped":
            out.append((f"roofline/{tag}", 0.0,
                        "kind=skip|" + c.get("reason", "")[:60]))
            continue
        if c.get("status") != "ok":
            out.append((f"roofline/{tag}", 0.0, "kind=ERROR"))
            continue
        extra = ""
        dci = c.get("ici_dci_bytes_per_device")
        if dci:
            extra = (f"|dci_bytes={dci['dci']:.3g}"
                     f"|ici_bytes={dci['ici']:.3g}")
        out.append((
            f"roofline/{tag}",
            c["step_s_lower_bound"] * 1e6,
            "kind=dryrun-roofline"
            f"|bottleneck={c['bottleneck']}"
            f"|compute_us={c['compute_s'] * 1e6:.0f}"
            f"|memory_us={c['memory_s'] * 1e6:.0f}"
            f"|collective_us={c['collective_s'] * 1e6:.0f}"
            f"|useful_flops={c['useful_flops_ratio']:.2f}"
            f"|fits_v5e={c.get('memory_analytic', {}).get('fits_16gb_v5e')}"
            + extra,
        ))
    return out


def markdown_table(include_variants: bool = False) -> str:
    lines = [
        "| arch | shape | mesh | compute (s) | memory (s) | collective (s) "
        "| bound | useful | fits v5e |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for c in load_cells(include_variants=include_variants):
        v = f" `{c['_variant']}`" if c.get("_variant") else ""
        if c.get("status") == "skipped":
            lines.append(
                f"| {c['arch']} | {c['shape']} | {c['mesh']}{v} | — | — | — "
                f"| *skip: sub-quadratic attention required* | — | — |"
            )
            continue
        if c.get("status") != "ok":
            lines.append(
                f"| {c.get('arch')} | {c.get('shape')} | {c.get('mesh')}{v} "
                f"| — | — | — | ERROR | — | — |"
            )
            continue
        fits = c.get("memory_analytic", {}).get("fits_16gb_v5e", "?")
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']}{v} "
            f"| {c['compute_s']:.4f} | {c['memory_s']:.4f} "
            f"| {c['collective_s']:.4f} | {c['bottleneck']} "
            f"| {c['useful_flops_ratio']:.2f} | {fits} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    import sys
    print(markdown_table(include_variants="--variants" in sys.argv))
