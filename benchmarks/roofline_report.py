"""Aggregate dry-run cell JSONs into the roofline table (EXPERIMENTS.md),
plus a modeled SpMV kernel-variant roofline (flat vs column-blocked)."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from repro.core.costmodel import V5E_HBM_BW, V5E_VPU_FLOPS

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")

# TPU v5e single-core numbers for the kernel roofline live in
# repro.core.costmodel (V5E_HBM_BW, V5E_VPU_FLOPS) so the overlap selector
# and these cells share one machine description (modeled, like the dry-run
# cells: labeled, never presented as measurements).


def spmv_kernel_cells(
    rows_per_proc: int = 2 ** 21,
    k: int = 9,
    ghost: int = 2 * 4096,
    value_bytes: int = 8,
    block_rows: int = 256,
    block_cols: int = 512,
) -> List[Dict]:
    """Modeled roofline of both SpMV variants on a paper-scale fine level.

    Flat reads x once (VMEM-resident — only legal when it fits); blocked
    re-streams each x column block once per row block, trading HBM traffic
    for a VMEM footprint independent of the x length.  Deterministic
    arithmetic — gated by ``benchmarks.compare``.
    """
    from repro.sparse.device import (
        spmv_blocked_vmem_bytes,
        spmv_flat_vmem_bytes,
    )

    n = rows_per_proc
    x_len = n + ghost
    flops = 2.0 * n * k
    ell_bytes = n * k * (4 + value_bytes)
    cells = []
    for variant in ("flat", "blocked"):
        if variant == "flat":
            x_bytes = x_len * value_bytes
            vmem = spmv_flat_vmem_bytes(
                in_pad=n, ghost_pad=ghost, k_local=k, k_ghost=k,
                value_bytes=value_bytes, rows=n, block_rows=block_rows,
            )
        else:
            # x re-streamed once per row block (the cost of column blocking)
            x_bytes = (n // block_rows) * (
                -(-x_len // block_cols) * block_cols
            ) * value_bytes
            vmem = spmv_blocked_vmem_bytes(
                bucket_k=k, value_bytes=value_bytes, rows=n,
                block_rows=block_rows, block_cols=block_cols,
            )
        hbm = ell_bytes + x_bytes + n * value_bytes
        t = max(hbm / V5E_HBM_BW, flops / V5E_VPU_FLOPS)
        cells.append({
            "variant": variant,
            "hbm_bytes": hbm,
            "flops": flops,
            "intensity": flops / hbm,
            "time_s": t,
            "vmem_bytes": vmem,
            "vmem_fits": vmem <= 16 * 2 ** 20,
        })
    return cells


def overlap_cell(
    rows_per_proc: int = 2 ** 21,
    k: int = 9,
    ghost: int = 2 * 4096,
    n_neighbors: int = 8,
    value_bytes: int = 8,
) -> Dict:
    """Modeled exchange/compute overlap on the paper-scale fine level.

    Exchange from the v5e postal model (DCI neighbors of a two-deep 2-D
    halo), local compute from the same roofline compute model the overlap
    selector uses; reports the exchange time left exposed by the split
    schedule and the fraction hidden.  Deterministic arithmetic.
    """
    from repro.core.costmodel import (
        exposed_exchange_seconds,
        hidden_fraction,
        modeled_fine_exchange_time,
        overlap_split_overhead,
        spmv_compute_time,
    )

    tx = modeled_fine_exchange_time(n_neighbors, ghost,
                                    value_bytes=value_bytes)
    tl = spmv_compute_time(rows_per_proc * k, rows_per_proc,
                           rows_per_proc + ghost, value_bytes=value_bytes)
    return {
        "exchange_s": tx,
        "local_s": tl,
        "exposed_s": exposed_exchange_seconds(tx, tl),
        "hidden_frac": hidden_fraction(tx, tl),
        "overhead_s": overlap_split_overhead(rows_per_proc,
                                             value_bytes=value_bytes),
    }


def kernel_rows():
    out = []
    for c in spmv_kernel_cells():
        out.append((
            f"roofline/spmv_{c['variant']}",
            c["time_s"] * 1e6,
            "kind=modeled-roofline"
            f"|hbm_gb={c['hbm_bytes'] / 1e9:.3f}"
            f"|intensity={c['intensity']:.4f}"
            f"|vmem_kib={c['vmem_bytes'] / 2 ** 10:.1f}"
            f"|vmem_fits={c['vmem_fits']}",
        ))
    ov = overlap_cell()
    out.append((
        "roofline/spmv_overlap",
        ov["exposed_s"] * 1e6,
        "kind=modeled-roofline"
        f"|tx_us={ov['exchange_s'] * 1e6:.3f}"
        f"|local_us={ov['local_s'] * 1e6:.3f}"
        f"|hidden_frac={ov['hidden_frac']:.4f}"
        f"|overhead_us={ov['overhead_s'] * 1e6:.3f}",
    ))
    return out


def load_cells(include_variants: bool = True) -> List[Dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        base = os.path.basename(path)[:-5]
        parts = base.split("__")
        is_variant = len(parts) > 3
        if is_variant and not include_variants:
            continue
        with open(path) as f:
            d = json.load(f)
        d["_variant"] = "__".join(parts[3:]) if is_variant else ""
        cells.append(d)
    return cells


def rows():
    out = kernel_rows()
    for c in load_cells():
        tag = f"{c.get('arch')}/{c.get('shape')}/{c.get('mesh')}"
        if c.get("_variant"):
            tag += f"/{c['_variant']}"
        if c.get("status") == "skipped":
            out.append((f"roofline/{tag}", 0.0,
                        "kind=skip|" + c.get("reason", "")[:60]))
            continue
        if c.get("status") != "ok":
            out.append((f"roofline/{tag}", 0.0, "kind=ERROR"))
            continue
        extra = ""
        dci = c.get("ici_dci_bytes_per_device")
        if dci:
            extra = (f"|dci_bytes={dci['dci']:.3g}"
                     f"|ici_bytes={dci['ici']:.3g}")
        out.append((
            f"roofline/{tag}",
            c["step_s_lower_bound"] * 1e6,
            "kind=dryrun-roofline"
            f"|bottleneck={c['bottleneck']}"
            f"|compute_us={c['compute_s'] * 1e6:.0f}"
            f"|memory_us={c['memory_s'] * 1e6:.0f}"
            f"|collective_us={c['collective_s'] * 1e6:.0f}"
            f"|useful_flops={c['useful_flops_ratio']:.2f}"
            f"|fits_v5e={c.get('memory_analytic', {}).get('fits_16gb_v5e')}"
            + extra,
        ))
    return out


def markdown_table(include_variants: bool = False) -> str:
    lines = [
        "| arch | shape | mesh | compute (s) | memory (s) | collective (s) "
        "| bound | useful | fits v5e |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for c in load_cells(include_variants=include_variants):
        v = f" `{c['_variant']}`" if c.get("_variant") else ""
        if c.get("status") == "skipped":
            lines.append(
                f"| {c['arch']} | {c['shape']} | {c['mesh']}{v} | — | — | — "
                f"| *skip: sub-quadratic attention required* | — | — |"
            )
            continue
        if c.get("status") != "ok":
            lines.append(
                f"| {c.get('arch')} | {c.get('shape')} | {c.get('mesh')}{v} "
                f"| — | — | — | ERROR | — | — |"
            )
            continue
        fits = c.get("memory_analytic", {}).get("fits_16gb_v5e", "?")
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']}{v} "
            f"| {c['compute_s']:.4f} | {c['memory_s']:.4f} "
            f"| {c['collective_s']:.4f} | {c['bottleneck']} "
            f"| {c['useful_flops_ratio']:.2f} | {fits} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    import sys
    print(markdown_table(include_variants="--variants" in sys.argv))
