"""One benchmark function per paper table/figure (Collom et al., EuroMPI'23).

Each returns rows (name, us_per_call, derived) where ``us_per_call`` is a
time in microseconds (measured host time or modeled network time — tagged
in ``derived``) and ``derived`` packs the figure's quantities.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.core import LASSEN, Topology, build_plan, plan_time
from repro.core.costmodel import init_time

from .amg_comm import (
    PROCS_PER_REGION,
    STRATEGIES,
    VALUE_BYTES,
    hierarchy_for,
    level_patterns,
    level_plans,
    modeled_level_times,
)

Row = Tuple[str, float, str]

FULL_ROWS = 524_288
SCALE_PROCS = (64, 256, 1024, 2048)


def fig6_graph_creation(rows=FULL_ROWS) -> List[Row]:
    """Paper Fig 6: cost of forming the neighborhood topology once per AMG
    level, strong-scaled.  Here: measured host time to extract every level's
    CommPattern (the dist-graph information) for the 524,288-row problem."""
    out = []
    for n_procs in SCALE_PROCS:
        level_patterns.cache_clear()
        t0 = time.perf_counter()
        pats = level_patterns(rows, n_procs)
        dt = time.perf_counter() - t0
        out.append((
            f"fig6/graph_create/p{n_procs}",
            dt * 1e6,
            f"kind=measured-host|levels={len(pats)}|rows={rows}",
        ))
    return out


def fig7_crossover(rows=FULL_ROWS, n_procs=2048) -> List[Row]:
    """Paper Fig 7: init cost + k x per-iteration cost; crossover iteration
    where each optimized collective beats the standard one."""
    plans = level_plans(rows, n_procs)
    inits = {}
    periter = {}
    walls = {}
    for s in STRATEGIES:
        inits[s] = sum(init_time(p, LASSEN) for p, _ in plans[s])
        walls[s] = sum(wall for _, wall in plans[s])
        periter[s] = sum(plan_time(p, LASSEN) for p, _ in plans[s])
    # aggregated setup first exchanges the ORIGINAL pattern's index lists
    # (to build the aggregation path + balance leaders) before its own:
    # the paper's partial init > full init > standard init ordering
    inits["partial"] += inits["standard"] + inits["full"]
    inits["full"] += inits["standard"]
    out = []
    for s in STRATEGIES:
        cross = ""
        if s != "standard" and periter[s] < periter["standard"]:
            k = (inits[s] - inits["standard"]) / (
                periter["standard"] - periter[s]
            )
            cross = f"|crossover_iters={max(0.0, k):.1f}"
        out.append((
            f"fig7/init_plus_iter/{s}",
            periter[s] * 1e6,
            f"kind=modeled-lassen|init_us={inits[s] * 1e6:.0f}"
            f"|measured_planning_s={walls[s]:.2f}{cross}",
        ))
    return out


def fig8_9_message_counts(rows=FULL_ROWS, n_procs=2048) -> List[Row]:
    """Paper Figs 8+9: per-level max intra-/inter-region message counts."""
    plans = level_plans(rows, n_procs)
    out = []
    for s in STRATEGIES:
        for lvl, (p, _) in enumerate(plans[s]):
            st = p.stats
            out.append((
                f"fig8_9/counts/{s}/L{lvl}",
                0.0,
                "kind=exact-plan"
                f"|intra_msgs_max={st.max_intra_msgs()}"
                f"|inter_msgs_max={st.max_inter_msgs()}",
            ))
    return out


def fig10_message_sizes(rows=FULL_ROWS, n_procs=2048) -> List[Row]:
    """Paper Fig 10: per-level max inter-region bytes, partial vs full
    (dedup saving)."""
    plans = level_plans(rows, n_procs)
    out = []
    for lvl in range(len(plans["partial"])):
        pb = plans["partial"][lvl][0].stats.max_inter_bytes()
        fb = plans["full"][lvl][0].stats.max_inter_bytes()
        save = 100.0 * (1 - fb / pb) if pb else 0.0
        out.append((
            f"fig10/inter_bytes/L{lvl}",
            0.0,
            f"kind=exact-plan|partial={pb}|full={fb}|dedup_saving_pct={save:.1f}",
        ))
    return out


def fig11_per_level_cost(rows=FULL_ROWS, n_procs=2048) -> List[Row]:
    """Paper Fig 11: modeled per-level SpMV communication cost."""
    times = modeled_level_times(rows, n_procs)
    out = []
    for s in STRATEGIES:
        for lvl, t in enumerate(times[s]):
            out.append((
                f"fig11/level_cost/{s}/L{lvl}",
                t * 1e6,
                "kind=modeled-lassen",
            ))
    return out


def _scaled_total(rows: int, n_procs: int):
    """Paper's scaling-study metric: per level take min(standard, optimized)
    for each optimized strategy; sum across levels."""
    times = modeled_level_times(rows, n_procs)
    std = sum(times["standard"])
    tot = {"standard": std}
    for s in ("partial", "full"):
        tot[s] = sum(
            min(a, b) for a, b in zip(times["standard"], times[s])
        )
    return tot


def fig12_strong_scaling(rows=FULL_ROWS) -> List[Row]:
    """Paper Fig 12: strong scaling of total SpMV comm time across levels."""
    out = []
    for n_procs in SCALE_PROCS:
        tot = _scaled_total(rows, n_procs)
        sp_p = tot["standard"] / tot["partial"] if tot["partial"] else 0
        sp_f = tot["standard"] / tot["full"] if tot["full"] else 0
        out.append((
            f"fig12/strong/p{n_procs}",
            tot["standard"] * 1e6,
            "kind=modeled-lassen"
            f"|partial_us={tot['partial'] * 1e6:.1f}"
            f"|full_us={tot['full'] * 1e6:.1f}"
            f"|speedup_partial={sp_p:.2f}|speedup_full={sp_f:.2f}",
        ))
    return out


def fig13_weak_scaling(rows_per_proc=256) -> List[Row]:
    """Paper Fig 13: weak scaling (rows/proc fixed)."""
    out = []
    for n_procs in SCALE_PROCS:
        rows = rows_per_proc * n_procs
        tot = _scaled_total(rows, n_procs)
        sp_p = tot["standard"] / tot["partial"] if tot["partial"] else 0
        sp_f = tot["standard"] / tot["full"] if tot["full"] else 0
        out.append((
            f"fig13/weak/p{n_procs}",
            tot["standard"] * 1e6,
            "kind=modeled-lassen"
            f"|rows={rows}"
            f"|partial_us={tot['partial'] * 1e6:.1f}"
            f"|full_us={tot['full'] * 1e6:.1f}"
            f"|speedup_partial={sp_p:.2f}|speedup_full={sp_f:.2f}",
        ))
    return out


def amg_solver_convergence(rows=65_536) -> List[Row]:
    """Sanity anchor: the AMG actually solves the paper's system."""
    from repro.amg import solve
    h = hierarchy_for(rows)
    rng = np.random.default_rng(0)
    b = rng.normal(size=h.levels[0].A.nrows)
    t0 = time.perf_counter()
    x, hist = solve(h, b, tol=1e-8, max_iters=60)
    dt = time.perf_counter() - t0
    return [(
        "amg/solve",
        dt * 1e6,
        f"kind=measured-host|iters={len(hist)}|final_rel_res={hist[-1]:.2e}"
        f"|levels={h.n_levels}|complexity={h.complexity():.2f}",
    )]
