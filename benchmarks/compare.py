"""Perf-regression gate: diff a benchmark results JSON against a baseline.

CI's ``bench-smoke`` job runs the smoke harness, then::

    python -m benchmarks.compare benchmarks/results/baseline.json \
        benchmarks/results/smoke.json --diff-out .../compare_diff.json

Row policy, driven by the ``kind=`` tag each row carries:

* DETERMINISTIC rows (``modeled-*``, ``exact-plan``, ``dryrun-roofline``,
  ``skip``) are exact arithmetic on plan/block geometry: ``us_per_call``
  and every numeric ``key=value`` field of ``derived`` must match the
  baseline within ``--modeled-rtol`` (non-numeric fields — strategy and
  kernel-variant choices — must match exactly).  A drift here means the
  model, a plan, or a selection changed: exactly the regression this gate
  exists to catch.  Deterministic ``obs/*`` rows (telemetry counter and
  span counts) are gated **exactly** (rtol=0): the same program must
  produce the same counts on every machine.
* MEASURED rows (``measured-*``) are wall-clock on whatever machine CI
  gives us: they must exist and be finite, and nonzero timings must stay
  within a generous ``--measured-band`` factor of the baseline.  Measured
  ``spmv_overlap/*`` rows additionally gate their ``exposed_frac`` field
  (the fraction of the exchange left visible in the full SpMV, in [0, 1]):
  it may not exceed the baseline by more than ``--overlap-frac-tol`` —
  one-sided, so getting *better* at hiding the exchange never fails.
* Rows present in the baseline but missing from the run FAIL (a silently
  dropped benchmark is a regression); new rows only warn — commit a
  regenerated baseline to adopt them.

Schema versions must match exactly: a schema bump requires a regenerated
baseline, not a tolerance.

Exit codes: 0 OK, 1 regression, 2 unusable input (schema/IO).  The diff is
always written to ``--diff-out`` (when given) so CI can upload it as an
artifact either way.
"""
from __future__ import annotations

import argparse
import json
import math
import pathlib
import sys
from typing import Dict, List, Tuple

_DETERMINISTIC_EXACT = frozenset({"exact-plan", "dryrun-roofline", "skip"})


def is_deterministic(kind: str) -> bool:
    """modeled-* rows (any machine model) and exact plan/dry-run rows are
    pure arithmetic; everything measured-* is wall-clock."""
    return kind.startswith("modeled") or kind in _DETERMINISTIC_EXACT


def parse_derived(derived: str) -> Tuple[str, Dict[str, str]]:
    """``kind=X|a=1|b=c`` -> ("X", {"a": "1", "b": "c"}); bare tokens get
    themselves as value."""
    kind = ""
    fields: Dict[str, str] = {}
    for tok in derived.split("|"):
        if not tok:
            continue
        key, _, val = tok.partition("=")
        if key == "kind":
            kind = val
        else:
            fields[key] = val if _ else key
    return kind, fields


def _as_float(s: str):
    try:
        return float(s)
    except ValueError:
        return None


def _rel_close(a: float, b: float, rtol: float, atol: float = 1e-9) -> bool:
    return abs(a - b) <= max(rtol * max(abs(a), abs(b)), atol)


def load_results(path: pathlib.Path) -> dict:
    with open(path) as f:
        payload = json.load(f)
    if "results" not in payload or "schema_version" not in payload:
        raise ValueError(f"{path}: not a benchmark results JSON")
    return payload


def index_rows(payload: dict) -> Dict[str, List[dict]]:
    idx: Dict[str, List[dict]] = {}
    for row in payload["results"]:
        idx.setdefault(row["name"], []).append(row)
    return idx


def compare_row(base: dict, new: dict, modeled_rtol: float,
                measured_band: float,
                overlap_frac_tol: float = 0.6) -> List[dict]:
    """Regression records (empty if the row is fine)."""
    name = base["name"]
    kind, bfields = parse_derived(base["derived"])
    nkind, nfields = parse_derived(new["derived"])
    regs = []
    if kind != nkind:
        regs.append({
            "name": name, "what": "kind-changed",
            "baseline": kind, "new": nkind,
        })
        return regs
    b_us, n_us = float(base["us_per_call"]), float(new["us_per_call"])
    if not math.isfinite(n_us):
        regs.append({"name": name, "what": "non-finite", "new": n_us})
        return regs

    if is_deterministic(kind):
        # telemetry counter/span-count rows are integers by construction:
        # the same program must produce the SAME count everywhere, so they
        # get exact (rtol=0) matching instead of the modeled tolerance
        if name.startswith("obs/"):
            modeled_rtol = 0.0
        if not _rel_close(b_us, n_us, modeled_rtol):
            regs.append({
                "name": name, "what": "modeled-us-drift",
                "baseline": b_us, "new": n_us, "rtol": modeled_rtol,
            })
        for key in sorted(set(bfields) | set(nfields)):
            if key.startswith("measured"):
                # wall-clock side-channel inside a deterministic row
                # (convention: measured* fields are informational)
                continue
            bv, nv = bfields.get(key), nfields.get(key)
            if bv is None or nv is None:
                regs.append({
                    "name": name, "what": "derived-field-missing",
                    "field": key, "baseline": bv, "new": nv,
                })
                continue
            bf, nf = _as_float(bv), _as_float(nv)
            if bf is not None and nf is not None:
                if not _rel_close(bf, nf, modeled_rtol, atol=1e-6):
                    regs.append({
                        "name": name, "what": "derived-field-drift",
                        "field": key, "baseline": bv, "new": nv,
                    })
            elif bv != nv:
                regs.append({
                    "name": name, "what": "derived-field-changed",
                    "field": key, "baseline": bv, "new": nv,
                })
    else:  # measured: generous band, only when both sides actually timed
        if b_us > 0.0 and n_us > 0.0:
            ratio = n_us / b_us
            if ratio > measured_band or ratio < 1.0 / measured_band:
                regs.append({
                    "name": name, "what": "measured-out-of-band",
                    "baseline": b_us, "new": n_us,
                    "ratio": ratio, "band": measured_band,
                })
        # overlap rows: the exposed-exchange fraction may not regress
        # beyond the tolerance (one-sided — improving never fails)
        if name.startswith("spmv_overlap/"):
            bf = _as_float(bfields.get("exposed_frac", ""))
            nf = _as_float(nfields.get("exposed_frac", ""))
            if bf is not None and nf is not None \
                    and nf > bf + overlap_frac_tol:
                regs.append({
                    "name": name, "what": "overlap-exposed-frac-regressed",
                    "baseline": bf, "new": nf, "tol": overlap_frac_tol,
                })
    return regs


def compare(baseline: dict, new: dict, modeled_rtol: float = 1e-6,
            measured_band: float = 25.0,
            overlap_frac_tol: float = 0.6) -> dict:
    """Full diff; ``status`` is "ok" or "regression"."""
    regressions: List[dict] = []
    if baseline["schema_version"] != new["schema_version"]:
        return {
            "status": "regression",
            "regressions": [{
                "name": "<schema>", "what": "schema-version-mismatch",
                "baseline": baseline["schema_version"],
                "new": new["schema_version"],
            }],
            "new_rows": [], "checked": 0,
        }
    if new.get("failed_sections"):
        regressions.append({
            "name": "<sections>", "what": "failed-sections",
            "new": new["failed_sections"],
        })
    bidx, nidx = index_rows(baseline), index_rows(new)
    checked = 0
    for name, brows in bidx.items():
        nrows = nidx.get(name)
        if not nrows:
            regressions.append({"name": name, "what": "missing-row"})
            continue
        if len(nrows) != len(brows):
            regressions.append({
                "name": name, "what": "row-count-changed",
                "baseline": len(brows), "new": len(nrows),
            })
            continue
        for b, n in zip(brows, nrows):
            checked += 1
            regressions.extend(
                compare_row(b, n, modeled_rtol, measured_band,
                            overlap_frac_tol)
            )
    new_rows = sorted(set(nidx) - set(bidx))
    return {
        "status": "regression" if regressions else "ok",
        "regressions": regressions,
        "new_rows": new_rows,
        "checked": checked,
        "baseline_sha": baseline.get("git_sha"),
        "new_sha": new.get("git_sha"),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", type=pathlib.Path)
    ap.add_argument("new", type=pathlib.Path)
    ap.add_argument("--modeled-rtol", type=float, default=1e-6,
                    help="relative tolerance for deterministic rows")
    ap.add_argument("--measured-band", type=float, default=25.0,
                    help="allowed slow/fast factor for measured rows")
    ap.add_argument("--overlap-frac-tol", type=float, default=0.6,
                    help="allowed one-sided increase of a measured "
                    "spmv_overlap row's exposed_frac over the baseline")
    ap.add_argument("--diff-out", type=pathlib.Path, default=None,
                    help="write the diff JSON here (for the CI artifact)")
    args = ap.parse_args(argv)

    try:
        baseline = load_results(args.baseline)
        new = load_results(args.new)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"compare: unusable input: {e}", file=sys.stderr)
        return 2

    diff = compare(baseline, new, args.modeled_rtol, args.measured_band,
                   args.overlap_frac_tol)
    if args.diff_out:
        args.diff_out.parent.mkdir(parents=True, exist_ok=True)
        args.diff_out.write_text(json.dumps(diff, indent=2))

    print(f"compare: {diff['checked']} rows checked against "
          f"{args.baseline} (baseline sha {diff.get('baseline_sha')})")
    for r in diff["new_rows"]:
        print(f"  NEW (not gated): {r}")
    for r in diff["regressions"]:
        print(f"  REGRESSION: {json.dumps(r)}")
    if diff["status"] != "ok":
        print(f"compare: FAIL — {len(diff['regressions'])} regression(s); "
              "if intentional, regenerate and commit "
              "benchmarks/results/baseline.json", file=sys.stderr)
        return 1
    print("compare: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
