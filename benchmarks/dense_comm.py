"""Dense-collective benchmark: the plan-based allreduce / allgatherv /
reduce_scatter of ``core.dense`` through the same selection/cache/measure
protocol the sparse exchanges use.

Two row families:

* ``dense/select/*`` — DETERMINISTIC modeled selection (kind=modeled-*):
  every candidate schedule is built and scored with the locality-aware
  max-rate model at a paper-scale multi-region geometry (where the
  hierarchical variant must beat the flat ring — flagged as
  ``hier_beats_ring``) and at the CI smoke geometry.  Pure plan
  arithmetic, gated exactly by ``benchmarks.compare``.
* ``dense/measured/*`` — MEASURED device executions on the local
  host-platform mesh through the ``dense_plan`` / ``dense_executor``
  cache namespaces, with the result asserted equal to the jnp reference
  (sum / concatenation of the per-device inputs) before timing.  With a
  ``tracer`` each timing is recorded as a ``pure_exchange`` sample under
  the plan's dense fingerprint, feeding the NNLS calibration fit exactly
  like the sparse transports.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.core import (
    TPU_V5E,
    Topology,
    default_plan_cache,
    even_counts,
    measure_dense_seconds,
    pack_dense_input,
    select_dense,
    unpack_dense_output,
)

DENSE_BENCH_COLLECTIVES = ("allreduce", "allgatherv", "reduce_scatter")

# paper-scale EP/DP group: 1024 processes, 32 per region (Section 5's
# multi-region regime, where locality-aware schedules win)
PAPER_PROCS = 1024
PAPER_PPR = 32
PAPER_VALUES = 1 << 20          # a ~1M-value gradient/weight vector


def _bench_counts(collective: str, n_procs: int, n_values: int) -> np.ndarray:
    """Deterministic per-segment counts; allgatherv gets *uneven* counts
    (the v in allgatherv) so the modeled rows exercise the padded wire."""
    counts = even_counts(n_values, n_procs)
    if collective == "allgatherv":
        # deterministic unevenness: +/- up to 25% in a fixed pattern
        jitter = (np.arange(n_procs, dtype=np.int64) * 7919) % 5 - 2
        counts = np.maximum(counts + jitter * (counts // 8), 1)
    return counts


def modeled_select_rows(
    n_procs: int = PAPER_PROCS,
    ppr: int = PAPER_PPR,
    n_values: int = PAPER_VALUES,
    params=TPU_V5E,
) -> List[Tuple[str, float, str]]:
    """Section-5 selection over every dense variant at the paper-scale
    multi-region geometry plus the 8-device smoke geometry.  The
    ``hier_beats_ring`` flag is the acceptance gate: at paper scale the
    cost model must prefer the hierarchical schedule."""
    out = []
    for label, topo, n in (
        ("paper", Topology(n_procs, ppr), n_values),
        ("smoke", Topology(8, 4), 4096),
    ):
        for coll in DENSE_BENCH_COLLECTIVES:
            counts = _bench_counts(coll, topo.n_procs, n)
            plan, sel = select_dense(coll, counts, topo, variant="auto",
                                     params=params)
            times = "|".join(
                f"{k}_us={v * 1e6:.2f}"
                for k, v in sorted(sel.modeled_times.items())
            )
            hier_wins = (
                "hier" in sel.modeled_times
                and sel.modeled_times["hier"] < sel.modeled_times["ring"]
            )
            out.append((
                f"dense/select/{label}/{coll}",
                sel.modeled_times[sel.chosen] * 1e6,
                f"kind=modeled-{params.name}|chosen={sel.chosen}"
                f"|n_procs={topo.n_procs}|ppr={topo.procs_per_region}"
                f"|rounds={plan.n_rounds}|{times}"
                f"|hier_beats_ring={'yes' if hier_wins else 'no'}",
            ))
    return out


def _reference(plan, vals: List[np.ndarray]) -> List[np.ndarray]:
    """jnp-free numpy reference for the collective over per-device vals."""
    P = plan.topo.n_procs
    if plan.collective == "allgatherv":
        cat = np.concatenate(vals)
        return [cat for _ in range(P)]
    total = np.sum(np.stack(vals), axis=0)
    if plan.collective == "allreduce":
        return [total for _ in range(P)]
    bounds = np.cumsum(plan.counts)[:-1]
    segs = np.split(total, bounds)
    return [segs[p] for p in range(P)]


def measured_dense_rows(
    iters: int = 10,
    warmup: int = 2,
    n_values: int = 4096,
    params=TPU_V5E,
    tracer=None,
) -> List[Tuple[str, float, str]]:
    """MEASURED dense collectives on the local mesh: every variant the
    geometry admits, planned and bound through the shared
    :class:`PlanCache` (``dense_plan`` + audited ``dense_executor``
    namespaces), equivalence-asserted against the numpy reference, then
    timed with the shared jit/compile/warmup protocol."""
    import jax

    jax.config.update("jax_enable_x64", True)

    from repro.core.dense import dense_variants

    n_dev = jax.device_count()
    ppr = 4 if n_dev % 4 == 0 else (2 if n_dev % 2 == 0 else 1)
    topo = Topology(n_dev, ppr)
    mesh = jax.make_mesh((n_dev,), ("proc",))
    cache = default_plan_cache()
    rng = np.random.default_rng(0)

    out = []
    for coll in DENSE_BENCH_COLLECTIVES:
        counts = _bench_counts(coll, n_dev, n_values)
        for variant in dense_variants(coll, topo):
            plan, _sel = cache.dense_collective(coll, counts, topo,
                                                variant=variant,
                                                params=params)
            fn = cache.dense_executor(plan, mesh, "proc")
            # equivalence first: executor output == numpy reference
            if coll == "allgatherv":
                vals = [rng.normal(size=int(c)) for c in plan.counts]
            else:
                n_tot = int(plan.counts.sum())
                vals = [rng.normal(size=n_tot) for _ in range(n_dev)]
            got = unpack_dense_output(plan, fn(pack_dense_input(plan, vals)))
            for g, r in zip(got, _reference(plan, vals)):
                np.testing.assert_allclose(g, r, rtol=1e-12, atol=1e-12)
            secs = measure_dense_seconds(
                plan, mesh, "proc", iters=iters, warmup=warmup,
                tracer=tracer, executor=fn,
            )
            out.append((
                f"dense/measured/{coll}/{variant}", secs * 1e6,
                f"kind=measured-device|devices={n_dev}"
                f"|rounds={plan.n_rounds}|equiv=ok",
            ))
    ns = cache.snapshot()["namespaces"]
    out.append((
        "dense/plan_cache", 0.0,
        f"kind=exact-plan|dense_plans={ns['dense_plan']['entries']}"
        f"|dense_executors={ns['dense_executor']['entries']}",
    ))
    return out


def dense_rows(smoke: bool, tracer=None) -> List[Tuple[str, float, str]]:
    """The harness section: modeled selection (always, deterministic) +
    measured device rows (small iteration counts under --smoke)."""
    rows = modeled_select_rows()
    if smoke:
        rows += measured_dense_rows(iters=3, warmup=1, n_values=1024,
                                    tracer=tracer)
    else:
        rows += measured_dense_rows(tracer=tracer)
    return rows
