"""Benchmark harness: one function per paper table/figure + roofline report.

Prints ``name,us_per_call,derived`` CSV (and optionally writes the same rows
as JSON).  Network times are *modeled* (locality-aware max-rate, Lassen
parameters) — message counts and bytes are exact plan quantities; rows are
tagged with kind=measured-host / measured-device / modeled-lassen /
exact-plan / dryrun-roofline accordingly.

    PYTHONPATH=src python -m benchmarks.run                 # full paper problem
    PYTHONPATH=src python -m benchmarks.run --rows 65536    # smaller/faster
    PYTHONPATH=src python -m benchmarks.run --smoke         # CI smoke: tiny
        # problem, every section must succeed (exceptions are fatal), rows
        # written to benchmarks/results/smoke.json for artifact upload

``REPRO_BENCH_ROWS`` is honored when ``--rows`` is not given.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys
import time

SMOKE_ROWS = 4096
SMOKE_PROCS = 64          # modeled process count for the smoke problem


def measured_exchange_rows(rows: int):
    """Per-level MEASURED device exchange (auto-selected strategy) on the
    local host-platform mesh; a small problem keeps setup fast.  kind=
    measured-device distinguishes these from the modeled network rows."""
    import jax

    # measured exchanges must move 8-byte values to be comparable with the
    # VALUE_BYTES=8 plan model; without this jnp silently downcasts to f32
    jax.config.update("jax_enable_x64", True)

    from repro.core import LASSEN

    from .amg_comm import level_selection, measured_device_exchange

    bench_rows = min(rows, 65_536)
    n_procs = jax.device_count()
    # one machine model for BOTH the selector report and the measured run,
    # so the strategy column and modeled_us describe the same choice
    params = LASSEN
    selected = {
        lvl: rep
        for lvl, _chosen, rep in level_selection(bench_rows, n_procs, params)
    }
    out = []
    for lvl, strategy, secs in measured_device_exchange(
        bench_rows, n_procs, params=params
    ):
        rep = selected.get(lvl)
        modeled = (f"modeled_us={rep.modeled_times[strategy] * 1e6:.1f}"
                   if rep and strategy in rep.modeled_times else "")
        out.append(
            (f"measured_exchange/L{lvl}", secs * 1e6,
             f"kind=measured-device|strategy={strategy}|{modeled}")
        )
    return out


def setup_exchange_modeled(rows: int, n_procs: int):
    """Setup-phase SpGEMM gathers, standard vs aggregated (modeled)."""
    from .amg_comm import setup_exchange_rows

    return setup_exchange_rows(min(rows, 65_536), n_procs)


def measured_setup_exchange_rows(rows: int):
    """MEASURED setup-phase gather exchanges on the local mesh."""
    import jax

    jax.config.update("jax_enable_x64", True)

    from .amg_comm import measured_setup_exchange

    out = []
    for label, strategy, secs in measured_setup_exchange(min(rows, 65_536)):
        out.append(
            (f"measured_setup_exchange/{label}", secs * 1e6,
             f"kind=measured-device|strategy={strategy}|")
        )
    return out


def moe_comm_rows(smoke: bool):
    """MoE dispatch exchange: modeled per-mode comparison on a paper-scale
    EP group plus MEASURED jitted dispatch (all transports + auto) on the
    local mesh, through the plan/executor cache."""
    from .moe_comm import measured_moe_dispatch, modeled_dispatch_rows

    if smoke:
        rows = modeled_dispatch_rows(tokens_per_lane=256, pods=2,
                                     lanes_per_pod=8)
        rows += measured_moe_dispatch(iters=3, warmup=1)
    else:
        rows = modeled_dispatch_rows()
        rows += measured_moe_dispatch()
    return rows


def build_sections(rows: int, smoke: bool):
    from . import paper_figs, roofline_report

    if smoke:
        # tiny problem, reduced modeled process count / rows-per-proc:
        # every section of the full harness is exercised, nothing takes
        # longer than seconds
        return [
            ("fig6", lambda: paper_figs.fig6_graph_creation(rows)),
            ("fig12", lambda: paper_figs.fig12_strong_scaling(rows)),
            ("fig13", lambda: paper_figs.fig13_weak_scaling(16)),
            ("fig7", lambda: paper_figs.fig7_crossover(rows, SMOKE_PROCS)),
            ("fig8_9",
             lambda: paper_figs.fig8_9_message_counts(rows, SMOKE_PROCS)),
            ("fig10",
             lambda: paper_figs.fig10_message_sizes(rows, SMOKE_PROCS)),
            ("fig11",
             lambda: paper_figs.fig11_per_level_cost(rows, SMOKE_PROCS)),
            ("amg", lambda: paper_figs.amg_solver_convergence(rows)),
            ("setup_exchange",
             lambda: setup_exchange_modeled(rows, SMOKE_PROCS)),
            ("measured_exchange", lambda: measured_exchange_rows(rows)),
            ("measured_setup_exchange",
             lambda: measured_setup_exchange_rows(rows)),
            ("moe_comm", lambda: moe_comm_rows(smoke=True)),
            ("roofline", roofline_report.rows),
        ]
    return [
        ("fig6", lambda: paper_figs.fig6_graph_creation(rows)),
        ("fig7", lambda: paper_figs.fig7_crossover(rows)),
        ("fig8_9", lambda: paper_figs.fig8_9_message_counts(rows)),
        ("fig10", lambda: paper_figs.fig10_message_sizes(rows)),
        ("fig11", lambda: paper_figs.fig11_per_level_cost(rows)),
        ("fig12", lambda: paper_figs.fig12_strong_scaling(rows)),
        ("fig13", lambda: paper_figs.fig13_weak_scaling()),
        ("amg", paper_figs.amg_solver_convergence),
        ("setup_exchange", lambda: setup_exchange_modeled(rows, 256)),
        ("measured_exchange", lambda: measured_exchange_rows(rows)),
        ("measured_setup_exchange",
         lambda: measured_setup_exchange_rows(rows)),
        ("moe_comm", lambda: moe_comm_rows(smoke=False)),
        ("roofline", roofline_report.rows),
    ]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--rows", type=int,
        default=int(os.environ.get("REPRO_BENCH_ROWS", 524_288)),
    )
    ap.add_argument(
        "--smoke", action="store_true",
        help="tiny problem, strict mode: any section exception is fatal, "
        "results JSON written (CI gate for the perf paths)",
    )
    ap.add_argument(
        "--out", default=None,
        help="write results JSON here (default in --smoke mode: "
        "benchmarks/results/smoke.json)",
    )
    args = ap.parse_args(argv)
    rows = SMOKE_ROWS if args.smoke else args.rows
    out_path = args.out
    if out_path is None and args.smoke:
        out_path = str(
            pathlib.Path(__file__).parent / "results" / "smoke.json"
        )

    t_start = time.time()
    collected = []
    failures = []
    print("name,us_per_call,derived")
    for section, fn in build_sections(rows, args.smoke):
        t0 = time.time()
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.2f},{derived}")
                collected.append(
                    {"name": name, "us_per_call": us, "derived": derived}
                )
        except Exception as e:  # keep the harness running (strict in smoke)
            if args.smoke:
                raise
            failures.append(section)
            print(f"{section}/ERROR,0.00,kind=ERROR|{type(e).__name__}:"
                  f"{str(e)[:120]}")
        sys.stdout.flush()
        print(f"# section {section} took {time.time() - t0:.1f}s",
              file=sys.stderr)
    total = time.time() - t_start
    print(f"# total {total:.1f}s", file=sys.stderr)

    if out_path:
        payload = {
            "rows_param": rows,
            "smoke": args.smoke,
            "total_seconds": total,
            "failed_sections": failures,
            "results": collected,
        }
        p = pathlib.Path(out_path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(payload, indent=2))
        print(f"# results JSON: {p}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
