"""Benchmark harness: one function per paper table/figure + roofline report.

Prints ``name,us_per_call,derived`` CSV.  Network times are *modeled*
(locality-aware max-rate, Lassen parameters) — message counts and bytes are
exact plan quantities; rows are tagged with kind=measured-host /
modeled-lassen / exact-plan / dryrun-roofline accordingly.

    PYTHONPATH=src python -m benchmarks.run            # full paper problem
    REPRO_BENCH_ROWS=65536 ... python -m benchmarks.run  # smaller/faster
"""
from __future__ import annotations

import os
import sys
import time


def measured_exchange_rows(rows: int):
    """Per-level MEASURED device exchange (auto-selected strategy) on the
    local host-platform mesh; a small problem keeps setup fast.  kind=
    measured-device distinguishes these from the modeled network rows."""
    import jax

    # measured exchanges must move 8-byte values to be comparable with the
    # VALUE_BYTES=8 plan model; without this jnp silently downcasts to f32
    jax.config.update("jax_enable_x64", True)

    from repro.core import LASSEN

    from .amg_comm import level_selection, measured_device_exchange

    bench_rows = min(rows, 65_536)
    n_procs = jax.device_count()
    # one machine model for BOTH the selector report and the measured run,
    # so the strategy column and modeled_us describe the same choice
    params = LASSEN
    selected = {
        lvl: rep
        for lvl, _chosen, rep in level_selection(bench_rows, n_procs, params)
    }
    out = []
    for lvl, strategy, secs in measured_device_exchange(
        bench_rows, n_procs, params=params
    ):
        rep = selected.get(lvl)
        modeled = (f"modeled_us={rep.modeled_times[strategy] * 1e6:.1f}"
                   if rep and strategy in rep.modeled_times else "")
        out.append(
            (f"measured_exchange/L{lvl}", secs * 1e6,
             f"kind=measured-device|strategy={strategy}|{modeled}")
        )
    return out


def main() -> None:
    rows = int(os.environ.get("REPRO_BENCH_ROWS", 524_288))
    t_start = time.time()
    from . import paper_figs, roofline_report

    sections = [
        ("fig6", lambda: paper_figs.fig6_graph_creation(rows)),
        ("fig7", lambda: paper_figs.fig7_crossover(rows)),
        ("fig8_9", lambda: paper_figs.fig8_9_message_counts(rows)),
        ("fig10", lambda: paper_figs.fig10_message_sizes(rows)),
        ("fig11", lambda: paper_figs.fig11_per_level_cost(rows)),
        ("fig12", lambda: paper_figs.fig12_strong_scaling(rows)),
        ("fig13", lambda: paper_figs.fig13_weak_scaling()),
        ("amg", paper_figs.amg_solver_convergence),
        ("measured_exchange", lambda: measured_exchange_rows(rows)),
        ("roofline", roofline_report.rows),
    ]
    print("name,us_per_call,derived")
    for section, fn in sections:
        t0 = time.time()
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.2f},{derived}")
        except Exception as e:  # keep the harness running
            print(f"{section}/ERROR,0.00,kind=ERROR|{type(e).__name__}:"
                  f"{str(e)[:120]}")
        sys.stdout.flush()
        print(f"# section {section} took {time.time() - t0:.1f}s",
              file=sys.stderr)
    print(f"# total {time.time() - t_start:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
