"""Benchmark harness: one function per paper table/figure + roofline report.

Prints ``name,us_per_call,derived`` CSV (and optionally writes the same rows
as JSON).  Network times are *modeled* (locality-aware max-rate, Lassen
parameters) — message counts and bytes are exact plan quantities; rows are
tagged with kind=measured-host / measured-device / modeled-lassen /
exact-plan / dryrun-roofline accordingly.

    PYTHONPATH=src python -m benchmarks.run                 # full paper problem
    PYTHONPATH=src python -m benchmarks.run --rows 65536    # smaller/faster
    PYTHONPATH=src python -m benchmarks.run --smoke         # CI smoke: tiny
        # problem, every section must succeed (exceptions are fatal), rows
        # written to benchmarks/results/smoke.json for artifact upload

``REPRO_BENCH_ROWS`` is honored when ``--rows`` is not given.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys
import time

SMOKE_ROWS = 4096
SMOKE_PROCS = 64          # modeled process count for the smoke problem
SCHEMA_VERSION = 2        # results-JSON schema (bump on layout changes)


def _git_sha() -> str | None:
    """Best-effort commit stamp so CI artifacts from different PRs are
    comparable; None outside a git checkout."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            capture_output=True, text=True, timeout=5,
            cwd=pathlib.Path(__file__).parent,
        )
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else None
    except Exception:
        return None


def measured_exchange_rows(rows: int, tracer=None):
    """Per-level MEASURED device exchange (auto-selected strategy) on the
    local host-platform mesh; a small problem keeps setup fast.  kind=
    measured-device distinguishes these from the modeled network rows.
    ``tracer`` records every timing for the --calibrate fit (so the
    calibration section reuses these measurements instead of re-timing)."""
    import jax

    # measured exchanges must move 8-byte values to be comparable with the
    # VALUE_BYTES=8 plan model; without this jnp silently downcasts to f32
    jax.config.update("jax_enable_x64", True)

    from repro.core import LASSEN

    from .amg_comm import level_selection, measured_device_exchange

    bench_rows = min(rows, 65_536)
    n_procs = jax.device_count()
    # one machine model for BOTH the selector report and the measured run,
    # so the strategy column and modeled_us describe the same choice
    params = LASSEN
    selected = {
        lvl: rep
        for lvl, _chosen, rep in level_selection(bench_rows, n_procs, params)
    }
    out = []
    for lvl, strategy, secs in measured_device_exchange(
        bench_rows, n_procs, params=params, tracer=tracer
    ):
        rep = selected.get(lvl)
        modeled = (f"modeled_us={rep.modeled_times[strategy] * 1e6:.1f}"
                   if rep and strategy in rep.modeled_times else "")
        out.append(
            (f"measured_exchange/L{lvl}", secs * 1e6,
             f"kind=measured-device|strategy={strategy}|{modeled}")
        )
    return out


def setup_exchange_modeled(rows: int, n_procs: int):
    """Setup-phase SpGEMM gathers, standard vs aggregated (modeled)."""
    from .amg_comm import setup_exchange_rows

    return setup_exchange_rows(min(rows, 65_536), n_procs)


def measured_setup_exchange_rows(rows: int, tracer=None):
    """MEASURED setup-phase gather exchanges on the local mesh."""
    import jax

    jax.config.update("jax_enable_x64", True)

    from .amg_comm import measured_setup_exchange

    out = []
    for label, strategy, secs in measured_setup_exchange(
        min(rows, 65_536), tracer=tracer
    ):
        out.append(
            (f"measured_setup_exchange/{label}", secs * 1e6,
             f"kind=measured-device|strategy={strategy}|")
        )
    return out


def spmv_kernel_rows(rows: int, n_procs: int):
    """Flat vs column-blocked SpMV kernel: deterministic modeled-VMEM
    selection rows (per level + paper-scale fine level) and measured
    CPU-reference / Pallas-interpret timings with equivalence asserted."""
    from .spmv_kernel import measured_rows, selection_rows

    return selection_rows(rows, n_procs) + measured_rows(rows)


def spmv_overlap_rows(rows: int, n_procs: int, tracer=None):
    """Exchange/compute overlap: deterministic modeled decisions (per level
    + paper-scale fine level, which must auto-select ``on``) and measured
    overlap-off vs overlap-on distributed SpMV with equivalence asserted;
    full-SpMV tracer samples carry pure_exchange=False."""
    from .spmv_kernel import measured_overlap_rows, overlap_rows

    return overlap_rows(rows, n_procs) + measured_overlap_rows(rows, tracer)


def dense_comm_rows(smoke: bool, tracer=None):
    """Dense plan-based collectives (allreduce/allgatherv/reduce_scatter):
    deterministic Section-5 selection rows at paper scale (hier must beat
    ring — the dense/select/* gate) plus measured 8-device executions with
    jnp-reference equivalence asserted; pure_exchange samples feed the
    --calibrate fit."""
    from .dense_comm import dense_rows

    return dense_rows(smoke, tracer)


def elastic_replan_rows(rows: int):
    """Elastic re-plan cost (cold setup vs shrink vs warm grow-back vs
    straggler rebalance) through one plan cache: measured-host wall times
    plus exact-plan cache miss/hit deltas — grow_warm is gated at 0
    misses (the warm-resize contract)."""
    from .elastic_bench import elastic_rows

    return elastic_rows(rows)


def moe_comm_rows(smoke: bool, tracer=None):
    """MoE dispatch exchange: modeled per-mode comparison on a paper-scale
    EP group plus MEASURED jitted dispatch (all transports + auto) on the
    local mesh, through the plan/executor cache."""
    from .moe_comm import measured_moe_dispatch, modeled_dispatch_rows

    if smoke:
        rows = modeled_dispatch_rows(tokens_per_lane=256, pods=2,
                                     lanes_per_pod=8)
        rows += measured_moe_dispatch(iters=3, warmup=1, tracer=tracer)
    else:
        rows = modeled_dispatch_rows()
        rows += measured_moe_dispatch(tracer=tracer)
    return rows


def calibration_rows(rows: int, out_dir: pathlib.Path, smoke: bool,
                     tracer=None):
    """The measure -> fit -> re-select loop (ROADMAP's measured-vs-modeled
    calibration item), as one benchmark section.

    Fits MachineParams (``repro.profile.calibrate``) from the trace the
    measured sections recorded earlier in this run (``tracer`` — the
    exchanges are timed once, not re-run), then re-runs Section-5
    selection under the *fitted* rates and reports it side by side with
    the shipped-constant selection — flagging every level/mode where the
    choice flips.  Standalone use (no pre-filled tracer) measures the
    per-level AMG and setup-phase gather exchanges itself.  The trace and
    the fitted params are written as JSON next to the results artifact.
    Non-finite fitted params or an unbounded residual raise (fatal in
    --smoke: the CI calibration gate).
    """
    import jax

    jax.config.update("jax_enable_x64", True)

    import numpy as np

    from repro.core import LASSEN
    from repro.models.moe import STRATEGY_OF_MODE, select_moe_mode
    from repro.profile import TraceRecorder, fit_trace, selection_flips

    from .amg_comm import (
        VALUE_BYTES,
        bench_topology,
        level_patterns,
        measured_device_exchange,
        measured_setup_exchange,
    )
    from .moe_comm import dispatch_plan, measured_moe_dispatch

    bench_rows = min(rows, 65_536)
    n_procs = jax.device_count()
    shipped = LASSEN
    if tracer is None:
        tracer = TraceRecorder()
    if not tracer.merged_rate_samples():
        # standalone: the measured sections did not run first — time the
        # pure exchanges here (MoE dispatch rows are reporting-only:
        # pure_exchange=False, they include expert compute)
        measured_device_exchange(bench_rows, n_procs, params=shipped,
                                 tracer=tracer)
        measured_setup_exchange(bench_rows, params=shipped, tracer=tracer)
        measured_moe_dispatch(iters=2, warmup=1, tracer=tracer)

    # --- fit --------------------------------------------------------------
    result = fit_trace(tracer, name=f"fitted-{shipped.name}", ref=shipped)
    fitted = result.params
    gof = result.gof
    # artifacts FIRST: a diverged fit is exactly when the trace must be
    # inspectable, so the JSONs exist even if the gate below raises
    out_dir.mkdir(parents=True, exist_ok=True)
    tracer.save(out_dir / "trace.json")
    result.save(out_dir / "fitted_params.json")
    # one definition of "converged" (CalibrationResult: gof flag + finite
    # params) plus a residual bound — the CI calibration gate
    if not result.converged or not np.isfinite(gof["rel_rmse"]) \
            or gof["rel_rmse"] > 10.0:
        raise RuntimeError(
            f"calibration fit did not converge: "
            f"converged={result.converged} gof={gof}"
        )

    out = []
    s = tracer.summary()
    out.append((
        "calibrate/trace", 0.0,
        f"kind=measured-device|samples={s['samples']}"
        f"|pure={s['pure_samples']}|patterns={s['patterns']}",
    ))
    for f in ("alpha_intra", "beta_intra", "alpha_inter", "beta_inter",
              "region_injection_bw"):
        a, b = float(getattr(shipped, f)), float(getattr(fitted, f))
        out.append((
            f"calibrate/params/{f}", 0.0,
            f"kind=measured-fit|shipped={a:.4g}|fitted={b:.4g}"
            f"|ratio={b / a:.3f}",
        ))
    out.append((
        "calibrate/fit", 0.0,
        f"kind=measured-fit|n={result.n_samples}"
        f"|rel_rmse={gof['rel_rmse']:.4f}|r2={gof['r2']:.4f}"
        f"|iters={int(gof['outer_iters'])}"
        f"|converged={bool(gof['converged'])}",
    ))

    # --- re-select: Section-5 under fitted vs shipped rates ---------------
    labeled = [
        (f"L{lvl}", pat)
        for lvl, (pat, _n) in enumerate(level_patterns(bench_rows, n_procs))
    ]
    flip_rows = selection_flips(labeled, bench_topology(n_procs), shipped,
                                fitted, value_bytes=VALUE_BYTES)
    flips = 0
    for r in flip_rows:
        flips += r["flip"] == "yes"
        out.append((
            f"calibrate/selection/{r['label']}", 0.0,
            f"kind=measured-fit|shipped={r['shipped']}"
            f"|fitted={r['fitted']}|flip={r['flip']}",
        ))
    # MoE dispatch mode selection under both parameter sets
    geom = dispatch_plan(tokens_per_lane=256, pods=2, lanes_per_pod=8) \
        if smoke else dispatch_plan()
    vb = 4096 * 2
    mode_s, _ = select_moe_mode(geom, 256 if smoke else 1024, vb, shipped)
    mode_f, _ = select_moe_mode(geom, 256 if smoke else 1024, vb, fitted)
    out.append((
        "calibrate/selection/moe", 0.0,
        f"kind=measured-fit|shipped={mode_s}|fitted={mode_f}"
        f"|flip={'yes' if mode_s != mode_f else 'no'}"
        f"|strategies={STRATEGY_OF_MODE[mode_s]}->"
        f"{STRATEGY_OF_MODE[mode_f]}",
    ))
    out.append((
        "calibrate/flips", float(flips),
        f"kind=measured-fit|levels={len(flip_rows)}"
        f"|topo={bench_topology(n_procs).n_regions}regions",
    ))

    return out


def verify_rows(rows: int):
    """Wall time of the static plan/kernel verifier (``repro.verify``) —
    what ``REPRO_VERIFY=1`` adds on top of plan construction.  Each row
    times one full verification sweep (structure + conservation + device
    plan + layouts + kernel budgets) over plans built beforehand, so the
    number is the verifier alone; kind=measured-host rows are
    band-compared by ``benchmarks.compare``, never exact."""
    import jax

    from repro.amg import DistributedHierarchy, build_hierarchy, diffusion_2d
    from repro.configs import reduced
    from repro.core import PlanCache
    from repro.models.moe import moe_plan_for
    from repro.verify import verify_hierarchy, verify_moe_dispatch

    n = max(int(round(rows ** 0.5)), 16)
    n_procs = jax.device_count()
    mesh = jax.make_mesh((n_procs,), ("proc",))
    A = diffusion_2d(n, n)
    cache = PlanCache()
    out = []

    for label, kwargs in (
        ("hierarchy", {}),
        ("hierarchy_blocked",
         {"spmv_variant": "blocked", "spmv_block_cols": 64}),
    ):
        dh = DistributedHierarchy.setup(
            build_hierarchy(A), mesh, procs_per_region=4, cache=cache,
            **kwargs,
        )
        t0 = time.perf_counter()
        counts = verify_hierarchy(dh)
        dt = time.perf_counter() - t0
        out.append((
            f"verify/wall_seconds/{label}", dt * 1e6,
            f"kind=measured-host|seconds={dt:.4f}"
            f"|levels={counts.get('levels', 0)}"
            f"|collectives={counts.get('collectives', 0)}"
            f"|partitions={counts.get('partitions', 0)}",
        ))

    cfg = reduced("mixtral-8x7b")
    moe_mesh = jax.make_mesh((1, n_procs), ("data", "model"))
    modes = ("a2a", "hier", "hier_dedup")
    plans = [moe_plan_for(cfg, moe_mesh, 64, mode=m, cache=cache)
             for m in modes]
    t0 = time.perf_counter()
    for plan in plans:
        verify_moe_dispatch(plan, 64)
    dt = time.perf_counter() - t0
    out.append((
        "verify/wall_seconds/moe_dispatch", dt * 1e6,
        f"kind=measured-host|seconds={dt:.4f}|modes={len(modes)}",
    ))
    return out


def obs_rows(rows: int, out_dir: pathlib.Path):
    """Telemetry layer (``repro.obs``) smoke: deterministic plan-cache
    counter rows, a deterministic span-count row, measured disabled-path
    overhead, and a Perfetto trace artifact.

    The ``obs/plan_cache/*`` and ``obs/spans/*`` rows are kind=exact-plan
    and **exactly** gated by ``benchmarks.compare`` (rtol=0 for ``obs/*``):
    the same program must produce the same hit/miss/span counts on every
    machine.  The pattern set is built host-side against a fixed
    ``Topology(8, 4)``, independent of the real device count."""
    import numpy as np

    from repro.core import CommPattern, PlanCache, Topology
    from repro.obs import Obs, default_obs, now as _now

    out = []
    obs = default_obs()
    was_enabled = obs.enabled
    obs.reset().enable()
    try:
        topo = Topology(8, 4)
        n_per = max(rows // topo.n_procs, 16)
        rng = np.random.default_rng(0)
        offsets = np.arange(topo.n_procs + 1) * n_per
        patterns = []
        for seed in range(4):
            rng = np.random.default_rng(seed)
            needs = [np.sort(rng.choice(topo.n_procs * n_per, size=12,
                                        replace=False))
                     for _ in range(topo.n_procs)]
            patterns.append(CommPattern.from_block_partition(needs, offsets))

        cache = PlanCache()
        before = obs.snapshot()
        for pat in patterns:                      # cold: every plan misses
            for strat in ("standard", "full"):
                cache.collective(pat, topo, strat)
        cold = obs.delta(before)["counters"].get("plan_cache/misses", [])
        cold_misses = sum(r["value"] for r in cold
                          if r["labels"].get("ns") == "collective")
        before = obs.snapshot()
        for pat in patterns:                      # warm: every plan hits
            for strat in ("standard", "full"):
                cache.collective(pat, topo, strat)
        d = obs.delta(before)["counters"]
        warm_hits = sum(r["value"]
                        for r in d.get("plan_cache/hits", [])
                        if r["labels"].get("ns") == "collective")
        warm_misses = sum(r["value"]
                          for r in d.get("plan_cache/misses", [])
                          if r["labels"].get("ns") == "collective")
        out.append((
            "obs/plan_cache/cold_misses", cold_misses,
            f"kind=exact-plan|patterns={len(patterns)}|strategies=2",
        ))
        out.append((
            "obs/plan_cache/warm_hits", warm_hits,
            f"kind=exact-plan|warm_misses={warm_misses:.0f}",
        ))

        # span determinism: a fixed-iteration loop emits exactly that many
        # spans (the solver's vcycle_iter span contract, mesh-free here)
        iters = 5
        for it in range(iters):
            with obs.span("bench/obs_iter", iter=it):
                pass
        n_spans = sum(1 for e in obs.spans.events(kind="span")
                      if e.name == "bench/obs_iter")
        out.append((
            "obs/spans/loop_iters", float(n_spans),
            f"kind=exact-plan|iters={iters}",
        ))

        # disabled-path overhead: counter inc + span open on a DISABLED
        # private Obs, reported as ns/op (measured, band-compared)
        off = Obs()
        c_off = off.counter("bench/off", "")
        n = 200_000
        t0 = _now()
        for _ in range(n):
            c_off.inc()
        dt_counter = (_now() - t0) / n
        t0 = _now()
        for _ in range(n):
            off.span("bench/off")
        dt_span = (_now() - t0) / n
        out.append((
            "obs/overhead/counter_disabled", dt_counter * 1e6,
            f"kind=measured-host|ns_per_op={dt_counter * 1e9:.1f}",
        ))
        out.append((
            "obs/overhead/span_disabled", dt_span * 1e6,
            f"kind=measured-host|ns_per_op={dt_span * 1e9:.1f}",
        ))

        # the Perfetto artifact CI uploads next to the results JSON
        out_dir.mkdir(parents=True, exist_ok=True)
        trace_path = out_dir / "obs_trace.json"
        obs.export_perfetto(trace_path)
        out.append((
            "obs/export/trace_events",
            float(len(obs.to_perfetto()["traceEvents"])),
            f"kind=measured-host|path={trace_path.name}",
        ))
    finally:
        if not was_enabled:
            obs.disable()
    return out


def build_sections(rows: int, smoke: bool, tracer=None):
    """Section list; ``tracer`` (set by --calibrate) makes the measured
    sections record their timings so the calibration fit reuses them
    instead of re-timing the same exchanges."""
    from . import paper_figs, roofline_report

    if smoke:
        # tiny problem, reduced modeled process count / rows-per-proc:
        # every section of the full harness is exercised, nothing takes
        # longer than seconds
        return [
            ("fig6", lambda: paper_figs.fig6_graph_creation(rows)),
            ("fig12", lambda: paper_figs.fig12_strong_scaling(rows)),
            ("fig13", lambda: paper_figs.fig13_weak_scaling(16)),
            ("fig7", lambda: paper_figs.fig7_crossover(rows, SMOKE_PROCS)),
            ("fig8_9",
             lambda: paper_figs.fig8_9_message_counts(rows, SMOKE_PROCS)),
            ("fig10",
             lambda: paper_figs.fig10_message_sizes(rows, SMOKE_PROCS)),
            ("fig11",
             lambda: paper_figs.fig11_per_level_cost(rows, SMOKE_PROCS)),
            ("amg", lambda: paper_figs.amg_solver_convergence(rows)),
            ("setup_exchange",
             lambda: setup_exchange_modeled(rows, SMOKE_PROCS)),
            ("spmv_kernel", lambda: spmv_kernel_rows(rows, SMOKE_PROCS)),
            ("spmv_overlap",
             lambda: spmv_overlap_rows(rows, SMOKE_PROCS, tracer)),
            ("measured_exchange",
             lambda: measured_exchange_rows(rows, tracer)),
            ("measured_setup_exchange",
             lambda: measured_setup_exchange_rows(rows, tracer)),
            ("moe_comm", lambda: moe_comm_rows(smoke=True,
                                               tracer=tracer)),
            ("dense_comm", lambda: dense_comm_rows(smoke=True,
                                                   tracer=tracer)),
            ("elastic", lambda: elastic_replan_rows(rows)),
            ("roofline", roofline_report.rows),
        ]
    return [
        ("fig6", lambda: paper_figs.fig6_graph_creation(rows)),
        ("fig7", lambda: paper_figs.fig7_crossover(rows)),
        ("fig8_9", lambda: paper_figs.fig8_9_message_counts(rows)),
        ("fig10", lambda: paper_figs.fig10_message_sizes(rows)),
        ("fig11", lambda: paper_figs.fig11_per_level_cost(rows)),
        ("fig12", lambda: paper_figs.fig12_strong_scaling(rows)),
        ("fig13", lambda: paper_figs.fig13_weak_scaling()),
        ("amg", paper_figs.amg_solver_convergence),
        ("setup_exchange", lambda: setup_exchange_modeled(rows, 256)),
        ("spmv_kernel", lambda: spmv_kernel_rows(rows, 256)),
        ("spmv_overlap", lambda: spmv_overlap_rows(rows, 256, tracer)),
        ("measured_exchange",
         lambda: measured_exchange_rows(rows, tracer)),
        ("measured_setup_exchange",
         lambda: measured_setup_exchange_rows(rows, tracer)),
        ("moe_comm", lambda: moe_comm_rows(smoke=False, tracer=tracer)),
        ("dense_comm", lambda: dense_comm_rows(smoke=False, tracer=tracer)),
        ("elastic", lambda: elastic_replan_rows(rows)),
        ("roofline", roofline_report.rows),
    ]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--rows", type=int,
        default=int(os.environ.get("REPRO_BENCH_ROWS", 524_288)),
    )
    ap.add_argument(
        "--smoke", action="store_true",
        help="tiny problem, strict mode: any section exception is fatal, "
        "results JSON written (CI gate for the perf paths)",
    )
    ap.add_argument(
        "--out", default=None,
        help="write results JSON here (default in --smoke mode: "
        "benchmarks/results/smoke.json)",
    )
    ap.add_argument(
        "--verify", action="store_true",
        help="time the static plan/kernel verifier (repro.verify) over the "
        "smoke hierarchy + MoE plans and report verify/wall_seconds/* rows "
        "(always on in --smoke)",
    )
    ap.add_argument(
        "--calibrate", action="store_true",
        help="run the measure->fit->re-select calibration loop: measure "
        "real exchanges, fit MachineParams (repro.profile), rerun the "
        "Section-5 selector under fitted rates, report any mode flips; "
        "writes trace.json + fitted_params.json next to the results JSON",
    )
    args = ap.parse_args(argv)
    rows = SMOKE_ROWS if args.smoke else args.rows
    out_path = args.out
    if out_path is None and args.smoke:
        out_path = str(
            pathlib.Path(__file__).parent / "results" / "smoke.json"
        )

    t_start = time.time()
    collected = []
    failures = []
    tracer = None
    if args.calibrate:
        from repro.profile import TraceRecorder

        tracer = TraceRecorder()   # shared: measured sections feed the fit
    art_dir = (pathlib.Path(out_path).parent if out_path
               else pathlib.Path(__file__).parent / "results")
    sections = build_sections(rows, args.smoke, tracer)
    if args.smoke or args.verify:
        sections.append(("verify", lambda: verify_rows(rows)))
    sections.append(("obs", lambda: obs_rows(rows, art_dir)))
    if args.calibrate:
        sections.append(
            ("calibrate",
             lambda: calibration_rows(rows, art_dir, args.smoke, tracer))
        )
    print("name,us_per_call,derived")
    for section, fn in sections:
        t0 = time.time()
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.2f},{derived}")
                collected.append(
                    {"name": name, "us_per_call": us, "derived": derived}
                )
        except Exception as e:  # keep the harness running (strict in smoke)
            if args.smoke:
                raise
            failures.append(section)
            print(f"{section}/ERROR,0.00,kind=ERROR|{type(e).__name__}:"
                  f"{str(e)[:120]}")
        sys.stdout.flush()
        print(f"# section {section} took {time.time() - t0:.1f}s",
              file=sys.stderr)
    total = time.time() - t_start
    print(f"# total {total:.1f}s", file=sys.stderr)

    if out_path:
        payload = {
            "schema_version": SCHEMA_VERSION,
            "git_sha": _git_sha(),
            "rows_param": rows,
            "smoke": args.smoke,
            "total_seconds": total,
            "failed_sections": failures,
            "results": collected,
        }
        p = pathlib.Path(out_path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(payload, indent=2))
        print(f"# results JSON: {p}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
