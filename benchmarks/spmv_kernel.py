"""Flat vs column-blocked SpMV kernel benchmark rows.

Two row families, matching the repo's modeled/measured labeling:

* :func:`selection_rows` — DETERMINISTIC modeled-VMEM footprints and the
  resulting flat-vs-blocked choice, per AMG level of the benchmark problem
  plus a paper-scale synthetic fine level (per-device x far beyond VMEM)
  that must come out ``blocked``.  These rows are exact arithmetic on block
  geometry (no timing) and are gated tightly by ``benchmarks.compare``.

* :func:`measured_rows` — MEASURED wall-clock of both kernel variants on
  this host: the jnp reference path (CPU backend) on the benchmark fine
  level, and the real Pallas kernels in interpret mode on a small problem.
  Before timing, both variants are asserted equivalent to the host matvec —
  the benchmark doubles as an equivalence gate in CI smoke.

Overlap row families (the exchange/compute-overlap schedule):

* :func:`overlap_rows` — DETERMINISTIC modeled overlap decisions per AMG
  level (exchange time from the plan model, local compute from the roofline
  compute model) plus the paper-scale analytic fine level, which must come
  out ``on`` (its local compute dwarfs both the exchange and the split
  overhead).  Exposed/hidden exchange times are exact cost-model arithmetic.

* :func:`measured_overlap_rows` — MEASURED wall-clock of the full
  distributed SpMV on the local device mesh under both schedules (overlap
  off vs on), next to the pure exchange and a kernel-only run, from which a
  measured exposed-exchange fraction is derived.  Both schedules are
  asserted equivalent to the host matvec before timing.  On the CPU host
  platform collectives are synchronous, so the measured fractions mainly
  document what XLA already hides; the modeled fields carry the v5e story.
"""
from __future__ import annotations

import time

import numpy as np

from repro.amg import diffusion_2d
from repro.core import LASSEN, TPU_V5E, build_plan, plan_time
from repro.core.costmodel import modeled_fine_exchange_time, spmv_compute_time
from repro.sparse import (
    default_spmv_vmem_limit,
    overlap_decision,
    partition_csr,
    partitioned_to_ell,
    partitioned_to_ell_blocked,
    select_spmv_kernel,
    select_spmv_overlap,
    spmv_blocked_vmem_bytes,
    spmv_flat_vmem_bytes,
)

from .amg_comm import VALUE_BYTES, bench_topology, hierarchy_for

#: Paper-scale synthetic fine level: ~2M unknowns per device (the scale at
#: which the paper's BoomerAMG fine levels run), 9-point stencil, a
#: two-cell-deep halo — per-device x alone is ~17 MB, past any VMEM tier.
PAPER_ROWS_PER_PROC = 2 ** 21
PAPER_K = 9
PAPER_GHOST = 2 * 4096
#: Inter-device neighbors of the analytic fine level: a two-deep halo on a
#: 2-D decomposition touches all eight surrounding subdomains.
PAPER_NEIGHBORS = 8


def _kib(b: int) -> str:
    return f"{b / 2 ** 10:.1f}"


def selection_rows(rows: int, n_procs: int):
    """Modeled footprint + variant choice per level and at paper scale."""
    out = []
    h = hierarchy_for(rows)
    for k, lvl in enumerate(h.levels):
        if lvl.A.nrows < n_procs:
            break
        part = partition_csr(lvl.A, n_procs)
        sel = select_spmv_kernel(part, value_bytes=VALUE_BYTES)
        out.append((
            f"spmv_kernel/select/L{k}", 0.0,
            f"kind=modeled-vmem|flat_kib={_kib(sel.flat_bytes)}"
            f"|blocked_kib={_kib(sel.blocked_bytes)}"
            f"|limit_kib={_kib(sel.limit_bytes)}|variant={sel.variant}",
        ))
    # paper-scale fine level from analytic geometry (the matrix itself is
    # never materialized): x footprint alone exceeds the threshold, so the
    # selector must fall over to the column-blocked kernel
    limit = default_spmv_vmem_limit()
    flat = spmv_flat_vmem_bytes(
        in_pad=PAPER_ROWS_PER_PROC, ghost_pad=PAPER_GHOST,
        k_local=PAPER_K, k_ghost=PAPER_K, value_bytes=VALUE_BYTES,
        rows=PAPER_ROWS_PER_PROC,
    )
    blocked = spmv_blocked_vmem_bytes(
        bucket_k=PAPER_K, value_bytes=VALUE_BYTES, rows=PAPER_ROWS_PER_PROC,
    )
    variant = "flat" if flat <= limit else "blocked"
    assert variant == "blocked", (flat, limit)  # paper scale MUST block
    out.append((
        "spmv_kernel/select/paper_fine", 0.0,
        f"kind=modeled-vmem|rows_per_proc={PAPER_ROWS_PER_PROC}"
        f"|flat_kib={_kib(flat)}|blocked_kib={_kib(blocked)}"
        f"|limit_kib={_kib(limit)}|variant={variant}",
    ))
    return out


def _time_fn(fn, x, iters: int, warmup: int) -> float:
    for _ in range(warmup):
        np.asarray(fn(x))
    t0 = time.perf_counter()
    for _ in range(iters):
        np.asarray(fn(x))
    return (time.perf_counter() - t0) / iters


def _single_proc_layouts(A, block_cols: int):
    """Both device layouts of an unpartitioned operator (1-proc partition:
    no ghosts, so the kernels are exercised in isolation)."""
    part = partition_csr(A, 1)
    return partitioned_to_ell(part), partitioned_to_ell_blocked(
        part, block_cols=block_cols
    )


def _check_and_time(A, block_cols: int, backend_name: str,
                    iters: int, warmup: int):
    """Assert flat == blocked == host matvec, then time both variants."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import use_backend
    from repro.kernels.spmv_ell.ops import spmv, spmv_blocked

    ell, bell = _single_proc_layouts(A, block_cols)
    rng = np.random.default_rng(7)
    x = rng.normal(size=A.ncols)
    want = A.matvec(x)

    xf = jnp.asarray(np.concatenate([x, [0.0]]))        # flat sentinel slot
    xb = np.zeros(bell.x_len)
    xb[: A.ncols] = x
    xb = jnp.asarray(xb)
    lc = jnp.asarray(ell.local_cols[0])
    lv = jnp.asarray(ell.local_vals[0])
    bc_ = jnp.asarray(bell.cols[0])
    bv = jnp.asarray(bell.vals[0])

    with use_backend(backend_name):
        flat_fn = jax.jit(lambda v: spmv(lc, lv, v))
        blocked_fn = jax.jit(
            lambda v: spmv_blocked(bc_, bv, v, bell.block_cols)
        )
        got_flat = np.asarray(flat_fn(xf))[: A.nrows]
        got_blocked = np.asarray(blocked_fn(xb))[: A.nrows]
        np.testing.assert_allclose(got_flat, want, rtol=1e-6, atol=1e-8)
        np.testing.assert_allclose(got_blocked, got_flat,
                                   rtol=1e-6, atol=1e-8)
        t_flat = _time_fn(flat_fn, xf, iters, warmup)
        t_blocked = _time_fn(blocked_fn, xb, iters, warmup)
    return t_flat, t_blocked, bell


def measured_rows(rows: int):
    """Measured flat/blocked timings: jnp reference path on the benchmark
    fine level, Pallas interpret mode on a small problem."""
    import jax

    # equivalence checks compare against the f64 host matvec
    jax.config.update("jax_enable_x64", True)
    out = []
    # -- CPU reference path on the fine level ------------------------------
    A = hierarchy_for(min(rows, 65_536)).levels[0].A
    t_flat, t_blocked, bell = _check_and_time(
        A, block_cols=512, backend_name="reference", iters=10, warmup=2
    )
    geom = (f"rows={A.nrows}|buckets={bell.n_buckets}"
            f"|bucket_k={bell.K}")
    out.append((
        "spmv_kernel/measured/flat_ref", t_flat * 1e6,
        f"kind=measured-host|backend=reference|{geom}",
    ))
    out.append((
        "spmv_kernel/measured/blocked_ref", t_blocked * 1e6,
        f"kind=measured-host|backend=reference|{geom}"
        f"|vs_flat={t_blocked / max(t_flat, 1e-12):.2f}x",
    ))
    # -- Pallas kernels in interpret mode (small: interpret is python) -----
    As = diffusion_2d(16, 16)
    t_flat, t_blocked, bell = _check_and_time(
        As, block_cols=64, backend_name="pallas_interpret",
        iters=2, warmup=1,
    )
    geom = f"rows={As.nrows}|buckets={bell.n_buckets}|bucket_k={bell.K}"
    out.append((
        "spmv_kernel/measured/flat_interpret", t_flat * 1e6,
        f"kind=measured-host|backend=pallas_interpret|{geom}",
    ))
    out.append((
        "spmv_kernel/measured/blocked_interpret", t_blocked * 1e6,
        f"kind=measured-host|backend=pallas_interpret|{geom}",
    ))
    return out


# ---------------------------------------------------------------------------
# exchange/compute overlap
# ---------------------------------------------------------------------------

def _overlap_fields(osel) -> str:
    return (
        f"mode={osel.mode}|tx_us={osel.exchange_s * 1e6:.3f}"
        f"|local_us={osel.local_s * 1e6:.3f}"
        f"|exposed_us={osel.exposed_s * 1e6:.3f}"
        f"|hidden_frac={osel.hidden_frac:.4f}"
        f"|overhead_us={osel.overhead_s * 1e6:.3f}"
    )


def overlap_rows(rows: int, n_procs: int):
    """Modeled overlap decision per level and at paper scale (deterministic).

    Per benchmark-problem level: exchange time from the standard-strategy
    plan under the Lassen postal/max-rate model, local compute from the
    roofline compute model — the same inputs ``DistributedHierarchy.setup``
    feeds ``select_spmv_overlap``.  The trailing ``paper_fine`` row models
    the analytic paper-scale fine level on v5e, where auto MUST choose
    ``on``: hiding the ~90us DCI exchange behind ~300us of local compute
    beats the split overhead (one carried-y HBM round trip).
    """
    out = []
    h = hierarchy_for(rows)
    topo = bench_topology(n_procs)
    for k, lvl in enumerate(h.levels):
        if lvl.A.nrows < n_procs:
            break
        part = partition_csr(lvl.A, n_procs)
        plan = build_plan(part.pattern, topo, "standard",
                          value_bytes=VALUE_BYTES)
        osel = select_spmv_overlap(
            part, plan_time(plan, LASSEN), value_bytes=VALUE_BYTES
        )
        out.append((
            f"spmv_overlap/select/L{k}", 0.0,
            f"kind=modeled-overlap|{_overlap_fields(osel)}",
        ))
    # paper-scale analytic fine level (never materialized): exchange from
    # the postal model, local compute from the roofline compute model
    tx = modeled_fine_exchange_time(
        PAPER_NEIGHBORS, PAPER_GHOST, value_bytes=VALUE_BYTES,
        params=TPU_V5E,
    )
    tl = spmv_compute_time(
        PAPER_ROWS_PER_PROC * PAPER_K, PAPER_ROWS_PER_PROC,
        PAPER_ROWS_PER_PROC + PAPER_GHOST, value_bytes=VALUE_BYTES,
    )
    osel = overlap_decision(
        tx, tl, rows=PAPER_ROWS_PER_PROC, value_bytes=VALUE_BYTES
    )
    assert osel.mode == "on", osel  # paper scale MUST overlap
    out.append((
        "spmv_overlap/select/paper_fine", 0.0,
        f"kind=modeled-overlap|rows_per_proc={PAPER_ROWS_PER_PROC}"
        f"|neighbors={PAPER_NEIGHBORS}|{_overlap_fields(osel)}",
    ))
    return out


def measured_overlap_rows(rows: int, tracer=None):
    """Measured overlap-off vs overlap-on distributed SpMV on the local mesh.

    Builds the benchmark fine level's blocked layout over all host devices,
    asserts both schedules match the host matvec, then times the pure
    exchange, a kernel-only run (exchange stubbed to zeros), and the full
    SpMV under both schedules.  The derived ``exposed_frac`` is the measured
    exchange time left visible in the full run: ``(t_full - t_kernel)/t_x``
    clamped to [0, 1].  Full-SpMV timings recorded to ``tracer`` carry
    ``pure_exchange=False`` so they never enter wire-rate calibration.
    """
    import jax
    import jax.numpy as jnp

    jax.config.update("jax_enable_x64", True)

    from repro.core import default_plan_cache, time_executor
    from repro.sparse import (
        make_distributed_spmv,
        pack_vector,
        unpack_vector,
    )

    n_procs = jax.device_count()
    mesh = jax.make_mesh((n_procs,), ("proc",))
    topo = bench_topology(n_procs)
    A = hierarchy_for(min(rows, 65_536)).levels[0].A
    part = partition_csr(A, n_procs)
    cache = default_plan_cache()
    coll = cache.collective(part.pattern, topo, "auto",
                            value_bytes=VALUE_BYTES, params=LASSEN)
    exchange = cache.executor(part.pattern, topo, mesh, "proc", "auto",
                              value_bytes=VALUE_BYTES, params=LASSEN)
    bell = partitioned_to_ell_blocked(part, block_cols=512)
    osel = select_spmv_overlap(
        part, plan_time(coll.plan, LASSEN), value_bytes=VALUE_BYTES
    )

    def kernel_only_exchange(v):
        # same gather geometry, no wire: isolates the kernel time
        return jnp.zeros((bell.n_procs, bell.ghost_pad, 1), v.dtype)

    fns = {
        "kernel_only": jax.jit(make_distributed_spmv(
            bell, mesh, "proc", kernel_only_exchange, overlap=False)),
        "off": jax.jit(make_distributed_spmv(
            bell, mesh, "proc", exchange, overlap=False)),
        "on": jax.jit(make_distributed_spmv(
            bell, mesh, "proc", exchange, overlap=True)),
    }

    # equivalence gate before any timing
    rng = np.random.default_rng(11)
    x = rng.normal(size=A.ncols)
    want = A.matvec(x)
    xg = jnp.asarray(pack_vector(part.col_offsets, bell.in_pad, x))
    for mode in ("off", "on"):
        got = unpack_vector(part.offsets, np.asarray(fns[mode](xg)))
        np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-12)

    t_x = time_executor(exchange, n_procs, bell.in_pad,
                        dtype=np.float64, iters=10, warmup=2)
    if tracer is not None:
        tracer.record_plan(coll.plan, t_x, label="spmv_overlap/exchange",
                           pure_exchange=True)
    times = {}
    for mode, fn in fns.items():
        times[mode] = _time_fn(fn, xg, iters=10, warmup=2)
        if tracer is not None and mode != "kernel_only":
            tracer.record_plan(
                coll.plan, times[mode], label=f"spmv_overlap/{mode}",
                pure_exchange=False,
            )
    t_k = times["kernel_only"]

    def exposed_frac(t_full: float) -> float:
        if t_x <= 0.0:
            return 0.0
        return min(max((t_full - t_k) / t_x, 0.0), 1.0)

    geom = (f"rows={A.nrows}|n_procs={n_procs}|buckets={bell.n_buckets}"
            f"|local_buckets={bell.n_local_buckets}|ghost_pad={bell.ghost_pad}")
    out = [
        ("spmv_overlap/measured/exchange", t_x * 1e6,
         f"kind=measured-device|{geom}"),
        ("spmv_overlap/measured/kernel_only", times["kernel_only"] * 1e6,
         f"kind=measured-device|{geom}"),
    ]
    modeled_exposed = {
        "off": osel.exchange_s,
        "on": max(0.0, osel.exchange_s - osel.local_s),
    }
    for mode in ("off", "on"):
        out.append((
            f"spmv_overlap/measured/{mode}", times[mode] * 1e6,
            f"kind=measured-device|overlap={mode}"
            f"|exposed_frac={exposed_frac(times[mode]):.4f}"
            f"|modeled_exposed_us={modeled_exposed[mode] * 1e6:.3f}"
            f"|modeled_mode={osel.mode}|{geom}",
        ))
    return out
