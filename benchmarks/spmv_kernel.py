"""Flat vs column-blocked SpMV kernel benchmark rows.

Two row families, matching the repo's modeled/measured labeling:

* :func:`selection_rows` — DETERMINISTIC modeled-VMEM footprints and the
  resulting flat-vs-blocked choice, per AMG level of the benchmark problem
  plus a paper-scale synthetic fine level (per-device x far beyond VMEM)
  that must come out ``blocked``.  These rows are exact arithmetic on block
  geometry (no timing) and are gated tightly by ``benchmarks.compare``.

* :func:`measured_rows` — MEASURED wall-clock of both kernel variants on
  this host: the jnp reference path (CPU backend) on the benchmark fine
  level, and the real Pallas kernels in interpret mode on a small problem.
  Before timing, both variants are asserted equivalent to the host matvec —
  the benchmark doubles as an equivalence gate in CI smoke.
"""
from __future__ import annotations

import time

import numpy as np

from repro.amg import diffusion_2d
from repro.sparse import (
    default_spmv_vmem_limit,
    partition_csr,
    partitioned_to_ell,
    partitioned_to_ell_blocked,
    select_spmv_kernel,
    spmv_blocked_vmem_bytes,
    spmv_flat_vmem_bytes,
)

from .amg_comm import VALUE_BYTES, hierarchy_for

#: Paper-scale synthetic fine level: ~2M unknowns per device (the scale at
#: which the paper's BoomerAMG fine levels run), 9-point stencil, a
#: two-cell-deep halo — per-device x alone is ~17 MB, past any VMEM tier.
PAPER_ROWS_PER_PROC = 2 ** 21
PAPER_K = 9
PAPER_GHOST = 2 * 4096


def _kib(b: int) -> str:
    return f"{b / 2 ** 10:.1f}"


def selection_rows(rows: int, n_procs: int):
    """Modeled footprint + variant choice per level and at paper scale."""
    out = []
    h = hierarchy_for(rows)
    for k, lvl in enumerate(h.levels):
        if lvl.A.nrows < n_procs:
            break
        part = partition_csr(lvl.A, n_procs)
        sel = select_spmv_kernel(part, value_bytes=VALUE_BYTES)
        out.append((
            f"spmv_kernel/select/L{k}", 0.0,
            f"kind=modeled-vmem|flat_kib={_kib(sel.flat_bytes)}"
            f"|blocked_kib={_kib(sel.blocked_bytes)}"
            f"|limit_kib={_kib(sel.limit_bytes)}|variant={sel.variant}",
        ))
    # paper-scale fine level from analytic geometry (the matrix itself is
    # never materialized): x footprint alone exceeds the threshold, so the
    # selector must fall over to the column-blocked kernel
    limit = default_spmv_vmem_limit()
    flat = spmv_flat_vmem_bytes(
        in_pad=PAPER_ROWS_PER_PROC, ghost_pad=PAPER_GHOST,
        k_local=PAPER_K, k_ghost=PAPER_K, value_bytes=VALUE_BYTES,
        rows=PAPER_ROWS_PER_PROC,
    )
    blocked = spmv_blocked_vmem_bytes(
        bucket_k=PAPER_K, value_bytes=VALUE_BYTES, rows=PAPER_ROWS_PER_PROC,
    )
    variant = "flat" if flat <= limit else "blocked"
    assert variant == "blocked", (flat, limit)  # paper scale MUST block
    out.append((
        "spmv_kernel/select/paper_fine", 0.0,
        f"kind=modeled-vmem|rows_per_proc={PAPER_ROWS_PER_PROC}"
        f"|flat_kib={_kib(flat)}|blocked_kib={_kib(blocked)}"
        f"|limit_kib={_kib(limit)}|variant={variant}",
    ))
    return out


def _time_fn(fn, x, iters: int, warmup: int) -> float:
    for _ in range(warmup):
        np.asarray(fn(x))
    t0 = time.perf_counter()
    for _ in range(iters):
        np.asarray(fn(x))
    return (time.perf_counter() - t0) / iters


def _single_proc_layouts(A, block_cols: int):
    """Both device layouts of an unpartitioned operator (1-proc partition:
    no ghosts, so the kernels are exercised in isolation)."""
    part = partition_csr(A, 1)
    return partitioned_to_ell(part), partitioned_to_ell_blocked(
        part, block_cols=block_cols
    )


def _check_and_time(A, block_cols: int, backend_name: str,
                    iters: int, warmup: int):
    """Assert flat == blocked == host matvec, then time both variants."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import use_backend
    from repro.kernels.spmv_ell.ops import spmv, spmv_blocked

    ell, bell = _single_proc_layouts(A, block_cols)
    rng = np.random.default_rng(7)
    x = rng.normal(size=A.ncols)
    want = A.matvec(x)

    xf = jnp.asarray(np.concatenate([x, [0.0]]))        # flat sentinel slot
    xb = np.zeros(bell.x_len)
    xb[: A.ncols] = x
    xb = jnp.asarray(xb)
    lc = jnp.asarray(ell.local_cols[0])
    lv = jnp.asarray(ell.local_vals[0])
    bc_ = jnp.asarray(bell.cols[0])
    bv = jnp.asarray(bell.vals[0])

    with use_backend(backend_name):
        flat_fn = jax.jit(lambda v: spmv(lc, lv, v))
        blocked_fn = jax.jit(
            lambda v: spmv_blocked(bc_, bv, v, bell.block_cols)
        )
        got_flat = np.asarray(flat_fn(xf))[: A.nrows]
        got_blocked = np.asarray(blocked_fn(xb))[: A.nrows]
        np.testing.assert_allclose(got_flat, want, rtol=1e-6, atol=1e-8)
        np.testing.assert_allclose(got_blocked, got_flat,
                                   rtol=1e-6, atol=1e-8)
        t_flat = _time_fn(flat_fn, xf, iters, warmup)
        t_blocked = _time_fn(blocked_fn, xb, iters, warmup)
    return t_flat, t_blocked, bell


def measured_rows(rows: int):
    """Measured flat/blocked timings: jnp reference path on the benchmark
    fine level, Pallas interpret mode on a small problem."""
    import jax

    # equivalence checks compare against the f64 host matvec
    jax.config.update("jax_enable_x64", True)
    out = []
    # -- CPU reference path on the fine level ------------------------------
    A = hierarchy_for(min(rows, 65_536)).levels[0].A
    t_flat, t_blocked, bell = _check_and_time(
        A, block_cols=512, backend_name="reference", iters=10, warmup=2
    )
    geom = (f"rows={A.nrows}|buckets={bell.n_buckets}"
            f"|bucket_k={bell.K}")
    out.append((
        "spmv_kernel/measured/flat_ref", t_flat * 1e6,
        f"kind=measured-host|backend=reference|{geom}",
    ))
    out.append((
        "spmv_kernel/measured/blocked_ref", t_blocked * 1e6,
        f"kind=measured-host|backend=reference|{geom}"
        f"|vs_flat={t_blocked / max(t_flat, 1e-12):.2f}x",
    ))
    # -- Pallas kernels in interpret mode (small: interpret is python) -----
    As = diffusion_2d(16, 16)
    t_flat, t_blocked, bell = _check_and_time(
        As, block_cols=64, backend_name="pallas_interpret",
        iters=2, warmup=1,
    )
    geom = f"rows={As.nrows}|buckets={bell.n_buckets}|bucket_k={bell.K}"
    out.append((
        "spmv_kernel/measured/flat_interpret", t_flat * 1e6,
        f"kind=measured-host|backend=pallas_interpret|{geom}",
    ))
    out.append((
        "spmv_kernel/measured/blocked_interpret", t_blocked * 1e6,
        f"kind=measured-host|backend=pallas_interpret|{geom}",
    ))
    return out
