"""Docs stay wired: the link/anchor check runs in tier-1 (fast half of
the CI docs job; the snippet execution half runs in CI only)."""
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]


def test_docs_links():
    out = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_docs.py"),
         "--links-only"],
        capture_output=True, text=True, timeout=120,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"


def test_docs_exist_and_crosslinked():
    arch = (REPO / "docs" / "ARCHITECTURE.md").read_text()
    ops = (REPO / "docs" / "OPERATIONS.md").read_text()
    readme = (REPO / "README.md").read_text()
    assert "OPERATIONS.md" in arch and "ARCHITECTURE.md" in ops
    assert "docs/ARCHITECTURE.md" in readme
    assert "docs/OPERATIONS.md" in readme
    # the quickstart convention the CI docs job depends on
    assert "```python" in arch
