"""Measured-rate profiling + calibration (repro.profile.trace/calibrate).

The load-bearing property is the round trip: traces synthesized *from* the
cost model under a known MachineParams must fit back to that params set —
only then can selection driven by fitted rates be trusted to mean what the
modeled selection means.
"""
import numpy as np
import pytest

from repro.core import LASSEN, MachineParams, Topology, build_plan, plan_time
from repro.core.costmodel import fit_machine_params
from repro.profile import (
    TraceRecorder,
    fit_trace,
    probe_plans,
    rate_probe_patterns,
    selection_flips,
    synthesize_trace,
)

RATE_FIELDS = ("alpha_intra", "beta_intra", "alpha_inter", "beta_inter",
               "region_injection_bw")

TRUE = MachineParams(
    name="truth",
    alpha_intra=3.0e-7,
    beta_intra=45.0e9,
    alpha_inter=4.0e-6,
    beta_inter=7.0e9,
    region_injection_bw=10.0e9,
)


def test_round_trip_fit_recovers_generating_params():
    """Synthesized trace (seconds = plan_time under TRUE) -> fit -> TRUE,
    every rate within tolerance, starting from different shipped params."""
    topo = Topology(8, 4)
    plans = probe_plans(topo, strategies=("standard", "full"), n_per=16384)
    trace = synthesize_trace(plans, TRUE)
    result = fit_trace(trace, ref=LASSEN)
    assert result.converged
    for f in RATE_FIELDS:
        a, b = getattr(TRUE, f), getattr(result.params, f)
        assert abs(b - a) / a < 1e-6, (f, a, b)
    # eager cutoff is not a rate: held fixed at the reference value
    assert result.params.eager_bytes == LASSEN.eager_bytes
    assert result.gof["rel_rmse"] < 1e-9
    assert result.gof["r2"] > 1.0 - 1e-9


def test_probe_patterns_excite_every_rate():
    """Each probe's bottleneck is the rate it is named for: perturbing that
    rate (and only that rate) changes the probe's modeled time."""
    topo = Topology(8, 4)
    probes = dict(rate_probe_patterns(topo, n_per=16384))
    assert set(probes) == {"intra_latency", "intra_band", "inter_latency",
                           "inter_band", "injection"}
    sensitive = {
        "intra_latency": "alpha_intra",
        "intra_band": "beta_intra",
        "inter_latency": "alpha_inter",
        "inter_band": "beta_inter",
        "injection": "region_injection_bw",
    }
    for label, pattern in probes.items():
        plan = build_plan(pattern, topo, "standard")
        base = plan_time(plan, TRUE)
        field = sensitive[label]
        bumped = MachineParams(**{
            **{f: getattr(TRUE, f) for f in RATE_FIELDS},
            "name": "bumped", field: getattr(TRUE, field) * (
                2.0 if field.startswith("alpha") else 0.5),
        })
        assert plan_time(plan, bumped) > base * 1.5, label


def test_fit_requires_nonzero_samples():
    with pytest.raises(ValueError):
        fit_machine_params([])


def test_unexcited_rates_fall_back_to_reference():
    """A trace with only intra traffic cannot identify inter rates; the
    fit must backfill them from the reference instead of inventing them."""
    topo = Topology(4, 4)  # one region: no inter traffic exists
    plans = probe_plans(topo, strategies=("standard",), n_per=4096)
    trace = synthesize_trace(plans, TRUE)
    result = fit_trace(trace, ref=LASSEN)
    assert result.converged
    assert result.params.alpha_inter == LASSEN.alpha_inter
    assert result.params.beta_inter == LASSEN.beta_inter
    assert result.params.region_injection_bw == LASSEN.region_injection_bw
    for f in ("alpha_intra", "beta_intra"):
        a, b = getattr(TRUE, f), getattr(result.params, f)
        assert abs(b - a) / a < 1e-6, (f, a, b)


def test_trace_json_round_trip(tmp_path):
    """save -> load preserves every sample; a refit over the loaded trace
    equals the original fit."""
    topo = Topology(8, 4)
    plans = probe_plans(topo, strategies=("standard",), n_per=16384)
    trace = synthesize_trace(plans, TRUE)
    trace.record_histogram("moe/observed", [3.0, 1.0, 0.0, 4.0], step=7)
    path = tmp_path / "trace.json"
    trace.save(path)
    loaded = TraceRecorder.load(path)
    assert loaded.summary() == trace.summary()
    assert loaded.histograms[0].counts == [3.0, 1.0, 0.0, 4.0]
    assert loaded.histograms[0].step == 7
    r1 = fit_trace(trace, ref=LASSEN)
    r2 = fit_trace(loaded, ref=LASSEN)
    for f in RATE_FIELDS:
        assert getattr(r1.params, f) == pytest.approx(
            getattr(r2.params, f), rel=1e-12)


def test_merged_rate_samples_median_and_purity():
    topo = Topology(8, 4)
    plan = probe_plans(topo, strategies=("standard",), n_per=64)[0]
    tr = TraceRecorder()
    for secs in (1.0, 3.0, 100.0):
        tr.record_plan(plan, secs, label="x")
    tr.record_plan(plan, 123.0, label="moe", pure_exchange=False)
    merged = tr.merged_rate_samples()
    assert len(merged) == 1
    assert merged[0].seconds == 3.0            # median, impure excluded
    assert len(tr.merged_rate_samples(pure_only=False)) == 2


def test_wrap_executor_records_samples():
    import jax

    from repro.core import (
        CommPattern,
        PlanCache,
        Topology as T,
        pattern_fingerprint,
    )

    n_dev = jax.device_count()
    offsets = np.arange(n_dev + 1) * 4
    needs = [np.arange(min(2, n_dev * 4)) for _ in range(n_dev)]
    pat = CommPattern.from_block_partition(needs, offsets)
    topo = T(n_dev, 1)
    cache = PlanCache()
    mesh = jax.make_mesh((n_dev,), ("proc",))
    coll = cache.collective(pat, topo, "standard")
    fn = cache.executor(pat, topo, mesh, "proc", "standard")
    tr = TraceRecorder()
    timed = tr.wrap_executor(coll.plan, fn, label="exec")
    x = np.zeros((n_dev, 4, 1))
    timed(x)
    timed(x)
    assert len(tr.samples) == 2
    assert all(s.seconds > 0 for s in tr.samples)
    assert tr.samples[0].fingerprint == pattern_fingerprint(pat)
    assert tr.samples[0].label == "exec"


def test_selection_flips_reports_side_by_side():
    """Fan-out pattern (proc 0 sends a distinct value to every proc of the
    remote region): slow inter latency (LASSEN) favors aggregation — one
    wire message instead of ppr — while a machine whose measured inter
    latency is near the intra latency favors standard.  The shipped vs
    fitted comparison must report that flip."""
    from repro.core import CommPattern

    topo = Topology(8, 4)
    offsets = np.arange(topo.n_procs + 1) * 8
    needs = [np.empty(0, dtype=np.int64) for _ in range(topo.n_procs)]
    for lr in range(topo.procs_per_region):
        needs[topo.procs_per_region + lr] = np.array([lr], dtype=np.int64)
    pattern = CommPattern.from_block_partition(needs, offsets)
    fast_inter = MachineParams(
        name="fast-inter", alpha_intra=LASSEN.alpha_intra,
        beta_intra=LASSEN.beta_intra, alpha_inter=LASSEN.alpha_intra,
        beta_inter=LASSEN.beta_inter,
        region_injection_bw=LASSEN.region_injection_bw,
    )
    rows = selection_flips([("fanout", pattern)], topo, LASSEN, fast_inter)
    assert len(rows) == 1
    row = rows[0]
    assert row["shipped"] != "standard"     # aggregation wins on LASSEN
    assert row["fitted"] == "standard"      # cheap inter: direct wins
    assert row["flip"] == "yes"
    # no flip when both parameter sets agree
    same = selection_flips([("fanout", pattern)], topo, LASSEN, LASSEN)
    assert same[0]["flip"] == "no"


def test_calibration_result_table_and_json(tmp_path):
    topo = Topology(8, 4)
    plans = probe_plans(topo, strategies=("standard",), n_per=16384)
    result = fit_trace(synthesize_trace(plans, TRUE), ref=LASSEN)
    table = result.table()
    assert "alpha_inter" in table and "converged=True" in table
    path = tmp_path / "fitted.json"
    result.save(path)
    import json

    payload = json.loads(path.read_text())
    assert payload["fitted"]["name"].startswith("fitted")
    assert payload["shipped"]["name"] == LASSEN.name
    assert np.isfinite(payload["gof"]["rel_rmse"])
