"""Flash-attention Pallas kernel vs jnp oracle: shape/dtype/mask sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import use_backend
from repro.kernels.flash_attention import attention, attention_ref
from repro.kernels.flash_attention.ref import attention_ref_naive


def rand(rng, shape, dtype):
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype=dtype)


@pytest.mark.parametrize(
    "B,Hq,Hkv,Tq,Tk,d",
    [
        (1, 2, 2, 64, 64, 32),      # MHA, block-aligned? (Tq<bq -> 1 block)
        (2, 4, 2, 128, 128, 64),    # GQA group 2
        (1, 8, 1, 100, 100, 16),    # MQA, ragged seq (padding path)
        (1, 4, 4, 256, 256, 32),    # multi-block kv loop
        (2, 2, 2, 1, 192, 32),      # decode: 1 query vs long kv
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matches_ref_causal(B, Hq, Hkv, Tq, Tk, d, dtype):
    rng = np.random.default_rng(0)
    q = rand(rng, (B, Hq, Tq, d), dtype)
    k = rand(rng, (B, Hkv, Tk, d), dtype)
    v = rand(rng, (B, Hkv, Tk, d), dtype)
    q_offset = Tk - Tq  # decode-style: query sits at the cache tail
    want = attention_ref(q, k, v, causal=True, q_offset=q_offset)
    with use_backend("pallas_interpret"):
        got = attention(q, k, v, causal=True, q_offset=q_offset,
                        block_q=64, block_k=64)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


@pytest.mark.parametrize("window", [16, 64])
def test_sliding_window(window):
    rng = np.random.default_rng(1)
    B, H, T, d = 1, 2, 160, 32
    q = rand(rng, (B, H, T, d), jnp.float32)
    k = rand(rng, (B, H, T, d), jnp.float32)
    v = rand(rng, (B, H, T, d), jnp.float32)
    want = attention_ref(q, k, v, causal=True, window=window)
    with use_backend("pallas_interpret"):
        got = attention(q, k, v, causal=True, window=window,
                        block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_kv_len_padding_mask():
    """Entries past kv_len must not contribute (serving: cache padded)."""
    rng = np.random.default_rng(2)
    B, H, T, d = 1, 2, 64, 32
    q = rand(rng, (B, H, 1, d), jnp.float32)
    k = rand(rng, (B, H, T, d), jnp.float32)
    v = rand(rng, (B, H, T, d), jnp.float32)
    kv_len = 37
    want = attention_ref(q, k[:, :, :kv_len], v[:, :, :kv_len],
                         causal=False)
    with use_backend("pallas_interpret"):
        got = attention(q, k, v, causal=False, kv_len=kv_len,
                        block_q=8, block_k=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("Tq,Tk,chunk_gt", [(64, 300, True), (1, 4000, True)])
def test_chunked_ref_matches_naive(Tq, Tk, chunk_gt):
    """The chunked (scan) reference == naive reference on long KV."""
    rng = np.random.default_rng(4)
    B, Hq, Hkv, d = 1, 4, 2, 32
    q = rand(rng, (B, Hq, Tq, d), jnp.float32)
    k = rand(rng, (B, Hkv, Tk, d), jnp.float32)
    v = rand(rng, (B, Hkv, Tk, d), jnp.float32)
    kv_len = Tk - 17
    want = attention_ref_naive(q, k, v, causal=True, q_offset=kv_len - Tq,
                               kv_len=kv_len, window=128)
    got = attention_ref(q, k, v, causal=True, q_offset=kv_len - Tq,
                        kv_len=kv_len, window=128, chunk=256)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
