"""Unit tests: rectangular partitioning + padded-ELL device conversion.

These run on the main (single-device) pytest process: ELL correctness is
checked against the CSR blocks with plain numpy gathers; the shard_map
device path is exercised end-to-end in test_distributed_amg.py.
"""
import numpy as np
import pytest

from repro.amg import build_hierarchy, diffusion_2d
from repro.core import Topology, build_plan
from repro.sparse import (
    block_offsets,
    distributed_spmv_numpy,
    overlap_decision,
    pack_vector,
    partition_csr,
    partition_rect_csr,
    partitioned_to_ell,
    partitioned_to_ell_blocked,
    row_block_bucket_map,
    select_spmv_kernel,
    select_spmv_overlap,
    spmv_blocked_vmem_bytes,
    spmv_flat_vmem_bytes,
    unpack_vector,
)


def _ell_matvec(cols, vals, x_ext):
    """Reference ELL matvec: cols/vals [R, K], x_ext padded with sentinel."""
    return np.sum(vals * x_ext[cols], axis=1)


def test_rect_partition_matches_serial_on_restriction():
    A = diffusion_2d(24, 18)
    h = build_hierarchy(A)
    R = h.levels[0].R
    assert R is not None and R.nrows < R.ncols
    n_procs = 6
    part = partition_rect_csr(
        R, block_offsets(R.nrows, n_procs), block_offsets(R.ncols, n_procs)
    )
    topo = Topology(n_procs, 3)
    rng = np.random.default_rng(0)
    x = rng.normal(size=R.ncols)
    for strategy in ("standard", "partial", "full"):
        plan = build_plan(part.pattern, topo, strategy)
        got = distributed_spmv_numpy(part, plan, x)
        np.testing.assert_allclose(got, R.matvec(x), rtol=1e-12, atol=1e-12)


def test_partitioned_to_ell_reproduces_blocks():
    A = diffusion_2d(16, 20)
    n_procs = 8
    part = partition_csr(A, n_procs)
    ell = partitioned_to_ell(part)
    assert ell.row_pad == int(np.diff(part.offsets).max())
    rng = np.random.default_rng(1)
    x = rng.normal(size=A.nrows)
    plan = build_plan(part.pattern, Topology(n_procs, 4), "standard")
    xs = [x[int(part.offsets[p]): int(part.offsets[p + 1])]
          for p in range(n_procs)]
    ghosts = plan.execute_numpy(xs)
    for p in range(n_procs):
        # local block: sentinel slot at index in_pad
        x_ext = np.zeros(ell.in_pad + 1)
        x_ext[: len(xs[p])] = xs[p]
        y = _ell_matvec(ell.local_cols[p], ell.local_vals[p], x_ext)
        g_ext = np.zeros(ell.ghost_pad + 1)
        g_ext[: len(ghosts[p])] = ghosts[p]
        y = y + _ell_matvec(ell.ghost_cols[p], ell.ghost_vals[p], g_ext)
        want = part.local[p].matvec(xs[p])
        if part.ghost[p].ncols:
            want = want + part.ghost[p].matvec(ghosts[p])
        n_rows = int(part.offsets[p + 1] - part.offsets[p])
        np.testing.assert_allclose(y[:n_rows], want, rtol=1e-12, atol=1e-12)
        # padded rows are exactly zero (they feed the next level's layout)
        np.testing.assert_array_equal(y[n_rows:], 0.0)


def test_pack_unpack_vector_roundtrip():
    off = block_offsets(37, 5)
    pad = int(np.diff(off).max())
    rng = np.random.default_rng(2)
    x = rng.normal(size=37)
    packed = pack_vector(off, pad, x)
    assert packed.shape == (5, pad)
    np.testing.assert_array_equal(unpack_vector(off, packed), x)


def _blocked_matvec(bell, p, x_local, ghosts):
    """Numpy oracle of the bucketed gather for one process block."""
    bc = bell.block_cols
    xcat = np.zeros(bell.x_len)
    xcat[: len(x_local)] = x_local
    g0 = bell.n_local_buckets * bc
    xcat[g0: g0 + len(ghosts)] = ghosts
    base = np.repeat(np.arange(bell.n_buckets) * bc, bell.K)
    return np.sum(bell.vals[p] * xcat[bell.cols[p] + base[None, :]], axis=1)


def test_partitioned_to_ell_blocked_reproduces_blocks():
    """Column-bucketed packing: per-proc blocked gather == CSR matvecs."""
    A = diffusion_2d(16, 20)
    n_procs = 8
    part = partition_csr(A, n_procs)
    bell = partitioned_to_ell_blocked(part, block_cols=16)
    assert bell.row_pad == int(np.diff(part.offsets).max())
    # ghost columns occupy the trailing buckets only
    assert bell.n_ghost_buckets >= 1
    rng = np.random.default_rng(3)
    x = rng.normal(size=A.nrows)
    plan = build_plan(part.pattern, Topology(n_procs, 4), "standard")
    xs = [x[int(part.offsets[p]): int(part.offsets[p + 1])]
          for p in range(n_procs)]
    ghosts = plan.execute_numpy(xs)
    for p in range(n_procs):
        y = _blocked_matvec(bell, p, xs[p], ghosts[p])
        want = part.local[p].matvec(xs[p])
        if part.ghost[p].ncols:
            want = want + part.ghost[p].matvec(ghosts[p])
        n_rows = int(part.offsets[p + 1] - part.offsets[p])
        np.testing.assert_allclose(y[:n_rows], want, rtol=1e-12, atol=1e-12)
        np.testing.assert_array_equal(y[n_rows:], 0.0)


def test_blocked_bucket_structure():
    """In-bucket indices stay inside their bucket; local entries never land
    in ghost buckets (and vice versa); bucket_K bounds every bucket."""
    A = diffusion_2d(12, 12)
    part = partition_csr(A, 4)
    bell = partitioned_to_ell_blocked(part, block_cols=8)
    assert np.all(bell.cols >= 0) and np.all(bell.cols < bell.block_cols)
    assert bell.K == int(bell.bucket_K.max())
    C, K = bell.n_buckets, bell.K
    for p in range(4):
        live = bell.vals[p] != 0.0
        per_bucket = live.reshape(bell.row_pad, C, K)
        # per-(row,bucket) live counts never exceed the recorded bucket_K
        counts = per_bucket.sum(axis=2)
        assert np.all(counts.max(axis=0) <= bell.bucket_K)


def test_vmem_estimators_and_selection():
    """Flat footprint grows with x; blocked footprint does not — and the
    selector flips exactly at the threshold."""
    flat_small = spmv_flat_vmem_bytes(in_pad=1000, ghost_pad=100,
                                      k_local=9, k_ghost=4, rows=1000)
    flat_big = spmv_flat_vmem_bytes(in_pad=2 ** 21, ghost_pad=100,
                                    k_local=9, k_ghost=4, rows=2 ** 21)
    assert flat_big > flat_small
    blk_small = spmv_blocked_vmem_bytes(bucket_k=9, rows=1000)
    blk_big = spmv_blocked_vmem_bytes(bucket_k=9, rows=2 ** 21)
    assert blk_big <= blk_small * 2  # row-clamp only; x-length independent
    assert flat_big > 2 ** 23 > blk_big

    A = diffusion_2d(24, 24)
    part = partition_csr(A, 4)
    auto = select_spmv_kernel(part)
    assert auto.variant == "flat" and not auto.forced  # tiny x: flat fits
    blocked = select_spmv_kernel(part, vmem_limit_bytes=auto.flat_bytes - 1)
    assert blocked.variant == "blocked" and not blocked.forced
    at_limit = select_spmv_kernel(part, vmem_limit_bytes=auto.flat_bytes)
    assert at_limit.variant == "flat"
    forced = select_spmv_kernel(part, variant="blocked")
    assert forced.variant == "blocked" and forced.forced
    with pytest.raises(ValueError):
        select_spmv_kernel(part, variant="banana")


def test_vmem_limit_env_override(monkeypatch):
    from repro.sparse import default_spmv_vmem_limit

    monkeypatch.setenv("REPRO_SPMV_VMEM_LIMIT_BYTES", "12345")
    assert default_spmv_vmem_limit() == 12345
    monkeypatch.delenv("REPRO_SPMV_VMEM_LIMIT_BYTES")
    assert default_spmv_vmem_limit() == 8 * 2 ** 20


def test_ell_padding_points_at_sentinel():
    """Every structural padding entry must be (sentinel col, 0.0 val)."""
    A = diffusion_2d(10, 14)
    part = partition_csr(A, 4)
    ell = partitioned_to_ell(part)
    for p in range(4):
        m = part.local[p]
        lens = np.diff(m.indptr)
        lc, lv = ell.local_cols[p], ell.local_vals[p]
        for i in range(ell.row_pad):
            k = int(lens[i]) if i < m.nrows else 0
            np.testing.assert_array_equal(lc[i, k:], ell.in_pad)
            np.testing.assert_array_equal(lv[i, k:], 0.0)
            # live entries point strictly inside the owned block
            assert np.all(lc[i, :k] < ell.in_pad)


def test_overlap_decision_modes():
    """auto flips exactly when the hidden time beats the split overhead;
    forced modes are honored (except on, without ghosts to hide)."""
    from repro.core.costmodel import overlap_split_overhead

    rows = 2 ** 21
    overhead = overlap_split_overhead(rows)
    # paper-scale regime: tx and tl both dwarf the overhead -> on
    on = overlap_decision(100e-6, 300e-6, rows=rows)
    assert on.mode == "on" and not on.forced
    assert on.exposed_s == 0.0 and on.hidden_frac == 1.0
    assert on.overhead_s == overhead
    # smoke regime: local compute below the overhead -> off, fully exposed
    off = overlap_decision(100e-6, overhead / 10, rows=rows)
    assert off.mode == "off" and off.exposed_s == 100e-6
    assert off.hidden_frac == 0.0
    # partial hiding: tl < tx but still worth it
    part = overlap_decision(100e-6, 60e-6, rows=1000)
    assert part.mode == "on"
    np.testing.assert_allclose(part.exposed_s, 40e-6)
    np.testing.assert_allclose(part.hidden_frac, 0.6)
    # forced modes
    fon = overlap_decision(1e-9, 1e-12, rows=rows, mode="on")
    assert fon.mode == "on" and fon.forced
    foff = overlap_decision(1.0, 1.0, rows=rows, mode="off")
    assert foff.mode == "off" and foff.forced
    # no ghosts: nothing to hide, even when forced on
    none = overlap_decision(0.0, 1.0, rows=rows, mode="on", has_ghost=False)
    assert none.mode == "off" and none.exposed_s == 0.0
    with pytest.raises(ValueError):
        overlap_decision(1.0, 1.0, rows=rows, mode="banana")


def test_select_spmv_overlap_on_partition():
    """The operator-level selector: off at smoke scale (local compute is
    sub-overhead), on when the exchange estimate justifies the split; the
    selection string is describe()-ready."""
    A = diffusion_2d(24, 24)
    part = partition_csr(A, 4)
    off = select_spmv_overlap(part, 1e-3)
    assert off.mode == "off" and not off.forced
    assert off.exchange_s == 1e-3 and off.exposed_s == 1e-3
    forced = select_spmv_overlap(part, 1e-3, mode="on")
    assert forced.mode == "on" and forced.forced
    assert "overlap=on (forced)" in str(forced)
    assert "tx=1000.0us" in str(forced)
    # single process: no ghosts, auto and forced both stay off
    solo = select_spmv_overlap(partition_csr(A, 1), 1e-3, mode="on")
    assert solo.mode == "off"


def test_row_block_bucket_map_structure():
    """Lists cover exactly the live buckets of each row block, padding
    holds bucket_lo, and the banded operator actually skips buckets."""
    A = diffusion_2d(24, 24)
    part = partition_csr(A, 4)
    bell = partitioned_to_ell_blocked(part, block_cols=32)
    C = bell.n_buckets
    lists, counts = row_block_bucket_map(bell, block_rows=16)
    P, nrb, M = lists.shape
    assert P == 4 and nrb == bell.row_pad // 16
    assert counts.shape == (P, nrb)
    assert M == counts.max() and M < C  # banded: skipping engages
    live = (bell.vals.reshape(P, bell.row_pad, C, bell.K) != 0).any(-1)
    for p in range(P):
        for rb in range(nrb):
            want = np.flatnonzero(live[p, rb * 16: (rb + 1) * 16].any(0))
            c = int(counts[p, rb])
            np.testing.assert_array_equal(lists[p, rb, :c], want)
            np.testing.assert_array_equal(lists[p, rb, c:], 0)  # bucket_lo
    # restricted windows partition the full lists
    Cl = bell.n_local_buckets
    llists, lcounts = row_block_bucket_map(bell, block_rows=16, bucket_hi=Cl)
    glists, gcounts = row_block_bucket_map(bell, block_rows=16, bucket_lo=Cl)
    assert np.all(lcounts + gcounts == counts)
    assert np.all(llists < Cl)
    assert np.all(glists >= Cl)  # padding holds bucket_lo == Cl
    with pytest.raises(AssertionError):
        row_block_bucket_map(bell, bucket_lo=C)
