"""Sparse substrate + AMG setup/solve + distributed SpMV (host path)."""
import numpy as np
import pytest

from repro.amg import build_hierarchy, diffusion_2d, solve
from repro.core import Topology, build_plan
from repro.sparse import CSR, distributed_spmv_numpy, partition_csr


def dense_ref(ny=12, nx=10):
    A = diffusion_2d(ny, nx)
    return A, A.to_dense()


def test_csr_matvec_matches_dense():
    A, D = dense_ref()
    rng = np.random.default_rng(0)
    x = rng.normal(size=A.ncols)
    np.testing.assert_allclose(A.matvec(x), D @ x, rtol=1e-12, atol=1e-12)


def test_csr_matmat_matches_dense():
    A, D = dense_ref(8, 9)
    B = A.transpose()
    got = A.matmat(B).to_dense()
    np.testing.assert_allclose(got, D @ D.T, rtol=1e-12, atol=1e-12)


def test_csr_transpose_diag():
    A, D = dense_ref(7, 6)
    np.testing.assert_allclose(A.transpose().to_dense(), D.T)
    np.testing.assert_allclose(A.diagonal(), np.diag(D))


def test_stencil_is_7_point_at_45deg():
    A = diffusion_2d(16, 16)
    # interior row has exactly 7 nonzeros
    interior = 8 * 16 + 8
    idx, _ = A.row(interior)
    assert len(idx) == 7
    # row sum ~ 0 in the interior (consistent discretization)
    _, val = A.row(interior)
    assert abs(val.sum()) < 1e-12


def test_amg_hierarchy_and_convergence():
    A = diffusion_2d(32, 32)
    h = build_hierarchy(A)
    assert h.n_levels >= 3
    # coarsening reduces size every level
    sizes = [l.A.nrows for l in h.levels]
    assert all(a > b for a, b in zip(sizes, sizes[1:]))
    rng = np.random.default_rng(1)
    b = rng.normal(size=A.nrows)
    x, hist = solve(h, b, tol=1e-8, max_iters=60)
    assert hist[-1] < 1e-8, f"AMG failed to converge: {hist[-5:]}"
    # true residual check
    assert np.linalg.norm(b - A.matvec(x)) / np.linalg.norm(b) < 1e-7


@pytest.mark.parametrize("strategy", ["standard", "partial", "full"])
def test_distributed_spmv_matches_serial(strategy):
    A = diffusion_2d(24, 16)
    part = partition_csr(A, n_procs=8)
    topo = Topology(8, procs_per_region=4)
    plan = build_plan(part.pattern, topo, strategy)
    rng = np.random.default_rng(2)
    x = rng.normal(size=A.nrows)
    got = distributed_spmv_numpy(part, plan, x)
    np.testing.assert_allclose(got, A.matvec(x), rtol=1e-12, atol=1e-12)
