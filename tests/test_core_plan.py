"""Unit tests: plan construction + host-oracle execution for all strategies."""
import numpy as np
import pytest

from repro.core import (
    CommPattern,
    Message,
    Topology,
    build_plan,
    color_rounds,
    padded_wire_volume,
    plan_full,
    plan_partial,
    plan_standard,
)


def random_pattern(rng, n_procs=8, n_per=16, ghosts_per=10):
    """Block-partitioned values; each proc needs random remote+local indices."""
    offsets = np.arange(n_procs + 1) * n_per
    needs = []
    n_global = n_procs * n_per
    for q in range(n_procs):
        k = rng.integers(0, ghosts_per + 1)
        needs.append(
            np.sort(rng.choice(n_global, size=k, replace=False))
        )
    return CommPattern.from_block_partition(needs, offsets)


def reference_ghosts(pattern, local_vals):
    out = []
    for q in range(pattern.n_procs):
        need = pattern.needs[q]
        vals = np.array(
            [
                local_vals[pattern.owner_proc[g]][pattern.owner_slot[g]]
                for g in need
            ],
            dtype=local_vals[0].dtype,
        ).reshape((len(need),) + local_vals[0].shape[1:])
        out.append(vals)
    return out


@pytest.mark.parametrize("strategy", ["standard", "partial", "full"])
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_strategies_deliver_correct_values(strategy, seed):
    rng = np.random.default_rng(seed)
    pattern = random_pattern(rng)
    topo = Topology(n_procs=8, procs_per_region=4)
    plan = build_plan(pattern, topo, strategy)
    local_vals = [
        rng.normal(size=(16,)).astype(np.float64) for _ in range(8)
    ]
    got = plan.execute_numpy(local_vals)
    want = reference_ghosts(pattern, local_vals)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)


def test_full_reduces_inter_region_bytes():
    """Dedup must not increase inter-region traffic; with heavy duplication
    it must strictly reduce it."""
    rng = np.random.default_rng(7)
    n_procs, n_per = 8, 8
    offsets = np.arange(n_procs + 1) * n_per
    # every proc in region 1 needs the same values from region 0 -> max dup
    shared = np.arange(4)
    needs = [np.array([], dtype=np.int64)] * 4 + [shared.copy() for _ in range(4)]
    pattern = CommPattern.from_block_partition(needs, offsets)
    topo = Topology(n_procs=8, procs_per_region=4)
    partial = plan_partial(pattern, topo)
    full = plan_full(pattern, topo)
    assert full.stats.totals()["inter_bytes"] < partial.stats.totals()["inter_bytes"]
    # 4 values x 4 dests dedup to 4 values
    assert full.stats.totals()["inter_bytes"] == 4 * 8
    assert partial.stats.totals()["inter_bytes"] == 16 * 8
    # correctness preserved
    vals = [rng.normal(size=(n_per,)) for _ in range(n_procs)]
    for plan in (partial, full):
        got = plan.execute_numpy(vals)
        for q in range(4, 8):
            np.testing.assert_array_equal(got[q], vals[0][:4])


def test_aggregation_reduces_inter_region_messages():
    """Three-step aggregation: at most one message per (region, region) pair."""
    rng = np.random.default_rng(3)
    pattern = random_pattern(rng, n_procs=16, n_per=32, ghosts_per=24)
    topo = Topology(n_procs=16, procs_per_region=4)
    std = plan_standard(pattern, topo)
    par = plan_partial(pattern, topo)
    n_region_pairs = topo.n_regions * (topo.n_regions - 1)
    assert par.stats.totals()["inter_msgs"] <= n_region_pairs
    assert par.stats.totals()["inter_msgs"] <= std.stats.totals()["inter_msgs"]


def test_rounds_are_partial_permutations():
    rng = np.random.default_rng(5)
    pattern = random_pattern(rng, n_procs=12, n_per=16, ghosts_per=12)
    topo = Topology(n_procs=12, procs_per_region=4)
    for strategy in ("standard", "partial", "full"):
        plan = build_plan(pattern, topo, strategy)
        for step in plan.steps:
            for rnd in color_rounds(step.messages):
                srcs = [s for s, _ in rnd.pairs]
                dsts = [d for _, d in rnd.pairs]
                assert len(set(srcs)) == len(srcs)
                assert len(set(dsts)) == len(dsts)


def test_multi_feature_values():
    """Values may be vectors (e.g. MoE hidden states), not just scalars."""
    rng = np.random.default_rng(11)
    pattern = random_pattern(rng)
    topo = Topology(8, 4)
    vals = [rng.normal(size=(16, 5)).astype(np.float32) for _ in range(8)]
    want = reference_ghosts(pattern, vals)
    for strategy in ("standard", "partial", "full"):
        got = build_plan(pattern, topo, strategy).execute_numpy(vals)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(g, w)


# ---------------------------------------------------------------------------
# round scheduling edge cases (device executor contract)
# ---------------------------------------------------------------------------


def test_color_rounds_empty_pattern():
    """An empty pattern yields plans with no wire rounds at every strategy."""
    n_procs = 8
    offsets = np.arange(n_procs + 1) * 4
    needs = [np.array([], dtype=np.int64)] * n_procs
    pattern = CommPattern.from_block_partition(needs, offsets)
    topo = Topology(n_procs, 4)
    for strategy in ("standard", "partial", "full"):
        plan = build_plan(pattern, topo, strategy)
        for step in plan.steps:
            assert color_rounds(step.messages) == []
        assert all(v == 0 for v in padded_wire_volume(plan).values())
        got = plan.execute_numpy([np.ones(4) for _ in range(n_procs)])
        assert all(len(g) == 0 for g in got)


def test_color_rounds_local_copy_only():
    """needs fully inside the owner block: local copies only, zero rounds."""
    n_procs, n_per = 4, 8
    offsets = np.arange(n_procs + 1) * n_per
    # every proc needs two of its OWN values -> src == dst messages only
    needs = [offsets[p] + np.array([1, 3]) for p in range(n_procs)]
    pattern = CommPattern.from_block_partition(needs, offsets)
    topo = Topology(n_procs, 2)
    for strategy in ("standard", "partial", "full"):
        plan = build_plan(pattern, topo, strategy)
        assert plan.stats.totals()["inter_msgs"] == 0
        assert plan.stats.totals()["intra_msgs"] == 0
        for step in plan.steps:
            assert color_rounds(step.messages) == []
        vals = [np.arange(n_per, dtype=np.float64) + 10 * p
                for p in range(n_procs)]
        got = plan.execute_numpy(vals)
        for p in range(n_procs):
            np.testing.assert_array_equal(got[p], vals[p][[1, 3]])


def test_color_rounds_width_homogeneity():
    """Largest-first coloring groups same-sized messages into one round."""
    big = np.arange(64)
    small = np.arange(2)
    # two conflicting big messages (same src) and two conflicting small ones
    msgs = [
        Message(0, 1, big, big),
        Message(0, 2, big, big),
        Message(3, 1, small, small),
        Message(3, 2, small, small),
    ]
    rounds = color_rounds(msgs)
    assert len(rounds) == 2
    # each round pairs one big with one small -> but big are colored first:
    # round widths are set by the big messages, never by interleaving order
    assert [r.width for r in rounds] == [64, 64]
    # all four messages scheduled exactly once
    assert sum(len(r.pairs) for r in rounds) == 4
    # non-conflicting same-size messages share a round
    msgs2 = [Message(0, 1, big, big), Message(2, 3, big, big)]
    assert len(color_rounds(msgs2)) == 1


def test_padded_wire_volume_vs_exact_stats():
    """Padded volume >= exact wire values; equal when sizes are uniform."""
    rng = np.random.default_rng(13)
    pattern = random_pattern(rng, n_procs=12, n_per=16, ghosts_per=12)
    topo = Topology(12, 4)
    for strategy in ("standard", "partial", "full"):
        plan = build_plan(pattern, topo, strategy)
        padded = padded_wire_volume(plan)
        for step, stats in zip(plan.steps, plan.stats.steps):
            exact = int(stats.intra_vals.sum() + stats.inter_vals.sum())
            assert padded[step.name] >= exact
            widths = {m.size for m in step.messages
                      if m.src != m.dst and m.size > 0}
            if len(widths) <= 1:  # uniform sizes pad nothing
                assert padded[step.name] == exact


def test_round_widths_cover_largest_message_first():
    """Round 0 always carries the globally largest wire message."""
    rng = np.random.default_rng(17)
    pattern = random_pattern(rng, n_procs=8, n_per=32, ghosts_per=20)
    topo = Topology(8, 4)
    for strategy in ("standard", "partial", "full"):
        plan = build_plan(pattern, topo, strategy)
        for step in plan.steps:
            wire = [m.size for m in step.messages
                    if m.src != m.dst and m.size > 0]
            rounds = color_rounds(step.messages)
            if wire:
                assert rounds[0].width == max(wire)
