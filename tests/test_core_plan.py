"""Unit tests: plan construction + host-oracle execution for all strategies."""
import numpy as np
import pytest

from repro.core import (
    CommPattern,
    Topology,
    build_plan,
    color_rounds,
    plan_full,
    plan_partial,
    plan_standard,
)


def random_pattern(rng, n_procs=8, n_per=16, ghosts_per=10):
    """Block-partitioned values; each proc needs random remote+local indices."""
    offsets = np.arange(n_procs + 1) * n_per
    needs = []
    n_global = n_procs * n_per
    for q in range(n_procs):
        k = rng.integers(0, ghosts_per + 1)
        needs.append(
            np.sort(rng.choice(n_global, size=k, replace=False))
        )
    return CommPattern.from_block_partition(needs, offsets)


def reference_ghosts(pattern, local_vals):
    out = []
    for q in range(pattern.n_procs):
        need = pattern.needs[q]
        vals = np.array(
            [
                local_vals[pattern.owner_proc[g]][pattern.owner_slot[g]]
                for g in need
            ],
            dtype=local_vals[0].dtype,
        ).reshape((len(need),) + local_vals[0].shape[1:])
        out.append(vals)
    return out


@pytest.mark.parametrize("strategy", ["standard", "partial", "full"])
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_strategies_deliver_correct_values(strategy, seed):
    rng = np.random.default_rng(seed)
    pattern = random_pattern(rng)
    topo = Topology(n_procs=8, procs_per_region=4)
    plan = build_plan(pattern, topo, strategy)
    local_vals = [
        rng.normal(size=(16,)).astype(np.float64) for _ in range(8)
    ]
    got = plan.execute_numpy(local_vals)
    want = reference_ghosts(pattern, local_vals)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)


def test_full_reduces_inter_region_bytes():
    """Dedup must not increase inter-region traffic; with heavy duplication
    it must strictly reduce it."""
    rng = np.random.default_rng(7)
    n_procs, n_per = 8, 8
    offsets = np.arange(n_procs + 1) * n_per
    # every proc in region 1 needs the same values from region 0 -> max dup
    shared = np.arange(4)
    needs = [np.array([], dtype=np.int64)] * 4 + [shared.copy() for _ in range(4)]
    pattern = CommPattern.from_block_partition(needs, offsets)
    topo = Topology(n_procs=8, procs_per_region=4)
    partial = plan_partial(pattern, topo)
    full = plan_full(pattern, topo)
    assert full.stats.totals()["inter_bytes"] < partial.stats.totals()["inter_bytes"]
    # 4 values x 4 dests dedup to 4 values
    assert full.stats.totals()["inter_bytes"] == 4 * 8
    assert partial.stats.totals()["inter_bytes"] == 16 * 8
    # correctness preserved
    vals = [rng.normal(size=(n_per,)) for _ in range(n_procs)]
    for plan in (partial, full):
        got = plan.execute_numpy(vals)
        for q in range(4, 8):
            np.testing.assert_array_equal(got[q], vals[0][:4])


def test_aggregation_reduces_inter_region_messages():
    """Three-step aggregation: at most one message per (region, region) pair."""
    rng = np.random.default_rng(3)
    pattern = random_pattern(rng, n_procs=16, n_per=32, ghosts_per=24)
    topo = Topology(n_procs=16, procs_per_region=4)
    std = plan_standard(pattern, topo)
    par = plan_partial(pattern, topo)
    n_region_pairs = topo.n_regions * (topo.n_regions - 1)
    assert par.stats.totals()["inter_msgs"] <= n_region_pairs
    assert par.stats.totals()["inter_msgs"] <= std.stats.totals()["inter_msgs"]


def test_rounds_are_partial_permutations():
    rng = np.random.default_rng(5)
    pattern = random_pattern(rng, n_procs=12, n_per=16, ghosts_per=12)
    topo = Topology(n_procs=12, procs_per_region=4)
    for strategy in ("standard", "partial", "full"):
        plan = build_plan(pattern, topo, strategy)
        for step in plan.steps:
            for rnd in color_rounds(step.messages):
                srcs = [s for s, _ in rnd.pairs]
                dsts = [d for _, d in rnd.pairs]
                assert len(set(srcs)) == len(srcs)
                assert len(set(dsts)) == len(dsts)


def test_multi_feature_values():
    """Values may be vectors (e.g. MoE hidden states), not just scalars."""
    rng = np.random.default_rng(11)
    pattern = random_pattern(rng)
    topo = Topology(8, 4)
    vals = [rng.normal(size=(16, 5)).astype(np.float32) for _ in range(8)]
    want = reference_ghosts(pattern, vals)
    for strategy in ("standard", "partial", "full"):
        got = build_plan(pattern, topo, strategy).execute_numpy(vals)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(g, w)
