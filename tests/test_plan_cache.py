"""Plan/executor cache: hits skip re-planning, keys are content-based."""
import numpy as np
import pytest

import repro.core.cache as cache_mod
from repro.core import (
    CommPattern,
    PlanCache,
    Topology,
    default_plan_cache,
    pattern_fingerprint,
    plan_cache_key,
)
from repro.core.costmodel import LASSEN, TPU_V5E


def make_pattern(seed=0, n_procs=8, n_per=16):
    rng = np.random.default_rng(seed)
    offsets = np.arange(n_procs + 1) * n_per
    needs = [
        np.sort(rng.choice(n_procs * n_per, size=6, replace=False))
        for _ in range(n_procs)
    ]
    return CommPattern.from_block_partition(needs, offsets)


def test_fingerprint_content_based():
    a = make_pattern(seed=3)
    b = make_pattern(seed=3)   # distinct objects, equal content
    c = make_pattern(seed=4)
    assert a is not b
    assert pattern_fingerprint(a) == pattern_fingerprint(b)
    assert pattern_fingerprint(a) != pattern_fingerprint(c)


def test_cache_hit_skips_replanning(monkeypatch):
    topo = Topology(8, 4)
    cache = PlanCache()
    calls = {"n": 0}
    real_init = cache_mod.NeighborAlltoallV.init

    def counting_init(*args, **kwargs):
        calls["n"] += 1
        return real_init(*args, **kwargs)

    monkeypatch.setattr(cache_mod.NeighborAlltoallV, "init", counting_init)

    coll1 = cache.collective(make_pattern(seed=1), topo, "auto")
    assert (cache.misses, cache.hits, calls["n"]) == (1, 0, 1)

    # equal-content pattern, distinct object: hit, NO re-planning
    coll2 = cache.collective(make_pattern(seed=1), topo, "auto")
    assert coll2 is coll1
    assert (cache.misses, cache.hits, calls["n"]) == (1, 1, 1)
    assert cache.init_seconds_saved > 0.0  # amortized init

    # different strategy or params -> different entry
    cache.collective(make_pattern(seed=1), topo, "standard")
    assert calls["n"] == 2
    cache.collective(make_pattern(seed=1), topo, "auto", params=LASSEN)
    assert calls["n"] == 3
    # different pattern content -> different entry
    cache.collective(make_pattern(seed=2), topo, "auto")
    assert calls["n"] == 4


def test_cache_key_includes_topology_and_width():
    pat = make_pattern(seed=5)
    k1 = plan_cache_key(pat, Topology(8, 4), "auto", 8, TPU_V5E)
    k2 = plan_cache_key(pat, Topology(8, 2), "auto", 8, TPU_V5E)
    k3 = plan_cache_key(pat, Topology(8, 4), "auto", 4, TPU_V5E)
    assert len({k1, k2, k3}) == 3


def test_executor_cache_reuses_bound_fn():
    import jax

    cache = PlanCache()
    n_dev = jax.device_count()
    mesh = jax.make_mesh((n_dev,), ("proc",))
    # pattern sized to the real device count so the executor is bindable
    rng = np.random.default_rng(0)
    offsets = np.arange(n_dev + 1) * 4
    needs = [np.arange(min(2, n_dev * 4)) for _ in range(n_dev)]
    pat = CommPattern.from_block_partition(needs, offsets)
    topo = Topology(n_dev, 1)
    f1 = cache.executor(pat, topo, mesh, "proc", "standard")
    f2 = cache.executor(pat, topo, mesh, "proc", "standard")
    assert f1 is f2
    assert (cache.exec_misses, cache.exec_hits) == (1, 1)


def test_default_cache_is_process_wide():
    assert default_plan_cache() is default_plan_cache()


def test_stats_breaks_out_namespaces():
    topo = Topology(8, 4)
    cache = PlanCache()
    cache.collective(make_pattern(seed=1), topo, "standard")
    cache.collective(make_pattern(seed=1), topo, "standard")   # hit
    cache.moe_plan(("k1",), lambda: "plan")
    cache.moe_plan(("k1",), lambda: "plan")                    # hit
    cache.moe_plan(("k2",), lambda: "plan2")
    s = cache.stats()
    assert s["namespaces"]["collective"] == \
        {"hits": 1, "misses": 1, "entries": 1}
    assert s["namespaces"]["moe_plan"] == \
        {"hits": 1, "misses": 2, "entries": 2}
    assert s["namespaces"]["executor"]["entries"] == 0
    assert s["entries"] == 3
    assert s["evictions"] == 0
    # legacy flat counters still aggregate across surfaces
    assert (s["hits"], s["misses"]) == (2, 3)


def test_lru_eviction_is_bounded_and_counted():
    topo = Topology(8, 4)
    cache = PlanCache(max_entries=3)
    for seed in range(5):
        cache.collective(make_pattern(seed=seed), topo, "standard")
    s = cache.stats()
    assert s["namespaces"]["collective"]["entries"] == 3
    assert s["evictions"] == 2
    # seeds 2..4 survive (LRU order); seed 0 was evicted -> re-plans
    m = cache.misses
    cache.collective(make_pattern(seed=4), topo, "standard")
    assert cache.misses == m                      # most recent: hit
    cache.collective(make_pattern(seed=0), topo, "standard")
    assert cache.misses == m + 1                  # evicted: miss again


def test_lru_hit_refreshes_recency():
    topo = Topology(8, 4)
    cache = PlanCache(max_entries=2)
    cache.collective(make_pattern(seed=0), topo, "standard")
    cache.collective(make_pattern(seed=1), topo, "standard")
    cache.collective(make_pattern(seed=0), topo, "standard")   # refresh 0
    cache.collective(make_pattern(seed=2), topo, "standard")   # evicts 1
    m = cache.misses
    cache.collective(make_pattern(seed=0), topo, "standard")
    assert cache.misses == m                      # 0 survived
    cache.collective(make_pattern(seed=1), topo, "standard")
    assert cache.misses == m + 1                  # 1 was the LRU victim
