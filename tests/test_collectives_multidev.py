"""Integration: device-side plan execution on 8 virtual host devices.

The main pytest process must keep seeing 1 device (smoke tests & benches),
so multi-device checks run in subprocesses with XLA_FLAGS set at spawn.
"""
import os
import pathlib
import subprocess
import sys

import pytest

PROGS = pathlib.Path(__file__).parent / "multidevice_progs"
SRC = pathlib.Path(__file__).resolve().parents[1] / "src"


def run_prog(name: str, timeout=600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(SRC) + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    out = subprocess.run(
        [sys.executable, str(PROGS / name)],
        env=env,
        capture_output=True,
        text=True,
        timeout=timeout,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_collectives_exec_matches_oracle():
    out = run_prog("check_collectives.py")
    assert "ALL_OK" in out


def test_moe_modes_agree_on_multipod_mesh():
    out = run_prog("check_moe_modes.py")
    assert "ALL_OK" in out


def test_dense_collective_consumers_on_8_devices():
    """Explicit plan-based grad sync == implicit GSPMD at 1e-12, AMG
    coarse-gather solve matches the sharded baseline, MoE expert gather
    reconstructs the original weights (see the prog's docstring)."""
    out = run_prog("check_dense_collectives.py")
    assert "ALL_OK" in out
    assert "explicit grad sync == implicit GSPMD" in out
