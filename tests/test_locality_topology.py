"""Region/class assignment on non-power-of-two and asymmetric topologies.

The cost model reshapes per-proc arrays to (n_regions, procs_per_region);
the planners classify message locality with ``Topology.same_region``.  The
two must agree on every shape — 6 = 3x2, 12 = 3x4, 6 = 2x3, ... — or the
modeled times describe a different machine than the plans.
"""
import numpy as np
import pytest

from repro.core import (
    CommPattern,
    LASSEN,
    StepStats,
    Topology,
    build_plan,
    plan_time,
)
from repro.core.costmodel import step_time

SHAPES = [(6, 2), (6, 3), (12, 4), (12, 3), (10, 5), (14, 7)]


def ring_pattern(topo: Topology, n_per: int = 5) -> CommPattern:
    """Every proc needs one value of its successor and of the proc two
    regions ahead — a mix of intra- and inter-region edges on any shape."""
    P = topo.n_procs
    offsets = np.arange(P + 1) * n_per
    needs = []
    for q in range(P):
        peers = [(q + 1) % P, (q + 2 * topo.procs_per_region) % P]
        needs.append(np.array(sorted(p * n_per for p in set(peers) - {q}),
                              dtype=np.int64))
    return CommPattern.from_block_partition(needs, offsets)


@pytest.mark.parametrize("n_procs,ppr", SHAPES)
def test_region_assignment_consistent_with_cost_model_reshape(n_procs, ppr):
    """Topology.region/local_rank agree with the (R, ppr) reshape the
    max-rate model applies to per-proc traffic arrays."""
    topo = Topology(n_procs, ppr)
    procs = np.arange(n_procs)
    grid = procs.reshape(topo.n_regions, ppr)
    for r in range(topo.n_regions):
        for lr in range(ppr):
            p = int(grid[r, lr])
            assert topo.region(p) == r
            assert topo.local_rank(p) == lr
            assert list(topo.procs_in_region(r)) == grid[r].tolist()
    for p in range(n_procs):
        for q in range(n_procs):
            assert topo.same_region(p, q) == (p // ppr == q // ppr)


@pytest.mark.parametrize("n_procs,ppr", SHAPES)
def test_step_stats_locality_classification(n_procs, ppr):
    """StepStats intra/inter split matches Topology.same_region per message
    on asymmetric shapes (the quantities behind every modeled row)."""
    topo = Topology(n_procs, ppr)
    pattern = ring_pattern(topo)
    plan = build_plan(pattern, topo, "standard")
    (step,) = plan.steps
    ss = StepStats.from_messages("p2p", step.messages, topo)
    exp_im = np.zeros(n_procs, dtype=np.int64)
    exp_xm = np.zeros(n_procs, dtype=np.int64)
    exp_iv = np.zeros(n_procs, dtype=np.int64)
    exp_xv = np.zeros(n_procs, dtype=np.int64)
    for m in step.messages:
        if m.src == m.dst or m.size == 0:
            continue
        if topo.same_region(m.src, m.dst):
            exp_im[m.src] += 1
            exp_iv[m.src] += m.size
        else:
            exp_xm[m.src] += 1
            exp_xv[m.src] += m.size
    np.testing.assert_array_equal(ss.intra_msgs, exp_im)
    np.testing.assert_array_equal(ss.inter_msgs, exp_xm)
    np.testing.assert_array_equal(ss.intra_vals, exp_iv)
    np.testing.assert_array_equal(ss.inter_vals, exp_xv)
    # total conservation: every ghost is delivered exactly once
    assert int((ss.intra_vals + ss.inter_vals).sum()) == \
        pattern.total_ghosts()


@pytest.mark.parametrize("n_procs,ppr", SHAPES)
@pytest.mark.parametrize("strategy", ["standard", "partial", "full"])
def test_plans_correct_and_aggregation_localizes(n_procs, ppr, strategy):
    """Every strategy delivers the right ghosts on asymmetric shapes, the
    aggregated wire step crosses regions only, and the cost model scores
    the plan without reshape errors."""
    topo = Topology(n_procs, ppr)
    pattern = ring_pattern(topo)
    plan = build_plan(pattern, topo, strategy)
    vals = [100.0 * p + np.arange(5, dtype=np.float64)
            for p in range(n_procs)]
    ghosts = plan.execute_numpy(vals)
    for q in range(n_procs):
        for slot, g in enumerate(pattern.needs[q]):
            owner = int(pattern.owner_proc[g])
            oslot = int(pattern.owner_slot[g])
            assert ghosts[q][slot] == vals[owner][oslot]
    by_name = {s.name: s for s in plan.steps}
    if strategy != "standard":
        for m in by_name["g"].messages:          # wire step: inter only
            assert not topo.same_region(m.src, m.dst)
        for name in ("l", "s", "r"):             # local steps: intra only
            for m in by_name[name].messages:
                assert topo.same_region(m.src, m.dst)
    # cost model handles the (R, ppr) reshape on this shape
    t = plan_time(plan, LASSEN)
    assert np.isfinite(t) and t > 0
    for ss in plan.stats.steps:
        assert np.isfinite(step_time(ss, topo, LASSEN, 8))


def test_indivisible_region_size_rejected():
    with pytest.raises(ValueError):
        Topology(6, 4)
    with pytest.raises(ValueError):
        Topology(10, 4)
