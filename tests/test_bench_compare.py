"""Unit tests for the CI perf-regression gate (benchmarks.compare)."""
import json

import pytest

from benchmarks.compare import compare, is_deterministic, main, parse_derived


def payload(rows, schema=2, failed=()):
    return {
        "schema_version": schema,
        "git_sha": "abc",
        "failed_sections": list(failed),
        "results": [
            {"name": n, "us_per_call": us, "derived": d}
            for n, us, d in rows
        ],
    }


MODELED = ("fig/a", 100.0, "kind=modeled-lassen|x_us=41.3|strategy=partial")
MEASURED = ("bench/m", 250.0, "kind=measured-device|strategy=standard|")


def test_parse_derived():
    kind, fields = parse_derived("kind=modeled-lassen|a=1.5|flag")
    assert kind == "modeled-lassen"
    assert fields == {"a": "1.5", "flag": "flag"}
    assert is_deterministic("modeled-tpu-v5e")
    assert is_deterministic("exact-plan")
    assert not is_deterministic("measured-host")


def test_identical_runs_pass():
    base = payload([MODELED, MEASURED])
    diff = compare(base, payload([MODELED, MEASURED]))
    assert diff["status"] == "ok" and diff["checked"] == 2


def test_modeled_drift_fails():
    new = payload([("fig/a", 130.0,
                    "kind=modeled-lassen|x_us=41.3|strategy=partial"),
                   MEASURED])
    diff = compare(payload([MODELED, MEASURED]), new)
    assert diff["status"] == "regression"
    assert any(r["what"] == "modeled-us-drift" for r in diff["regressions"])


def test_modeled_derived_field_drift_fails():
    new = payload([("fig/a", 100.0,
                    "kind=modeled-lassen|x_us=55.0|strategy=partial"),
                   MEASURED])
    diff = compare(payload([MODELED, MEASURED]), new)
    assert any(r["what"] == "derived-field-drift"
               for r in diff["regressions"])


def test_selection_flip_fails():
    """A strategy/variant choice change in a deterministic row is gated."""
    new = payload([("fig/a", 100.0,
                    "kind=modeled-lassen|x_us=41.3|strategy=full"),
                   MEASURED])
    diff = compare(payload([MODELED, MEASURED]), new)
    assert any(r["what"] == "derived-field-changed"
               and r["field"] == "strategy" for r in diff["regressions"])


def test_measured_band_is_generous_but_bounded():
    ok = payload([MODELED, ("bench/m", 250.0 * 5, MEASURED[2])])
    assert compare(payload([MODELED, MEASURED]), ok)["status"] == "ok"
    bad = payload([MODELED, ("bench/m", 250.0 * 50, MEASURED[2])])
    diff = compare(payload([MODELED, MEASURED]), bad)
    assert any(r["what"] == "measured-out-of-band"
               for r in diff["regressions"])
    # measured derived fields are never compared
    relabeled = payload([MODELED,
                         ("bench/m", 240.0,
                          "kind=measured-device|strategy=partial|")])
    assert compare(payload([MODELED, MEASURED]), relabeled)["status"] == "ok"


def _overlap_row(frac, us=900.0):
    return ("spmv_overlap/measured/on", us,
            f"kind=measured-device|overlap=on|exposed_frac={frac:.4f}|")


def test_overlap_exposed_frac_gate():
    """Measured spmv_overlap rows gate exposed_frac one-sidedly."""
    base = payload([MODELED, _overlap_row(0.10)])
    # small wobble within tolerance: ok
    assert compare(base, payload([MODELED, _overlap_row(0.40)]),
                   overlap_frac_tol=0.6)["status"] == "ok"
    # regression beyond tolerance: fail
    diff = compare(base, payload([MODELED, _overlap_row(0.95)]),
                   overlap_frac_tol=0.6)
    assert any(r["what"] == "overlap-exposed-frac-regressed"
               for r in diff["regressions"])
    # one-sided: improving (or dropping to zero) never fails
    assert compare(payload([MODELED, _overlap_row(0.95)]),
                   payload([MODELED, _overlap_row(0.0)]),
                   overlap_frac_tol=0.6)["status"] == "ok"
    # rows without the field (exchange / kernel_only) are not gated
    bare = ("spmv_overlap/measured/exchange", 800.0,
            "kind=measured-device|rows=4096|")
    assert compare(payload([bare]), payload([bare]))["status"] == "ok"


def test_measured_inside_modeled_rows_exempt():
    """measured_* fields inside deterministic rows are informational."""
    base = payload([("fig/a", 100.0,
                     "kind=modeled-lassen|x_us=41.3|measured_planning_s=0.03")])
    new = payload([("fig/a", 100.0,
                    "kind=modeled-lassen|x_us=41.3|measured_planning_s=0.91")])
    assert compare(base, new)["status"] == "ok"


def test_obs_rows_gated_exactly():
    """Deterministic obs/* counter rows use rtol=0: a one-count drift that
    the modeled tolerance would wave through fails the gate."""
    base = payload([("obs/plan_cache/cold_misses", 8.0,
                     "kind=exact-plan|patterns=4|strategies=2")])
    same = payload([("obs/plan_cache/cold_misses", 8.0,
                     "kind=exact-plan|patterns=4|strategies=2")])
    assert compare(base, same)["status"] == "ok"
    # 8 -> 9 is within any generous rtol, but obs counts must be EXACT
    off_by_one = payload([("obs/plan_cache/cold_misses", 9.0,
                           "kind=exact-plan|patterns=4|strategies=2")])
    diff = compare(base, off_by_one, modeled_rtol=0.5)
    assert diff["status"] == "regression"
    assert any(r["what"] == "modeled-us-drift" for r in diff["regressions"])
    # measured obs rows (overhead timings) stay band-compared, not exact
    m_base = payload([("obs/overhead/counter_disabled", 0.05,
                       "kind=measured-host|ns_per_op=50.0")])
    m_new = payload([("obs/overhead/counter_disabled", 0.10,
                      "kind=measured-host|ns_per_op=100.0")])
    assert compare(m_base, m_new)["status"] == "ok"


def test_missing_row_fails_new_row_warns():
    diff = compare(payload([MODELED, MEASURED]), payload([MODELED]))
    assert any(r["what"] == "missing-row" for r in diff["regressions"])
    extra = ("new/row", 1.0, "kind=modeled-lassen|")
    diff = compare(payload([MODELED]), payload([MODELED, extra]))
    assert diff["status"] == "ok" and diff["new_rows"] == ["new/row"]


def test_schema_mismatch_fails():
    diff = compare(payload([MODELED]), payload([MODELED], schema=3))
    assert diff["status"] == "regression"
    assert diff["regressions"][0]["what"] == "schema-version-mismatch"


def test_failed_sections_fail():
    diff = compare(payload([MODELED]),
                   payload([MODELED], failed=["moe_comm"]))
    assert any(r["what"] == "failed-sections" for r in diff["regressions"])


@pytest.mark.parametrize("mutate,code", [
    (lambda p: p, 0),
    (lambda p: payload([("fig/a", 150.0, MODELED[2])]), 1),
])
def test_cli_exit_codes(tmp_path, mutate, code):
    base = payload([MODELED])
    b = tmp_path / "baseline.json"
    n = tmp_path / "new.json"
    d = tmp_path / "diff.json"
    b.write_text(json.dumps(base))
    n.write_text(json.dumps(mutate(base)))
    rc = main([str(b), str(n), "--diff-out", str(d)])
    assert rc == code
    assert json.loads(d.read_text())["status"] == ("ok" if code == 0
                                                   else "regression")


def test_cli_unusable_input(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text("{}")
    ok = tmp_path / "ok.json"
    ok.write_text(json.dumps(payload([MODELED])))
    assert main([str(bad), str(ok)]) == 2
