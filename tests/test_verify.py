"""Fast tier-1 subset of the static verifier (repro.verify).

Covers every pass once — pattern/plan structure + conservation, partition
and device-ELL layout checks, bucket-map exhaustiveness, kernel VMEM
budgets, the jaxpr audit of a bound executor, the PlanCache insertion
hook, the canonical pattern fingerprint, ServeEngine.verify(), and the
repo lint (self-test on seeded bugs + clean run over the tree).  The
exhaustive randomized accept/reject coverage is hypothesis P10 in
tests/test_property.py; the full plan zoo runs in CI's static-analysis
job (tools/verify_zoo.py).
"""
import dataclasses
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro.core import CommPattern, Topology, build_plan
from repro.core.cache import PlanCache, pattern_fingerprint, plan_cache_key
from repro.core.collectives import build_device_plan
from repro.core.costmodel import TPU_V5E
from repro.core.neighborhood import NeighborAlltoallV
from repro.sparse import (
    CSR,
    partition_csr,
    partitioned_to_ell,
    partitioned_to_ell_blocked,
)
from repro.sparse.device import row_block_bucket_map, select_spmv_kernel
from repro.verify import (
    VerifyError,
    audit_executor,
    check_bucket_map,
    verify_bucket_map,
    verify_collective,
    verify_device_ell,
    verify_ell_blocked,
    verify_enabled,
    verify_kernel_budget,
    verify_moe_dispatch,
    verify_moe_plan,
    verify_partition,
    verify_pattern,
    verify_plan,
)

REPO = Path(__file__).resolve().parents[1]


def small_pattern():
    needs = [np.array([4, 5, 9]), np.array([0, 8]), np.array([2]),
             np.array([1, 6])]
    return CommPattern.from_block_partition(needs, np.arange(5) * 3)


def small_partition(seed=0, n=24, n_procs=3):
    rng = np.random.default_rng(seed)
    nnz = 4 * n
    A = CSR.from_coo(rng.integers(0, n, nnz), rng.integers(0, n, nnz),
                     rng.normal(size=nnz), (n, n))
    return partition_csr(A, n_procs)


# ---------------------------------------------------------------- patterns


def test_pattern_accepts_valid():
    verify_pattern(small_pattern())


def test_pattern_rejects_broken_ownership():
    pat = small_pattern()
    pat.owner_slot[4] = pat.owner_slot[5]    # two values share one slot
    with pytest.raises(VerifyError, match="share one local slot"):
        verify_pattern(pat)


def test_pattern_rejects_out_of_range_need():
    pat = small_pattern()
    pat.needs[2] = np.array([99])
    with pytest.raises(VerifyError, match="rank=2"):
        verify_pattern(pat)


# ------------------------------------------------------------------ plans


@pytest.mark.parametrize("strategy", ["standard", "partial", "full"])
def test_plan_accepts_all_strategies(strategy):
    pat = small_pattern()
    plan = build_plan(pat, Topology(4, 2), strategy)
    verify_plan(plan)


def test_plan_rejects_dropped_delivery():
    pat = small_pattern()
    plan = build_plan(pat, Topology(4, 2), "standard")
    wire = [m for s in plan.steps for m in s.messages
            if m.src != m.dst and m.size > 0]
    wire[0].src_idx = wire[0].src_idx[:-1]
    wire[0].dst_idx = wire[0].dst_idx[:-1]
    with pytest.raises(VerifyError, match="never written"):
        verify_plan(plan)


def test_plan_rejects_duplicated_delivery():
    pat = small_pattern()
    plan = build_plan(pat, Topology(4, 2), "standard")
    # aim two copies of one payload at the same ghost slot
    wire = [m for s in plan.steps for m in s.messages
            if m.src != m.dst and m.size > 1]
    m = wire[0]
    m.dst_idx = m.dst_idx.copy()
    m.dst_idx[1] = m.dst_idx[0]
    with pytest.raises(VerifyError, match="same slot|more than once"):
        verify_plan(plan)


def test_collective_accepts_and_device_plan_checked():
    pat = small_pattern()
    coll = NeighborAlltoallV.init(pat, Topology(4, 2), "partial")
    verify_collective(coll)
    step = next(s for s in coll.device_plan.steps if s.rounds)
    step.rounds[0].gather[0, 0] = 10 ** 6
    with pytest.raises(VerifyError, match="sentinel"):
        verify_collective(coll)


# ----------------------------------------------------- partitions + layouts


def test_partition_and_layouts_accept():
    part = small_partition()
    verify_partition(part)
    ell = partitioned_to_ell(part)
    verify_device_ell(ell, part)
    bell = partitioned_to_ell_blocked(part, block_cols=8)
    verify_ell_blocked(bell, part)
    verify_bucket_map(bell, block_rows=8)


def test_partition_rejects_dropped_ghost_column():
    part = small_partition()
    assert len(part.needs[0])
    part.needs[0] = part.needs[0][:-1]
    with pytest.raises(VerifyError, match="rank=0"):
        verify_partition(part)


def test_ell_rejects_moved_nonzero():
    part = small_partition()
    ell = partitioned_to_ell(part)
    live = np.argwhere(ell.local_vals[0] != 0)
    r, k = live[0]
    ell.local_vals[0, r, k] *= 2.0
    with pytest.raises(VerifyError, match="rank=0"):
        verify_device_ell(ell, part)


def test_bucket_map_rejects_duplicated_bucket():
    part = small_partition()
    bell = partitioned_to_ell_blocked(part, block_cols=8)
    lists, counts = row_block_bucket_map(bell, block_rows=8)
    lists = np.concatenate([lists, np.zeros_like(lists[:, :, :1])], axis=2)
    p, rb = np.argwhere(counts > 0)[0]
    n = int(counts[p, rb])
    lists[p, rb, n] = lists[p, rb, n - 1]
    counts = counts.copy()
    counts[p, rb] = n + 1
    with pytest.raises(VerifyError, match="accumulated twice"):
        check_bucket_map(bell, lists, counts, block_rows=8)


def test_bucket_map_rejects_missing_bucket():
    part = small_partition()
    bell = partitioned_to_ell_blocked(part, block_cols=8)
    lists, counts = row_block_bucket_map(bell, block_rows=8)
    p, rb = np.argwhere(counts > 0)[0]
    counts = counts.copy()
    counts[p, rb] -= 1                       # hide the last live bucket
    lists = lists.copy()
    lists[p, rb, int(counts[p, rb])] = 0     # restore padding invariant
    with pytest.raises(VerifyError, match="dropped"):
        check_bucket_map(bell, lists, counts, block_rows=8)


# ---------------------------------------------------------- kernel budgets


def test_kernel_budget_accepts_both_layouts():
    part = small_partition()
    sel = select_spmv_kernel(part)
    verify_kernel_budget(partitioned_to_ell(part), sel)
    verify_kernel_budget(
        partitioned_to_ell_blocked(part, block_cols=8),
        select_spmv_kernel(part, block_cols=8),
    )


def test_kernel_budget_rejects_underreported_selection():
    part = small_partition()
    bell = partitioned_to_ell_blocked(part, block_cols=8)
    sel = select_spmv_kernel(part, block_cols=8)
    lying = dataclasses.replace(sel, blocked_bytes=1)
    with pytest.raises(VerifyError, match="under-reports"):
        verify_kernel_budget(bell, lying)


# -------------------------------------------------------------- jaxpr audit


def test_audit_accepts_bound_executor_and_rejects_foreign_plan():
    import jax

    pat = small_pattern()
    coll = NeighborAlltoallV.init(pat, Topology(4, 2), "partial")
    mesh = jax.make_mesh((4,), ("proc",),
                         devices=jax.devices()[:4])
    fn = coll.bind(mesh, "proc")
    records = audit_executor(fn, coll.device_plan, "proc")
    assert len(records) == coll.device_plan.n_rounds
    # the same traced program must NOT pass as some other plan
    other = NeighborAlltoallV.init(pat, Topology(4, 2), "standard")
    with pytest.raises(VerifyError):
        audit_executor(fn, other.device_plan, "proc")
    with pytest.raises(VerifyError, match="axis"):
        audit_executor(fn, coll.device_plan, "wrong_axis")


# ------------------------------------------------------- PlanCache wiring


def test_cache_insertion_verifies_under_env(monkeypatch):
    monkeypatch.setenv("REPRO_VERIFY", "1")
    assert verify_enabled()
    pat = small_pattern()
    cache = PlanCache()
    cache.collective(pat, Topology(4, 2), "partial")   # valid: inserts

    # a corrupted collective must be refused at the insertion choke point
    bad = NeighborAlltoallV.init(pat, Topology(4, 2), "standard")
    wire = [m for s in bad.plan.steps for m in s.messages if m.size > 0]
    wire[0].src_idx = wire[0].src_idx[:-1]
    wire[0].dst_idx = wire[0].dst_idx[:-1]
    key = plan_cache_key(pat, Topology(4, 2), "corrupt", 8, TPU_V5E)
    with pytest.raises(VerifyError):
        cache._insert(cache._colls, key, bad, "collective")

    monkeypatch.setenv("REPRO_VERIFY", "0")
    assert not verify_enabled()
    cache._insert(cache._colls, key, bad, "collective")   # hot path: no check


def test_cache_executor_audited_under_env(monkeypatch):
    import jax

    monkeypatch.setenv("REPRO_VERIFY", "1")
    pat = small_pattern()
    cache = PlanCache()
    mesh = jax.make_mesh((4,), ("proc",), devices=jax.devices()[:4])
    fn = cache.executor(pat, Topology(4, 2), mesh, "proc", "partial")
    assert fn is cache.executor(pat, Topology(4, 2), mesh, "proc", "partial")


# ------------------------------------------------------------ fingerprints


def test_fingerprint_stable_and_distinct():
    pat = small_pattern()
    fp = pattern_fingerprint(pat)
    assert fp == pattern_fingerprint(small_pattern())    # content hash
    # any content change moves the digest
    variants = []
    v = small_pattern()
    v.needs[0] = v.needs[0][:-1]
    variants.append(v)
    v = small_pattern()
    v.needs[0] = np.array([4, 5, 10])
    variants.append(v)
    v = small_pattern()
    v.owner_proc[0] = 1
    variants.append(v)
    # moving a need between procs (same multiset of values) must differ
    v = small_pattern()
    v.needs[1], v.needs[2] = v.needs[2], v.needs[1]
    variants.append(v)
    digests = {pattern_fingerprint(x) for x in variants}
    assert fp not in digests
    assert len(digests) == len(variants)


def test_fingerprint_deterministic_across_processes():
    """The digest is a pure content hash — a fresh interpreter computes
    the identical hex string (no id()/hash()/dict-order dependence)."""
    fp = pattern_fingerprint(small_pattern())
    prog = textwrap.dedent("""
        import numpy as np
        from repro.core import CommPattern
        from repro.core.cache import pattern_fingerprint
        needs = [np.array([4, 5, 9]), np.array([0, 8]), np.array([2]),
                 np.array([1, 6])]
        pat = CommPattern.from_block_partition(needs, np.arange(5) * 3)
        print(pattern_fingerprint(pat))
    """)
    import os

    env = dict(os.environ, PYTHONPATH=str(REPO / "src"),
               PYTHONHASHSEED="17")
    out = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True,
        cwd=REPO, env=env, check=True,
    )
    assert out.stdout.strip().splitlines()[-1] == fp


# -------------------------------------------------------------------- MoE


def moe_mesh_stub(*shape):
    from types import SimpleNamespace

    names = ("pod", "data", "model")[-len(shape):] if len(shape) > 2 \
        else ("data", "model")[-len(shape):]
    return SimpleNamespace(axis_names=names, devices=np.empty(shape))


@pytest.mark.parametrize("mode", ["a2a", "hier", "hier_dedup"])
def test_moe_dispatch_verifies(mode):
    from repro.configs import reduced
    from repro.models.moe import make_moe_plan

    plan = make_moe_plan(reduced("mixtral-8x7b"), moe_mesh_stub(1, 8), 32,
                         mode=mode)
    verify_moe_dispatch(plan, 32)


def test_moe_plan_rejects_broken_geometry():
    from repro.configs import reduced
    from repro.models.moe import make_moe_plan

    plan = make_moe_plan(reduced("mixtral-8x7b"), moe_mesh_stub(1, 8), 32,
                         mode="hier")
    bad = dataclasses.replace(plan, e_per_dev=plan.e_per_dev + 1)
    with pytest.raises(VerifyError, match="e_per_dev"):
        verify_moe_plan(bad)


def test_serve_engine_verify():
    import jax.numpy as jnp

    from repro.configs import reduced
    from repro.models import Model
    from repro.serve import ServeEngine

    cfg0 = reduced("mixtral-8x7b")
    cfg = cfg0.__class__(**{**cfg0.__dict__, "dtype": jnp.float32})
    model = Model(cfg, moe_mode="auto", remat=False, moe_cap_factor=8.0)
    eng = ServeEngine(model, model.init_params(seed=0), batch_slots=2,
                      max_len=32)
    assert eng.verify() == {"moe_plans": 2}


# -------------------------------------------------------------------- lint


def test_lint_flags_seeded_bugs(tmp_path):
    sys.path.insert(0, str(REPO))
    try:
        from tools.lint_repro import lint_paths
    finally:
        sys.path.pop(0)
    bad = tmp_path / "seeded.py"
    bad.write_text(textwrap.dedent("""
        import dataclasses
        import hashlib

        @dataclasses.dataclass
        class Cfg:
            xs: list = []                      # R1
            n: int = 0

        def fingerprint(d):
            h = hashlib.blake2b()
            for k, v in d.items():             # R2
                h.update(str((k, v)).encode())
            return h.hexdigest()

        def run(tracer, plan):
            tracer.record_plan(plan, 1.0)      # R3
    """))
    rules = sorted(r for _, _, r, _ in lint_paths([bad]))
    assert rules == [
        "R1-mutable-dataclass-default",
        "R2-unsorted-hash-iteration",
        "R3-tracer-missing-pure-exchange",
    ]


def test_lint_clean_over_tree():
    """The regression guard: re-introducing any flagged pattern anywhere
    in src/ or benchmarks/ fails tier-1, not just the CI lint job."""
    sys.path.insert(0, str(REPO))
    try:
        from tools.lint_repro import lint_paths
    finally:
        sys.path.pop(0)
    findings = lint_paths([REPO / "src", REPO / "benchmarks",
                           REPO / "tools"])
    assert not findings, "\n".join(
        f"{p}:{line}: {rule} {msg}" for p, line, rule, msg in findings
    )
