"""Partial (bucket-range, carried-output) and bucket-skipping blocked SpMV
kernels vs oracles — the kernel substrate of the exchange/compute-overlap
schedule.  All Pallas calls run in interpret mode (CPU CI)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.amg import diffusion_2d
from repro.kernels.spmv_ell import (
    spmv_ell_blocked_partial_ref,
    spmv_ell_blocked_ref,
)
from repro.kernels.spmv_ell.spmv_ell import (
    spmv_ell_blocked,
    spmv_ell_blocked_partial,
    spmv_ell_blocked_skip,
)
from repro.sparse import (
    partition_csr,
    partitioned_to_ell_blocked,
    row_block_bucket_map,
)


def _random_bucketed(rng, R, C, K, bc, dtype=np.float32, empty=()):
    """Random bucketed ELL layout; buckets in ``empty`` hold all zeros."""
    cols = rng.integers(0, bc, size=(R, C * K)).astype(np.int32)
    vals = rng.normal(size=(R, C * K)).astype(dtype)
    for j in empty:
        vals[:, j * K: (j + 1) * K] = 0.0
    x = rng.normal(size=C * bc).astype(dtype)
    return jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(x)


@pytest.mark.parametrize("R,C,K,bc,br", [(64, 5, 4, 16, 16),
                                         (97, 5, 3, 32, 32),   # prime R
                                         (128, 2, 6, 64, 32)])
@pytest.mark.parametrize("lo,hi", [(0, 1), (1, 2), (0, 2), (2, 2)])
def test_partial_vs_ref(R, C, K, bc, br, lo, hi):
    """Carried-output partial kernel vs its oracle on every bucket range
    (including the empty range, which must return y0 exactly)."""
    rng = np.random.default_rng(6)
    cols, vals, x = _random_bucketed(rng, R, C, K, bc)
    y0 = jnp.asarray(rng.normal(size=R).astype(np.float32))
    xs = x[lo * bc: hi * bc]
    want = spmv_ell_blocked_partial_ref(cols, vals, xs, y0, lo, hi, bc, C)
    got = spmv_ell_blocked_partial(
        cols, vals, xs, y0, bucket_lo=lo, bucket_hi=hi, n_buckets=C,
        block_cols=bc, block_rows=br, interpret=True,
    )
    assert got.shape == (R,)
    if hi == lo:
        np.testing.assert_array_equal(np.asarray(got), np.asarray(y0))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("R,C,K,bc,br,split", [(64, 5, 4, 16, 16, 3),
                                               (97, 4, 3, 32, 32, 1),
                                               (128, 3, 6, 64, 32, 2)])
def test_partial_composition_equals_full(R, C, K, bc, br, split):
    """local buckets [0, split) then ghost buckets [split, C) carried on
    top — the overlap schedule's two phases — must equal the one-shot
    blocked kernel and its oracle."""
    rng = np.random.default_rng(7)
    cols, vals, x = _random_bucketed(rng, R, C, K, bc)
    full = spmv_ell_blocked(cols, vals, x, block_cols=bc, block_rows=br,
                            interpret=True)
    want = spmv_ell_blocked_ref(cols, vals, x, bc)
    y_local = spmv_ell_blocked_partial(
        cols, vals, x[: split * bc], jnp.zeros((R,), vals.dtype),
        bucket_lo=0, bucket_hi=split, n_buckets=C, block_cols=bc,
        block_rows=br, interpret=True,
    )
    y = spmv_ell_blocked_partial(
        cols, vals, x[split * bc:], y_local,
        bucket_lo=split, bucket_hi=C, n_buckets=C, block_cols=bc,
        block_rows=br, interpret=True,
    )
    np.testing.assert_allclose(np.asarray(y), np.asarray(full),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("empty", [(), (1,), (0, 2, 4)])
def test_skip_vs_ref_with_empty_buckets(empty):
    """Bucket-skipping kernel (scalar-prefetched per-row-block bucket
    lists) vs the dense oracle; zero buckets may be skipped entirely."""
    R, C, K, bc, br = 64, 5, 4, 16, 16
    rng = np.random.default_rng(8)
    cols, vals, x = _random_bucketed(rng, R, C, K, bc, empty=empty)
    want = spmv_ell_blocked_ref(cols, vals, x, bc)

    # host-side bucket lists: which buckets are live per row block
    nrb = R // br
    live = (np.asarray(vals).reshape(R, C, K) != 0).any(-1)
    live_rb = live.reshape(nrb, br, C).any(1)
    counts = live_rb.sum(1).astype(np.int32)
    M = max(int(counts.max()), 1)
    lists = np.zeros((nrb, M), np.int32)
    for rb in range(nrb):
        idx = np.flatnonzero(live_rb[rb])
        lists[rb, : len(idx)] = idx
    assert M == C - len(empty) or (M == 1 and C - len(empty) == 0)

    got = spmv_ell_blocked_skip(
        cols, vals, x, jnp.asarray(lists), jnp.asarray(counts),
        n_buckets=C, block_cols=bc, block_rows=br, interpret=True,
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_skip_ghost_phase_carried():
    """Skip kernel over a trailing bucket window with bucket_base and a
    carried y0 (the overlap schedule's ghost phase) vs the partial oracle."""
    R, C, K, bc, br = 64, 6, 3, 16, 16
    base = 4  # ghost buckets [4, 6)
    rng = np.random.default_rng(9)
    cols, vals, x = _random_bucketed(rng, R, C, K, bc)
    y0 = jnp.asarray(rng.normal(size=R).astype(np.float32))
    want = spmv_ell_blocked_partial_ref(
        cols, vals, x[base * bc:], y0, base, C, bc, C
    )
    nrb = R // br
    lists = jnp.asarray(np.tile(np.arange(base, C, dtype=np.int32),
                                (nrb, 1)))
    counts = jnp.asarray(np.full(nrb, C - base, np.int32))
    got = spmv_ell_blocked_skip(
        cols, vals, x[base * bc:], lists, counts,
        n_buckets=C, block_cols=bc, bucket_base=base, y0=y0,
        block_rows=br, interpret=True,
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_skip_equals_blocked_on_amg_matrix():
    """On a real partitioned operator, the skip kernel fed by
    row_block_bucket_map matches the dense blocked kernel (a banded
    operator leaves most off-diagonal buckets empty)."""
    A = diffusion_2d(24, 24)
    part = partition_csr(A, 4)
    bell = partitioned_to_ell_blocked(part, block_cols=32)
    lists, counts = row_block_bucket_map(bell, block_rows=16)
    assert lists.shape[2] < bell.n_buckets  # skipping actually engages
    rng = np.random.default_rng(10)
    for p in range(bell.n_procs):
        # f32 on-device (tier-1 runs without x64): summation-order changes
        # between dense and skipping accumulation stay within f32 rounding
        x = rng.normal(size=bell.x_len).astype(np.float32)
        want = spmv_ell_blocked_ref(
            jnp.asarray(bell.cols[p]), jnp.asarray(bell.vals[p]),
            jnp.asarray(x), bell.block_cols,
        )
        got = spmv_ell_blocked_skip(
            jnp.asarray(bell.cols[p]), jnp.asarray(bell.vals[p]),
            jnp.asarray(x), jnp.asarray(lists[p]), jnp.asarray(counts[p]),
            n_buckets=bell.n_buckets, block_cols=bell.block_cols,
            block_rows=16, interpret=True,
        )
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
