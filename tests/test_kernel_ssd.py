"""SSD scan kernel + chunked ref vs per-timestep recurrence oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import use_backend
from repro.kernels.ssd_scan import ssd, ssd_chunked_ref, ssd_ref
from repro.kernels.ssd_scan.ssd_scan import ssd_scan_h


def make_inputs(rng, H=4, T=64, P=16, N=8, dtype=np.float32):
    x = rng.normal(size=(H, T, P)).astype(dtype)
    dt = (0.01 + 0.2 * rng.random(size=(H, T))).astype(dtype)
    A = (-0.5 - rng.random(H)).astype(dtype)
    B = rng.normal(size=(H, T, N)).astype(dtype)
    C = rng.normal(size=(H, T, N)).astype(dtype)
    return map(jnp.asarray, (x, dt, A, B, C))


@pytest.mark.parametrize("T,chunk", [(32, 8), (64, 16), (128, 32), (96, 32)])
def test_chunked_ref_matches_scan(T, chunk):
    rng = np.random.default_rng(0)
    x, dt, A, B, C = make_inputs(rng, T=T)
    want = ssd_ref(x, dt, A, B, C)
    got = ssd_chunked_ref(x, dt, A, B, C, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("H,T,P,N,chunk", [
    (2, 32, 8, 8, 8),
    (4, 64, 16, 8, 16),
    (3, 128, 32, 16, 32),
])
@pytest.mark.parametrize("dtype", [np.float32, "bfloat16"])
def test_kernel_matches_scan(H, T, P, N, chunk, dtype):
    rng = np.random.default_rng(1)
    dt_np = np.float32
    x, dt, A, B, C = make_inputs(rng, H=H, T=T, P=P, N=N, dtype=dt_np)
    if dtype == "bfloat16":
        x = x.astype(jnp.bfloat16)
    want = ssd_ref(x.astype(jnp.float32), dt, A, B, C)
    got = ssd_scan_h(x, dt, A, B, C, chunk=chunk, interpret=True)
    tol = 3e-2 if dtype == "bfloat16" else 1e-4
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


def test_batched_op_group_broadcast():
    """ops.ssd with grouped B/C (G < H) against the manual repeat."""
    rng = np.random.default_rng(2)
    Bt, T, H, G, P, N = 2, 32, 4, 2, 8, 8
    x = jnp.asarray(rng.normal(size=(Bt, T, H, P)).astype(np.float32))
    dt = jnp.asarray((0.01 + 0.2 * rng.random((Bt, T, H))).astype(np.float32))
    A = jnp.asarray((-1.0 - rng.random(H)).astype(np.float32))
    B = jnp.asarray(rng.normal(size=(Bt, T, G, N)).astype(np.float32))
    C = jnp.asarray(rng.normal(size=(Bt, T, G, N)).astype(np.float32))
    with use_backend("pallas_interpret"):
        got = ssd(x, dt, A, B, C, chunk=8)
    # oracle: per batch, repeat groups then per-timestep scan
    Bh = jnp.repeat(B, H // G, axis=2)
    Ch = jnp.repeat(C, H // G, axis=2)
    for b in range(Bt):
        want = ssd_ref(
            jnp.moveaxis(x[b], 1, 0), jnp.moveaxis(dt[b], 1, 0), A,
            jnp.moveaxis(Bh[b], 1, 0), jnp.moveaxis(Ch[b], 1, 0),
        )
        np.testing.assert_allclose(
            np.asarray(jnp.moveaxis(got[b], 1, 0)), np.asarray(want),
            rtol=1e-4, atol=1e-4,
        )
