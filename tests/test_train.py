"""Trainer substrate: optimizer math, schedules, data determinism,
compression, end-to-end loss decrease on a tiny model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced
from repro.models import Model
from repro.train import (
    AdamWConfig,
    DataConfig,
    TokenStream,
    TrainerConfig,
    adamw_update,
    compress,
    decompress,
    ef_compress_tree,
    init_opt_state,
    init_residual,
    lr_at,
    make_train_state,
    make_train_step,
)


def test_adamw_matches_reference():
    """One step of our AdamW vs a hand-rolled numpy reference."""
    rng = np.random.default_rng(0)
    p = {"w": jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32)),
         "b": jnp.asarray(rng.normal(size=(3,)).astype(np.float32))}
    g = jax.tree.map(lambda a: jnp.ones_like(a) * 0.1, p)
    cfg = AdamWConfig(lr=1e-2, warmup_steps=1, weight_decay=0.5,
                      grad_clip=1e9)
    st = init_opt_state(p)
    newp, st2, m = adamw_update(cfg, p, g, st)
    # reference
    for name, is_mat in (("w", True), ("b", False)):
        gg = 0.1
        mm = (1 - cfg.b1) * gg / (1 - cfg.b1)
        vv = (1 - cfg.b2) * gg * gg / (1 - cfg.b2)
        delta = mm / (np.sqrt(vv) + cfg.eps)
        want = np.asarray(p[name]) - cfg.lr * (
            delta + (cfg.weight_decay * np.asarray(p[name]) if is_mat else 0)
        )
        np.testing.assert_allclose(np.asarray(newp[name]), want, rtol=1e-5)
    assert int(st2.step) == 1


def test_lr_schedules():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      schedule="cosine", min_lr_frac=0.1)
    lrs = [float(lr_at(cfg, jnp.asarray(s))) for s in [0, 9, 50, 99]]
    assert lrs[0] < lrs[1] <= 1.0
    assert lrs[2] < lrs[1]
    assert lrs[3] >= 0.099
    wsd = AdamWConfig(lr=1.0, warmup_steps=1, total_steps=100,
                      schedule="wsd")
    assert abs(float(lr_at(wsd, jnp.asarray(50)))) > 0.9


def test_grad_clip():
    p = {"w": jnp.zeros((2, 2))}
    g = {"w": jnp.full((2, 2), 100.0)}
    cfg = AdamWConfig(grad_clip=1.0, warmup_steps=1)
    _, _, m = adamw_update(cfg, p, g, init_opt_state(p))
    assert float(m["gnorm"]) == pytest.approx(200.0)


def test_data_deterministic_and_shardable():
    cfg = DataConfig(vocab=128, seq_len=32, global_batch=8, seed=3)
    s = TokenStream(cfg)
    a = s.sample(step=5, shard=0, n_shards=1)
    # resharded into 2: concatenation of both shards == the single shard
    b0 = s.sample(step=5, shard=0, n_shards=2)
    b1 = s.sample(step=5, shard=1, n_shards=2)
    np.testing.assert_array_equal(
        a["tokens"], np.concatenate([b0["tokens"], b1["tokens"]])
    )
    # next-token labels
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_compression_error_feedback():
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
    q, s = compress(g)
    deq = decompress(q, s)
    assert float(jnp.abs(g - deq).max()) <= float(s) * 0.51 + 1e-6
    # error feedback: accumulated compressed steps converge to the truth
    grads = {"w": g}
    res = init_residual(grads)
    total = jnp.zeros_like(g)
    for _ in range(50):
        out, res = ef_compress_tree(grads, res)
        total = total + out["w"]
    np.testing.assert_allclose(np.asarray(total / 50), np.asarray(g),
                               atol=float(s) * 1.1)


@pytest.mark.parametrize("microbatches", [1, 2])
def test_loss_decreases_tiny_model(microbatches):
    cfg0 = reduced("qwen2-0.5b")
    cfg = cfg0.__class__(**{**cfg0.__dict__, "dtype": jnp.float32,
                            "vocab": 128})
    model = Model(cfg, remat=False)
    tcfg = TrainerConfig(
        opt=AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60),
        microbatches=microbatches,
    )
    state = make_train_state(model, tcfg, seed=0)
    step = jax.jit(make_train_step(model, tcfg))
    data = TokenStream(DataConfig(vocab=128, seq_len=32, global_batch=4))
    losses = []
    for i in range(30):
        batch = jax.tree.map(jnp.asarray, data.global_batch_at(i))
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::6]
    assert np.isfinite(losses).all()
