"""Integration: the elastic/straggler runtime layer on 8 virtual devices.

The heavy check (mid-solve and mid-decode shrink vs cold start, warm
grow-back via plan-cache counters, injected-straggler rebalance+refit)
runs in a subprocess with XLA_FLAGS set at spawn so the main pytest
process keeps its device configuration.  Single-process edge cases of the
same machinery live in test_runtime.py.
"""
import os
import pathlib
import subprocess
import sys

PROGS = pathlib.Path(__file__).parent / "multidevice_progs"
SRC = pathlib.Path(__file__).resolve().parents[1] / "src"


def run_prog(name: str, timeout=600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(SRC) + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    out = subprocess.run(
        [sys.executable, str(PROGS / name)],
        env=env,
        capture_output=True,
        text=True,
        timeout=timeout,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_elastic_solve_serve_straggler():
    out = run_prog("check_elastic.py")
    assert "ALL_OK" in out
    # mid-solve shrink matches cold start; grow-back re-plans nothing
    assert "solve shrink/grow OK" in out
    assert "grow:   resize[requested] 4->8 procs: warm" in out
    # mid-decode shrink matches cold start; serve grow-back is warm too
    assert "decode shrink/grow OK" in out
    assert "serve grow:   resize[requested] 4->8 procs: warm" in out
    # exactly one rebalance+refit episode for the injected straggler
    assert out.count("mitigation: rebalance@") == 1
    assert "straggler mitigation OK" in out
