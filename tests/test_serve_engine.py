"""Batched serving engine: slot recycling, drain, output consistency,
and MoE dispatch-plan amortization across decode steps."""
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced
from repro.core import default_plan_cache
from repro.models import Model
from repro.serve import Request, ServeEngine


def test_engine_drains_mixed_requests():
    cfg0 = reduced("qwen2-0.5b")
    cfg = cfg0.__class__(**{**cfg0.__dict__, "dtype": jnp.float32})
    model = Model(cfg, remat=False)
    params = model.init_params(seed=0)
    eng = ServeEngine(model, params, batch_slots=2, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i,
                prompt=rng.integers(0, cfg.vocab, size=(4 + i,)).astype(
                    np.int32),
                max_new_tokens=3 + i % 2)
        for i in range(4)
    ]
    for r in reqs:
        eng.submit(r)
    done = eng.run_until_drained(max_steps=200)
    assert len(done) == 4
    assert all(r.done for r in done)
    for r in done:
        assert len(r.generated) == r.max_new_tokens
        assert all(0 <= t < cfg.vocab for t in r.generated)


def test_moe_engine_decode_replans_nothing():
    """Serving a MoE model: the engine pre-plans its static decode-step
    dispatch at construction, so decode steps cause zero additional
    plan-cache misses — the whole point of the persistent collective."""
    cfg0 = reduced("mixtral-8x7b")
    cfg = cfg0.__class__(**{**cfg0.__dict__, "dtype": jnp.float32})
    model = Model(cfg, moe_mode="auto", remat=False, moe_cap_factor=8.0)
    params = model.init_params(seed=0)
    eng = ServeEngine(model, params, batch_slots=2, max_len=32)
    assert eng.plan_cache is default_plan_cache()
    rng = np.random.default_rng(1)
    eng.submit(Request(rid=0,
                       prompt=rng.integers(0, cfg.vocab, size=(4,))
                       .astype(np.int32),
                       max_new_tokens=6))
    eng.step()                      # admit + prefill (may plan: new shape)
    eng.step()                      # first decode: plan pre-warmed at init
    cache = eng.plan_cache
    m0, e0 = cache.misses, cache.exec_misses
    for _ in range(3):
        eng.step()
    assert (cache.misses, cache.exec_misses) == (m0, e0)
