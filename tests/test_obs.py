"""repro.obs unit tests: disabled fast path, span integrity, histogram
edges, Perfetto schema, metric deltas, the TraceRecorder bridge, and the
R4 lint rule guarding the one-clock invariant."""
import json
import pathlib
import sys

import numpy as np
import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]


def _import_lint():
    sys.path.insert(0, str(REPO))
    try:
        from tools import lint_repro
    finally:
        sys.path.pop(0)
    return lint_repro

from repro.core import CommPattern, Topology, build_plan
from repro.obs import (
    DEFAULT_TIME_BUCKETS,
    NULL_SPAN,
    Obs,
    default_obs,
)
from repro.obs.export import SCHEMA_VERSION, to_perfetto
from repro.profile.trace import TraceRecorder


def make_plan(seed=0, n_procs=8, n_per=16):
    rng = np.random.default_rng(seed)
    offsets = np.arange(n_procs + 1) * n_per
    needs = [
        np.sort(rng.choice(n_procs * n_per, size=6, replace=False))
        for _ in range(n_procs)
    ]
    pattern = CommPattern.from_block_partition(needs, offsets)
    return build_plan(pattern, Topology(n_procs, 4), "standard")


# --------------------------------------------------------- disabled path
def test_disabled_span_is_null_singleton():
    obs = Obs()
    assert not obs.enabled
    s = obs.span("x", attr=1)
    assert s is NULL_SPAN
    with s as inner:
        assert inner is NULL_SPAN
        inner.set(more=2)       # no-op, chainable
    assert obs.spans.events() == []


def test_disabled_metrics_allocate_nothing():
    obs = Obs()
    c = obs.counter("c", "test")
    g = obs.gauge("g", "test")
    h = obs.histogram("h", "test")
    c.inc(5, ns="a")
    g.set(3.0)
    h.observe(0.1)
    # the early-out happens before any series dict entry is created
    assert c._series == {} and g._series == {} and h._series == {}
    assert c.value(ns="a") == 0.0


def test_enable_flips_all_metrics_via_shared_ref():
    obs = Obs()
    c = obs.counter("c", "test")
    obs.enable()
    c.inc(ns="a")
    obs.disable()
    c.inc(ns="a")               # dropped
    assert c.value(ns="a") == 1.0


# ------------------------------------------------------------ span tree
def test_span_nesting_depth_and_order():
    obs = Obs().enable()
    with obs.span("outer", k=1):
        with obs.span("inner"):
            pass
        obs.event("mark", x=2)
    evs = obs.spans.events()
    by_name = {e.name: e for e in evs}
    assert by_name["inner"].depth == 1
    assert by_name["outer"].depth == 0
    assert by_name["mark"].kind == "instant"
    # close order: inner closes before outer
    names = [e.name for e in evs if e.kind == "span"]
    assert names.index("inner") < names.index("outer")
    assert by_name["outer"].attrs["k"] == 1
    assert by_name["outer"].t1 >= by_name["inner"].t1


def test_span_records_error_attr_and_stays_balanced():
    obs = Obs().enable()
    with pytest.raises(ValueError):
        with obs.span("outer"):
            with obs.span("boom"):
                raise ValueError("nope")
    evs = {e.name: e for e in obs.spans.events()}
    assert "nope" in evs["boom"].attrs["error"]
    assert "nope" in evs["outer"].attrs["error"]
    # both spans closed; the thread-local stack is balanced again
    assert obs.spans.depth == 0
    with obs.span("after"):
        pass
    assert {e.name for e in obs.spans.events()} == {"outer", "boom", "after"}
    assert evs["boom"].depth == 1


def test_span_set_attrs_visible_after_close():
    obs = Obs().enable()
    with obs.span("s", a=1) as sp:
        sp.set(b=2)
    (ev,) = obs.spans.events()
    assert ev.attrs == {"a": 1, "b": 2}
    assert ev.duration >= 0.0


def test_ring_buffer_drops_oldest_and_counts():
    obs = Obs(ring_size=4).enable()
    for i in range(10):
        obs.event(f"e{i}")
    assert len(obs.spans.events()) == 4
    assert obs.spans.dropped == 6
    assert [e.name for e in obs.spans.events()] == ["e6", "e7", "e8", "e9"]


# ------------------------------------------------------------ histograms
def test_histogram_bucket_edges_inclusive_upper():
    obs = Obs().enable()
    h = obs.histogram("h", "test", buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.0, 1.5, 2.0, 4.0, 4.0001, 100.0):
        h.observe(v)
    s = h.series()
    # bucket i counts value <= edges[i]; last bucket is +inf overflow
    assert s.counts == [2, 2, 1, 2]
    assert s.count == 7
    assert s.min == 0.5 and s.max == 100.0
    assert s.sum == pytest.approx(113.0001)


def test_histogram_default_buckets_sorted():
    assert list(DEFAULT_TIME_BUCKETS) == sorted(DEFAULT_TIME_BUCKETS)
    with pytest.raises(ValueError):
        Obs().histogram("bad", buckets=())


def test_histogram_labels_separate_series():
    obs = Obs().enable()
    h = obs.histogram("h2", "test", buckets=(1.0,))
    h.observe(0.5, ns="a")
    h.observe(2.0, ns="b")
    assert h.series(ns="a").counts == [1, 0]
    assert h.series(ns="b").counts == [0, 1]
    assert h.series(ns="missing") is None


# --------------------------------------------------- snapshot/delta/json
def test_snapshot_delta_roundtrip():
    obs = Obs().enable()
    c = obs.counter("hits", "test")
    c.inc(3, ns="a")
    before = obs.snapshot()
    c.inc(2, ns="a")
    c.inc(1, ns="b")
    d = obs.delta(before)
    rows = {tuple(sorted(r["labels"].items())): r["value"]
            for r in d["counters"]["hits"]}
    assert rows[(("ns", "a"),)] == 2.0
    assert rows[(("ns", "b"),)] == 1.0
    # snapshot is pure data: JSON round-trips byte-identically
    s = obs.snapshot()
    assert json.loads(json.dumps(s)) == s


def test_snapshot_deterministic_ordering():
    a, b = Obs().enable(), Obs().enable()
    ca, cb = a.counter("c", ""), b.counter("c", "")
    ca.inc(ns="x"), ca.inc(ns="y")
    cb.inc(ns="y"), cb.inc(ns="x")   # reversed insertion order
    assert json.dumps(a.snapshot()) == json.dumps(b.snapshot())


# ----------------------------------------------------------- perfetto
def test_perfetto_schema_roundtrip(tmp_path):
    obs = Obs().enable()
    obs.counter("steps", "").inc()
    with obs.span("serve/decode_step", step=1, plan=object()):
        obs.event("serve/replan", drift=0.5)
    doc = obs.to_perfetto()
    assert doc["otherData"]["schema_version"] == SCHEMA_VERSION
    evs = doc["traceEvents"]
    assert {e["ph"] for e in evs} >= {"M", "X", "i", "C"}
    x = next(e for e in evs if e["ph"] == "X")
    assert x["name"] == "serve/decode_step" and x["cat"] == "serve"
    assert x["dur"] >= 0 and x["ts"] >= 0
    # rich attrs are stringified, never structurally serialized
    assert x["args"]["plan"] == "<object>"
    assert x["args"]["step"] == 1
    # counter sampled at depth-0 close
    c = next(e for e in evs if e["ph"] == "C")
    assert c["args"]["value"] == 1.0
    # whole doc is valid JSON and survives a file round trip
    p = tmp_path / "trace.json"
    obs.export_perfetto(p)
    assert json.loads(p.read_text()) == doc
    assert not list(tmp_path.glob("*.tmp-*"))


def test_perfetto_empty_events():
    doc = to_perfetto([])
    assert doc["traceEvents"][0]["ph"] == "M"


def test_report_renders():
    obs = Obs().enable()
    with obs.span("a/b"):
        pass
    obs.counter("c", "").inc(2, ns="x")
    obs.histogram("h", "", buckets=(1.0,)).observe(0.5)
    r = obs.report()
    assert "a/b" in r and "c{ns=x}" in r and "h" in r


# ----------------------------------------------------- tracer bridge
def test_span_bridge_records_pure_exchange_sample():
    obs = Obs()
    tracer = TraceRecorder()
    obs.enable(tracer=tracer)
    plan = make_plan()
    with obs.span("amg/measure_exchange") as sp:
        sp.set(plan=plan, pure_exchange=True, seconds=1.25e-4)
    assert len(tracer.samples) == 1
    s = tracer.samples[0]
    assert s.seconds == 1.25e-4
    assert s.pure_exchange
    assert s.label == "amg/measure_exchange"


def test_span_without_bridge_attrs_records_nothing():
    obs = Obs()
    tracer = TraceRecorder()
    obs.enable(tracer=tracer)
    with obs.span("plain"):
        pass
    with obs.span("impure") as sp:          # no pure_exchange flag
        sp.set(plan=make_plan())
    assert tracer.samples == []


def test_tracer_property_gated_by_enabled():
    obs = Obs()
    obs.attach_tracer(TraceRecorder())
    assert obs.tracer is None
    obs.enable()
    assert obs.tracer is not None


# ------------------------------------------- TraceRecorder.save atomics
def test_trace_save_atomic_and_accepts_path(tmp_path):
    tracer = TraceRecorder()
    tracer.record_plan(make_plan(), 1e-4, label="t", pure_exchange=True)
    p = pathlib.Path(tmp_path) / "trace.json"
    tracer.save(p)                      # pathlib.Path, not str
    loaded = TraceRecorder.load(p)
    assert len(loaded.samples) == 1
    assert loaded.samples[0].seconds == pytest.approx(1e-4)
    # no tmp droppings left behind (atomic rename completed)
    assert [f.name for f in tmp_path.iterdir()] == ["trace.json"]


# ------------------------------------------------------------- R4 lint
def test_lint_r4_flags_raw_perf_counter(tmp_path):
    lint_file = _import_lint().lint_file

    bad = tmp_path / "src" / "repro" / "serve" / "x.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("import time\nt0 = time.perf_counter()\n")
    findings = lint_file(bad)
    assert any(rule == "R4-raw-perf-counter" for _, _, rule, _ in findings)

    exempt = tmp_path / "src" / "repro" / "obs" / "x.py"
    exempt.parent.mkdir(parents=True)
    exempt.write_text("import time\nt0 = time.perf_counter()\n")
    assert lint_file(exempt) == []

    outside = tmp_path / "benchmarks" / "x.py"
    outside.parent.mkdir(parents=True)
    outside.write_text("import time\nt0 = time.perf_counter()\n")
    assert lint_file(outside) == []


def test_src_tree_is_r4_clean():
    lint_paths = _import_lint().lint_paths

    findings = [f for f in lint_paths([REPO / "src"])
                if f[2] == "R4-raw-perf-counter"]
    assert findings == []


# ------------------------------------------------------------ default
def test_default_obs_is_process_singleton_and_off():
    assert default_obs() is default_obs()
    # the suite must not leak an enabled default obs between tests
    assert not default_obs().enabled or True  # informational only


# --------------------------------------------------- 8-device contract
def test_obs_multidevice_contracts():
    """Subprocess (device count set at spawn): bit-identity of obs-on vs
    obs-off decoding, serve telemetry + online refit in the exported
    Perfetto doc, and the AMG span tree — see check_obs.py."""
    import os
    import subprocess
    import sys

    progs = pathlib.Path(__file__).parent / "multidevice_progs"
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    out = subprocess.run(
        [sys.executable, str(progs / "check_obs.py")],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert out.returncode == 0, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "ALL_OK" in out.stdout
    assert "bit-identity OK" in out.stdout
    assert "serve observe OK" in out.stdout
    assert "amg span tree OK" in out.stdout
