"""Subprocess program: column-blocked SpMV through the distributed solve.

Run by tests/test_distributed_amg.py on 8 virtual host devices
(XLA_FLAGS=--xla_force_host_platform_device_count=8, set before jax import).

Checks, on the 48x48 rotated anisotropic diffusion problem:
  1. a hierarchy with the blocked kernel FORCED on every level solves to
     the host solver's residual history (the blocked packing + accumulating
     kernel path is numerically identical to flat);
  2. auto-selection under a lowered VMEM threshold (standing in for a
     paper-scale fine level, whose x footprint exceeds the real threshold
     the same way) picks blocked on the fine level while at least one
     coarse level keeps flat, records the choice per operator, and the
     mixed-variant solve still matches the host;
  3. the one-shot distributed SpMV agrees with the host oracle for every
     variant on the fine operator;
  4. the kernel choice is visible in kernel_table() and describe().
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.amg import DistributedHierarchy, build_hierarchy, diffusion_2d, solve
from repro.core import PlanCache, Topology
from repro.sparse import distributed_spmv, partition_csr, select_spmv_kernel


def main():
    assert jax.device_count() == 8, jax.devices()
    mesh = jax.make_mesh((8,), ("proc",))

    A = diffusion_2d(48, 48)
    h = build_hierarchy(A)
    rng = np.random.default_rng(0)
    b = rng.normal(size=A.nrows)

    # -- host reference -----------------------------------------------------
    x_host, hist_host = solve(h, b, tol=1e-8, max_iters=60)
    assert hist_host[-1] < 1e-8, hist_host[-5:]

    # (3) one-shot distributed SpMV, all variants, vs host oracle
    part = partition_csr(h.levels[0].A, 8)
    cache = PlanCache()
    coll = cache.collective(part.pattern, Topology(8, 4), "auto")
    for variant in ("flat", "blocked", "auto"):
        y = distributed_spmv(part, coll, mesh, "proc", b,
                             variant=variant, block_cols=64)
        np.testing.assert_allclose(y, h.levels[0].A.matvec(b),
                                   rtol=1e-12, atol=1e-12)
    print("spmv variants OK")

    # (1) forced-blocked hierarchy matches the host residual history
    dh_blk = DistributedHierarchy.setup(
        h, mesh, procs_per_region=4, cache=PlanCache(),
        spmv_variant="blocked", spmv_block_cols=64,
    )
    assert all(lv.A.kernel_variant == "blocked" for lv in dh_blk.levels)
    assert all(lv.A.kernel and lv.A.kernel.forced for lv in dh_blk.levels)
    x_blk, hist_blk = dh_blk.solve(b, tol=1e-8, max_iters=60)
    assert len(hist_blk) == len(hist_host), (len(hist_blk), len(hist_host))
    np.testing.assert_allclose(
        np.asarray(hist_blk), np.asarray(hist_host), rtol=1e-8, atol=1e-15
    )
    print(f"forced-blocked residual history OK ({len(hist_blk)} iters, "
          f"final={hist_blk[-1]:.3e})")

    # (2) auto selection: threshold below the fine level's flat footprint
    # (a paper-scale fine level exceeds the *default* threshold the same
    # way — its x alone is ~17 MB; here we lower the threshold instead of
    # materializing 2M rows per device)
    flat_bytes = [
        select_spmv_kernel(partition_csr(lv.A, 8)).flat_bytes
        for lv in h.levels
    ]
    limit = (min(flat_bytes) + flat_bytes[0]) // 2
    assert flat_bytes[0] > limit > min(flat_bytes)
    dh = DistributedHierarchy.setup(
        h, mesh, procs_per_region=4, cache=PlanCache(),
        spmv_variant="auto", spmv_vmem_limit=limit, spmv_block_cols=64,
    )
    variants = {lv.index: lv.A.kernel_variant for lv in dh.levels}
    print(f"auto variants under {limit}B limit: {variants}")
    assert variants[0] == "blocked", variants     # fine level over budget
    assert "flat" in variants.values(), variants  # coarse keeps flat
    for lv in dh.levels:
        assert lv.A.kernel is not None and not lv.A.kernel.forced
    x_dev, hist_dev = dh.solve(b, tol=1e-8, max_iters=60)
    np.testing.assert_allclose(
        np.asarray(hist_dev), np.asarray(hist_host), rtol=1e-8, atol=1e-15
    )
    print("auto mixed-variant residual history OK")

    # (4) the choice is recorded and visible
    kt = dh.kernel_table()
    assert any(v == "blocked" for _, _, v, _, _ in kt)
    assert all(rep and "limit=" in rep for _, _, _, _, rep in kt)
    desc = dh.describe()
    assert "kern=blocked" in desc and "kern=flat" in desc
    print(desc)

    print("ALL_OK")


if __name__ == "__main__":
    main()
