"""Subprocess program: device-resident distributed AMG V-cycle vs host solver.

Run by tests/test_distributed_amg.py on 8 virtual host devices
(XLA_FLAGS=--xla_force_host_platform_device_count=8, set before jax import).

Checks, on the 64x64 rotated anisotropic diffusion problem:
  1. the jitted device V-cycle's residual history matches the host
     ``Hierarchy`` solver's to 1e-8 relative tolerance;
  2. the Section-5 auto-selector picks >= 2 distinct strategies across
     levels (fine -> standard, coarse -> aggregated);
  3. a second setup on the same hierarchy hits the plan cache only
     (no re-planning), and the bound executors are reused as-is;
  4. the device distributed SpMV matches the host oracle on the fine level;
  5. measured device exchange times are finite and positive.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.amg import DistributedHierarchy, build_hierarchy, diffusion_2d, solve
from repro.core import PlanCache, Topology
from repro.sparse import distributed_spmv, partition_csr


def main():
    assert jax.device_count() == 8, jax.devices()
    mesh = jax.make_mesh((8,), ("proc",))

    A = diffusion_2d(64, 64)
    h = build_hierarchy(A)
    rng = np.random.default_rng(0)
    b = rng.normal(size=A.nrows)

    # -- host reference -----------------------------------------------------
    x_host, hist_host = solve(h, b, tol=1e-8, max_iters=60)
    assert hist_host[-1] < 1e-8, hist_host[-5:]

    # -- device hierarchy ---------------------------------------------------
    cache = PlanCache()
    dh = DistributedHierarchy.setup(
        h, mesh, procs_per_region=4, strategy="auto", cache=cache
    )
    print(dh.describe())

    # (4) fine-level device SpMV vs host oracle
    part = partition_csr(h.levels[0].A, 8)
    coll = cache.collective(part.pattern, Topology(8, 4), "auto")
    y_dev = distributed_spmv(part, coll, mesh, "proc", b)
    np.testing.assert_allclose(y_dev, A.matvec(b), rtol=1e-12, atol=1e-12)
    print("spmv OK")

    # (1) residual histories match to 1e-8 relative tolerance
    x_dev, hist_dev = dh.solve(b, tol=1e-8, max_iters=60)
    assert len(hist_dev) == len(hist_host), (len(hist_dev), len(hist_host))
    # atol = f64 machine epsilon on the unit-normalized initial residual:
    # summation-order roundoff puts an absolute noise floor of ~1e-16 under
    # every entry; above that floor the histories agree to 1e-8 relative.
    np.testing.assert_allclose(
        np.asarray(hist_dev), np.asarray(hist_host), rtol=1e-8, atol=1e-15
    )
    assert hist_dev[-1] < 1e-8
    rel_x = np.linalg.norm(x_dev - x_host) / np.linalg.norm(x_host)
    print(f"residual history OK ({len(hist_dev)} iters, "
          f"final={hist_dev[-1]:.3e}, |x_dev-x_host|/|x_host|={rel_x:.3e})")

    # (2) >= 2 distinct strategies across the levels' operator collectives
    per_level = {lv.index: lv.A.strategy for lv in dh.levels}
    strategies = set(per_level.values())
    print(f"per-level strategies: {per_level}")
    assert len(strategies) >= 2, strategies
    assert per_level[0] == "standard", per_level  # fine level is comm-light
    for lv in dh.levels:
        assert lv.A.selection is not None  # auto ran the selector
    print("selection OK")

    # (3) repeated setup: all plan lookups hit, zero new planning
    misses_before = cache.misses
    exec_misses_before = cache.exec_misses
    dh2 = DistributedHierarchy.setup(
        h, mesh, procs_per_region=4, strategy="auto", cache=cache
    )
    assert cache.misses == misses_before, (cache.misses, misses_before)
    assert cache.exec_misses == exec_misses_before
    assert cache.hits > 0 and cache.init_seconds_saved > 0.0
    # same persistent collective objects — init was skipped, not repeated
    for lv1, lv2 in zip(dh.levels, dh2.levels):
        assert lv1.A.coll is lv2.A.coll
    print(f"plan cache OK: {cache.stats()}")

    # (5) measured device exchange
    for lvl, strat, secs in dh.measure_exchange_seconds(iters=5, warmup=2):
        assert np.isfinite(secs) and secs >= 0.0
        print(f"  L{lvl} {strat:8s} measured exchange {secs * 1e6:8.1f}us")

    print("ALL_OK")


if __name__ == "__main__":
    main()
