"""Subprocess program: validate shard_map executor vs numpy oracle on 8
virtual host devices.  Run by tests/test_collectives_multidev.py with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (set before jax import)."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np

from repro.core import (
    CommPattern,
    NeighborAlltoallV,
    Topology,
    pack_local_values,
    unpack_ghosts,
)


def main():
    assert jax.device_count() == 8, jax.devices()
    mesh = jax.make_mesh((8,), ("proc",))
    rng = np.random.default_rng(0)
    n_procs, n_per = 8, 16
    offsets = np.arange(n_procs + 1) * n_per
    for trial in range(3):
        needs = [
            np.sort(
                rng.choice(n_procs * n_per, size=rng.integers(1, 14), replace=False)
            )
            for _ in range(n_procs)
        ]
        pattern = CommPattern.from_block_partition(needs, offsets)
        topo = Topology(n_procs, procs_per_region=4)
        vals = [rng.normal(size=(n_per, 3)).astype(np.float32) for _ in range(n_procs)]
        for strategy in ("standard", "partial", "full", "auto"):
            coll = NeighborAlltoallV.init(pattern, topo, strategy)
            want = coll(vals)  # numpy oracle
            exec_fn = jax.jit(coll.bind(mesh, "proc"))
            x = pack_local_values(coll.plan, vals)
            got = unpack_ghosts(coll.plan, np.asarray(exec_fn(x)))
            for g, w in zip(got, want):
                np.testing.assert_allclose(g, w, rtol=0, atol=0)
            print(f"trial={trial} strategy={coll.strategy:8s} rounds="
                  f"{coll.device_plan.n_rounds} OK")
    print("ALL_OK")


if __name__ == "__main__":
    main()
