"""Subprocess: MoE dispatch strategies agree on a (pod,data,model)=(2,2,2)
mesh — the paper's standard/partial/full mapped onto EP must be numerically
identical transports (ample capacity => no drops)."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import reduced
from repro.models.moe import MODES, make_moe_plan, moe_layer, init_moe
from repro.models.common import Initializer


def dense_oracle(x, params, cfg, plan_topk):
    """Route + compute every token against its experts directly (numpy-ish)."""
    import numpy as np
    xf = np.asarray(x, np.float32).reshape(-1, x.shape[-1])
    router = np.asarray(params["router"], np.float32)
    wg = np.asarray(params["w_gate"], np.float32)
    wu = np.asarray(params["w_up"], np.float32)
    wd = np.asarray(params["w_down"], np.float32)
    logits = xf @ router
    e = np.exp(logits - logits.max(-1, keepdims=True))
    probs = e / e.sum(-1, keepdims=True)
    k = plan_topk
    out = np.zeros_like(xf)
    order = np.argsort(-probs, axis=-1, kind="stable")[:, :k]
    for t in range(xf.shape[0]):
        ws = probs[t, order[t]]
        ws = ws / ws.sum()
        for j, e_id in enumerate(order[t]):
            h = xf[t] @ wg[e_id]
            h = (h * (1.0 / (1.0 + np.exp(-h)))) * (xf[t] @ wu[e_id])
            out[t] += ws[j] * (h @ wd[e_id])
    return out.reshape(x.shape)


def main():
    assert jax.device_count() == 8
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg0 = reduced("mixtral-8x7b")
    cfg = cfg0.__class__(**{**cfg0.__dict__, "dtype": jnp.float32,
                            "n_experts": 8, "top_k": 2})
    rng = np.random.default_rng(0)
    B, S, D = 4, 8, cfg.d_model
    x = jnp.asarray(rng.normal(size=(B, S, D)).astype(np.float32))
    x = jax.device_put(x, NamedSharding(mesh, P(("pod", "data"), None, None)))

    results = {}
    for mode in MODES:
        for ep_over_pods in ([False, True] if mode != "dense" else [False]):
            plan = make_moe_plan(cfg, mesh, tokens_per_lane=B * S,
                                 mode=mode, ep_over_pods=ep_over_pods,
                                 cap_factor=8.0, dedup_factor=1.0)
            from repro.models.moe import moe_param_specs
            init = Initializer(3, jnp.float32)
            params = {k: v[0] for k, v in
                      init_moe(init, cfg, 1, plan.e_phys).items()}
            specs = {k: P(*s[1:]) for k, s in
                     moe_param_specs(cfg, plan).items()}
            pin = {
                k: jax.device_put(v, NamedSharding(mesh, specs[k]))
                for k, v in params.items() if k in specs
            }
            y, aux = jax.jit(
                lambda xx, pp: moe_layer(xx, pp, plan, cfg, mesh,
                                         ("pod", "data"))
            )(x, pin)
            key = f"{mode}{'+pods' if ep_over_pods else ''}"
            results[key] = np.asarray(y)
            print(f"{key:16s} aux={float(aux):.4f} |y|={np.abs(y).mean():.4f}")

    # replication differs between plans (e_phys) but logical routing must
    # agree; compare every mode against flat a2a (no pods)
    ref = results["a2a"]
    for key, val in results.items():
        err = np.abs(val - ref).max()
        print(f"{key:16s} max|diff vs a2a| = {err:.2e}")
        assert err < 1e-4, (key, err)
    print("ALL_OK")


if __name__ == "__main__":
    main()
