"""Subprocess: MoE dispatch strategies agree on a (pod,data,model)=(2,2,2)
mesh — the paper's standard/partial/full mapped onto EP must be numerically
identical transports (ample capacity => no drops).  Also asserts the
planned-dispatch contract: ``mode="auto"`` (Section-5 selection) picks a
concrete transport whose output is BIT-identical to the explicitly chosen
mode, and a repeated forward on the unchanged mesh/token count reports zero
new plan-cache misses."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import reduced
from repro.core import default_plan_cache
from repro.models.moe import (
    MODES,
    init_moe,
    make_moe_plan,
    moe_layer,
    moe_plan_for,
)
from repro.models.common import Initializer


def dense_oracle(x, params, cfg, plan_topk):
    """Route + compute every token against its experts directly (numpy-ish)."""
    import numpy as np
    xf = np.asarray(x, np.float32).reshape(-1, x.shape[-1])
    router = np.asarray(params["router"], np.float32)
    wg = np.asarray(params["w_gate"], np.float32)
    wu = np.asarray(params["w_up"], np.float32)
    wd = np.asarray(params["w_down"], np.float32)
    logits = xf @ router
    e = np.exp(logits - logits.max(-1, keepdims=True))
    probs = e / e.sum(-1, keepdims=True)
    k = plan_topk
    out = np.zeros_like(xf)
    order = np.argsort(-probs, axis=-1, kind="stable")[:, :k]
    for t in range(xf.shape[0]):
        ws = probs[t, order[t]]
        ws = ws / ws.sum()
        for j, e_id in enumerate(order[t]):
            h = xf[t] @ wg[e_id]
            h = (h * (1.0 / (1.0 + np.exp(-h)))) * (xf[t] @ wu[e_id])
            out[t] += ws[j] * (h @ wd[e_id])
    return out.reshape(x.shape)


def main():
    assert jax.device_count() == 8
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg0 = reduced("mixtral-8x7b")
    cfg = cfg0.__class__(**{**cfg0.__dict__, "dtype": jnp.float32,
                            "n_experts": 8, "top_k": 2})
    rng = np.random.default_rng(0)
    B, S, D = 4, 8, cfg.d_model
    x = jnp.asarray(rng.normal(size=(B, S, D)).astype(np.float32))
    x = jax.device_put(x, NamedSharding(mesh, P(("pod", "data"), None, None)))

    def params_for(plan):
        from repro.models.moe import moe_param_specs
        init = Initializer(3, jnp.float32)
        params = {k: v[0] for k, v in
                  init_moe(init, cfg, 1, plan.e_phys).items()}
        specs = {k: P(*s[1:]) for k, s in
                 moe_param_specs(cfg, plan).items()}
        return {
            k: jax.device_put(v, NamedSharding(mesh, specs[k]))
            for k, v in params.items() if k in specs
        }

    def run(plan, pin):
        y, aux, drop = jax.jit(
            lambda xx, pp: moe_layer(xx, pp, plan, cfg, mesh,
                                     ("pod", "data"))
        )(x, pin)
        return np.asarray(y), float(aux), float(drop)

    results = {}
    for mode in MODES:
        for ep_over_pods in ([False, True] if mode != "dense" else [False]):
            plan = make_moe_plan(cfg, mesh, tokens_per_lane=B * S,
                                 mode=mode, ep_over_pods=ep_over_pods,
                                 cap_factor=8.0, dedup_factor=1.0)
            y, aux, drop = run(plan, params_for(plan))
            key = f"{mode}{'+pods' if ep_over_pods else ''}"
            results[key] = y
            print(f"{key:16s} aux={aux:.4f} |y|={np.abs(y).mean():.4f} "
                  f"dropped={drop:.4f}")
            assert drop == 0.0, (key, drop)  # ample capacity => no drops

    # replication differs between plans (e_phys) but logical routing must
    # agree; compare every mode against flat a2a (no pods)
    ref = results["a2a"]
    for key, val in results.items():
        err = np.abs(val - ref).max()
        print(f"{key:16s} max|diff vs a2a| = {err:.2e}")
        assert err < 1e-4, (key, err)

    # ---- planned dispatch: auto selection + plan-cache amortization -------
    cache = default_plan_cache()
    kw = dict(mode="auto", ep_over_pods=True, cap_factor=8.0,
              dedup_factor=1.0)
    plan_auto = moe_plan_for(cfg, mesh, tokens_per_lane=B * S, **kw)
    assert plan_auto.mode in ("a2a", "hier", "hier_dedup"), plan_auto.mode
    assert plan_auto.fingerprint, "auto plan must carry its fingerprint"
    m0 = cache.misses
    plan_again = moe_plan_for(cfg, mesh, tokens_per_lane=B * S, **kw)
    assert plan_again is plan_auto and cache.misses == m0, \
        "second identical planning call must re-plan nothing"
    print(f"auto selected: {plan_auto.mode}")

    pin = params_for(plan_auto)
    y_auto, _, _ = run(plan_auto, pin)
    explicit = make_moe_plan(cfg, mesh, tokens_per_lane=B * S,
                             mode=plan_auto.mode, ep_over_pods=True,
                             cap_factor=8.0, dedup_factor=1.0)
    y_exp, _, _ = run(explicit, pin)
    assert np.array_equal(y_auto, y_exp), \
        "auto output must be bit-identical to the explicitly chosen mode"
    print("auto bit-identical to", plan_auto.mode)

    # repeated forward through the cached executor: zero new misses
    m0, e0 = cache.misses, cache.exec_misses
    for _ in range(2):
        y, _, _ = jax.jit(
            lambda xx, pp: moe_layer(xx, pp, plan_auto, cfg, mesh,
                                     ("pod", "data"), cache=cache)
        )(x, pin)
    assert cache.misses == m0, "repeated forward must not re-plan"
    assert cache.exec_misses <= e0 + 1, "executor built at most once"
    print("ALL_OK")


if __name__ == "__main__":
    main()
