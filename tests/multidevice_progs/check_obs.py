"""Subprocess: repro.obs end to end on 8 host devices.

Three contracts (the PR-9 acceptance criteria):

1. **Bit-identity** — an ``observe=True`` engine decodes the exact same
   tokens and final-step logits as an ``observe=False`` engine (spans and
   refit probes never touch the numerics); checked first, while the
   process-wide obs layer has never been enabled, so the off-engine is
   genuinely uninstrumented.
2. **Serve telemetry + online refit** — a skewed-traffic adaptive decode
   under ``observe=True`` produces (a) exactly one ``serve/replan``
   instant inside the exported Perfetto trace, (b) per-step
   ``serve/decode_step`` spans, and (c) non-empty ``refit_events`` whose
   fitted ``MachineParams`` landed on both ``engine.machine_params`` and
   the adaptive planner — the ROADMAP online-calibration loop, fed by
   production-step pure-exchange samples through the span bridge.
3. **AMG span tree** — hierarchy setup + solve emits the expected nested
   span structure (``amg/setup`` > ``amg/build_level`` per level,
   ``amg/solve`` > ``amg/vcycle_iter`` per iteration), and
   ``measure_exchange_seconds`` bridges one pure sample per level into
   the attached tracer without an explicit tracer argument.
"""
import json
import os
import tempfile

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax

jax.config.update("jax_enable_x64", True)   # f64 AMG exchange timing

import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.obs import default_obs


def make_engine(observe: bool, adaptive: bool, refit_every: int = 8):
    from repro.configs import reduced
    from repro.models import Model
    from repro.serve import ServeEngine

    cfg0 = reduced("mixtral-8x7b")
    cfg = cfg0.__class__(**{**cfg0.__dict__, "dtype": jnp.float32})
    mesh = jax.make_mesh((1, jax.device_count()), ("data", "model"))
    model = Model(cfg, mesh=mesh, moe_mode="auto", remat=False,
                  moe_cap_factor=8.0)
    params = model.init_params(seed=0)
    return ServeEngine(model, params, batch_slots=2, max_len=96,
                       adaptive=adaptive, drift_threshold=0.3,
                       drift_warmup=2, observe=observe,
                       refit_every=refit_every), cfg


def submit_and_run(eng, cfg, n_steps):
    from repro.serve import Request

    rng = np.random.default_rng(1)
    for rid in range(2):
        eng.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab, size=(4,)).astype(np.int32),
            max_new_tokens=n_steps + 4,
        ))
    for _ in range(n_steps):
        eng.step()
    logits = eng._decode(
        eng.params, {"tokens": jnp.asarray(eng._next_tok)},
        eng.caches, jnp.asarray(eng.cur_len, jnp.int32),
    )[0]
    toks = [list(s.generated) for s in eng.slots if s is not None]
    return toks, np.asarray(logits)


def check_bit_identity():
    obs = default_obs()
    assert not obs.enabled, "must run before any obs-enabling check"
    toks_off, logits_off = submit_and_run(*make_engine(False, False), 12)

    # observe=True enables the process-wide layer; refit_every=4 forces
    # exchange probes + refits DURING the compared decode
    eng_on, cfg = make_engine(True, False, refit_every=4)
    toks_on, logits_on = submit_and_run(eng_on, cfg, 12)
    assert obs.enabled

    assert toks_on == toks_off, (toks_on, toks_off)
    assert np.array_equal(logits_on, logits_off), "logits must be bit-equal"
    n_steps = int(obs.counter("serve/steps", "").value())
    assert n_steps >= 12, n_steps
    print(f"bit-identity OK: {len(toks_on)} sequences, "
          f"{n_steps} instrumented steps, "
          f"{len(eng_on.refit_events)} refits during the compared decode")


def check_serve_observe():
    obs = default_obs()
    obs.reset()
    eng, cfg = make_engine(True, True, refit_every=8)
    from repro.serve import Request

    rng = np.random.default_rng(1)
    eng.submit(Request(
        rid=0,
        prompt=rng.integers(0, cfg.vocab, size=(4,)).astype(np.int32),
        max_new_tokens=60,
    ))
    eng.step()
    for _ in range(12):                       # steady reference window
        eng.step()
    # zero router ties every logit -> top-k sends everything to experts
    # {0..k-1}: maximal histogram drift, exactly one re-selection
    eng.params["blocks"]["moe"]["router"] = jnp.zeros_like(
        eng.params["blocks"]["moe"]["router"]
    )
    for _ in range(20):
        eng.step()
        if eng.replan_events:
            break
    for _ in range(8):
        eng.step()

    assert len(eng.replan_events) == 1, eng.replan_events
    assert eng.refit_events, "periodic refit must have fired"
    assert eng.machine_params is not None
    assert eng.machine_params.name == "online-refit"
    # the fitted params drive subsequent adaptive re-selections
    assert eng.planner.params is eng.machine_params
    for ev in eng.refit_events:
        print(f"  {ev}")
    assert obs.tracer is not None and len(obs.tracer.samples) >= len(
        eng.refit_events), "each refit bridges >=1 pure probe sample"

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "serve_trace.json")
        obs.export_perfetto(path)
        doc = json.load(open(path))
    evs = doc["traceEvents"]
    assert doc["otherData"]["schema_version"] == 1
    decode_spans = [e for e in evs
                    if e["ph"] == "X" and e["name"] == "serve/decode_step"]
    assert len(decode_spans) >= 20
    assert all(e["dur"] >= 0 and e["ts"] >= 0 for e in decode_spans)
    replans = [e for e in evs
               if e["ph"] == "i" and e["name"] == "serve/replan"]
    assert len(replans) == 1
    assert replans[0]["args"]["drift"] >= 0.3
    refits = [e for e in evs
              if e["ph"] == "i" and e["name"] == "serve/refit"]
    assert len(refits) == len(eng.refit_events)
    assert any(e["ph"] == "C" for e in evs), "counter tracks sampled"
    print(f"serve observe OK: {len(decode_spans)} decode-step spans, "
          f"1 replan instant, {len(refits)} refit instants in Perfetto doc")


def check_amg_span_tree():
    from repro.amg.distributed import DistributedHierarchy
    from repro.amg.hierarchy import build_hierarchy
    from repro.profile.trace import TraceRecorder
    from repro.sparse.csr import CSR

    def poisson2d(nx):
        n = nx * nx
        rows, cols, vals = [], [], []
        for i in range(nx):
            for j in range(nx):
                k = i * nx + j
                rows.append(k); cols.append(k); vals.append(4.0)
                for di, dj in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                    ii, jj = i + di, j + dj
                    if 0 <= ii < nx and 0 <= jj < nx:
                        rows.append(k); cols.append(ii * nx + jj)
                        vals.append(-1.0)
        return CSR.from_coo(np.array(rows), np.array(cols),
                            np.array(vals), (n, n))

    obs = default_obs()
    obs.reset()
    tracer = TraceRecorder()
    obs.enable(tracer=tracer)

    A = poisson2d(24)
    h = build_hierarchy(A)
    mesh = Mesh(np.array(jax.devices()[:8]), ("proc",))
    dh = DistributedHierarchy.setup(h, mesh, "proc")
    b = np.random.default_rng(0).normal(size=A.nrows)
    _, hist = dh.solve(b, tol=0.0, max_iters=5)

    spans = obs.spans.events(kind="span")
    by_name = {}
    for e in spans:
        by_name.setdefault(e.name, []).append(e)
    assert "amg/setup" in by_name and by_name["amg/setup"][0].depth == 0
    n_levels = len(dh.levels)
    assert len(by_name["amg/build_level"]) == n_levels
    assert all(e.depth == 1 for e in by_name["amg/build_level"])
    # build-level spans carry the per-level selection verdicts
    for e in by_name["amg/build_level"]:
        assert {"level", "strategy", "kernel", "overlap"} <= set(e.attrs)
    (solve,) = by_name["amg/solve"]
    assert solve.depth == 0 and solve.attrs["iters"] == len(hist)
    assert len(by_name["amg/vcycle_iter"]) == len(hist) == 5
    assert all(e.depth == 1 for e in by_name["amg/vcycle_iter"])

    # no explicit tracer argument: the span bridge carries the samples
    # (one per level that actually exchanges — ghost-free levels skip)
    n_ex = sum(1 for lv in dh.levels if lv.A.ell.ghost_pad)
    assert n_ex > 0
    n0 = len(tracer.samples)
    secs = dh.measure_exchange_seconds()
    assert len(secs) == n_levels
    bridged = tracer.samples[n0:]
    assert len(bridged) == n_ex
    assert all(s.pure_exchange for s in bridged)
    names_now = {e.name for e in obs.spans.events(kind="span")}
    assert "amg/measure_exchange" in names_now
    print(f"amg span tree OK: {n_levels} levels, {len(hist)} V-cycle "
          f"iterations, {len(bridged)} bridged exchange samples")
    print(obs.span_tree().splitlines()[0])


def main():
    check_bit_identity()       # must run first: needs obs never-enabled
    check_serve_observe()
    check_amg_span_tree()
    print("ALL_OK")


if __name__ == "__main__":
    main()
