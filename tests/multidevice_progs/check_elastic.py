"""Subprocess: the elastic/straggler layer end to end on 8 host devices.

Four contracts (the ISSUE-7 acceptance criteria):

1. **Mid-solve shrink** — k V-cycle iterations on 8 procs, repartition to
   4 via ``DistributedHierarchy.repartition``, warm-start the remaining m
   iterations with ``solve(x0=)``: the final iterate matches a cold
   4-proc solve of k+m iterations to 1e-12 (the stationary iteration is
   contracting, so the only divergence is fp reduction order).
2. **Grow back** — repartitioning 4 -> 8 through the same ``PlanCache``
   re-plans ZERO patterns (every 8-proc pattern survives in the cache);
   asserted via the attached ``ResizeEvent``'s cache-counter delta.
3. **Mid-decode shrink** — a ``ServeEngine(elastic=True)`` decoding a
   float64 MoE model on 8 devices resizes to 4 mid-stream; the generated
   tokens are identical and the final-step logits match a cold 4-device
   engine to 1e-12.
4. **Straggler** — an injected 3x-slow host flagged by the controller
   triggers exactly ONE rebalance+refit event: the rebuilt hierarchy's
   row blocks shrink on the slow host and its MachineParams come from
   ``fit_trace`` over the recorded exchange samples.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.amg.hierarchy import build_hierarchy
from repro.amg.distributed import DistributedHierarchy
from repro.core.cache import PlanCache
from repro.profile.trace import TraceRecorder
from repro.runtime import ElasticController, StragglerConfig
from repro.sparse.csr import CSR


def poisson2d(nx: int) -> CSR:
    n = nx * nx
    rows, cols, vals = [], [], []
    for i in range(nx):
        for j in range(nx):
            k = i * nx + j
            rows.append(k); cols.append(k); vals.append(4.0)
            for di, dj in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                ii, jj = i + di, j + dj
                if 0 <= ii < nx and 0 <= jj < nx:
                    rows.append(k); cols.append(ii * nx + jj)
                    vals.append(-1.0)
    return CSR.from_coo(np.array(rows), np.array(cols), np.array(vals),
                        (n, n))


def mesh_n(n: int) -> Mesh:
    return Mesh(np.array(jax.devices()[:n]), ("proc",))


def check_solve_shrink_grow():
    A = poisson2d(28)
    h = build_hierarchy(A)
    cache = PlanCache()
    dh8 = DistributedHierarchy.setup(h, mesh_n(8), "proc", cache=cache)
    b = np.random.default_rng(0).normal(size=A.nrows)
    k, m = 4, 4

    # k iterations on 8 procs, then the device set shrinks to 4
    x_mid, _ = dh8.solve(b, tol=0.0, max_iters=k)
    dh4 = dh8.repartition(mesh_n(4), reason="heartbeat")
    ev_shrink = dh4.last_resize
    print(f"shrink: {ev_shrink}")
    assert ev_shrink.old_n == 8 and ev_shrink.new_n == 4
    assert ev_shrink.plan_misses > 0, "first 4-proc build must plan"
    x_elastic, _ = dh4.solve(b, tol=0.0, max_iters=m, x0=x_mid)

    # cold start on 4 devices, same total iterations
    dh4_cold = DistributedHierarchy.setup(h, mesh_n(4), "proc",
                                          cache=PlanCache())
    x_cold, _ = dh4_cold.solve(b, tol=0.0, max_iters=k + m)
    err = np.abs(x_elastic - x_cold).max() / max(np.abs(x_cold).max(),
                                                 1e-300)
    print(f"mid-solve shrink vs cold-start rel err: {err:.3e}")
    assert err < 1e-12, err

    # grow back to 8: every pattern must come out of the cache
    dh8b = dh4.repartition(mesh_n(8), reason="requested")
    ev_grow = dh8b.last_resize
    print(f"grow:   {ev_grow}")
    assert ev_grow.plan_misses == 0, ev_grow
    assert ev_grow.exec_misses == 0, ev_grow
    assert ev_grow.plan_hits > 0 and ev_grow.warm
    x_back, _ = dh8b.solve(b, tol=0.0, max_iters=k + m)
    err2 = np.abs(x_back - x_cold).max() / max(np.abs(x_cold).max(), 1e-300)
    assert err2 < 1e-10, err2
    print("solve shrink/grow OK")


def check_decode_shrink():
    from repro.configs import reduced
    from repro.models import Model
    from repro.serve import Request, ServeEngine

    cfg0 = reduced("mixtral-8x7b")
    # float64 end to end: the 1e-12 contract is unreachable in f32
    cfg = cfg0.__class__(**{**cfg0.__dict__, "dtype": jnp.float64,
                            "n_experts": 8, "top_k": 2})
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, size=(6,)).astype(np.int32)
               for _ in range(2)]

    def make_engine(n_dev: int):
        mesh = jax.make_mesh((1, n_dev), ("data", "model"))
        model = Model(cfg, mesh=mesh, moe_mode="auto", remat=False,
                      moe_cap_factor=8.0)
        params = model.init_params(seed=0)
        return ServeEngine(model, params, batch_slots=2, max_len=64,
                           elastic=True)

    def submit_all(eng):
        for rid, p in enumerate(prompts):
            eng.submit(Request(rid=rid, prompt=p, max_new_tokens=10))

    def last_logits(eng):
        out = eng._decode(
            eng.params, {"tokens": jnp.asarray(eng._next_tok)},
            eng.caches, jnp.asarray(eng.cur_len, jnp.int32),
        )
        return np.asarray(out[0])

    # elastic: admit + 4 decode steps on 8, shrink to 4, 4 more steps
    eng = make_engine(8)
    submit_all(eng)
    for _ in range(5):
        eng.step()
    ev = eng.resize(4, reason="heartbeat")
    print(f"serve shrink: {ev}")
    assert ev.old_n == 8 and ev.new_n == 4
    for _ in range(4):
        eng.step()
    toks_elastic = [list(s.generated) for s in eng.slots]
    logits_elastic = last_logits(eng)

    # cold start on 4 devices, same number of steps
    eng4 = make_engine(4)
    submit_all(eng4)
    for _ in range(9):
        eng4.step()
    toks_cold = [list(s.generated) for s in eng4.slots]
    assert toks_elastic == toks_cold, (toks_elastic, toks_cold)
    logits_cold = last_logits(eng4)
    err = np.abs(logits_elastic - logits_cold).max() / max(
        np.abs(logits_cold).max(), 1e-300
    )
    print(f"mid-decode shrink vs cold-start logits rel err: {err:.3e}")
    assert err < 1e-12, err

    # grow back to 8 through the same engine cache: the dispatch plan for
    # the 8-device geometry survives -> zero new plan misses
    ev_grow = eng.resize(8, reason="requested")
    print(f"serve grow:   {ev_grow}")
    assert ev_grow.plan_misses == 0, ev_grow
    for _ in range(2):
        eng.step()
    print("decode shrink/grow OK")


def check_straggler():
    A = poisson2d(24)
    h = build_hierarchy(A)
    cache = PlanCache()
    tracer = TraceRecorder()
    dh = DistributedHierarchy.setup(h, mesh_n(8), "proc", cache=cache)
    dh.measure_exchange_seconds(iters=2, warmup=1, tracer=tracer)

    ctrl = ElasticController(
        8, cache=cache, tracer=tracer,
        straggler_cfg=StragglerConfig(patience=3), cooldown=8,
    )
    base = np.full(8, 0.010)
    n_events = 0
    for t in range(24):
        times = base.copy()
        if n_events == 0:
            times[2] *= 3.0          # injected straggler on host 2
        times *= 1.0 + 0.01 * np.sin(t)   # benign jitter
        flagged = ctrl.observe_step_times(times)
        if flagged:
            assert flagged == [2], flagged
            dh, ev = ctrl.mitigate_hierarchy(dh, flagged)
            n_events += 1
            print(f"mitigation: {ev}")
            # host 2 gets the fewest rows on the fine level
            rows = np.diff(dh.levels[0].A.part.offsets)
            print(f"fine-level rows/host after rebalance: {rows}")
            assert rows[2] == rows.min() and rows[2] < rows.max(), rows
            assert ev.refit and ev.params_name == "straggler-refit", ev
            assert dh.params.name == "straggler-refit"
    assert len(ctrl.rebalance_events) == 1, ctrl.rebalance_events
    assert n_events == 1
    # the rebalanced hierarchy still solves
    b = np.random.default_rng(2).normal(size=A.nrows)
    x, hist = dh.solve(b, tol=1e-8, max_iters=40)
    assert hist[-1] < 1e-8, hist[-1]
    r = b - A.matvec(x)
    assert np.linalg.norm(r) / np.linalg.norm(b) < 1e-6
    print("straggler mitigation OK")


def main():
    assert jax.device_count() == 8, jax.device_count()
    check_solve_shrink_grow()
    check_decode_shrink()
    check_straggler()
    print("ALL_OK")


if __name__ == "__main__":
    main()
