"""Subprocess program: distributed AMG SETUP -> device solve on 8 devices.

Run by tests/test_distributed_setup.py on 8 virtual host devices
(XLA_FLAGS=--xla_force_host_platform_device_count=8, set before jax import).

Checks, on the 64x64 rotated anisotropic diffusion problem:
  1. the hierarchy built END-TO-END from a partitioned fine matrix
     (``DistributedHierarchy.setup_partitioned`` — PMIS, interpolation and
     the Galerkin SpGEMM all distributed, exchanges through cached
     persistent collectives) matches the host ``build_hierarchy`` level by
     level: identical C/F splittings, operators equal to 1e-12;
  2. the lowered device V-cycle converges and tracks the host solver;
  3. a second partitioned setup re-plans nothing (all collectives and
     bound executors served from the PlanCache);
  4. the setup-phase exchange log covers discovery + gathers per level.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.amg import (
    DistributedHierarchy,
    build_hierarchy,
    diffusion_2d,
    partition_fine_matrix,
    solve,
)
from repro.core import PlanCache


def main():
    assert jax.device_count() == 8, jax.devices()
    mesh = jax.make_mesh((8,), ("proc",))

    A = diffusion_2d(64, 64)
    blocks, off = partition_fine_matrix(A, 8)
    cache = PlanCache()
    dh = DistributedHierarchy.setup_partitioned(
        blocks, off, mesh, procs_per_region=4, cache=cache
    )
    print(dh.setup_info.describe())
    print(dh.describe())

    # (1) level-by-level equality with the host setup
    h = build_hierarchy(A)
    hh = dh.setup_info.to_host_hierarchy()
    assert hh.n_levels == h.n_levels, (hh.n_levels, h.n_levels)
    for k in range(h.n_levels):
        lh, ld = h.levels[k], hh.levels[k]
        if lh.splitting is not None:
            assert ld.splitting is not None
            assert np.array_equal(lh.splitting, ld.splitting), f"L{k} split"
        dA = np.abs(lh.A.to_dense() - ld.A.to_dense()).max()
        assert dA < 1e-12, (k, dA)
        if lh.P is not None and ld.P is not None:
            assert np.abs(lh.P.to_dense() - ld.P.to_dense()).max() < 1e-12
            assert np.abs(lh.R.to_dense() - ld.R.to_dense()).max() < 1e-12
        assert abs(lh.rho - ld.rho) < 1e-6 * max(lh.rho, 1.0), (k, lh.rho, ld.rho)
    print(f"levels OK ({h.n_levels} levels, splittings identical, "
          "operators <= 1e-12)")

    # (4) exchange inventory: every distributed-setup phase is accounted
    phases = {r.phase for r in dh.setup_info.records}
    assert {"halo", "strength_transpose", "p_transpose",
            "gather_A", "gather_P"} <= phases, phases
    n_coarsened = sum(
        1 for sl in dh.setup_info.levels if sl.P_blocks is not None
    )
    gathers = [r for r in dh.setup_info.records if r.phase == "gather_A"]
    assert len(gathers) == n_coarsened
    print(f"exchange log OK ({len(dh.setup_info.records)} records)")

    # (2) the lowered solve converges and tracks the host solver
    rng = np.random.default_rng(0)
    b = rng.normal(size=A.nrows)
    x_host, hist_host = solve(h, b, tol=1e-8, max_iters=60)
    x_dev, hist_dev = dh.solve(b, tol=1e-8, max_iters=60)
    assert hist_dev[-1] < 1e-8, hist_dev[-5:]
    assert len(hist_dev) == len(hist_host), (len(hist_dev), len(hist_host))
    # operators agree to ~1e-16 relative, rho to ~1e-12: the histories track
    # well inside 1e-6 even after 36 amplifying V-cycles
    np.testing.assert_allclose(
        np.asarray(hist_dev), np.asarray(hist_host), rtol=1e-6, atol=1e-14
    )
    rel_x = np.linalg.norm(x_dev - x_host) / np.linalg.norm(x_host)
    assert rel_x < 1e-8, rel_x
    print(f"solve OK ({len(hist_dev)} iters, final={hist_dev[-1]:.3e}, "
          f"|x_dev-x_host|/|x_host|={rel_x:.3e})")

    # (3) repeated partitioned setup: zero new planning, zero new binding
    misses, exec_misses = cache.misses, cache.exec_misses
    dh2 = DistributedHierarchy.setup_partitioned(
        blocks, off, mesh, procs_per_region=4, cache=cache
    )
    assert cache.misses == misses, (cache.misses, misses)
    assert cache.exec_misses == exec_misses
    assert cache.hits > 0 and cache.init_seconds_saved > 0.0
    for lv1, lv2 in zip(dh.levels, dh2.levels):
        assert lv1.A.coll is lv2.A.coll  # same persistent collectives
    print(f"plan cache OK: {cache.stats()}")

    print("ALL_OK")


if __name__ == "__main__":
    main()
