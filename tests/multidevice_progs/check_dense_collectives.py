"""8-device check of the dense-collective consumers (run via subprocess).

Three gates, one per consumer of ``core.dense``:

1. TRAINER — ``make_dp_train_step`` with explicit plan-based grad sync
   (``ring`` / ``hier`` / ``auto``) must be numerically EQUAL (1e-12, f64)
   to the implicit GSPMD path (``grad_sync="jit"``): same loss, same
   updated parameters after a full optimizer step.
2. AMG — ``DistributedHierarchy`` with ``coarse_gather`` on (the coarsest
   level solved replicated after a plan-based allgatherv) must converge in
   the same iterations to the same solution as the sharded baseline.
3. MOE — ``gather_expert_weights`` must reconstruct the exact original
   expert weights from their EP shards.

Prints ALL_OK iff every gate passes.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ["REPRO_VERIFY"] = "1"

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding


def check_grad_sync():
    from repro.train.optimizer import init_opt_state
    from repro.train.trainer import (
        TrainerConfig,
        TrainState,
        make_dp_train_step,
    )

    rng = np.random.default_rng(1)
    params = {
        "w": jnp.asarray(rng.normal(size=(16, 4))),
        "b": jnp.asarray(rng.normal(size=(4,))),
    }

    def loss_fn(p, batch):
        y = batch["x"] @ p["w"] + p["b"]
        return jnp.mean((y - batch["y"]) ** 2)

    mesh = Mesh(np.array(jax.devices()), ("dp",))
    batch = {
        "x": jnp.asarray(rng.normal(size=(32, 16))),
        "y": jnp.asarray(rng.normal(size=(32, 4))),
    }

    outs = {}
    for method in ("jit", "ring", "hier", "auto"):
        step, sel = make_dp_train_step(
            loss_fn, params, TrainerConfig(grad_sync=method), mesh, "dp"
        )
        assert (sel is None) == (method == "jit"), (method, sel)
        state = TrainState(jax.tree.map(jnp.array, params),
                           init_opt_state(params), None)
        st2, m = step(state, batch)
        outs[method] = (st2.params, m["loss"])
        print(f"  grad_sync={method}: loss={float(m['loss']):.12f}"
              + (f" [{sel.chosen}]" if sel else ""))

    ref_p, ref_l = outs["jit"]
    for method in ("ring", "hier", "auto"):
        p, loss = outs[method]
        assert abs(float(loss - ref_l)) < 1e-12, (method, float(loss - ref_l))
        for k in ref_p:
            d = float(jnp.max(jnp.abs(p[k] - ref_p[k])))
            assert d < 1e-12, (method, k, d)
    print("  explicit grad sync == implicit GSPMD at 1e-12")


def poisson2d(nx):
    from repro.sparse.csr import CSR

    n = nx * nx
    rows, cols, vals = [], [], []
    for i in range(nx):
        for j in range(nx):
            k = i * nx + j
            rows.append(k)
            cols.append(k)
            vals.append(4.0)
            for di, dj in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                ii, jj = i + di, j + dj
                if 0 <= ii < nx and 0 <= jj < nx:
                    rows.append(k)
                    cols.append(ii * nx + jj)
                    vals.append(-1.0)
    return CSR.from_coo(np.array(rows), np.array(cols), np.array(vals),
                        (n, n))


def check_coarse_gather():
    from repro.amg.distributed import DistributedHierarchy
    from repro.amg.hierarchy import build_hierarchy

    A = poisson2d(24)
    h = build_hierarchy(A)
    mesh = Mesh(np.array(jax.devices()), ("proc",))
    b = np.random.default_rng(3).normal(size=A.shape[0])

    x0, hist0 = DistributedHierarchy.setup(h, mesh).solve(
        b, tol=1e-10, max_iters=40
    )
    for cg in ("auto", "hier", "ring"):
        dh = DistributedHierarchy.setup(h, mesh, coarse_gather=cg)
        x, hist = dh.solve(b, tol=1e-10, max_iters=40)
        d = np.max(np.abs(x - x0)) / np.max(np.abs(x0))
        print(f"  coarse_gather={cg}: iters={len(hist)} (base {len(hist0)})"
              f" reldiff={d:.2e} [{dh.coarse_selection.chosen}]")
        assert len(hist) <= len(hist0) + 2, (cg, len(hist), len(hist0))
        assert d < 1e-8, (cg, d)
    assert "coarse_gather=" in dh.describe()


def check_expert_gather():
    from repro.configs import reduced
    from repro.models.common import Initializer
    from repro.models.moe import (
        gather_expert_weights,
        init_moe,
        make_moe_plan,
        moe_param_specs,
    )

    cfg0 = reduced("mixtral-8x7b")
    cfg = cfg0.__class__(**{**cfg0.__dict__, "dtype": jnp.float32})
    mesh = jax.make_mesh((8,), ("model",))
    plan = make_moe_plan(cfg, mesh, 8, mode="hier")
    params = init_moe(Initializer(0, jnp.float32), cfg, L=2,
                      e_phys=plan.e_phys)
    specs = moe_param_specs(cfg, plan)
    sharded = {k: jax.device_put(v, NamedSharding(mesh, specs[k]))
               for k, v in params.items()}
    gathered, sel = gather_expert_weights(sharded, plan, mesh)
    print(f"  expert gather: {sel}")
    for k in ("w_gate", "w_up", "w_down"):
        ref = np.asarray(params[k])
        got = np.asarray(jax.device_get(gathered[k]))
        assert got.shape == ref.shape, (k, got.shape, ref.shape)
        np.testing.assert_array_equal(got, ref)


def main():
    assert jax.device_count() == 8, jax.devices()
    check_grad_sync()
    check_coarse_gather()
    check_expert_gather()
    print("ALL_OK")


if __name__ == "__main__":
    main()
