"""Subprocess program: exchange/compute-overlapped SpMV through the
distributed solve.

Run by tests/test_distributed_amg.py on 8 virtual host devices
(XLA_FLAGS=--xla_force_host_platform_device_count=8, set before jax import).

Checks, on the 48x48 rotated anisotropic diffusion problem:
  1. hierarchies with the overlapped schedule FORCED on every level — for
     both the flat and the column-blocked kernel — solve to the host
     solver's residual history (the split local-then-ghost accumulation is
     numerically identical to the fused path);
  2. the one-shot distributed SpMV agrees with the host oracle for every
     kernel variant x overlap mode combination on the fine operator;
  3. the default auto selection (off at this scale: local compute is below
     the split overhead) solves correctly and records its per-level
     decision on each operator;
  4. the decision is visible in kernel_table() and describe() (ov= column);
  5. measure_spmv_seconds records full-SpMV timings to a TraceRecorder
     with pure_exchange=False, so they never enter wire-rate calibration.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.amg import DistributedHierarchy, build_hierarchy, diffusion_2d, solve
from repro.core import PlanCache, Topology
from repro.profile import TraceRecorder
from repro.sparse import distributed_spmv, partition_csr


def main():
    assert jax.device_count() == 8, jax.devices()
    mesh = jax.make_mesh((8,), ("proc",))

    A = diffusion_2d(48, 48)
    h = build_hierarchy(A)
    rng = np.random.default_rng(0)
    b = rng.normal(size=A.nrows)

    # -- host reference -----------------------------------------------------
    x_host, hist_host = solve(h, b, tol=1e-8, max_iters=60)
    assert hist_host[-1] < 1e-8, hist_host[-5:]

    # (2) one-shot distributed SpMV: kernel variants x overlap modes
    part = partition_csr(h.levels[0].A, 8)
    cache = PlanCache()
    coll = cache.collective(part.pattern, Topology(8, 4), "auto")
    for variant in ("flat", "blocked"):
        for overlap in ("off", "on", "auto"):
            y = distributed_spmv(part, coll, mesh, "proc", b,
                                 variant=variant, block_cols=64,
                                 overlap=overlap)
            np.testing.assert_allclose(y, h.levels[0].A.matvec(b),
                                       rtol=1e-12, atol=1e-12)
    print("spmv variant x overlap grid OK")

    # (1) forced-overlap hierarchies match the host residual history
    for variant in ("flat", "blocked"):
        dh = DistributedHierarchy.setup(
            h, mesh, procs_per_region=4, cache=PlanCache(),
            spmv_variant=variant, spmv_block_cols=64, spmv_overlap="on",
        )
        ghosted = [lv for lv in dh.levels if lv.A.ell.ghost_pad > 0]
        assert ghosted, "test problem must have halo exchanges"
        for lv in ghosted:
            assert lv.A.overlap_mode == "on", (lv.index, lv.A.overlap)
            assert lv.A.overlap is not None and lv.A.overlap.forced
        x_dev, hist_dev = dh.solve(b, tol=1e-8, max_iters=60)
        assert len(hist_dev) == len(hist_host), (len(hist_dev),
                                                 len(hist_host))
        np.testing.assert_allclose(
            np.asarray(hist_dev), np.asarray(hist_host),
            rtol=1e-8, atol=1e-15,
        )
        print(f"forced-overlap {variant} residual history OK "
              f"({len(hist_dev)} iters, final={hist_dev[-1]:.3e})")

    # (3) auto: off at this scale (local compute < split overhead), the
    # decision recorded per level, and the solve still correct
    dh = DistributedHierarchy.setup(
        h, mesh, procs_per_region=4, cache=PlanCache(), spmv_block_cols=64,
    )
    for lv in dh.levels:
        assert lv.A.overlap is not None and not lv.A.overlap.forced
        assert lv.A.overlap_mode == "off", (lv.index, lv.A.overlap)
    x_dev, hist_dev = dh.solve(b, tol=1e-8, max_iters=60)
    np.testing.assert_allclose(
        np.asarray(hist_dev), np.asarray(hist_host), rtol=1e-8, atol=1e-15
    )
    print("auto-overlap residual history OK")

    # (4) the decision is recorded and visible
    kt = dh.kernel_table()
    assert all(ov in ("on", "off") for _, _, _, ov, _ in kt)
    assert all("overlap=" in rep for _, _, _, _, rep in kt), kt
    desc = dh.describe()
    assert "ov=off" in desc
    print(desc)

    # (5) measured SpMV rows are non-pure trace samples
    tracer = TraceRecorder()
    rows = dh.measure_spmv_seconds(iters=2, warmup=1, tracer=tracer)
    assert rows and all(secs > 0 for _, _, _, secs in rows)
    ghosted_levels = {lv.index for lv in dh.levels
                      if lv.A.ell.ghost_pad > 0}
    assert {s.label for s in tracer.samples} \
        == {f"amg/L{i}/spmv" for i in ghosted_levels}
    assert all(not s.pure_exchange for s in tracer.samples)
    assert not tracer.merged_rate_samples()  # excluded from rate fitting
    print(f"measure_spmv_seconds OK ({len(rows)} levels, "
          f"{len(tracer.samples)} non-pure samples)")

    print("ALL_OK")


if __name__ == "__main__":
    main()
