"""Unit tests for ``core.dense``: plan-based dense collectives.

Host-side coverage (device execution lives in
``tests/multidevice_progs/check_dense_collectives.py``): round schedules
verify (conflict-free + conserving), the host oracle matches independent
references on uneven counts and non-divisible region sizes, Section-5
selection prefers the hierarchical schedule at paper-scale multi-region
geometries, the PlanCache ``dense_plan`` namespace hits on re-request,
and fingerprints are stable across processes regardless of the hash seed
(the PYTHONHASHSEED determinism contract CI pins).
"""
import os
import pathlib
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import (
    DENSE_COLLECTIVES,
    PlanCache,
    Topology,
    build_dense_plan,
    dense_fingerprint,
    dense_time,
    dense_variants,
    even_counts,
    select_dense,
    unpack_dense_output,
    pack_dense_input,
)
from repro.core.costmodel import TPU_V5E
from repro.verify import verify_dense_plan

REPO = pathlib.Path(__file__).resolve().parents[1]

GEOMETRIES = [(8, 4), (8, 2), (8, 1), (6, 3), (12, 4), (4, 2)]


def uneven_counts(n_procs: int, seed: int = 0) -> np.ndarray:
    return np.random.default_rng(seed).integers(1, 23, size=n_procs)


def reference(plan, vals):
    """Independent semantics: sum / concat / owned-segment-of-sum."""
    P = plan.topo.n_procs
    if plan.collective == "allgatherv":
        cat = np.concatenate(vals)
        return [cat] * P
    total = np.sum(np.stack(vals), axis=0)
    if plan.collective == "allreduce":
        return [total] * P
    segs = np.split(total, np.cumsum(plan.counts)[:-1])
    return [segs[p] for p in range(P)]


def inputs_for(plan, seed=1):
    rng = np.random.default_rng(seed)
    if plan.collective == "allgatherv":
        return [rng.normal(size=int(c)) for c in plan.counts]
    n = int(plan.counts.sum())
    return [rng.normal(size=n) for _ in range(plan.topo.n_procs)]


def all_plans():
    for n_procs, ppr in GEOMETRIES:
        topo = Topology(n_procs, ppr)
        for coll in DENSE_COLLECTIVES:
            for variant in dense_variants(coll, topo):
                yield build_dense_plan(coll, uneven_counts(n_procs), topo,
                                       variant)


@pytest.mark.parametrize("plan", all_plans(),
                         ids=lambda p: f"{p.strategy}-{p.topo.n_procs}p"
                                       f"{p.topo.procs_per_region}r")
def test_schedule_verifies_and_oracle_matches_reference(plan):
    verify_dense_plan(plan)   # conflict-free rounds + symbolic conservation
    vals = inputs_for(plan)
    got = plan.execute_numpy(vals)
    for g, r in zip(got, reference(plan, vals)):
        np.testing.assert_allclose(g, r, rtol=1e-13, atol=1e-13)


def test_pack_unpack_roundtrip():
    plan = build_dense_plan("allgatherv", uneven_counts(8), Topology(8, 4),
                            "hier")
    vals = inputs_for(plan)
    packed = pack_dense_input(plan, vals)
    assert packed.shape == (8, plan.cmax)
    for p in range(8):
        c = int(plan.counts[p])
        np.testing.assert_array_equal(packed[p, :c], vals[p])
        assert not packed[p, c:].any()
    # a fully-gathered padded buffer unpacks to the concatenated vector
    buf = np.zeros((8, len(plan.counts), plan.cmax))
    for s in range(8):
        buf[:, s, : int(plan.counts[s])] = vals[s]
    cat = np.concatenate(vals)
    for g in unpack_dense_output(plan, buf):
        np.testing.assert_array_equal(g, cat)


def test_rd_requires_power_of_two_allreduce():
    with pytest.raises(ValueError):
        build_dense_plan("allreduce", uneven_counts(6), Topology(6, 3), "rd")
    with pytest.raises(ValueError):
        build_dense_plan("allgatherv", uneven_counts(8), Topology(8, 4),
                         "rd")


def test_unknown_collective_rejected():
    with pytest.raises(ValueError):
        build_dense_plan("alltoall", uneven_counts(8), Topology(8, 4),
                         "ring")


def test_selection_prefers_hier_at_paper_scale():
    """The acceptance gate: at the paper's multi-region scale the cost
    model must score the hierarchical schedule below the flat ring for
    every collective (and auto-select it for the non-power-of-2-free
    cases)."""
    topo = Topology(1024, 32)
    counts = even_counts(1 << 20, 1024)
    for coll in DENSE_COLLECTIVES:
        plan, sel = select_dense(coll, counts, topo, variant="auto")
        assert sel.modeled_times["hier"] < sel.modeled_times["ring"], sel
        assert sel.chosen == "hier", sel
        assert plan.variant == "hier"
        assert f"dense/{coll}" in str(sel) and "selected=hier" in str(sel)


def test_selection_modeled_times_are_plan_times():
    topo = Topology(8, 4)
    counts = uneven_counts(8)
    _plan, sel = select_dense("allreduce", counts, topo, variant="auto")
    for variant, t in sel.modeled_times.items():
        p = build_dense_plan("allreduce", counts, topo, variant)
        assert t == pytest.approx(dense_time(p, TPU_V5E), rel=1e-12)
    assert sel.chosen == min(sel.modeled_times, key=sel.modeled_times.get)


def test_single_region_geometry_has_no_hier():
    assert dense_variants("allgatherv", Topology(8, 8)) == ["ring"]
    assert dense_variants("allgatherv", Topology(8, 1)) == ["ring"]
    assert "rd" in dense_variants("allreduce", Topology(8, 8))


def test_dense_plan_cache_hits_and_saved_seconds():
    cache = PlanCache()
    topo = Topology(8, 4)
    counts = uneven_counts(8)
    plan1, sel1 = cache.dense_collective("allreduce", counts, topo)
    ns = cache.snapshot()["namespaces"]["dense_plan"]
    assert ns["entries"] == 1 and ns["misses"] == 1 and ns["hits"] == 0
    plan2, sel2 = cache.dense_collective("allreduce", counts.copy(), topo)
    ns = cache.snapshot()["namespaces"]["dense_plan"]
    assert ns["hits"] == 1 and ns["entries"] == 1
    assert plan2.fingerprint == plan1.fingerprint
    assert sel2.chosen == sel1.chosen
    # a different variant pin or counts vector is a different entry
    cache.dense_collective("allreduce", counts, topo, variant="ring")
    cache.dense_collective("allreduce", uneven_counts(8, seed=9), topo)
    assert cache.snapshot()["namespaces"]["dense_plan"]["entries"] == 3


def test_fingerprint_separates_collective_variant_counts_topology():
    topo = Topology(8, 4)
    counts = uneven_counts(8)
    fps = {
        dense_fingerprint("allreduce", counts, topo, "ring", 8),
        dense_fingerprint("allreduce", counts, topo, "hier", 8),
        dense_fingerprint("reduce_scatter", counts, topo, "ring", 8),
        dense_fingerprint("allreduce", counts + 1, topo, "ring", 8),
        dense_fingerprint("allreduce", counts, Topology(8, 2), "ring", 8),
        dense_fingerprint("allreduce", counts, topo, "ring", 4),
    }
    assert len(fps) == 6
    assert dense_fingerprint("allreduce", counts, topo, "ring", 8) \
        == dense_fingerprint("allreduce", counts.tolist(), topo, "ring", 8)


def test_fingerprint_stable_across_processes_and_hash_seeds():
    """The determinism contract behind CI's PYTHONHASHSEED=0 pin: the
    dense fingerprint is a pure content hash, so a fresh interpreter with
    a DIFFERENT hash seed computes the identical digest."""
    counts = np.array([5, 3, 7, 2, 9, 4, 6, 8])
    fp = dense_fingerprint("allgatherv", counts, Topology(8, 4), "hier", 8)
    prog = textwrap.dedent("""
        import numpy as np
        from repro.core import Topology, dense_fingerprint
        counts = np.array([5, 3, 7, 2, 9, 4, 6, 8])
        print(dense_fingerprint("allgatherv", counts, Topology(8, 4),
                                "hier", 8))
    """)
    for seed in ("17", "4242"):
        env = dict(os.environ, PYTHONPATH=str(REPO / "src"),
                   PYTHONHASHSEED=seed)
        out = subprocess.run(
            [sys.executable, "-c", prog], capture_output=True, text=True,
            cwd=REPO, env=env, check=True,
        )
        assert out.stdout.strip().splitlines()[-1] == fp, seed


def test_grad_sync_config_validation():
    from repro.train.trainer import TrainerConfig, jit_train_step

    with pytest.raises(ValueError, match="make_dp_train_step"):
        jit_train_step(object(), TrainerConfig(grad_sync="hier"))


def test_stats_use_generic_cost_path():
    """Dense rounds are named d0..dk — not the sparse step alphabet — so
    stats_time must take the generic serial-sum path and stay positive
    and additive in the round count."""
    topo = Topology(8, 4)
    counts = uneven_counts(8)
    ring = build_dense_plan("allreduce", counts, topo, "ring")
    assert all(s.name.startswith("d") for s in ring.stats.steps)
    t = dense_time(ring, TPU_V5E)
    assert np.isfinite(t) and t > 0
    # doubling payload can't make the modeled time cheaper
    big = build_dense_plan("allreduce", counts * 2, topo, "ring")
    assert dense_time(big, TPU_V5E) >= t - 1e-15
