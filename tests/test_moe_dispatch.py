"""MoE dispatch through the neighborhood-collective planning stack.

Covers the planned-dispatch tentpole (``moe_plan_for`` / PlanCache keys /
Section-5 ``auto`` selection) and the dispatch-geometry bugfixes: expert
replication round-up for non-divisible (n_experts, ep_size), push-side
empty-exchange dtype inference, and the capacity-drop observability
(``dropped_fraction``, token-major drop order).
"""
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced
from repro.core import (
    PlanCache,
    SparseDynamicExchange,
    Topology,
    build_plan,
    default_plan_cache,
)
from repro.models.common import Initializer
from repro.models.moe import (
    capacity_pack,
    dispatch_pattern,
    init_moe,
    make_moe_plan,
    moe_layer,
    moe_plan_for,
    select_moe_mode,
)


def mesh_stub(*shape, pods=False):
    """make_moe_plan only reads axis_names/devices.shape — a stub covers
    every (e_log, ep_size) combination without real devices."""
    names = ("pod", "data", "model")[-len(shape):] if pods or len(shape) > 2 \
        else ("data", "model")[-len(shape):]
    return SimpleNamespace(axis_names=names, devices=np.empty(shape))


def moe_cfg(**over):
    cfg = reduced("mixtral-8x7b")
    return cfg.__class__(**{**cfg.__dict__, "dtype": jnp.float32, **over})


# ---------------------------------------------------------------------------
# geometry bugfix: non-divisible (n_experts, ep_size)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("e_log,ep", [(3, 4), (5, 8), (6, 4), (3, 8),
                                      (7, 4), (5, 2), (9, 6)])
def test_replication_rounds_up_to_even_packing(e_log, ep):
    """3 logical experts on 4 devices used to hit e_phys=6, 6 % 4 != 0."""
    cfg = moe_cfg(n_experts=e_log)
    plan = make_moe_plan(cfg, mesh_stub(1, ep), 32, mode="a2a")
    assert plan.ep_size == ep
    assert plan.e_phys % ep == 0
    assert plan.e_phys % e_log == 0           # whole replicas only
    assert plan.e_per_dev * ep == plan.e_phys
    assert plan.replicas >= 1
    # minimality: one fewer replication step would break even packing
    # (replicas is the least multiple of ep/gcd(e_log, ep) >= ceil(ep/e_log))
    import math
    step = ep // math.gcd(e_log, ep)
    assert plan.replicas % step == 0
    assert plan.replicas - step < max(1, math.ceil(ep / e_log)) \
        or plan.replicas == step


@pytest.mark.parametrize("e_log,ep", [(8, 4), (4, 4), (2, 8)])
def test_replication_unchanged_when_divisible(e_log, ep):
    cfg = moe_cfg(n_experts=e_log)
    plan = make_moe_plan(cfg, mesh_stub(1, ep), 32, mode="a2a")
    assert plan.e_phys == max(e_log, ep)


# ---------------------------------------------------------------------------
# push-side exchange: empty-receiver dtype, pattern equivalence
# ---------------------------------------------------------------------------


def test_push_all_empty_keeps_declared_dtype():
    """An all-empty exchange must still honor the senders' dtype (it used
    to fall back to float64 because only non-empty payloads were probed)."""
    n = 4
    dest = [np.zeros(0, np.int64)] * n
    payload = [np.zeros((0, 3), np.float32)] * n
    received, sources, _stats = SparseDynamicExchange.push(dest, payload)
    for r, s in zip(received, sources):
        assert r.dtype == np.float32
        assert r.shape == (0, 3)
        assert len(s) == 0


def test_push_mixed_empty_prefers_nonempty_dtype():
    dest = [np.array([1]), np.zeros(0, np.int64)]
    payload = [np.array([[1, 2]], np.int32), np.zeros((0, 2), np.float64)]
    received, _src, _stats = SparseDynamicExchange.push(dest, payload)
    assert received[1].dtype == np.int32
    np.testing.assert_array_equal(received[1], [[1, 2]])


def test_push_pattern_matches_push_delivery():
    """The CommPattern from push_pattern, executed as a standard plan,
    delivers exactly what push() delivers (same values, same order)."""
    rng = np.random.default_rng(7)
    n = 4
    dest = [rng.integers(0, n, size=rng.integers(0, 9)).astype(np.int64)
            for _ in range(n)]
    offsets = np.cumsum([0] + [len(d) for d in dest])
    # payload rows = their global ids, so delivered values identify rows
    payload = [np.arange(offsets[p], offsets[p] + len(dest[p]), dtype=np.int64)
               for p in range(n)]
    received, sources, _ = SparseDynamicExchange.push(dest, payload)

    pattern, stats = SparseDynamicExchange.push_pattern(dest)
    topo = Topology(n, 2)
    plan = build_plan(pattern, topo, "standard")
    local_vals = [p.astype(np.float64) for p in payload]
    ghosts = plan.execute_numpy(local_vals)
    for q in range(n):
        np.testing.assert_array_equal(ghosts[q].astype(np.int64), received[q])
        np.testing.assert_array_equal(
            pattern.owner_proc[pattern.needs[q]], sources[q]
        )
    assert stats.allreduce_ints == n * n


def test_push_pattern_duplicates_enable_dedup():
    """Pushing one value to several ranks of a region (top-k fan-out) must
    survive as duplicate global indices — which the full planner removes."""
    n = 4
    # rank 0 pushes its value 0 to ranks 2 and 3 (one region)
    dest = [np.array([2, 3]), np.zeros(0, np.int64),
            np.zeros(0, np.int64), np.zeros(0, np.int64)]
    local_ids = [np.array([0, 0]), np.zeros(0, np.int64),
                 np.zeros(0, np.int64), np.zeros(0, np.int64)]
    pattern, _ = SparseDynamicExchange.push_pattern(
        dest, local_ids, n_local=[1, 1, 1, 1]
    )
    topo = Topology(n, 2)
    partial = build_plan(pattern, topo, "partial")
    full = build_plan(pattern, topo, "full")
    assert int(partial.stats.inter_bytes.sum()) == 2 * 8
    assert int(full.stats.inter_bytes.sum()) == 1 * 8   # deduped crossing
    ghosts = full.execute_numpy([np.array([5.0]), np.zeros(0),
                                 np.zeros(0), np.zeros(0)])
    assert ghosts[2][0] == 5.0 and ghosts[3][0] == 5.0


# ---------------------------------------------------------------------------
# capacity drops: observable fraction, token-major order
# ---------------------------------------------------------------------------


def test_capacity_pack_drops_late_tokens_first():
    """Single hot expert: the first C pairs in token-major order keep their
    slots, every later-sequence token is dropped (documented bias)."""
    cfg = moe_cfg(n_experts=1, top_k=1)
    plan = make_moe_plan(cfg, mesh_stub(1, 1), 16, mode="a2a",
                         cap_factor=0.5)
    assert plan.capacity == 8
    phys = jnp.zeros((16, 1), jnp.int32)       # everyone routes to expert 0
    slot, keep, slot_token = capacity_pack(phys, plan)
    keep = np.asarray(keep).reshape(-1)
    assert keep[:8].all() and not keep[8:].any()
    np.testing.assert_array_equal(np.asarray(slot_token)[:8], np.arange(8))


def test_dropped_fraction_excludes_padding_rows():
    """Pads are routed (and may consume capacity) but must not enter the
    capacity-health metric: with 12 real of 16 rows and capacity 8 on one
    hot expert, dropped is 1 - 8/12, not 1 - 8/16."""
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    from repro.models.moe import moe_dispatch_lane

    cfg = moe_cfg(n_experts=1, top_k=1)
    plan = make_moe_plan(cfg, mesh_stub(1, 1), 16, mode="a2a",
                         cap_factor=0.5)
    assert plan.capacity == 8
    init = Initializer(0, jnp.float32)
    params = {k: v[0] for k, v in init_moe(init, cfg, 1, plan.e_phys).items()
              if not k.startswith("ws_")}
    mesh = jax.make_mesh((1,), ("model",))
    x = jnp.asarray(np.random.default_rng(0)
                    .normal(size=(16, cfg.d_model)).astype(np.float32))

    def body(xl):
        valid = jnp.arange(16) < 12
        _y, _aux, drop, _counts = moe_dispatch_lane(xl, params, plan, cfg,
                                                    valid=valid)
        return drop

    drop = shard_map(body, mesh=mesh, in_specs=(P(None, None),),
                     out_specs=P(), check_vma=False)(x)
    np.testing.assert_allclose(float(drop), 1.0 - 8.0 / 12.0, atol=1e-6)


def test_moe_layer_surfaces_dropped_fraction():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = moe_cfg(n_experts=4, top_k=1)
    cache = PlanCache()
    # biased router -> all tokens pick expert 0; capacity 8 of 16 pairs
    plan = moe_plan_for(cfg, mesh, 16, mode="a2a", cap_factor=0.5,
                        cache=cache)
    assert plan.capacity * plan.e_phys >= 8
    init = Initializer(0, jnp.float32)
    params = {k: v[0] for k, v in init_moe(init, cfg, 1, plan.e_phys).items()}
    params["router"] = params["router"] * 0.0
    params["router"] = params["router"].at[:, 0].set(50.0)
    # strictly positive features so the +50 column dominates every token's
    # logits and routing really is all-to-expert-0
    x = jnp.asarray(np.random.default_rng(0)
                    .uniform(0.1, 1.0, size=(1, 16, cfg.d_model))
                    .astype(np.float32))
    y, aux, dropped = moe_layer(x, params, plan, cfg, mesh, ("data",),
                                cache=cache)
    assert y.shape == x.shape
    # all 16 pairs target expert 0 (replicas=1): capacity keeps 8
    np.testing.assert_allclose(float(dropped), 0.5, atol=1e-6)
    # ample capacity drops nothing
    plan2 = moe_plan_for(cfg, mesh, 16, mode="a2a", cap_factor=8.0,
                         cache=cache)
    _y, _aux, dropped2 = moe_layer(x, params, plan2, cfg, mesh, ("data",),
                                   cache=cache)
    assert float(dropped2) == 0.0


def test_dropped_fraction_counts_dedup_uniq_overflow():
    """hier_dedup can also drop pairs when a region's distinct-token count
    exceeds uniq_capacity; those silent zero-contributions must show up in
    dropped_fraction exactly like expert-capacity drops."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = moe_cfg(n_experts=4, top_k=2)
    cache = PlanCache()
    # ample expert capacity, but dedup_factor squeezes uniq slots to 8 for
    # 16 distinct tokens hitting the (single-device) region
    plan = moe_plan_for(cfg, mesh, 16, mode="hier_dedup", cap_factor=8.0,
                        dedup_factor=0.05, cache=cache)
    assert plan.uniq_capacity == 8
    init = Initializer(0, jnp.float32)
    params = {k: v[0] for k, v in init_moe(init, cfg, 1, plan.e_phys).items()}
    x = jnp.asarray(np.random.default_rng(0)
                    .normal(size=(1, 16, cfg.d_model)).astype(np.float32))
    _y, _aux, dropped = moe_layer(x, params, plan, cfg, mesh, ("data",),
                                  cache=cache)
    # 8 of 16 tokens win a uniq slot; both pairs of each loser are dropped
    np.testing.assert_allclose(float(dropped), 0.5, atol=1e-6)


# ---------------------------------------------------------------------------
# planned dispatch: cache behavior + auto selection
# ---------------------------------------------------------------------------


def test_moe_plan_for_caches_by_shape_and_fingerprint():
    cfg = moe_cfg()
    mesh = mesh_stub(2, 1, 8, pods=True)      # EP spans 2 pods x 8 lanes
    cache = PlanCache()
    p1 = moe_plan_for(cfg, mesh, 128, mode="auto", cache=cache)
    assert (cache.misses, cache.hits) == (1, 0)
    assert p1.mode in ("a2a", "hier", "hier_dedup")
    assert p1.fingerprint
    p2 = moe_plan_for(cfg, mesh, 128, mode="auto", cache=cache)
    assert p2 is p1
    assert (cache.misses, cache.hits) == (1, 1)
    # a different token count is a different dispatch geometry
    p3 = moe_plan_for(cfg, mesh, 256, mode="auto", cache=cache)
    assert cache.misses == 2 and p3.capacity >= p1.capacity
    # explicit mode entry is distinct but equal geometry when auto agrees
    p4 = moe_plan_for(cfg, mesh, 128, mode=p1.mode, cache=cache)
    assert cache.misses == 3
    assert p4 == p1


def test_auto_selection_follows_cost_model_crossover():
    """Section-5 selection on a 4-pod EP group: aggregation wins the
    message-count-dominated regime (small wire rows), the flat a2a wins
    once bandwidth dominates — the paper's crossover, and the selected
    mode is always the model's argmin."""
    from repro.models.moe import STRATEGY_OF_MODE

    cfg = moe_cfg(n_experts=8, top_k=2)
    plan = make_moe_plan(cfg, mesh_stub(4, 1, 16, pods=True), 512,
                         mode="a2a")
    for vb, expect in ((512, ("hier", "hier_dedup")), (32768, ("a2a",))):
        mode, report = select_moe_mode(plan, 512, value_bytes=vb)
        best = min(report.modeled_times, key=report.modeled_times.get)
        assert STRATEGY_OF_MODE[mode] == best
        assert mode in expect, (vb, mode, report.modeled_times)
    # with top_k > 1, dedup never crosses more bytes than plain aggregation
    mode, report = select_moe_mode(plan, 512, value_bytes=512)
    assert report.modeled_times["full"] <= report.modeled_times["partial"]


def test_dispatch_pattern_fingerprint_is_stable():
    cfg = moe_cfg()
    plan = make_moe_plan(cfg, mesh_stub(2, 1, 4, pods=True), 64, mode="a2a")
    _pat1, _st1, fp1 = dispatch_pattern(plan, 64)
    _pat2, _st2, fp2 = dispatch_pattern(plan, 64)
    assert fp1 == fp2
    _pat3, _st3, fp3 = dispatch_pattern(plan, 128)
    assert fp3 != fp1


def test_repeated_forward_and_decode_plan_nothing():
    """Second identical forward and second identical decode step must
    report zero additional PlanCache misses (plans AND executors)."""
    from repro.models import Model, serving

    cfg = moe_cfg()
    model = Model(cfg, moe_mode="auto", remat=False, moe_cap_factor=8.0)
    params = model.init_params(seed=0)
    rng = np.random.default_rng(0)
    inputs = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, size=(2, 16)).astype(np.int32))}
    cache = default_plan_cache()

    model.forward(params, inputs)
    m0, e0 = cache.misses, cache.exec_misses
    model.forward(params, inputs)
    assert (cache.misses, cache.exec_misses) == (m0, e0)

    _last, caches = serving.prefill(model, params, inputs, max_len=32)
    tok = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, size=(2, 1))
                                 .astype(np.int32))}
    _l1, caches = serving.decode_step(model, params, tok, caches, cur_len=16)
    m0, e0 = cache.misses, cache.exec_misses
    _l2, caches = serving.decode_step(model, params, tok, caches, cur_len=17)
    assert (cache.misses, cache.exec_misses) == (m0, e0)
