"""Distributed AMG setup: partner discovery, remote-row gather, block
SpGEMM, and the full hierarchy build validated against the host setup.

Host-process tests run the rank-simulated machinery directly (no devices
needed); the device lowering of the distributed setup runs in a subprocess
on 8 virtual devices (check_distributed_setup.py), mirroring
test_distributed_amg.py.
"""
import os
import pathlib
import subprocess
import sys

import numpy as np

from repro.amg import (
    build_hierarchy,
    diffusion_2d,
    distributed_build_hierarchy,
    partition_fine_matrix,
)
from repro.core import PlanCache, SparseDynamicExchange, Topology
from repro.sparse import (
    CSR,
    block_offsets,
    gather_remote_rows,
    merge_row_sets,
    spgemm_local,
    spgemm_rap,
    split_rows,
    stack_blocks,
)

PROGS = pathlib.Path(__file__).parent / "multidevice_progs"
SRC = pathlib.Path(__file__).resolve().parents[1] / "src"


def random_csr(rng, m, n, density=0.08) -> CSR:
    nnz = max(1, int(m * n * density))
    rows = rng.integers(0, m, size=nnz)
    cols = rng.integers(0, n, size=nnz)
    vals = rng.normal(size=nnz)
    return CSR.from_coo(rows, cols, vals, (m, n))


# ---------------------------------------------------------------------------
# sparse dynamic data exchange
# ---------------------------------------------------------------------------


def test_discover_partners_counts_and_pattern():
    off = np.array([0, 3, 6, 10])
    needs = [np.array([4, 8]), np.array([0, 1, 9]), np.zeros(0, dtype=np.int64)]
    pattern, stats = SparseDynamicExchange.discover(needs, off)
    assert stats.n_procs == 3
    assert stats.allreduce_ints == 9          # the P*P count matrix
    assert stats.request_ints == 5            # total requested indices
    # rank 0 pulls from ranks 1 and 2; rank 1 from 0 and 2; rank 2 idles
    assert stats.request_partners.tolist() == [2, 2, 0]
    # owners: rank 0 serves rank 1; rank 1 serves rank 0; rank 2 serves both
    assert stats.serve_partners.tolist() == [1, 1, 2]
    assert pattern.n_procs == 3
    for p in range(3):
        assert np.array_equal(pattern.needs[p], needs[p])
    # ownership arrays agree with the block partition
    assert pattern.owner_proc[4] == 1 and pattern.owner_proc[8] == 2


def test_push_exchange_roundtrip():
    rng = np.random.default_rng(3)
    P_ = 4
    dest = [rng.integers(0, P_, size=k) for k in (5, 0, 7, 3)]
    payload = [
        np.stack([np.full(len(d), p, dtype=float), rng.normal(size=len(d))],
                 axis=-1)
        for p, d in enumerate(dest)
    ]
    received, sources, stats = SparseDynamicExchange.push(dest, payload)
    assert stats.allreduce_ints == P_ * P_
    total = sum(len(d) for d in dest)
    assert stats.request_ints == total
    assert sum(len(r) for r in received) == total
    for q in range(P_):
        # every delivered row really was addressed to q, by its claimed src
        for src, row in zip(sources[q], received[q]):
            assert int(row[0]) == src
        # sources arrive in ascending rank order (deterministic assembly)
        assert np.all(np.diff(sources[q]) >= 0)


# ---------------------------------------------------------------------------
# remote-row gather + local SpGEMM
# ---------------------------------------------------------------------------


def test_gather_remote_rows_roundtrip():
    rng = np.random.default_rng(0)
    A = random_csr(rng, 60, 45)
    P_ = 4
    off = block_offsets(A.nrows, P_)
    blocks = split_rows(A, off)
    topo = Topology(P_, 2)
    cache = PlanCache()
    needs = []
    for p in range(P_):
        lo, hi = int(off[p]), int(off[p + 1])
        others = np.setdiff1d(np.arange(A.nrows), np.arange(lo, hi))
        needs.append(np.sort(rng.choice(others, size=8, replace=False)))
    g = gather_remote_rows(blocks, off, needs, topo, cache, strategy="auto")
    for p in range(P_):
        ref = A.take_rows(needs[p])
        assert np.array_equal(g.rows[p].indptr, ref.indptr)
        assert np.array_equal(g.rows[p].indices, ref.indices)
        assert np.array_equal(g.rows[p].data, ref.data)
    assert g.discovery.request_ints == sum(len(n) for n in needs)
    # both exchange plans went through the cache
    assert cache.misses == 2
    # a second identical gather re-plans nothing
    gather_remote_rows(blocks, off, needs, topo, cache, strategy="auto")
    assert cache.misses == 2 and cache.hits == 2


def test_spgemm_local_matches_matmat():
    rng = np.random.default_rng(1)
    L = random_csr(rng, 20, 30)
    B = random_csr(rng, 30, 25)
    ids = np.arange(30)
    out = spgemm_local(L, ids, B)
    ref = L.matmat(B)
    assert np.abs(out.to_dense() - ref.to_dense()).max() < 1e-14
    # row-subset path: only the referenced rows available, in sorted order
    used = np.unique(L.indices)
    out2 = spgemm_local(
        CSR(L.shape, L.indptr, L.indices, L.data), used, B.take_rows(used)
    )
    assert np.abs(out2.to_dense() - ref.to_dense()).max() < 1e-14


def test_spgemm_local_missing_rows_raises():
    rng = np.random.default_rng(2)
    L = random_csr(rng, 10, 12)
    B = random_csr(rng, 12, 9)
    present = np.unique(L.indices)[:-1]  # drop one referenced row
    try:
        spgemm_local(L, present, B.take_rows(present))
    except ValueError as e:
        assert "missing" in str(e)
    else:
        raise AssertionError("expected ValueError for missing rows")


def test_merge_row_sets_sorted():
    rng = np.random.default_rng(4)
    M = random_csr(rng, 12, 8)
    ids_a, ids_b = np.array([3, 4, 5]), np.array([0, 9, 11])
    ids, sub = merge_row_sets(
        ids_a, M.take_rows(ids_a), ids_b, M.take_rows(ids_b)
    )
    assert np.array_equal(ids, np.array([0, 3, 4, 5, 9, 11]))
    assert np.abs(sub.to_dense() - M.take_rows(ids).to_dense()).max() == 0


def test_rap_blocks_match_host_galerkin():
    A = diffusion_2d(16, 16)
    h = build_hierarchy(A)
    lvl = h.levels[0]
    P_ = 4
    off = block_offsets(A.nrows, P_)
    coff = block_offsets(lvl.R.nrows, P_)
    topo = Topology(P_, 2)
    cache = PlanCache()
    res = spgemm_rap(
        split_rows(lvl.R, coff), split_rows(A, off), split_rows(lvl.P, off),
        off, topo, cache,
    )
    Ac = stack_blocks(res.Ac_blocks).prune(1e-14)
    ref = h.levels[1].A
    assert np.abs(Ac.to_dense() - ref.to_dense()).max() < 1e-12
    # per-rank block equality, not only the assembled product
    for p, blk in enumerate(res.Ac_blocks):
        ref_blk = ref.take_rows(np.arange(coff[p], coff[p + 1]))
        assert (
            np.abs(blk.prune(1e-14).to_dense() - ref_blk.to_dense()).max()
            < 1e-12
        )


# ---------------------------------------------------------------------------
# full distributed setup vs host hierarchy
# ---------------------------------------------------------------------------


def test_distributed_setup_matches_host_hierarchy():
    A = diffusion_2d(24, 24)
    h = build_hierarchy(A)
    P_ = 6
    blocks, off = partition_fine_matrix(A, P_)
    ds = distributed_build_hierarchy(
        blocks, off, Topology(P_, 2), cache=PlanCache()
    )
    hh = ds.to_host_hierarchy()
    assert hh.n_levels == h.n_levels
    for k in range(h.n_levels):
        lh, ld = h.levels[k], hh.levels[k]
        if lh.splitting is not None:
            assert ld.splitting is not None
            assert np.array_equal(lh.splitting, ld.splitting), f"L{k}"
        assert np.abs(lh.A.to_dense() - ld.A.to_dense()).max() < 1e-12, f"L{k}"
        if lh.P is not None and ld.P is not None:
            assert np.abs(lh.P.to_dense() - ld.P.to_dense()).max() < 1e-12
            assert np.abs(lh.R.to_dense() - ld.R.to_dense()).max() < 1e-12
        assert abs(lh.rho - ld.rho) < 1e-6 * max(lh.rho, 1.0)
    # exchange accounting covers every phase of the pipeline
    phases = {r.phase for r in ds.records}
    assert {"halo", "strength_transpose", "p_transpose",
            "gather_A", "gather_P"} <= phases


def test_setup_plans_served_from_cache_on_rebuild():
    A = diffusion_2d(16, 16)
    P_ = 4
    blocks, off = partition_fine_matrix(A, P_)
    topo = Topology(P_, 2)
    cache = PlanCache()
    distributed_build_hierarchy(blocks, off, topo, cache=cache)
    misses = cache.misses
    assert misses > 0 and cache.hits == 0
    ds2 = distributed_build_hierarchy(blocks, off, topo, cache=cache)
    # repeated build: every setup-phase exchange plan is a cache hit
    assert cache.misses == misses
    assert cache.hits == misses
    assert cache.init_seconds_saved > 0.0
    assert ds2.to_host_hierarchy().n_levels >= 2


# ---------------------------------------------------------------------------
# device lowering (8 virtual devices, subprocess)
# ---------------------------------------------------------------------------


def run_prog(name: str, timeout=600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(SRC) + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    out = subprocess.run(
        [sys.executable, str(PROGS / name)],
        env=env,
        capture_output=True,
        text=True,
        timeout=timeout,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_distributed_setup_multidevice():
    out = run_prog("check_distributed_setup.py")
    assert "ALL_OK" in out
    assert "levels OK" in out
    assert "solve OK" in out
    assert "plan cache OK" in out
