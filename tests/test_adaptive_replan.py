"""Adaptive MoE re-planning (repro.profile.adapt + serve.engine wiring).

The acceptance properties: repeated serve-engine decodes under an
unchanged routing histogram incur zero new plan-cache misses, and a
drifted histogram triggers exactly one re-selection.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced
from repro.core import PlanCache
from repro.models import Model
from repro.models.moe import (
    make_moe_plan,
    moe_plan_from_histogram,
    quantize_histogram,
)
from repro.profile import AdaptivePlanner, TraceRecorder
from repro.serve import Request, ServeEngine


def moe_cfg():
    cfg0 = reduced("mixtral-8x7b")
    return cfg0.__class__(**{**cfg0.__dict__, "dtype": jnp.float32})


# ---------------------------------------------------------------------------
# histogram quantization + re-fingerprinting
# ---------------------------------------------------------------------------


def test_quantize_histogram_is_stable_under_small_noise():
    base = np.array([10.0, 30.0, 40.0, 20.0])
    q1 = quantize_histogram(base, 4, quantum=64)
    q2 = quantize_histogram(base * 3.7, 4, quantum=64)          # scale-free
    q3 = quantize_histogram(base + np.array([0.05, -0.04, 0.02, 0.0]), 4,
                            quantum=64)
    assert q1 == q2 == q3
    assert sum(q1) == 64
    far = quantize_histogram([90.0, 5.0, 3.0, 2.0], 4, quantum=64)
    assert far != q1
    # all-zero histogram -> uniform, not a crash
    assert sum(quantize_histogram([0, 0, 0, 0], 4, quantum=64)) == 64


def test_histogram_plan_unchanged_distribution_hits_cache():
    import jax

    cfg = moe_cfg()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cache = PlanCache()
    h = np.array([5.0, 3.0, 2.0, 6.0])
    p1 = moe_plan_from_histogram(cfg, mesh, 32, h, cache=cache)
    m1 = cache.misses
    # scaled + sub-quantum noise: same quantized fingerprint -> pure hit
    p2 = moe_plan_from_histogram(cfg, mesh, 32, h * 2.0 + 1e-3, cache=cache)
    assert p2 is p1
    assert cache.misses == m1
    # a genuinely different distribution re-plans
    p3 = moe_plan_from_histogram(
        cfg, mesh, 32, np.array([99.0, 1.0, 0.0, 0.0]), cache=cache)
    assert cache.misses == m1 + 1
    assert p3.fingerprint != "" and p1.fingerprint != ""


def test_histogram_pattern_reflects_skew():
    """A fully skewed histogram concentrates the synthesized pattern's
    traffic on the hot experts' device (visible as fewer dst devices)."""
    import jax

    cfg = moe_cfg()
    mesh = jax.make_mesh((1, 4), ("data", "model"))
    cache = PlanCache()
    hot = moe_plan_from_histogram(
        cfg, mesh, 32, np.array([1.0, 0.0, 0.0, 0.0]), mode="a2a",
        cache=cache)
    uni = moe_plan_from_histogram(
        cfg, mesh, 32, np.ones(4), mode="a2a", cache=cache)
    assert hot.fingerprint != uni.fingerprint


# ---------------------------------------------------------------------------
# planner unit semantics (synthetic observations: deterministic)
# ---------------------------------------------------------------------------


def planner_for(cache, **kw):
    """Planner on a 4-lane EP mesh: routing regimes produce genuinely
    different dispatch patterns (on 1 lane every no-drop routing is the
    same all-local pattern and re-fingerprinting is correctly a no-op)."""
    import jax

    cfg = moe_cfg()
    mesh = jax.make_mesh((1, 4), ("data", "model"))
    plan = make_moe_plan(cfg, mesh, 8, mode="a2a")
    defaults = dict(cfg=cfg, mesh=mesh, tokens_per_lane=8, plan=plan,
                    threshold=0.3, warmup=2, window=4, cache=cache)
    defaults.update(kw)
    return AdaptivePlanner(**defaults)


def test_planner_steady_histogram_never_replans():
    cache = PlanCache()
    pl = planner_for(cache)
    uniform = np.array([4.0, 4.0, 4.0, 4.0])
    for _ in range(20):
        assert pl.observe(uniform) is None
    assert pl.events == []
    assert cache.misses == 0


def test_planner_drift_triggers_exactly_one_reselection():
    cache = PlanCache()
    tracer = TraceRecorder()
    pl = planner_for(cache, tracer=tracer)
    uniform = np.array([4.0, 4.0, 4.0, 4.0])
    skew = np.array([14.0, 2.0, 0.0, 0.0])
    for _ in range(6):
        pl.observe(uniform)
    old_fp = pl.plan.fingerprint
    events = [pl.observe(skew) for _ in range(12)]
    fired = [e for e in events if e is not None]
    assert len(fired) == 1                       # exactly one re-selection
    assert len(pl.events) == 1
    ev = fired[0]
    assert ev.drift > 0.3
    assert ev.old_fingerprint == old_fp
    assert pl.plan.fingerprint == ev.new_fingerprint
    # every observation was recorded for offline analysis
    assert len(tracer.histograms) == 18


def test_planner_returning_regime_replans_from_cache():
    """Drift A -> B -> A: the second A re-selection re-fingerprints to the
    already-cached plan — a hit, not a re-plan."""
    cache = PlanCache()
    pl = planner_for(cache)
    a = np.array([4.0, 4.0, 4.0, 4.0])
    b = np.array([16.0, 0.0, 0.0, 0.0])
    for _ in range(6):
        pl.observe(a)
    for _ in range(12):
        pl.observe(b)
    assert len(pl.events) == 1
    misses_after_b = cache.misses
    for _ in range(12):
        pl.observe(a)
    assert len(pl.events) == 2
    assert cache.misses == misses_after_b + 1    # A's plan built once...
    for _ in range(12):
        pl.observe(b)
    assert len(pl.events) == 3
    assert cache.misses == misses_after_b + 1    # ...B's plan: cache hit
    assert cache.hits >= 1


def test_planner_rejects_wrong_bin_count():
    pl = planner_for(PlanCache())
    with pytest.raises(ValueError):
        pl.observe(np.ones(7))


# ---------------------------------------------------------------------------
# serve-engine wiring (real decodes)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def moe_engine():
    cfg = moe_cfg()
    model = Model(cfg, moe_mode="auto", remat=False, moe_cap_factor=8.0)
    params = model.init_params(seed=0)
    eng = ServeEngine(model, params, batch_slots=2, max_len=96,
                      adaptive=True, drift_threshold=0.3, drift_warmup=2)
    rng = np.random.default_rng(1)
    eng.submit(Request(
        rid=0,
        prompt=rng.integers(0, cfg.vocab, size=(4,)).astype(np.int32),
        max_new_tokens=64,
    ))
    eng.step()      # admit + prefill
    return eng


def test_engine_steady_decode_zero_new_misses_then_drift_replans(moe_engine):
    eng = moe_engine
    # --- steady phase: unchanged routing histogram ------------------------
    for _ in range(8):
        eng.step()
    cache = eng.plan_cache
    m0, e0 = cache.misses, cache.exec_misses
    for _ in range(4):
        eng.step()
    assert (cache.misses, cache.exec_misses) == (m0, e0)
    assert eng.replan_events == []
    assert eng.planner.observed >= 12

    # --- drift phase: zero router -> ties -> all tokens to experts {0,1} --
    p = eng.params
    p["blocks"]["moe"]["router"] = jnp.zeros_like(p["blocks"]["moe"]["router"])
    pre_mode = eng.moe_plan.mode
    for _ in range(24):
        eng.step()
    assert len(eng.replan_events) == 1           # exactly one re-selection
    ev = eng.replan_events[0]
    assert ev.drift > 0.3
    assert eng.moe_plan is eng.planner.plan
    assert eng.moe_plan.mode in ("a2a", "hier", "hier_dedup")
    # re-selection swapped (or kept) a decode executable without touching
    # the executor cache: same mode -> zero new compiled dispatch programs
    if eng.moe_plan.mode == pre_mode:
        assert cache.exec_misses == e0
    # steady again under the new regime: no further re-planning
    m1 = cache.misses
    for _ in range(4):
        eng.step()
    assert len(eng.replan_events) == 1
    assert cache.misses == m1
    # the engine still produces valid tokens after migration
    req = eng.slots[0]
    assert req is not None
    assert all(0 <= t < eng.model.cfg.vocab for t in req.generated)
