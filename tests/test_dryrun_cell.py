"""Integration: one real dry-run cell end-to-end in a subprocess (512
virtual devices), plus the skip rule."""
import json
import os
import pathlib
import subprocess
import sys

SRC = pathlib.Path(__file__).resolve().parents[1] / "src"


def run_dryrun(*args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(SRC) + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)  # dryrun.py sets its own
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        env=env, capture_output=True, text=True, timeout=timeout,
        cwd=str(SRC.parent),
    )
    return out


def test_skipped_cell_reports_reason():
    out = run_dryrun("--arch", "nemotron-4-15b", "--shape", "long_500k",
                     "--mesh", "single", timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    d = json.loads(out.stdout)
    assert d["status"] == "skipped"
    assert "sub-quadratic" in d["reason"]


def test_train_cell_compiles_and_reports_roofline():
    out = run_dryrun("--arch", "qwen1.5-0.5b", "--shape", "train_4k",
                     "--mesh", "single", "--force")
    assert out.returncode == 0, out.stderr[-3000:]
    d = json.loads(out.stdout)
    assert d["status"] == "ok"
    assert d["chips"] == 256
    assert d["cost_method"] == "scan+ladder-extrapolation"
    assert d["hlo_flops_per_device"] > 0
    assert d["collective_bytes_total_per_device"] > 0
    assert d["bottleneck"] in ("compute", "memory", "collective")
    assert 0.05 < d["useful_flops_ratio"] <= 1.5
    assert d["memory_analytic"]["fits_16gb_v5e"] is True
