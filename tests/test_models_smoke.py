"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
assert output shapes + finiteness; serving prefill+decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs import ARCHS, reduced
from repro.models import Model, serving


def make_inputs(cfg, B=2, T=16, rng=None):
    rng = rng or np.random.default_rng(0)
    inputs = {}
    if cfg.family == "audio":
        inputs["enc_embeds"] = jnp.asarray(
            rng.normal(size=(B, T, cfg.d_model)).astype(np.float32)
        )
        inputs["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, size=(B, T)).astype(np.int32)
        )
    elif cfg.frontend_stub and cfg.family == "vlm":
        # vlm: precomputed patch+text embeddings + 3D mrope positions
        inputs["embeds"] = jnp.asarray(
            rng.normal(size=(B, T, cfg.d_model)).astype(np.float32)
        )
        pos = np.broadcast_to(np.arange(T, dtype=np.int32), (B, T))
        inputs["positions"] = jnp.asarray(
            np.broadcast_to(pos[:, None, :], (B, 3, T)).copy()
        )
    else:
        inputs["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, size=(B, T)).astype(np.int32)
        )
    inputs["labels"] = jnp.asarray(
        rng.integers(0, cfg.vocab, size=(B, T)).astype(np.int32)
    )
    return inputs


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch):
    cfg = reduced(arch)
    cfg = cfg.__class__(**{**cfg.__dict__, "dtype": jnp.float32})
    model = Model(cfg, moe_mode="a2a", remat=False)
    params = model.init_params(seed=0)
    inputs = make_inputs(cfg)
    logits, aux = jax.jit(model.forward)(params, inputs)
    B, T = inputs["labels"].shape
    assert logits.shape == (B, T, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    loss, metrics = jax.jit(model.loss)(params, inputs)
    assert np.isfinite(float(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_grad_step_no_nans(arch):
    cfg = reduced(arch)
    cfg = cfg.__class__(**{**cfg.__dict__, "dtype": jnp.float32})
    model = Model(cfg, moe_mode="a2a", remat=True)
    params = model.init_params(seed=1)
    inputs = make_inputs(cfg, B=2, T=8)

    def loss_fn(p):
        return model.loss(p, inputs)[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, np.float32))) for g in flat)
    # at least some gradient signal somewhere
    assert any(float(jnp.abs(g).max()) > 0 for g in flat)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    """Greedy next-token from (prefill + decode) must match the train-path
    forward logits at the same positions."""
    cfg = reduced(arch)
    cfg = cfg.__class__(**{**cfg.__dict__, "dtype": jnp.float32})
    # ample MoE capacity: drop patterns depend on batch composition, which
    # differs between forward(T) and forward(T+1) — not what this test probes
    model = Model(cfg, moe_mode="a2a", remat=False, moe_cap_factor=8.0)
    params = model.init_params(seed=2)
    B, T = 2, 12
    inputs = make_inputs(cfg, B=B, T=T)
    max_len = 32

    logits_ref, _ = model.forward(params, inputs)   # [B, T, V]

    last, caches = serving.prefill(model, params, inputs, max_len=max_len)
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(logits_ref[:, -1]), rtol=2e-3, atol=2e-3
    )
    # one decode step: feed token t=T, compare against forward over T+1
    rng = np.random.default_rng(9)
    new_tok = rng.integers(0, cfg.vocab, size=(B, 1)).astype(np.int32)
    step_inputs = {}
    if "embeds" in inputs:
        new_emb = rng.normal(size=(B, 1, cfg.d_model)).astype(np.float32)
        step_inputs["embeds"] = jnp.asarray(new_emb)
    else:
        step_inputs["tokens"] = jnp.asarray(new_tok)
    logits_step, _ = serving.decode_step(model, params, step_inputs, caches,
                                         cur_len=T)

    ext = dict(inputs)
    if "embeds" in inputs:
        ext["embeds"] = jnp.concatenate(
            [inputs["embeds"], step_inputs["embeds"]], axis=1
        )
        pos = np.broadcast_to(np.arange(T + 1, dtype=np.int32), (B, T + 1))
        ext["positions"] = jnp.asarray(
            np.broadcast_to(pos[:, None, :], (B, 3, T + 1)).copy()
        )
    else:
        ext["tokens"] = jnp.concatenate(
            [inputs["tokens"], jnp.asarray(new_tok)], axis=1
        )
    ext["labels"] = jnp.zeros((B, T + 1), jnp.int32)
    logits_ext, _ = model.forward(params, ext)
    np.testing.assert_allclose(
        np.asarray(logits_step), np.asarray(logits_ext[:, -1]),
        rtol=2e-3, atol=2e-3,
    )


def test_param_counts_match_analytic_scale():
    """Full configs: analytic param count is in the advertised ballpark."""
    expect = {
        "nemotron-4-15b": (12e9, 18e9),
        "gemma3-1b": (0.7e9, 1.6e9),
        "qwen1.5-0.5b": (0.3e9, 0.7e9),
        "qwen2-0.5b": (0.3e9, 0.7e9),
        "mamba2-780m": (0.6e9, 1.0e9),
        "qwen2-vl-2b": (1.2e9, 2.5e9),
        "deepseek-v2-lite-16b": (12e9, 20e9),
        "mixtral-8x7b": (40e9, 52e9),
        "zamba2-7b": (6e9, 9e9),
        "seamless-m4t-medium": (0.4e9, 1.5e9),
    }
    for arch, (lo, hi) in expect.items():
        n = configs.get(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n:,} not in [{lo:,.0f}, {hi:,.0f}]"
