"""Property-based tests (hypothesis) for the system's invariants.

Invariants under test:
  P1  Every strategy delivers exactly the requested values (conservation +
      correctness) for ANY pattern/topology.
  P2  Aggregation never increases the max inter-region message count, and
      bounds it by the number of remote regions.
  P3  Dedup never increases inter-region bytes and never changes results.
  P4  Round schedules are valid partial permutations covering all wire
      messages exactly once.
  P5  Load balancing (LPT) is within 2x of the ideal max load.
  P6  The cost model is monotone in message sizes.
  P7  MoE capacity packing: slots are unique, within bounds, and respect
      per-expert capacity.
  P8  Column-bucketed ELL packing + the blocked SpMV kernel agree with the
      flat kernel, the jnp oracle, and the host matvec on ANY random
      sparsity/ghost pattern.
  P9  The overlap schedule's split execution (local buckets, then ghost
      buckets carried on top — via both the partial and the bucket-skipping
      kernel) equals the one-shot blocked kernel and the host matvec on ANY
      random sparsity/ghost pattern.
  P10 The static verifier (repro.verify) accepts every plan/partition/
      layout built from random patterns, and rejects every injected
      corruption — size-mismatched send, dropped ghost column, duplicated
      bucket, round-coloring conflict — with a diagnostic naming the
      offending rank/bucket.
  P11 Every dense-collective schedule (ring / rd / hier allreduce,
      allgatherv, reduce_scatter) verifies statically and its oracle
      equals the jnp reference (sum / concat / owned-segment) on ANY
      random geometry with uneven counts, including non-divisible
      region sizes.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    CommPattern,
    LASSEN,
    Topology,
    build_plan,
    color_rounds,
    plan_time,
)
from repro.core.locality import balance_assignments
from repro.sparse import CSR


@st.composite
def patterns(draw):
    n_regions = draw(st.integers(2, 4))
    ppr = draw(st.integers(1, 4))
    n_procs = n_regions * ppr
    n_per = draw(st.integers(1, 12))
    n_global = n_procs * n_per
    seed = draw(st.integers(0, 2 ** 16))
    rng = np.random.default_rng(seed)
    needs = []
    for q in range(n_procs):
        k = int(rng.integers(0, min(n_global, 20)))
        needs.append(np.sort(rng.choice(n_global, size=k, replace=False)))
    offsets = np.arange(n_procs + 1) * n_per
    return CommPattern.from_block_partition(needs, offsets), \
        Topology(n_procs, ppr), seed


@settings(max_examples=40, deadline=None)
@given(patterns(), st.sampled_from(["standard", "partial", "full"]))
def test_p1_delivery_correct(pt, strategy):
    pattern, topo, seed = pt
    plan = build_plan(pattern, topo, strategy)
    rng = np.random.default_rng(seed + 1)
    vals = [rng.normal(size=(int(n),)) for n in pattern.n_local]
    got = plan.execute_numpy(vals)
    for q in range(pattern.n_procs):
        want = np.array([
            vals[pattern.owner_proc[g]][pattern.owner_slot[g]]
            for g in pattern.needs[q]
        ])
        np.testing.assert_array_equal(got[q], want.reshape(got[q].shape))


@settings(max_examples=40, deadline=None)
@given(patterns())
def test_p2_aggregation_bounds_inter_messages(pt):
    pattern, topo, _ = pt
    std = build_plan(pattern, topo, "standard")
    par = build_plan(pattern, topo, "partial")
    # per-proc inter messages bounded by remote region count
    assert par.stats.max_inter_msgs() <= topo.n_regions - 1 + 1
    assert (par.stats.totals()["inter_msgs"]
            <= max(std.stats.totals()["inter_msgs"],
                   topo.n_regions * (topo.n_regions - 1)))


@settings(max_examples=40, deadline=None)
@given(patterns())
def test_p3_dedup_never_worse_and_equal_results(pt):
    pattern, topo, seed = pt
    par = build_plan(pattern, topo, "partial")
    ful = build_plan(pattern, topo, "full")
    assert (ful.stats.totals()["inter_bytes"]
            <= par.stats.totals()["inter_bytes"])
    rng = np.random.default_rng(seed + 2)
    vals = [rng.normal(size=(int(n),)) for n in pattern.n_local]
    a = par.execute_numpy(vals)
    b = ful.execute_numpy(vals)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


@settings(max_examples=40, deadline=None)
@given(patterns(), st.sampled_from(["standard", "partial", "full"]))
def test_p4_rounds_partition_wire_messages(pt, strategy):
    pattern, topo, _ = pt
    plan = build_plan(pattern, topo, strategy)
    for step in plan.steps:
        wire = [(m.src, m.dst, m.size) for m in step.messages
                if m.src != m.dst and m.size > 0]
        scheduled = []
        for rnd in color_rounds(step.messages):
            srcs = [s for s, _ in rnd.pairs]
            dsts = [d for _, d in rnd.pairs]
            assert len(set(srcs)) == len(srcs)
            assert len(set(dsts)) == len(dsts)
            scheduled.extend(
                (s, d, len(si)) for (s, d), si in zip(rnd.pairs, rnd.src_idx)
            )
        assert sorted(scheduled) == sorted(wire)


@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(1, 1000), min_size=1, max_size=40),
       st.integers(1, 8))
def test_p5_lpt_balance(weights, n_workers):
    w = {i: v for i, v in enumerate(weights)}
    assign = balance_assignments(w, n_workers)
    loads = np.zeros(n_workers)
    for k, wk in assign.items():
        loads[wk] += w[k]
    ideal = max(sum(weights) / n_workers, max(weights))
    assert loads.max() <= 2 * ideal


@settings(max_examples=30, deadline=None)
@given(patterns())
def test_p6_costmodel_monotone(pt):
    pattern, topo, _ = pt
    plan8 = build_plan(pattern, topo, "standard", value_bytes=8)
    plan16 = build_plan(pattern, topo, "standard", value_bytes=16)
    assert plan_time(plan16, LASSEN) >= plan_time(plan8, LASSEN) - 1e-12


@st.composite
def sparse_partitions(draw):
    """A random square CSR (uneven blocks, random sparsity => random ghost
    pattern) plus a bucket width and rng seed."""
    n_procs = draw(st.integers(1, 3))
    n = draw(st.integers(n_procs, 24))
    bc = draw(st.sampled_from([4, 8, 16]))
    seed = draw(st.integers(0, 2 ** 16))
    rng = np.random.default_rng(seed)
    nnz = int(rng.integers(0, 4 * n))
    rows = rng.integers(0, n, size=nnz)
    cols = rng.integers(0, n, size=nnz)
    vals = rng.normal(size=nnz)
    return CSR.from_coo(rows, cols, vals, (n, n)), n_procs, bc, seed


@settings(max_examples=15, deadline=None)
@given(sparse_partitions())
def test_p8_blocked_packing_matches_flat_and_ref(sp):
    from repro.kernels.spmv_ell import spmv_ell_ref
    from repro.kernels.spmv_ell.spmv_ell import spmv_ell, spmv_ell_blocked
    from repro.sparse import (
        partition_csr,
        partitioned_to_ell,
        partitioned_to_ell_blocked,
    )
    import jax.numpy as jnp

    A, n_procs, bc, seed = sp
    part = partition_csr(A, n_procs)
    ell = partitioned_to_ell(part, dtype=np.float32)
    bell = partitioned_to_ell_blocked(part, block_cols=bc, dtype=np.float32)
    plan = build_plan(part.pattern, Topology(n_procs, 1), "standard")
    rng = np.random.default_rng(seed + 1)
    x = rng.normal(size=A.ncols).astype(np.float32)
    xs = [x[int(part.offsets[p]): int(part.offsets[p + 1])]
          for p in range(n_procs)]
    ghosts = plan.execute_numpy(xs)
    want_all = A.matvec(x.astype(np.float64))
    for p in range(n_procs):
        n_rows = int(part.offsets[p + 1] - part.offsets[p])
        # flat: local + ghost kernels with sentinel slots
        xf = np.zeros(ell.in_pad + 1, dtype=np.float32)
        xf[: len(xs[p])] = xs[p]
        flat = spmv_ell(
            jnp.asarray(ell.local_cols[p]), jnp.asarray(ell.local_vals[p]),
            jnp.asarray(xf), block_rows=8, interpret=True,
        )
        ref = spmv_ell_ref(
            jnp.asarray(ell.local_cols[p]), jnp.asarray(ell.local_vals[p]),
            jnp.asarray(xf),
        )
        if ell.ghost_pad:
            gf = np.zeros(ell.ghost_pad + 1, dtype=np.float32)
            gf[: len(ghosts[p])] = ghosts[p].astype(np.float32)
            flat = flat + spmv_ell(
                jnp.asarray(ell.ghost_cols[p]),
                jnp.asarray(ell.ghost_vals[p]),
                jnp.asarray(gf), block_rows=8, interpret=True,
            )
            ref = ref + spmv_ell_ref(
                jnp.asarray(ell.ghost_cols[p]),
                jnp.asarray(ell.ghost_vals[p]), jnp.asarray(gf),
            )
        # blocked: one accumulating kernel over [local | ghost] buckets
        xb = np.zeros(bell.x_len, dtype=np.float32)
        xb[: len(xs[p])] = xs[p]
        g0 = bell.n_local_buckets * bc
        xb[g0: g0 + len(ghosts[p])] = ghosts[p].astype(np.float32)
        blocked = spmv_ell_blocked(
            jnp.asarray(bell.cols[p]), jnp.asarray(bell.vals[p]),
            jnp.asarray(xb), block_cols=bc, block_rows=8, interpret=True,
        )
        np.testing.assert_allclose(np.asarray(blocked), np.asarray(flat),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(blocked), np.asarray(ref),
                                   rtol=1e-5, atol=1e-6)
        want = want_all[int(part.offsets[p]): int(part.offsets[p + 1])]
        np.testing.assert_allclose(
            np.asarray(blocked)[:n_rows], want, rtol=1e-4, atol=1e-4
        )


@settings(max_examples=15, deadline=None)
@given(sparse_partitions())
def test_p9_overlap_split_matches_blocked_and_host(sp):
    from repro.kernels.spmv_ell.spmv_ell import (
        spmv_ell_blocked,
        spmv_ell_blocked_partial,
        spmv_ell_blocked_skip,
    )
    from repro.sparse import (
        partition_csr,
        partitioned_to_ell_blocked,
        row_block_bucket_map,
    )
    import jax.numpy as jnp

    A, n_procs, bc, seed = sp
    part = partition_csr(A, n_procs)
    bell = partitioned_to_ell_blocked(part, block_cols=bc, dtype=np.float32)
    Cl, C = bell.n_local_buckets, bell.n_buckets
    llists, lcounts = row_block_bucket_map(bell, block_rows=8, bucket_hi=Cl)
    if C > Cl:
        glists, gcounts = row_block_bucket_map(bell, block_rows=8,
                                               bucket_lo=Cl)
    plan = build_plan(part.pattern, Topology(n_procs, 1), "standard")
    rng = np.random.default_rng(seed + 1)
    x = rng.normal(size=A.ncols).astype(np.float32)
    xs = [x[int(part.offsets[p]): int(part.offsets[p + 1])]
          for p in range(n_procs)]
    ghosts = plan.execute_numpy(xs)
    want_all = A.matvec(x.astype(np.float64))
    for p in range(n_procs):
        n_rows = int(part.offsets[p + 1] - part.offsets[p])
        cols = jnp.asarray(bell.cols[p])
        vals = jnp.asarray(bell.vals[p])
        xb = np.zeros(bell.x_len, dtype=np.float32)
        xb[: len(xs[p])] = xs[p]
        g0 = Cl * bc
        xb[g0: g0 + len(ghosts[p])] = ghosts[p].astype(np.float32)
        full = spmv_ell_blocked(
            cols, vals, jnp.asarray(xb), block_cols=bc, block_rows=8,
            interpret=True,
        )
        x_local, x_ghost = jnp.asarray(xb[:g0]), jnp.asarray(xb[g0:])
        # split schedule via the carried-output partial kernel
        y = spmv_ell_blocked_partial(
            cols, vals, x_local, jnp.zeros((bell.row_pad,), vals.dtype),
            bucket_lo=0, bucket_hi=Cl, n_buckets=C, block_cols=bc,
            block_rows=8, interpret=True,
        )
        if C > Cl:
            y = spmv_ell_blocked_partial(
                cols, vals, x_ghost, y, bucket_lo=Cl, bucket_hi=C,
                n_buckets=C, block_cols=bc, block_rows=8, interpret=True,
            )
        np.testing.assert_allclose(np.asarray(y), np.asarray(full),
                                   rtol=1e-5, atol=1e-6)
        # same split via the bucket-skipping kernel
        ys = spmv_ell_blocked_skip(
            cols, vals, x_local, jnp.asarray(llists[p]),
            jnp.asarray(lcounts[p]), n_buckets=C, block_cols=bc,
            block_rows=8, interpret=True,
        )
        if C > Cl:
            ys = spmv_ell_blocked_skip(
                cols, vals, x_ghost, jnp.asarray(glists[p]),
                jnp.asarray(gcounts[p]), n_buckets=C, block_cols=bc,
                bucket_base=Cl, y0=ys, block_rows=8, interpret=True,
            )
        np.testing.assert_allclose(np.asarray(ys), np.asarray(full),
                                   rtol=1e-5, atol=1e-6)
        want = want_all[int(part.offsets[p]): int(part.offsets[p + 1])]
        np.testing.assert_allclose(
            np.asarray(y)[:n_rows], want, rtol=1e-4, atol=1e-4
        )


# ---------------------------------------------------------------------------
# P10: the verifier accepts everything the planners build, and rejects
# every injected corruption with a rank/bucket diagnostic
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(patterns(), st.sampled_from(["standard", "partial", "full"]))
def test_p10_verifier_accepts_built_plans(pt, strategy):
    from repro.core.collectives import build_device_plan
    from repro.verify import verify_device_plan, verify_pattern, verify_plan

    pattern, topo, _ = pt
    verify_pattern(pattern)
    plan = build_plan(pattern, topo, strategy)
    verify_plan(plan)
    verify_device_plan(build_device_plan(plan), pattern)


@settings(max_examples=15, deadline=None)
@given(sparse_partitions())
def test_p10_verifier_accepts_built_partitions(sp):
    from repro.sparse import (
        partition_csr,
        partitioned_to_ell,
        partitioned_to_ell_blocked,
    )
    from repro.sparse.device import select_spmv_kernel
    from repro.verify import (
        verify_bucket_map,
        verify_device_ell,
        verify_ell_blocked,
        verify_kernel_budget,
        verify_partition,
    )

    A, n_procs, bc, _ = sp
    part = partition_csr(A, n_procs)
    verify_partition(part)
    ell = partitioned_to_ell(part)
    verify_device_ell(ell, part)
    verify_kernel_budget(ell, select_spmv_kernel(part))
    bell = partitioned_to_ell_blocked(part, block_cols=bc)
    verify_ell_blocked(bell, part)
    verify_kernel_budget(bell, select_spmv_kernel(part, block_cols=bc))
    verify_bucket_map(bell, block_rows=8)
    Cl = bell.n_local_buckets
    verify_bucket_map(bell, block_rows=8, bucket_hi=Cl)
    if bell.n_ghost_buckets:
        verify_bucket_map(bell, block_rows=8, bucket_lo=Cl)


@settings(max_examples=25, deadline=None)
@given(patterns(), st.sampled_from(["standard", "partial", "full"]))
def test_p10_rejects_size_mismatched_send(pt, strategy):
    """Truncating one wire message's payload (sizes still equal, so the
    Message invariant holds) must surface as a conservation failure naming
    the starved rank — the undelivered ghost slot."""
    from hypothesis import assume

    from repro.verify import VerifyError, verify_plan

    pattern, topo, _ = pt
    plan = build_plan(pattern, topo, strategy)
    wire = [m for st_ in plan.steps for m in st_.messages
            if m.src != m.dst and m.size > 0]
    assume(wire)
    m = wire[len(wire) // 2]
    m.src_idx = m.src_idx[:-1]
    m.dst_idx = m.dst_idx[:-1]
    with pytest.raises(VerifyError) as ei:
        verify_plan(plan)
    msg = str(ei.value)
    assert "rank=" in msg or "dst=" in msg, msg


@settings(max_examples=25, deadline=None)
@given(sparse_partitions())
def test_p10_rejects_dropped_ghost_column(sp):
    """Deleting the last exchange slot of a rank with ghosts must be
    rejected with a diagnostic naming that rank."""
    from hypothesis import assume

    from repro.sparse import partition_csr
    from repro.verify import VerifyError, verify_partition

    A, n_procs, _, _ = sp
    part = partition_csr(A, n_procs)
    victims = [p for p in range(n_procs) if len(part.needs[p])]
    assume(victims)
    p = victims[0]
    part.needs[p] = part.needs[p][:-1]
    with pytest.raises(VerifyError) as ei:
        verify_partition(part)
    assert f"rank={p}" in str(ei.value)


@settings(max_examples=20, deadline=None)
@given(sparse_partitions())
def test_p10_rejects_duplicated_bucket(sp):
    """Listing a live bucket twice in a row-block window (its values would
    be accumulated twice by the skip kernel) must be rejected naming the
    bucket."""
    from hypothesis import assume

    from repro.sparse import partition_csr, partitioned_to_ell_blocked
    from repro.sparse.device import row_block_bucket_map
    from repro.verify import VerifyError, check_bucket_map

    A, n_procs, bc, _ = sp
    assume(A.nnz > 0)
    part = partition_csr(A, n_procs)
    bell = partitioned_to_ell_blocked(part, block_cols=bc)
    lists, counts = row_block_bucket_map(bell, block_rows=8)
    # widen the list capacity by one padding column, then duplicate the
    # last live entry of the first non-empty row block
    lists = np.concatenate(
        [lists, np.zeros_like(lists[:, :, :1])], axis=2
    )
    p, rb = np.argwhere(counts > 0)[0]
    n = int(counts[p, rb])
    bucket = int(lists[p, rb, n - 1])
    lists[p, rb, n] = bucket
    counts = counts.copy()
    counts[p, rb] = n + 1
    with pytest.raises(VerifyError) as ei:
        check_bucket_map(bell, lists, counts, block_rows=8)
    msg = str(ei.value)
    assert f"bucket={bucket}" in msg and f"rank={p}" in msg, msg


@settings(max_examples=25, deadline=None)
@given(patterns(), st.sampled_from(["standard", "partial", "full"]))
def test_p10_rejects_round_coloring_conflict(pt, strategy):
    """Merging two wire rounds re-creates the conflict the edge coloring
    exists to prevent (a rank doubly booked in one ppermute) — rejected
    naming the rank."""
    from hypothesis import assume

    from repro.core.plan import Round
    from repro.verify import VerifyError, verify_round_schedule

    pattern, topo, _ = pt
    plan = build_plan(pattern, topo, strategy)
    rounds = None
    for step in plan.steps:
        rs = color_rounds(step.messages)
        if len(rs) >= 2:
            rounds = rs
            break
    assume(rounds is not None)
    a, b = rounds[0], rounds[1]
    merged = Round(
        pairs=list(a.pairs) + list(b.pairs),
        src_idx=list(a.src_idx) + list(b.src_idx),
        dst_idx=list(a.dst_idx) + list(b.dst_idx),
    )
    with pytest.raises(VerifyError) as ei:
        verify_round_schedule([merged])
    assert "rank=" in str(ei.value)


# ---------------------------------------------------------------------------
# P11: dense collectives — every variant verifies and matches the jnp
# reference on random geometries with uneven counts
# ---------------------------------------------------------------------------


@st.composite
def dense_cases(draw):
    n_regions = draw(st.integers(1, 4))
    ppr = draw(st.integers(1, 4))
    n_procs = n_regions * ppr
    if n_procs < 2:
        n_procs, ppr = 2, 1
    coll = draw(st.sampled_from(["allreduce", "allgatherv",
                                 "reduce_scatter"]))
    seed = draw(st.integers(0, 2 ** 16))
    counts = np.random.default_rng(seed).integers(1, 13, size=n_procs)
    return coll, counts, Topology(n_procs, ppr), seed


@settings(max_examples=40, deadline=None)
@given(dense_cases())
def test_p11_dense_oracle_matches_reference(case):
    """Reference semantics computed independently of the schedule (f64
    host arithmetic — the device-vs-jnp equivalence at matching dtypes is
    asserted by check_dense_collectives.py and benchmarks.dense_comm)."""
    from repro.core import build_dense_plan
    from repro.core.dense import dense_variants
    from repro.verify import verify_dense_plan

    coll, counts, topo, seed = case
    rng = np.random.default_rng(seed + 1)
    if coll == "allgatherv":
        vals = [rng.normal(size=int(c)) for c in counts]
        ref = [np.concatenate(vals)] * topo.n_procs
    else:
        n = int(counts.sum())
        vals = [rng.normal(size=n) for _ in range(topo.n_procs)]
        total = np.sum(np.stack(vals), axis=0)
        if coll == "allreduce":
            ref = [total] * topo.n_procs
        else:
            segs = np.split(total, np.cumsum(counts)[:-1])
            ref = [segs[p] for p in range(topo.n_procs)]
    for variant in dense_variants(coll, topo):
        plan = build_dense_plan(coll, counts, topo, variant)
        verify_dense_plan(plan)
        got = plan.execute_numpy(vals)
        for g, r in zip(got, ref):
            np.testing.assert_allclose(g, r, rtol=1e-12, atol=1e-12)


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2 ** 16), st.integers(1, 64), st.integers(1, 4),
       st.integers(2, 16))
def test_p7_capacity_pack_invariants(seed, n_tokens, k, e_phys):
    import jax.numpy as jnp
    from repro.models.moe import MoEPlan, capacity_pack

    rng = np.random.default_rng(seed)
    k = min(k, e_phys)
    plan = MoEPlan(
        mode="a2a", ep_axes=("model",), ep_size=1, e_log=e_phys,
        e_phys=e_phys, e_per_dev=e_phys, top_k=k,
        capacity=int(rng.integers(1, 8)), region_axis="model",
        region_size=1, devs_per_region=1, uniq_capacity=8, cap_factor=1.0,
    )
    phys = np.stack([
        rng.choice(e_phys, size=k, replace=False) for _ in range(n_tokens)
    ]).astype(np.int32)
    slot, keep, slot_token = map(
        np.asarray, capacity_pack(jnp.asarray(phys), plan)
    )
    C = plan.capacity
    kept = slot[keep]
    # slots unique and in range
    assert len(np.unique(kept)) == len(kept)
    assert np.all(kept < e_phys * C)
    # per-expert occupancy <= capacity
    experts = kept // C
    _, counts = np.unique(experts, return_counts=True)
    assert np.all(counts <= C)
    # inverse map consistent
    tok = np.repeat(np.arange(n_tokens), k)[keep.reshape(-1)]
    assert np.all(slot_token[kept] == tok)
    # dropped slots point at sentinel
    assert np.all(slot[~keep] == e_phys * C)
