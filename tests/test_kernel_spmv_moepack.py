"""ELL SpMV (flat + column-blocked) + MoE pack/combine kernels vs oracles
(+ AMG matrices)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.amg import diffusion_2d
from repro.kernels.moe_pack import combine_rows_ref, gather_rows_ref
from repro.kernels.moe_pack.moe_pack import combine_rows, gather_rows
from repro.kernels.spmv_ell import (
    csr_to_ell,
    spmv_ell_blocked_ref,
    spmv_ell_ref,
)
from repro.kernels.spmv_ell.spmv_ell import spmv_ell, spmv_ell_blocked


@pytest.mark.parametrize("R,K,N,br", [(64, 4, 32, 16), (128, 7, 100, 32),
                                      (256, 11, 257, 64)])
@pytest.mark.parametrize("dtype", [np.float32])
def test_spmv_random(R, K, N, br, dtype):
    rng = np.random.default_rng(0)
    cols = rng.integers(0, N, size=(R, K)).astype(np.int32)
    vals = rng.normal(size=(R, K)).astype(dtype)
    x = rng.normal(size=N).astype(dtype)
    want = spmv_ell_ref(jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(x))
    got = spmv_ell(jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(x),
                   block_rows=br, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("R,br", [(257, 64), (101, 32), (7, 8)])
def test_spmv_prime_rows_padded(R, br):
    """Regression: row counts not divisible by block_rows used to assert;
    the kernel must pad the trailing block and slice the output."""
    rng = np.random.default_rng(4)
    K, N = 5, 90
    cols = rng.integers(0, N, size=(R, K)).astype(np.int32)
    vals = rng.normal(size=(R, K)).astype(np.float32)
    x = rng.normal(size=N).astype(np.float32)
    want = spmv_ell_ref(jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(x))
    got = spmv_ell(jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(x),
                   block_rows=br, interpret=True)
    assert got.shape == (R,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("R,C,K,bc,br", [(64, 3, 4, 16, 16),
                                         (97, 5, 3, 32, 32),   # prime R
                                         (128, 1, 6, 64, 32)])  # single bucket
def test_spmv_blocked_random(R, C, K, bc, br):
    """Blocked kernel vs its oracle on random bucketed layouts."""
    rng = np.random.default_rng(5)
    cols = rng.integers(0, bc, size=(R, C * K)).astype(np.int32)
    vals = rng.normal(size=(R, C * K)).astype(np.float32)
    x = rng.normal(size=C * bc).astype(np.float32)
    want = spmv_ell_blocked_ref(
        jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(x), bc
    )
    got = spmv_ell_blocked(
        jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(x),
        block_cols=bc, block_rows=br, interpret=True,
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_spmv_blocked_matches_flat_on_amg_matrix():
    """Column-bucketed packing + blocked kernel == flat kernel == host
    matvec on a real AMG operator."""
    from repro.sparse import (
        partition_csr,
        partitioned_to_ell,
        partitioned_to_ell_blocked,
    )

    A = diffusion_2d(16, 16)
    part = partition_csr(A, 1)          # single block: no ghosts
    ell = partitioned_to_ell(part, dtype=np.float32)
    bell = partitioned_to_ell_blocked(part, block_cols=64, dtype=np.float32)
    rng = np.random.default_rng(6)
    x = rng.normal(size=A.ncols).astype(np.float32)

    xf = jnp.asarray(np.concatenate([x, [0.0]]).astype(np.float32))
    flat = spmv_ell(jnp.asarray(ell.local_cols[0]),
                    jnp.asarray(ell.local_vals[0]), xf,
                    block_rows=64, interpret=True)
    xb = np.zeros(bell.x_len, dtype=np.float32)
    xb[: A.ncols] = x
    blocked = spmv_ell_blocked(
        jnp.asarray(bell.cols[0]), jnp.asarray(bell.vals[0]),
        jnp.asarray(xb), block_cols=bell.block_cols, block_rows=64,
        interpret=True,
    )
    want = A.matvec(x.astype(np.float64))
    np.testing.assert_allclose(np.asarray(flat)[: A.nrows], want,
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(blocked)[: A.nrows],
                               np.asarray(flat)[: A.nrows],
                               rtol=1e-5, atol=1e-6)


def test_spmv_amg_matrix():
    """End-to-end on a real AMG matrix via csr_to_ell."""
    A = diffusion_2d(16, 16)
    rng = np.random.default_rng(1)
    x = rng.normal(size=A.ncols).astype(np.float32)
    # pad slot: one extra zero entry at index A.ncols
    cols, vals = csr_to_ell(A.indptr, A.indices, A.data, A.nrows,
                            pad_col=A.ncols, block_rows=64)
    xp = np.concatenate([x, [0.0]]).astype(np.float32)
    got = spmv_ell(jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(xp),
                   block_rows=64, interpret=True)
    want = A.matvec(x.astype(np.float64))
    np.testing.assert_allclose(np.asarray(got)[: A.nrows], want,
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("N,D,M,bm,bd", [(32, 16, 64, 16, 16),
                                         (100, 64, 128, 32, 32),
                                         (57, 128, 96, 48, 64)])
def test_gather_rows(N, D, M, bm, bd):
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(N, D)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, N, size=M).astype(np.int32))
    want = gather_rows_ref(x, idx)
    got = gather_rows(x, idx, block_m=bm, block_d=bd, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("K", [1, 2, 6])
def test_combine_rows(K):
    rng = np.random.default_rng(3)
    N, D, T = 64, 32, 48
    buf = jnp.asarray(rng.normal(size=(N, D)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, N, size=(T, K)).astype(np.int32))
    w = jnp.asarray(rng.random((T, K)).astype(np.float32))
    want = combine_rows_ref(buf, idx, w)
    got = combine_rows(buf, idx, w, block_m=16, block_d=16, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
