"""ELL SpMV + MoE pack/combine kernels vs oracles (+ AMG matrices)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.amg import diffusion_2d
from repro.kernels.moe_pack import combine_rows_ref, gather_rows_ref
from repro.kernels.moe_pack.moe_pack import combine_rows, gather_rows
from repro.kernels.spmv_ell import csr_to_ell, spmv_ell_ref
from repro.kernels.spmv_ell.spmv_ell import spmv_ell


@pytest.mark.parametrize("R,K,N,br", [(64, 4, 32, 16), (128, 7, 100, 32),
                                      (256, 11, 257, 64)])
@pytest.mark.parametrize("dtype", [np.float32])
def test_spmv_random(R, K, N, br, dtype):
    rng = np.random.default_rng(0)
    cols = rng.integers(0, N, size=(R, K)).astype(np.int32)
    vals = rng.normal(size=(R, K)).astype(dtype)
    x = rng.normal(size=N).astype(dtype)
    want = spmv_ell_ref(jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(x))
    got = spmv_ell(jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(x),
                   block_rows=br, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_spmv_amg_matrix():
    """End-to-end on a real AMG matrix via csr_to_ell."""
    A = diffusion_2d(16, 16)
    rng = np.random.default_rng(1)
    x = rng.normal(size=A.ncols).astype(np.float32)
    # pad slot: one extra zero entry at index A.ncols
    cols, vals = csr_to_ell(A.indptr, A.indices, A.data, A.nrows,
                            pad_col=A.ncols, block_rows=64)
    xp = np.concatenate([x, [0.0]]).astype(np.float32)
    got = spmv_ell(jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(xp),
                   block_rows=64, interpret=True)
    want = A.matvec(x.astype(np.float64))
    np.testing.assert_allclose(np.asarray(got)[: A.nrows], want,
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("N,D,M,bm,bd", [(32, 16, 64, 16, 16),
                                         (100, 64, 128, 32, 32),
                                         (57, 128, 96, 48, 64)])
def test_gather_rows(N, D, M, bm, bd):
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(N, D)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, N, size=M).astype(np.int32))
    want = gather_rows_ref(x, idx)
    got = gather_rows(x, idx, block_m=bm, block_d=bd, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("K", [1, 2, 6])
def test_combine_rows(K):
    rng = np.random.default_rng(3)
    N, D, T = 64, 32, 48
    buf = jnp.asarray(rng.normal(size=(N, D)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, N, size=(T, K)).astype(np.int32))
    w = jnp.asarray(rng.random((T, K)).astype(np.float32))
    want = combine_rows_ref(buf, idx, w)
    got = combine_rows(buf, idx, w, block_m=16, block_d=16, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
