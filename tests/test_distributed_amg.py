"""Integration: device-resident distributed AMG on 8 virtual host devices.

The heavy check (jitted V-cycle vs host solver, strategy selection, plan
cache) runs in a subprocess with XLA_FLAGS set at spawn so the main pytest
process keeps its device configuration.  Single-device sanity of the same
machinery (rect partition, ELL conversion) lives in test_sparse_device.py.
"""
import os
import pathlib
import subprocess
import sys

PROGS = pathlib.Path(__file__).parent / "multidevice_progs"
SRC = pathlib.Path(__file__).resolve().parents[1] / "src"


def run_prog(name: str, timeout=600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(SRC) + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    out = subprocess.run(
        [sys.executable, str(PROGS / name)],
        env=env,
        capture_output=True,
        text=True,
        timeout=timeout,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_distributed_amg_vcycle_matches_host():
    out = run_prog("check_distributed_amg.py")
    assert "ALL_OK" in out
    assert "residual history OK" in out
    assert "plan cache OK" in out
    # Section-5 selector: fine level standard, >=2 strategies over levels
    assert "A=standard" in out
    assert "A=full" in out or "A=partial" in out


def test_blocked_spmv_hierarchy_matches_host():
    """Column-blocked kernel end to end: forced-blocked and auto-selected
    (fine blocked / coarse flat) hierarchies both track the host solver."""
    out = run_prog("check_blocked_spmv.py")
    assert "ALL_OK" in out
    assert "forced-blocked residual history OK" in out
    assert "auto mixed-variant residual history OK" in out
    assert "kern=blocked" in out and "kern=flat" in out


def test_overlap_spmv_hierarchy_matches_host():
    """Exchange/compute-overlapped schedule end to end: forced-overlap
    hierarchies (flat + blocked kernels) track the host solver, auto
    records its per-level decision, and measured SpMV timings are tagged
    non-pure for calibration."""
    out = run_prog("check_overlap_spmv.py")
    assert "ALL_OK" in out
    assert "forced-overlap flat residual history OK" in out
    assert "forced-overlap blocked residual history OK" in out
    assert "auto-overlap residual history OK" in out
    assert "ov=off" in out
    assert "measure_spmv_seconds OK" in out
