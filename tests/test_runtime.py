"""Fault-tolerance runtime: checkpoint roundtrip/corruption/gc, elastic
mesh choice, straggler detection, end-to-end crash-restart continuity."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced
from repro.models import Model
from repro.runtime import (
    CheckpointManager,
    HeartbeatMonitor,
    MeshRequirements,
    StragglerDetector,
    choose_mesh_shape,
    latest_step,
    rebalance_shards,
    restore_checkpoint,
    save_checkpoint,
)
from repro.train import (
    AdamWConfig,
    DataConfig,
    TokenStream,
    TrainerConfig,
    make_train_state,
    make_train_step,
)


def sample_tree():
    rng = np.random.default_rng(0)
    return {
        "a": jnp.asarray(rng.normal(size=(4, 5)).astype(np.float32)),
        "b": {"c": jnp.asarray(rng.integers(0, 9, size=(3,)).astype(np.int32)),
              "d": jnp.asarray(rng.normal(size=(2, 2)).astype(np.float32)
                               ).astype(jnp.bfloat16)},
    }


def test_checkpoint_roundtrip(tmp_path):
    tree = sample_tree()
    save_checkpoint(str(tmp_path), 7, tree)
    assert latest_step(str(tmp_path)) == 7
    step, got = restore_checkpoint(str(tmp_path), tree)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_detects_corruption(tmp_path):
    tree = sample_tree()
    path = save_checkpoint(str(tmp_path), 1, tree)
    victim = os.path.join(path, "leaf_00000.bin")
    raw = bytearray(open(victim, "rb").read())
    raw[0] ^= 0xFF
    open(victim, "wb").write(bytes(raw))
    with pytest.raises(IOError, match="checksum"):
        restore_checkpoint(str(tmp_path), tree)


def test_checkpoint_manager_keeps_k_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    tree = sample_tree()
    for s in range(5):
        mgr.save(s, tree)
    mgr.wait()
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_000000003", "step_000000004"]
    step, _ = mgr.restore_latest(tree)
    assert step == 4


def test_checkpoint_structure_mismatch(tmp_path):
    tree = sample_tree()
    save_checkpoint(str(tmp_path), 0, tree)
    bad = {"a": tree["a"]}
    with pytest.raises(ValueError, match="leaves"):
        restore_checkpoint(str(tmp_path), bad)


def test_choose_mesh_shape_shrinks_gracefully():
    req = MeshRequirements(model_divisors=48, prefer_model=16)
    # full two pods
    shape, axes = choose_mesh_shape(512, req)
    assert shape == (2, 16, 16) and axes == ("pod", "data", "model")
    # lost a host: 504 devices -> keep TP=16? 504 % 16 != 0 -> TP 8
    shape, axes = choose_mesh_shape(504, req)
    assert np.prod(shape) == 504
    # tiny survivor set
    shape, axes = choose_mesh_shape(8, req)
    assert np.prod(shape) == 8
    # model degree must divide heads
    req2 = MeshRequirements(model_divisors=14, prefer_model=16)
    shape, _ = choose_mesh_shape(64, req2)
    assert shape[-1] in (1, 2)  # 14 = 2*7 -> largest pow2 divisor is 2


def test_heartbeat_monitor():
    hb = HeartbeatMonitor(n_hosts=3, timeout_steps=2)
    for step in range(4):
        hb.beat(0)
        hb.beat(1)
        if step < 1:
            hb.beat(2)
        dead = hb.advance()
    assert dead == [2]


def test_straggler_detector_and_rebalance():
    det = StragglerDetector(4)
    flagged = []
    for _ in range(10):
        times = np.array([1.0, 1.0, 1.0, 2.2])
        flagged = det.update(times)
    assert flagged == [3]
    counts = rebalance_shards(det.times, total_rows=64)
    assert counts.sum() == 64
    assert counts[3] < counts[0]


def test_crash_restart_training_continuity(tmp_path):
    """Train 6 steps; 'crash' after step 3; restart from checkpoint and
    verify steps 4-6 produce bitwise-identical losses."""
    cfg0 = reduced("qwen2-0.5b")
    cfg = cfg0.__class__(**{**cfg0.__dict__, "dtype": jnp.float32,
                            "vocab": 64})
    model = Model(cfg, remat=False)
    tcfg = TrainerConfig(opt=AdamWConfig(lr=1e-3, warmup_steps=2,
                                         total_steps=10))
    data = TokenStream(DataConfig(vocab=64, seq_len=16, global_batch=2))
    step_fn = jax.jit(make_train_step(model, tcfg))

    def run(state, start, end, mgr=None):
        losses = []
        for i in range(start, end):
            batch = jax.tree.map(jnp.asarray, data.global_batch_at(i))
            state, m = step_fn(state, batch)
            losses.append(float(m["loss"]))
            if mgr is not None:
                mgr.save(i + 1, state)
        return state, losses

    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    state = make_train_state(model, tcfg, seed=0)
    state, l_a = run(state, 0, 3, mgr)
    _, l_b_truth = run(state, 3, 6)

    # restart in a "new process": fresh state template, restore
    template = make_train_state(model, tcfg, seed=1)  # different init
    step_restored, restored = mgr.restore_latest(template)
    assert step_restored == 3
    restored = jax.tree.map(jnp.asarray, restored)
    _, l_b = run(restored, 3, 6)
    np.testing.assert_array_equal(l_b, l_b_truth)
