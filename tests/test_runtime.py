"""Fault-tolerance runtime: checkpoint roundtrip/corruption/gc, elastic
mesh choice, straggler detection, end-to-end crash-restart continuity."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import reduced
from repro.models import Model
from repro.runtime import (
    CheckpointManager,
    ElasticController,
    HeartbeatMonitor,
    MeshRequirements,
    StragglerConfig,
    StragglerDetector,
    choose_mesh_shape,
    latest_step,
    rebalance_shards,
    reshard_state,
    restore_checkpoint,
    save_checkpoint,
)
from repro.train import (
    AdamWConfig,
    DataConfig,
    TokenStream,
    TrainerConfig,
    make_train_state,
    make_train_step,
)


def sample_tree():
    rng = np.random.default_rng(0)
    return {
        "a": jnp.asarray(rng.normal(size=(4, 5)).astype(np.float32)),
        "b": {"c": jnp.asarray(rng.integers(0, 9, size=(3,)).astype(np.int32)),
              "d": jnp.asarray(rng.normal(size=(2, 2)).astype(np.float32)
                               ).astype(jnp.bfloat16)},
    }


def test_checkpoint_roundtrip(tmp_path):
    tree = sample_tree()
    save_checkpoint(str(tmp_path), 7, tree)
    assert latest_step(str(tmp_path)) == 7
    step, got = restore_checkpoint(str(tmp_path), tree)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_detects_corruption(tmp_path):
    tree = sample_tree()
    path = save_checkpoint(str(tmp_path), 1, tree)
    victim = os.path.join(path, "leaf_00000.bin")
    raw = bytearray(open(victim, "rb").read())
    raw[0] ^= 0xFF
    open(victim, "wb").write(bytes(raw))
    with pytest.raises(IOError, match="checksum"):
        restore_checkpoint(str(tmp_path), tree)


def test_checkpoint_manager_keeps_k_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    tree = sample_tree()
    for s in range(5):
        mgr.save(s, tree)
    mgr.wait()
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_000000003", "step_000000004"]
    step, _ = mgr.restore_latest(tree)
    assert step == 4


def test_checkpoint_structure_mismatch(tmp_path):
    tree = sample_tree()
    save_checkpoint(str(tmp_path), 0, tree)
    bad = {"a": tree["a"]}
    with pytest.raises(ValueError, match="leaves"):
        restore_checkpoint(str(tmp_path), bad)


def test_choose_mesh_shape_shrinks_gracefully():
    req = MeshRequirements(model_divisors=48, prefer_model=16)
    # full two pods
    shape, axes = choose_mesh_shape(512, req)
    assert shape == (2, 16, 16) and axes == ("pod", "data", "model")
    # lost a host: 504 devices -> keep TP=16? 504 % 16 != 0 -> TP 8
    shape, axes = choose_mesh_shape(504, req)
    assert np.prod(shape) == 504
    # tiny survivor set
    shape, axes = choose_mesh_shape(8, req)
    assert np.prod(shape) == 8
    # model degree must divide heads
    req2 = MeshRequirements(model_divisors=14, prefer_model=16)
    shape, _ = choose_mesh_shape(64, req2)
    assert shape[-1] in (1, 2)  # 14 = 2*7 -> largest pow2 divisor is 2


def test_heartbeat_monitor():
    hb = HeartbeatMonitor(n_hosts=3, timeout_steps=2)
    for step in range(4):
        hb.beat(0)
        hb.beat(1)
        if step < 1:
            hb.beat(2)
        dead = hb.advance()
    assert dead == [2]


def test_straggler_detector_and_rebalance():
    det = StragglerDetector(4)
    flagged = []
    for _ in range(10):
        times = np.array([1.0, 1.0, 1.0, 2.2])
        flagged = det.update(times)
    assert flagged == [3]
    counts = rebalance_shards(det.times, total_rows=64)
    assert counts.sum() == 64
    assert counts[3] < counts[0]


def test_crash_restart_training_continuity(tmp_path):
    """Train 6 steps; 'crash' after step 3; restart from checkpoint and
    verify steps 4-6 produce bitwise-identical losses."""
    cfg0 = reduced("qwen2-0.5b")
    cfg = cfg0.__class__(**{**cfg0.__dict__, "dtype": jnp.float32,
                            "vocab": 64})
    model = Model(cfg, remat=False)
    tcfg = TrainerConfig(opt=AdamWConfig(lr=1e-3, warmup_steps=2,
                                         total_steps=10))
    data = TokenStream(DataConfig(vocab=64, seq_len=16, global_batch=2))
    step_fn = jax.jit(make_train_step(model, tcfg))

    def run(state, start, end, mgr=None):
        losses = []
        for i in range(start, end):
            batch = jax.tree.map(jnp.asarray, data.global_batch_at(i))
            state, m = step_fn(state, batch)
            losses.append(float(m["loss"]))
            if mgr is not None:
                mgr.save(i + 1, state)
        return state, losses

    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    state = make_train_state(model, tcfg, seed=0)
    state, l_a = run(state, 0, 3, mgr)
    _, l_b_truth = run(state, 3, 6)

    # restart in a "new process": fresh state template, restore
    template = make_train_state(model, tcfg, seed=1)  # different init
    step_restored, restored = mgr.restore_latest(template)
    assert step_restored == 3
    restored = jax.tree.map(jnp.asarray, restored)
    _, l_b = run(restored, 3, 6)
    np.testing.assert_array_equal(l_b, l_b_truth)


# --------------------------------------------------------------------------
# elastic/straggler edge cases (the single-process half of test_elastic.py)
# --------------------------------------------------------------------------


def test_rebalance_single_host_is_identity():
    """One host has nobody to shed rows to: the rebalance degenerates to
    the identity [total_rows] no matter the weight."""
    for w in (1e-9, 0.01, 3.7):
        counts = rebalance_shards(np.array([w]), total_rows=64)
        assert counts.tolist() == [64]
    # and a uniform fleet stays (near-)uniform
    counts = rebalance_shards(np.full(4, 0.02), total_rows=64)
    assert counts.sum() == 64 and counts.max() - counts.min() <= 1


def test_straggler_all_slow_is_not_flagged():
    """A uniformly degraded fleet is a calibration problem, not an
    eviction: when every host trips the threshold the update returns []."""
    det = StragglerDetector(4, StragglerConfig(threshold=0.5, patience=2))
    flagged = []
    for _ in range(6):
        # every host above 0.5x the median -> the whole fleet is "slow"
        flagged = det.update(np.full(4, 0.02))
    assert (det.flags >= det.cfg.patience).all()
    assert flagged == []


def test_straggler_flapping_hysteresis():
    """A host that flaps (alternates slow/normal) never accumulates
    ``patience`` consecutive flags; after a mitigation, the detector reset
    + controller cooldown keep the handled episode from storming."""
    det = StragglerDetector(4, StragglerConfig(ewma=1.0, patience=3))
    base = np.full(4, 0.01)
    for t in range(12):
        times = base.copy()
        if t % 2 == 0:
            times[1] *= 3.0          # flaps: slow only every other step
        assert det.update(times) == []

    # persistent slowness DOES trip it ...
    ctrl = ElasticController(
        4, straggler_cfg=StragglerConfig(ewma=1.0, patience=3), cooldown=5)
    slow = base.copy()
    slow[1] *= 3.0
    flagged = []
    for _ in range(3):
        flagged = ctrl.observe_step_times(slow)
    assert flagged == [1]
    # ... and after the mitigation's reset + cooldown, a host that went
    # back to normal never re-triggers: the handled episode is closed
    ctrl.detector.reset(reseed_times=True)
    ctrl._cooldown_left = ctrl.cooldown
    for _ in range(ctrl.cooldown + 6):
        assert ctrl.observe_step_times(base) == []
    # whereas a host that is STILL slow post-mitigation re-flags only
    # once the cooldown has fully drained (escalation, not a storm)
    ctrl.detector.reset(reseed_times=True)
    ctrl._cooldown_left = ctrl.cooldown
    for _ in range(ctrl.cooldown):
        assert ctrl.observe_step_times(slow) == []
    assert ctrl.observe_step_times(slow) == [1]


def test_reshard_state_preserves_dtype_and_shape():
    """reshard_state is placement-only: dtypes/shapes/values survive a
    move onto a smaller mesh exactly (including bf16 and int leaves)."""
    devs = jax.devices()
    big = Mesh(np.array(devs), ("data",))
    small = Mesh(np.array(devs[:1]), ("data",))
    rng = np.random.default_rng(3)
    state = {
        "w": jnp.asarray(rng.normal(size=(4, 6)).astype(np.float32)),
        "h": jnp.asarray(rng.normal(size=(2, 2)).astype(np.float32)
                         ).astype(jnp.bfloat16),
        "n": jnp.asarray(rng.integers(0, 99, size=(5,)).astype(np.int32)),
    }
    specs = {"w": P(), "h": P(), "n": P()}
    on_big = reshard_state(state, specs, big)
    on_small = reshard_state(on_big, specs, small)
    for k in state:
        assert on_small[k].dtype == state[k].dtype, k
        assert on_small[k].shape == state[k].shape, k
        np.testing.assert_array_equal(
            np.asarray(on_small[k].astype(jnp.float32)),
            np.asarray(state[k].astype(jnp.float32)),
        )


def test_restore_latest_ignores_partial_async_save(tmp_path):
    """A crash mid-async-save leaves a step_N.tmp-* directory; LATEST,
    restore and gc must all treat it as invisible and fall back to the
    newest complete checkpoint."""
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    tree = sample_tree()
    mgr.save(1, tree)
    mgr.wait()
    # simulate a writer that died mid-save of step 2: partial tmp dir,
    # some leaves on disk, no manifest rename, LATEST untouched
    partial = os.path.join(str(tmp_path), "step_000000002.tmp-4242-7")
    os.makedirs(partial)
    open(os.path.join(partial, "leaf_00000.bin"), "wb").write(b"\x00" * 16)
    assert latest_step(str(tmp_path)) == 1
    step, got = mgr.restore_latest(tree)
    assert step == 1
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    # the next successful save gc-sweeps by step number and must not trip
    # over (or delete) the foreign tmp dir either
    mgr.save(3, tree)
    mgr.wait()
    assert latest_step(str(tmp_path)) == 3
    assert os.path.isdir(partial)
