"""Roofline derivation utilities + shape applicability rules."""
import numpy as np
import pytest

from repro.configs import ARCHS, get
from repro.configs.shapes import SHAPES, applicable, skip_reason
from repro.launch.roofline import (
    HBM_BW,
    PEAK_FLOPS,
    active_param_count,
    analytic_attention_flops,
    collective_bytes_from_hlo,
    model_flops,
    roofline_terms,
)


def test_collective_bytes_parser():
    hlo = """
  %ag = bf16[128,256]{1,0} all-gather(bf16[8,256]{1,0} %x), dimensions={0}
  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %y), to_apply=%add
  %ars = (f32[512]{0}, f32[512]{0}) all-reduce-start(f32[512]{0} %z)
  %a2a = f32[64,32]{1,0} all-to-all(f32[64,32]{1,0} %w), dimensions={0}
  %cp = u8[100]{0} collective-permute(u8[100]{0} %v)
  %dot = f32[64,64]{1,0} dot(f32[64,64]{1,0} %a, f32[64,64]{1,0} %b)
"""
    got = collective_bytes_from_hlo(hlo)
    assert got["all-gather"] == 128 * 256 * 2
    assert got["all-reduce"] == 1024 * 4 + 2 * 512 * 4
    assert got["all-to-all"] == 64 * 32 * 4
    assert got["collective-permute"] == 100
    assert got["reduce-scatter"] == 0


def test_roofline_terms_bottleneck():
    t = roofline_terms(
        flops_dev=PEAK_FLOPS,          # 1 s compute
        hbm_bytes_dev=HBM_BW * 2.0,    # 2 s memory
        collective_bytes_dev=0.0,
        chips=256,
    )
    assert t["bottleneck"] == "memory"
    assert t["step_s_lower_bound"] == pytest.approx(2.0)


def test_active_params_moe_less_than_total():
    cfg = get("mixtral-8x7b")
    assert active_param_count(cfg) < cfg.param_count()
    # top-2 of 8 experts: active ~ total * (2/8) on the expert share
    dense = get("qwen2-0.5b")
    assert active_param_count(dense) == dense.param_count()


def test_model_flops_conventions():
    cfg = get("qwen2-0.5b")
    n = cfg.param_count()
    assert model_flops(cfg, "train", 1000) == pytest.approx(6.0 * n * 1000)
    assert model_flops(cfg, "decode", 10) == pytest.approx(2.0 * n * 10)


def test_attention_flops_window_clips():
    cfg = get("mixtral-8x7b")  # SWA 4096
    full = analytic_attention_flops(cfg, B=1, Tq=32768, Tk=32768)
    cfg2 = get("nemotron-4-15b")  # full attention
    causal = analytic_attention_flops(cfg2, B=1, Tq=32768, Tk=32768)
    # windowed layers see at most 4096 keys -> far fewer flops per head
    per_head_w = full / (cfg.n_layers * cfg.n_heads * cfg.head_dim)
    per_head_f = causal / (cfg2.n_layers * cfg2.n_heads * cfg2.head_dim)
    assert per_head_w < per_head_f


def test_shape_applicability_rules():
    # 40 cells total; long_500k skipped exactly for pure full-attn archs
    skips = [(a, s) for a in ARCHS for s in SHAPES if not applicable(a, s)]
    assert all(s == "long_500k" for _, s in skips)
    assert {a for a, _ in skips} == {
        "nemotron-4-15b", "qwen1.5-0.5b", "qwen2-0.5b", "qwen2-vl-2b",
        "deepseek-v2-lite-16b", "seamless-m4t-medium",
    }
    assert applicable("mamba2-780m", "long_500k")
    assert applicable("mixtral-8x7b", "long_500k")  # sliding window
    assert skip_reason("nemotron-4-15b", "long_500k") is not None
    assert len(list(SHAPES)) * len(ARCHS) == 40
