"""End-to-end driver (the paper's workload): rotated anisotropic diffusion
-> classical AMG -> solve, with every level's SpMV halo exchange executed
through locality-aware persistent neighborhood collectives, exactly like
the Hypre + MPI Advance integration the paper evaluates.

    PYTHONPATH=src python examples/amg_solve.py --rows 65536 --procs 256
    PYTHONPATH=src python examples/amg_solve.py --rows 524288 --procs 2048  # paper scale
"""
import argparse
import time

import numpy as np

from repro.amg import build_hierarchy, diffusion_2d
from repro.amg.hierarchy import chebyshev, v_cycle
from repro.core import LASSEN, NeighborAlltoallV, Topology
from repro.sparse import distributed_spmv_numpy, partition_csr


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=65_536)
    ap.add_argument("--procs", type=int, default=256)
    ap.add_argument("--procs-per-region", type=int, default=16)
    ap.add_argument("--strategy", default="auto",
                    choices=["auto", "standard", "partial", "full"])
    ap.add_argument("--iters", type=int, default=30)
    args = ap.parse_args()

    nx = 1 << int(np.ceil(np.log2(np.sqrt(args.rows))))
    ny = args.rows // nx
    print(f"[amg] assembling {ny}x{nx} rotated anisotropic diffusion "
          f"(theta=45deg, eps=1e-3)")
    A = diffusion_2d(ny, nx)
    t0 = time.time()
    h = build_hierarchy(A)
    print(f"[amg] setup {time.time() - t0:.1f}s\n{h.describe()}")

    topo = Topology(args.procs, min(args.procs_per_region, args.procs))
    print(f"\n[comm] {args.procs} processes in {topo.n_regions} regions; "
          f"persistent neighborhood collectives per level "
          f"(strategy={args.strategy}):")
    colls = []
    parts = []
    total_modeled = {"standard": 0.0, "chosen": 0.0}
    for lvl, level in enumerate(h.levels):
        if level.A.nrows < args.procs:
            break
        part = partition_csr(level.A, args.procs)
        coll = NeighborAlltoallV.init(part.pattern, topo, args.strategy)
        parts.append(part)
        colls.append(coll)
        from repro.core import build_plan, plan_time
        std = plan_time(build_plan(part.pattern, topo, "standard"), LASSEN)
        mine = coll.modeled_time(LASSEN)
        total_modeled["standard"] += std
        total_modeled["chosen"] += min(std, mine)
        t = coll.plan.stats.totals()
        print(f"  L{lvl}: strategy={coll.strategy:8s} "
              f"inter_msgs={t['inter_msgs']:6d} "
              f"inter_bytes={t['inter_bytes']:9d} "
              f"modeled={mine * 1e6:7.1f}us (standard {std * 1e6:7.1f}us)")
    sp = total_modeled["standard"] / max(total_modeled["chosen"], 1e-12)
    print(f"[comm] modeled per-iteration speedup over standard: {sp:.2f}x")

    # solve, with the fine-level SpMV residual computed through the
    # distributed halo-exchange path (verifying the collective inside the
    # solver loop, Hypre-style)
    rng = np.random.default_rng(0)
    b = rng.normal(size=A.nrows)
    x = np.zeros_like(b)
    nb = np.linalg.norm(b)
    t0 = time.time()
    for it in range(args.iters):
        r_dist = b - distributed_spmv_numpy(parts[0], colls[0].plan, x)
        rn = np.linalg.norm(r_dist) / nb
        if it % 5 == 0 or rn < 1e-8:
            print(f"[solve] iter {it:3d} rel_res={rn:.3e}")
        if rn < 1e-8:
            break
        x = x + v_cycle(h, r_dist)
    print(f"[solve] {time.time() - t0:.1f}s; final rel_res="
          f"{np.linalg.norm(b - A.matvec(x)) / nb:.3e}")


if __name__ == "__main__":
    main()
