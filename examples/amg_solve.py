"""End-to-end driver (the paper's workload): rotated anisotropic diffusion
-> classical AMG -> device-resident distributed solve, with every level's
halo exchange executed through a locality-aware persistent neighborhood
collective — the Hypre + MPI Advance integration the paper evaluates, but
running as one jitted shard_map program.

Two communication sections are printed:

* *modeled* per-level times at the requested paper-scale process count
  (``--procs``, e.g. 2048) — exact plan message counts/bytes, max-rate model;
* *measured* device exchange + a full device V-cycle solve on the local
  mesh (``jax.device_count()`` processes) validated against the host solver.

    PYTHONPATH=src python examples/amg_solve.py --rows 65536 --procs 256
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/amg_solve.py --rows 16384
"""
import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=65_536)
    ap.add_argument("--procs", type=int, default=256,
                    help="modeled (paper-scale) process count")
    ap.add_argument("--procs-per-region", type=int, default=16)
    ap.add_argument("--strategy", default="auto",
                    choices=["auto", "standard", "partial", "full"])
    ap.add_argument("--iters", type=int, default=30)
    ap.add_argument("--setup", default="host",
                    choices=["host", "distributed"],
                    help="host: lower the host-built hierarchy; distributed: "
                    "build the hierarchy end-to-end from the partitioned "
                    "fine matrix (PMIS/interpolation/Galerkin SpGEMM over "
                    "sparse dynamic data exchanges) — no rank ever holds a "
                    "global operator")
    ap.add_argument("--no-device", action="store_true",
                    help="skip the device-resident solve")
    ap.add_argument("--spmv-variant", default="auto",
                    choices=["auto", "flat", "blocked"],
                    help="per-level SpMV kernel layout (auto: modeled-VMEM "
                    "selection; see also REPRO_SPMV_VMEM_LIMIT_BYTES)")
    args = ap.parse_args()

    import jax

    jax.config.update("jax_enable_x64", True)

    from repro.amg import DistributedHierarchy, build_hierarchy, diffusion_2d, \
        partition_fine_matrix, solve
    from repro.core import LASSEN, NeighborAlltoallV, Topology, build_plan, \
        default_plan_cache, plan_time
    from repro.sparse import partition_csr

    nx = 1 << int(np.ceil(np.log2(np.sqrt(args.rows))))
    ny = args.rows // nx
    print(f"[amg] assembling {ny}x{nx} rotated anisotropic diffusion "
          f"(theta=45deg, eps=1e-3)")
    A = diffusion_2d(ny, nx)
    t0 = time.time()
    h = build_hierarchy(A)
    print(f"[amg] setup {time.time() - t0:.1f}s\n{h.describe()}")

    # ---- modeled section: paper-scale process count ------------------------
    topo = Topology(args.procs, min(args.procs_per_region, args.procs))
    print(f"\n[comm/modeled] {args.procs} processes in {topo.n_regions} "
          f"regions; persistent neighborhood collectives per level "
          f"(strategy={args.strategy}):")
    total_modeled = {"standard": 0.0, "chosen": 0.0}
    for lvl, level in enumerate(h.levels):
        if level.A.nrows < args.procs:
            break
        part = partition_csr(level.A, args.procs)
        coll = NeighborAlltoallV.init(part.pattern, topo, args.strategy,
                                      params=LASSEN)
        std = plan_time(build_plan(part.pattern, topo, "standard"), LASSEN)
        mine = coll.modeled_time(LASSEN)
        total_modeled["standard"] += std
        total_modeled["chosen"] += min(std, mine)
        t = coll.plan.stats.totals()
        print(f"  L{lvl}: strategy={coll.strategy:8s} "
              f"inter_msgs={t['inter_msgs']:6d} "
              f"inter_bytes={t['inter_bytes']:9d} "
              f"modeled={mine * 1e6:7.1f}us (standard {std * 1e6:7.1f}us)")
    sp = total_modeled["standard"] / max(total_modeled["chosen"], 1e-12)
    print(f"[comm/modeled] per-iteration speedup over standard: {sp:.2f}x")

    if args.no_device:
        return

    # ---- measured section: device-resident distributed solve ---------------
    n_dev = jax.device_count()
    mesh = jax.make_mesh((n_dev,), ("proc",))
    print(f"\n[device] {n_dev} device(s); setting up distributed hierarchy "
          f"(persistent init through the plan cache, {args.setup} setup)...")
    cache = default_plan_cache()
    t0 = time.time()
    if args.setup == "distributed":
        # end-to-end distributed setup: each rank owns a row block of A and
        # coarsens it in place — strength/PMIS/interp with halo'd rounds,
        # R = P^T and the Galerkin R*A*P over sparse dynamic data exchanges
        blocks, off = partition_fine_matrix(A, n_dev)
        dh = DistributedHierarchy.setup_partitioned(
            blocks, off, mesh, strategy=args.strategy, cache=cache,
            spmv_variant=args.spmv_variant,
        )
        print(f"[device] setup {time.time() - t0:.1f}s")
        print(dh.setup_info.describe())
    else:
        dh = DistributedHierarchy.setup(
            h, mesh, strategy=args.strategy, cache=cache,
            spmv_variant=args.spmv_variant,
        )
        print(f"[device] setup {time.time() - t0:.1f}s")
    print(dh.describe())
    for lvl, op, strat, rep in dh.selection_table():
        if op == "A" and rep:
            print(f"  L{lvl} {op}: {rep}")
    for lvl, op, variant, ov, rep in dh.kernel_table():
        if op == "A" and rep:
            print(f"  L{lvl} {op}: {rep}")
    if n_dev > 1:
        print("[device] measured per-level exchange (jitted executor):")
        for lvl, strat, secs in dh.measure_exchange_seconds():
            print(f"  L{lvl}: strategy={strat:8s} "
                  f"measured={secs * 1e6:8.1f}us")

    rng = np.random.default_rng(0)
    b = rng.normal(size=A.nrows)
    t0 = time.time()
    x, hist = dh.solve(b, tol=1e-8, max_iters=args.iters)
    dt = time.time() - t0
    for it in range(0, len(hist), 5):
        print(f"[solve] iter {it:3d} rel_res={hist[it]:.3e}")
    print(f"[solve] device {dt:.1f}s, {len(hist)} iters, final rel_res="
          f"{np.linalg.norm(b - A.matvec(x)) / np.linalg.norm(b):.3e}")

    # cross-check against the host solver
    x_h, hist_h = solve(h, b, tol=1e-8, max_iters=args.iters)
    drift = max(
        abs(d - hh) / max(hh, 1e-300) for d, hh in zip(hist, hist_h)
    )
    print(f"[solve] host cross-check: {len(hist_h)} iters, max history "
          f"drift {drift:.2e} (plan cache: {cache.stats()})")


if __name__ == "__main__":
    main()
