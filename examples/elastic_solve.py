"""Elastic, straggler-tolerant distributed solving — the runtime layer
wired into the planning stack, narrated end to end.

Three acts on one Poisson problem:

1. **Shrink mid-solve**: run k V-cycle iterations on the full device set,
   drop half the devices (as a heartbeat timeout would), repartition the
   whole hierarchy through ``DistributedHierarchy.repartition`` and warm-
   start the remaining iterations from the mid-solve iterate.  The printed
   ``ResizeEvent`` shows the re-plan wall time and the plan-cache delta.
2. **Grow back**: repartition to the original device count through the
   SAME plan cache — every pattern for the seen geometry survives, so the
   event reports ``plan misses=0`` (a warm resize: re-planning cost is the
   paper's init amortization argument applied to failure recovery).
3. **Straggler**: inject a 3x-slow host into the per-host step-seconds an
   ``ElasticController`` observes; after ``patience`` consecutive flags it
   rebalances the row blocks inversely to the measured EWMA times and
   re-fits ``MachineParams`` from the recorded exchange trace, so the
   rebuilt hierarchy's Section-5 transport selection runs under the
   degraded (measured) rates.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/elastic_solve.py
"""
import argparse
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=28 * 28)
    ap.add_argument("--iters", type=int, default=8,
                    help="total V-cycle iterations (half before the shrink)")
    ap.add_argument("--slow-host", type=int, default=2)
    ap.add_argument("--slow-factor", type=float, default=3.0)
    args = ap.parse_args()

    import jax

    jax.config.update("jax_enable_x64", True)

    from repro.amg import DistributedHierarchy, build_hierarchy, diffusion_2d
    from repro.core import default_plan_cache
    from repro.profile import TraceRecorder
    from repro.runtime import ElasticController, StragglerConfig

    n_dev = jax.device_count()
    assert n_dev >= 2, "run with XLA_FLAGS=--xla_force_host_platform_device_count=8"
    nx = int(np.sqrt(args.rows))
    A = diffusion_2d(nx, nx)
    h = build_hierarchy(A)
    cache = default_plan_cache()
    rng = np.random.default_rng(0)
    b = rng.normal(size=A.nrows)

    def mesh_n(n):
        return jax.sharding.Mesh(np.array(jax.devices()[:n]), ("proc",))

    # ---- act 1: shrink mid-solve -----------------------------------------
    print(f"[elastic] solving on {n_dev} devices "
          f"({nx}x{nx} diffusion, {len(h.levels)} AMG levels)")
    dh = DistributedHierarchy.setup(h, mesh_n(n_dev), "proc", cache=cache)
    k = args.iters // 2
    x_mid, hist = dh.solve(b, tol=0.0, max_iters=k)
    print(f"[elastic] {k} iters done, rel_res={hist[-1]:.3e}; "
          f"2 devices time out -> shrink to {n_dev // 2}")
    dh_small = dh.repartition(mesh_n(n_dev // 2), reason="heartbeat")
    print(f"[elastic]   {dh_small.last_resize}")
    x, hist2 = dh_small.solve(b, tol=0.0, max_iters=args.iters - k, x0=x_mid)
    rel = np.linalg.norm(b - A.matvec(x)) / np.linalg.norm(b)
    print(f"[elastic] warm-started remaining {args.iters - k} iters on "
          f"{n_dev // 2} devices, rel_res={rel:.3e}")

    # ---- act 2: grow back (warm: zero re-plans) --------------------------
    dh_back = dh_small.repartition(mesh_n(n_dev), reason="requested")
    ev = dh_back.last_resize
    print(f"[elastic] devices return -> grow back: {ev}")
    print(f"[elastic]   warm resize: {ev.warm} "
          f"(every pattern came out of the plan cache)")

    # ---- act 3: straggler rebalance + refit ------------------------------
    tracer = TraceRecorder()
    dh_back.measure_exchange_seconds(iters=2, warmup=1, tracer=tracer)
    ctrl = ElasticController(n_dev, cache=cache, tracer=tracer,
                             straggler_cfg=StragglerConfig(patience=3),
                             cooldown=8)
    print(f"[straggler] injecting {args.slow_factor:.1f}x slowdown on "
          f"host {args.slow_host}; feeding per-host step seconds...")
    base = np.full(n_dev, 0.010)
    mitigated = False
    for t in range(24):
        times = base.copy()
        if not mitigated:
            times[args.slow_host] *= args.slow_factor
        flagged = ctrl.observe_step_times(times)
        if flagged:
            dh_back, event = ctrl.mitigate_hierarchy(dh_back, flagged)
            mitigated = True
            print(f"[straggler] {event}")
            print(f"[straggler]   {event.resize}")
            rows = np.diff(dh_back.levels[0].A.part.offsets)
            print(f"[straggler] fine-level rows/host: {rows.tolist()} "
                  f"(host {args.slow_host} sheds load)")
    x2, hist3 = dh_back.solve(b, tol=1e-8, max_iters=40)
    rel2 = np.linalg.norm(b - A.matvec(x2)) / np.linalg.norm(b)
    print(f"[straggler] rebalanced solve: {len(hist3)} iters, "
          f"rel_res={rel2:.3e}, params={dh_back.params.name}")
    print(f"[elastic] controller summary: {ctrl.summary()}")
    print(f"[elastic] plan cache: hits={cache.hits} misses={cache.misses} "
          f"exec_hits={cache.exec_hits} exec_misses={cache.exec_misses}")


if __name__ == "__main__":
    main()
