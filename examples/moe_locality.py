"""The paper's technique inside an LM: locality-aware MoE dispatch.

Spawns an 8-virtual-device (pod=2, data=2, model=2) subprocess that runs
the same Mixtral-family MoE layer under all four transports and verifies
they agree bit-exactly, then prints the per-strategy traffic profile from
the planner (what crosses the slow 'pod' axis vs the fast 'model' axis).

    PYTHONPATH=src python examples/moe_locality.py
"""
import os
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]


def main():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(ROOT / "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    prog = ROOT / "tests" / "multidevice_progs" / "check_moe_modes.py"
    print("[moe] running all dispatch strategies on a 2-pod virtual mesh...")
    out = subprocess.run([sys.executable, str(prog)], env=env,
                         capture_output=True, text=True, timeout=900)
    print(out.stdout)
    if out.returncode != 0:
        print(out.stderr[-2000:])
        sys.exit(1)
    print("[moe] strategies agree — see DESIGN.md for the paper mapping:\n"
          "  a2a        = paper 'standard'   (flat all-to-all)\n"
          "  hier       = paper 'partial'    (3-step aggregation)\n"
          "  hier_dedup = paper 'full'       (+ duplicate removal)\n"
          "  auto       = paper Section 5    (cost-model selection,\n"
          "               plan-cached via moe_plan_for — bit-identical\n"
          "               to the selected mode, re-plans nothing on\n"
          "               repeated batches)")


if __name__ == "__main__":
    main()
