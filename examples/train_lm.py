"""Train a language model end-to-end with the full substrate: synthetic
data pipeline, AdamW + cosine schedule, remat, checkpointing, restart.

Default is a ~10M-parameter qwen2-family model for a quick CPU run; pass
--dmodel 512 --layers 12 --vocab 32000 for a ~100M configuration (same
code path — only wall-clock changes).

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import Model
from repro.runtime import CheckpointManager
from repro.train import (
    AdamWConfig, DataConfig, TokenStream, TrainerConfig,
    make_train_state, make_train_step,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--dmodel", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    base = configs.get("qwen2-0.5b")
    cfg = dataclasses.replace(
        base, name="train-lm-example", n_layers=args.layers,
        d_model=args.dmodel, n_heads=max(4, args.dmodel // 64),
        n_kv_heads=max(2, args.dmodel // 128), d_ff=args.dmodel * 4,
        vocab=args.vocab, dtype=jnp.float32,
    )
    model = Model(cfg)
    print(f"[example] params: {cfg.param_count():,}")
    tcfg = TrainerConfig(opt=AdamWConfig(
        lr=1e-3, warmup_steps=max(10, args.steps // 20),
        total_steps=args.steps))
    data = TokenStream(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  global_batch=args.batch))
    step = jax.jit(make_train_step(model, tcfg))
    state = make_train_state(model, tcfg, seed=0)
    mgr = CheckpointManager(args.ckpt, keep=2)
    start = 0
    got = mgr.restore_latest(state)
    if got:
        start, state = got
        state = jax.tree.map(jnp.asarray, state)
        print(f"[example] resumed at step {start}")
    for i in range(start, args.steps):
        batch = jax.tree.map(jnp.asarray, data.global_batch_at(i))
        state, m = step(state, batch)
        if (i + 1) % 20 == 0:
            mgr.save(i + 1, state)
            print(f"step {i + 1:4d} loss={float(m['loss']):.4f} "
                  f"lr={float(m['lr']):.2e}")
    mgr.wait()
    print("[example] done — loss should have dropped by >1 nat "
          "(motif structure is learnable)")


if __name__ == "__main__":
    main()
