"""Batched serving example: prefill a batch of prompts, stream greedy
tokens with per-layer KV caches (rolling windows where the arch is
sliding-window).  Uses the same serving path the decode_32k / long_500k
dry-run cells lower.

    PYTHONPATH=src python examples/serve_decode.py --arch gemma3-1b

Adaptive re-planning demo (the repro.profile feedback loop): serve a
reduced MoE model, let the engine observe its measured per-batch expert
histograms, then skew the routing mid-run — the histogram drift triggers
exactly one re-fingerprint/re-selection of the dispatch plan, printed with
the before/after transport mode.  Deterministic on the 8 virtual host
devices test.sh configures:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \\
      python examples/serve_decode.py --adaptive

Observability demo (``--observe``): the same skewed decode with the
``repro.obs`` telemetry layer enabled — decode-step spans, the replan as
a trace instant, periodic online ``MachineParams`` refits from
production-step exchange probes, and a Perfetto trace export.
"""
import sys


def adaptive_demo():
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import reduced
    from repro.models import Model
    from repro.serve import Request, ServeEngine

    cfg0 = reduced("mixtral-8x7b")
    cfg = cfg0.__class__(**{**cfg0.__dict__, "dtype": jnp.float32})
    n_dev = jax.device_count()
    mesh = jax.make_mesh((1, n_dev), ("data", "model"))
    model = Model(cfg, mesh=mesh, moe_mode="auto", remat=False,
                  moe_cap_factor=8.0)
    params = model.init_params(seed=0)
    eng = ServeEngine(model, params, batch_slots=2, max_len=96,
                      adaptive=True, drift_threshold=0.3, drift_warmup=2)
    rng = np.random.default_rng(1)
    eng.submit(Request(
        rid=0,
        prompt=rng.integers(0, cfg.vocab, size=(4,)).astype(np.int32),
        max_new_tokens=80,
    ))
    eng.step()                                        # admit + prefill
    print(f"engine: {n_dev} devices, experts={cfg.n_experts} "
          f"top_k={cfg.top_k}, initial mode={eng.moe_plan.mode} "
          f"(Section-5 auto)")

    for _ in range(12):                               # steady workload
        eng.step()
    ref = eng.planner.reference_fractions()
    print(f"steady: {eng.planner.observed} observations, "
          f"expert fractions={np.round(ref, 3)}, "
          f"replan events={len(eng.replan_events)}")

    # skew the workload: a zero router ties every logit, so top-k sends
    # every token to experts {0..k-1} — a maximal routing drift
    params["blocks"]["moe"]["router"] = jnp.zeros_like(
        params["blocks"]["moe"]["router"]
    )
    pre_mode = eng.moe_plan.mode
    for _ in range(30):
        eng.step()
        if eng.replan_events:
            break
    for ev in eng.replan_events:
        print(f"drift detected: {ev}")
    if eng.replan_events:
        print(f"migrated dispatch mode: {pre_mode} -> {eng.moe_plan.mode} "
              f"(histogram-fingerprinted plan, cached in PlanCache)")
    else:
        print("no drift event (unexpected on the 8-device demo config)")
    for _ in range(4):                                # decode continues
        eng.step()
    print(f"post-migration decodes OK, total replan events: "
          f"{len(eng.replan_events)} (expected exactly 1)")
    s = eng.plan_cache.stats()
    print(f"plan cache: hits={s['hits']} misses={s['misses']} "
          f"evictions={s['evictions']}")


def observe_demo():
    """Observability + online-recalibration demo (``observe=True``).

    Runs the adaptive skewed-traffic decode with the telemetry layer on:
    every decode step becomes a span, the drift re-selection lands as a
    ``serve/replan`` instant in the trace, and every ``refit_every``
    steps the engine probes the live dispatch exchange and re-fits
    ``MachineParams`` from the accumulated pure samples.  Exports
    ``serve_trace.json`` — open it at https://ui.perfetto.dev — and
    prints the obs rollup table.

        XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \\
          python examples/serve_decode.py --observe
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import reduced
    from repro.models import Model
    from repro.obs import default_obs
    from repro.serve import Request, ServeEngine

    cfg0 = reduced("mixtral-8x7b")
    cfg = cfg0.__class__(**{**cfg0.__dict__, "dtype": jnp.float32})
    n_dev = jax.device_count()
    mesh = jax.make_mesh((1, n_dev), ("data", "model"))
    model = Model(cfg, mesh=mesh, moe_mode="auto", remat=False,
                  moe_cap_factor=8.0)
    params = model.init_params(seed=0)
    eng = ServeEngine(model, params, batch_slots=2, max_len=96,
                      adaptive=True, drift_threshold=0.3, drift_warmup=2,
                      observe=True, refit_every=8)
    rng = np.random.default_rng(1)
    eng.submit(Request(
        rid=0,
        prompt=rng.integers(0, cfg.vocab, size=(4,)).astype(np.int32),
        max_new_tokens=60,
    ))
    for _ in range(13):
        eng.step()
    # skew the routing mid-run (see adaptive_demo): drift -> one replan
    params["blocks"]["moe"]["router"] = jnp.zeros_like(
        params["blocks"]["moe"]["router"]
    )
    for _ in range(20):
        eng.step()
        if eng.replan_events:
            break
    for _ in range(8):
        eng.step()

    obs = default_obs()
    print(obs.report())
    print()
    for ev in eng.replan_events:
        print(f"replan:  {ev}")
    for ev in eng.refit_events:
        print(f"refit:   {ev}")
    if eng.machine_params is not None:
        print(f"fitted MachineParams '{eng.machine_params.name}' now "
              f"drive the adaptive planner's transport selection")
    obs.export_perfetto("serve_trace.json")
    print("\nPerfetto trace written to serve_trace.json "
          "(open at https://ui.perfetto.dev)")


def main():
    argv = sys.argv[1:]
    if "--adaptive" in argv:
        adaptive_demo()
        return
    if "--observe" in argv:
        observe_demo()
        return
    if "--arch" not in argv:
        argv = ["--arch", "gemma3-1b"] + argv
    if "--reduced" not in argv:
        argv.append("--reduced")
    sys.argv = [sys.argv[0]] + argv
    from repro.launch.serve import main as serve_main
    serve_main()


if __name__ == "__main__":
    main()
