"""Batched serving example: prefill a batch of prompts, stream greedy
tokens with per-layer KV caches (rolling windows where the arch is
sliding-window).  Uses the same serving path the decode_32k / long_500k
dry-run cells lower.

    PYTHONPATH=src python examples/serve_decode.py --arch gemma3-1b
"""
import sys

from repro.launch import serve


def main():
    argv = sys.argv[1:]
    if "--arch" not in argv:
        argv = ["--arch", "gemma3-1b"] + argv
    if "--reduced" not in argv:
        argv.append("--reduced")
    sys.argv = [sys.argv[0]] + argv
    from repro.launch.serve import main as serve_main
    serve_main()


if __name__ == "__main__":
    main()
