"""Quickstart: the paper's three neighborhood collectives in 60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (
    CommPattern,
    NeighborAlltoallV,
    Topology,
    build_plan,
)

# 16 processes in 4 regions of 4 (think: 4 pods of 4 chips)
topo = Topology(n_procs=16, procs_per_region=4)

# an irregular pattern: every process owns 8 values; each needs a random
# subset of remote values (this is exactly what a SpMV halo exchange or a
# MoE dispatch looks like to the collective)
rng = np.random.default_rng(0)
n_per = 8
offsets = np.arange(17) * n_per
needs = [
    np.sort(rng.choice(16 * n_per, size=rng.integers(4, 14), replace=False))
    for _ in range(16)
]
pattern = CommPattern.from_block_partition(needs, offsets)

print("strategy  | inter msgs | inter bytes | intra msgs | intra bytes")
for strategy in ("standard", "partial", "full"):
    plan = build_plan(pattern, topo, strategy)
    t = plan.stats.totals()
    print(f"{strategy:9s} | {t['inter_msgs']:10d} | {t['inter_bytes']:11d}"
          f" | {t['intra_msgs']:10d} | {t['intra_bytes']:11d}")

# persistent-collective API: init once (expensive), execute every iteration
coll = NeighborAlltoallV.init(pattern, topo, strategy="auto")
print(f"\nauto-selected: {coll.strategy} "
      f"(modeled {coll.modeled_time() * 1e6:.1f} us/iter); "
      f"init took {coll.init_seconds * 1e3:.1f} ms")

vals = [rng.normal(size=(n_per,)) for _ in range(16)]
ghosts = coll(vals)  # start + wait
want = np.concatenate([
    [vals[pattern.owner_proc[g]][pattern.owner_slot[g]] for g in needs[q]]
    for q in range(16) if len(needs[q])
])
got = np.concatenate([g for g in ghosts if len(g)])
assert np.array_equal(got, want)
print("delivery verified: every process received exactly its needed values")
