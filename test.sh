#!/usr/bin/env bash
# Tier-1 test runner with a deterministic multidevice environment.
#
# shard_map tests (collectives, distributed AMG) need several devices; on
# CPU-only machines XLA fakes them with --xla_force_host_platform_device_count
# (set BEFORE any jax import, hence here and not in conftest).  Usage:
#
#   bash test.sh                       # whole tier-1 suite
#   bash test.sh tests/test_core_plan.py -k rounds
set -euo pipefail
cd "$(dirname "$0")"

export PYTHONPATH="$PWD/src${PYTHONPATH:+:$PYTHONPATH}"
export XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
# Verify-on-insertion: every plan entering the PlanCache is statically
# checked (repro.verify) in tests/CI; production hot paths leave it unset.
export REPRO_VERIFY="${REPRO_VERIFY:-1}"
# Deterministic hashing: plan/pattern fingerprints are content-hashed
# (blake2b), but set ordering anywhere upstream must not depend on the
# per-process hash seed — pin it so every run and every CI shard agrees
# (tests/test_dense_collectives.py asserts cross-process stability).
export PYTHONHASHSEED="${PYTHONHASHSEED:-0}"

exec /usr/bin/env python3 -m pytest -x -q "$@"
