"""Static Pallas kernel budget + bucket-map coverage checks.

Two contracts are checked here, both without running a kernel:

* **Honest VMEM numbers.**  ``select_spmv_kernel`` picks flat vs blocked
  from the modeled ``spmv_flat/blocked_vmem_bytes`` estimators.  Those
  numbers are only trustworthy while they track the kernels' *actual*
  BlockSpec footprints — this module recomputes the footprint directly
  from the BlockSpec geometry in ``kernels/spmv_ell`` (block shapes,
  constant-vs-streamed index maps, double buffering of grid-varying
  blocks) and requires the estimator to agree within a tolerance, and the
  selected variant's actual residency to fit in a physical core's VMEM.
  If someone retiles a kernel and forgets the estimator, this is the
  tripwire.

* **Bucket-map exhaustiveness.**  The bucket-skipping kernel trusts
  ``row_block_bucket_map`` to enumerate, per row block, exactly the
  buckets holding nonzeros: a missing bucket silently drops values from
  the matvec, a duplicated bucket accumulates them twice.
  :func:`check_bucket_map` proves every nonzero is covered exactly once.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from ..kernels.spmv_ell import DEFAULT_BLOCK_COLS, DEFAULT_BLOCK_ROWS
from ..sparse.device import (
    _IDX_BYTES,
    VMEM_BYTES_PER_CORE,
    row_block_bucket_map,
    spmv_blocked_vmem_bytes,
    spmv_flat_vmem_bytes,
)
from .invariants import VerifyError, _fail


# ---------------------------------------------------------------------------
# actual BlockSpec footprints (independent mirror of kernels/spmv_ell)
# ---------------------------------------------------------------------------


def flat_kernel_actual_bytes(
    ell, *, value_bytes: int = 8, block_rows: int = DEFAULT_BLOCK_ROWS
) -> int:
    """Residency of the flat path straight from its BlockSpecs.

    ``spmv_ell`` runs twice (local + ghost matvec).  Per launch: cols and
    vals blocks are ``(br, K)`` and vary with the grid step (double
    buffered), x is a grid-constant ``(N, 1)`` block resident once
    (``N = pad + 1`` sentinel slot), and the output block is ``(br, 1)``.
    The two launches are summed with one shared output accumulator,
    mirroring the estimator's both-resident assumption.
    """
    br = min(int(block_rows), ell.row_pad) if ell.row_pad else int(block_rows)
    kl = ell.local_cols.shape[2]
    kg = ell.ghost_cols.shape[2]
    x_local = (ell.in_pad + 1) * value_bytes
    x_ghost = (ell.ghost_pad + 1) * value_bytes if ell.ghost_pad else 0
    stream = 2 * br * (kl + kg) * (_IDX_BYTES + value_bytes)
    out = br * value_bytes
    return int(x_local + x_ghost + stream + out)


def blocked_kernel_actual_bytes(
    ell, *, value_bytes: int = 8, block_rows: int = DEFAULT_BLOCK_ROWS
) -> int:
    """Residency of the blocked path straight from its BlockSpecs.

    ``spmv_ell_blocked`` streams ``(br, K)`` cols/vals blocks and a
    ``(bc, 1)`` x bucket per grid step — all three vary with the grid, so
    all are double buffered — plus the ``(br, 1)`` output block.  Uses the
    *packed* per-bucket width ``ell.K`` (what the kernel actually loads),
    not the pre-packing upper bound the selector models with.
    """
    br = min(int(block_rows), ell.row_pad) if ell.row_pad else int(block_rows)
    stream = 2 * br * ell.K * (_IDX_BYTES + value_bytes)
    x_bytes = 2 * ell.block_cols * value_bytes
    out = br * value_bytes
    return int(stream + x_bytes + out)


def verify_kernel_budget(
    ell,
    selection=None,
    *,
    value_bytes: int = 8,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    rtol: float = 0.5,
) -> None:
    """Estimator honesty + hard VMEM fit for one device operator.

    ``ell`` is a ``DeviceEll`` (flat layout) or ``DeviceEllBlocked``
    (blocked layout), dispatched by shape fields.  Checks:

    1. the modeled estimator agrees with the BlockSpec-derived actual
       footprint within ``rtol`` (relative to the actual);
    2. for blocked layouts, the selector's recorded ``blocked_bytes`` is
       an upper bound on the actual (packing may shrink ``K``, never grow
       it) — a selector that under-reports would steer traffic into
       kernels that do not fit;
    3. the actual footprint of the laid-out kernel fits in a physical
       core's VMEM (the selection threshold is softer; this is the hard
       wall).
    """
    blocked = hasattr(ell, "bucket_K")
    if blocked:
        actual = blocked_kernel_actual_bytes(
            ell, value_bytes=value_bytes, block_rows=block_rows
        )
        modeled = spmv_blocked_vmem_bytes(
            bucket_k=ell.K, value_bytes=value_bytes,
            rows=ell.row_pad, block_rows=block_rows,
            block_cols=ell.block_cols,
        )
        variant = "blocked"
    else:
        actual = flat_kernel_actual_bytes(
            ell, value_bytes=value_bytes, block_rows=block_rows
        )
        modeled = spmv_flat_vmem_bytes(
            in_pad=ell.in_pad, ghost_pad=ell.ghost_pad,
            k_local=ell.local_cols.shape[2],
            k_ghost=ell.ghost_cols.shape[2],
            value_bytes=value_bytes, rows=ell.row_pad,
            block_rows=block_rows,
        )
        variant = "flat"
    if abs(modeled - actual) > rtol * max(actual, 1):
        _fail("modeled VMEM estimator drifted from the kernel's BlockSpec "
              "footprint", variant=variant, modeled=modeled, actual=actual,
              rtol=rtol)
    if blocked and selection is not None and \
            selection.blocked_bytes < actual:
        _fail("kernel selection under-reports the blocked footprint",
              recorded=selection.blocked_bytes, actual=actual)
    if selection is not None and selection.variant == variant and \
            actual > VMEM_BYTES_PER_CORE:
        _fail("selected kernel's actual footprint exceeds physical VMEM",
              variant=variant, actual=actual, vmem=VMEM_BYTES_PER_CORE)


# ---------------------------------------------------------------------------
# bucket-map coverage (skip kernel)
# ---------------------------------------------------------------------------


def check_bucket_map(
    ell,
    lists: np.ndarray,
    counts: np.ndarray,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    bucket_lo: int = 0,
    bucket_hi: Optional[int] = None,
) -> None:
    """Prove a (lists, counts) pair covers every nonzero exactly once.

    The skip kernel visits, for row block ``i``, exactly the buckets
    ``lists[p, i, :counts[p, i]]``: a live bucket absent from its list is
    dropped from the matvec; a bucket listed twice is accumulated twice.
    Checks shapes against the kernel's row blocking, ascending unique
    in-window entries, inert ``bucket_lo`` padding, and exact agreement
    with the live set recomputed from ``ell.vals``.
    """
    C, K = ell.n_buckets, ell.K
    lo = int(bucket_lo)
    hi = C if bucket_hi is None else int(bucket_hi)
    R = ell.row_pad
    br = min(int(block_rows), R)
    nrb = (R + (-R) % br) // br
    if counts.shape != (ell.n_procs, nrb):
        _fail("bucket-map counts shape disagrees with the kernel grid",
              shape=counts.shape, expected=(ell.n_procs, nrb))
    if lists.shape[:2] != (ell.n_procs, nrb):
        _fail("bucket-map lists shape disagrees with the kernel grid",
              shape=lists.shape, expected_leading=(ell.n_procs, nrb))
    M = lists.shape[2]
    live = (ell.vals.reshape(ell.n_procs, R, C, K) != 0).any(-1)
    for p in range(ell.n_procs):
        for rb in range(nrb):
            n = int(counts[p, rb])
            if not 0 <= n <= M:
                _fail("bucket count outside the list capacity", rank=p,
                      row_block=rb, count=n, capacity=M)
            row = lists[p, rb]
            head = row[:n].astype(np.int64)
            if n and (head.min() < lo or head.max() >= hi):
                _fail("listed bucket outside the kernel's window", rank=p,
                      row_block=rb,
                      bucket=int(head[np.argmax(
                          (head < lo) | (head >= hi))]),
                      window=(lo, hi))
            if np.any(np.diff(head) == 0):
                dup = int(head[np.argmax(np.diff(head) == 0)])
                _fail("duplicated bucket in a row-block list (its values "
                      "would be accumulated twice)", rank=p, row_block=rb,
                      bucket=dup)
            if np.any(np.diff(head) < 0):
                _fail("bucket list not ascending", rank=p, row_block=rb)
            if np.any(row[n:] != lo):
                _fail("bucket-list padding is not the inert bucket_lo "
                      "value", rank=p, row_block=rb,
                      slot=int(n + np.argmax(row[n:] != lo)))
            rows = live[p, rb * br: min((rb + 1) * br, R), lo:hi]
            want = np.flatnonzero(rows.any(0)) + lo
            missing = np.setdiff1d(want, head)
            if len(missing):
                _fail("live bucket missing from the row-block list (its "
                      "nonzeros would be dropped)", rank=p, row_block=rb,
                      bucket=int(missing[0]))
            extra = np.setdiff1d(head, want)
            if len(extra):
                _fail("dead bucket listed for a row block", rank=p,
                      row_block=rb, bucket=int(extra[0]))


def verify_bucket_map(
    ell,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    bucket_lo: int = 0,
    bucket_hi: Optional[int] = None,
) -> None:
    """Build the map the kernels would use and prove it exhaustive."""
    lists, counts = row_block_bucket_map(
        ell, block_rows=block_rows, bucket_lo=bucket_lo,
        bucket_hi=bucket_hi,
    )
    check_bucket_map(
        ell, lists, counts, block_rows=block_rows, bucket_lo=bucket_lo,
        bucket_hi=bucket_hi,
    )


__all__ = [
    "VerifyError",
    "flat_kernel_actual_bytes",
    "blocked_kernel_actual_bytes",
    "verify_kernel_budget",
    "check_bucket_map",
    "verify_bucket_map",
]
