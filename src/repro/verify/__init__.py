"""repro.verify — static plan/kernel verification.

The planners hand the whole communication pattern to the runtime; this
package checks the whole pattern.  Three passes:

* :mod:`.invariants` — host-side structural checks over patterns, plans,
  partitions, device ELL layouts and MoE dispatch geometry (conservation,
  duality, round conflict-freedom, bucket exhaustiveness).
* :mod:`.jaxpr_audit` — trace bound executors and prove the compiled
  collective sequence matches the frozen DevicePlan (SPMD uniformity; no
  collective under data-dependent control flow).
* :mod:`.kernel_budget` — the Pallas kernels' actual BlockSpec footprints
  agree with the modeled VMEM estimators, and bucket-skip maps cover every
  nonzero exactly once.

Entry points: :func:`verify_hierarchy` sweeps every operator of a
``DistributedHierarchy``; ``ServeEngine.verify()`` checks a serving
engine's MoE plans; ``PlanCache`` calls :func:`verify_cache_value` /
:func:`audit_executor` on insertion when :func:`verify_enabled` — i.e.
``REPRO_VERIFY=1`` (tests/CI default via ``test.sh``; unset in production
hot paths).  All failures raise :class:`VerifyError` with a diagnostic
naming the offending rank / slot / bucket.
"""
from __future__ import annotations

import os
from typing import Dict

from .invariants import (
    VerifyError,
    verify_cache_value,
    verify_collective,
    verify_dense_plan,
    verify_device_ell,
    verify_device_plan,
    verify_ell_blocked,
    verify_moe_dispatch,
    verify_moe_plan,
    verify_partition,
    verify_pattern,
    verify_plan,
    verify_round_schedule,
)
from .jaxpr_audit import (
    COLLECTIVE_PRIMITIVES,
    CollectiveRecord,
    audit_dense_executor,
    audit_executor,
    collective_signature,
    trace_collectives,
)
from .kernel_budget import (
    blocked_kernel_actual_bytes,
    check_bucket_map,
    flat_kernel_actual_bytes,
    verify_bucket_map,
    verify_kernel_budget,
)

__all__ = [
    "VerifyError",
    "verify_enabled",
    "verify_pattern",
    "verify_round_schedule",
    "verify_plan",
    "verify_device_plan",
    "verify_collective",
    "verify_partition",
    "verify_device_ell",
    "verify_ell_blocked",
    "verify_moe_plan",
    "verify_moe_dispatch",
    "verify_dense_plan",
    "verify_cache_value",
    "COLLECTIVE_PRIMITIVES",
    "CollectiveRecord",
    "collective_signature",
    "trace_collectives",
    "audit_executor",
    "audit_dense_executor",
    "flat_kernel_actual_bytes",
    "blocked_kernel_actual_bytes",
    "verify_kernel_budget",
    "check_bucket_map",
    "verify_bucket_map",
    "verify_dist_op",
    "verify_hierarchy",
]


def verify_enabled() -> bool:
    """Whether plan-cache insertions verify (``REPRO_VERIFY``).

    Read per call, not at import, so tests and operators can flip it at
    runtime.  On by default in tests/CI (``test.sh`` exports it); leave it
    unset in production hot paths — verification is host-side numpy over
    plan metadata, cheap next to planning but not free.
    """
    return os.environ.get("REPRO_VERIFY", "0").lower() in ("1", "true", "on")


def verify_dist_op(op, *, value_bytes: int = 8) -> Dict[str, int]:
    """All static checks for one distributed operator (a ``DistOp``):
    partition, bound collective, device layout, kernel budget, and — for
    blocked layouts — bucket-map exhaustiveness over the full window and
    both overlap windows (local / ghost) when an exchange exists.

    Each pass runs under an obs span (``verify/<pass>``) so
    ``obs.report()`` breaks verification wall time out per pass.
    """
    from ..obs import default_obs

    obs = default_obs()
    counts: Dict[str, int] = {}

    def tick(k: str) -> None:
        counts[k] = counts.get(k, 0) + 1

    with obs.span("verify/partition"):
        verify_partition(op.part)
    tick("partitions")
    if op.coll is not None:
        with obs.span("verify/collective"):
            verify_collective(op.coll)
        tick("collectives")
    ell = op.ell
    if hasattr(ell, "bucket_K"):
        with obs.span("verify/blocked_layout"):
            verify_ell_blocked(ell, op.part)
            verify_bucket_map(ell)
            if op.coll is not None and ell.n_ghost_buckets:
                verify_bucket_map(ell, bucket_hi=ell.n_local_buckets)
                verify_bucket_map(ell, bucket_lo=ell.n_local_buckets)
        tick("blocked_layouts")
    else:
        with obs.span("verify/flat_layout"):
            verify_device_ell(ell, op.part)
        tick("flat_layouts")
    with obs.span("verify/kernel_budget"):
        verify_kernel_budget(ell, op.kernel, value_bytes=value_bytes)
    tick("kernel_budgets")
    return counts


def verify_hierarchy(h) -> Dict[str, int]:
    """Sweep every operator (A, R, P per level) of a
    ``DistributedHierarchy``; returns check counts per category.  Raises
    :class:`VerifyError` on the first violated invariant."""
    from ..obs import default_obs

    counts: Dict[str, int] = {"levels": len(h.levels)}
    with default_obs().span("verify/hierarchy", levels=len(h.levels)):
        for lv in h.levels:
            for name, op in (("A", lv.A), ("R", lv.R), ("P", lv.P)):
                if op is None:
                    continue
                try:
                    for k, v in verify_dist_op(
                            op, value_bytes=h.value_bytes).items():
                        counts[k] = counts.get(k, 0) + v
                except VerifyError as e:
                    raise VerifyError(
                        f"level {lv.index} operator {name}: {e}"
                    ) from e
    return counts
