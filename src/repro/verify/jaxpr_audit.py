"""SPMD collective-uniformity audit of traced plan executors.

MPI neighborhood collectives deadlock when ranks disagree on the call
sequence.  The SPMD analogue: every device runs the *same* jaxpr, so the
collective sequence is uniform by construction — *unless* a collective's
execution or ordering becomes data-dependent (under ``lax.cond`` /
``lax.while_loop``), or the traced program simply disagrees with the plan
it claims to implement (wrong round count, wrong perm, wrong axis).

This module traces a bound executor with ``jax.make_jaxpr`` (tracing is
static — no devices run) and walks the jaxpr recursively, collecting every
collective primitive with its axis name, permutation, operand shape/dtype,
and whether it sits under data-dependent control flow.
:func:`audit_executor` then requires the collected sequence to match the
frozen :class:`~repro.core.collectives.DevicePlan` round for round.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from .invariants import VerifyError, _fail

#: primitives that communicate across an axis
COLLECTIVE_PRIMITIVES = frozenset({
    "ppermute",
    "pshuffle",
    "all_to_all",
    "all_gather",
    "all_gather_invariant",
    "psum",
    "psum2",
    "pmin",
    "pmax",
    "reduce_scatter",
    "psum_scatter",
})

#: primitives whose branch choice / trip count depends on traced values —
#: a collective beneath one executes a data-dependent number of times,
#: the SPMD analogue of an unmatched MPI call
_DATA_DEPENDENT_CONTROL = frozenset({"cond", "while"})


@dataclass
class CollectiveRecord:
    """One collective occurrence in a traced program."""

    kind: str
    axis_name: Any
    perm: Optional[Tuple[Tuple[int, int], ...]]
    shape: Tuple[int, ...]
    dtype: Any
    in_control_flow: bool
    control_path: Tuple[str, ...] = field(default_factory=tuple)


def _sub_jaxprs(params: Dict[str, Any]):
    """Yield every jaxpr nested in an eqn's params (cond branches, the
    shard_map body, custom-call callees, ...)."""
    try:
        import jax.extend.core as jex_core
    except ImportError:  # pragma: no cover - older jax 0.4.x
        import jax.core as jex_core

    def is_jaxpr(v):
        return isinstance(v, (jex_core.Jaxpr, jex_core.ClosedJaxpr))

    for v in params.values():
        if is_jaxpr(v):
            yield v
        elif isinstance(v, (list, tuple)):
            for item in v:
                if is_jaxpr(item):
                    yield item


def _walk(jaxpr, out: List[CollectiveRecord],
          control_path: Tuple[str, ...]) -> None:
    inner = getattr(jaxpr, "jaxpr", jaxpr)   # unwrap ClosedJaxpr
    for eqn in inner.eqns:
        name = eqn.primitive.name
        if name in COLLECTIVE_PRIMITIVES:
            axis = eqn.params.get("axis_name",
                                  eqn.params.get("axes"))
            perm = eqn.params.get("perm")
            aval = eqn.invars[0].aval if eqn.invars else None
            out.append(CollectiveRecord(
                kind=name,
                axis_name=axis,
                perm=tuple(tuple(p) for p in perm)
                if perm is not None else None,
                shape=tuple(getattr(aval, "shape", ())),
                dtype=getattr(aval, "dtype", None),
                in_control_flow=bool(control_path),
                control_path=control_path,
            ))
        child_path = (control_path + (name,)
                      if name in _DATA_DEPENDENT_CONTROL else control_path)
        for sub in _sub_jaxprs(eqn.params):
            _walk(sub, out, child_path)


def collective_signature(jaxpr) -> List[CollectiveRecord]:
    """All collective occurrences of a (Closed)Jaxpr, in program order,
    recursing through shard_map / pjit / control-flow bodies."""
    out: List[CollectiveRecord] = []
    _walk(jaxpr, out, ())
    return out


def trace_collectives(fn, *avals) -> List[CollectiveRecord]:
    """Trace ``fn`` on abstract inputs and collect its collectives."""
    import jax

    return collective_signature(jax.make_jaxpr(fn)(*avals))


def audit_executor(fn, dplan, axis_name: str,
                   dtype=np.float32, width: int = 1) -> List[CollectiveRecord]:
    """Prove a bound executor implements exactly its DevicePlan.

    Traces ``fn`` on a ``[P, n_local_pad, width]`` abstract input and
    checks, against the frozen plan, that the program contains exactly one
    ``ppermute`` per wire round, in step-then-round order, each with the
    plan's permutation, the bound axis name, and the plan's padded message
    width — and that no collective executes under data-dependent control
    flow and no off-plan collective kind appears.  Returns the collected
    records for reporting.
    """
    import jax

    aval = jax.ShapeDtypeStruct(
        (dplan.n_procs, max(dplan.n_local_pad, 1), width), dtype
    )
    records = trace_collectives(fn, aval)

    for rec in records:
        if rec.in_control_flow:
            _fail("collective under data-dependent control flow (devices "
                  "could disagree on whether it executes)", kind=rec.kind,
                  path="/".join(rec.control_path))
        if rec.kind != "ppermute":
            _fail("off-plan collective kind in an exchange executor",
                  kind=rec.kind)

    want = [(st.name, r, rnd) for st in dplan.steps
            for r, rnd in enumerate(st.rounds)]
    if len(records) != len(want):
        _fail("traced ppermute count disagrees with the plan's wire "
              "rounds", traced=len(records), plan_rounds=len(want))
    for rec, (step, r, rnd) in zip(records, want):
        if rec.perm is None or set(rec.perm) != set(
                (int(s), int(d)) for s, d in rnd.perm):
            _fail("traced permutation disagrees with the plan round",
                  step=step, round=r, traced=rec.perm,
                  plan=tuple(rnd.perm))
        axes = rec.axis_name
        if isinstance(axes, (tuple, list)):
            ok = axis_name in axes
        else:
            ok = axes == axis_name
        if not ok:
            _fail("collective bound to the wrong mesh axis", step=step,
                  round=r, traced=axes, expected=axis_name)
        if rec.shape and rec.shape[0] != rnd.width:
            _fail("traced message width disagrees with the plan round",
                  step=step, round=r, traced=rec.shape[0],
                  plan=rnd.width)
        if rec.dtype is not None and np.dtype(rec.dtype) != np.dtype(dtype):
            _fail("collective payload dtype disagrees with the input",
                  step=step, round=r, traced=rec.dtype, expected=dtype)
    return records


def audit_dense_executor(fn, plan, axis_name: str,
                         dtype=np.float32) -> List[CollectiveRecord]:
    """Prove a bound dense executor implements exactly its DensePlan.

    Traces the executor on the collective's global input shape and checks
    one ``ppermute`` per plan round in order — the plan's pair set, the
    bound axis, the round's padded slab width (segments gathered per
    device), the payload dtype — and the usual uniformity conditions (no
    collective under data-dependent control flow, no off-plan kinds).
    """
    import jax

    P = plan.topo.n_procs
    n_seg, cmax = len(plan.counts), plan.cmax
    if plan.collective == "allgatherv":
        aval = jax.ShapeDtypeStruct((P, cmax), dtype)
    else:
        aval = jax.ShapeDtypeStruct((P, n_seg, cmax), dtype)
    records = trace_collectives(fn, aval)

    for rec in records:
        if rec.in_control_flow:
            _fail("collective under data-dependent control flow (devices "
                  "could disagree on whether it executes)", kind=rec.kind,
                  path="/".join(rec.control_path))
        if rec.kind != "ppermute":
            _fail("off-plan collective kind in a dense executor",
                  kind=rec.kind)

    if len(records) != len(plan.rounds):
        _fail("traced ppermute count disagrees with the dense plan's "
              "rounds", traced=len(records), plan_rounds=len(plan.rounds))
    for r, (rec, rnd) in enumerate(zip(records, plan.rounds)):
        if rec.perm is None or set(rec.perm) != set(
                (int(s), int(d)) for s, d in rnd.pairs):
            _fail("traced permutation disagrees with the dense round",
                  collective=plan.collective, variant=plan.variant,
                  round=r, traced=rec.perm, plan=tuple(rnd.pairs))
        axes = rec.axis_name
        if isinstance(axes, (tuple, list)):
            ok = axis_name in axes
        else:
            ok = axes == axis_name
        if not ok:
            _fail("dense collective bound to the wrong mesh axis",
                  round=r, traced=axes, expected=axis_name)
        if rec.shape and rec.shape[0] != rnd.width_segments():
            _fail("traced slab width disagrees with the dense round",
                  round=r, traced=rec.shape[0],
                  plan=rnd.width_segments())
        if rec.dtype is not None and np.dtype(rec.dtype) != np.dtype(dtype):
            _fail("dense collective payload dtype disagrees with the "
                  "input", round=r, traced=rec.dtype, expected=dtype)
    return records


__all__ = [
    "VerifyError",
    "COLLECTIVE_PRIMITIVES",
    "CollectiveRecord",
    "collective_signature",
    "trace_collectives",
    "audit_executor",
    "audit_dense_executor",
]
