"""Structural verification of CommPattern / CommPlan / partition / MoE plans.

The paper's persistent neighborhood collectives hand the planner the *whole*
communication pattern, which makes whole-pattern checking possible: every
invariant the planners rely on implicitly is stated here as an explicit,
machine-checked predicate.  A violated invariant raises :class:`VerifyError`
with a diagnostic naming the offending rank / slot / bucket, instead of
manifesting downstream as a hang (a ppermute round with a doubly-booked
rank) or a silently wrong residual (a dropped or duplicated ghost value).

What each check proves:

* :func:`verify_pattern` — ownership is a bijection (every global value has
  exactly one (proc, slot) home and every local slot exactly one value) and
  every requested ghost index exists.
* :func:`verify_round_schedule` — conflict-freedom of the edge coloring: no
  rank sends or receives twice in one round, no self-pairs — the SPMD
  deadlock-freedom condition (each round is a partial permutation, i.e. one
  well-formed ``lax.ppermute``).
* :func:`verify_plan` — send/recv duality and end-to-end conservation of an
  arbitrary multi-step (aggregated / dedup'd) plan: the plan is executed
  symbolically with *global indices as the payload*, so every ghost slot
  must end up holding exactly the global index the pattern requested,
  written exactly once — no dropped, duplicated, or misrouted bytes.
* :func:`verify_partition` — every ghost column of a :class:`PartitionedCSR`
  is served by exactly one exchange slot (``needs[p][j]``), and the
  attached pattern agrees with the column ownership.
* :func:`verify_device_ell` / :func:`verify_ell_blocked` — the device ELL
  forms carry exactly the partition's nonzeros: each nonzero lands in
  exactly one (row, column / bucket) slot and all padding is inert.
* :func:`verify_collective` — plan checks plus the frozen device plan
  (round perms, index-array shapes and sentinel bounds).
* :func:`verify_moe_plan` / :func:`verify_moe_dispatch` — dispatch geometry
  arithmetic (replication, capacity, region factorization) and per-expert
  token conservation of the capacity-packed routing pattern.

Everything here is plain numpy over host-side plan metadata — no jax, no
devices — so the verifier can run in CI lint jobs and on plan-cache
insertion (``REPRO_VERIFY=1``) without touching the compiled hot path.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from ..core.plan import (
    CommPattern,
    CommPlan,
    Round,
    color_rounds,
)


class VerifyError(Exception):
    """A violated plan/kernel invariant.

    ``context`` carries the structured fields (rank, slot, bucket, ...)
    the message interpolates, so programmatic consumers need not parse
    the string.
    """

    def __init__(self, message: str, **context: Any):
        if context:
            message = f"{message} [{', '.join(f'{k}={v}' for k, v in sorted(context.items()))}]"
        super().__init__(message)
        self.context: Dict[str, Any] = context


def _fail(message: str, **context: Any) -> None:
    raise VerifyError(message, **context)


# ---------------------------------------------------------------------------
# patterns
# ---------------------------------------------------------------------------


def verify_pattern(pattern: CommPattern) -> None:
    """Ownership bijection + ghost-request validity of a CommPattern."""
    P = pattern.n_procs
    G = pattern.n_global
    if len(pattern.owner_proc) != G or len(pattern.owner_slot) != G:
        _fail("owner arrays disagree on n_global",
              owner_proc=len(pattern.owner_proc),
              owner_slot=len(pattern.owner_slot))
    if len(pattern.n_local) != P:
        _fail("n_local length != n_procs",
              n_local=len(pattern.n_local), n_procs=P)
    if G and (pattern.owner_proc.min() < 0 or pattern.owner_proc.max() >= P):
        bad = int(np.flatnonzero(
            (pattern.owner_proc < 0) | (pattern.owner_proc >= P))[0])
        _fail("owner_proc out of range", global_index=bad,
              owner=int(pattern.owner_proc[bad]), n_procs=P)
    if int(pattern.n_local.sum()) != G:
        _fail("n_local does not sum to n_global",
              sum=int(pattern.n_local.sum()), n_global=G)
    for p in range(P):
        mine = np.flatnonzero(pattern.owner_proc == p)
        slots = pattern.owner_slot[mine]
        n_p = int(pattern.n_local[p])
        if len(mine) != n_p:
            _fail("proc owns a different value count than n_local claims",
                  rank=p, owned=len(mine), n_local=n_p)
        if n_p and (slots.min() < 0 or slots.max() >= n_p):
            g = int(mine[np.argmax((slots < 0) | (slots >= n_p))])
            _fail("owner_slot out of range", rank=p, global_index=g,
                  slot=int(pattern.owner_slot[g]), n_local=n_p)
        if len(np.unique(slots)) != len(slots):
            dup = int(np.unique(slots, return_counts=True)[0][
                np.argmax(np.unique(slots, return_counts=True)[1] > 1)])
            _fail("two global values share one local slot", rank=p, slot=dup)
    for q, need in enumerate(pattern.needs):
        if len(need) and (need.min() < 0 or need.max() >= G):
            j = int(np.argmax((need < 0) | (need >= G)))
            _fail("ghost request outside the global index space",
                  rank=q, ghost_slot=j, global_index=int(need[j]),
                  n_global=G)


# ---------------------------------------------------------------------------
# round schedules (deadlock freedom)
# ---------------------------------------------------------------------------


def verify_round_schedule(rounds: Sequence[Round], step: str = "?") -> None:
    """Each round must be a partial permutation: no rank twice as a sender
    or receiver, no self-pairs — the conditions for one well-formed
    ``lax.ppermute`` (their violation is the SPMD deadlock analogue)."""
    for r, rnd in enumerate(rounds):
        seen_src: Dict[int, int] = {}
        seen_dst: Dict[int, int] = {}
        for src, dst in rnd.pairs:
            if src == dst:
                _fail("self-pair in a wire round", step=step, round=r,
                      rank=src)
            if src in seen_src:
                _fail("rank sends twice in one round", step=step, round=r,
                      rank=src)
            if dst in seen_dst:
                _fail("rank receives twice in one round", step=step,
                      round=r, rank=dst)
            seen_src[src] = dst
            seen_dst[dst] = src
        if len(rnd.src_idx) != len(rnd.pairs) or \
                len(rnd.dst_idx) != len(rnd.pairs):
            _fail("round index lists disagree with pair count", step=step,
                  round=r, pairs=len(rnd.pairs))
        for (src, dst), si, di in zip(rnd.pairs, rnd.src_idx, rnd.dst_idx):
            if len(si) != len(di):
                _fail("size-mismatched send: gather and scatter lengths "
                      "differ", step=step, round=r, src=src, dst=dst,
                      sent=len(si), received=len(di))


# ---------------------------------------------------------------------------
# plans (duality + conservation)
# ---------------------------------------------------------------------------


def _owned_ids(pattern: CommPattern) -> List[np.ndarray]:
    """Per proc: global index held at each local slot (ownership inverse)."""
    out = [np.full(int(n), -1, dtype=np.int64) for n in pattern.n_local]
    for g in range(pattern.n_global):
        out[int(pattern.owner_proc[g])][int(pattern.owner_slot[g])] = g
    return out


def verify_plan(plan: CommPlan, pattern: Optional[CommPattern] = None) -> None:
    """Full structural + conservation check of a CommPlan.

    Structural: message endpoints and buffer indices in range, step buffer
    sizes chain, every delivery slot written at most once per buffer, each
    step's wire rounds conflict-free.  Conservation: the plan is executed
    symbolically with global indices as payload — ghost slot ``j`` of rank
    ``q`` must receive exactly ``needs[q][j]``, exactly once, through every
    staging hop of an aggregated/dedup'd plan.
    """
    pattern = plan.pattern if pattern is None else pattern
    verify_pattern(pattern)
    P = plan.topo.n_procs
    if pattern.n_procs != P:
        _fail("plan topology and pattern disagree on n_procs",
              topo=P, pattern=pattern.n_procs)

    ids = _owned_ids(pattern)
    # staging buffers hold the global index of the value occupying each
    # slot (-1 = never written); writes counted per ghost slot
    bufs: List[Optional[np.ndarray]] = [None] * P
    ghost_ids = [np.full(len(need), -1, dtype=np.int64)
                 for need in pattern.needs]
    ghost_writes = [np.zeros(len(need), dtype=np.int64)
                    for need in pattern.needs]

    prev_out: Optional[np.ndarray] = None
    for step in plan.steps:
        if len(step.in_sizes) != P or len(step.out_sizes) != P:
            _fail("step buffer-size arrays not per-proc", step=step.name,
                  in_sizes=len(step.in_sizes), out_sizes=len(step.out_sizes))
        if not step.reads_local:
            if prev_out is None:
                _fail("step reads the staging chain before any step "
                      "produced it", step=step.name)
            if not np.array_equal(step.in_sizes, prev_out):
                _fail("step input sizes do not chain from the previous "
                      "step's outputs", step=step.name)
        src_bufs = ids if step.reads_local else bufs
        src_sizes = pattern.n_local if step.reads_local else step.in_sizes
        if step.writes_ghost:
            dst_bufs: List[np.ndarray] = ghost_ids
            dst_sizes = np.asarray([len(n) for n in pattern.needs])
        else:
            dst_bufs = [np.full(int(step.out_sizes[p]), -1, dtype=np.int64)
                        for p in range(P)]
            dst_sizes = step.out_sizes
        written = [np.zeros(int(dst_sizes[p]), dtype=np.int64)
                   for p in range(P)]
        for m in step.messages:
            if not (0 <= m.src < P and 0 <= m.dst < P):
                _fail("message endpoint outside the process group",
                      step=step.name, src=m.src, dst=m.dst, n_procs=P)
            if m.size == 0:
                continue
            if int(m.src_idx.min()) < 0 or \
                    int(m.src_idx.max()) >= int(src_sizes[m.src]):
                _fail("message gathers outside its source buffer",
                      step=step.name, src=m.src, dst=m.dst,
                      index=int(m.src_idx.max()),
                      buffer=int(src_sizes[m.src]))
            if int(m.dst_idx.min()) < 0 or \
                    int(m.dst_idx.max()) >= int(dst_sizes[m.dst]):
                _fail("message scatters outside its destination buffer",
                      step=step.name, src=m.src, dst=m.dst,
                      index=int(m.dst_idx.max()),
                      buffer=int(dst_sizes[m.dst]))
            src = src_bufs[m.src]
            if src is None:
                _fail("message reads a buffer no prior step wrote",
                      step=step.name, src=m.src)
            vals = src[m.src_idx]
            if np.any(vals < 0):
                j = int(m.src_idx[np.argmax(vals < 0)])
                _fail("message forwards an undefined staging slot",
                      step=step.name, src=m.src, dst=m.dst, slot=j)
            dst_bufs[m.dst][m.dst_idx] = vals
            np.add.at(written[m.dst], m.dst_idx, 1)
            if step.writes_ghost:
                np.add.at(ghost_writes[m.dst], m.dst_idx, 1)
        for p in range(P):
            if np.any(written[p] > 1):
                j = int(np.argmax(written[p] > 1))
                _fail("two messages deliver into the same slot (duplicated "
                      "bytes)", step=step.name, rank=p, slot=j)
        if not step.writes_ghost:
            bufs = dst_bufs
            prev_out = np.asarray(step.out_sizes)
        verify_round_schedule(color_rounds(step.messages), step=step.name)

    for q, need in enumerate(pattern.needs):
        for j in range(len(need)):
            if ghost_writes[q][j] == 0:
                _fail("ghost slot never written (dropped value)", rank=q,
                      ghost_slot=j, global_index=int(need[j]))
            if ghost_writes[q][j] > 1:
                _fail("ghost slot written more than once (duplicated "
                      "value)", rank=q, ghost_slot=j,
                      global_index=int(need[j]),
                      writes=int(ghost_writes[q][j]))
            if ghost_ids[q][j] != need[j]:
                _fail("ghost slot received the wrong value", rank=q,
                      ghost_slot=j, expected=int(need[j]),
                      got=int(ghost_ids[q][j]))


# ---------------------------------------------------------------------------
# bound collectives (frozen device plans)
# ---------------------------------------------------------------------------


def verify_device_plan(dplan, pattern: CommPattern) -> None:
    """The frozen per-device index arrays agree with the pattern padding
    and every wire round's perm is a partial permutation."""
    n_local_pad = int(pattern.n_local.max()) if len(pattern.n_local) else 0
    ghost_pad = int(max((len(n) for n in pattern.needs), default=0))
    if dplan.n_local_pad != n_local_pad or dplan.ghost_pad != ghost_pad:
        _fail("device plan padding disagrees with the pattern",
              n_local_pad=dplan.n_local_pad, expected_local=n_local_pad,
              ghost_pad=dplan.ghost_pad, expected_ghost=ghost_pad)
    for st in dplan.steps:
        for r, rnd in enumerate(st.rounds):
            srcs = [s for s, _ in rnd.perm]
            dsts = [d for _, d in rnd.perm]
            if len(set(srcs)) != len(srcs):
                _fail("device round has a doubly-booked sender",
                      step=st.name, round=r,
                      rank=[s for s in srcs if srcs.count(s) > 1][0])
            if len(set(dsts)) != len(dsts):
                _fail("device round has a doubly-booked receiver",
                      step=st.name, round=r,
                      rank=[d for d in dsts if dsts.count(d) > 1][0])
            for g, s, what, pad in ((rnd.gather, rnd.scatter, "gather",
                                     st.in_pad),):
                pass
            if rnd.gather.shape != (dplan.n_procs, rnd.width) or \
                    rnd.scatter.shape != (dplan.n_procs, rnd.width):
                _fail("round index arrays not [P, width]", step=st.name,
                      round=r, width=rnd.width)
            if rnd.width and int(rnd.gather.max()) > st.in_pad:
                _fail("gather index beyond the sentinel slot", step=st.name,
                      round=r, index=int(rnd.gather.max()),
                      sentinel=st.in_pad)
            if rnd.width and int(rnd.scatter.max()) > st.out_pad:
                _fail("scatter index beyond the sentinel slot",
                      step=st.name, round=r, index=int(rnd.scatter.max()),
                      sentinel=st.out_pad)


def verify_collective(coll) -> None:
    """Everything a cached ``NeighborAlltoallV`` promises: a conserving,
    conflict-free plan plus a consistent frozen device plan."""
    verify_plan(coll.plan)
    verify_device_plan(coll.device_plan, coll.plan.pattern)


# ---------------------------------------------------------------------------
# partitions + device ELL forms (bucket exhaustiveness)
# ---------------------------------------------------------------------------


def verify_partition(part) -> None:
    """Every ghost column served by exactly one exchange slot.

    ``needs[p]`` must be strictly increasing (slot -> global column is then
    injective), entirely off-block, and referenced exactly as the ghost CSR
    block's column space; the attached CommPattern must be the one
    ``from_block_partition`` derives from the same needs/ownership.
    """
    P = part.n_procs
    n_cols = int(part.col_offsets[-1])
    for p in range(P):
        clo, chi = int(part.col_offsets[p]), int(part.col_offsets[p + 1])
        need = part.needs[p]
        if len(need):
            if np.any(np.diff(need) <= 0):
                j = int(np.argmax(np.diff(need) <= 0)) + 1
                _fail("needs not strictly increasing (a ghost column is "
                      "served by two exchange slots)", rank=p, ghost_slot=j,
                      global_column=int(need[j]))
            if need.min() < 0 or need.max() >= n_cols:
                _fail("ghost column outside the global column space",
                      rank=p, global_column=int(need.max()), n_cols=n_cols)
            inblock = (need >= clo) & (need < chi)
            if np.any(inblock):
                j = int(np.argmax(inblock))
                _fail("owned column listed as a ghost", rank=p,
                      ghost_slot=j, global_column=int(need[j]))
        gh = part.ghost[p]
        if gh.ncols != len(need):
            _fail("ghost block width disagrees with the exchange slot "
                  "count", rank=p, ghost_cols=gh.ncols, slots=len(need))
        if gh.nnz:
            gidx = gh.indices.astype(np.int64)
            if gidx.min() < 0 or gidx.max() >= len(need):
                _fail("ghost nonzero references a column no exchange slot "
                      "serves (dropped ghost column)", rank=p,
                      ghost_slot=int(gidx.max()), slots=len(need))
            unused = np.setdiff1d(np.arange(len(need)), np.unique(gidx))
        else:
            unused = np.arange(len(need))
        if len(unused):
            _fail("exchange slot serves no nonzero (dead ghost column)",
                  rank=p, ghost_slot=int(unused[0]),
                  global_column=int(need[int(unused[0])]))
        loc = part.local[p]
        if loc.ncols != chi - clo:
            _fail("local block width disagrees with the column block",
                  rank=p, local_cols=loc.ncols, block=chi - clo)
        if loc.nnz and (loc.indices.min() < 0 or
                        int(loc.indices.max()) >= chi - clo):
            _fail("local nonzero outside the owned column block", rank=p,
                  column=int(loc.indices.max()), block=chi - clo)
    pat = part.pattern
    if pat.n_procs != P:
        _fail("partition pattern has the wrong process count",
              pattern=pat.n_procs, partition=P)
    if not np.array_equal(pat.n_local, np.diff(part.col_offsets)):
        _fail("pattern n_local disagrees with the column ownership")
    for p in range(P):
        if not np.array_equal(pat.needs[p], part.needs[p]):
            _fail("pattern needs disagree with the partition needs", rank=p)
    # ownership must be the block partition over col_offsets
    want_owner = np.searchsorted(part.col_offsets, np.arange(n_cols),
                                 side="right") - 1
    if not np.array_equal(pat.owner_proc, want_owner):
        g = int(np.argmax(pat.owner_proc != want_owner))
        _fail("pattern ownership disagrees with the column blocks",
              global_column=g, owner=int(pat.owner_proc[g]),
              expected=int(want_owner[g]))
    verify_pattern(pat)


def _csr_triples(m, rows_shift=0):
    """(row, col, val) triples of a CSR block's nonzero entries."""
    if not m.nnz:
        return np.zeros((0, 2), np.int64), np.zeros(0)
    rows = m.row_indices().astype(np.int64) + rows_shift
    cols = m.indices.astype(np.int64)
    keep = m.data != 0
    return np.stack([rows[keep], cols[keep]], 1), m.data[keep]


def _multiset_equal(where: str, p: int, keys_a, vals_a, keys_b, vals_b,
                    what_a: str, what_b: str) -> None:
    def order(keys, vals):
        idx = np.lexsort((vals, keys[:, 1], keys[:, 0]))
        return keys[idx], vals[idx]

    ka, va = order(keys_a, vals_a)
    kb, vb = order(keys_b, vals_b)
    if len(ka) != len(kb):
        _fail(f"{where}: nonzero counts differ", rank=p,
              **{what_a: len(ka), what_b: len(kb)})
    if len(ka) and (not np.array_equal(ka, kb) or
                    not np.array_equal(va, vb)):
        bad = np.flatnonzero(
            np.any(ka != kb, axis=1) | (va != vb))[0]
        _fail(f"{where}: nonzero multiset mismatch", rank=p,
              row=int(ka[bad, 0]), slot=int(ka[bad, 1]))


def verify_device_ell(ell, part) -> None:
    """Flat ELL carries exactly the partition's nonzeros, once each, with
    padding entries pointing at the sentinel x slot with value zero."""
    if ell.row_pad != int(np.diff(part.offsets).max()):
        _fail("flat ELL row padding disagrees with the partition",
              row_pad=ell.row_pad)
    for p in range(part.n_procs):
        for blk, cols, vals, width, what in (
            (part.local[p], ell.local_cols[p], ell.local_vals[p],
             ell.in_pad, "local"),
            (part.ghost[p], ell.ghost_cols[p], ell.ghost_vals[p],
             ell.ghost_pad, "ghost"),
        ):
            live = vals != 0
            if np.any(cols[live] >= blk.ncols):
                r = int(np.argwhere(live & (cols >= blk.ncols))[0][0])
                _fail(f"flat ELL {what} entry references a column outside "
                      "the block", rank=p, row=r)
            if np.any(cols > width):
                _fail(f"flat ELL {what} column index beyond the sentinel",
                      rank=p, sentinel=width)
            r_idx, c_idx = np.nonzero(live)
            keys = np.stack([r_idx.astype(np.int64),
                             cols[live].astype(np.int64)], 1)
            ck, cv = _csr_triples(blk)
            _multiset_equal(f"flat ELL {what} block", p, keys, vals[live],
                            ck, cv, "ell_nnz", "csr_nnz")


def verify_ell_blocked(ell, part) -> None:
    """Every nonzero of the partition lands in exactly one ELL bucket slot
    (local buckets for local columns, trailing ghost buckets for exchange
    slots) and ``bucket_K`` bounds hold."""
    bc = ell.block_cols
    Cl, C, K = ell.n_local_buckets, ell.n_buckets, ell.K
    if ell.cols.shape != (ell.n_procs, ell.row_pad, C * K):
        _fail("blocked ELL arrays have the wrong shape",
              shape=ell.cols.shape)
    if int(ell.bucket_K.max(initial=0)) > K:
        _fail("bucket_K exceeds the uniform padded width",
              bucket=int(np.argmax(ell.bucket_K)), K=K)
    for p in range(part.n_procs):
        vals = ell.vals[p].reshape(ell.row_pad, C, K)
        cols = ell.cols[p].reshape(ell.row_pad, C, K)
        live = vals != 0
        if np.any(cols[live] >= bc) or np.any(cols[live] < 0):
            _fail("blocked ELL in-bucket index outside the bucket",
                  rank=p, block_cols=bc)
        r_idx, b_idx, k_idx = np.nonzero(live)
        # device-side nonzeros as (row, absolute x position)
        keys = np.stack(
            [r_idx.astype(np.int64),
             b_idx.astype(np.int64) * bc + cols[live].astype(np.int64)], 1)
        # per-bucket occupancy must respect the recorded bucket_K
        if len(r_idx):
            occ = np.bincount(r_idx * C + b_idx,
                              minlength=ell.row_pad * C)
            occ = occ.reshape(ell.row_pad, C).max(0)
            over = np.flatnonzero(occ > ell.bucket_K)
            if len(over):
                _fail("bucket holds more nonzeros than bucket_K records",
                      rank=p, bucket=int(over[0]), count=int(occ[over[0]]),
                      bucket_K=int(ell.bucket_K[over[0]]))
        # partition-side nonzeros in the same coordinates
        lk, lv = _csr_triples(part.local[p])
        gk, gv = _csr_triples(part.ghost[p])
        want_keys = np.concatenate([
            np.stack([lk[:, 0],
                      (lk[:, 1] // bc) * bc + lk[:, 1] % bc], 1)
            if len(lk) else np.zeros((0, 2), np.int64),
            np.stack([gk[:, 0],
                      (Cl + gk[:, 1] // bc) * bc + gk[:, 1] % bc], 1)
            if len(gk) else np.zeros((0, 2), np.int64),
        ])
        want_vals = np.concatenate([lv, gv])
        # local nonzeros must stay in local buckets, ghosts in ghost buckets
        bucket_of = keys[:, 1] // bc
        dev_is_ghost = bucket_of >= Cl
        n_ghost_dev = int(dev_is_ghost.sum())
        if n_ghost_dev != len(gv):
            _fail("blocked ELL ghost-bucket population disagrees with the "
                  "ghost block (duplicated or dropped bucket entries)",
                  rank=p, ell_ghost_nnz=n_ghost_dev, csr_ghost_nnz=len(gv))
        _multiset_equal("blocked ELL", p, keys, vals[live], want_keys,
                        want_vals, "ell_nnz", "csr_nnz")


# ---------------------------------------------------------------------------
# MoE dispatch plans (token conservation)
# ---------------------------------------------------------------------------


def verify_moe_plan(plan) -> None:
    """Geometry arithmetic of an ``MoEPlan``: replication, capacity and the
    region factorization must be internally consistent."""
    if plan.e_log <= 0 or plan.e_phys <= 0 or plan.ep_size <= 0:
        _fail("non-positive MoE geometry", e_log=plan.e_log,
              e_phys=plan.e_phys, ep_size=plan.ep_size)
    if plan.e_phys % plan.e_log != 0:
        _fail("physical experts not a whole replication of logical ones",
              e_phys=plan.e_phys, e_log=plan.e_log)
    if plan.e_phys % plan.ep_size != 0:
        _fail("physical experts do not pack evenly onto the EP group",
              e_phys=plan.e_phys, ep_size=plan.ep_size)
    if plan.e_per_dev * plan.ep_size != plan.e_phys:
        _fail("e_per_dev inconsistent with e_phys / ep_size",
              e_per_dev=plan.e_per_dev, e_phys=plan.e_phys,
              ep_size=plan.ep_size)
    if plan.capacity <= 0:
        _fail("non-positive expert capacity", capacity=plan.capacity)
    if plan.mode != "dense":
        if plan.region_size * plan.devs_per_region != plan.ep_size:
            _fail("region factorization does not cover the EP group",
                  region_size=plan.region_size,
                  devs_per_region=plan.devs_per_region,
                  ep_size=plan.ep_size)
        pair_bound = plan.devs_per_region * plan.e_per_dev * plan.capacity
        if plan.uniq_capacity > pair_bound:
            _fail("uniq_capacity exceeds the exact per-region bound",
                  uniq_capacity=plan.uniq_capacity, bound=pair_bound)
    if plan.top_k > plan.e_log:
        _fail("top_k exceeds the number of logical experts",
              top_k=plan.top_k, e_log=plan.e_log)


def verify_moe_dispatch(plan, tokens_per_lane: int) -> None:
    """Token conservation of the capacity-packed dispatch pattern.

    Synthesizes the plan's routing pattern and checks: every lane owns
    exactly ``tokens_per_lane`` token values; no token is shipped more than
    ``top_k`` times; no (source lane, destination device) pair exceeds the
    hard ``e_per_dev * capacity`` bound; and the transport plan built for
    the plan's own mode conserves the pattern end to end.
    """
    from ..core.locality import build_plan
    from ..models.moe import (
        STRATEGY_OF_MODE,
        dispatch_pattern,
        dispatch_topology,
    )

    verify_moe_plan(plan)
    if plan.mode == "dense":
        return
    pattern, _stats, _fp = dispatch_pattern(plan, int(tokens_per_lane))
    verify_pattern(pattern)
    if pattern.n_procs != plan.ep_size:
        _fail("dispatch pattern lane count disagrees with the EP group",
              lanes=pattern.n_procs, ep_size=plan.ep_size)
    if np.any(pattern.n_local != tokens_per_lane):
        q = int(np.argmax(pattern.n_local != tokens_per_lane))
        _fail("lane owns the wrong token count", rank=q,
              n_local=int(pattern.n_local[q]), tokens=tokens_per_lane)
    # each kept (token, k) pair is one push: a token value may be requested
    # at most top_k times across the whole group
    counts = np.zeros(pattern.n_global, dtype=np.int64)
    for need in pattern.needs:
        np.add.at(counts, need, 1)
    if counts.max(initial=0) > plan.top_k:
        g = int(np.argmax(counts))
        _fail("token shipped more often than top_k routes allow",
              global_index=g, copies=int(counts[g]), top_k=plan.top_k)
    # per (src lane, dst device): at most capacity per hosted expert
    bound = plan.e_per_dev * plan.capacity
    for q, need in enumerate(pattern.needs):
        if not len(need):
            continue
        per_src = np.bincount(pattern.owner_proc[need],
                              minlength=plan.ep_size)
        if per_src.max() > bound:
            src = int(np.argmax(per_src))
            _fail("capacity overflow: lane ships more tokens to a device "
                  "than its experts can seat", src=src, dst=q,
                  shipped=int(per_src.max()), bound=bound)
    cplan = build_plan(pattern, dispatch_topology(plan),
                       STRATEGY_OF_MODE[plan.mode])
    verify_plan(cplan, pattern)


# ---------------------------------------------------------------------------
# dense collective plans (conflict-freedom + contribution conservation)
# ---------------------------------------------------------------------------


def verify_dense_plan(plan) -> None:
    """Full check of a ``core.dense.DensePlan``.

    Structural: one segment per device, non-negative counts, in-range and
    duplicate-free segment lists.  Conflict-freedom: every round reduces to
    a :class:`Round` and must pass :func:`verify_round_schedule` (partial
    permutation = one well-formed ppermute).  Conservation: the schedule is
    executed symbolically with *contribution vectors* as payload —
    ``contrib[p][s]`` is the 0/1 vector of source devices whose
    contribution to segment ``s`` device ``p`` currently holds — and the
    final state must be exactly the collective's definition: allreduce →
    every device holds every contribution of every segment; reduce_scatter
    → device ``p`` holds every contribution of segment ``p``; allgatherv →
    every device holds exactly the owner's copy of every segment.
    """
    P = plan.topo.n_procs
    n_seg = len(plan.counts)
    if n_seg != P:
        _fail("dense plan must carry one segment per device",
              segments=n_seg, n_procs=P)
    if np.any(plan.counts < 0):
        s = int(np.argmax(plan.counts < 0))
        _fail("negative segment count", segment=s,
              count=int(plan.counts[s]))
    if plan.collective not in ("allreduce", "allgatherv", "reduce_scatter"):
        _fail("unknown dense collective", collective=plan.collective)

    for r, rnd in enumerate(plan.rounds):
        if len(rnd.segs) != len(rnd.pairs):
            _fail("dense round segment lists disagree with pair count",
                  round=r, pairs=len(rnd.pairs), segs=len(rnd.segs))
        for (src, dst), segs in zip(rnd.pairs, rnd.segs):
            if len(segs) and (segs.min() < 0 or segs.max() >= n_seg):
                _fail("dense round moves a segment outside the plan",
                      round=r, src=src, dst=dst,
                      segment=int(segs.max()), segments=n_seg)
            if len(np.unique(segs)) != len(segs):
                _fail("dense round sends a segment twice in one message",
                      round=r, src=src, dst=dst)
    verify_round_schedule(
        [Round(list(r.pairs), list(r.segs), list(r.segs))
         for r in plan.rounds],
        step=f"dense/{plan.collective}/{plan.variant}",
    )

    # symbolic execution with contribution-set payloads
    eye = np.eye(P, dtype=np.int64)
    if plan.collective == "allgatherv":
        contrib = [np.zeros((n_seg, P), dtype=np.int64) for _ in range(P)]
        for p in range(P):
            contrib[p][p] = eye[p]
    else:
        contrib = [np.tile(eye[p], (n_seg, 1)) for p in range(P)]
    for r, rnd in enumerate(plan.rounds):
        payloads = [
            (dst, segs, contrib[src][segs].copy())
            for (src, dst), segs in zip(rnd.pairs, rnd.segs)
        ]
        for dst, segs, pay in payloads:
            if rnd.reduce:
                contrib[dst][segs] += pay
            else:
                contrib[dst][segs] = pay

    ones = np.ones(P, dtype=np.int64)
    for p in range(P):
        if plan.collective == "allreduce":
            bad = np.flatnonzero(~(contrib[p] == ones).all(axis=1))
            if len(bad):
                s = int(bad[0])
                _fail("allreduce segment not an exact sum of all "
                      "contributions", rank=p, segment=s,
                      contributions=contrib[p][s].tolist())
        elif plan.collective == "reduce_scatter":
            if not np.array_equal(contrib[p][p], ones):
                _fail("reduce_scatter own segment not an exact sum of all "
                      "contributions", rank=p,
                      contributions=contrib[p][p].tolist())
        else:  # allgatherv
            if not np.array_equal(contrib[p], eye):
                s = int(np.argmax((contrib[p] != eye).any(axis=1)))
                _fail("allgatherv segment is not exactly the owner's copy "
                      "(dropped, duplicated or summed values)", rank=p,
                      segment=s, contributions=contrib[p][s].tolist())


# ---------------------------------------------------------------------------
# cache-insertion dispatch (the REPRO_VERIFY hook)
# ---------------------------------------------------------------------------


def verify_cache_value(ns: str, value) -> None:
    """Verify a value entering a ``PlanCache`` namespace.

    Collectives get the full plan + device-plan check; MoE plan entries
    (stored as ``(plan, init_seconds)``) get the geometry check — the
    token-level :func:`verify_moe_dispatch` needs the token count, which
    the cache does not see, and runs in ``verify_zoo`` / engine verify.
    Executor namespaces hold opaque callables; their jaxpr audit happens
    where the collective is still in scope (``PlanCache.executor``).
    """
    if ns == "collective":
        verify_collective(value)
    elif ns == "moe_plan":
        plan = value[0] if isinstance(value, tuple) else value
        if hasattr(plan, "e_phys"):
            verify_moe_plan(plan)
    elif ns == "dense_plan":
        # stored as ((DensePlan, DenseSelection), init_seconds) — unwrap
        # tuples until the object with a round schedule surfaces
        plan = value
        while isinstance(plan, tuple) and not hasattr(plan, "rounds"):
            plan = plan[0]
        if hasattr(plan, "rounds"):
            verify_dense_plan(plan)
