"""Batched serving engine: request queue -> fixed-slot batch -> decode loop.

A deliberately simple production pattern (static batch slots rather than
continuous batching): requests are admitted into free slots, the whole
batch prefills/decodes together, finished slots are recycled each step.
Because the decode step is a single compiled program over [B_slots, ...]
caches, admission/recycling never recompiles.

Per-slot bookkeeping keeps each sequence's own length; the shared
``cur_len`` passed to the model is the max across active slots, and
per-slot attention masking comes from the cache invariants (positions
beyond a slot's own length hold zeros written at admission time — their
keys are roped-zero vectors whose scores are finite but uniform; for
exactness the engine tracks per-slot validity and re-prefilliing a slot
resets its cache rows).  Greedy sampling only (argmax) — the framework's
focus is the communication layer.

Elastic serving (``elastic=True``): :meth:`ServeEngine.resize` drains the
decode loop mid-stream (every sequence already lives host-side as
prompt+generated), rebuilds the model on a mesh chosen by
``runtime.elastic.choose_mesh_shape`` for the surviving device count,
re-shards the weights with ``reshard_state`` (re-replicating expert
weights if the EP group size changed), re-plans the MoE dispatch through
the SAME plan cache (a grow-back to a seen geometry re-plans nothing),
and resumes by re-prefilling the surviving sequences — exact, because
admission re-prefill was already the engine's slot-recycling contract.
Each resize is recorded as a ``runtime.controller.ResizeEvent``.

Observability (``observe=True``): the engine enables the process-wide
``repro.obs`` layer, wraps every decode step in a span, tracks per-request
admit→finish latency histograms, and — every ``refit_every`` decode steps
— runs :func:`repro.models.serving.moe_exchange_probe` (the decode
dispatch pattern as a bare exchange), bridges the pure sample into its
``TraceRecorder`` through the obs span bridge, and re-fits
``MachineParams`` via ``profile.calibrate.fit_trace`` — the ROADMAP's
online-calibration loop, recorded as ``runtime.controller.RefitEvent`` s.
Spans never touch the numerics: obs-on decode output is bit-identical to
obs-off (asserted by ``tests/multidevice_progs/check_obs.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import default_plan_cache
from ..models import Model, serving
from ..obs import default_obs, now as _now
from ..profile.adapt import AdaptivePlanner, ReplanEvent

_OBS = default_obs()
_H_REQUEST = _OBS.histogram("serve/request_seconds",
                            "per-request admit->finish latency")
_C_STEPS = _OBS.counter("serve/steps", "engine steps taken")
_C_TOKENS = _OBS.counter("serve/tokens", "tokens decoded (all slots)")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [T] int32
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model: Model, params, batch_slots: int = 4,
                 max_len: int = 256, adaptive: bool = False,
                 drift_threshold: float = 0.3, drift_warmup: int = 2,
                 tracer=None, elastic: bool = False,
                 observe: bool = False, refit_every: int = 32):
        self.model = model
        self.params = params
        self.B = batch_slots
        self.max_len = max_len
        self.elastic = elastic
        self.resize_events: List[object] = []
        # online calibration (observe=True): every `refit_every` decode
        # steps, probe the dispatch exchange and refit MachineParams from
        # the tracer's pure samples; fitted params land here and on the
        # adaptive planner (so subsequent re-selections use measured rates)
        self.observe = observe
        self.refit_every = int(refit_every)
        self.refit_events: List[object] = []
        self.machine_params = None      # last fitted MachineParams
        self._step_count = 0
        self._admit_times: Dict[int, float] = {}
        if observe:
            if tracer is None:
                from ..profile.trace import TraceRecorder

                tracer = TraceRecorder()
            # enables the PROCESS-WIDE obs layer and attaches the tracer
            # as the span-bridge target (production steps feed fit_trace)
            _OBS.enable(tracer=tracer)
        # device-count -> (mesh shape, axis names) this engine has served
        # on: a grow-back to a seen count reuses that exact geometry, so
        # every plan/executor for it is still in the cache (ISSUE-7's
        # "grow-back re-plans nothing" contract)
        self._seen_geometries: Dict[int, tuple] = {
            int(model.mesh.devices.size): (tuple(model.mesh.devices.shape),
                                           tuple(model.mesh.axis_names)),
        }
        self._tracer = tracer
        self._drift_threshold = drift_threshold
        self._drift_warmup = drift_warmup
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.queue: List[Request] = []
        self.caches = None
        self.cur_len = 0
        self._next_tok = np.zeros((batch_slots, 1), np.int32)
        # dispatch planning is hoisted out of the decode loop: the engine's
        # decode token count is static (one token per slot), so the MoE
        # dispatch plan is built once here and every decode step hits it.
        # Prefill dispatch is planned ONCE for the worst case (B * max_len
        # tokens) and pinned: admission and elastic re-prefills at every
        # history length share one plan-cache entry (a grow-back to a seen
        # device count therefore re-plans nothing).
        self.plan_cache = default_plan_cache()
        self.moe_plan = None
        self.moe_prefill_plan = None
        self.planner: Optional[AdaptivePlanner] = None
        self.adaptive = adaptive and model.cfg.family == "moe"
        if model.cfg.family == "moe":
            self.moe_plan = self._warm_moe_plan()
            self.moe_prefill_plan = self._warm_prefill_plan()
        self._prefill = self._prefill_for(model)
        if self.adaptive:
            self.planner = self._make_planner()
        # decode executables keyed per plan geometry (fingerprint
        # stripped): an adaptive re-selection that lands on an
        # already-compiled geometry+mode swaps a dict entry — the
        # non-dispatch graph is not recompiled
        self._decode_fns: Dict[object, Callable] = {}
        self._decode = self._decode_for(self.moe_plan)

    def verify(self) -> Dict[str, int]:
        """Statically verify the engine's live MoE dispatch plans.

        Runs ``repro.verify``'s geometry + token-conservation checks over
        the decode-step and worst-case-prefill plans (dense/non-MoE
        families have nothing to dispatch and verify trivially).  Raises
        :class:`repro.verify.VerifyError` with a rank/slot diagnostic on
        the first violated invariant; returns check counts otherwise.
        Independent of ``REPRO_VERIFY`` — calling it is the opt-in.
        """
        from repro.verify import verify_moe_dispatch

        counts = {"moe_plans": 0}
        for plan, n_tokens in (
            (self.moe_plan, self.B),
            (self.moe_prefill_plan, self.B * self.max_len),
        ):
            if plan is None:
                continue
            verify_moe_dispatch(
                plan, serving.moe_tokens_per_lane(self.model, n_tokens)
            )
            counts["moe_plans"] += 1
        return counts

    def _warm_moe_plan(self):
        """Pre-plan the decode-step MoE dispatch through the same helper
        `_moe_ffn` keys with (n_tokens = batch_slots), so even the first
        decode step re-plans nothing."""
        return serving.moe_plan_for_model(self.model, self.B,
                                          cache=self.plan_cache)

    def _warm_prefill_plan(self):
        """Worst-case prefill dispatch plan (B * max_len tokens): one
        plan-cache entry covers every admission / elastic re-prefill
        regardless of history length (oversized capacity is exact — unused
        slots get zero combine weight)."""
        return serving.moe_plan_for_model(self.model, self.B * self.max_len,
                                          cache=self.plan_cache)

    def _prefill_for(self, model) -> Callable:
        plan = self.moe_prefill_plan
        return jax.jit(
            lambda p, i: serving.prefill(model, p, i, max_len=self.max_len,
                                         moe_plan=plan)
        )

    def _make_planner(self) -> AdaptivePlanner:
        return AdaptivePlanner(
            cfg=self.model.cfg,
            mesh=self.model.mesh,
            tokens_per_lane=serving.moe_tokens_per_lane(self.model, self.B),
            plan=self.moe_plan,
            threshold=self._drift_threshold,
            warmup=self._drift_warmup,
            # honor a user-pinned transport: re-plans re-fingerprint
            # under the measured histogram but keep the pinned mode;
            # only moe_mode="auto" lets drift migrate the transport
            mode=self.model.moe_mode,
            ep_over_pods=self.model.ep_over_pods,
            cap_factor=self.model.moe_cap_factor,
            cache=self.plan_cache,
            tracer=self._tracer,
        )

    def _decode_for(self, plan) -> Callable:
        """Decode executable for a dispatch plan, memoized by the
        fingerprint-stripped plan geometry (the compiled program depends
        on geometry + mode, never on the routing fingerprint — the same
        key discipline as ``moe_layer``'s executor cache, so a future
        geometry-changing re-plan correctly recompiles)."""
        model = self.model
        key = (dataclasses.replace(plan, fingerprint="")
               if (self.adaptive and plan is not None) else None)
        fn = self._decode_fns.get(key)
        if fn is None:
            if key is None:
                fn = jax.jit(
                    lambda p, i, c, n: serving.decode_step(model, p, i, c, n)
                )
            else:
                fn = jax.jit(
                    lambda p, i, c, n, _plan=plan: serving.decode_step(
                        model, p, i, c, n, moe_plan=_plan,
                        return_moe_stats=True,
                    )
                )
            self._decode_fns[key] = fn
        return fn

    @property
    def replan_events(self) -> List[ReplanEvent]:
        return self.planner.events if self.planner is not None else []

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self) -> bool:
        """Admit queued requests into free slots; (re)prefill the batch.

        Static-slot engine: admission triggers a batch prefill of the
        CURRENT prompts (active slots re-present their full history as the
        prompt), so every slot's cache is exact after admission."""
        free = [i for i, s in enumerate(self.slots) if s is None]
        if not free or not self.queue:
            return False
        while free and self.queue:
            req = self.queue.pop(0)
            self.slots[free.pop(0)] = req
            if _OBS.enabled:
                self._admit_times[req.rid] = _now()
        self._prefill_slots()
        return True

    def _prefill_slots(self) -> None:
        """(Re)prefill the batch from the slots' host-side histories.

        Used by admission AND by the elastic resume: each slot's full
        sequence (prompt + generated so far) re-presents as the prompt, so
        the caches are exact on whatever mesh the model currently runs."""
        # build the padded prompt batch: each slot's prompt + generated
        seqs = []
        for s in self.slots:
            if s is None:
                seqs.append(np.zeros((1,), np.int32))
            else:
                seqs.append(np.concatenate(
                    [s.prompt, np.asarray(s.generated, np.int32)]
                ))
        T = max(len(x) for x in seqs)
        toks = np.zeros((self.B, T), np.int32)
        for i, x in enumerate(seqs):
            toks[i, T - len(x):] = x  # right-align so last token is real
        with _OBS.span("serve/prefill", tokens=self.B * T, seq_len=T):
            logits, caches = self._prefill(
                self.params, {"tokens": jnp.asarray(toks)}
            )
        self.caches = caches
        self.cur_len = T
        self._next_tok = np.asarray(
            jnp.argmax(logits, axis=-1), np.int32
        )[:, None]

    # ------------------------------------------------------------- elastic
    def resize(self, n_devices: Optional[int] = None, devices=None,
               mesh=None, reason: str = "requested"):
        """Drain, rebuild on a new device set, and resume mid-decode.

        Pass the surviving ``n_devices`` (mesh chosen by
        ``runtime.elastic.choose_mesh_shape``, keeping the current TP
        degree when it still divides) or an explicit ``mesh``.  Weights
        are pulled to host, re-replicated if the EP group size changed,
        and ``reshard_state``-placed under the new model's specs; the MoE
        dispatch re-plans through the engine's plan cache (so a grow-back
        to a previously served geometry re-plans nothing); active
        sequences resume by re-prefilling their host-side histories —
        exact, per the admission contract.  Returns the recorded
        ``runtime.controller.ResizeEvent``.
        """
        assert self.elastic, "construct ServeEngine(..., elastic=True)"
        from ..runtime.controller import cache_delta_event
        from ..runtime.elastic import (
            MeshRequirements,
            choose_mesh_shape,
            make_mesh_from_devices,
            reshard_state,
        )

        old = self.model
        old_n = int(old.mesh.devices.size)
        # drain: every sequence already lives host-side in its Request
        # (prompt + generated); only the weights need to come off-mesh
        host_params = jax.device_get(self.params)
        before = self.plan_cache.counters()
        t0 = _now()
        with _OBS.span("serve/resize", reason=reason,
                       old_n=old_n) as sp:
            if mesh is None:
                seen = self._seen_geometries.get(int(n_devices))
                if seen is not None:
                    # a geometry this engine already served on: reusing it
                    # keeps every cached plan/executor valid (grow-back warm)
                    shape, axes = seen
                else:
                    old_tp = dict(zip(old.mesh.axis_names,
                                      old.mesh.devices.shape)).get("model", 1)
                    # divisors of a working TP degree still divide the model
                    req = MeshRequirements(model_divisors=old_tp,
                                           prefer_model=old_tp)
                    shape, axes = choose_mesh_shape(int(n_devices), req)
                mesh = make_mesh_from_devices(shape, axes, devices)
            self._seen_geometries[int(mesh.devices.size)] = (
                tuple(mesh.devices.shape), tuple(mesh.axis_names)
            )
            new_model = Model(
                old.cfg, mesh=mesh, moe_mode=old.moe_mode,
                ep_over_pods=old.ep_over_pods, remat=old.remat, fsdp=old.fsdp,
                moe_cap_factor=old.moe_cap_factor,
                scan_layers=old.scan_layers, seq_shard=old.seq_shard,
            )
            if old.cfg.family == "moe" and new_model.e_phys != old.e_phys:
                from ..models.moe import remap_expert_params

                e_log = old.cfg.n_experts
                host_params = dict(host_params)
                blocks = dict(host_params["blocks"])
                blocks["moe"] = remap_expert_params(
                    blocks["moe"], e_log,
                    old.e_phys // e_log, new_model.e_phys // e_log,
                )
                host_params["blocks"] = blocks
            self.model = new_model
            self.params = reshard_state(
                host_params, new_model.param_specs(), mesh
            )
            # compiled programs are mesh-bound: drop them, re-plan the dispatch
            # through the shared cache (the plans themselves may warm-hit)
            self._decode_fns = {}
            self.moe_plan = None
            self.moe_prefill_plan = None
            if new_model.cfg.family == "moe":
                self.moe_plan = self._warm_moe_plan()
                self.moe_prefill_plan = self._warm_prefill_plan()
            self._prefill = self._prefill_for(new_model)
            if self.adaptive:
                events = self.planner.events if self.planner is not None else []
                self.planner = self._make_planner()
                self.planner.events = events
            self._decode = self._decode_for(self.moe_plan)
            # resume: re-prefill the surviving sequences on the new mesh
            self.caches = None
            if any(s is not None for s in self.slots):
                self._prefill_slots()
            sp.set(new_n=int(mesh.devices.size))
        event = cache_delta_event(
            self.plan_cache, before, reason,
            old_n, int(mesh.devices.size), _now() - t0,
        )
        self.resize_events.append(event)
        return event

    def step(self) -> List[Request]:
        """One engine step: admit if possible, else decode one token for
        the active batch.  Returns requests completed this step."""
        finished: List[Request] = []
        self._step_count += 1
        _C_STEPS.inc()
        if any(s is None for s in self.slots) and self.queue:
            with _OBS.span("serve/admit", queued=len(self.queue)):
                self._admit()
        if self.caches is None:
            return finished
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return finished
        for i in active:
            self.slots[i].generated.append(int(self._next_tok[i, 0]))
        with _OBS.span("serve/decode_step", step=self._step_count,
                       cur_len=self.cur_len, active=len(active)):
            out = self._decode(
                self.params, {"tokens": jnp.asarray(self._next_tok)},
                self.caches, jnp.asarray(self.cur_len, jnp.int32),
            )
            if self.adaptive:
                logits, self.caches, moe_stats = out
                self._observe_moe(moe_stats)
            else:
                logits, self.caches = out
            self.cur_len += 1
            self._next_tok = np.asarray(
                jnp.argmax(logits, axis=-1), np.int32
            )[:, None]
        _C_TOKENS.inc(len(active))
        for i in active:
            s = self.slots[i]
            if (len(s.generated) >= s.max_new_tokens
                    or self.cur_len >= self.max_len - 1):
                s.done = True
                finished.append(s)
                self.slots[i] = None
                t_admit = self._admit_times.pop(s.rid, None)
                if t_admit is not None:
                    _H_REQUEST.observe(_now() - t_admit)
        if (self.observe and self.refit_every > 0
                and self._step_count % self.refit_every == 0):
            self._refit()
        return finished

    def _observe_moe(self, moe_stats) -> Optional[ReplanEvent]:
        """Feed one decode step's measured routing histogram to the
        adaptive planner; on a drift re-selection, swap the decode
        executable for the new plan's mode (compiled programs are reused
        per mode — migration does not recompile the non-dispatch graph
        for modes already seen)."""
        event = self.planner.observe(
            np.asarray(moe_stats["expert_counts"], dtype=np.float64)
        )
        if event is not None:
            self.moe_plan = self.planner.plan
            self._decode = self._decode_for(self.moe_plan)
            _OBS.event("serve/replan", step=event.step,
                       drift=float(event.drift), old_mode=event.old_mode,
                       new_mode=event.new_mode)
        return event

    def _refit(self):
        """Online re-calibration (the ROADMAP's closing loop): probe the
        live decode dispatch pattern as a *bare* exchange (no FFN compute,
        so the sample is pure), bridge it into the attached tracer via the
        obs span bridge, and re-fit ``MachineParams`` from every pure
        sample recorded so far.  Decode numerics are untouched — the probe
        runs on throwaway data and only ``machine_params`` / the adaptive
        planner's cost model are updated.  Returns the
        :class:`~repro.runtime.controller.RefitEvent`, or ``None`` when
        there is no MoE dispatch to probe or the fit did not converge."""
        if self.moe_plan is None or self._tracer is None:
            return None
        from ..profile.calibrate import fit_trace
        from ..runtime.controller import RefitEvent

        with _OBS.span("serve/refit", step=self._step_count) as sp:
            probed = serving.moe_exchange_probe(
                self.model, self.moe_plan, self.B, cache=self.plan_cache,
            )
            if probed is not None:
                plan, secs = probed
                # closing this span bridges (plan, secs) into the tracer
                # as a pure-exchange sample — same path production
                # exchange spans take — BEFORE fit_trace reads the trace
                with _OBS.span("serve/exchange_probe") as psp:
                    psp.set(plan=plan, pure_exchange=True, seconds=secs)
            ref = self.machine_params
            if ref is None and self.planner is not None:
                ref = self.planner.params
            kw = {} if ref is None else {"ref": ref}
            try:
                res = fit_trace(self._tracer, name="online-refit", **kw)
            except ValueError:
                sp.set(fitted=False, why="no pure samples")
                return None
            if not res.converged:
                sp.set(fitted=False, why="fit did not converge")
                return None
            self.machine_params = res.params
            if self.planner is not None:
                # subsequent drift re-selections price transports under
                # the *measured* rates
                self.planner.params = res.params
            event = RefitEvent(
                step=self._step_count,
                params_name=res.params.name,
                rel_rmse=float(res.gof.get("rel_rmse", float("nan"))),
                n_samples=int(res.n_samples),
            )
            self.refit_events.append(event)
            sp.set(fitted=True, params_name=event.params_name,
                   rel_rmse=event.rel_rmse, n_samples=event.n_samples)
            _OBS.event("serve/refit", step=event.step,
                       params_name=event.params_name,
                       rel_rmse=event.rel_rmse, n_samples=event.n_samples)
        return event

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_steps):
            done.extend(self.step())
            if not self.queue and all(s is None for s in self.slots):
                break
        return done
