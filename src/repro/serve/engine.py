"""Batched serving engine: request queue -> fixed-slot batch -> decode loop.

A deliberately simple production pattern (static batch slots rather than
continuous batching): requests are admitted into free slots, the whole
batch prefills/decodes together, finished slots are recycled each step.
Because the decode step is a single compiled program over [B_slots, ...]
caches, admission/recycling never recompiles.

Per-slot bookkeeping keeps each sequence's own length; the shared
``cur_len`` passed to the model is the max across active slots, and
per-slot attention masking comes from the cache invariants (positions
beyond a slot's own length hold zeros written at admission time — their
keys are roped-zero vectors whose scores are finite but uniform; for
exactness the engine tracks per-slot validity and re-prefilliing a slot
resets its cache rows).  Greedy sampling only (argmax) — the framework's
focus is the communication layer.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import default_plan_cache
from ..models import Model, serving


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [T] int32
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model: Model, params, batch_slots: int = 4,
                 max_len: int = 256):
        self.model = model
        self.params = params
        self.B = batch_slots
        self.max_len = max_len
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.queue: List[Request] = []
        self._prefill = jax.jit(
            lambda p, i: serving.prefill(model, p, i, max_len=max_len)
        )
        self._decode = jax.jit(
            lambda p, i, c, n: serving.decode_step(model, p, i, c, n)
        )
        self.caches = None
        self.cur_len = 0
        self._next_tok = np.zeros((batch_slots, 1), np.int32)
        # dispatch planning is hoisted out of the decode loop: the engine's
        # decode token count is static (one token per slot), so the MoE
        # dispatch plan is built once here and every decode step hits it
        self.plan_cache = default_plan_cache()
        if model.cfg.family == "moe":
            self._warm_moe_plan()

    def _warm_moe_plan(self) -> None:
        """Pre-plan the decode-step MoE dispatch through the same helper
        `_moe_ffn` keys with (n_tokens = batch_slots), so even the first
        decode step re-plans nothing."""
        serving.moe_plan_for_model(self.model, self.B,
                                   cache=self.plan_cache)

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self) -> bool:
        """Admit queued requests into free slots; (re)prefill the batch.

        Static-slot engine: admission triggers a batch prefill of the
        CURRENT prompts (active slots re-present their full history as the
        prompt), so every slot's cache is exact after admission."""
        free = [i for i, s in enumerate(self.slots) if s is None]
        if not free or not self.queue:
            return False
        while free and self.queue:
            self.slots[free.pop(0)] = self.queue.pop(0)
        # build the padded prompt batch: each slot's prompt + generated
        seqs = []
        for s in self.slots:
            if s is None:
                seqs.append(np.zeros((1,), np.int32))
            else:
                seqs.append(np.concatenate(
                    [s.prompt, np.asarray(s.generated, np.int32)]
                ))
        T = max(len(x) for x in seqs)
        toks = np.zeros((self.B, T), np.int32)
        for i, x in enumerate(seqs):
            toks[i, T - len(x):] = x  # right-align so last token is real
        logits, caches = self._prefill(
            self.params, {"tokens": jnp.asarray(toks)}
        )
        self.caches = caches
        self.cur_len = T
        self._next_tok = np.asarray(
            jnp.argmax(logits, axis=-1), np.int32
        )[:, None]
        return True

    def step(self) -> List[Request]:
        """One engine step: admit if possible, else decode one token for
        the active batch.  Returns requests completed this step."""
        finished: List[Request] = []
        if any(s is None for s in self.slots) and self.queue:
            self._admit()
        if self.caches is None:
            return finished
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return finished
        for i in active:
            self.slots[i].generated.append(int(self._next_tok[i, 0]))
        logits, self.caches = self._decode(
            self.params, {"tokens": jnp.asarray(self._next_tok)},
            self.caches, jnp.asarray(self.cur_len, jnp.int32),
        )
        self.cur_len += 1
        self._next_tok = np.asarray(
            jnp.argmax(logits, axis=-1), np.int32
        )[:, None]
        for i in active:
            s = self.slots[i]
            if (len(s.generated) >= s.max_new_tokens
                    or self.cur_len >= self.max_len - 1):
                s.done = True
                finished.append(s)
                self.slots[i] = None
        return finished

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_steps):
            done.extend(self.step())
            if not self.queue and all(s is None for s in self.slots):
                break
        return done
