"""Batched serving engine: request queue -> fixed-slot batch -> decode loop.

A deliberately simple production pattern (static batch slots rather than
continuous batching): requests are admitted into free slots, the whole
batch prefills/decodes together, finished slots are recycled each step.
Because the decode step is a single compiled program over [B_slots, ...]
caches, admission/recycling never recompiles.

Per-slot bookkeeping keeps each sequence's own length; the shared
``cur_len`` passed to the model is the max across active slots, and
per-slot attention masking comes from the cache invariants (positions
beyond a slot's own length hold zeros written at admission time — their
keys are roped-zero vectors whose scores are finite but uniform; for
exactness the engine tracks per-slot validity and re-prefilliing a slot
resets its cache rows).  Greedy sampling only (argmax) — the framework's
focus is the communication layer.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import default_plan_cache
from ..models import Model, serving
from ..profile.adapt import AdaptivePlanner, ReplanEvent


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [T] int32
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model: Model, params, batch_slots: int = 4,
                 max_len: int = 256, adaptive: bool = False,
                 drift_threshold: float = 0.3, drift_warmup: int = 2,
                 tracer=None):
        self.model = model
        self.params = params
        self.B = batch_slots
        self.max_len = max_len
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.queue: List[Request] = []
        self._prefill = jax.jit(
            lambda p, i: serving.prefill(model, p, i, max_len=max_len)
        )
        self.caches = None
        self.cur_len = 0
        self._next_tok = np.zeros((batch_slots, 1), np.int32)
        # dispatch planning is hoisted out of the decode loop: the engine's
        # decode token count is static (one token per slot), so the MoE
        # dispatch plan is built once here and every decode step hits it
        self.plan_cache = default_plan_cache()
        self.moe_plan = None
        self.planner: Optional[AdaptivePlanner] = None
        self.adaptive = adaptive and model.cfg.family == "moe"
        if model.cfg.family == "moe":
            self.moe_plan = self._warm_moe_plan()
        if self.adaptive:
            self.planner = AdaptivePlanner(
                cfg=model.cfg,
                mesh=model.mesh,
                tokens_per_lane=serving.moe_tokens_per_lane(model, self.B),
                plan=self.moe_plan,
                threshold=drift_threshold,
                warmup=drift_warmup,
                # honor a user-pinned transport: re-plans re-fingerprint
                # under the measured histogram but keep the pinned mode;
                # only moe_mode="auto" lets drift migrate the transport
                mode=model.moe_mode,
                ep_over_pods=model.ep_over_pods,
                cap_factor=model.moe_cap_factor,
                cache=self.plan_cache,
                tracer=tracer,
            )
        # decode executables keyed per plan geometry (fingerprint
        # stripped): an adaptive re-selection that lands on an
        # already-compiled geometry+mode swaps a dict entry — the
        # non-dispatch graph is not recompiled
        self._decode_fns: Dict[object, Callable] = {}
        self._decode = self._decode_for(self.moe_plan)

    def _warm_moe_plan(self):
        """Pre-plan the decode-step MoE dispatch through the same helper
        `_moe_ffn` keys with (n_tokens = batch_slots), so even the first
        decode step re-plans nothing."""
        return serving.moe_plan_for_model(self.model, self.B,
                                          cache=self.plan_cache)

    def _decode_for(self, plan) -> Callable:
        """Decode executable for a dispatch plan, memoized by the
        fingerprint-stripped plan geometry (the compiled program depends
        on geometry + mode, never on the routing fingerprint — the same
        key discipline as ``moe_layer``'s executor cache, so a future
        geometry-changing re-plan correctly recompiles)."""
        model = self.model
        key = (dataclasses.replace(plan, fingerprint="")
               if (self.adaptive and plan is not None) else None)
        fn = self._decode_fns.get(key)
        if fn is None:
            if key is None:
                fn = jax.jit(
                    lambda p, i, c, n: serving.decode_step(model, p, i, c, n)
                )
            else:
                fn = jax.jit(
                    lambda p, i, c, n, _plan=plan: serving.decode_step(
                        model, p, i, c, n, moe_plan=_plan,
                        return_moe_stats=True,
                    )
                )
            self._decode_fns[key] = fn
        return fn

    @property
    def replan_events(self) -> List[ReplanEvent]:
        return self.planner.events if self.planner is not None else []

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self) -> bool:
        """Admit queued requests into free slots; (re)prefill the batch.

        Static-slot engine: admission triggers a batch prefill of the
        CURRENT prompts (active slots re-present their full history as the
        prompt), so every slot's cache is exact after admission."""
        free = [i for i, s in enumerate(self.slots) if s is None]
        if not free or not self.queue:
            return False
        while free and self.queue:
            self.slots[free.pop(0)] = self.queue.pop(0)
        # build the padded prompt batch: each slot's prompt + generated
        seqs = []
        for s in self.slots:
            if s is None:
                seqs.append(np.zeros((1,), np.int32))
            else:
                seqs.append(np.concatenate(
                    [s.prompt, np.asarray(s.generated, np.int32)]
                ))
        T = max(len(x) for x in seqs)
        toks = np.zeros((self.B, T), np.int32)
        for i, x in enumerate(seqs):
            toks[i, T - len(x):] = x  # right-align so last token is real
        logits, caches = self._prefill(
            self.params, {"tokens": jnp.asarray(toks)}
        )
        self.caches = caches
        self.cur_len = T
        self._next_tok = np.asarray(
            jnp.argmax(logits, axis=-1), np.int32
        )[:, None]
        return True

    def step(self) -> List[Request]:
        """One engine step: admit if possible, else decode one token for
        the active batch.  Returns requests completed this step."""
        finished: List[Request] = []
        if any(s is None for s in self.slots) and self.queue:
            self._admit()
        if self.caches is None:
            return finished
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return finished
        for i in active:
            self.slots[i].generated.append(int(self._next_tok[i, 0]))
        out = self._decode(
            self.params, {"tokens": jnp.asarray(self._next_tok)},
            self.caches, jnp.asarray(self.cur_len, jnp.int32),
        )
        if self.adaptive:
            logits, self.caches, moe_stats = out
            self._observe_moe(moe_stats)
        else:
            logits, self.caches = out
        self.cur_len += 1
        self._next_tok = np.asarray(
            jnp.argmax(logits, axis=-1), np.int32
        )[:, None]
        for i in active:
            s = self.slots[i]
            if (len(s.generated) >= s.max_new_tokens
                    or self.cur_len >= self.max_len - 1):
                s.done = True
                finished.append(s)
                self.slots[i] = None
        return finished

    def _observe_moe(self, moe_stats) -> Optional[ReplanEvent]:
        """Feed one decode step's measured routing histogram to the
        adaptive planner; on a drift re-selection, swap the decode
        executable for the new plan's mode (compiled programs are reused
        per mode — migration does not recompile the non-dispatch graph
        for modes already seen)."""
        event = self.planner.observe(
            np.asarray(moe_stats["expert_counts"], dtype=np.float64)
        )
        if event is not None:
            self.moe_plan = self.planner.plan
            self._decode = self._decode_for(self.moe_plan)
        return event

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_steps):
            done.extend(self.step())
            if not self.queue and all(s is None for s in self.slots):
                break
        return done
