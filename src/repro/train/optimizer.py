"""AdamW (own implementation) + LR schedules + gradient clipping.

ZeRO-1 style: optimizer moments live in fp32 and are sharded over the
'data' axis (spec helper below) while bf16 params stay TP-sharded — the
standard memory layout for 1000+-chip runs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    schedule: str = "cosine"     # cosine | wsd | constant


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def lr_at(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (s + 1.0) / max(cfg.warmup_steps, 1))
    if cfg.schedule == "constant":
        decay = 1.0
    elif cfg.schedule == "wsd":
        # warmup-stable-decay: linear decay over the last 10%
        tail = 0.1 * cfg.total_steps
        decay = jnp.clip((cfg.total_steps - s) / jnp.maximum(tail, 1.0),
                         cfg.min_lr_frac, 1.0)
    else:  # cosine
        frac = jnp.clip(s / max(cfg.total_steps, 1), 0.0, 1.0)
        decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
            1 + jnp.cos(jnp.pi * frac)
        )
    return cfg.lr * warm * decay


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )
    zeros2 = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros, nu=zeros2)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(tree))
    )


def _is_matrix(p) -> bool:
    return p.ndim >= 2


def adamw_update(
    cfg: AdamWConfig,
    params,
    grads,
    state: OptState,
) -> Tuple[Any, OptState, Dict[str, jnp.ndarray]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = lr_at(cfg, state.step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if _is_matrix(p):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    mu = jax.tree.map(lambda t: t[1], out,
                      is_leaf=lambda t: isinstance(t, tuple))
    nu = jax.tree.map(lambda t: t[2], out,
                      is_leaf=lambda t: isinstance(t, tuple))
    return new_params, OptState(step, mu, nu), {"gnorm": gnorm, "lr": lr}


def opt_state_specs(params_abstract, param_specs, mesh_axes: Dict[str, int]):
    """ZeRO-1: shard fp32 moments over 'data' on the first dim that is both
    unsharded in the param spec and divisible by the data-axis size."""
    dp = mesh_axes.get("data", 1)

    def shard(leaf, spec: P):
        if dp <= 1:
            return spec
        entries = list(spec) if len(spec) else [None] * len(leaf.shape)
        while len(entries) < len(leaf.shape):
            entries.append(None)
        used = set()
        for e in entries:
            if isinstance(e, tuple):
                used.update(e)
            elif e is not None:
                used.add(e)
        if "data" in used:
            return spec
        for i, e in enumerate(entries):
            if e is None and leaf.shape[i] % dp == 0 and leaf.shape[i] > 0:
                entries[i] = "data"
                return P(*entries)
        return spec

    moment_specs = jax.tree.map(
        shard, params_abstract, param_specs,
        is_leaf=lambda s: isinstance(s, P),
    )
    return OptState(step=P(), mu=moment_specs, nu=moment_specs)
