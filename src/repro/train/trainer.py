"""Trainer: jitted train_step with TP/DP/EP sharding, microbatching,
remat, ZeRO-1 moments, optional error-feedback gradient compression.

``make_train_step(model, opt_cfg)`` returns (state_specs, train_step) where
train_step(state, batch) -> (state, metrics) is ready for jax.jit with
in_shardings/out_shardings derived from the specs — the same artifact the
multi-pod dry-run lowers and the real launcher executes.

Gradient sync (``TrainerConfig.grad_sync``): the default ``"jit"`` leaves
the data-parallel allreduce to GSPMD.  ``"auto"`` / ``"hier"`` / ``"ring"``
route it through an *explicit* plan-based dense allreduce
(``core.dense``) selected by the Section-5 cost model —
:func:`make_dp_train_step` builds the shard_map step, returns the
:class:`~repro.core.dense.DenseSelection` it recorded, and is numerically
equal to the implicit path (same mean-of-shard-means arithmetic).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree
from jax.sharding import NamedSharding, PartitionSpec as P

from ..compat import shard_map
from ..core import (
    TPU_V5E,
    DenseSelection,
    MachineParams,
    Topology,
    default_plan_cache,
    dense_round_runner,
    even_counts,
)
from ..models.lm import Model
from ..obs import default_obs
from .compression import ef_compress_tree, init_residual
from .optimizer import (
    AdamWConfig,
    OptState,
    adamw_update,
    init_opt_state,
    opt_state_specs,
)

_OBS = default_obs()

GRAD_SYNC_METHODS = ("jit", "auto", "hier", "ring")


class TrainState(NamedTuple):
    params: Any
    opt: OptState
    residual: Optional[Any]      # error-feedback state (None if off)


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    microbatches: int = 1        # gradient accumulation
    compress_grads: bool = False
    # "jit" (implicit GSPMD allreduce) | "auto" | "hier" | "ring"
    # (explicit plan-based dense allreduce, see make_dp_train_step)
    grad_sync: str = "jit"


def batch_specs(model: Model) -> Dict[str, P]:
    ba = model.batch_axes
    b = ba if len(ba) > 1 else (ba[0] if ba else None)
    fam = model.cfg.family
    d = {"labels": P(b, None)}
    if fam == "audio":
        d["enc_embeds"] = P(b, None, None)
        d["tokens"] = P(b, None)
    elif fam == "vlm":
        d["embeds"] = P(b, None, None)
        d["positions"] = P(b, None, None)
    else:
        d["tokens"] = P(b, None)
    return d


def make_train_state(model: Model, tcfg: TrainerConfig, seed: int = 0,
                     abstract: bool = False) -> TrainState:
    params = model.init_params(seed=seed, abstract=abstract)
    if abstract:
        opt = jax.eval_shape(init_opt_state, params)
        res = (jax.eval_shape(init_residual, params)
               if tcfg.compress_grads else None)
    else:
        opt = init_opt_state(params)
        res = init_residual(params) if tcfg.compress_grads else None
    return TrainState(params, opt, res)


def state_specs(model: Model, tcfg: TrainerConfig) -> TrainState:
    pspecs = model.param_specs()
    axes = dict(zip(model.mesh.axis_names, model.mesh.devices.shape))
    params_abs = model.init_params(abstract=True)
    ospecs = opt_state_specs(params_abs, pspecs, axes)
    rspecs = (jax.tree.map(lambda s: s, ospecs.mu)
              if tcfg.compress_grads else None)
    return TrainState(pspecs, ospecs, rspecs)


def make_train_step(model: Model, tcfg: TrainerConfig):
    """Returns train_step(state, batch) -> (new_state, metrics)."""

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def train_step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        mb = tcfg.microbatches
        if mb > 1:
            B = batch["tokens"].shape[0] if "tokens" in batch else \
                batch["embeds"].shape[0]
            assert B % mb == 0

            def micro(i, acc):
                grads_acc, loss_acc = acc
                sl = {
                    k: jax.lax.dynamic_slice_in_dim(v, i * (B // mb),
                                                    B // mb, axis=0)
                    for k, v in batch.items()
                }
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state.params, sl
                )
                grads_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), grads_acc, g
                )
                return grads_acc, loss_acc + l

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            grads, loss = jax.lax.fori_loop(
                0, mb, lambda i, acc: micro(i, acc),
                (zero, jnp.zeros((), jnp.float32)),
            )
            grads = jax.tree.map(lambda g: g / mb, grads)
            loss = loss / mb
            metrics_extra = {}
        else:
            (loss, metrics_extra), grads = jax.value_and_grad(
                loss_fn, has_aux=True
            )(state.params, batch)

        residual = state.residual
        if tcfg.compress_grads:
            grads, residual = ef_compress_tree(grads, residual)

        new_params, new_opt, om = adamw_update(
            tcfg.opt, state.params, grads, state.opt
        )
        metrics = {"loss": loss, **om}
        if isinstance(metrics_extra, dict):
            metrics.update({k: v for k, v in metrics_extra.items()})
        return TrainState(new_params, new_opt, residual), metrics

    return train_step


def _default_procs_per_region(n: int) -> int:
    for r in (4, 2, 1):
        if n % r == 0:
            return r
    return 1


def make_grad_sync(
    mesh,
    axis_name: str,
    n: int,
    method: str = "auto",
    procs_per_region: Optional[int] = None,
    cache=None,
    value_bytes: int = 8,
    params: MachineParams = TPU_V5E,
) -> Tuple[Callable, Any, DenseSelection]:
    """Explicit gradient-sync primitive: ``(sync, plan, selection)``.

    ``sync(flat)`` sums a per-device flat ``[m]`` vector (``m <= padded
    capacity``) across ``axis_name`` via a plan-based dense allreduce —
    for use *inside* a ``shard_map`` over that axis.  ``method`` pins the
    variant (``"hier"`` / ``"ring"``) or lets the cost model choose
    (``"auto"``); the plan comes through the shared :class:`PlanCache`
    ``dense_plan`` namespace, so repeated trainer builds re-plan nothing.
    """
    if method not in ("auto", "hier", "ring"):
        raise ValueError(
            f"grad_sync method {method!r} not in ('auto', 'hier', 'ring')"
        )
    n_dev = mesh.shape[axis_name]
    ppr = (procs_per_region if procs_per_region is not None
           else _default_procs_per_region(n_dev))
    topo = Topology(n_dev, ppr)
    cache = cache if cache is not None else default_plan_cache()
    with _OBS.span("train/grad_sync_plan", method=method, n=n,
                   n_dev=n_dev) as sp:
        plan, sel = cache.dense_collective(
            "allreduce", even_counts(n, n_dev), topo, variant=method,
            value_bytes=value_bytes, params=params,
        )
        sp.set(chosen=sel.chosen)
    run = dense_round_runner(plan, axis_name)
    n_seg, cmax = len(plan.counts), plan.cmax

    def sync(flat):
        m = flat.shape[0]
        if m > n_seg * cmax:
            raise ValueError(
                f"grad_sync built for {n_seg * cmax} values, got {m}"
            )
        buf = jnp.pad(flat, (0, n_seg * cmax - m)).reshape(n_seg, cmax)
        return run(buf).reshape(-1)[:m]

    return sync, plan, sel


def make_dp_train_step(
    loss_fn: Callable,
    template_params: Any,
    tcfg: TrainerConfig,
    mesh,
    axis_name: str = "dp",
    procs_per_region: Optional[int] = None,
    cache=None,
    machine: MachineParams = TPU_V5E,
):
    """Pure data-parallel train step with selectable gradient sync.

    ``loss_fn(params, batch) -> scalar`` must be a *mean over the leading
    batch axis* (equal shard sizes), so the global loss is the mean of
    per-shard losses and the global gradient the mean of per-shard
    gradients — which makes the explicit path (per-shard ``value_and_grad``
    under ``shard_map``, one plan-based dense allreduce of grads+loss,
    divide by the device count) numerically equal to the implicit GSPMD
    path (``grad_sync="jit"``: jit of the global loss with the batch
    sharded and params replicated).

    Returns ``(train_step, selection)`` where ``train_step(state, batch)
    -> (state, metrics)`` is jitted with the batch sharded over
    ``axis_name`` and ``selection`` is the recorded
    :class:`DenseSelection` (``None`` for the implicit path) — the
    trainer's analogue of ``DistOp`` recording ``kern=``/``ov=``.
    """
    method = tcfg.grad_sync
    if method not in GRAD_SYNC_METHODS:
        raise ValueError(
            f"grad_sync {method!r} not in {GRAD_SYNC_METHODS}"
        )
    n_dev = mesh.shape[axis_name]
    repl = NamedSharding(mesh, P())
    shard = NamedSharding(mesh, P(axis_name))

    def finish(state, loss, grads):
        new_params, new_opt, om = adamw_update(
            tcfg.opt, state.params, grads, state.opt
        )
        return (TrainState(new_params, new_opt, state.residual),
                {"loss": loss, **om})

    if method == "jit":

        def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
            return finish(state, loss, grads)

        return jax.jit(train_step, in_shardings=(repl, shard),
                       donate_argnums=(0,)), None

    flat0, unravel = ravel_pytree(template_params)
    n_flat = int(flat0.size)
    # one allreduce covers the gradient vector plus the loss scalar
    sync, _plan, sel = make_grad_sync(
        mesh, axis_name, n_flat + 1, method=method,
        procs_per_region=procs_per_region, cache=cache, params=machine,
    )

    def per_shard(params, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        flat, _ = ravel_pytree(grads)
        vec = jnp.concatenate([flat, loss[None].astype(flat.dtype)])
        return sync(vec) / n_dev

    mapped = shard_map(
        per_shard, mesh=mesh, in_specs=(P(), P(axis_name)),
        out_specs=P(), check_rep=False,
    )

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        avg = mapped(state.params, batch)
        grads = unravel(avg[:n_flat])
        loss = avg[n_flat]
        return finish(state, loss, grads)

    return jax.jit(train_step, in_shardings=(repl, shard),
                   donate_argnums=(0,)), sel


def jit_train_step(model: Model, tcfg: TrainerConfig):
    """jit with explicit in/out shardings (what dryrun.py lowers)."""
    if tcfg.grad_sync != "jit":
        raise ValueError(
            "jit_train_step is the implicit-GSPMD path; explicit "
            f"grad_sync={tcfg.grad_sync!r} is served by make_dp_train_step"
        )
    specs = state_specs(model, tcfg)
    bspecs = batch_specs(model)
    mesh = model.mesh

    def shardify(tree):
        return jax.tree.map(
            lambda s: NamedSharding(mesh, s), tree,
            is_leaf=lambda s: isinstance(s, P),
        )

    step = make_train_step(model, tcfg)
    return jax.jit(
        step,
        in_shardings=(shardify(specs), shardify(bspecs)),
        out_shardings=(shardify(specs), None),
        donate_argnums=(0,),
    ), specs
