"""Trainer: jitted train_step with TP/DP/EP sharding, microbatching,
remat, ZeRO-1 moments, optional error-feedback gradient compression.

``make_train_step(model, opt_cfg)`` returns (state_specs, train_step) where
train_step(state, batch) -> (state, metrics) is ready for jax.jit with
in_shardings/out_shardings derived from the specs — the same artifact the
multi-pod dry-run lowers and the real launcher executes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models.lm import Model
from .compression import ef_compress_tree, init_residual
from .optimizer import (
    AdamWConfig,
    OptState,
    adamw_update,
    init_opt_state,
    opt_state_specs,
)


class TrainState(NamedTuple):
    params: Any
    opt: OptState
    residual: Optional[Any]      # error-feedback state (None if off)


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    microbatches: int = 1        # gradient accumulation
    compress_grads: bool = False


def batch_specs(model: Model) -> Dict[str, P]:
    ba = model.batch_axes
    b = ba if len(ba) > 1 else (ba[0] if ba else None)
    fam = model.cfg.family
    d = {"labels": P(b, None)}
    if fam == "audio":
        d["enc_embeds"] = P(b, None, None)
        d["tokens"] = P(b, None)
    elif fam == "vlm":
        d["embeds"] = P(b, None, None)
        d["positions"] = P(b, None, None)
    else:
        d["tokens"] = P(b, None)
    return d


def make_train_state(model: Model, tcfg: TrainerConfig, seed: int = 0,
                     abstract: bool = False) -> TrainState:
    params = model.init_params(seed=seed, abstract=abstract)
    if abstract:
        opt = jax.eval_shape(init_opt_state, params)
        res = (jax.eval_shape(init_residual, params)
               if tcfg.compress_grads else None)
    else:
        opt = init_opt_state(params)
        res = init_residual(params) if tcfg.compress_grads else None
    return TrainState(params, opt, res)


def state_specs(model: Model, tcfg: TrainerConfig) -> TrainState:
    pspecs = model.param_specs()
    axes = dict(zip(model.mesh.axis_names, model.mesh.devices.shape))
    params_abs = model.init_params(abstract=True)
    ospecs = opt_state_specs(params_abs, pspecs, axes)
    rspecs = (jax.tree.map(lambda s: s, ospecs.mu)
              if tcfg.compress_grads else None)
    return TrainState(pspecs, ospecs, rspecs)


def make_train_step(model: Model, tcfg: TrainerConfig):
    """Returns train_step(state, batch) -> (new_state, metrics)."""

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def train_step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        mb = tcfg.microbatches
        if mb > 1:
            B = batch["tokens"].shape[0] if "tokens" in batch else \
                batch["embeds"].shape[0]
            assert B % mb == 0

            def micro(i, acc):
                grads_acc, loss_acc = acc
                sl = {
                    k: jax.lax.dynamic_slice_in_dim(v, i * (B // mb),
                                                    B // mb, axis=0)
                    for k, v in batch.items()
                }
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state.params, sl
                )
                grads_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), grads_acc, g
                )
                return grads_acc, loss_acc + l

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            grads, loss = jax.lax.fori_loop(
                0, mb, lambda i, acc: micro(i, acc),
                (zero, jnp.zeros((), jnp.float32)),
            )
            grads = jax.tree.map(lambda g: g / mb, grads)
            loss = loss / mb
            metrics_extra = {}
        else:
            (loss, metrics_extra), grads = jax.value_and_grad(
                loss_fn, has_aux=True
            )(state.params, batch)

        residual = state.residual
        if tcfg.compress_grads:
            grads, residual = ef_compress_tree(grads, residual)

        new_params, new_opt, om = adamw_update(
            tcfg.opt, state.params, grads, state.opt
        )
        metrics = {"loss": loss, **om}
        if isinstance(metrics_extra, dict):
            metrics.update({k: v for k, v in metrics_extra.items()})
        return TrainState(new_params, new_opt, residual), metrics

    return train_step


def jit_train_step(model: Model, tcfg: TrainerConfig):
    """jit with explicit in/out shardings (what dryrun.py lowers)."""
    specs = state_specs(model, tcfg)
    bspecs = batch_specs(model)
    mesh = model.mesh

    def shardify(tree):
        return jax.tree.map(
            lambda s: NamedSharding(mesh, s), tree,
            is_leaf=lambda s: isinstance(s, P),
        )

    step = make_train_step(model, tcfg)
    return jax.jit(
        step,
        in_shardings=(shardify(specs), shardify(bspecs)),
        out_shardings=(shardify(specs), None),
        donate_argnums=(0,),
    ), specs
