"""Synthetic-but-structured LM data pipeline.

Deterministic, seekable, shardable: every (step, data_shard) pair maps to a
unique slice of an infinite token stream, so restarts resume exactly and
elastic re-shards (different data-parallel size) never replay or skip data.
The stream is a mixture of Zipfian unigrams + repeated n-gram motifs so a
~100M model shows a real, declining loss curve (used by examples/train_lm).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    motif_len: int = 16
    n_motifs: int = 512
    motif_prob: float = 0.5


class TokenStream:
    """Stateless sampler: sample(step, shard, n_shards) -> (tokens, labels)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # motif table: recurring phrases the model can learn to complete
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_a)
        self.probs = probs / probs.sum()
        self.motifs = rng.integers(
            0, cfg.vocab, size=(cfg.n_motifs, cfg.motif_len)
        ).astype(np.int32)

    def _sample_doc(self, rng: np.random.Generator, length: int) -> np.ndarray:
        out = np.empty(length + 1, dtype=np.int32)
        i = 0
        while i < length + 1:
            if rng.random() < self.cfg.motif_prob:
                m = self.motifs[rng.integers(self.cfg.n_motifs)]
                take = min(len(m), length + 1 - i)
                out[i: i + take] = m[:take]
                i += take
            else:
                n = int(rng.integers(4, 32))
                take = min(n, length + 1 - i)
                out[i: i + take] = rng.choice(
                    self.cfg.vocab, size=take, p=self.probs
                )
                i += take
        return out

    def sample(
        self, step: int, shard: int, n_shards: int
    ) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        assert cfg.global_batch % n_shards == 0
        per = cfg.global_batch // n_shards
        toks = np.empty((per, cfg.seq_len), dtype=np.int32)
        labels = np.empty((per, cfg.seq_len), dtype=np.int32)
        for row in range(per):
            global_row = step * cfg.global_batch + shard * per + row
            rng = np.random.default_rng(
                np.random.SeedSequence([cfg.seed, 7919, global_row])
            )
            doc = self._sample_doc(rng, cfg.seq_len)
            toks[row] = doc[:-1]
            labels[row] = doc[1:]
        return {"tokens": toks, "labels": labels}

    def global_batch_at(self, step: int) -> Dict[str, np.ndarray]:
        return self.sample(step, 0, 1)


class Prefetcher:
    """Background-thread double-buffered prefetch of host batches."""

    def __init__(self, stream: TokenStream, n_shards: int = 1,
                 shard: int = 0, depth: int = 2):
        import queue
        import threading

        self.stream = stream
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def worker():
            step = 0
            while not self._stop.is_set():
                batch = stream.sample(step, shard, n_shards)
                self.q.put((step, batch))
                step += 1

        self.t = threading.Thread(target=worker, daemon=True)
        self.t.start()

    def __iter__(self):
        while True:
            yield self.q.get()

    def close(self):
        self._stop.set()
        try:
            self.q.get_nowait()
        except Exception:
            pass
