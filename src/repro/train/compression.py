"""Error-feedback int8 gradient compression for the slow (inter-pod) hop.

The locality principle of the paper applied to gradient reduction: the
intra-pod reduce-scatter runs at ICI speed and stays fp32; only the
pod-crossing exchange is compressed.  Error feedback (residual carried to
the next step) keeps the compression unbiased over time (1-bit Adam /
EF-SGD lineage).

compress(g) -> (int8 payload, fp32 scale); decompress reverses.  The
trainer keeps `residual` in the train state when compression is on.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def compress(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_compress_tree(grads, residual):
    """Apply error-feedback compression leafwise.
    Returns (decompressed grads as seen by the optimizer, new residual)."""

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, s = compress(gf)
        deq = decompress(q, s)
        return deq.astype(g.dtype), gf - deq

    out = jax.tree.map(one, grads, residual)
    g_new = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    r_new = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return g_new, r_new


def init_residual(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
