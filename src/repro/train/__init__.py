from .optimizer import AdamWConfig, OptState, adamw_update, init_opt_state, lr_at
from .data import DataConfig, Prefetcher, TokenStream
from .compression import compress, decompress, ef_compress_tree, init_residual
from .trainer import (
    TrainState,
    TrainerConfig,
    batch_specs,
    jit_train_step,
    make_train_state,
    make_train_step,
    state_specs,
)

__all__ = [
    "AdamWConfig", "OptState", "adamw_update", "init_opt_state", "lr_at",
    "DataConfig", "Prefetcher", "TokenStream",
    "compress", "decompress", "ef_compress_tree", "init_residual",
    "TrainState", "TrainerConfig", "batch_specs", "jit_train_step",
    "make_train_state", "make_train_step", "state_specs",
]
