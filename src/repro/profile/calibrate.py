"""Measured-rate calibration: fit MachineParams from a trace, report fit
quality, and compare Section-5 selections under fitted vs shipped rates.

The numeric fit lives in ``core.costmodel.fit_machine_params`` (the
piecewise-linear max-rate model alternated with nonnegative least squares);
this module is the orchestration around it: trace -> fit ->
:class:`CalibrationResult` (params + goodness-of-fit + shipped-vs-fitted
table), plus :func:`synthesize_trace` (the round-trip oracle: samples
generated *from* the cost model must fit back to the generating params —
tested in tests/test_profile_calibration.py and exercised by the CI
calibration smoke) and :func:`rate_probe_patterns` (a pattern set that
excites every fitted rate: intra latency/bandwidth, inter
latency/bandwidth, and the region injection cap).
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.costmodel import (
    MachineParams,
    RateSample,
    TPU_V5E,
    fit_machine_params,
    plan_time,
)
from ..core.locality import build_plan
from ..core.plan import CommPattern, CommPlan, Topology
from .trace import TraceRecorder

PARAM_FIELDS = ("alpha_intra", "beta_intra", "alpha_inter", "beta_inter",
                "region_injection_bw", "eager_bytes")


@dataclass
class CalibrationResult:
    """A fitted MachineParams plus how well it explains the trace."""

    params: MachineParams
    ref: MachineParams          # the shipped constants the fit started from
    gof: Dict[str, float]
    n_samples: int

    @property
    def converged(self) -> bool:
        return bool(self.gof.get("converged", 0.0)) and all(
            np.isfinite(getattr(self.params, f)) for f in PARAM_FIELDS
        )

    def table(self) -> str:
        """Fitted-vs-shipped MachineParams table (the README's lifecycle
        artifact): one row per rate, with the fitted/shipped ratio."""
        rows = [f"{'param':>20s} {'shipped':>12s} {'fitted':>12s} "
                f"{'ratio':>8s}"]
        for f in PARAM_FIELDS:
            a = float(getattr(self.ref, f))
            b = float(getattr(self.params, f))
            ratio = b / a if a else float("inf")
            rows.append(f"{f:>20s} {a:12.4g} {b:12.4g} {ratio:8.3f}")
        g = self.gof
        rows.append(
            f"fit: n={self.n_samples} rel_rmse={g['rel_rmse']:.3f} "
            f"r2={g['r2']:.3f} iters={int(g['outer_iters'])} "
            f"converged={bool(g['converged'])}"
        )
        return "\n".join(rows)

    def to_json(self) -> Dict:
        return {
            "fitted": dataclasses.asdict(self.params),
            "shipped": dataclasses.asdict(self.ref),
            "gof": self.gof,
            "n_samples": self.n_samples,
        }

    def save(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1)


def fit_trace(
    trace: TraceRecorder | Sequence[RateSample],
    name: str = "fitted",
    ref: MachineParams = TPU_V5E,
    pure_only: bool = True,
) -> CalibrationResult:
    """Fit MachineParams from a trace (or raw RateSamples).

    Only ``pure_exchange`` samples enter the fit by default: MoE dispatch
    wall times include expert compute and would bias the wire rates.
    Raises ``ValueError`` when the trace holds no usable samples.
    """
    if isinstance(trace, TraceRecorder):
        samples = trace.merged_rate_samples(pure_only=pure_only)
    else:
        samples = list(trace)
    params, gof = fit_machine_params(samples, name=name, ref=ref)
    return CalibrationResult(params=params, ref=ref, gof=gof,
                             n_samples=len(samples))


def synthesize_trace(
    plans: Sequence[CommPlan],
    params: MachineParams,
    label_prefix: str = "synthetic",
) -> TraceRecorder:
    """Trace whose seconds are the cost model's own predictions under
    ``params`` — the round-trip oracle: ``fit_trace`` on this trace must
    recover ``params`` (rates the plan set excites) to high precision."""
    tr = TraceRecorder()
    for i, plan in enumerate(plans):
        tr.record_plan(plan, plan_time(plan, params),
                       label=f"{label_prefix}/{i}", pure_exchange=True)
    return tr


def rate_probe_patterns(
    topo: Topology, n_per: int = 64
) -> List[Tuple[str, CommPattern]]:
    """Patterns that jointly excite all five fitted rates on ``topo``.

    * ``intra_latency``  — many 1-value messages inside one region
    * ``intra_band``     — one large message inside one region
    * ``inter_latency``  — many 1-value messages between two procs of
      different regions
    * ``inter_band``     — one large inter-region message from a single
      sender (per-proc bandwidth binds, not the shared injection cap)
    * ``injection``      — every proc of region 0 streams large messages
      out of the region (the summed bytes hit the injection cap)

    Requires at least two regions with at least two procs each for the
    full set; degenerate topologies get the subset that exists.
    """
    P = topo.n_procs
    ppr = topo.procs_per_region
    offsets = np.arange(P + 1) * n_per

    def empty_needs() -> List[np.ndarray]:
        return [np.empty(0, dtype=np.int64) for _ in range(P)]

    probes: List[Tuple[str, CommPattern]] = []

    if ppr > 1:
        # intra latency: proc 1..ppr-1 each need 1 value of proc 0
        needs = empty_needs()
        for q in range(1, ppr):
            needs[q] = np.array([0], dtype=np.int64)
        probes.append(
            ("intra_latency", CommPattern.from_block_partition(needs, offsets))
        )
        # intra bandwidth: proc 1 needs all of proc 0
        needs = empty_needs()
        needs[1] = np.arange(n_per, dtype=np.int64)
        probes.append(
            ("intra_band", CommPattern.from_block_partition(needs, offsets))
        )
    if topo.n_regions > 1:
        far = ppr  # first proc of region 1
        # inter latency: one value of each proc of region 0 -> proc `far`
        needs = empty_needs()
        needs[far] = np.array([p * n_per for p in range(ppr)], dtype=np.int64)
        probes.append(
            ("inter_latency", CommPattern.from_block_partition(needs, offsets))
        )
        # inter bandwidth: proc `far` needs all of proc 0 (single sender:
        # per-proc beta_inter binds before the region injection cap)
        needs = empty_needs()
        needs[far] = np.arange(n_per, dtype=np.int64)
        probes.append(
            ("inter_band", CommPattern.from_block_partition(needs, offsets))
        )
        # injection: every proc of region 0 sends its whole block to its
        # counterpart in region 1 -> region-0 summed egress binds the cap
        needs = empty_needs()
        for lr in range(ppr):
            needs[far + lr] = lr * n_per + np.arange(n_per, dtype=np.int64)
        probes.append(
            ("injection", CommPattern.from_block_partition(needs, offsets))
        )
    return probes


def probe_plans(
    topo: Topology,
    value_bytes: int = 8,
    strategies: Sequence[str] = ("standard",),
    n_per: int = 64,
) -> List[CommPlan]:
    """Built plans over :func:`rate_probe_patterns` (fit input helper)."""
    out = []
    for _label, pattern in rate_probe_patterns(topo, n_per=n_per):
        for strat in strategies:
            out.append(build_plan(pattern, topo, strat,
                                  value_bytes=value_bytes))
    return out


def selection_flips(
    labeled_patterns: Sequence[Tuple[str, CommPattern]],
    topo: Topology,
    shipped: MachineParams,
    fitted: MachineParams,
    value_bytes: int = 8,
    candidates: Optional[Sequence[str]] = None,
) -> List[Dict[str, str]]:
    """Section-5 selection under shipped vs fitted rates, side by side.

    Returns one row per pattern: label, the strategy each parameter set
    selects, and whether the choice flipped — the actionable output of the
    calibrate flow (``benchmarks.run --calibrate`` prints these rows).
    """
    from ..core.selection import select_plan

    kw = {"value_bytes": value_bytes}
    if candidates is not None:
        kw["candidates"] = tuple(candidates)
    rows = []
    for label, pattern in labeled_patterns:
        _p, rep_s = select_plan(pattern, topo, shipped, **kw)
        _p, rep_f = select_plan(pattern, topo, fitted, **kw)
        rows.append({
            "label": label,
            "shipped": rep_s.chosen,
            "fitted": rep_f.chosen,
            "flip": "yes" if rep_s.chosen != rep_f.chosen else "no",
        })
    return rows
