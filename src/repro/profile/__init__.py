"""Measured-rate profiling, cost-model calibration, adaptive re-planning.

The repo's first feedback loop from execution back into planning (MPI
Advance's thesis that portable communication optimization must *observe*
the actual machine, arXiv 2309.07337):

* :mod:`.trace` — :class:`TraceRecorder`: per-pattern timing/bytes/round
  samples keyed by the same fingerprints ``core.cache.PlanCache`` uses,
  with JSON export/import (hooks in ``amg.distributed`` and the measured
  benchmark paths).
* :mod:`.calibrate` — :func:`fit_trace`: least-squares fit of
  ``MachineParams`` from a trace (the numeric core lives in
  ``core.costmodel.fit_machine_params``), goodness-of-fit reporting,
  round-trip synthesis, and shipped-vs-fitted selection comparison.
* :mod:`.adapt` — :class:`AdaptivePlanner`: measured expert-histogram
  drift detection + MoE re-fingerprinting/re-selection, wired into
  ``serve.engine.ServeEngine(adaptive=True)``.
"""
from .trace import ExchangeSample, HistogramSample, StepSample, TraceRecorder
from .calibrate import (
    CalibrationResult,
    fit_trace,
    probe_plans,
    rate_probe_patterns,
    selection_flips,
    synthesize_trace,
)
from .adapt import AdaptivePlanner, ReplanEvent

__all__ = [
    "ExchangeSample", "HistogramSample", "StepSample", "TraceRecorder",
    "CalibrationResult", "fit_trace", "probe_plans", "rate_probe_patterns",
    "selection_flips", "synthesize_trace",
    "AdaptivePlanner", "ReplanEvent",
]
