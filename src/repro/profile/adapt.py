"""Adaptive MoE re-planning from measured routing histograms.

The dispatch plan a serve engine runs was fingerprinted from a synthesized
*uniform* routing (the steady state the aux loss drives toward).  Real
decode workloads drift — a domain shift concentrates tokens on few experts
— and the plan that was optimal for uniform routing may no longer be.
:class:`AdaptivePlanner` is the feedback loop: it consumes the measured
per-batch expert histograms ``models.moe.moe_dispatch_lane`` now surfaces,
detects drift against the histogram the current plan was planned for, and
re-fingerprints/re-selects through ``models.moe.moe_plan_from_histogram``
when the drift crosses a threshold.

Noise handling: observations are summed over a sliding ``window`` of
recent batches and compared as normalized distributions (total-variation
distance) against a reference formed from the ``warmup`` observations
after the last (re-)plan.  A single noisy decode batch moves the windowed
distribution by at most its share of the window mass, so tiny batches
cannot spuriously trigger re-planning, while a persistent shift fills the
window and crosses the threshold exactly once — the planner then
re-warms on the drifted regime, so continued drifted traffic does not
re-trigger.  Quantized fingerprints (``models.moe.quantize_histogram``)
make re-planning under an effectively unchanged distribution a plan-cache
*hit*.

``serve.engine.ServeEngine(adaptive=True)`` owns the wiring: it feeds every
decode step's histogram and swaps its per-mode decode executable on a
:class:`ReplanEvent` — compiled programs are keyed by transport mode, so
migrating back to an already-seen mode recompiles nothing.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from ..core.costmodel import MachineParams, TPU_V5E
from ..models.moe import MoEPlan, moe_plan_from_histogram


@dataclass
class ReplanEvent:
    """One histogram-drift re-selection."""

    step: int                 # observation index that triggered the re-plan
    drift: float              # total-variation distance vs the reference
    old_mode: str
    new_mode: str
    old_fingerprint: str
    new_fingerprint: str

    def __str__(self) -> str:
        flip = "" if self.old_mode == self.new_mode else "  (mode flip)"
        return (f"replan@obs{self.step}: drift={self.drift:.3f} "
                f"mode {self.old_mode} -> {self.new_mode}{flip} "
                f"fp {self.old_fingerprint[:8]} -> "
                f"{self.new_fingerprint[:8]}")


@dataclass
class AdaptivePlanner:
    """Observe measured expert histograms; re-plan on drift.

    ``observe(counts)`` is the single entry point: pass the per-batch
    logical-expert pair counts (``moe_layer(..., return_expert_counts=
    True)``'s fourth output, or any nonnegative histogram) and get back a
    :class:`ReplanEvent` when that observation pushed the accumulated
    distribution past ``threshold``, else ``None``.  ``plan`` always holds
    the current (possibly re-selected) :class:`MoEPlan`.
    """

    cfg: object                       # ArchConfig (n_experts, top_k, ...)
    mesh: object
    tokens_per_lane: int
    plan: MoEPlan
    threshold: float = 0.3            # total-variation trigger
    quantum: int = 64                 # histogram fingerprint resolution
    warmup: int = 2                   # observations forming the reference
    window: int = 8                   # sliding observation window
    mode: str = "auto"                # re-selection policy
    ep_over_pods: bool = True
    cap_factor: float = 1.25
    dedup_factor: Optional[float] = None
    params: MachineParams = TPU_V5E
    cache: Optional[object] = None    # PlanCache (default process-wide)
    tracer: Optional[object] = None   # TraceRecorder for histogram logging
    events: List[ReplanEvent] = field(default_factory=list)
    _recent: List[np.ndarray] = field(default_factory=list)  # window
    _ref: Optional[np.ndarray] = None
    _obs: int = 0                     # total observations
    _since: int = 0                   # observations since the last re-plan

    @staticmethod
    def tv_distance(a: np.ndarray, b: np.ndarray) -> float:
        """Total variation between two histograms (normalized first)."""
        a = np.asarray(a, dtype=np.float64).reshape(-1)
        b = np.asarray(b, dtype=np.float64).reshape(-1)
        sa, sb = float(a.sum()), float(b.sum())
        if sa <= 0 or sb <= 0:
            return 0.0
        return 0.5 * float(np.abs(a / sa - b / sb).sum())

    def observe(self, counts) -> Optional[ReplanEvent]:
        c = np.asarray(counts, dtype=np.float64).reshape(-1)
        if len(c) != self.cfg.n_experts:
            raise ValueError(
                f"histogram has {len(c)} bins, expected {self.cfg.n_experts}"
            )
        self._obs += 1
        self._since += 1
        if self.tracer is not None:
            self.tracer.record_histogram("moe/observed", c, step=self._obs)
        self._recent.append(c)
        if len(self._recent) > max(1, self.window):
            self._recent.pop(0)
        acc = np.sum(self._recent, axis=0)
        if self._since <= self.warmup or float(acc.sum()) <= 0:
            # reference = everything seen during (re-)warmup
            self._ref = acc.copy()
            return None
        if self._ref is None:
            self._ref = acc.copy()
            return None
        drift = self.tv_distance(acc, self._ref)
        if drift <= self.threshold:
            return None
        old = self.plan
        # the trigger-moment window straddles the transition; plan for the
        # *new* regime: the newest `warmup` observations, which carry the
        # drifted distribution undiluted by pre-drift mass
        tail = np.sum(self._recent[-max(1, self.warmup):], axis=0)
        new = moe_plan_from_histogram(
            self.cfg, self.mesh, self.tokens_per_lane, tail,
            mode=self.mode, quantum=self.quantum,
            ep_over_pods=self.ep_over_pods, cap_factor=self.cap_factor,
            dedup_factor=self.dedup_factor, params=self.params,
            cache=self.cache,
        )
        event = ReplanEvent(
            step=self._obs,
            drift=drift,
            old_mode=old.mode,
            new_mode=new.mode,
            old_fingerprint=old.fingerprint,
            new_fingerprint=new.fingerprint,
        )
        self.plan = new
        self.events.append(event)
        # re-warm on the new regime: the window clears and the next
        # ``warmup`` observations form the next reference, so continued
        # drifted traffic does not re-trigger against the pre-drift mix
        self._recent.clear()
        self._ref = None
        self._since = 0
        return event

    @property
    def observed(self) -> int:
        return self._obs

    def reference_fractions(self) -> Optional[np.ndarray]:
        if self._ref is None or self._ref.sum() <= 0:
            return None
        return self._ref / self._ref.sum()
