"""Low-overhead execution trace recorder — the measurement half of the
measured-vs-modeled feedback loop.

Every Section-5 decision in this repo is made by ``core.costmodel`` on
*published* MachineParams while the benchmarks already *measure* real
exchange timings; this module is where the two meet.  A
:class:`TraceRecorder` accumulates :class:`ExchangeSample` s — per-pattern
timing + the pattern's exact per-step/per-process traffic split by locality
class — keyed by the same content fingerprints ``core.cache.PlanCache``
uses, so a trace row is directly attributable to a cached plan.  Traces
export/import as JSON (CI uploads them as artifacts) and convert to
``core.costmodel.RateSample`` s for :func:`repro.profile.calibrate.fit_trace`.

Hook points (all optional, zero overhead when no tracer is passed):

* ``amg.distributed.DistributedHierarchy.measure_exchange_seconds(tracer=)``
* ``benchmarks.amg_comm.measured_device_exchange(tracer=)`` /
  ``measured_setup_exchange(tracer=)``
* ``benchmarks.moe_comm.measured_moe_dispatch(tracer=)`` (dispatch wall
  time includes expert compute, so those samples are recorded with
  ``pure_exchange=False`` and excluded from rate fitting by default)
* :meth:`TraceRecorder.wrap_executor` for ad-hoc executors.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import asdict, dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np

from ..core.cache import pattern_fingerprint
from ..core.costmodel import RateSample
from ..core.plan import CommPlan, PlanStats, StepStats, Topology, color_rounds

SCHEMA_VERSION = 1


@dataclass
class StepSample:
    """Exact per-process traffic of one plan step (see ``plan.StepStats``),
    plus the on-wire round count of its ppermute schedule."""

    name: str
    intra_msgs: List[int]
    inter_msgs: List[int]
    intra_vals: List[int]
    inter_vals: List[int]
    rounds: int = 0

    def to_step_stats(self) -> StepStats:
        return StepStats(
            self.name,
            np.asarray(self.intra_msgs, dtype=np.int64),
            np.asarray(self.inter_msgs, dtype=np.int64),
            np.asarray(self.intra_vals, dtype=np.int64),
            np.asarray(self.inter_vals, dtype=np.int64),
        )


@dataclass
class ExchangeSample:
    """One timed execution of one communication pattern."""

    fingerprint: str           # == cache.pattern_fingerprint of the pattern
    label: str                 # e.g. "amg/L2", "setup/L0/gather_A", "moe/a2a"
    strategy: str
    n_procs: int
    procs_per_region: int
    value_bytes: int
    seconds: float
    pure_exchange: bool = True  # False: timing includes non-wire compute
    steps: List[StepSample] = field(default_factory=list)

    def stats(self) -> PlanStats:
        return PlanStats([s.to_step_stats() for s in self.steps],
                         self.value_bytes)

    def topo(self) -> Topology:
        return Topology(self.n_procs, self.procs_per_region)

    def rate_sample(self) -> RateSample:
        return RateSample(self.stats(), self.topo(), self.seconds,
                          label=self.label)


@dataclass
class HistogramSample:
    """One observed per-expert routing histogram (MoE dispatch feed)."""

    label: str
    counts: List[float]
    step: int = 0


class TraceRecorder:
    """Accumulates exchange timings and routing histograms.

    Recording is append-only and cheap (one dataclass per observation;
    plan traffic arrays are copied once).  ``merged_rate_samples`` is the
    fitting view: one ``RateSample`` per (fingerprint, strategy,
    value_bytes) with the median of its measured seconds, so repeated
    timings of one pattern count as one observation instead of over-
    weighting the fit.
    """

    def __init__(self):
        self.samples: List[ExchangeSample] = []
        self.histograms: List[HistogramSample] = []

    # ------------------------------------------------------------ record
    def record_plan(
        self,
        plan: CommPlan,
        seconds: float,
        label: str = "",
        pure_exchange: bool = True,
        fingerprint: Optional[str] = None,
    ) -> ExchangeSample:
        """Record one timed execution of ``plan`` (the PlanCache identity —
        the pattern's content fingerprint — is derived unless given)."""
        fp = fingerprint if fingerprint is not None \
            else pattern_fingerprint(plan.pattern)
        steps = [
            StepSample(
                name=ss.name,
                intra_msgs=[int(v) for v in ss.intra_msgs],
                inter_msgs=[int(v) for v in ss.inter_msgs],
                intra_vals=[int(v) for v in ss.intra_vals],
                inter_vals=[int(v) for v in ss.inter_vals],
                rounds=len(color_rounds(st.messages)),
            )
            for st, ss in zip(plan.steps, plan.stats.steps)
        ]
        sample = ExchangeSample(
            fingerprint=fp,
            label=label,
            strategy=plan.strategy,
            n_procs=plan.topo.n_procs,
            procs_per_region=plan.topo.procs_per_region,
            value_bytes=plan.stats.value_bytes,
            seconds=float(seconds),
            pure_exchange=pure_exchange,
            steps=steps,
        )
        self.samples.append(sample)
        return sample

    def record_histogram(self, label: str, counts,
                         step: int = 0) -> HistogramSample:
        h = HistogramSample(
            label=label,
            counts=[float(c) for c in np.asarray(counts).reshape(-1)],
            step=int(step),
        )
        self.histograms.append(h)
        return h

    def wrap_executor(
        self, plan: CommPlan, fn: Callable, label: str = ""
    ) -> Callable:
        """Wrap a bound device executor so every call is timed (with
        ``block_until_ready``) and recorded against ``plan``'s pattern."""

        def timed(*args, **kwargs):
            import jax  # deferred: recording itself never needs jax

            t0 = time.perf_counter()
            out = fn(*args, **kwargs)
            # handles arrays AND pytree outputs (e.g. multi-output
            # dispatch executors) — a missed sync would record dispatch
            # enqueue time and silently skew the fitted rates
            jax.block_until_ready(out)
            self.record_plan(plan, time.perf_counter() - t0, label=label)
            return out

        return timed

    # ----------------------------------------------------------- views
    def merged_rate_samples(self, pure_only: bool = True) -> List[RateSample]:
        """One RateSample per (fingerprint, strategy, value_bytes), with
        the median measured seconds of that pattern's observations."""
        groups: Dict[tuple, List[ExchangeSample]] = {}
        for s in self.samples:
            if pure_only and not s.pure_exchange:
                continue
            groups.setdefault(
                (s.fingerprint, s.strategy, s.value_bytes, s.pure_exchange),
                [],
            ).append(s)
        out = []
        for members in groups.values():
            secs = float(np.median([m.seconds for m in members]))
            rep = members[0]
            out.append(RateSample(rep.stats(), rep.topo(), secs,
                                  label=rep.label))
        return out

    def per_proc_step_seconds(
        self, n_procs: int, pure_only: bool = True
    ) -> np.ndarray:
        """Per-host step *seconds* attributed from the recorded exchanges —
        the measured feed for ``runtime.straggler.StragglerDetector``.

        Each sample's wall seconds are split across processes by their
        share of the sample's total traffic (values moved, intra + inter,
        summed over plan steps): a host that moved 2x the values of the
        fleet is charged 2x the time.  Samples recorded on a different
        process count are skipped.  Returns ``[n_procs]`` seconds (zeros
        when no matching samples exist) — a *relative* load signal, not a
        literal wall clock: exchanges are synchronous, so true per-host
        time is unobservable from one-sided timings; traffic share is the
        deterministic proxy the detector thresholds against the median.
        """
        out = np.zeros(int(n_procs), dtype=float)
        for s in self.samples:
            if pure_only and not s.pure_exchange:
                continue
            if s.n_procs != n_procs:
                continue
            per = np.zeros(n_procs, dtype=float)
            for st in s.steps:
                per += np.asarray(st.intra_vals, dtype=float)
                per += np.asarray(st.inter_vals, dtype=float)
            tot = per.sum()
            if tot <= 0:
                out += s.seconds / n_procs
            else:
                out += s.seconds * (per / tot)
        return out

    def summary(self) -> Dict[str, int]:
        return {
            "samples": len(self.samples),
            "pure_samples": sum(1 for s in self.samples if s.pure_exchange),
            "patterns": len({s.fingerprint for s in self.samples}),
            "histograms": len(self.histograms),
        }

    # ------------------------------------------------------------- JSON
    def to_json(self) -> Dict:
        return {
            "schema_version": SCHEMA_VERSION,
            "samples": [asdict(s) for s in self.samples],
            "histograms": [asdict(h) for h in self.histograms],
        }

    @classmethod
    def from_json(cls, payload: Dict) -> "TraceRecorder":
        tr = cls()
        for d in payload.get("samples", []):
            steps = [StepSample(**sd) for sd in d.get("steps", [])]
            rest = {k: v for k, v in d.items() if k != "steps"}
            tr.samples.append(ExchangeSample(steps=steps, **rest))
        for d in payload.get("histograms", []):
            tr.histograms.append(HistogramSample(**d))
        return tr

    def save(self, path) -> None:
        """Atomic write (tmp + rename): a process killed mid-save can
        never leave a truncated trace that later fails ``fit_trace``.
        Accepts ``str`` or ``pathlib.Path``."""
        path = os.fspath(path)
        tmp = f"{path}.tmp-{os.getpid()}"
        try:
            with open(tmp, "w") as f:
                json.dump(self.to_json(), f, indent=1)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)

    @classmethod
    def load(cls, path) -> "TraceRecorder":
        with open(os.fspath(path)) as f:
            return cls.from_json(json.load(f))
