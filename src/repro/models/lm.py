"""Model assembly: one Model class covering all ten assigned architectures.

Families
--------
dense / vlm   : scan over homogeneous GQA transformer blocks; per-layer
                window array realizes gemma3's 5 local : 1 global pattern
                and Mixtral SWA; vlm consumes precomputed patch embeddings
                (frontend stub) + M-RoPE 3-D positions.
moe           : attention (GQA or MLA) + expert-parallel MoE FFN via
                ``models.moe`` (the paper's locality-aware dispatch);
                optional shared experts + leading dense layers (DeepSeek).
ssm           : scan over Mamba-2 SSD blocks.
hybrid        : zamba2 — (period x mamba -> shared attn block) segments;
                the two shared transformer blocks alternate and read
                concat(x, x_emb) (2d) as attention input.
audio         : seamless enc-dec — bidirectional encoder over stub frame
                embeddings; causal decoder with cross-attention.

Serving: prefill() fills per-layer caches (rolling window caches for
sliding-window layers — a window layer never allocates more than
``window`` KV slots, which is what makes gemma3/mixtral long_500k fit);
decode_step() advances one token with O(1) (SSM) or O(cache) (attn) work.

Sharding: ``param_specs()`` returns a PartitionSpec pytree (Megatron-style
TP over 'model', vocab-parallel embed/logits; expert weights over the EP
axes; everything replicated over 'pod'/'data' unless fsdp=True adds a
'data' shard on the large dims).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from .attention import (
    gqa_attention,
    gqa_cross_from_cache,
    gqa_project_out,
    gqa_project_qkv,
    init_gqa,
    init_mla,
    mla_attention,
    project_cross_kv,
)
from .blocks import dense_block, init_dense_block, init_mlp, mlp
from .common import ArchConfig, Initializer, rms_norm
from ..core import default_plan_cache
from .moe import (
    MoEPlan,
    init_moe,
    make_moe_plan,
    moe_layer,
    moe_param_specs,
    moe_plan_for,
)
from .ssm import init_mamba, init_mamba_state, mamba_block


def _stack_slice(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


class Model:
    def __init__(
        self,
        cfg: ArchConfig,
        mesh: Optional[Mesh] = None,
        moe_mode: str = "auto",
        ep_over_pods: bool = True,
        remat: bool = True,
        fsdp: bool = False,
        moe_cap_factor: float = 1.25,
        scan_layers: bool = True,
        seq_shard: bool = False,
    ):
        self.cfg = cfg
        from ..compat import make_mesh_auto
        self.mesh = mesh if mesh is not None else make_mesh_auto(
            (1, 1), ("data", "model")
        )
        self.moe_mode = moe_mode
        self.ep_over_pods = ep_over_pods
        self.remat = remat
        self.fsdp = fsdp
        self.moe_cap_factor = moe_cap_factor
        # scan_layers=False unrolls layer loops: bigger HLO, but
        # cost_analysis() counts every layer (scan bodies count once) —
        # the dry-run uses unrolled for truthful roofline terms.
        self.scan_layers = scan_layers
        # Megatron-style sequence sharding of the residual stream between
        # blocks: remat residuals shrink by the TP degree; the compiler
        # inserts all-gather (entering attention/mlp) + reduce-scatter
        # (leaving) — trading memory for ICI traffic.
        self.seq_shard = seq_shard
        axes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        self.batch_axes = tuple(
            a for a in ("pod", "data") if a in axes
        )
        if cfg.family == "moe":
            self.e_phys = self._probe_plan().e_phys
        else:
            self.e_phys = 0
        # per-layer window schedule (dense/vlm/moe)
        self.windows = np.array(
            [
                0 if cfg.layer_is_global(i) else cfg.window
                for i in range(cfg.n_layers)
            ],
            dtype=np.int32,
        ) if cfg.window and cfg.local_global_period else np.full(
            cfg.n_layers, cfg.window, dtype=np.int32
        )

    def _probe_plan(self, tokens_per_lane: int = 8) -> MoEPlan:
        """Geometry-only plan (e_phys / param sharding don't depend on the
        transport, so ``auto`` probes with the flat-a2a geometry)."""
        return make_moe_plan(
            self.cfg, self.mesh, tokens_per_lane,
            mode=("a2a" if self.moe_mode == "auto" else self.moe_mode),
            ep_over_pods=self.ep_over_pods,
        )

    # ------------------------------------------------------------------ init

    def init_params(self, seed: int = 0, abstract: bool = False) -> Dict:
        cfg = self.cfg
        init = Initializer(seed, cfg.dtype, abstract=abstract)
        p: Dict[str, Any] = {
            "embed": init.tensor((cfg.vocab, cfg.d_model), fan_in=cfg.d_model),
            "final_norm": init.tensor((cfg.d_model,), zero=True),
        }
        if not cfg.tie_embeddings:
            p["lm_head"] = init.tensor((cfg.d_model, cfg.vocab),
                                       fan_in=cfg.d_model)
        fam = cfg.family
        if fam in ("dense", "vlm"):
            p["blocks"] = init_dense_block(init, cfg, cfg.n_layers)
        elif fam == "moe":
            L = cfg.n_layers - cfg.first_dense_layers
            blocks = {
                "ln1": init.tensor((L, cfg.d_model), zero=True),
                "ln2": init.tensor((L, cfg.d_model), zero=True),
                "attn": (init_mla(init, cfg, L) if cfg.mla
                         else init_gqa(init, cfg, L)),
                "moe": init_moe(init, cfg, L, self.e_phys),
            }
            p["blocks"] = blocks
            if cfg.first_dense_layers:
                p["dense0"] = init_dense_block(
                    init, cfg, cfg.first_dense_layers
                )
        elif fam == "ssm":
            p["blocks"] = init_mamba(init, cfg, cfg.n_layers)
        elif fam == "hybrid":
            per = cfg.shared_attn_period
            n_seg = cfg.n_layers // per
            tail = cfg.n_layers - n_seg * per
            p["mamba_main"] = init_mamba(init, cfg, n_seg * per)
            p["mamba_tail"] = init_mamba(init, cfg, tail) if tail else {}
            shared = {
                "ln1": init.tensor((cfg.n_shared_attn_blocks, 2 * cfg.d_model),
                                   zero=True),
                "attn": init_gqa(init, cfg, cfg.n_shared_attn_blocks,
                                 d_in=2 * cfg.d_model),
                "ln2": init.tensor((cfg.n_shared_attn_blocks, cfg.d_model),
                                   zero=True),
                "mlp": init_mlp(init, cfg.d_model, cfg.d_ff,
                                cfg.n_shared_attn_blocks),
            }
            p["shared"] = shared
        elif fam == "audio":
            p["enc_blocks"] = init_dense_block(init, cfg, cfg.n_enc_layers)
            p["enc_norm"] = init.tensor((cfg.d_model,), zero=True)
            p["dec_blocks"] = init_dense_block(init, cfg, cfg.n_dec_layers,
                                               cross=True)
        else:
            raise ValueError(fam)
        return p

    # ---------------------------------------------------------------- specs

    def param_specs(self) -> Dict:
        cfg = self.cfg
        axes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        fsdp_ax = "data" if (self.fsdp and axes.get("data", 1) > 1) else None
        moe_plan = self._probe_plan() if cfg.family == "moe" else None
        moe_specs = moe_param_specs(cfg, moe_plan) if moe_plan else {}

        col = {"wq", "wk", "wv", "wz", "wx", "wB", "wC", "wdt",
               "w_gate", "w_up", "ws_gate", "ws_up", "w_uk", "w_uv"}
        row = {"wo", "w_down", "ws_down"}
        bias = {"bq", "bk", "bv"}

        def rule(path, leaf) -> P:
            names = [getattr(k, "key", getattr(k, "name", None))
                     for k in path]
            name = names[-1]
            under_moe = "moe" in names
            nd = len(leaf.shape)
            if under_moe and name in moe_specs:
                return moe_specs[name]
            if name == "embed":
                return P("model", fsdp_ax)
            if name == "lm_head":
                return P(fsdp_ax, "model")
            if name in col:
                lead = (None,) * (nd - 2)
                return P(*lead, fsdp_ax, "model")
            if name in row:
                lead = (None,) * (nd - 2)
                return P(*lead, "model", fsdp_ax)
            if name in bias:
                lead = (None,) * (nd - 1)
                return P(*lead, "model")
            if name in ("conv_x", "conv_B", "conv_C"):
                return P(None, None, "model")
            return P()  # norms, scalars, routers, w_dkv, A_log, D, ...

        params = self.init_params(abstract=True)
        return jax.tree_util.tree_map_with_path(rule, params)

    # -------------------------------------------------------------- forward

    def _positions(self, inputs: Dict, T: int, B: int):
        if "positions" in inputs:
            return inputs["positions"]
        if self.cfg.mrope_sections is not None:
            pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
            return jnp.broadcast_to(pos[:, None, :], (B, 3, T))
        return jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))

    def _embed_in(self, params, inputs) -> jnp.ndarray:
        if "embeds" in inputs:
            return inputs["embeds"].astype(self.cfg.dtype)
        x = params["embed"][inputs["tokens"]]
        return x * jnp.asarray(np.sqrt(self.cfg.d_model), x.dtype)

    def _logits(self, params, x):
        head = (params["embed"].T if self.cfg.tie_embeddings
                else params["lm_head"])
        logits = x @ head.astype(x.dtype)
        spec = P(self.batch_axes if len(self.batch_axes) > 1
                 else (self.batch_axes[0] if self.batch_axes else None),
                 None, "model")
        from jax.sharding import NamedSharding
        return jax.lax.with_sharding_constraint(
            logits, NamedSharding(self.mesh, spec)
        )

    def _maybe_remat(self, fn):
        return jax.checkpoint(fn) if self.remat else fn

    def _sp(self, x):
        """Sequence-shard the residual stream over 'model' (if enabled)."""
        if not self.seq_shard:
            return x
        axes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        if x.shape[1] % axes.get("model", 1):
            return x
        from jax.sharding import NamedSharding
        b = (self.batch_axes if len(self.batch_axes) > 1
             else (self.batch_axes[0] if self.batch_axes else None))
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(b, "model", None))
        )

    def _scan_or_loop(self, body, carry, xs):
        """lax.scan when scan_layers else an unrolled python loop.
        ``xs``: pytree stacked on the leading (layer) axis."""
        fn = self._maybe_remat(body)
        if self.scan_layers:
            carry, _ = jax.lax.scan(fn, carry, xs)
            return carry
        L = jax.tree.leaves(xs)[0].shape[0]
        for i in range(L):
            carry, _ = fn(carry, _stack_slice(xs, i))
        return carry

    def forward(self, params: Dict, inputs: Dict,
                return_hidden: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Training/eval forward. Returns (logits [B,S,V], aux loss);
        return_hidden=True returns the final-norm hidden states instead
        (the chunked xent projects them block-by-block)."""
        cfg = self.cfg
        if cfg.family == "audio":
            return self._forward_encdec(params, inputs, return_hidden)
        if "embeds" in inputs:
            B, T = inputs["embeds"].shape[:2]
        else:
            B, T = inputs["tokens"].shape
        x = self._embed_in(params, inputs)
        pos = self._positions(inputs, T, B)
        aux = jnp.zeros((), jnp.float32)

        if cfg.family in ("dense", "vlm"):
            win = jnp.asarray(self.windows)

            def body(h, per):
                p_l, w_l = per
                h, _ = dense_block(p_l, h, pos, cfg, window=w_l)
                return self._sp(h), None

            x = self._scan_or_loop(body, x, (params["blocks"], win))
        elif cfg.family == "moe":
            x, aux = self._forward_moe(params, x, pos)
        elif cfg.family == "ssm":
            def body(h, p_l):
                h, _ = mamba_block(p_l, h, cfg)
                return self._sp(h), None

            x = self._scan_or_loop(body, x, params["blocks"])
        elif cfg.family == "hybrid":
            x = self._forward_hybrid(params, x, pos)
        h = rms_norm(x, params["final_norm"])
        if return_hidden:
            return h, aux
        return self._logits(params, h), aux

    def _forward_moe(self, params, x, pos):
        cfg = self.cfg
        B, T = x.shape[0], x.shape[1]
        n_tok_dev = B * T // max(
            1, int(np.prod([dict(zip(self.mesh.axis_names,
                                     self.mesh.devices.shape))[a]
                            for a in self.batch_axes]))
        )
        axes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        lanes = axes["model"]
        # cached planning: repeated forwards on an unchanged mesh and token
        # count hit the plan cache (mode="auto" -> Section-5 selection)
        plan = moe_plan_for(
            cfg, self.mesh, max(1, n_tok_dev // lanes),
            mode=self.moe_mode, ep_over_pods=self.ep_over_pods,
            cap_factor=self.moe_cap_factor,
        )
        if cfg.first_dense_layers:
            for i in range(cfg.first_dense_layers):
                x, _ = dense_block(_stack_slice(params["dense0"], i), x, pos,
                                   cfg, window=0)

        def body(carry, p_l):
            h, aux = carry
            hn = rms_norm(h, p_l["ln1"])
            if cfg.mla:
                a, _ = mla_attention(p_l["attn"], hn, pos, cfg)
            else:
                a, _ = gqa_attention(p_l["attn"], hn, pos, cfg,
                                     window=cfg.window)
            h = h + a
            hn = rms_norm(h, p_l["ln2"])
            y, aux_l, _drop = moe_layer(hn, p_l["moe"], plan, cfg, self.mesh,
                                        self.batch_axes,
                                        cache=default_plan_cache())
            if cfg.n_shared_experts:
                y = y + mlp({"w_" + k[3:]: v for k, v in p_l["moe"].items()
                             if k.startswith("ws_")}, hn, cfg.act)
            return (h + y, aux + aux_l), None

        x, aux = self._scan_or_loop(
            body, (x, jnp.zeros((), jnp.float32)), params["blocks"]
        )
        return x, aux * self.cfg.router_aux_coef

    def _shared_attn_block(self, p_s, x, x0, pos):
        """zamba2 shared block: attention over concat(x, x0)."""
        cfg = self.cfg
        cat = jnp.concatenate([x, x0], axis=-1)
        h = rms_norm(cat, p_s["ln1"])
        q, k, v = gqa_project_qkv(p_s["attn"], h, pos, cfg)
        from ..kernels.flash_attention import attention as flash
        o = flash(q, k, v, causal=True)
        x = x + gqa_project_out(p_s["attn"], o, cfg)
        h = rms_norm(x, p_s["ln2"])
        return x + mlp(p_s["mlp"], h, cfg.act)

    def _forward_hybrid(self, params, x, pos):
        cfg = self.cfg
        per = cfg.shared_attn_period
        n_seg = cfg.n_layers // per
        x0 = x
        main = jax.tree.map(
            lambda a: a.reshape((n_seg, per) + a.shape[1:]),
            params["mamba_main"],
        )

        def seg_body(h, inp):
            seg_params, seg_idx = inp

            def inner(hh, p_l):
                hh, _ = mamba_block(p_l, hh, cfg)
                return hh, None

            if self.scan_layers:
                h, _ = jax.lax.scan(inner, h, seg_params)
            else:
                for j in range(per):
                    h, _ = inner(h, _stack_slice(seg_params, j))
            sb = jax.tree.map(
                lambda a: a[seg_idx % cfg.n_shared_attn_blocks],
                params["shared"],
            )
            h = self._shared_attn_block(sb, h, x0, pos)
            return h, None

        x = self._scan_or_loop(seg_body, x, (main, jnp.arange(n_seg)))
        if params.get("mamba_tail"):
            def tail_body(h, p_l):
                h, _ = mamba_block(p_l, h, cfg)
                return h, None
            x = self._scan_or_loop(tail_body, x, params["mamba_tail"])
        return x

    def _forward_encdec(self, params, inputs, return_hidden=False):
        cfg = self.cfg
        enc = inputs["enc_embeds"].astype(cfg.dtype)   # [B, Se, d] stub
        B, Se = enc.shape[:2]
        pos_e = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32), (B, Se))

        def ebody(h, p_l):
            h, _ = dense_block(p_l, h, pos_e, cfg, causal=False)
            return h, None

        enc = self._scan_or_loop(ebody, enc, params["enc_blocks"])
        memory = rms_norm(enc, params["enc_norm"])

        tokens = inputs["tokens"]
        B, T = tokens.shape
        x = self._embed_in(params, {"tokens": tokens})
        pos = self._positions(inputs, T, B)

        def dbody(h, p_l):
            h, _ = dense_block(p_l, h, pos, cfg, memory=memory)
            return h, None

        x = self._scan_or_loop(dbody, x, params["dec_blocks"])
        h = rms_norm(x, params["final_norm"])
        if return_hidden:
            return h, jnp.zeros((), jnp.float32)
        return self._logits(params, h), jnp.zeros((), jnp.float32)

    # ----------------------------------------------------------------- loss

    def _xent(self, x: jnp.ndarray, head: jnp.ndarray,
              labels: jnp.ndarray, mask: jnp.ndarray,
              block: int = 512) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Fused, vocab-parallel, sequence-chunked softmax cross entropy.

        Memory discipline for 256k vocabs: logits are produced per sequence
        block inside a checkpointed scan, so neither the [B,S,V] logits nor
        their f32 backward ever materialize (the projection is recomputed
        per block in the backward pass).  The vocab reduction never gathers:
        lse and the label logit are *reductions* over the model-sharded
        vocab dim (tiny [B,blk] all-reduces).
        Returns (ce_sum [scalar], z_sum [scalar]) — caller normalizes."""
        B, S, _ = x.shape
        if S % block or S <= block:
            block = S
        nb = S // block
        xb = jnp.moveaxis(x.reshape(B, nb, block, -1), 1, 0)
        lb = jnp.moveaxis(labels.reshape(B, nb, block), 1, 0)
        mb = jnp.moveaxis(mask.reshape(B, nb, block), 1, 0)

        def body(carry, inp):
            ce_sum, z_sum = carry
            xc, lc, mc = inp
            logits = xc @ head.astype(xc.dtype)          # [B, blk, V/tp]
            m = jax.lax.stop_gradient(
                jnp.max(logits, axis=-1, keepdims=True)
            ).astype(jnp.float32)
            ef = jnp.exp(logits.astype(jnp.float32) - m)
            lse = jnp.log(jnp.sum(ef, axis=-1)) + m[..., 0]
            iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
            ll = jnp.sum(
                jnp.where(iota == lc[..., None],
                          logits.astype(jnp.float32), 0.0),
                axis=-1,
            )
            ce_sum = ce_sum + jnp.sum((lse - ll) * mc)
            z_sum = z_sum + jnp.sum(jnp.square(lse) * mc)
            return (ce_sum, z_sum), None

        (ce_sum, z_sum), _ = jax.lax.scan(
            jax.checkpoint(body),
            (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            (xb, lb, mb),
        )
        return ce_sum, z_sum

    def loss(self, params: Dict, batch: Dict) -> Tuple[jnp.ndarray, Dict]:
        cfg = self.cfg
        x, aux = self.forward(params, batch, return_hidden=True)
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"])
        labels = batch["labels"]
        mask = batch.get("loss_mask", jnp.ones_like(labels, jnp.float32))
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        ce_sum, z_sum = self._xent(x, head, labels, mask)
        ce = ce_sum / denom
        zloss = 1e-4 * z_sum / denom
        total = ce + zloss + aux
        return total, {"ce": ce, "aux": aux, "zloss": zloss}
