from .common import ArchConfig, Initializer
from .lm import Model
from . import serving

__all__ = ["ArchConfig", "Initializer", "Model", "serving"]
