"""Serving: prefill + single-token decode for every family.

The serving forward uses a python loop over layers (not scan) so per-layer
cache shapes may differ: sliding-window layers allocate exactly ``window``
KV slots (rolling cache, left-aligned, roll-when-full) while global layers
allocate ``max_len``.  That asymmetry is what makes gemma3 / mixtral
long_500k decodable: only the global/full layers pay O(max_len) memory.

Cache invariants (attention layers):
  * slots [0, filled) hold the most recent ``filled`` tokens in order;
  * filled = min(cur_len, Lc); K entries are stored *post-RoPE* at their
    true positions, so relative attention survives eviction;
  * the flash kernel masks with kv_len=filled, q_offset=filled-1+T_new.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.flash_attention import attention as flash
from .attention import (
    gqa_project_out,
    gqa_project_qkv,
    mla_attention,
    project_cross_kv,
    gqa_cross_from_cache,
)
from ..core import default_plan_cache
from .blocks import mlp
from .common import rms_norm
from .lm import Model, _stack_slice
from .moe import moe_layer, moe_plan_for
from .ssm import init_mamba_state, mamba_block


# ---------------------------------------------------------------------------
# attention-layer cache ops
# ---------------------------------------------------------------------------


def _prefill_attn(p_l, x, pos, cfg, window, max_len):
    """Full-sequence attention; returns (out, (ck, cv, filled))."""
    B, T, _ = x.shape
    q, k, v = gqa_project_qkv(p_l, x, pos, cfg)
    o = flash(q, k, v, causal=True, window=window)
    out = gqa_project_out(p_l, o, cfg)
    Lc = window if window > 0 else max_len
    Hkv, dh = k.shape[1], k.shape[3]
    if T >= Lc:
        ck, cv = k[:, :, T - Lc:], v[:, :, T - Lc:]
        filled = Lc
    else:
        ck = jnp.zeros((B, Hkv, Lc, dh), k.dtype).at[:, :, :T].set(k)
        cv = jnp.zeros((B, Hkv, Lc, dh), v.dtype).at[:, :, :T].set(v)
        filled = T
    return out, {"k": ck, "v": cv}


def _decode_attn(p_l, x, cur, cfg, window, cache):
    """One-token attention against a rolling cache."""
    B = x.shape[0]
    pos = jnp.broadcast_to(cur[None, None], (B, 1)).astype(jnp.int32)
    if cfg.mrope_sections is not None:
        pos = jnp.broadcast_to(pos[:, None, :], (B, 3, 1))
    q, k, v = gqa_project_qkv(p_l, x, pos, cfg)   # k roped at true pos
    ck, cv = cache["k"], cache["v"]
    Lc = ck.shape[2]

    def append(args):
        ck, cv = args
        # literal 0s promote to int64 under jax_enable_x64 while `cur`
        # stays the caller's int32 — dynamic_update_slice requires one type
        zero = jnp.zeros((), cur.dtype)
        idx = (zero, zero, cur, zero)
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), idx)
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), idx)
        return ck, cv

    def roll(args):
        ck, cv = args
        ck = jnp.concatenate([ck[:, :, 1:], k.astype(ck.dtype)], axis=2)
        cv = jnp.concatenate([cv[:, :, 1:], v.astype(cv.dtype)], axis=2)
        return ck, cv

    ck, cv = jax.lax.cond(cur >= Lc, roll, append, (ck, cv))
    filled = jnp.minimum(cur + 1, Lc)
    o = flash(q, ck, cv, causal=True, kv_len=filled, q_offset=filled - 1)
    return gqa_project_out(p_l, o, cfg), {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# family dispatch: one layer (prefill or decode)
# ---------------------------------------------------------------------------


def moe_tokens_per_lane(model: Model, n_tokens: int) -> int:
    """Per-lane token count a forward of ``n_tokens`` global tokens
    dispatches — the single shape-derivation site shared by `_moe_ffn`,
    ``serve.engine``'s pre-warm and the adaptive re-planner, so the three
    can never key different plan-cache entries for one workload."""
    axes = dict(zip(model.mesh.axis_names, model.mesh.devices.shape))
    lanes = axes["model"]
    n_dev = max(1, int(np.prod([axes[a] for a in model.batch_axes])))
    return max(1, n_tokens // n_dev // lanes)


def moe_plan_for_model(model: Model, n_tokens: int, cache=None):
    """The dispatch plan a ``model`` forward uses for ``n_tokens`` global
    tokens — see :func:`moe_tokens_per_lane` for the shared shape key.

    Cached planning: every decode step (n_tokens=B) and every prefill of
    an equal prompt length key the same plan-cache entry — steady-state
    serving re-plans nothing."""
    return moe_plan_for(
        model.cfg, model.mesh, moe_tokens_per_lane(model, n_tokens),
        mode=model.moe_mode, ep_over_pods=model.ep_over_pods,
        cap_factor=model.moe_cap_factor, cache=cache,
    )


def moe_exchange_probe(
    model: Model,
    plan,
    n_tokens: int,
    cache=None,
    iters: int = 5,
    warmup: int = 1,
):
    """Time ``plan``'s dispatch pattern as a PURE exchange: (CommPlan,
    seconds_per_exchange), or None when there is nothing to probe (dense
    mode / non-MoE family).

    The online-calibration feed of ``ServeEngine(observe=True)``: decode
    dispatch wall time includes expert compute (recorded
    ``pure_exchange=False``, excluded from rate fits), so the engine
    periodically runs the *same routing pattern* as a bare neighborhood
    exchange on the EP devices — those samples are fit-grade.  The
    collective and its bound executor go through ``cache``, so repeated
    probes re-plan and re-bind nothing.  Synthetic f32 payload with
    ``d_model * itemsize`` bytes per value matches the plan's modeled
    wire volume.
    """
    from ..obs import now as _now
    from .moe import STRATEGY_OF_MODE, dispatch_pattern, dispatch_topology

    if plan is None or plan.mode not in STRATEGY_OF_MODE:
        return None
    cache = cache if cache is not None else default_plan_cache()
    pattern, _stats, _fp = dispatch_pattern(
        plan, moe_tokens_per_lane(model, n_tokens)
    )
    topo = dispatch_topology(plan)
    value_bytes = model.cfg.d_model * np.dtype(model.cfg.dtype).itemsize
    strategy = STRATEGY_OF_MODE[plan.mode]
    devs = np.asarray(model.mesh.devices).reshape(-1)[: topo.n_procs]
    mesh = jax.sharding.Mesh(devs, ("probe",))
    coll = cache.collective(pattern, topo, strategy, value_bytes)
    fn = jax.jit(cache.executor(pattern, topo, mesh, "probe",
                                strategy=strategy, value_bytes=value_bytes))
    # f32 payload, one value = d columns -> value_bytes on the wire
    d = max(1, value_bytes // 4)
    n_pad = max(1, int(pattern.n_local.max()))
    x = jnp.asarray(
        np.random.default_rng(0)
        .normal(size=(topo.n_procs, n_pad, d))
        .astype(np.float32)
    )
    fn(x).block_until_ready()          # compile
    for _ in range(warmup):
        fn(x).block_until_ready()
    t0 = _now()
    for _ in range(iters):
        fn(x).block_until_ready()
    return coll.plan, (_now() - t0) / iters


def _moe_ffn(model: Model, p_l, h, n_tokens, moe_plan=None, collect=False):
    """One MoE FFN sublayer.  ``moe_plan`` overrides the cached per-shape
    plan (the adaptive serving path pins a re-selected plan); with
    ``collect=True`` returns (y, expert_counts, dropped) so the decode
    loop can feed measured routing histograms to the re-planner."""
    cfg = model.cfg
    plan = moe_plan if moe_plan is not None \
        else moe_plan_for_model(model, n_tokens)
    out = moe_layer(h, p_l["moe"], plan, cfg, model.mesh,
                    model.batch_axes, cache=default_plan_cache(),
                    return_expert_counts=collect)
    y = out[0]
    if cfg.n_shared_experts:
        y = y + mlp({"w_" + k[3:]: v for k, v in p_l["moe"].items()
                     if k.startswith("ws_")}, h, cfg.act)
    if collect:
        return y, out[3], out[2]
    return y


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------


def prefill(model: Model, params: Dict, inputs: Dict, max_len: int,
            moe_plan=None):
    """Fill caches from a prompt. Returns (last_logits [B,V], cache).

    ``moe_plan`` pins the MoE dispatch plan instead of the per-(B*T)
    cached one — ``serve.engine`` plans prefill dispatch once for the
    worst case (B * max_len tokens) so re-prefills at every history
    length share a single plan-cache entry (capacity oversizes, results
    are unchanged: excess slots carry zero combine weight)."""
    cfg = model.cfg
    if cfg.family == "audio":
        return _prefill_encdec(model, params, inputs, max_len)
    x = model._embed_in(params, inputs)
    B, T = x.shape[:2]
    pos = model._positions(inputs, T, B)
    caches = []

    if cfg.family in ("dense", "vlm"):
        for i in range(cfg.n_layers):
            p_l = _stack_slice(params["blocks"], i)
            w = int(model.windows[i])
            h = rms_norm(x, p_l["ln1"])
            a, c = _prefill_attn(p_l["attn"], h, pos, cfg, w, max_len)
            if cfg.sandwich_norm:
                a = rms_norm(a, p_l["ln1_post"])
            x = x + a
            h = rms_norm(x, p_l["ln2"])
            m = mlp(p_l["mlp"], h, cfg.act)
            if cfg.sandwich_norm:
                m = rms_norm(m, p_l["ln2_post"])
            x = x + m
            caches.append(c)
    elif cfg.family == "moe":
        for i in range(cfg.first_dense_layers):
            p_l = _stack_slice(params["dense0"], i)
            h = rms_norm(x, p_l["ln1"])
            if cfg.mla:
                ckv0 = jnp.zeros(
                    (B, max_len, cfg.kv_lora + cfg.qk_rope_dim), cfg.dtype
                )
                a, ckv = mla_attention(p_l["attn"], h, pos, cfg,
                                       cache=ckv0, kv_len=0)
                c = {"ckv": ckv}
            else:
                a, c = _prefill_attn(p_l["attn"], h, pos, cfg, 0, max_len)
            x = x + a
            x = x + mlp(p_l["mlp"], rms_norm(x, p_l["ln2"]), cfg.act)
            caches.append(c)
        L = cfg.n_layers - cfg.first_dense_layers
        for i in range(L):
            p_l = _stack_slice(params["blocks"], i)
            h = rms_norm(x, p_l["ln1"])
            if cfg.mla:
                ckv0 = jnp.zeros(
                    (B, max_len, cfg.kv_lora + cfg.qk_rope_dim), cfg.dtype
                )
                a, ckv = mla_attention(p_l["attn"], h, pos, cfg,
                                       cache=ckv0, kv_len=0)
                c = {"ckv": ckv}
            else:
                a, c = _prefill_attn(p_l["attn"], h, pos, cfg, cfg.window,
                                     max_len)
            x = x + a
            h = rms_norm(x, p_l["ln2"])
            x = x + _moe_ffn(model, p_l, h, B * T, moe_plan=moe_plan)
            caches.append(c)
    elif cfg.family == "ssm":
        for i in range(cfg.n_layers):
            p_l = _stack_slice(params["blocks"], i)
            x, st = mamba_block(p_l, x, cfg, state=None,
                                return_state=True)
            caches.append(st)
    elif cfg.family == "hybrid":
        x0 = x
        per = cfg.shared_attn_period
        n_seg = cfg.n_layers // per
        li = 0
        for seg in range(n_seg):
            for j in range(per):
                p_l = _stack_slice(params["mamba_main"], li)
                x, st = mamba_block(p_l, x, cfg, return_state=True)
                caches.append(st)
                li += 1
            sb = _stack_slice(params["shared"],
                              seg % cfg.n_shared_attn_blocks)
            cat = jnp.concatenate([x, x0], axis=-1)
            h = rms_norm(cat, sb["ln1"])
            a, c = _prefill_attn(sb["attn"], h, pos, cfg, 0, max_len)
            x = x + a
            x = x + mlp(sb["mlp"], rms_norm(x, sb["ln2"]), cfg.act)
            caches.append(c)
        tail = cfg.n_layers - n_seg * per
        for j in range(tail):
            p_l = _stack_slice(params["mamba_tail"], j)
            x, st = mamba_block(p_l, x, cfg, return_state=True)
            caches.append(st)
    logits = model._logits(params, rms_norm(x[:, -1:], params["final_norm"]))
    return logits[:, 0], tuple(caches)


def _prefill_encdec(model: Model, params, inputs, max_len):
    cfg = model.cfg
    enc = inputs["enc_embeds"].astype(cfg.dtype)
    B, Se = enc.shape[:2]
    pos_e = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32), (B, Se))
    from .blocks import dense_block
    for i in range(cfg.n_enc_layers):
        p_l = _stack_slice(params["enc_blocks"], i)
        enc, _ = dense_block(p_l, enc, pos_e, cfg, causal=False)
    memory = rms_norm(enc, params["enc_norm"])

    tokens = inputs["tokens"]             # decoder prompt (BOS etc.)
    B, T = tokens.shape
    x = model._embed_in(params, {"tokens": tokens})
    pos = model._positions({}, T, B)
    caches = []
    for i in range(cfg.n_dec_layers):
        p_l = _stack_slice(params["dec_blocks"], i)
        h = rms_norm(x, p_l["ln1"])
        a, c = _prefill_attn(p_l["attn"], h, pos, cfg, 0, max_len)
        x = x + a
        hx = rms_norm(x, p_l["ln_x"])
        ckv = project_cross_kv(p_l["cross"], memory, cfg)
        x = x + gqa_cross_from_cache(p_l["cross"], hx, ckv, cfg)
        x = x + mlp(p_l["mlp"], rms_norm(x, p_l["ln2"]), cfg.act)
        caches.append({**c, "cross_k": ckv[0], "cross_v": ckv[1]})
    logits = model._logits(params, rms_norm(x[:, -1:], params["final_norm"]))
    return logits[:, 0], tuple(caches)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def decode_step(model: Model, params: Dict, inputs: Dict,
                caches: Tuple, cur_len, moe_plan=None,
                return_moe_stats: bool = False):
    """One-token step. ``inputs``: {"tokens": [B,1]} or {"embeds": [B,1,d]}.
    ``cur_len``: number of tokens already in the caches (traced scalar ok).
    Returns (logits [B, V], new caches); with ``return_moe_stats=True``
    (moe family) additionally a stats dict: ``expert_counts`` — the step's
    measured routing histogram summed over MoE layers ([e_log] f32, the
    adaptive re-planner's observation) — and ``dropped`` (mean capacity
    drop fraction over MoE layers).  ``moe_plan`` pins a dispatch plan
    (adaptive serving) instead of the per-shape cached lookup."""
    cfg = model.cfg
    cur = jnp.asarray(cur_len, jnp.int32)
    x = model._embed_in(params, inputs)
    B = x.shape[0]
    new_caches = []
    ci = 0
    moe_counts = None
    moe_drop = jnp.zeros((), jnp.float32)
    n_moe = 0

    def nxt():
        nonlocal ci
        c = caches[ci]
        ci += 1
        return c

    if cfg.family in ("dense", "vlm"):
        for i in range(cfg.n_layers):
            p_l = _stack_slice(params["blocks"], i)
            w = int(model.windows[i])
            h = rms_norm(x, p_l["ln1"])
            a, c = _decode_attn(p_l["attn"], h, cur, cfg, w, nxt())
            if cfg.sandwich_norm:
                a = rms_norm(a, p_l["ln1_post"])
            x = x + a
            h = rms_norm(x, p_l["ln2"])
            m = mlp(p_l["mlp"], h, cfg.act)
            if cfg.sandwich_norm:
                m = rms_norm(m, p_l["ln2_post"])
            x = x + m
            new_caches.append(c)
    elif cfg.family == "moe":
        pos = jnp.broadcast_to(cur[None, None], (B, 1)).astype(jnp.int32)
        for i in range(cfg.first_dense_layers):
            p_l = _stack_slice(params["dense0"], i)
            h = rms_norm(x, p_l["ln1"])
            if cfg.mla:
                c = nxt()
                a, ckv = mla_attention(p_l["attn"], h, pos, cfg,
                                       cache=c["ckv"], kv_len=cur)
                c = {"ckv": ckv}
            else:
                a, c = _decode_attn(p_l["attn"], h, cur, cfg, 0, nxt())
            x = x + a
            x = x + mlp(p_l["mlp"], rms_norm(x, p_l["ln2"]), cfg.act)
            new_caches.append(c)
        L = cfg.n_layers - cfg.first_dense_layers
        for i in range(L):
            p_l = _stack_slice(params["blocks"], i)
            h = rms_norm(x, p_l["ln1"])
            if cfg.mla:
                c = nxt()
                a, ckv = mla_attention(p_l["attn"], h, pos, cfg,
                                       cache=c["ckv"], kv_len=cur)
                c = {"ckv": ckv}
            else:
                a, c = _decode_attn(p_l["attn"], h, cur, cfg, cfg.window,
                                    nxt())
            x = x + a
            h = rms_norm(x, p_l["ln2"])
            if return_moe_stats:
                y, counts, drop = _moe_ffn(model, p_l, h, B,
                                           moe_plan=moe_plan, collect=True)
                moe_counts = counts if moe_counts is None \
                    else moe_counts + counts
                moe_drop = moe_drop + drop
                n_moe += 1
            else:
                y = _moe_ffn(model, p_l, h, B, moe_plan=moe_plan)
            x = x + y
            new_caches.append(c)
    elif cfg.family == "ssm":
        for i in range(cfg.n_layers):
            p_l = _stack_slice(params["blocks"], i)
            x, st = mamba_block(p_l, x, cfg, state=nxt())
            new_caches.append(st)
    elif cfg.family == "hybrid":
        x0 = x
        per = cfg.shared_attn_period
        n_seg = cfg.n_layers // per
        li = 0
        for seg in range(n_seg):
            for j in range(per):
                p_l = _stack_slice(params["mamba_main"], li)
                x, st = mamba_block(p_l, x, cfg, state=nxt())
                new_caches.append(st)
                li += 1
            sb = _stack_slice(params["shared"],
                              seg % cfg.n_shared_attn_blocks)
            cat = jnp.concatenate([x, x0], axis=-1)
            h = rms_norm(cat, sb["ln1"])
            a, c = _decode_attn(sb["attn"], h, cur, cfg, 0, nxt())
            x = x + a
            x = x + mlp(sb["mlp"], rms_norm(x, sb["ln2"]), cfg.act)
            new_caches.append(c)
        for j in range(cfg.n_layers - n_seg * per):
            p_l = _stack_slice(params["mamba_tail"], j)
            x, st = mamba_block(p_l, x, cfg, state=nxt())
            new_caches.append(st)
    elif cfg.family == "audio":
        for i in range(cfg.n_dec_layers):
            p_l = _stack_slice(params["dec_blocks"], i)
            c = nxt()
            h = rms_norm(x, p_l["ln1"])
            a, cc = _decode_attn(p_l["attn"], h, cur, cfg, 0,
                                 {"k": c["k"], "v": c["v"]})
            x = x + a
            hx = rms_norm(x, p_l["ln_x"])
            x = x + gqa_cross_from_cache(
                p_l["cross"], hx, (c["cross_k"], c["cross_v"]), cfg
            )
            x = x + mlp(p_l["mlp"], rms_norm(x, p_l["ln2"]), cfg.act)
            new_caches.append({**cc, "cross_k": c["cross_k"],
                               "cross_v": c["cross_v"]})
    logits = model._logits(params, rms_norm(x, params["final_norm"]))
    if return_moe_stats:
        if moe_counts is None:
            moe_counts = jnp.zeros((max(1, cfg.n_experts),), jnp.float32)
        stats = {
            "expert_counts": moe_counts,
            "dropped": moe_drop / max(1, n_moe),
        }
        return logits[:, 0], tuple(new_caches), stats
    return logits[:, 0], tuple(new_caches)
