"""Mamba-2 (SSD) block: projections, depthwise conv, SSD scan, gated norm.

Used by mamba2-780m (pure SSM stack) and zamba2-7b (hybrid backbone).
Serving keeps O(1) per-token state: (conv tail, SSM state) per layer.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels.ssd_scan import ssd, ssd_decode_step
from .common import ArchConfig, Initializer, rms_norm


def init_mamba(init: Initializer, cfg: ArchConfig, L: int) -> Dict:
    d, di = cfg.d_model, cfg.d_inner
    G, N, H = cfg.ssm_groups, cfg.ssm_state, cfg.n_ssm_heads
    K = cfg.d_conv
    return {
        "norm": init.tensor((L, d), zero=True),
        "wz": init.tensor((L, d, di), fan_in=d),
        "wx": init.tensor((L, d, di), fan_in=d),
        "wB": init.tensor((L, d, G * N), fan_in=d),
        "wC": init.tensor((L, d, G * N), fan_in=d),
        "wdt": init.tensor((L, d, H), fan_in=d),
        "conv_x": init.tensor((L, K, di), fan_in=K),
        "conv_B": init.tensor((L, K, G * N), fan_in=K),
        "conv_C": init.tensor((L, K, G * N), fan_in=K),
        "A_log": init.tensor((L, H), zero=True),       # A = -exp(A_log)
        "D": init.tensor((L, H), zero=True),
        "dt_bias": init.tensor((L, H), zero=True),
        "out_norm": init.tensor((L, di), zero=True),
        "wo": init.tensor((L, di, d), fan_in=di),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray,
                 tail: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Depthwise causal conv. x: [B, T, Cdim], w: [K, Cdim].
    ``tail``: [B, K-1, Cdim] cached inputs for decode."""
    K = w.shape[0]
    pad = (
        jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
        if tail is None else tail.astype(x.dtype)
    )
    xp = jnp.concatenate([pad, x], axis=1)              # [B, T+K-1, C]
    out = sum(
        xp[:, i: i + x.shape[1]] * w[i][None, None, :] for i in range(K)
    )
    return jax.nn.silu(out)


def _final_ssm_state(xc, dt, A, Bc, Cc, cfg):
    """State after consuming the whole sequence (prefill -> decode handoff).
    xc: [B,T,H,P], dt: [B,T,H], Bc: [B,T,G,N] -> [B,H,N,P] (f32)."""
    H = cfg.n_ssm_heads
    G = cfg.ssm_groups
    Bh = jnp.repeat(Bc, H // G, axis=2).astype(jnp.float32)  # [B,T,H,N]
    la = dt * A[None, None, :]                               # [B,T,H]
    rev = jnp.sum(la, axis=1, keepdims=True) - jnp.cumsum(la, axis=1)
    w = jnp.exp(rev) * dt                                    # decay s -> T
    return jnp.einsum("bthn,bthp->bhnp", Bh * w[..., None],
                      xc.astype(jnp.float32))


def mamba_block(
    p: Dict,                     # single-layer slice
    x: jnp.ndarray,              # [B, T, d]
    cfg: ArchConfig,
    state: Optional[Dict] = None,  # decode: {"conv": [B,K-1,Cc], "ssm": [B,H,N,P]}
    return_state: bool = False,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    B, T, d = x.shape
    G, N, H = cfg.ssm_groups, cfg.ssm_state, cfg.n_ssm_heads
    P = cfg.ssm_head_dim
    h = rms_norm(x, p["norm"])
    z = h @ p["wz"]                                     # [B, T, di]
    xin = h @ p["wx"]
    Bin = h @ p["wB"]
    Cin = h @ p["wC"]
    dt = jax.nn.softplus(h.astype(jnp.float32) @ p["wdt"].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # [B, T, H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))        # [H]

    new_state = None
    if state is None:
        xc = _causal_conv(xin, p["conv_x"])
        Bc = _causal_conv(Bin, p["conv_B"])
        Cc = _causal_conv(Cin, p["conv_C"])
        y = ssd(
            xc.reshape(B, T, H, P),
            dt,
            A,
            Bc.reshape(B, T, G, N),
            Cc.reshape(B, T, G, N),
        )                                               # [B, T, H, P]
        if return_state:
            K = cfg.d_conv
            conv_in = jnp.concatenate([xin, Bin, Cin], axis=-1)
            pad = jnp.zeros(
                (B, max(0, K - 1 - T), conv_in.shape[-1]), conv_in.dtype
            )
            tail = jnp.concatenate([pad, conv_in[:, -(K - 1):]], axis=1)
            S = _final_ssm_state(
                xc.reshape(B, T, H, P), dt, A,
                Bc.reshape(B, T, G, N), Cc.reshape(B, T, G, N), cfg,
            )
            new_state = {"conv": tail, "ssm": S}
    else:
        conv_in = jnp.concatenate([xin, Bin, Cin], axis=-1)  # [B, 1, Cc]
        tail = state["conv"]                                 # [B, K-1, Cc]
        full = jnp.concatenate([tail, conv_in], axis=1)
        w = jnp.concatenate([p["conv_x"], p["conv_B"], p["conv_C"]], axis=-1)
        out = sum(full[:, i: i + 1] * w[i][None, None] for i in range(cfg.d_conv))
        out = jax.nn.silu(out)[:, 0]                         # [B, Cc]
        di = cfg.d_inner
        xc = out[:, :di]
        Bc = out[:, di: di + G * N]
        Cc = out[:, di + G * N:]
        S, yh = ssd_decode_step(
            state["ssm"],
            xc.reshape(B, H, P),
            dt[:, 0],
            A,
            Bc.reshape(B, G, N),
            Cc.reshape(B, G, N),
        )
        y = yh.reshape(B, 1, H, P)
        new_state = {"conv": full[:, 1:], "ssm": S}

    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * (
        xc.reshape(B, T, H, P) if state is None
        else xc.reshape(B, 1, H, P)
    ).astype(jnp.float32)
    y = y.reshape(B, T, cfg.d_inner).astype(x.dtype)
    y = rms_norm(y, p["out_norm"]) * jax.nn.silu(z)
    return x + y @ p["wo"], new_state


def init_mamba_state(cfg: ArchConfig, B: int, dtype) -> Dict:
    """Per-layer decode state."""
    Cc = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "conv": jnp.zeros((B, cfg.d_conv - 1, Cc), dtype),
        "ssm": jnp.zeros(
            (B, cfg.n_ssm_heads, cfg.ssm_state, cfg.ssm_head_dim),
            jnp.float32,
        ),
    }
