"""Expert-parallel MoE dispatch with locality-aware (paper) strategies.

Token -> expert all-to-all is the canonical irregular communication in the
assigned LM pool, and the place where the paper's three collectives map
one-to-one onto MoE serving/training:

``a2a``        (paper: *standard*)  one flat all-to-all over the whole EP
               group.  When EP spans pods, every device exchanges a message
               with every remote device: (Pp-1)*Pm inter-pod messages/device.
``hier``       (paper: *partially optimized*, 3-step aggregation)  tokens
               first cross the fast intra-pod 'model' axis so that lane m
               holds everything bound for remote lane m (lane m is the
               load-balanced "leader" for lane-m traffic — the paper's
               balanced leader assignment), then one inter-pod message per
               pod pair crosses the slow 'pod' axis: Pp-1 inter-pod
               messages/device, Pm x fewer than ``a2a``.
``hier_dedup`` (paper: *fully optimized*, index extension)  with top-k > 1
               a token is often routed to several experts hosted in the same
               remote region; the aggregated path still ships its hidden
               state once per (token, expert).  Dedup ships each distinct
               token once per destination region plus int32 fan-out
               metadata, replicating only *inside* the region (cheap axis).
               Region = pod when EP spans pods, else destination device.
``dense``      no dispatch at all: every device computes its local expert
               shard for all (replicated) tokens, masked by router weights —
               the naive pjit-auto baseline for benchmarks.
``auto``       (paper: *Section-5 dynamic selection*)  not a transport but a
               selector: the batch's routing pattern is expressed as a
               ``core.plan.CommPattern`` (push-side sparse dynamic data
               exchange, arXiv 2308.13869), the three candidate strategies
               are scored with the locality-aware max-rate cost model
               (``core.costmodel``), and the cheapest of a2a / hier /
               hier_dedup is chosen — the same per-pattern choice the AMG
               levels make.  ``dense`` is never auto-selected (it is a
               baseline, not a transport).

Plan-cache lifecycle
--------------------
:func:`moe_plan_for` is the cached entry point (``lm``, ``serving`` and
``serve.engine`` all plan through it): dispatch geometry plus a
routing-pattern fingerprint key an entry in ``core.cache.PlanCache``, so
the expensive init — representative-routing construction, candidate
planning, Section-5 selection — runs once per (mesh, tokens_per_lane,
top_k, mode, cap_factor) shape.  Repeated batches and decode steps on an
unchanged mesh and token count re-plan *nothing* (observable as zero new
``PlanCache`` misses).  :func:`moe_layer` additionally memoizes its jitted
shard_map dispatch executor in the same cache (``moe_executor``), so the
per-layer transport program is built once and reused across layers, calls
and solves — the MoE analogue of ``MPI_Neighbor_alltoallv_init``.

Implementation notes
--------------------
* Sequence-sharded dispatch: x is replicated over 'model'; each lane routes
  its 1/Pm slice of tokens, so token sets are disjoint per lane and dedup is
  lane-local (no cross-lane duplicates exist by construction).
* All buffers are static-capacity; overflow tokens are dropped (standard MoE
  capacity semantics) and their combine weights zeroed.
* Experts with E < |EP| are replicated (r = |EP|/E); the router spreads
  tokens over replicas by token index — doubling as load balancing.
* Pallas ``moe_pack`` kernels do the pack/fan-out gathers on TPU.
* Expert outputs differ per expert, so the *return* trip cannot dedup; it
  uses the aggregated transport (the paper's partial path) in all modes.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..core import (
    CommPattern,
    SparseDynamicExchange,
    Topology,
    default_plan_cache,
    pattern_fingerprint,
    select_plan,
)
from ..core.costmodel import MachineParams, TPU_V5E
from ..core.dynexchange import DiscoveryStats
from ..core.selection import SelectionReport
from ..kernels.moe_pack import combine as pack_combine
from ..kernels.moe_pack import pack as pack_gather
from ..obs import default_obs
from .common import ArchConfig, Initializer, activation

_OBS = default_obs()

MODES = ("dense", "a2a", "hier", "hier_dedup")

# paper strategy <-> MoE transport (the Section-5 selector speaks strategy)
STRATEGY_OF_MODE = {"a2a": "standard", "hier": "partial",
                    "hier_dedup": "full"}
MODE_OF_STRATEGY = {v: k for k, v in STRATEGY_OF_MODE.items()}


@dataclasses.dataclass(frozen=True)
class MoEPlan:
    """Static dispatch geometry (the persistent 'init' of the collective)."""

    mode: str
    ep_axes: Tuple[str, ...]     # mesh axes the experts are sharded over
    ep_size: int
    e_log: int                   # logical experts
    e_phys: int                  # after replication
    e_per_dev: int
    top_k: int
    capacity: int                # C: per (src device, physical expert)
    region_axis: str             # slow axis for dedup ('pod' or 'model')
    region_size: int
    devs_per_region: int
    uniq_capacity: int           # Cu: unique tokens per (src lane, region)
    cap_factor: float
    fingerprint: str = ""        # routing-pattern fingerprint (cache identity)

    @property
    def replicas(self) -> int:
        return self.e_phys // self.e_log

    @property
    def ec(self) -> int:         # rows per (src, dst-device) block
        return self.e_per_dev * self.capacity


def make_moe_plan(
    cfg: ArchConfig,
    mesh: Mesh,
    tokens_per_lane: int,
    mode: str = "hier_dedup",
    ep_over_pods: bool = True,
    cap_factor: float = 1.25,
    dedup_factor: Optional[float] = None,
) -> MoEPlan:
    assert mode in MODES, mode
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    has_pod = "pod" in axes and axes["pod"] > 1 and ep_over_pods \
        and mode != "dense"
    ep_axes = ("pod", "model") if has_pod else ("model",)
    ep_size = int(np.prod([axes[a] for a in ep_axes]))
    e_log = cfg.n_experts
    # least replication r >= ceil(ep_size/e_log) with e_log*r divisible by
    # ep_size, so every device hosts the same number of physical experts
    # even when n_experts does not pack evenly onto the EP group (e.g. 3
    # logical experts on 4 devices -> r=4, e_phys=12, 3 per device)
    r0 = max(1, math.ceil(ep_size / e_log))
    step = ep_size // math.gcd(e_log, ep_size)
    r = ((r0 + step - 1) // step) * step
    e_phys = e_log * r
    assert e_phys % ep_size == 0, (e_phys, ep_size)
    e_per_dev = e_phys // ep_size
    k = cfg.top_k
    N = tokens_per_lane
    cap = max(8, int(math.ceil(k * N / e_phys * cap_factor / 8.0)) * 8)

    region_axis = "pod" if has_pod else "model"
    region_size = axes[region_axis]
    devs_per_region = ep_size // region_size
    pair_bound = devs_per_region * e_per_dev * cap   # exact per-region bound
    if dedup_factor is None:
        # expected distinct tokens hitting a region:
        # P(hit) = 1 - (1 - e_region/E_phys)^k, with 30% slack
        e_region = devs_per_region * e_per_dev
        frac = 1.0 - (1.0 - e_region / e_phys) ** k
        est = int(math.ceil(N * frac * 1.3))
        uniq = min(pair_bound, min(N, max(8, ((est + 7) // 8) * 8)))
    else:
        uniq = min(pair_bound, max(8, int(pair_bound * dedup_factor)
                                   // 8 * 8))
    return MoEPlan(
        mode=mode, ep_axes=ep_axes, ep_size=ep_size, e_log=e_log,
        e_phys=e_phys, e_per_dev=e_per_dev, top_k=k, capacity=cap,
        region_axis=region_axis, region_size=region_size,
        devs_per_region=devs_per_region, uniq_capacity=uniq,
        cap_factor=cap_factor,
    )


# ---------------------------------------------------------------------------
# planned dispatch: routing pattern -> CommPattern -> Section-5 selection ->
# PlanCache (the persistent 'init' shared with the AMG levels)
# ---------------------------------------------------------------------------


def _pack_routing(
    eids: list,
    replicas: int,
    e_per_dev: int,
    capacity: int,
    tokens_per_lane: int,
) -> Tuple[CommPattern, DiscoveryStats, str]:
    """Per-lane [N, k] logical-expert assignments -> dispatch CommPattern.

    Shared tail of the uniform and the measured-histogram synthesizers:
    replicate over physical experts, capacity-pack with exactly the
    semantics of :func:`route` / :func:`capacity_pack` (token-major rank),
    then discover the pattern via the push-side sparse dynamic data
    exchange: lane ``p`` owns its ``tokens_per_lane`` token values, each
    kept (token, k) pair pushes that token to the destination device.
    """
    N = tokens_per_lane
    dest: list = []
    local_ids: list = []
    for p, eid in enumerate(eids):
        k = eid.shape[1]
        rep = (np.arange(N) % replicas)[:, None]
        phys = (eid * replicas + rep).reshape(-1)
        # capacity packing: rank within each physical expert, token-major
        order = np.argsort(phys, kind="stable")
        sorted_e = phys[order]
        starts = np.r_[0, np.flatnonzero(np.diff(sorted_e)) + 1]
        run_len = np.diff(np.r_[starts, len(phys)])
        rank = np.empty(len(phys), np.int64)
        rank[order] = np.arange(len(phys)) - np.repeat(starts, run_len)
        keep = rank < capacity
        dest.append((phys[keep] // e_per_dev).astype(np.int64))
        local_ids.append((np.repeat(np.arange(N), k)[keep]).astype(np.int64))
    pattern, stats = SparseDynamicExchange.push_pattern(
        dest, local_ids, n_local=[N] * len(eids)
    )
    return pattern, stats, pattern_fingerprint(pattern)


@functools.lru_cache(maxsize=256)
def _routing_pattern(
    ep_size: int,
    e_log: int,
    replicas: int,
    e_per_dev: int,
    capacity: int,
    top_k: int,
    tokens_per_lane: int,
) -> Tuple[CommPattern, DiscoveryStats, str]:
    """Representative dispatch routing of one batch as a ``CommPattern``.

    Routing is synthesized from a fixed-seed uniform router (the
    load-balanced steady state the aux loss drives toward).  A token routed
    to several experts of one region appears as duplicate global indices —
    what the ``full`` planner dedups.  Deterministic, so the fingerprint is
    stable across calls and processes: repeated batches and decode steps
    key the same cache entry.
    """
    N, k = tokens_per_lane, top_k
    eids = []
    for p in range(ep_size):
        rng = np.random.default_rng(p)
        eids.append(np.argsort(rng.random((N, e_log)), axis=1)[:, :k])
    return _pack_routing(eids, replicas, e_per_dev, capacity, N)


def quantize_histogram(
    hist, e_log: int, quantum: int = 64
) -> Tuple[int, ...]:
    """Normalize an expert histogram to integer counts summing ``quantum``.

    Largest-remainder apportionment, deterministic tie-break on expert
    index.  Two measured histograms that differ by less than ~1/quantum in
    every fraction quantize identically — so their synthesized routing
    patterns share a fingerprint and the adaptive re-planner's cache lookup
    hits instead of re-planning (the "unchanged histogram re-plans
    nothing" property asserted in tests).
    """
    h = np.asarray(hist, dtype=np.float64).reshape(-1)
    if len(h) != e_log:
        raise ValueError(f"histogram has {len(h)} bins, expected {e_log}")
    total = float(h.sum())
    frac = (h / total) if total > 0 else np.full(e_log, 1.0 / e_log)
    raw = frac * quantum
    base = np.floor(raw).astype(np.int64)
    short = quantum - int(base.sum())
    if short > 0:
        order = np.lexsort((np.arange(e_log), -(raw - base)))
        base[order[:short]] += 1
    return tuple(int(x) for x in base)


@functools.lru_cache(maxsize=256)
def _histogram_routing_pattern(
    ep_size: int,
    e_log: int,
    replicas: int,
    e_per_dev: int,
    capacity: int,
    top_k: int,
    tokens_per_lane: int,
    qhist: Tuple[int, ...],
) -> Tuple[CommPattern, DiscoveryStats, str]:
    """Dispatch CommPattern whose expert marginals match a *measured*
    histogram (``qhist``: quantized counts from :func:`quantize_histogram`)
    instead of the synthesized uniform routing — the pattern the adaptive
    re-planner fingerprints when a serve workload drifts.

    Each token draws ``top_k`` *distinct* experts weighted by the
    histogram (Gumbel top-k, lane-seeded rng: deterministic across calls
    and processes) — matching :func:`route`'s semantics, where one token
    never hits the same logical expert twice, so the dedup planner scores
    duplicate counts the real workload would actually produce.
    """
    N, k = tokens_per_lane, top_k
    q = np.asarray(qhist, dtype=np.float64)
    frac = q / max(float(q.sum()), 1.0)
    # zero-probability experts stay drawable at ~1e-12 so k distinct
    # experts always exist even for a fully collapsed histogram
    logp = np.log(np.maximum(frac, 1e-12))
    eids = []
    for p in range(ep_size):
        rng = np.random.default_rng(100_003 + p)
        g = rng.gumbel(size=(N, e_log))
        eids.append(np.argsort(-(logp[None, :] + g), axis=1)[:, :k])
    return _pack_routing(eids, replicas, e_per_dev, capacity, N)


def dispatch_pattern(
    plan: MoEPlan, tokens_per_lane: int
) -> Tuple[CommPattern, DiscoveryStats, str]:
    """(pattern, discovery stats, fingerprint) of ``plan``'s dispatch.

    Region topology is deliberately absent: the pattern records only who
    needs which values; locality enters at planning time via
    :func:`dispatch_topology`."""
    return _routing_pattern(
        plan.ep_size, plan.e_log, plan.replicas,
        plan.e_per_dev, plan.capacity, plan.top_k, tokens_per_lane,
    )


def dispatch_topology(plan: MoEPlan) -> Topology:
    """EP group as a locality topology: regions are pods (or single
    devices when EP does not span pods), pod-major device order — the
    same layout :func:`ep_exchange` moves data in."""
    return Topology(plan.ep_size, max(1, plan.devs_per_region))


def _select_mode_over_pattern(
    plan: MoEPlan,
    pattern: CommPattern,
    value_bytes: int,
    params: MachineParams = TPU_V5E,
) -> Tuple[str, SelectionReport]:
    """Section-5 selection of a transport mode for one routing pattern."""
    _plan, report = select_plan(
        pattern, dispatch_topology(plan), params=params,
        value_bytes=value_bytes,
        candidates=tuple(MODE_OF_STRATEGY),
    )
    return MODE_OF_STRATEGY[report.chosen], report


def select_moe_mode(
    plan: MoEPlan,
    tokens_per_lane: int,
    value_bytes: int,
    params: MachineParams = TPU_V5E,
) -> Tuple[str, SelectionReport]:
    """Section-5 dynamic selection over a2a / hier / hier_dedup.

    Scores the three candidate strategies on the batch's routing pattern
    with the locality-aware max-rate model (message counts and bytes are
    exact plan quantities; ``value_bytes`` is the full hidden-state row) and
    returns the winning transport mode — mirroring the per-level AMG
    strategy choice.
    """
    pattern, _stats, _fp = dispatch_pattern(plan, tokens_per_lane)
    return _select_mode_over_pattern(plan, pattern, value_bytes, params)


def moe_plan_for(
    cfg: ArchConfig,
    mesh: Mesh,
    tokens_per_lane: int,
    mode: str = "auto",
    ep_over_pods: bool = True,
    cap_factor: float = 1.25,
    dedup_factor: Optional[float] = None,
    params: MachineParams = TPU_V5E,
    cache=None,
) -> MoEPlan:
    """Cached dispatch planning — the entry point ``lm`` / ``serving`` /
    ``serve.engine`` use instead of calling :func:`make_moe_plan` per call.

    Keyed on (mesh, tokens_per_lane, top_k, mode, cap_factor, ...) plus the
    routing-pattern fingerprint in ``core.cache.PlanCache`` (process-wide
    default unless ``cache`` is passed): the first call for a shape builds
    the geometry, synthesizes the routing pattern and — for
    ``mode="auto"`` — runs the Section-5 selector; every later call with an
    unchanged mesh and token count is a cache hit that re-plans nothing.

    The pattern synthesis behind the fingerprint is itself memoized
    (:func:`dispatch_pattern` lru), so its O(ep_size * tokens * experts)
    numpy cost is paid once per dispatch geometry per process — the same
    amortization class as the planning it keys.
    """
    cache = default_plan_cache() if cache is None else cache
    geom = make_moe_plan(
        cfg, mesh, tokens_per_lane,
        mode=("a2a" if mode == "auto" else mode),
        ep_over_pods=ep_over_pods, cap_factor=cap_factor,
        dedup_factor=dedup_factor,
    )
    if geom.mode == "dense":
        # no dispatch exchange to plan: geometry is the whole plan
        return geom
    _pattern, _stats, fp = dispatch_pattern(geom, tokens_per_lane)
    value_bytes = cfg.d_model * np.dtype(cfg.dtype).itemsize
    # mesh enters the key by content (axes x shape): a rebuilt-but-equal
    # mesh still hits, mirroring the content-hashed pattern fingerprints
    mesh_key = (tuple(mesh.axis_names), tuple(np.shape(mesh.devices)))
    key = (
        "moe_plan", mesh_key, tokens_per_lane, cfg.n_experts, cfg.top_k,
        mode, ep_over_pods, cap_factor, dedup_factor, value_bytes, params,
        fp,
    )

    def build() -> MoEPlan:
        chosen = mode
        if mode == "auto":
            chosen, _report = select_moe_mode(
                geom, tokens_per_lane, value_bytes, params
            )
        return dataclasses.replace(geom, mode=chosen, fingerprint=fp)

    return cache.moe_plan(key, build)


def moe_plan_from_histogram(
    cfg: ArchConfig,
    mesh: Mesh,
    tokens_per_lane: int,
    hist,
    mode: str = "auto",
    quantum: int = 64,
    ep_over_pods: bool = True,
    cap_factor: float = 1.25,
    dedup_factor: Optional[float] = None,
    params: MachineParams = TPU_V5E,
    cache=None,
) -> MoEPlan:
    """Cached dispatch planning over a *measured* expert histogram — the
    re-planning entry point of ``repro.profile.adapt.AdaptivePlanner``.

    Mirrors :func:`moe_plan_for` but the routing pattern (and therefore the
    fingerprint keying the plan cache) is synthesized from ``hist`` — the
    observed per-expert (token, k)-pair counts of a batch, fed from
    :func:`moe_dispatch_lane`'s ``expert_counts`` output — instead of the
    uniform steady-state router.  The histogram is quantized
    (:func:`quantize_histogram`) before fingerprinting, so re-planning
    under an effectively unchanged routing distribution is a cache hit
    that re-plans nothing; a drifted histogram keys (and, for
    ``mode="auto"``, re-selects) a genuinely new plan.
    """
    cache = default_plan_cache() if cache is None else cache
    geom = make_moe_plan(
        cfg, mesh, tokens_per_lane,
        mode=("a2a" if mode == "auto" else mode),
        ep_over_pods=ep_over_pods, cap_factor=cap_factor,
        dedup_factor=dedup_factor,
    )
    if geom.mode == "dense":
        return geom
    qhist = quantize_histogram(hist, geom.e_log, quantum)
    pattern, _stats, fp = _histogram_routing_pattern(
        geom.ep_size, geom.e_log, geom.replicas, geom.e_per_dev,
        geom.capacity, geom.top_k, tokens_per_lane, qhist,
    )
    value_bytes = cfg.d_model * np.dtype(cfg.dtype).itemsize
    mesh_key = (tuple(mesh.axis_names), tuple(np.shape(mesh.devices)))
    key = (
        "moe_plan_hist", mesh_key, tokens_per_lane, cfg.n_experts,
        cfg.top_k, mode, ep_over_pods, cap_factor, dedup_factor,
        value_bytes, params, fp,
    )

    def build() -> MoEPlan:
        chosen = mode
        if mode == "auto":
            chosen, _report = _select_mode_over_pattern(
                geom, pattern, value_bytes, params
            )
        return dataclasses.replace(geom, mode=chosen, fingerprint=fp)

    return cache.moe_plan(key, build)


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------


def init_moe(init: Initializer, cfg: ArchConfig, L: int, e_phys: int) -> Dict:
    d, f = cfg.d_model, cfg.d_ff_expert
    p = {
        "router": init.tensor((L, d, cfg.n_experts), fan_in=d,
                              dtype=jnp.float32),
        "w_gate": init.tensor((L, e_phys, d, f), fan_in=d),
        "w_up": init.tensor((L, e_phys, d, f), fan_in=d),
        "w_down": init.tensor((L, e_phys, f, d), fan_in=f),
    }
    if cfg.n_shared_experts:
        fs = cfg.d_ff_expert * cfg.n_shared_experts
        p["ws_gate"] = init.tensor((L, d, fs), fan_in=d)
        p["ws_up"] = init.tensor((L, d, fs), fan_in=d)
        p["ws_down"] = init.tensor((L, fs, d), fan_in=fs)
    return p


def remap_expert_params(moe_params: Dict, e_log: int,
                        r_old: int, r_new: int) -> Dict:
    """Re-replicate expert weights for a changed EP group size.

    The physical expert layout is ``phys = logical * replicas + rep``
    (see ``_pack_routing``), so replica 0 of every logical expert lives at
    stride ``replicas`` — slicing ``[:, ::r_old]`` recovers the logical
    weights and ``np.repeat(..., r_new, axis=1)`` re-expands them for the
    new group.  Operates host-side on the expert tensors (``w_gate`` /
    ``w_up`` / ``w_down``, shape [L, e_log*r, ...]); router and shared
    weights are replication-independent and pass through untouched.
    Dtypes are preserved (``np.repeat`` never casts).
    """
    import jax

    out = dict(moe_params)
    for key in ("w_gate", "w_up", "w_down"):
        v = np.asarray(jax.device_get(moe_params[key]))
        assert v.shape[1] == e_log * r_old, (v.shape, e_log, r_old)
        base = v[:, ::r_old]                   # replica 0 per logical expert
        out[key] = np.repeat(base, r_new, axis=1)
    return out


def moe_param_specs(cfg: ArchConfig, plan: MoEPlan) -> Dict:
    """PartitionSpecs for init_moe params (leading L axis unsharded)."""
    e_spec = plan.ep_axes if len(plan.ep_axes) > 1 else plan.ep_axes[0]
    p = {
        "router": P(),
        "w_gate": P(None, e_spec, None, None),
        "w_up": P(None, e_spec, None, None),
        "w_down": P(None, e_spec, None, None),
    }
    if cfg.n_shared_experts:
        p["ws_gate"] = P(None, None, "model")
        p["ws_up"] = P(None, None, "model")
        p["ws_down"] = P(None, "model", None)
    return p


EXPERT_WEIGHT_KEYS = ("w_gate", "w_up", "w_down")


def gather_expert_weights(
    moe_params: Dict,
    plan: MoEPlan,
    mesh: Mesh,
    method: str = "auto",
    cache=None,
    params: MachineParams = TPU_V5E,
):
    """Replicate the EP-sharded expert weights with a plan-based dense
    allgatherv — ``(gathered_params, DenseSelection)``.

    The expert tensors (``w_gate``/``w_up``/``w_down``, sharded over the
    EP axis) are flattened per device into one segment and gathered in a
    single dense collective over :func:`dispatch_topology` (so region
    structure matches the dispatch transport), selected by the Section-5
    cost model (``method="auto"``) or pinned (``"hier"``/``"ring"``) — the
    weight-replication step of a dense fallback forward, an elastic
    EP-group rebuild, or a checkpoint re-shard.  Router and shared-expert
    weights are already replicated and pass through untouched.  The
    returned :class:`~repro.core.dense.DenseSelection` is the recorded
    choice, the way ``DistOp`` records ``kern=``/``ov=``.
    """
    from ..compat import shard_map
    from ..core import dense_round_runner

    if len(plan.ep_axes) != 1:
        raise ValueError(
            f"gather_expert_weights needs a single EP mesh axis, got "
            f"{plan.ep_axes!r}"
        )
    axis = plan.ep_axes[0]
    ep, e_per_dev = plan.ep_size, plan.e_per_dev
    gshapes = {k: tuple(moe_params[k].shape) for k in EXPERT_WEIGHT_KEYS}
    lshapes = {k: (s[0], e_per_dev) + s[2:] for k, s in gshapes.items()}
    sizes = {k: int(np.prod(s)) for k, s in lshapes.items()}
    chunk = sum(sizes.values())

    cache = cache if cache is not None else default_plan_cache()
    topo = dispatch_topology(plan)
    variant = "auto" if method == "auto" else method
    with _OBS.span("moe/expert_gather_plan", method=method, ep=ep,
                   chunk=chunk) as sp:
        dplan, sel = cache.dense_collective(
            "allgatherv", np.full(ep, chunk, dtype=np.int64), topo,
            variant=variant, params=params,
        )
        sp.set(chosen=sel.chosen)
    run = dense_round_runner(dplan, axis)

    def per_device(*leaves):
        rank = jax.lax.axis_index(axis)
        zero = jnp.zeros((), rank.dtype)
        flat = jnp.concatenate([x.reshape(-1) for x in leaves])
        buf = jnp.zeros((ep, chunk), flat.dtype)
        buf = jax.lax.dynamic_update_slice(buf, flat[None], (rank, zero))
        full = run(buf)                      # [ep, chunk] replicated
        outs, off = [], 0
        for k in EXPERT_WEIGHT_KEYS:
            part = full[:, off:off + sizes[k]].reshape((ep,) + lshapes[k])
            # [ep, L, e_per_dev, ...] -> [L, ep*e_per_dev, ...]: devices
            # hold contiguous expert blocks in rank order, so the outer
            # ep axis folds straight back into e_phys order
            part = jnp.moveaxis(part, 0, 1).reshape(gshapes[k])
            outs.append(part)
            off += sizes[k]
        return tuple(outs)

    fn = shard_map(
        per_device, mesh=mesh,
        in_specs=(P(None, axis, None, None),) * len(EXPERT_WEIGHT_KEYS),
        out_specs=(P(),) * len(EXPERT_WEIGHT_KEYS),
        check_rep=False,
    )
    gathered = jax.jit(fn)(*(moe_params[k] for k in EXPERT_WEIGHT_KEYS))
    out = dict(moe_params)
    out.update(dict(zip(EXPERT_WEIGHT_KEYS, gathered)))
    return out, sel


# ---------------------------------------------------------------------------
# routing + capacity packing (all shapes static)
# ---------------------------------------------------------------------------


def _segment_ranks(sorted_ids: jnp.ndarray) -> jnp.ndarray:
    """Rank of each element within its run of equal ids (ids pre-sorted)."""
    n = sorted_ids.shape[0]
    idx = jnp.arange(n)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_ids[1:] != sorted_ids[:-1]]
    )
    seg_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(is_start, idx, 0)
    )
    return idx - seg_start


def _rank_within(ids: jnp.ndarray) -> jnp.ndarray:
    """Stable rank of each element among equal values of ``ids``."""
    order = jnp.argsort(ids, stable=True)
    ranks_sorted = _segment_ranks(ids[order])
    return jnp.zeros_like(ranks_sorted).at[order].set(ranks_sorted)


def route(
    x: jnp.ndarray,              # [N, D] this lane's tokens
    router_w: jnp.ndarray,       # [D, E_log] (f32)
    plan: MoEPlan,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Top-k routing -> (phys expert ids [N,k], weights [N,k], aux loss)."""
    N = x.shape[0]
    # f32 floor, but f64 activations keep their width (bit-match checks)
    cdt = jnp.promote_types(x.dtype, jnp.float32)
    logits = x.astype(cdt) @ router_w.astype(cdt)          # [N, E_log]
    probs = jax.nn.softmax(logits, axis=-1)
    w, eid = jax.lax.top_k(probs, plan.top_k)              # [N, k]
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    # load-balance aux (Switch-style): E * sum_e f_e * P_e
    f = jnp.zeros((plan.e_log,), jnp.float32).at[eid.reshape(-1)].add(
        1.0 / (N * plan.top_k)
    )
    aux = plan.e_log * jnp.sum(f * jnp.mean(probs, axis=0))
    if plan.replicas > 1:  # spread over replicas by token index
        rep = (jnp.arange(N) % plan.replicas)[:, None]
        phys = eid * plan.replicas + rep
    else:
        phys = eid
    return phys.astype(jnp.int32), w, aux


def capacity_pack(
    phys: jnp.ndarray,           # [N, k]
    plan: MoEPlan,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Assign each (token, k) a slot in the [E_phys * C] send layout.

    Drop order: pairs claim expert slots in token-major order (flat index
    ``token * k + j``), so when an expert overflows its capacity ``C`` the
    *late-sequence* tokens are the ones dropped — first-come-first-served
    by sequence position, NOT random or load-aware.  This bias is invisible
    in the outputs (dropped pairs just get zero combine weight), which is
    why :func:`moe_dispatch_lane` surfaces a ``dropped_fraction`` scalar:
    benchmarks and tests assert capacity health instead of silently
    under-serving the end of every sequence.

    Returns (slot [N,k] (sentinel E_phys*C when dropped), keep [N,k],
    slot_token [E_phys*C]: source token per slot, sentinel N when empty)."""
    N, k = phys.shape
    C = plan.capacity
    flat_e = phys.reshape(-1)
    rank = _rank_within(flat_e)
    keep = rank < C
    slot = jnp.where(keep, flat_e * C + rank, plan.e_phys * C)
    token_of_pair = jnp.repeat(jnp.arange(N), k).astype(jnp.int32)
    slot_token = jnp.full((plan.e_phys * C + 1,), N, jnp.int32)
    slot_token = slot_token.at[slot].set(token_of_pair)[: plan.e_phys * C]
    return slot.reshape(N, k), keep.reshape(N, k), slot_token


# ---------------------------------------------------------------------------
# transport (the paper's strategies)
# ---------------------------------------------------------------------------


def _a2a(x, axis, split, concat):
    return jax.lax.all_to_all(x, axis, split_axis=split, concat_axis=concat,
                              tiled=True)


def ep_exchange(send: jnp.ndarray, plan: MoEPlan) -> jnp.ndarray:
    """send: [G*eC, D] ordered by destination device (pod-major);
    returns [G*eC, D] ordered by source device."""
    G, D = plan.ep_size, send.shape[-1]
    eC = send.shape[0] // G
    if len(plan.ep_axes) == 1:
        return _a2a(send, plan.ep_axes[0], 0, 0)
    if plan.mode == "a2a":
        return _a2a(send, plan.ep_axes, 0, 0)
    # hierarchical: fast-axis hop to the leader lane, then one slow-axis
    # message per pod pair (paper's 3-step aggregation, s then g)
    Pp, Pm = plan.region_size, plan.devs_per_region
    b = send.reshape(Pp, Pm, eC, D)          # [dst pod, dst lane, eC]
    b = _a2a(b, "model", 1, 1)               # -> [dst pod, src lane, eC]
    b = _a2a(b, "pod", 0, 0)                 # -> [src pod, src lane, eC]
    return b.reshape(G * eC, D)


def ep_exchange_back(recv: jnp.ndarray, plan: MoEPlan) -> jnp.ndarray:
    """Inverse transport: rows ordered by source device -> back to sources,
    arriving ordered by destination (computing) device = send layout."""
    G, D = plan.ep_size, recv.shape[-1]
    eC = recv.shape[0] // G
    if len(plan.ep_axes) == 1:
        return _a2a(recv, plan.ep_axes[0], 0, 0)
    if plan.mode == "a2a":
        return _a2a(recv, plan.ep_axes, 0, 0)
    Pp, Pm = plan.region_size, plan.devs_per_region
    b = recv.reshape(Pp, Pm, eC, D)          # [src pod, src lane, eC]
    b = _a2a(b, "pod", 0, 0)                 # -> [cmp pod, src lane, eC]
    b = _a2a(b, "model", 1, 1)               # -> [cmp pod, cmp lane, eC]
    return b.reshape(G * eC, D)


# ---------------------------------------------------------------------------
# the layer body (runs under shard_map)
# ---------------------------------------------------------------------------


def _expert_ffn(wg, wu, wd, act_fn, xb):
    """xb: [e_per_dev, T, D]; w*: [e_per_dev, D, f] / [e_per_dev, f, D]."""
    xf = xb.astype(wg.dtype)
    h = act_fn(jnp.einsum("etd,edf->etf", xf, wg)) * jnp.einsum(
        "etd,edf->etf", xf, wu
    )
    return jnp.einsum("etf,efd->etd", h, wd)


def moe_dispatch_lane(
    x_lane: jnp.ndarray,         # [N, D] this lane's tokens
    params: Dict,                # per-layer slices; expert weights LOCAL shard
    plan: MoEPlan,
    cfg: ArchConfig,
    valid: Optional[jnp.ndarray] = None,   # [N] bool; False rows are pads
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (y_lane [N, D], aux scalar, dropped_fraction scalar,
    expert_counts [e_log] f32).

    ``dropped_fraction`` is the fraction of this lane's *valid* (token, k)
    pairs that lost their expert slot to capacity overflow (see
    :func:`capacity_pack` for the token-major drop order) — 0 in ``dense``
    mode, which routes nothing.  ``valid`` masks sequence-padding rows out
    of the metric (pads are still routed and can consume capacity, but
    they are not real tokens: counting them would distort the fraction
    whenever tokens don't divide the lane count).  An all-pad lane reports
    1.0 — weight lane fractions by their valid-pair count when averaging
    (as :func:`moe_layer` does).

    ``expert_counts`` is this lane's measured routing histogram: valid
    (token, k) pairs per *logical* expert, pre-capacity (drops are a
    capacity symptom, not a routing signal).  It is the observation the
    adaptive re-planner consumes (``repro.profile.adapt``) in place of the
    synthesized uniform routing behind :func:`dispatch_pattern`."""
    N, D = x_lane.shape
    C = plan.capacity
    act_fn = activation(cfg.act)
    if valid is None:
        valid = jnp.ones((N,), bool)
    phys, w, aux = route(x_lane, params["router"], plan)
    pair_valid = jnp.broadcast_to(valid[:, None], phys.shape)
    counts = jnp.zeros((plan.e_log,), jnp.float32).at[
        (phys // plan.replicas).reshape(-1)
    ].add(pair_valid.reshape(-1).astype(jnp.float32))

    if plan.mode == "dense":
        wg, wu, wd = params["w_gate"], params["w_up"], params["w_down"]
        e_per = wg.shape[0]
        ep_idx = jax.lax.axis_index("model")
        xb = jnp.broadcast_to(x_lane[None], (e_per, N, D))
        y_all = _expert_ffn(wg, wu, wd, act_fn, xb)      # [e_per, N, D]
        e_ids = ep_idx * e_per + jnp.arange(e_per)
        cdt = jnp.promote_types(x_lane.dtype, jnp.float32)
        match = phys[None, :, :] == e_ids[:, None, None]  # [e_per, N, k]
        wk = jnp.sum(match * w[None].astype(cdt), axis=-1)
        y = jnp.einsum("en,end->nd", wk, y_all.astype(cdt))
        y = jax.lax.psum(y, "model")
        return (y.astype(x_lane.dtype), aux, jnp.zeros((), jnp.float32),
                counts)

    slot, keep, slot_token = capacity_pack(phys, plan)
    w = w * keep.astype(w.dtype)

    x_pad = jnp.concatenate([x_lane, jnp.zeros((1, D), x_lane.dtype)], 0)
    send = pack_gather(x_pad, jnp.minimum(slot_token, N))  # [E_phys*C, D]

    # delivered = pairs whose expert output actually comes back; the dedup
    # path can additionally lose pairs to uniq_capacity overflow (their
    # fan-out reads the zero pad row), which must be just as observable as
    # expert-capacity drops
    delivered = keep
    if plan.mode == "hier_dedup" and plan.top_k > 1:
        yb, pair_ok = _dedup_outbound(x_lane, slot, keep, phys, params,
                                      plan, act_fn)
        delivered = keep & pair_ok.reshape(N, plan.top_k)
    else:
        recv = ep_exchange(send, plan)                   # by source device
        xb = recv.reshape(plan.ep_size, plan.e_per_dev, C, D)
        xb = jnp.swapaxes(xb, 0, 1).reshape(
            plan.e_per_dev, plan.ep_size * C, D
        )
        yo = _expert_ffn(params["w_gate"], params["w_up"], params["w_down"],
                         act_fn, xb)
        yb = jnp.swapaxes(
            yo.reshape(plan.e_per_dev, plan.ep_size, C, D), 0, 1
        ).reshape(plan.ep_size * plan.e_per_dev * C, D)
    y_recv = ep_exchange_back(yb.astype(x_lane.dtype), plan)

    kept_real = jnp.sum((delivered & valid[:, None]).astype(jnp.float32))
    n_real = jnp.sum(valid.astype(jnp.float32)) * plan.top_k
    dropped = 1.0 - kept_real / jnp.maximum(n_real, 1.0)

    buf = jnp.concatenate([y_recv, jnp.zeros((1, D), y_recv.dtype)], 0)
    y = pack_combine(buf, jnp.minimum(slot, plan.e_phys * C), w)
    return y.astype(x_lane.dtype), aux, dropped, counts


def moe_layer(
    x: jnp.ndarray,              # [B, S, D] batch sharded over batch_axes
    params: Dict,                # per-layer slices (no leading L dim)
    plan: MoEPlan,
    cfg: ArchConfig,
    mesh: Mesh,
    batch_axes: Tuple[str, ...],
    cache=None,
    return_expert_counts: bool = False,
) -> Tuple[jnp.ndarray, ...]:
    """shard_map wrapper: sequence-shard tokens over 'model' lanes, dispatch,
    all_gather the lane outputs back.  Returns (y [B,S,D], aux scalar,
    dropped_fraction scalar — mean over lanes, see :func:`capacity_pack`);
    with ``return_expert_counts=True`` a fourth output is appended: the
    batch's measured routing histogram ([e_log] f32, valid (token, k)
    pairs per logical expert, psum'd over every mesh axis — so replicated
    lanes multiply the scale uniformly; normalize before comparing).

    When ``cache`` (a ``core.cache.PlanCache``) is given, the jitted
    shard_map dispatch executor is memoized in it keyed on (plan geometry,
    mesh, specs, param-tree structure): every MoE layer of every forward
    reuses one compiled transport program per dispatch geometry instead of
    rebuilding it each call.  The routing *fingerprint* is deliberately
    excluded from that key — the compiled transport depends only on
    geometry + mode, so an adaptively re-selected plan that lands back on
    a previously compiled mode recompiles nothing.
    """
    from ..compat import shard_map

    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    Pm = axes["model"]
    all_axes = tuple(mesh.axis_names)

    pspecs = moe_param_specs(cfg, plan)
    # strip the leading L axis from the specs (params are per-layer slices)
    def strip(spec):
        return P(*spec[1:]) if len(spec) else spec
    pspecs = {k: strip(v) for k, v in pspecs.items()
              if k in params and not k.startswith("ws_")}
    pflat, ptree = jax.tree.flatten(
        {k: params[k] for k in pspecs}
    )
    spec_flat = jax.tree.flatten({k: pspecs[k] for k in pspecs})[0]
    # batch sharding only when the batch divides the data axes (long-context
    # decode has global_batch=1: tokens replicate, dispatch stays correct
    # because every replica performs the identical exchange)
    n_batch_dev = int(np.prod([axes[a] for a in batch_axes])) \
        if batch_axes else 1
    if batch_axes and x.shape[0] % n_batch_dev == 0:
        x_spec = P(batch_axes if len(batch_axes) > 1 else batch_axes[0],
                   None, None)
    else:
        x_spec = P(None, None, None)

    def build():
        def body(xb, *pvals):
            pb = jax.tree.unflatten(ptree, pvals)
            b_loc, S, D = xb.shape
            n_all = b_loc * S
            xf = xb.reshape(n_all, D)
            if plan.mode == "dense":
                y, aux, drop, counts = moe_dispatch_lane(xf, pb, plan, cfg)
                out = (y.reshape(b_loc, S, D),
                       jax.lax.pmean(aux, all_axes),
                       jax.lax.pmean(drop, all_axes))
                if return_expert_counts:
                    out += (jax.lax.psum(counts, all_axes),)
                return out
            n_pad = n_all + ((-n_all) % Pm)
            if n_pad != n_all:
                xf = jnp.pad(xf, ((0, n_pad - n_all), (0, 0)))
            n_lane = n_pad // Pm
            m = jax.lax.axis_index("model")
            x_lane = jax.lax.dynamic_slice_in_dim(xf, m * n_lane, n_lane, 0)
            # pad rows (appended past n_all) are routed but masked out of
            # the capacity-health metric; lane fractions are averaged
            # weighted by their real-pair counts
            valid = m * n_lane + jnp.arange(n_lane) < n_all
            y_lane, aux, drop, counts = moe_dispatch_lane(
                x_lane, pb, plan, cfg, valid=valid
            )
            y = jax.lax.all_gather(y_lane, "model", axis=0, tiled=True)
            y = y[:n_all].reshape(b_loc, S, D)
            nv = jnp.sum(valid.astype(jnp.float32))
            drop = jax.lax.psum(drop * nv, all_axes) / jnp.maximum(
                jax.lax.psum(nv, all_axes), 1.0
            )
            out = (y, jax.lax.pmean(aux, all_axes), drop)
            if return_expert_counts:
                out += (jax.lax.psum(counts, all_axes),)
            return out

        n_out = 4 if return_expert_counts else 3
        return jax.jit(shard_map(
            body,
            mesh=mesh,
            in_specs=(x_spec,) + tuple(spec_flat),
            out_specs=(x_spec,) + (P(),) * (n_out - 1),
            check_vma=False,
        ))

    if cache is not None:
        # fingerprint-stripped: the compiled transport depends on geometry
        # + mode only, so adaptive re-plans reuse compiled executors
        geom_key = dataclasses.replace(plan, fingerprint="")
        key = ("moe_exec", geom_key, mesh, x_spec, ptree, cfg.act,
               return_expert_counts)
        fn = cache.moe_executor(key, build)
    else:
        fn = build()
    return fn(x, *pflat)


def _dedup_outbound(x_lane, slot, keep, phys, params, plan, act_fn):
    """Paper's fully-optimized outbound: one copy per (token, dst region) +
    int32 metadata; fan out to expert slots inside the region.

    Returns (expert outputs laid out [G(src device, pod-major) * eC, D],
    pair_ok [N*k] bool: pairs whose token won a uniq slot and will come
    back — pairs beyond ``uniq_capacity`` fan out from the zero pad row,
    i.e. they are dropped and the caller must count them as such)."""
    N, D = x_lane.shape
    C = plan.capacity
    Rg = plan.region_size
    Dg = plan.devs_per_region
    eC = plan.ec
    Cu = plan.uniq_capacity
    Cp = Dg * eC                              # exact pair bound per region

    keep_f = keep.reshape(-1)
    dev = (phys // plan.e_per_dev).reshape(-1)           # dst device
    region = jnp.where(keep_f, dev // Dg, Rg)            # pod-major order
    pair_token = jnp.repeat(jnp.arange(N), plan.top_k)

    # ---- lane-local dedup: first pair of each (region, token) key --------
    key = region * (N + 1) + pair_token
    order = jnp.argsort(key, stable=True)
    key_s = key[order]
    is_first = jnp.concatenate(
        [jnp.ones((1,), bool), key_s[1:] != key_s[:-1]]
    )
    region_s = region[order]
    # unique rank within region: count of firsts so far in this region
    reg_start = jnp.concatenate(
        [jnp.ones((1,), bool), region_s[1:] != region_s[:-1]]
    )
    firsts = is_first.astype(jnp.int32)
    cum = jnp.cumsum(firsts)
    reg_base = jax.lax.associative_scan(
        jnp.maximum, jnp.where(reg_start, cum - firsts, 0)
    )
    ur = cum - firsts - reg_base                          # 0-based, sorted
    uniq_ok_s = is_first & (ur < Cu) & (region_s < Rg)
    uslot_s = jnp.where(uniq_ok_s, region_s * Cu + ur, Rg * Cu)

    # forward-fill each key's uslot to its non-first pairs via segment ids
    n_pairs = key.shape[0]
    seg_id = cum - 1                                      # key index, sorted
    seg_uslot = jnp.full((n_pairs + 1,), Rg * Cu, jnp.int32)
    seg_uslot = seg_uslot.at[
        jnp.where(is_first, seg_id, n_pairs)
    ].set(uslot_s.astype(jnp.int32))
    pair_uslot_s = seg_uslot[seg_id]
    pair_uslot = jnp.zeros((n_pairs,), jnp.int32).at[order].set(pair_uslot_s)

    # uniq value buffer [Rg*Cu] -> source token
    uniq_token = jnp.full((Rg * Cu + 1,), N, jnp.int32)
    uniq_token = uniq_token.at[uslot_s].set(
        pair_token[order].astype(jnp.int32)
    )[: Rg * Cu]

    # ---- metadata: meta[region, dst_in_region] = uslot-within-region ------
    slot_f = slot.reshape(-1)
    dst_in_region = jnp.where(
        keep_f, (dev % Dg) * eC + slot_f % eC, Cp
    )
    pair_ok = keep_f & (pair_uslot < Rg * Cu)
    mpos = jnp.where(pair_ok, region * Cp + dst_in_region, Rg * Cp)
    meta = jnp.full((Rg * Cp + 1,), -1, jnp.int32)
    meta = meta.at[mpos].set((pair_uslot % Cu).astype(jnp.int32))[: Rg * Cp]

    # ---- ship uniques + metadata across the slow axis ---------------------
    x_pad = jnp.concatenate([x_lane, jnp.zeros((1, D), x_lane.dtype)], 0)
    uniq_vals = pack_gather(x_pad, jnp.minimum(uniq_token, N))  # [Rg*Cu, D]
    uniq_rcv = _a2a(uniq_vals.reshape(Rg, Cu, D), plan.region_axis, 0, 0)
    meta_rcv = _a2a(meta.reshape(Rg, Cp), plan.region_axis, 0, 0)

    # ---- fan out inside the region (paper step r) --------------------------
    u_flat = uniq_rcv.reshape(Rg * Cu, D)
    u_pad = jnp.concatenate([u_flat, jnp.zeros((1, D), u_flat.dtype)], 0)
    m_flat = meta_rcv.reshape(Rg * Cp)                   # uslot or -1
    src_reg = jnp.repeat(jnp.arange(Rg), Cp)
    valid = m_flat >= 0
    gidx = jnp.where(valid, src_reg * Cu + m_flat, Rg * Cu)
    vals = pack_gather(u_pad, gidx)                      # [Rg*Cp, D]
    # rearrange [src_reg, dst_dev_in_region, eC] -> [dst_dev, src_reg, eC]
    fan = vals.reshape(Rg, Dg, eC, D)
    fan = jnp.swapaxes(fan, 0, 1).reshape(Dg, Rg * eC, D)
    if Dg > 1:
        fan = _a2a(fan, "model", 0, 0)                   # dim0 -> src lane
    xb = fan.reshape(Dg, Rg, plan.e_per_dev, C, D)
    # expert batches with source device pod-major: g0 = src_reg * Dg + lane
    xb = xb.transpose(2, 1, 0, 3, 4).reshape(
        plan.e_per_dev, Rg * Dg * C, D
    )
    yo = _expert_ffn(params["w_gate"], params["w_up"], params["w_down"],
                     act_fn, xb)
    yb = yo.reshape(plan.e_per_dev, Rg, Dg, C, D).transpose(1, 2, 0, 3, 4)
    return yb.reshape(plan.ep_size * eC, D), pair_ok
