"""Attention variants: GQA (w/ bias, qk-norm, sliding window, M-RoPE) and
DeepSeek-style MLA (compressed KV cache with decoupled RoPE).

All variants share the cache contract:
  prefill: cache is None -> returns full-length K/V (or compressed) tensors
  decode : cache given    -> new token written at position ``kv_len``; the
           flash kernel masks entries >= kv_len+T.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels.flash_attention import attention as flash
from .common import ArchConfig, Initializer, apply_mrope, apply_rope, rms_norm


# ---------------------------------------------------------------------------
# standard GQA
# ---------------------------------------------------------------------------


def init_gqa(init: Initializer, cfg: ArchConfig, L: int, d_in: int = 0) -> Dict:
    d = d_in or cfg.d_model
    dh = cfg.head_dim
    p = {
        "wq": init.tensor((L, d, cfg.n_heads * dh), fan_in=d),
        "wk": init.tensor((L, d, cfg.n_kv_heads * dh), fan_in=d),
        "wv": init.tensor((L, d, cfg.n_kv_heads * dh), fan_in=d),
        "wo": init.tensor((L, cfg.n_heads * dh, cfg.d_model),
                          fan_in=cfg.n_heads * dh),
    }
    if cfg.qkv_bias:
        p["bq"] = init.tensor((L, cfg.n_heads * dh), zero=True)
        p["bk"] = init.tensor((L, cfg.n_kv_heads * dh), zero=True)
        p["bv"] = init.tensor((L, cfg.n_kv_heads * dh), zero=True)
    if cfg.qk_norm:
        p["q_norm"] = init.tensor((L, dh), zero=True)
        p["k_norm"] = init.tensor((L, dh), zero=True)
    return p


def gqa_project_qkv(
    p: Dict,
    x: jnp.ndarray,               # [B, T, d]
    positions: jnp.ndarray,       # [B, T] (or [B, 3, T] when mrope)
    cfg: ArchConfig,
    rope: bool = True,
    kv_x: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Project + (m)rope q/k/v -> [B, H(q|kv), T, dh]."""
    B, T, _ = x.shape
    dh = cfg.head_dim
    src = x if kv_x is None else kv_x
    Ts = src.shape[1]
    q = x @ p["wq"]
    k = src @ p["wk"]
    v = src @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, T, cfg.n_heads, dh).transpose(0, 2, 1, 3)
    k = k.reshape(B, Ts, cfg.n_kv_heads, dh).transpose(0, 2, 1, 3)
    v = v.reshape(B, Ts, cfg.n_kv_heads, dh).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if rope:
        if cfg.mrope_sections is not None:
            q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = apply_rope(q, positions, cfg.rope_theta, cfg.partial_rotary)
            k = apply_rope(k, positions, cfg.rope_theta, cfg.partial_rotary)
    return q, k, v


def gqa_project_out(p: Dict, o: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """o: [B, Hq, T, dh] -> [B, T, d]."""
    B, H, T, dh = o.shape
    return o.transpose(0, 2, 1, 3).reshape(B, T, H * dh) @ p["wo"]


def gqa_attention(
    p: Dict,                      # single-layer slice of init_gqa params
    x: jnp.ndarray,               # [B, T, d]
    positions: jnp.ndarray,       # [B, T] (or [B, 3, T] when mrope)
    cfg: ArchConfig,
    window: int = 0,
    cache: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,  # (k,v) [B,Hkv,S,dh]
    kv_len: Optional[jnp.ndarray | int] = None,               # filled entries
    kv_x: Optional[jnp.ndarray] = None,                       # cross-attn memory
) -> Tuple[jnp.ndarray, Optional[Tuple[jnp.ndarray, jnp.ndarray]]]:
    B, T, _ = x.shape
    dh = cfg.head_dim
    causal = kv_x is None
    q, k, v = gqa_project_qkv(p, x, positions, cfg, rope=causal, kv_x=kv_x)
    Ts = k.shape[2]

    new_cache = None
    if cache is not None:
        ck, cv = cache  # [B, Hkv, S, dh]
        start = kv_len if kv_len is not None else 0
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (0, 0, start, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (0, 0, start, 0))
        k, v = ck, cv
        new_cache = (ck, cv)
        total = (kv_len + T) if kv_len is not None else T
        out = flash(q, k, v, causal=causal, window=window,
                    kv_len=total, q_offset=kv_len if kv_len is not None else 0)
    else:
        out = flash(q, k, v, causal=causal, window=window,
                    kv_len=Ts if kv_x is not None else T, q_offset=0)
    return gqa_project_out(p, out, cfg), new_cache


def gqa_cross_from_cache(
    p: Dict,
    x: jnp.ndarray,               # [B, T, d] decoder states
    cache: Tuple[jnp.ndarray, jnp.ndarray],  # projected enc K/V [B,Hkv,S,dh]
    cfg: ArchConfig,
    enc_len: Optional[int] = None,
) -> jnp.ndarray:
    """Cross-attention against a *static* projected encoder cache (decode
    path: K/V are projected once at prefill, never recomputed)."""
    B, T, _ = x.shape
    dh = cfg.head_dim
    q = x @ p["wq"]
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(B, T, cfg.n_heads, dh).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
    k, v = cache
    out = flash(q, k, v, causal=False, kv_len=enc_len or k.shape[2])
    out = out.transpose(0, 2, 1, 3).reshape(B, T, cfg.n_heads * dh)
    return out @ p["wo"]


def project_cross_kv(
    p: Dict, memory: jnp.ndarray, cfg: ArchConfig
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    B, S, _ = memory.shape
    dh = cfg.head_dim
    k = memory @ p["wk"]
    v = memory @ p["wv"]
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    k = k.reshape(B, S, cfg.n_kv_heads, dh).transpose(0, 2, 1, 3)
    v = v.reshape(B, S, cfg.n_kv_heads, dh).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        k = rms_norm(k, p["k_norm"])
    return k, v


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): low-rank compressed KV + decoupled RoPE
# ---------------------------------------------------------------------------


def init_mla(init: Initializer, cfg: ArchConfig, L: int) -> Dict:
    d = cfg.d_model
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "wq": init.tensor((L, d, cfg.n_heads * qk), fan_in=d),
        "w_dkv": init.tensor((L, d, cfg.kv_lora + cfg.qk_rope_dim), fan_in=d),
        "kv_norm": init.tensor((L, cfg.kv_lora), zero=True),
        "w_uk": init.tensor((L, cfg.kv_lora, cfg.n_heads * cfg.qk_nope_dim),
                            fan_in=cfg.kv_lora),
        "w_uv": init.tensor((L, cfg.kv_lora, cfg.n_heads * cfg.v_head_dim),
                            fan_in=cfg.kv_lora),
        "wo": init.tensor((L, cfg.n_heads * cfg.v_head_dim, d),
                          fan_in=cfg.n_heads * cfg.v_head_dim),
    }


def mla_attention(
    p: Dict,
    x: jnp.ndarray,               # [B, T, d]
    positions: jnp.ndarray,       # [B, T]
    cfg: ArchConfig,
    cache: Optional[jnp.ndarray] = None,   # compressed: [B, S, kv_lora+rope]
    kv_len: Optional[jnp.ndarray | int] = None,
) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    B, T, _ = x.shape
    H = cfg.n_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim

    q = (x @ p["wq"]).reshape(B, T, H, qk).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., : cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv_new = x @ p["w_dkv"]                       # [B, T, lora+rope]
    # rope part of k is shared across heads, rotated at *its own* position
    k_rope_new = apply_rope(
        ckv_new[:, None, :, cfg.kv_lora:], positions, cfg.rope_theta
    )[:, 0]
    ckv_new = jnp.concatenate(
        [ckv_new[..., : cfg.kv_lora], k_rope_new], axis=-1
    )

    new_cache = None
    if cache is not None:
        start = kv_len if kv_len is not None else 0
        cache = jax.lax.dynamic_update_slice(
            cache, ckv_new.astype(cache.dtype), (0, start, 0)
        )
        new_cache = cache
        ckv = cache
        total = (kv_len + T) if kv_len is not None else T
        q_offset = kv_len if kv_len is not None else 0
    else:
        ckv = ckv_new
        total = T
        q_offset = 0

    S = ckv.shape[1]
    c = rms_norm(ckv[..., : cfg.kv_lora], p["kv_norm"])
    k_nope = (c @ p["w_uk"]).reshape(B, S, H, cfg.qk_nope_dim
                                     ).transpose(0, 2, 1, 3)
    v = (c @ p["w_uv"]).reshape(B, S, H, cfg.v_head_dim).transpose(0, 2, 1, 3)
    k_rope = jnp.broadcast_to(
        ckv[:, None, :, cfg.kv_lora:], (B, H, S, cfg.qk_rope_dim)
    )
    k = jnp.concatenate([k_nope, k_rope], axis=-1)
    qfull = jnp.concatenate([q_nope, q_rope], axis=-1)

    # pad v head dim up to qk dim for the shared kernel, slice after
    if cfg.v_head_dim < qk:
        v = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk - cfg.v_head_dim)))
    out = flash(qfull, k, v, causal=True, kv_len=total, q_offset=q_offset,
                scale=qk ** -0.5)
    out = out[..., : cfg.v_head_dim]
    out = out.transpose(0, 2, 1, 3).reshape(B, T, H * cfg.v_head_dim)
    return out @ p["wo"], new_cache
