"""Transformer blocks shared across the dense/moe/vlm/audio families."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import (
    gqa_attention,
    gqa_cross_from_cache,
    init_gqa,
    init_mla,
    mla_attention,
    project_cross_kv,
)
from .common import ArchConfig, Initializer, activation, rms_norm


def init_mlp(init: Initializer, d: int, f: int, L: int,
             gated: bool = True) -> Dict:
    p = {
        "w_up": init.tensor((L, d, f), fan_in=d),
        "w_down": init.tensor((L, f, d), fan_in=f),
    }
    if gated:
        p["w_gate"] = init.tensor((L, d, f), fan_in=d)
    return p


def mlp(p: Dict, x: jnp.ndarray, act: str) -> jnp.ndarray:
    fn = activation(act)
    if "w_gate" in p:
        return (fn(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    return fn(x @ p["w_up"]) @ p["w_down"]


def init_dense_block(init: Initializer, cfg: ArchConfig, L: int,
                     cross: bool = False, causal_family: bool = True) -> Dict:
    p = {
        "ln1": init.tensor((L, cfg.d_model), zero=True),
        "ln2": init.tensor((L, cfg.d_model), zero=True),
        "attn": (init_mla(init, cfg, L) if cfg.mla
                 else init_gqa(init, cfg, L)),
        "mlp": init_mlp(init, cfg.d_model, cfg.d_ff, L,
                        gated=cfg.gated_mlp),
    }
    if cfg.sandwich_norm:
        p["ln1_post"] = init.tensor((L, cfg.d_model), zero=True)
        p["ln2_post"] = init.tensor((L, cfg.d_model), zero=True)
    if cross:
        p["ln_x"] = init.tensor((L, cfg.d_model), zero=True)
        p["cross"] = init_gqa(init, cfg, L)
    return p


def dense_block(
    p: Dict,                       # single-layer slice
    x: jnp.ndarray,                # [B, T, d]
    positions: jnp.ndarray,
    cfg: ArchConfig,
    window: jnp.ndarray | int = 0,
    cache=None,
    kv_len=None,
    memory: Optional[jnp.ndarray] = None,          # enc-dec cross input
    cross_cache: Optional[Tuple] = None,           # projected enc K/V
    enc_len: Optional[int] = None,
    causal: bool = True,
) -> Tuple[jnp.ndarray, Optional[Tuple]]:
    h = rms_norm(x, p["ln1"])
    if cfg.mla:
        a, new_cache = mla_attention(p["attn"], h, positions, cfg,
                                     cache=cache, kv_len=kv_len)
    else:
        a, new_cache = gqa_attention(
            p["attn"], h, positions, cfg, window=window, cache=cache,
            kv_len=kv_len, kv_x=None if causal else h,
        )
    if cfg.sandwich_norm:
        a = rms_norm(a, p["ln1_post"])
    x = x + a
    if "cross" in p and (memory is not None or cross_cache is not None):
        hx = rms_norm(x, p["ln_x"])
        if cross_cache is not None:
            cx = gqa_cross_from_cache(p["cross"], hx, cross_cache, cfg,
                                      enc_len=enc_len)
        else:
            cx, _ = gqa_attention(p["cross"], hx, positions, cfg,
                                  kv_x=memory)
        x = x + cx
    h = rms_norm(x, p["ln2"])
    m = mlp(p["mlp"], h, cfg.act)
    if cfg.sandwich_norm:
        m = rms_norm(m, p["ln2_post"])
    return x + m, new_cache
