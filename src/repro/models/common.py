"""Shared model substrate: configs, norms, rotary embeddings, init, sharding.

One ArchConfig dataclass covers all ten assigned families; family-specific
fields are ignored where inapplicable.  Parameters are plain dict pytrees;
per-layer parameters are stacked on a leading layer axis so the forward pass
scans over layers (keeps the 512-device dry-run HLO small and compile times
sane).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | ssm | moe | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None          # default d_model // n_heads
    qkv_bias: bool = False
    act: str = "silu"                     # silu | gelu | relu2
    gated_mlp: bool = True                # False: plain act(xW_up)W_down
    rope_theta: float = 10000.0
    partial_rotary: float = 1.0
    qk_norm: bool = False
    sandwich_norm: bool = False           # gemma3 pre+post block norms
    tie_embeddings: bool = False
    # local/global attention (gemma3, mixtral SWA)
    window: int = 0                       # sliding window; 0 = full
    local_global_period: int = 0          # every k-th layer is global (gemma3: 6)
    # multimodal rope (qwen2-vl)
    mrope_sections: Optional[Tuple[int, int, int]] = None
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    first_dense_layers: int = 0           # deepseek: first k layers dense
    router_aux_coef: float = 0.001
    # MLA (deepseek)
    mla: bool = False
    kv_lora: int = 0
    q_lora: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # SSM (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    d_conv: int = 4
    # hybrid (zamba2): shared attention block every k SSM blocks
    shared_attn_period: int = 0
    n_shared_attn_blocks: int = 0
    # encoder-decoder (seamless)
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    # modality frontend stub (vlm/audio): inputs arrive as embeddings
    frontend_stub: bool = False
    max_seq: int = 131072
    dtype: Any = jnp.bfloat16

    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def layer_is_global(self, i: int) -> bool:
        """gemma3-style 5 local : 1 global pattern."""
        if self.local_global_period <= 0:
            return True
        return (i + 1) % self.local_global_period == 0

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline math)."""
        return count_params_analytic(self)


def count_params_analytic(c: ArchConfig) -> int:
    dh = c.head_dim
    n = 0
    n += c.vocab * c.d_model                      # embed
    if not c.tie_embeddings:
        n += c.vocab * c.d_model                  # lm head
    mlp_mats = 3 if c.gated_mlp else 2
    if c.family in ("dense", "vlm"):
        per = (
            c.d_model * (c.n_heads * dh)          # q
            + 2 * c.d_model * (c.n_kv_heads * dh) # k, v
            + (c.n_heads * dh) * c.d_model        # o
            + mlp_mats * c.d_model * c.d_ff       # (gate/)up/down
            + 2 * c.d_model                       # norms
        )
        n += c.n_layers * per
    elif c.family == "moe":
        att = (
            c.d_model * (c.n_heads * dh)
            + 2 * c.d_model * (c.n_kv_heads * dh)
            + (c.n_heads * dh) * c.d_model
        ) if not c.mla else (
            c.d_model * (c.n_heads * (c.qk_nope_dim + c.qk_rope_dim))
            + c.d_model * (c.kv_lora + c.qk_rope_dim)
            + c.kv_lora * (c.n_heads * (c.qk_nope_dim + c.v_head_dim))
            + (c.n_heads * c.v_head_dim) * c.d_model
        )
        ffe = 3 * c.d_model * c.d_ff_expert
        dense_ff = 3 * c.d_model * c.d_ff if c.d_ff else 0
        moe_layers = c.n_layers - c.first_dense_layers
        n += c.n_layers * (att + 2 * c.d_model)
        n += c.first_dense_layers * dense_ff
        n += moe_layers * (
            c.n_experts * ffe
            + c.n_shared_experts * ffe
            + c.d_model * c.n_experts
        )
    elif c.family == "ssm":
        di = c.d_inner
        H = c.n_ssm_heads
        per = (
            c.d_model * (2 * di + 2 * c.ssm_groups * c.ssm_state + H)  # in_proj
            + c.d_conv * (di + 2 * c.ssm_groups * c.ssm_state)         # conv
            + 3 * H                                                     # A, D, dt_bias
            + di * c.d_model                                            # out
            + 2 * c.d_model
        )
        n += c.n_layers * per
    elif c.family == "hybrid":
        di = c.d_inner
        H = c.n_ssm_heads
        per = (
            c.d_model * (2 * di + 2 * c.ssm_groups * c.ssm_state + H)
            + c.d_conv * (di + 2 * c.ssm_groups * c.ssm_state)
            + 3 * H + di * c.d_model + 2 * c.d_model
        )
        n += c.n_layers * per
        attn = (
            (2 * c.d_model) * (c.n_heads * dh)    # q from concat(2d)
            + 2 * (2 * c.d_model) * (c.n_kv_heads * dh)
            + (c.n_heads * dh) * c.d_model
            + 3 * c.d_model * c.d_ff
            + 2 * c.d_model
        )
        n += c.n_shared_attn_blocks * attn
    elif c.family == "audio":
        per = (
            c.d_model * (c.n_heads * dh)
            + 2 * c.d_model * (c.n_kv_heads * dh)
            + (c.n_heads * dh) * c.d_model
            + 3 * c.d_model * c.d_ff
            + 2 * c.d_model
        )
        cross = (
            c.d_model * (c.n_heads * dh)
            + 2 * c.d_model * (c.n_kv_heads * dh)
            + (c.n_heads * dh) * c.d_model
            + c.d_model
        )
        n += c.n_enc_layers * per + c.n_dec_layers * (per + cross)
    return n


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------


def compute_dtype(dtype) -> jnp.dtype:
    """Numerics floor: bf16 inputs compute in f32, but a wider input
    (f64, e.g. the elastic bit-match checks) keeps its own precision —
    downcasting f64 intermediates to f32 would quantize away the 1e-12
    reproducibility the serving resume contract is verified against."""
    return jnp.promote_types(dtype, jnp.float32)


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    cdt = compute_dtype(x.dtype)
    xf = x.astype(cdt)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * (1.0 + w.astype(cdt))
            ).astype(x.dtype)


def activation(name: str) -> Callable[[jnp.ndarray], jnp.ndarray]:
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":  # nemotron squared ReLU
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


def rope_freqs(dh_rot: int, theta: float,
               dtype=jnp.float32) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, dh_rot, 2, dtype=dtype) / dh_rot))


def apply_rope(
    x: jnp.ndarray,            # [B, H, T, dh]
    positions: jnp.ndarray,    # [B, T] int32
    theta: float,
    partial: float = 1.0,
) -> jnp.ndarray:
    dh = x.shape[-1]
    dh_rot = int(dh * partial)
    dh_rot -= dh_rot % 2
    cdt = compute_dtype(x.dtype)
    freqs = rope_freqs(dh_rot, theta, dtype=cdt)            # [dh_rot/2]
    ang = positions[:, None, :, None].astype(cdt) * freqs   # [B,1,T,dr/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    xr = x[..., :dh_rot].astype(cdt)
    x1, x2 = xr[..., ::2], xr[..., 1::2]
    rot = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    rot = rot.reshape(xr.shape)
    return jnp.concatenate(
        [rot.astype(x.dtype), x[..., dh_rot:]], axis=-1
    ) if dh_rot < dh else rot.astype(x.dtype)


def apply_mrope(
    x: jnp.ndarray,            # [B, H, T, dh]
    positions3: jnp.ndarray,   # [B, 3, T] (t, h, w) position ids
    theta: float,
    sections: Tuple[int, int, int],
) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE: frequency pairs split into (t,h,w) sections."""
    dh = x.shape[-1]
    cdt = compute_dtype(x.dtype)
    freqs = rope_freqs(dh, theta, dtype=cdt)                # [dh/2]
    sec = jnp.concatenate([
        jnp.full((s,), i, dtype=jnp.int32) for i, s in enumerate(sections)
    ])                                                      # [dh/2]
    pos = jnp.take(positions3.astype(cdt), sec, axis=1)     # [B, dh/2, T]
    ang = pos.transpose(0, 2, 1)[:, None] * freqs[None, None, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)                   # [B,1,T,dh/2]
    xf = x.astype(cdt)
    x1, x2 = xf[..., ::2], xf[..., 1::2]
    rot = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return rot.reshape(x.shape).astype(x.dtype)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def _trunc_normal(key, shape, scale, dtype):
    std = scale
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            ).astype(dtype)


class Initializer:
    """Deterministic keyed initializer; abstract=True yields ShapeDtypeStructs
    (the dry-run path: no host allocation of 15B-parameter models)."""

    def __init__(self, seed: int, dtype, abstract: bool = False):
        self.key = jax.random.PRNGKey(seed)
        self.dtype = dtype
        self.abstract = abstract
        self._n = 0

    def tensor(self, shape, fan_in: Optional[int] = None, zero: bool = False,
               dtype=None):
        dtype = dtype or self.dtype
        if self.abstract:
            return jax.ShapeDtypeStruct(tuple(shape), dtype)
        self._n += 1
        k = jax.random.fold_in(self.key, self._n)
        if zero:
            return jnp.zeros(shape, dtype)
        fan = fan_in if fan_in else (shape[-2] if len(shape) >= 2 else shape[-1])
        return _trunc_normal(k, shape, 1.0 / math.sqrt(max(fan, 1)), dtype)
