"""Persistent plan/executor cache — ``MPI_*_init`` semantics across solves.

MPI's persistent neighborhood collectives amortize the expensive init
(plan construction, leader election, dedup) over the iterations of *one*
solve.  This cache extends the amortization across solves and across
operators that share a communication pattern: repeated AMG cycles on the
same matrix, a rebuilt hierarchy on an unchanged grid, or several operators
whose halos coincide all hit the same entry.

Entries are keyed on a *pattern fingerprint* — a content hash of the
pattern's ownership/needs arrays plus topology, strategy, value width and
machine params — so two equal patterns hit regardless of object identity.
Bound device executors (which carry ``device_put`` index arrays) are cached
one level down, keyed additionally on (mesh, axis_name).

Entry points:

* :func:`pattern_fingerprint` — content hash of a :class:`CommPattern`.
* :meth:`PlanCache.collective` — cached ``NeighborAlltoallV.init``.
* :meth:`PlanCache.executor` — cached ``collective.bind(mesh, axis)``.
* :meth:`PlanCache.moe_plan` / :meth:`PlanCache.moe_executor` — the same
  amortization surface for MoE token dispatch (``models.moe.moe_plan_for``):
  entries are keyed on the dispatch geometry plus a routing-pattern
  fingerprint, values are opaque to the cache (an ``MoEPlan`` / a jitted
  shard_map dispatch executor), and they share the miss/hit counters so a
  forward pass whose routing re-plans nothing is *observable*.
* :func:`default_plan_cache` — process-wide instance (used by
  ``amg.distributed``, the MoE dispatch path and the benchmarks unless a
  private cache is passed).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np

from ..obs import default_obs, now as _now
from .costmodel import MachineParams, TPU_V5E
from .neighborhood import NeighborAlltoallV
from .plan import CommPattern, Topology

_OBS = default_obs()
_M_HITS = _OBS.counter("plan_cache/hits", "plan-cache hits by namespace")
_M_MISSES = _OBS.counter("plan_cache/misses",
                         "plan-cache misses by namespace")
_M_EVICTIONS = _OBS.counter("plan_cache/evictions",
                            "LRU evictions by namespace")
_H_VERIFY = _OBS.histogram("plan_cache/verify_seconds",
                           "verify-on-insertion wall time by namespace")


def _hash_array(h, name: str, arr: np.ndarray) -> None:
    """Feed one array to the hash with an unambiguous framing.

    The field name, dtype, rank and shape are encoded ahead of the raw
    bytes, so two patterns whose arrays happen to serialize to the same
    byte stream (e.g. an int32 array vs the int64 half its length, or
    needs lists split at different boundaries) cannot collide, and the
    digest is a pure function of content — identical across processes
    and interpreter runs (no ``PYTHONHASHSEED`` anywhere).
    """
    a = np.ascontiguousarray(arr)
    h.update(name.encode())
    h.update(b"\x00")
    h.update(str(a.dtype).encode())
    h.update(np.asarray([a.ndim, *a.shape], dtype=np.int64).tobytes())
    h.update(a.tobytes())


def pattern_fingerprint(pattern: CommPattern) -> str:
    """Content hash of a pattern: equal content -> equal fingerprint.

    Canonical by construction: fields are hashed in a fixed order, each
    framed with its name/dtype/shape (:func:`_hash_array`), and the
    variable-length ``needs`` list is prefixed with its count — the same
    pattern fingerprints identically in every process, and distinct
    patterns cannot alias through ambiguous byte concatenation.
    """
    h = hashlib.blake2b(digest_size=16)
    _hash_array(h, "owner_proc", pattern.owner_proc)
    _hash_array(h, "owner_slot", pattern.owner_slot)
    _hash_array(h, "n_local", pattern.n_local)
    h.update(np.int64(len(pattern.needs)).tobytes())
    for q, need in enumerate(pattern.needs):
        _hash_array(h, f"needs[{q}]", need)
    return h.hexdigest()


def plan_cache_key(
    pattern: CommPattern,
    topo: Topology,
    strategy: str,
    value_bytes: int,
    params: MachineParams,
) -> Tuple:
    """Full cache key: everything ``NeighborAlltoallV.init`` depends on.

    ``params`` matters because ``strategy="auto"`` selects per machine
    model; the frozen dataclass itself is the key component (not just its
    name) so a re-calibrated params object with an unchanged name cannot
    hit a plan selected under the old rates.
    """
    return (
        pattern_fingerprint(pattern),
        topo.n_procs,
        topo.procs_per_region,
        strategy,
        value_bytes,
        params,
    )


@dataclass
class PlanCache:
    """Cache of initialized collectives and bound device executors.

    Bounded: each namespace (collectives, executors, MoE plans, MoE
    executors) holds at most :attr:`max_entries` entries under LRU
    eviction — many distinct routing fingerprints (e.g. adaptive MoE
    re-planning over drifting histograms) can no longer grow the cache
    without bound.  Evictions are counted (:attr:`evictions`) and
    :meth:`stats` breaks hits/misses/entries out per namespace, which is
    what ``repro.profile`` reads when reporting amortization.

    **Stats schema.**  The per-namespace ``_ns_counts`` dicts (filled by
    :meth:`_lookup`, the single increment point) are the only source of
    truth; :meth:`snapshot` is the one documented schema::

        {"counters":   {hits, misses, exec_hits, exec_misses, evictions},
         "namespaces": {ns: {hits, misses, entries}},   # 6 namespaces
         "entries": int, "max_entries": int,
         "init_seconds_spent": float, "init_seconds_saved": float}

    where the flat ``counters`` aggregate plan namespaces (``collective``
    + ``moe_plan`` + ``dense_plan`` → hits/misses) and executor namespaces
    (``executor`` + ``moe_executor`` + ``dense_executor`` →
    exec_hits/exec_misses).  :attr:`hits` &c are
    read-only properties over that aggregation, and :meth:`counters` /
    :meth:`stats` are backward-compatible aliases — both ``repro.obs``
    and ``runtime.controller.cache_delta_event`` read this one schema.
    """

    evictions: int = 0
    max_entries: int = 512          # per namespace; <= 0 disables the bound
    init_seconds_spent: float = 0.0
    init_seconds_saved: float = 0.0
    _colls: Dict[Tuple, NeighborAlltoallV] = field(default_factory=dict)
    _execs: Dict[Tuple, Callable] = field(default_factory=dict)
    # MoE dispatch surface: (value, init_seconds) keyed on geometry +
    # routing-pattern fingerprint (see models.moe.moe_plan_for)
    _moe_plans: Dict[Tuple, Tuple[Any, float]] = field(default_factory=dict)
    _moe_execs: Dict[Tuple, Callable] = field(default_factory=dict)
    # dense-collective surface: ((DensePlan, DenseSelection), init_seconds)
    # keyed on the dense fingerprint + variant + params (core.dense)
    _dense_plans: Dict[Tuple, Tuple[Any, float]] = field(default_factory=dict)
    _dense_execs: Dict[Tuple, Callable] = field(default_factory=dict)
    _ns_counts: Dict[str, Dict[str, int]] = field(default_factory=dict)

    PLAN_NAMESPACES = ("collective", "moe_plan", "dense_plan")
    EXEC_NAMESPACES = ("executor", "moe_executor", "dense_executor")

    # ------------------------------------------------- derived counters
    def _ns_sum(self, namespaces: Tuple[str, ...], which: str) -> int:
        return sum(self._ns(ns)[which] for ns in namespaces)

    @property
    def hits(self) -> int:
        return self._ns_sum(self.PLAN_NAMESPACES, "hits")

    @property
    def misses(self) -> int:
        return self._ns_sum(self.PLAN_NAMESPACES, "misses")

    @property
    def exec_hits(self) -> int:
        return self._ns_sum(self.EXEC_NAMESPACES, "hits")

    @property
    def exec_misses(self) -> int:
        return self._ns_sum(self.EXEC_NAMESPACES, "misses")

    # ---------------------------------------------------- LRU bookkeeping
    def _ns(self, name: str) -> Dict[str, int]:
        return self._ns_counts.setdefault(name, {"hits": 0, "misses": 0})

    def _lookup(self, store: Dict, key, ns: str):
        """LRU-aware get: a hit moves the entry to the recent end.

        The single hit/miss increment point — the flat properties and
        the obs ``plan_cache/*`` counters both hang off it.
        """
        entry = store.get(key)
        if entry is not None:
            store[key] = store.pop(key)    # dicts iterate in insert order
            self._ns(ns)["hits"] += 1
            _M_HITS.inc(ns=ns)
        else:
            self._ns(ns)["misses"] += 1
            _M_MISSES.inc(ns=ns)
        return entry

    def _insert(self, store: Dict, key, value, ns: str) -> None:
        # Verification-on-insertion: every plan entering the cache is
        # checked once, at the only choke point all five plan producers
        # share, then served from the cache unverified (hits are free).
        # The import is lazy (repro.verify imports core) and the knob is
        # read per insert so tests can flip it at runtime.
        from ..verify import verify_cache_value, verify_enabled

        if verify_enabled():
            t0 = _now()
            verify_cache_value(ns, value)
            _H_VERIFY.observe(_now() - t0, ns=ns)
        if self.max_entries > 0 and len(store) >= self.max_entries:
            store.pop(next(iter(store)))   # least-recently used
            self.evictions += 1
            _M_EVICTIONS.inc(ns=ns)
        store[key] = value

    def collective(
        self,
        pattern: CommPattern,
        topo: Topology,
        strategy: str = "auto",
        value_bytes: int = 8,
        params: MachineParams = TPU_V5E,
    ) -> NeighborAlltoallV:
        """Cached ``NeighborAlltoallV.init`` — a hit skips re-planning."""
        key = plan_cache_key(pattern, topo, strategy, value_bytes, params)
        coll = self._lookup(self._colls, key, "collective")
        if coll is not None:
            self.init_seconds_saved += coll.init_seconds
            return coll
        coll = NeighborAlltoallV.init(
            pattern, topo, strategy, value_bytes=value_bytes, params=params
        )
        self.init_seconds_spent += coll.init_seconds
        self._insert(self._colls, key, coll, "collective")
        return coll

    def executor(
        self,
        pattern: CommPattern,
        topo: Topology,
        mesh,
        axis_name: str,
        strategy: str = "auto",
        value_bytes: int = 8,
        params: MachineParams = TPU_V5E,
    ) -> Callable:
        """Cached bound executor (plan + ``device_put`` index arrays)."""
        ckey = plan_cache_key(pattern, topo, strategy, value_bytes, params)
        # silent lookup: binding an executor for an already-initialized
        # collective is not a plan-cache hit (it never risked re-planning)
        coll = self._colls.get(ckey)
        if coll is None:
            coll = self.collective(pattern, topo, strategy, value_bytes, params)
        key = (ckey, mesh, axis_name)
        fn = self._lookup(self._execs, key, "executor")
        if fn is not None:
            return fn
        fn = coll.bind(mesh, axis_name)
        # The jaxpr audit needs the collective's DevicePlan, which only
        # this frame still has next to the bound callable — so executors
        # are audited here rather than in _insert (where they are opaque).
        from ..verify import audit_executor, verify_enabled

        if verify_enabled():
            t0 = _now()
            audit_executor(fn, coll.device_plan, axis_name)
            _H_VERIFY.observe(_now() - t0, ns="executor_audit")
        self._insert(self._execs, key, fn, "executor")
        return fn

    def moe_plan(self, key: Tuple, build: Callable[[], Any]) -> Any:
        """Cached MoE dispatch plan — ``key`` must carry the full dispatch
        geometry (mesh, tokens_per_lane, top_k, mode, cap_factor, ...) plus
        the routing-pattern fingerprint; ``build`` runs only on a miss.

        Shares :attr:`hits` / :attr:`misses` with the collective surface so
        tests can assert "a repeated forward re-plans nothing" across both
        the AMG and the MoE paths with one counter.
        """
        entry = self._lookup(self._moe_plans, key, "moe_plan")
        if entry is not None:
            self.init_seconds_saved += entry[1]
            return entry[0]
        t0 = _now()
        value = build()
        secs = _now() - t0
        self.init_seconds_spent += secs
        self._insert(self._moe_plans, key, (value, secs), "moe_plan")
        return value

    def moe_executor(self, key: Tuple, build: Callable[[], Callable]) -> Callable:
        """Cached jitted dispatch executor for an MoE plan (counts as an
        executor hit/miss, mirroring :meth:`executor`)."""
        fn = self._lookup(self._moe_execs, key, "moe_executor")
        if fn is not None:
            return fn
        fn = build()
        self._insert(self._moe_execs, key, fn, "moe_executor")
        return fn

    def dense_collective(
        self,
        collective: str,
        counts: np.ndarray,
        topo: Topology,
        variant: str = "auto",
        value_bytes: int = 8,
        params: MachineParams = TPU_V5E,
    ) -> Tuple[Any, Any]:
        """Cached ``dense.select_dense`` — returns ``(DensePlan,
        DenseSelection)``; a hit skips building and scoring the candidate
        round schedules (and re-verification)."""
        from .dense import dense_cache_key, select_dense

        key = dense_cache_key(collective, counts, topo, variant,
                              value_bytes, params)
        entry = self._lookup(self._dense_plans, key, "dense_plan")
        if entry is not None:
            self.init_seconds_saved += entry[1]
            return entry[0]
        t0 = _now()
        plan, sel = select_dense(collective, counts, topo, variant,
                                 value_bytes, params)
        secs = _now() - t0
        self.init_seconds_spent += secs
        self._insert(self._dense_plans, key, ((plan, sel), secs),
                     "dense_plan")
        return plan, sel

    def dense_executor(self, plan, mesh, axis_name: str) -> Callable:
        """Cached ``dense.bind_dense`` (jaxpr-audited on the miss, like
        :meth:`executor`), keyed on the plan fingerprint + binding."""
        from .dense import bind_dense

        key = (plan.fingerprint, mesh, axis_name)
        fn = self._lookup(self._dense_execs, key, "dense_executor")
        if fn is not None:
            return fn
        fn = bind_dense(plan, mesh, axis_name)
        from ..verify import audit_dense_executor, verify_enabled

        if verify_enabled():
            t0 = _now()
            audit_dense_executor(fn, plan, axis_name)
            _H_VERIFY.observe(_now() - t0, ns="dense_executor_audit")
        self._insert(self._dense_execs, key, fn, "dense_executor")
        return fn

    def snapshot(self) -> Dict[str, Any]:
        """The one documented stats schema (see class docstring): flat
        aggregates under ``"counters"``, per-namespace breakdowns under
        ``"namespaces"``.  Both :meth:`counters` and :meth:`stats` are
        views of this."""
        sizes = {
            "collective": len(self._colls),
            "executor": len(self._execs),
            "moe_plan": len(self._moe_plans),
            "moe_executor": len(self._moe_execs),
            "dense_plan": len(self._dense_plans),
            "dense_executor": len(self._dense_execs),
        }
        return {
            "counters": {
                "hits": self.hits,
                "misses": self.misses,
                "exec_hits": self.exec_hits,
                "exec_misses": self.exec_misses,
                "evictions": self.evictions,
            },
            "namespaces": {
                ns: {**self._ns(ns), "entries": sizes[ns]}
                for ns in sizes
            },
            "entries": sum(sizes.values()),
            "max_entries": self.max_entries,
            "init_seconds_spent": self.init_seconds_spent,
            "init_seconds_saved": self.init_seconds_saved,
        }

    def counters(self) -> Dict[str, int]:
        """Alias: the flat ``snapshot()["counters"]`` hit/miss aggregates.
        Take one before a rebuild and diff afterwards to attribute
        plan/executor work to that rebuild —
        ``runtime.controller.cache_delta_event`` turns the pair into a
        ``ResizeEvent`` (how the elastic path proves a grow-back to a
        seen geometry re-planned nothing)."""
        return self.snapshot()["counters"]

    def stats(self) -> Dict[str, Any]:
        """Alias: the legacy flat layout (snapshot counters hoisted to the
        top level) plus ``"namespaces"`` — the surface ``repro.profile``
        and the benchmarks report."""
        snap = self.snapshot()
        return {**snap["counters"],
                **{k: v for k, v in snap.items() if k != "counters"}}

    def clear(self) -> None:
        self._colls.clear()
        self._execs.clear()
        self._moe_plans.clear()
        self._moe_execs.clear()
        self._dense_plans.clear()
        self._dense_execs.clear()


_DEFAULT_CACHE: Optional[PlanCache] = None


def default_plan_cache() -> PlanCache:
    """Process-wide cache shared by AMG setup, benchmarks and examples."""
    global _DEFAULT_CACHE
    if _DEFAULT_CACHE is None:
        _DEFAULT_CACHE = PlanCache()
    return _DEFAULT_CACHE
