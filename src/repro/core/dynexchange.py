"""Sparse dynamic data exchange: partner discovery for irregular patterns.

The SpGEMM communication problem the AMG *setup* phase faces (and that
"A More Scalable Sparse Dynamic Data Exchange", arXiv 2308.13869, studies):
a process knows which remote rows it must *fetch* — but the owners of those
rows do not know who will ask.  Before any ``NeighborAlltoallV`` can be
initialized, the send side of the pattern has to be discovered.

This module implements the allreduce-on-counts discovery protocol: every
process contributes a length-``P`` vector of per-destination request counts,
one allreduce(sum) of the ``P x P`` count matrix tells each process exactly
which partners will contact it (and with how much), and the requests
themselves then flow point-to-point between the discovered pairs.  The
output is a :class:`~repro.core.plan.CommPattern` ready for
``PlanCache.collective`` — discovery is the dynamic part, the payload
exchange is a cached persistent collective.

Two primitives cover both directions of irregularity:

* :meth:`SparseDynamicExchange.discover` — *pull*: each rank names the
  globally-indexed values it needs; owners learn their serving sets.
  Used by ``sparse.spgemm.gather_remote_rows`` (remote-row fetch for the
  distributed Galerkin product).
* :meth:`SparseDynamicExchange.push` — *push*: each rank holds payload rows
  with known destinations; receivers learn their sources.  Used for the
  transpose exchanges of the distributed AMG setup (reverse strength edges,
  ``R = P^T``), and the same shape as MoE token routing (tokens know their
  expert, experts do not know their senders) — the utility is deliberately
  payload-agnostic so the MoE dispatch path can reuse it.

Everything here is host-side numpy over simulated ranks, matching the rest
of the planning stack (``core.plan`` / ``core.locality``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .plan import CommPattern


@dataclass
class DiscoveryStats:
    """Cost accounting of one allreduce-on-counts discovery.

    ``allreduce_ints`` is the size of the reduced count matrix (``P*P``
    int64 entries — the protocol's fixed cost); ``request_ints`` the total
    number of request indices that crossed the wire point-to-point after
    discovery; the partner arrays give the per-rank neighborhood sizes the
    discovered pattern will have.
    """

    n_procs: int
    allreduce_ints: int
    request_ints: int
    request_partners: np.ndarray   # per rank: # owners it requests from
    serve_partners: np.ndarray     # per rank: # requesters it must serve

    @property
    def max_request_partners(self) -> int:
        return int(self.request_partners.max()) if self.n_procs else 0

    @property
    def max_serve_partners(self) -> int:
        return int(self.serve_partners.max()) if self.n_procs else 0


def _stats_from_counts(counts: np.ndarray) -> DiscoveryStats:
    """DiscoveryStats of one allreduce-on-counts round, from the reduced
    ``P x P`` count matrix (row = sender/requester, col = receiver/owner)."""
    n_procs = counts.shape[0]
    return DiscoveryStats(
        n_procs=n_procs,
        allreduce_ints=n_procs * n_procs,
        request_ints=int(counts.sum()),
        request_partners=(counts > 0).sum(axis=1),
        serve_partners=(counts > 0).sum(axis=0),
    )


class SparseDynamicExchange:
    """Allreduce-on-counts partner discovery (arXiv 2308.13869)."""

    @staticmethod
    def discover(
        needs: Sequence[np.ndarray], proc_offsets: np.ndarray
    ) -> Tuple[CommPattern, DiscoveryStats]:
        """Pull-side discovery: ``needs[p]`` are the global indices rank
        ``p`` must fetch; ownership is contiguous by ``proc_offsets``.

        Simulates the protocol faithfully: rank ``p`` forms its count row
        ``counts[p, q] = |{g in needs[p] : owner(g) = q}|``, the rows are
        allreduced, and owners read their incoming column.  Returns the
        resulting :class:`CommPattern` (feed it to ``PlanCache.collective``
        for the persistent payload exchange) plus discovery-cost stats.
        """
        proc_offsets = np.asarray(proc_offsets, dtype=np.int64)
        n_procs = len(proc_offsets) - 1
        needs = [np.asarray(n, dtype=np.int64) for n in needs]
        counts = np.zeros((n_procs, n_procs), dtype=np.int64)
        for p, need in enumerate(needs):
            if len(need):
                owners = np.searchsorted(proc_offsets, need, side="right") - 1
                np.add.at(counts[p], owners, 1)
        pattern = CommPattern.from_block_partition(needs, proc_offsets)
        return pattern, _stats_from_counts(counts)

    @staticmethod
    def push_pattern(
        dest: Sequence[np.ndarray],
        local_ids: Optional[Sequence[np.ndarray]] = None,
        n_local: Optional[Sequence[int]] = None,
    ) -> Tuple[CommPattern, DiscoveryStats]:
        """Push-side discovery as a :class:`CommPattern` — the persistent
        half of :meth:`push`.

        Rank ``p`` owns ``n_local[p]`` values; entry ``i`` of ``dest[p]``
        pushes the value locally indexed ``local_ids[p][i]`` (default: row
        ``i`` itself) to rank ``dest[p][i]``.  Globally, rank ``p``'s value
        ``j`` is index ``offset[p] + j``; the receiver's ghost order matches
        :meth:`push` delivery (ascending source rank, original order within
        a source).  The same value may be pushed to several destinations
        (MoE top-k fan-out) — that duplication is exactly what the
        paper's index extension lets the ``full`` planner remove, so the
        returned pattern is directly scoreable by ``core.selection``.
        Feed it to ``PlanCache.collective`` / fingerprint it for
        ``PlanCache.moe_plan`` keys.
        """
        n_procs = len(dest)
        dest = [np.asarray(d, dtype=np.int64) for d in dest]
        if local_ids is None:
            local_ids = [np.arange(len(d), dtype=np.int64) for d in dest]
        else:
            local_ids = [np.asarray(i, dtype=np.int64) for i in local_ids]
        if n_local is None:
            n_local = [
                max(len(d), int(i.max()) + 1 if len(i) else 0)
                for d, i in zip(dest, local_ids)
            ]
        offsets = np.zeros(n_procs + 1, dtype=np.int64)
        offsets[1:] = np.cumsum(n_local)
        counts = np.zeros((n_procs, n_procs), dtype=np.int64)
        for p, d in enumerate(dest):
            if len(d):
                np.add.at(counts[p], d, 1)
        needs: List[np.ndarray] = []
        for q in range(n_procs):
            chunks = [
                offsets[p] + local_ids[p][dest[p] == q]
                for p in range(n_procs)
                if len(dest[p])
            ]
            needs.append(
                np.concatenate(chunks) if chunks
                else np.zeros(0, dtype=np.int64)
            )
        pattern = CommPattern.from_block_partition(needs, offsets)
        return pattern, _stats_from_counts(counts)

    @staticmethod
    def push(
        dest: Sequence[np.ndarray], payload: Sequence[np.ndarray]
    ) -> Tuple[List[np.ndarray], List[np.ndarray], DiscoveryStats]:
        """Push-side exchange: rank ``p`` holds ``payload[p]`` (``[k, ...]``)
        whose row ``i`` is bound for rank ``dest[p][i]``; receivers do not
        know their sources until discovery.

        Returns ``(received, sources, stats)``: ``received[q]`` stacks the
        payload rows delivered to ``q`` (sources in ascending rank order,
        original order preserved within a source — deterministic, so setup
        results are reproducible), ``sources[q]`` the matching source-rank
        array.
        """
        n_procs = len(dest)
        dest = [np.asarray(d, dtype=np.int64) for d in dest]
        payload = [np.asarray(v) for v in payload]
        counts = np.zeros((n_procs, n_procs), dtype=np.int64)
        for p, d in enumerate(dest):
            if len(d):
                np.add.at(counts[p], d, 1)
        trailing = next(
            (v.shape[1:] for v in payload if v.ndim > 1), ()
        )
        # empty-receiver buffers must still carry the senders' declared
        # dtype: an all-empty exchange has no non-empty payload to inspect,
        # so fall back to any payload array's dtype before float64
        dtype = next(
            (v.dtype for v in payload if len(v)),
            next((v.dtype for v in payload), np.float64),
        )
        # one stable sort per sender groups its rows by destination; the
        # per-receiver assembly is then pure concatenation (ascending rank,
        # original order within a rank — same deterministic layout)
        parts: List[List[np.ndarray]] = [[] for _ in range(n_procs)]
        srcs: List[List[np.ndarray]] = [[] for _ in range(n_procs)]
        for p, d in enumerate(dest):
            if not len(d):
                continue
            order = np.argsort(d, kind="stable")
            sorted_d = d[order]
            bounds = np.flatnonzero(np.diff(sorted_d)) + 1
            for chunk in np.split(order, bounds):
                q = int(d[chunk[0]])
                parts[q].append(payload[p][chunk])
                srcs[q].append(np.full(len(chunk), p, dtype=np.int64))
        received: List[np.ndarray] = []
        sources: List[np.ndarray] = []
        for q in range(n_procs):
            if parts[q]:
                received.append(np.concatenate(parts[q]))
                sources.append(np.concatenate(srcs[q]))
            else:
                received.append(np.zeros((0,) + trailing, dtype=dtype))
                sources.append(np.zeros(0, dtype=np.int64))
        return received, sources, _stats_from_counts(counts)
