"""Core: locality-aware persistent neighborhood collectives (the paper's
contribution), planned on host and executed as shard_map collective programs.

Layers: ``plan`` (patterns/plans/round schedules) -> ``locality`` (the three
aggregation strategies) -> ``selection`` (Section-5 dynamic selector) ->
``collectives`` (device executor) -> ``neighborhood`` (the
``NeighborAlltoallV`` facade) -> ``cache`` (plan/executor cache keyed on
pattern fingerprints, amortizing init across solves — the entry point for
anything that exchanges repeatedly, e.g. ``amg.distributed``).
"""
from .plan import (
    CommPattern,
    CommPlan,
    CommStep,
    Message,
    PlanStats,
    StepStats,
    Topology,
    color_rounds,
    padded_wire_volume,
)
from .locality import STRATEGIES, build_plan, plan_full, plan_partial, plan_standard
from .costmodel import (
    LASSEN,
    MACHINES,
    TPU_V5E,
    MachineParams,
    RateSample,
    fit_machine_params,
    plan_time,
    stats_time,
)
from .selection import SelectionReport, per_pattern_best, select_plan
from .collectives import (
    DevicePlan,
    build_device_plan,
    make_executor,
    pack_local_values,
    time_executor,
    unpack_ghosts,
)
from .neighborhood import NeighborAlltoallV
from .dynexchange import DiscoveryStats, SparseDynamicExchange
from .dense import (
    DENSE_COLLECTIVES,
    DensePlan,
    DenseRound,
    DenseSelection,
    bind_dense,
    build_dense_plan,
    dense_fingerprint,
    dense_round_runner,
    dense_time,
    dense_variants,
    even_counts,
    measure_dense_seconds,
    pack_dense_input,
    select_dense,
    unpack_dense_output,
)
from .cache import (
    PlanCache,
    default_plan_cache,
    pattern_fingerprint,
    plan_cache_key,
)

__all__ = [
    "PlanCache", "default_plan_cache", "pattern_fingerprint", "plan_cache_key",
    "DiscoveryStats", "SparseDynamicExchange",
    "DENSE_COLLECTIVES", "DensePlan", "DenseRound", "DenseSelection",
    "bind_dense", "build_dense_plan", "dense_fingerprint",
    "dense_round_runner", "dense_time", "dense_variants", "even_counts",
    "measure_dense_seconds", "pack_dense_input", "select_dense",
    "unpack_dense_output",
    "CommPattern", "CommPlan", "CommStep", "Message", "PlanStats", "StepStats",
    "Topology", "color_rounds", "padded_wire_volume",
    "STRATEGIES", "build_plan", "plan_full", "plan_partial", "plan_standard",
    "LASSEN", "MACHINES", "TPU_V5E", "MachineParams", "RateSample",
    "fit_machine_params", "plan_time", "stats_time",
    "SelectionReport", "per_pattern_best", "select_plan",
    "DevicePlan", "build_device_plan", "make_executor",
    "pack_local_values", "time_executor", "unpack_ghosts",
    "NeighborAlltoallV",
]
