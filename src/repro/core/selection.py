"""Dynamic strategy selection (paper Section 5, future work — implemented).

The paper notes locality-aware aggregation *hurts* on communication-light
patterns (fine AMG levels) and that "a simple performance measure is needed
within the neighborhood collective to dynamically select the optimal
communication strategy".  This module is that selector: build candidate
plans, score them with the locality-aware max-rate model, pick the cheapest.

``select_plan`` is what ``NeighborAlltoallV.init(strategy="auto")`` calls.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from ..obs import now as _now
from .costmodel import MachineParams, TPU_V5E, plan_time
from .locality import STRATEGIES, build_plan
from .plan import CommPattern, CommPlan, Topology


@dataclass
class SelectionReport:
    chosen: str
    modeled_times: Dict[str, float]
    planning_seconds: Dict[str, float]

    def __str__(self) -> str:
        rows = ", ".join(
            f"{k}={v * 1e6:.1f}us" for k, v in sorted(self.modeled_times.items())
        )
        return f"selected={self.chosen} ({rows})"


def select_plan(
    pattern: CommPattern,
    topo: Topology,
    params: MachineParams = TPU_V5E,
    value_bytes: int = 8,
    candidates: Sequence[str] = STRATEGIES,
    amortization_iters: Optional[int] = None,
) -> Tuple[CommPlan, SelectionReport]:
    """Pick the cheapest strategy under the cost model.

    If ``amortization_iters`` is given, planning wall time is amortized over
    that many iterations and added to the per-iteration score — this encodes
    the paper's crossover analysis (Fig 7): aggregation only pays off past
    its crossover iteration count.
    """
    plans: Dict[str, CommPlan] = {}
    times: Dict[str, float] = {}
    walls: Dict[str, float] = {}
    for strat in candidates:
        t0 = _now()
        plan = build_plan(pattern, topo, strat, value_bytes=value_bytes)
        walls[strat] = _now() - t0
        score = plan_time(plan, params)
        if amortization_iters:
            score += walls[strat] / amortization_iters
        plans[strat] = plan
        times[strat] = score
    chosen = min(times, key=lambda k: times[k])
    return plans[chosen], SelectionReport(chosen, times, walls)


def per_pattern_best(
    patterns: Sequence[CommPattern],
    topo: Topology,
    params: MachineParams = TPU_V5E,
    value_bytes: int = 8,
) -> List[Tuple[CommPlan, SelectionReport]]:
    """Paper's scaling-study methodology: per level, take the cheapest of
    standard vs each optimized collective ("summing up the least expensive
    of standard communication and the given optimized neighbor collective")."""
    return [
        select_plan(p, topo, params, value_bytes=value_bytes) for p in patterns
    ]
