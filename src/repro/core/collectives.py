"""Device-side execution of CommPlans: edge-colored ppermute rounds in shard_map.

XLA programs are SPMD with static shapes, so the MPI world of independent
ragged sends becomes a *round schedule*: the planner edge-colors the message
multigraph (``plan.color_rounds``) so that within a round every device sends
to at most one peer and receives from at most one peer — exactly one
``jax.lax.ppermute`` per round, padded to the round's widest message.

Padding bookkeeping uses a sentinel slot: every staging buffer carries one
extra row; gather indices pointing at it read zeros, scatter indices pointing
at it are harmless writes that get dropped when the buffer is consumed.

The executor is built once per plan ("init") and the returned function is
jitted by the caller — persistent-collective semantics for free.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .plan import CommPlan, CommStep, Message, Round, color_rounds


@dataclass
class DeviceRound:
    perm: List[Tuple[int, int]]
    width: int
    gather: np.ndarray   # [P, width] indices into step input buffer (pad = in_pad)
    scatter: np.ndarray  # [P, width] indices into step output buffer (pad = out_pad)


@dataclass
class DeviceStep:
    name: str
    reads_local: bool
    writes_ghost: bool
    in_pad: int    # padded per-device input size (excl. sentinel row)
    out_pad: int
    local_gather: np.ndarray   # [P, Lw] local-copy gathers (pad = in_pad)
    local_scatter: np.ndarray  # [P, Lw]
    rounds: List[DeviceRound]


@dataclass
class DevicePlan:
    strategy: str
    n_procs: int
    n_local_pad: int
    ghost_pad: int
    steps: List[DeviceStep]

    @property
    def n_rounds(self) -> int:
        return sum(len(s.rounds) for s in self.steps)

    @property
    def padded_wire_values(self) -> int:
        return sum(
            r.width * len(r.perm) for s in self.steps for r in s.rounds
        )


def _pack(idx_lists: Sequence[Tuple[int, np.ndarray]], P: int, width: int,
          pad: int) -> np.ndarray:
    out = np.full((P, width), pad, dtype=np.int32)
    for proc, idx in idx_lists:
        out[proc, : len(idx)] = idx
    return out


def build_device_plan(plan: CommPlan) -> DevicePlan:
    """Freeze a CommPlan into padded per-device index arrays + round schedule."""
    P_ = plan.topo.n_procs
    n_local_pad = int(plan.pattern.n_local.max())
    ghost_pad = int(max((len(n) for n in plan.pattern.needs), default=0))

    dsteps: List[DeviceStep] = []
    for step in plan.steps:
        in_pad = n_local_pad if step.reads_local else int(step.in_sizes.max())
        out_pad = ghost_pad if step.writes_ghost else int(step.out_sizes.max())
        local = [m for m in step.messages if m.src == m.dst and m.size > 0]
        lw = max((m.size for m in local), default=0)
        lg = _pack([(m.src, m.src_idx) for m in local], P_, lw, in_pad)
        ls = _pack([(m.dst, m.dst_idx) for m in local], P_, lw, out_pad)
        rounds = []
        for rnd in color_rounds(step.messages):
            w = rnd.width
            g = _pack(
                [(sd[0], si) for sd, si in zip(rnd.pairs, rnd.src_idx)],
                P_, w, in_pad,
            )
            s = _pack(
                [(sd[1], di) for sd, di in zip(rnd.pairs, rnd.dst_idx)],
                P_, w, out_pad,
            )
            rounds.append(DeviceRound(list(rnd.pairs), w, g, s))
        dsteps.append(
            DeviceStep(
                name=step.name,
                reads_local=step.reads_local,
                writes_ghost=step.writes_ghost,
                in_pad=in_pad,
                out_pad=out_pad,
                local_gather=lg,
                local_scatter=ls,
                rounds=rounds,
            )
        )
    return DevicePlan(plan.strategy, P_, n_local_pad, ghost_pad, dsteps)


# ---------------------------------------------------------------------------
# shard_map executor
# ---------------------------------------------------------------------------


def _with_sentinel(buf: jnp.ndarray) -> jnp.ndarray:
    """Append one zero row (the pad sentinel)."""
    pad = jnp.zeros((1,) + buf.shape[1:], buf.dtype)
    return jnp.concatenate([buf, pad], axis=0)


def make_executor(
    dplan: DevicePlan,
    mesh: Mesh,
    axis_name: str,
) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """Build ``exec(x) -> ghosts``.

    ``x``: [n_procs, n_local_pad, d] sharded over ``axis_name`` on dim 0;
    returns [n_procs, ghost_pad, d] with the delivered values.  The function
    body runs under shard_map; jit it (optionally fusing surrounding compute
    — that is how the paper's start/wait overlap materializes: XLA schedules
    the ``l`` rounds concurrently with the ``s``/``g`` chain).
    """
    # Device-plan index arrays become sharded constants.
    steps = dplan.steps

    def per_device(x_blk, *idx_blks):
        # x_blk: [1, n_local_pad, d]
        x = _with_sentinel(x_blk[0])
        ghost = jnp.zeros((dplan.ghost_pad + 1,) + x.shape[1:], x.dtype)
        it = iter(idx_blks)
        buf = None
        for st in steps:
            src = x if st.reads_local else buf
            out = ghost if st.writes_ghost else jnp.zeros(
                (st.out_pad + 1,) + x.shape[1:], x.dtype
            )
            lg = next(it)[0]
            ls = next(it)[0]
            if lg.shape[0] > 0:
                out = out.at[ls].set(src[lg])
            for rnd in st.rounds:
                g = next(it)[0]
                s = next(it)[0]
                sendbuf = src[g]
                recvbuf = jax.lax.ppermute(sendbuf, axis_name, rnd.perm)
                out = out.at[s].set(recvbuf)
            if st.writes_ghost:
                ghost = out
            else:
                buf = out
        return ghost[None, :-1]

    # flatten index arrays in traversal order
    idx_arrays: List[np.ndarray] = []
    for st in steps:
        idx_arrays.append(st.local_gather)
        idx_arrays.append(st.local_scatter)
        for rnd in st.rounds:
            idx_arrays.append(rnd.gather)
            idx_arrays.append(rnd.scatter)

    spec = P(axis_name)
    from ..compat import shard_map

    fn = shard_map(
        per_device,
        mesh=mesh,
        in_specs=(spec,) * (1 + len(idx_arrays)),
        out_specs=spec,
        check_rep=False,
    )

    idx_device = [
        jax.device_put(a, NamedSharding(mesh, spec)) for a in idx_arrays
    ]

    def exec_fn(x: jnp.ndarray) -> jnp.ndarray:
        return fn(x, *idx_device)

    return exec_fn


def time_executor(
    exchange: Callable,
    n_procs: int,
    n_pad: int,
    dtype=np.float64,
    iters: int = 20,
    warmup: int = 3,
    seed: int = 0,
) -> float:
    """Measured wall seconds per exchange of a bound executor.

    The one timing protocol shared by ``benchmarks.amg_comm`` and
    ``amg.distributed`` (jit + compile call + warmup + timed loop), so the
    two measured paths cannot drift.  ``dtype`` defaults to float64 to match
    the plans' ``value_bytes=8`` modeling assumption.
    """
    import jax

    from ..obs import now as _now

    fn = jax.jit(exchange)
    x = jnp.asarray(
        np.random.default_rng(seed)
        .normal(size=(n_procs, max(n_pad, 1), 1))
        .astype(dtype)
    )
    if x.dtype != np.dtype(dtype):
        # jnp.asarray silently downcasts f64 -> f32 when jax_enable_x64 is
        # off, which would halve the wire volume being timed vs the claim
        raise RuntimeError(
            f"requested {np.dtype(dtype)} but device materialized {x.dtype};"
            " enable jax_enable_x64 (or pass the narrower dtype explicitly)"
        )
    fn(x).block_until_ready()  # compile
    for _ in range(warmup):
        fn(x).block_until_ready()
    t0 = _now()
    for _ in range(iters):
        fn(x).block_until_ready()
    return (_now() - t0) / iters


def pack_local_values(
    plan: CommPlan, local_vals: Sequence[np.ndarray], d: Optional[int] = None
) -> np.ndarray:
    """[P, n_local_pad(, d)] global array from ragged per-proc values."""
    P_ = plan.topo.n_procs
    n_pad = int(plan.pattern.n_local.max())
    trailing = local_vals[0].shape[1:]
    out = np.zeros((P_, n_pad) + trailing, dtype=local_vals[0].dtype)
    for p, v in enumerate(local_vals):
        out[p, : len(v)] = v
    return out


def unpack_ghosts(plan: CommPlan, ghosts: np.ndarray) -> List[np.ndarray]:
    return [
        np.asarray(ghosts[p, : len(plan.pattern.needs[p])])
        for p in range(plan.topo.n_procs)
    ]
