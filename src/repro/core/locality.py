"""Locality-aware aggregation planners (paper Sections 3.1-3.3).

Three strategies build a :class:`~repro.core.plan.CommPlan` from a
:class:`~repro.core.plan.CommPattern`:

``standard``
    Algorithm 1-3: every (src, dst) pair exchanges one direct message,
    regardless of locality.  This is what wrapping point-to-point
    communication in a neighborhood collective gives you.

``partial`` (locality-aware aggregation, Section 3.2)
    Three-step aggregation.  Traffic between processes of the *same* region
    stays direct (step ``l``).  Inter-region traffic is (s) redistributed
    inside the source region so that one designated process per destination
    region holds everything bound for it, (g) sent as a single message per
    (region, region) pair, and (r) redistributed inside the destination
    region.  Which local rank serves which remote region is load-balanced.
    Duplicate values (one value needed by several processes of a remote
    region) still cross the wire multiple times — the standard API carries
    no value identity.

``full`` (duplicate removal, Section 3.3)
    Same three-step path, but the planner exploits global value indices (the
    paper's proposed API extension) to move each distinct value at most once
    per hop: once from its owner to the source-region leader, once across
    regions, and fan out to all final destinations only inside the
    destination region.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, List, Sequence, Tuple

import numpy as np

from .plan import (
    CommPattern,
    CommPlan,
    CommStep,
    Message,
    PlanStats,
    StepStats,
    Topology,
)

STRATEGIES = ("standard", "partial", "full")


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _group_needs_by_owner(
    pattern: CommPattern,
) -> List[List[Tuple[int, np.ndarray, np.ndarray]]]:
    """For each dst proc q: list of (src proc, global idx, ghost slots)."""
    out = []
    for q in range(pattern.n_procs):
        need = pattern.needs[q]
        entries: List[Tuple[int, np.ndarray, np.ndarray]] = []
        if len(need):
            owners = pattern.owner_proc[need]
            order = np.argsort(owners, kind="stable")
            sorted_owners = owners[order]
            bounds = np.flatnonzero(np.diff(sorted_owners)) + 1
            for chunk in np.split(order, bounds):
                src = int(owners[chunk[0]])
                entries.append((src, need[chunk], chunk))
        out.append(entries)
    return out


def balance_assignments(
    weights: Dict[int, int], n_workers: int
) -> Dict[int, int]:
    """LPT greedy: assign each key (a remote region) to the least-loaded
    worker (a local rank), heaviest first.  This is the paper's load
    balancing of inter-region responsibility across a region's processes:
    'a minimal portion of messages for small data sizes, or an equal portion
    of data when sizes are large'."""
    loads = np.zeros(n_workers, dtype=np.int64)
    counts = np.zeros(n_workers, dtype=np.int64)
    assign: Dict[int, int] = {}
    # heaviest first; deterministic tie-break on key
    for key in sorted(weights, key=lambda k: (-weights[k], k)):
        w = int(np.lexsort((counts, loads))[0])
        assign[key] = w
        loads[w] += weights[key]
        counts[w] += 1
    return assign


# ---------------------------------------------------------------------------
# standard (Section 3.1)
# ---------------------------------------------------------------------------


def plan_standard(
    pattern: CommPattern, topo: Topology, value_bytes: int = 8
) -> CommPlan:
    msgs: List[Message] = []
    by_owner = _group_needs_by_owner(pattern)
    for q in range(pattern.n_procs):
        for src, gidx, ghost_slots in by_owner[q]:
            msgs.append(
                Message(
                    src=src,
                    dst=q,
                    src_idx=pattern.owner_slot[gidx],
                    dst_idx=ghost_slots,
                )
            )
    ghost_sizes = np.array([len(n) for n in pattern.needs], dtype=np.int64)
    step = CommStep(
        name="p2p",
        messages=msgs,
        in_sizes=pattern.n_local.copy(),
        out_sizes=ghost_sizes,
        reads_local=True,
        writes_ghost=True,
    )
    stats = PlanStats([StepStats.from_messages("p2p", msgs, topo)], value_bytes)
    return CommPlan("standard", topo, pattern, [step], stats)


# ---------------------------------------------------------------------------
# three-step aggregation (Sections 3.2 / 3.3) — shared machinery
# ---------------------------------------------------------------------------


def _plan_aggregated(
    pattern: CommPattern,
    topo: Topology,
    dedup: bool,
    value_bytes: int = 8,
) -> CommPlan:
    P = topo.n_procs
    by_owner = _group_needs_by_owner(pattern)

    # ---- step l: fully-local traffic (direct, incl. self-copies) ----------
    l_msgs: List[Message] = []
    # inter-region demand:
    #   per (src_region R, dst_region S):  entries to cross the wire.
    # dedup=False: one entry per (owner proc p, value g, final dst proc q)
    # dedup=True : one entry per (owner proc p, value g)
    # Collected as: demand[R][S][p] = list of (g, [(q, ghost_slot), ...])
    demand: Dict[int, Dict[int, Dict[int, Dict[int, List[Tuple[int, int]]]]]] = (
        defaultdict(lambda: defaultdict(lambda: defaultdict(dict)))
    )
    for q in range(P):
        S = topo.region(q)
        for src, gidx, ghost_slots in by_owner[q]:
            R = topo.region(src)
            if R == S:
                l_msgs.append(
                    Message(
                        src=src,
                        dst=q,
                        src_idx=pattern.owner_slot[gidx],
                        dst_idx=ghost_slots,
                    )
                )
            else:
                dd = demand[R][S][src]
                for g, slot in zip(gidx.tolist(), ghost_slots.tolist()):
                    dd.setdefault(g, []).append((q, slot))

    ghost_sizes = np.array([len(n) for n in pattern.needs], dtype=np.int64)
    n_local = pattern.n_local

    # ---- leader election + load balancing ---------------------------------
    # send side: region R assigns each destination region S to a local rank
    # recv side: region S assigns each source region R to a local rank
    send_leader: Dict[Tuple[int, int], int] = {}
    recv_leader: Dict[Tuple[int, int], int] = {}

    def wire_entries(R: int, S: int) -> int:
        total = 0
        for p, dd in demand[R][S].items():
            for g, dests in dd.items():
                total += 1 if dedup else len(dests)
        return total

    for R in list(demand.keys()):
        weights = {S: wire_entries(R, S) for S in demand[R]}
        assign = balance_assignments(weights, topo.procs_per_region)
        for S, lr in assign.items():
            send_leader[(R, S)] = R * topo.procs_per_region + lr
    recv_weights: Dict[int, Dict[int, int]] = defaultdict(dict)
    for R in demand:
        for S in demand[R]:
            recv_weights[S][R] = wire_entries(R, S)
    for S, weights in recv_weights.items():
        assign = balance_assignments(weights, topo.procs_per_region)
        for R, lr in assign.items():
            recv_leader[(S, R)] = S * topo.procs_per_region + lr

    # ---- build step s (initial local redistribution) ----------------------
    # stage_s buffer on each send leader: contiguous segments per (S, p, g[,q])
    s_offsets = np.zeros(P, dtype=np.int64)  # running size of stage_s per proc
    s_msgs_acc: Dict[Tuple[int, int], Tuple[List[int], List[int]]] = defaultdict(
        lambda: ([], [])
    )
    # position of each wire entry in the leader's stage_s buffer:
    #   key (R, S) -> list over entries in wire order of
    #   (stage_pos_on_leader, g, [(q, slot), ...])
    wire_layout: Dict[Tuple[int, int], List[Tuple[int, int, List[Tuple[int, int]]]]] = {}

    for R in sorted(demand.keys()):
        for S in sorted(demand[R].keys()):
            ldr = send_leader[(R, S)]
            layout: List[Tuple[int, int, List[Tuple[int, int]]]] = []
            for p in sorted(demand[R][S].keys()):
                dd = demand[R][S][p]
                src_slots: List[int] = []
                stage_pos: List[int] = []
                for g in sorted(dd.keys()):
                    dests = dd[g]
                    owner_slot = int(pattern.owner_slot[g])
                    if dedup:
                        pos = int(s_offsets[ldr]) + len(stage_pos)
                        src_slots.append(owner_slot)
                        stage_pos.append(pos)
                        layout.append((pos, g, dests))
                    else:
                        for (q, slot) in dests:
                            pos = int(s_offsets[ldr]) + len(stage_pos)
                            src_slots.append(owner_slot)
                            stage_pos.append(pos)
                            layout.append((pos, g, [(q, slot)]))
                if src_slots:
                    acc = s_msgs_acc[(p, ldr)]
                    acc[0].extend(src_slots)
                    acc[1].extend(stage_pos)
                    s_offsets[ldr] += len(src_slots)
            wire_layout[(R, S)] = layout

    s_msgs = [
        Message(src=p, dst=ldr, src_idx=np.array(si), dst_idx=np.array(di))
        for (p, ldr), (si, di) in s_msgs_acc.items()
    ]

    # ---- build step g (inter-region) ---------------------------------------
    g_offsets = np.zeros(P, dtype=np.int64)  # stage_g size per proc
    g_msgs: List[Message] = []
    # recv-side layout: key (S, R) -> list of (stage_g_pos_on_recv_leader, g, dests)
    recv_layout: Dict[Tuple[int, int], List[Tuple[int, int, List[Tuple[int, int]]]]] = {}
    for (R, S), layout in sorted(wire_layout.items()):
        if not layout:
            continue
        ldr = send_leader[(R, S)]
        rcv = recv_leader[(S, R)]
        src_idx = np.array([pos for pos, _, _ in layout], dtype=np.int64)
        base = int(g_offsets[rcv])
        dst_idx = base + np.arange(len(layout), dtype=np.int64)
        g_offsets[rcv] += len(layout)
        g_msgs.append(Message(src=ldr, dst=rcv, src_idx=src_idx, dst_idx=dst_idx))
        recv_layout[(S, R)] = [
            (base + i, g, dests) for i, (_, g, dests) in enumerate(layout)
        ]

    # ---- build step r (final local redistribution, with fan-out) ----------
    r_msgs_acc: Dict[Tuple[int, int], Tuple[List[int], List[int]]] = defaultdict(
        lambda: ([], [])
    )
    for (S, R), layout in sorted(recv_layout.items()):
        rcv = recv_leader[(S, R)]
        for pos, g, dests in layout:
            for (q, slot) in dests:
                acc = r_msgs_acc[(rcv, q)]
                acc[0].append(pos)
                acc[1].append(slot)
    r_msgs = [
        Message(src=rcv, dst=q, src_idx=np.array(si), dst_idx=np.array(di))
        for (rcv, q), (si, di) in r_msgs_acc.items()
    ]

    stage_s_sizes = s_offsets
    stage_g_sizes = g_offsets

    steps = [
        CommStep(
            name="l",
            messages=l_msgs,
            in_sizes=n_local.copy(),
            out_sizes=ghost_sizes,
            reads_local=True,
            writes_ghost=True,
        ),
        CommStep(
            name="s",
            messages=s_msgs,
            in_sizes=n_local.copy(),
            out_sizes=stage_s_sizes,
            reads_local=True,
        ),
        CommStep(
            name="g",
            messages=g_msgs,
            in_sizes=stage_s_sizes,
            out_sizes=stage_g_sizes,
        ),
        CommStep(
            name="r",
            messages=r_msgs,
            in_sizes=stage_g_sizes,
            out_sizes=ghost_sizes,
            writes_ghost=True,
        ),
    ]
    stats = PlanStats(
        [StepStats.from_messages(s.name, s.messages, topo) for s in steps],
        value_bytes,
    )
    return CommPlan("full" if dedup else "partial", topo, pattern, steps, stats)


def plan_partial(
    pattern: CommPattern, topo: Topology, value_bytes: int = 8
) -> CommPlan:
    return _plan_aggregated(pattern, topo, dedup=False, value_bytes=value_bytes)


def plan_full(pattern: CommPattern, topo: Topology, value_bytes: int = 8) -> CommPlan:
    return _plan_aggregated(pattern, topo, dedup=True, value_bytes=value_bytes)


PLANNERS = {
    "standard": plan_standard,
    "partial": plan_partial,
    "full": plan_full,
}


def build_plan(
    pattern: CommPattern,
    topo: Topology,
    strategy: str,
    value_bytes: int = 8,
) -> CommPlan:
    if strategy not in PLANNERS:
        raise ValueError(f"unknown strategy {strategy!r}; choose from {STRATEGIES}")
    return PLANNERS[strategy](pattern, topo, value_bytes=value_bytes)
