"""Communication patterns and persistent-collective plans.

This module is the host-side (numpy) half of the paper's contribution: the
data structures behind ``MPI_Neighbor_alltoallv_init``.  A :class:`CommPattern`
describes *what* must move (which process needs which globally-indexed values);
a :class:`CommPlan` describes *how* it moves (an ordered list of
:class:`CommStep` s, each a set of point-to-point :class:`Message` s between
staging buffers).  Building a plan is the expensive, once-per-pattern
"init" of the persistent collective; executing it every iteration is cheap
(``core.collectives`` compiles the plan into ``ppermute`` rounds inside
``shard_map``; :meth:`CommPlan.execute_numpy` is the host oracle).

Value identity is a *global index*, which is exactly the API extension the
paper proposes (Section 3.3): with indices available, the planner can remove
duplicate values from inter-region traffic.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Topology: the machine's locality structure (regions of processes).
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Topology:
    """Processes grouped into regions of uniform size.

    A *region* is the locality domain inside which communication is cheap:
    a NUMA domain / CPU / node in the paper; a TPU pod (ICI domain) here.
    """

    n_procs: int
    procs_per_region: int

    def __post_init__(self):
        if self.n_procs % self.procs_per_region != 0:
            raise ValueError(
                f"n_procs={self.n_procs} not divisible by "
                f"procs_per_region={self.procs_per_region}"
            )

    @property
    def n_regions(self) -> int:
        return self.n_procs // self.procs_per_region

    def region(self, proc: int) -> int:
        return proc // self.procs_per_region

    def local_rank(self, proc: int) -> int:
        return proc % self.procs_per_region

    def procs_in_region(self, region: int) -> range:
        base = region * self.procs_per_region
        return range(base, base + self.procs_per_region)

    def same_region(self, p: int, q: int) -> bool:
        return self.region(p) == self.region(q)


# ---------------------------------------------------------------------------
# Pattern: what must be communicated.
# ---------------------------------------------------------------------------


class CommPattern:
    """An irregular communication pattern over globally-indexed values.

    Every value has a unique global index ``g``; ``owner_proc[g]`` holds it at
    slot ``owner_slot[g]`` of that process's local value array.  Process ``q``
    must end up with the values listed in ``needs[q]`` (its "ghost" slots, in
    order).  This is the information carried by the send/recv argument lists
    of ``MPI_Neighbor_alltoallv_init`` *plus* the paper's proposed index
    extension (needed for de-duplication).
    """

    def __init__(
        self,
        owner_proc: np.ndarray,
        owner_slot: np.ndarray,
        needs: Sequence[np.ndarray],
        n_local: np.ndarray,
    ):
        self.owner_proc = np.asarray(owner_proc, dtype=np.int64)
        self.owner_slot = np.asarray(owner_slot, dtype=np.int64)
        self.needs = [np.asarray(n, dtype=np.int64) for n in needs]
        self.n_local = np.asarray(n_local, dtype=np.int64)
        self.n_procs = len(self.needs)
        self.n_global = len(self.owner_proc)

    # -- constructors -------------------------------------------------------

    @staticmethod
    def from_block_partition(
        needs: Sequence[np.ndarray], proc_offsets: np.ndarray
    ) -> "CommPattern":
        """Pattern where global indices are contiguously block-partitioned.

        ``proc_offsets`` has length n_procs+1; proc ``p`` owns global indices
        ``[proc_offsets[p], proc_offsets[p+1])``.
        """
        proc_offsets = np.asarray(proc_offsets, dtype=np.int64)
        n_procs = len(proc_offsets) - 1
        n_global = int(proc_offsets[-1])
        owner_proc = np.zeros(n_global, dtype=np.int64)
        owner_slot = np.zeros(n_global, dtype=np.int64)
        for p in range(n_procs):
            lo, hi = int(proc_offsets[p]), int(proc_offsets[p + 1])
            owner_proc[lo:hi] = p
            owner_slot[lo:hi] = np.arange(hi - lo)
        n_local = np.diff(proc_offsets)
        return CommPattern(owner_proc, owner_slot, list(needs), n_local)

    # -- derived ------------------------------------------------------------

    def sends_for(self, q: int) -> Dict[int, np.ndarray]:
        """Group ``needs[q]`` by owner: {src_proc: global indices}."""
        need = self.needs[q]
        if len(need) == 0:
            return {}
        owners = self.owner_proc[need]
        order = np.argsort(owners, kind="stable")
        out: Dict[int, np.ndarray] = {}
        sorted_owners = owners[order]
        bounds = np.flatnonzero(np.diff(sorted_owners)) + 1
        for chunk in np.split(order, bounds):
            out[int(owners[chunk[0]])] = need[chunk]
        return out

    def total_ghosts(self) -> int:
        return int(sum(len(n) for n in self.needs))


# ---------------------------------------------------------------------------
# Plan: how it is communicated.
# ---------------------------------------------------------------------------


@dataclass
class Message:
    """One point-to-point message between staging buffers.

    ``src_idx[i]`` (index into ``src``'s input buffer of this step) is
    delivered to ``dst_idx[i]`` (index into ``dst``'s output buffer).
    ``src == dst`` denotes a local copy (no wire traffic).
    """

    src: int
    dst: int
    src_idx: np.ndarray
    dst_idx: np.ndarray

    def __post_init__(self):
        self.src_idx = np.asarray(self.src_idx, dtype=np.int64)
        self.dst_idx = np.asarray(self.dst_idx, dtype=np.int64)
        assert len(self.src_idx) == len(self.dst_idx)

    @property
    def size(self) -> int:
        return len(self.src_idx)


@dataclass
class CommStep:
    """One step of a plan: a set of messages input-buffer -> output-buffer.

    ``in_sizes[p]`` / ``out_sizes[p]`` are the per-process buffer sizes.
    Step inputs chain: step k's output buffer is step k+1's input buffer,
    except steps flagged ``reads_local=True`` which read the original local
    values, and ``writes_ghost=True`` which write the final ghost buffer.
    """

    name: str
    messages: List[Message]
    in_sizes: np.ndarray
    out_sizes: np.ndarray
    reads_local: bool = False
    writes_ghost: bool = False


@dataclass
class StepStats:
    """Exact (unpadded) per-process traffic of one step, split by locality."""

    name: str
    # per-proc counts of *sent* messages / values (excluding local copies)
    intra_msgs: np.ndarray
    inter_msgs: np.ndarray
    intra_vals: np.ndarray
    inter_vals: np.ndarray

    @staticmethod
    def from_messages(name: str, msgs: List[Message], topo: Topology) -> "StepStats":
        P = topo.n_procs
        im = np.zeros(P, dtype=np.int64)
        xm = np.zeros(P, dtype=np.int64)
        iv = np.zeros(P, dtype=np.int64)
        xv = np.zeros(P, dtype=np.int64)
        for m in msgs:
            if m.src == m.dst or m.size == 0:
                continue
            if topo.same_region(m.src, m.dst):
                im[m.src] += 1
                iv[m.src] += m.size
            else:
                xm[m.src] += 1
                xv[m.src] += m.size
        return StepStats(name, im, xm, iv, xv)


@dataclass
class PlanStats:
    """Aggregated over steps; the quantities behind the paper's Figs 8-10."""

    steps: List[StepStats]
    value_bytes: int

    def _sum(self, attr: str) -> np.ndarray:
        return np.sum([getattr(s, attr) for s in self.steps], axis=0)

    @property
    def intra_msgs(self) -> np.ndarray:
        return self._sum("intra_msgs")

    @property
    def inter_msgs(self) -> np.ndarray:
        return self._sum("inter_msgs")

    @property
    def intra_bytes(self) -> np.ndarray:
        return self._sum("intra_vals") * self.value_bytes

    @property
    def inter_bytes(self) -> np.ndarray:
        return self._sum("inter_vals") * self.value_bytes

    def max_intra_msgs(self) -> int:
        return int(self.intra_msgs.max()) if len(self.steps) else 0

    def max_inter_msgs(self) -> int:
        return int(self.inter_msgs.max()) if len(self.steps) else 0

    def max_inter_bytes(self) -> int:
        return int(self.inter_bytes.max()) if len(self.steps) else 0

    def max_intra_bytes(self) -> int:
        return int(self.intra_bytes.max()) if len(self.steps) else 0

    def totals(self) -> Dict[str, int]:
        return {
            "intra_msgs": int(self.intra_msgs.sum()),
            "inter_msgs": int(self.inter_msgs.sum()),
            "intra_bytes": int(self.intra_bytes.sum()),
            "inter_bytes": int(self.inter_bytes.sum()),
        }


@dataclass
class CommPlan:
    """A fully-resolved persistent neighborhood collective.

    Produced once per pattern by ``core.locality`` planners (the "init");
    executed every iteration either on host (:meth:`execute_numpy`, the
    oracle) or on device (``core.collectives.build_executor``).
    """

    strategy: str
    topo: Topology
    pattern: CommPattern
    steps: List[CommStep]
    stats: PlanStats

    # ------------------------------------------------------------------ exec

    def execute_numpy(self, local_vals: Sequence[np.ndarray]) -> List[np.ndarray]:
        """Host-side reference execution. ``local_vals[p]``: [n_local_p, ...]."""
        P = self.topo.n_procs
        trailing = local_vals[0].shape[1:] if local_vals[0].ndim > 1 else ()
        dtype = local_vals[0].dtype
        ghosts: List[np.ndarray] = [
            np.zeros((len(self.pattern.needs[p]),) + trailing, dtype=dtype)
            for p in range(P)
        ]
        bufs: List[Optional[np.ndarray]] = [None] * P
        for step in self.steps:
            src_bufs = local_vals if step.reads_local else bufs
            if step.writes_ghost:
                dst_bufs = ghosts
            else:
                dst_bufs = [
                    np.zeros((int(step.out_sizes[p]),) + trailing, dtype=dtype)
                    for p in range(P)
                ]
            for m in step.messages:
                if m.size == 0:
                    continue
                dst_bufs[m.dst][m.dst_idx] = src_bufs[m.src][m.src_idx]
            if not step.writes_ghost:
                bufs = dst_bufs
        return ghosts

    # ----------------------------------------------------------------- introspection

    def describe(self) -> str:
        lines = [f"CommPlan(strategy={self.strategy}, procs={self.topo.n_procs}, "
                 f"regions={self.topo.n_regions})"]
        for st, ss in zip(self.steps, self.stats.steps):
            lines.append(
                f"  step {st.name:>3}: msgs intra={int(ss.intra_msgs.sum())} "
                f"inter={int(ss.inter_msgs.sum())}  vals intra={int(ss.intra_vals.sum())} "
                f"inter={int(ss.inter_vals.sum())}"
            )
        t = self.stats.totals()
        lines.append(f"  totals: {t}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Round scheduling: edge-color messages so each round is a partial permutation
# (one ``lax.ppermute`` per round on device).
# ---------------------------------------------------------------------------


@dataclass
class Round:
    """One ppermute round: disjoint (src, dst) pairs + per-proc slices."""

    pairs: List[Tuple[int, int]]
    # per message in `pairs` order: gather / scatter index arrays
    src_idx: List[np.ndarray]
    dst_idx: List[np.ndarray]

    @property
    def width(self) -> int:
        return max((len(s) for s in self.src_idx), default=0)


def color_rounds(messages: List[Message]) -> List[Round]:
    """Greedy edge coloring of the message multigraph.

    Each process sends to at most one peer and receives from at most one peer
    per round, matching a single ``lax.ppermute``.  Local copies (src==dst)
    are excluded (they execute as gather/scatter without wire traffic).
    Larger messages are colored first so that rounds are size-homogeneous,
    minimizing padding waste.
    """
    wire = [m for m in messages if m.src != m.dst and m.size > 0]
    wire.sort(key=lambda m: -m.size)
    send_used: Dict[int, set] = {}
    recv_used: Dict[int, set] = {}
    rounds: List[Round] = []
    for m in wire:
        su = send_used.setdefault(m.src, set())
        ru = recv_used.setdefault(m.dst, set())
        c = 0
        while c in su or c in ru:
            c += 1
        while c >= len(rounds):
            rounds.append(Round([], [], []))
        su.add(c)
        ru.add(c)
        rounds[c].pairs.append((m.src, m.dst))
        rounds[c].src_idx.append(m.src_idx)
        rounds[c].dst_idx.append(m.dst_idx)
    return rounds


def plan_wire_rounds(plan: CommPlan) -> Dict[str, List[Round]]:
    """Rounds per step — the on-wire schedule the device executor runs."""
    return {s.name: color_rounds(s.messages) for s in plan.steps}


def padded_wire_volume(plan: CommPlan) -> Dict[str, int]:
    """Values actually moved per step after SPMD padding (width × pairs)."""
    out = {}
    for s in plan.steps:
        rounds = color_rounds(s.messages)
        out[s.name] = int(sum(r.width * len(r.pairs) for r in rounds))
    return out
