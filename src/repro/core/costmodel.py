"""Locality-aware communication cost models (paper refs [2,6,16,32]).

The container is CPU-only, so network timings for paper-figure benchmarks are
*modeled* while message counts/bytes are *measured* from plans.  We implement
the locality-aware max-rate model of Bienz/Gropp/Olson: postal model
``alpha + bytes/beta`` with distinct parameters per locality class, plus a
per-region injection-bandwidth cap shared by the region's active senders.

Two parameter sets ship:

* ``LASSEN`` — SMP-cluster constants representative of the paper's system
  (Power9 + EDR InfiniBand; on-node via shared memory).
* ``TPU_V5E`` — the repo's target: intra-pod ICI vs inter-pod DCI.

Absolute values are representative published orders of magnitude; every
EXPERIMENTS.md table derived from this model is labeled *modeled*.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import numpy as np

from .plan import CommPlan, PlanStats, Topology


@dataclass(frozen=True)
class MachineParams:
    name: str
    # postal parameters per locality class
    alpha_intra: float  # latency, s
    beta_intra: float   # per-proc bandwidth, B/s
    alpha_inter: float
    beta_inter: float
    # max-rate: total injection bandwidth out of a region, B/s (shared)
    region_injection_bw: float
    # short-message eager cutoff: below this, latency dominates & msgs pipeline
    eager_bytes: int = 8192


LASSEN = MachineParams(
    name="lassen-smp",
    alpha_intra=5.0e-7,
    beta_intra=30.0e9,
    alpha_inter=2.2e-6,
    beta_inter=11.0e9,
    region_injection_bw=22.0e9,
)

TPU_V5E = MachineParams(
    name="tpu-v5e",
    alpha_intra=1.0e-6,
    beta_intra=100.0e9,   # ICI per-chip (multiple 50 GB/s links, bidir torus)
    alpha_inter=10.0e-6,
    beta_inter=6.25e9,    # DCI per-chip share
    region_injection_bw=400.0e9,
)

MACHINES: Dict[str, MachineParams] = {m.name: m for m in (LASSEN, TPU_V5E)}


def step_time(
    stats_step, topo: Topology, params: MachineParams, value_bytes: int
) -> float:
    """Max-rate time of one plan step (bulk-synchronous: max over procs)."""
    intra_b = stats_step.intra_vals * value_bytes
    inter_b = stats_step.inter_vals * value_bytes
    t_proc = (
        stats_step.intra_msgs * params.alpha_intra
        + intra_b / params.beta_intra
        + stats_step.inter_msgs * params.alpha_inter
        + inter_b / params.beta_inter
    )
    # max-rate injection constraint: a region's combined inter-region bytes
    # cannot exceed its injection bandwidth.
    R = topo.n_regions
    per_region = inter_b.reshape(R, topo.procs_per_region).sum(axis=1)
    t_inject = per_region / params.region_injection_bw
    t_region = (
        t_proc.reshape(R, topo.procs_per_region).max(axis=1)
    )
    return float(np.maximum(t_region, t_inject).max())


def plan_time(plan: CommPlan, params: MachineParams) -> float:
    """Modeled per-iteration time of a plan.

    Steps are dependency-ordered (s -> g -> r) except step ``l`` which
    overlaps the global path (the paper starts ``l`` and ``g`` together and
    waits at the end): total = max(l, s + g + r).
    """
    vb = plan.stats.value_bytes
    by_name = {s.name: step_time(s, plan.topo, params, vb) for s in plan.stats.steps}
    if set(by_name) == {"p2p"}:
        return by_name["p2p"]
    serial = by_name.get("s", 0.0) + by_name.get("g", 0.0) + by_name.get("r", 0.0)
    return max(by_name.get("l", 0.0), serial)


def init_time(plan: CommPlan, params: MachineParams,
              measured_wall: float = 0.0) -> float:
    """Modeled network cost of the persistent init (graph creation +
    aggregation setup), comparable with the modeled per-iteration cost:

    * one handshake round-trip per neighbor (topology/graph creation),
    * two index-exchange sweeps over the plan's own message structure
      (int32 indices instead of f64 values — the load-balancing and
      path-setup traffic of aggregated strategies).

    ``measured_wall`` (host planning time) is reported separately by the
    benchmarks — it is C-library work in the paper's MPI Advance, so the
    python wall time is not added into the modeled crossover."""
    st = plan.stats
    handshakes = int(st.inter_msgs.max() + st.intra_msgs.max())
    index_sweeps = 2 * plan_time(plan, params) * (4.0 / plan.stats.value_bytes)
    return handshakes * params.alpha_inter * 2 + index_sweeps
