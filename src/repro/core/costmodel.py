"""Locality-aware communication cost models (paper refs [2,6,16,32]).

The container is CPU-only, so network timings for paper-figure benchmarks are
*modeled* while message counts/bytes are *measured* from plans.  We implement
the locality-aware max-rate model of Bienz/Gropp/Olson: postal model
``alpha + bytes/beta`` with distinct parameters per locality class, plus a
per-region injection-bandwidth cap shared by the region's active senders.

Two parameter sets ship:

* ``LASSEN`` — SMP-cluster constants representative of the paper's system
  (Power9 + EDR InfiniBand; on-node via shared memory).
* ``TPU_V5E`` — the repo's target: intra-pod ICI vs inter-pod DCI.

Absolute values are representative published orders of magnitude; every
EXPERIMENTS.md table derived from this model is labeled *modeled*.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Sequence, Tuple

import numpy as np

from .plan import CommPlan, PlanStats, Topology


@dataclass(frozen=True)
class MachineParams:
    name: str
    # postal parameters per locality class
    alpha_intra: float  # latency, s
    beta_intra: float   # per-proc bandwidth, B/s
    alpha_inter: float
    beta_inter: float
    # max-rate: total injection bandwidth out of a region, B/s (shared)
    region_injection_bw: float
    # short-message eager cutoff: below this, latency dominates & msgs pipeline
    eager_bytes: int = 8192


LASSEN = MachineParams(
    name="lassen-smp",
    alpha_intra=5.0e-7,
    beta_intra=30.0e9,
    alpha_inter=2.2e-6,
    beta_inter=11.0e9,
    region_injection_bw=22.0e9,
)

TPU_V5E = MachineParams(
    name="tpu-v5e",
    alpha_intra=1.0e-6,
    beta_intra=100.0e9,   # ICI per-chip (multiple 50 GB/s links, bidir torus)
    alpha_inter=10.0e-6,
    beta_inter=6.25e9,    # DCI per-chip share
    region_injection_bw=400.0e9,
)

MACHINES: Dict[str, MachineParams] = {m.name: m for m in (LASSEN, TPU_V5E)}


def step_time(
    stats_step, topo: Topology, params: MachineParams, value_bytes: int
) -> float:
    """Max-rate time of one plan step (bulk-synchronous: max over procs)."""
    intra_b = stats_step.intra_vals * value_bytes
    inter_b = stats_step.inter_vals * value_bytes
    t_proc = (
        stats_step.intra_msgs * params.alpha_intra
        + intra_b / params.beta_intra
        + stats_step.inter_msgs * params.alpha_inter
        + inter_b / params.beta_inter
    )
    # max-rate injection constraint: a region's combined inter-region bytes
    # cannot exceed its injection bandwidth.
    R = topo.n_regions
    per_region = inter_b.reshape(R, topo.procs_per_region).sum(axis=1)
    t_inject = per_region / params.region_injection_bw
    t_region = (
        t_proc.reshape(R, topo.procs_per_region).max(axis=1)
    )
    return float(np.maximum(t_region, t_inject).max())


def stats_time(stats: PlanStats, topo: Topology, params: MachineParams) -> float:
    """Modeled per-iteration time from plan *stats* alone.

    Steps are dependency-ordered (s -> g -> r) except step ``l`` which
    overlaps the global path (the paper starts ``l`` and ``g`` together and
    waits at the end): total = max(l, s + g + r).  Split out of
    :func:`plan_time` so trace samples (which carry stats, not full plans)
    can be scored and fitted with the identical arithmetic.
    """
    vb = stats.value_bytes
    by_name = {s.name: step_time(s, topo, params, vb) for s in stats.steps}
    if set(by_name) == {"p2p"}:
        return by_name["p2p"]
    if not set(by_name) <= {"p2p", "l", "s", "g", "r"}:
        # generic round schedules (dense collectives: steps d0..dk) are
        # bulk-synchronous and dependency-ordered -> plain serial sum.
        return float(sum(by_name.values()))
    serial = by_name.get("s", 0.0) + by_name.get("g", 0.0) + by_name.get("r", 0.0)
    return max(by_name.get("l", 0.0), serial)


def plan_time(plan: CommPlan, params: MachineParams) -> float:
    """Modeled per-iteration time of a plan (see :func:`stats_time`)."""
    return stats_time(plan.stats, plan.topo, params)


def init_time(plan: CommPlan, params: MachineParams,
              measured_wall: float = 0.0) -> float:
    """Modeled network cost of the persistent init (graph creation +
    aggregation setup), comparable with the modeled per-iteration cost:

    * one handshake round-trip per neighbor (topology/graph creation),
    * two index-exchange sweeps over the plan's own message structure
      (int32 indices instead of f64 values — the load-balancing and
      path-setup traffic of aggregated strategies).

    ``measured_wall`` (host planning time) is reported separately by the
    benchmarks — it is C-library work in the paper's MPI Advance, so the
    python wall time is not added into the modeled crossover."""
    st = plan.stats
    handshakes = int(st.inter_msgs.max() + st.intra_msgs.max())
    index_sweeps = 2 * plan_time(plan, params) * (4.0 / plan.stats.value_bytes)
    return handshakes * params.alpha_inter * 2 + index_sweeps


# ---------------------------------------------------------------------------
# Exchange/compute overlap terms.
#
# The split SpMV schedule (sparse.device.make_distributed_spmv(overlap=True))
# runs the local-bucket matvec while the NeighborAlltoallV is in flight, so
# of a modeled exchange time tx only max(0, tx - tl) stays exposed, where tl
# is the local compute time.  The compute side is the same roofline
# arithmetic as benchmarks/roofline_report.py (which imports these
# constants): HBM-bound sparse streams vs VPU multiply-add throughput.
# ---------------------------------------------------------------------------

#: v5e HBM bandwidth and VPU f32 multiply-add throughput (per chip).
V5E_HBM_BW = 819e9
V5E_VPU_FLOPS = 1.97e12 / 4

#: Fixed cost of one extra kernel dispatch (the overlap split adds one).
KERNEL_LAUNCH_S = 2e-6

_IDX_BYTES = 4  # int32 column indices


def spmv_compute_time(
    nnz: int,
    rows: int,
    x_len: int,
    value_bytes: int = 8,
    hbm_bw: float = V5E_HBM_BW,
    vpu_flops: float = V5E_VPU_FLOPS,
) -> float:
    """Roofline compute time of one per-device ELL matvec phase: stream
    nnz (cols + vals) + x + y through HBM, 2 flops per nonzero."""
    bytes_moved = (
        nnz * (_IDX_BYTES + value_bytes)
        + x_len * value_bytes
        + rows * value_bytes
    )
    flops = 2.0 * nnz
    return max(bytes_moved / hbm_bw, flops / vpu_flops)


def overlap_split_overhead(
    rows: int,
    value_bytes: int = 8,
    hbm_bw: float = V5E_HBM_BW,
    launch_s: float = KERNEL_LAUNCH_S,
) -> float:
    """Cost of splitting the SpMV into local + ghost phases: the carried
    partial output makes one extra HBM round trip (write then read of
    ``rows`` values), plus one extra kernel launch."""
    return launch_s + 2.0 * rows * value_bytes / hbm_bw


def modeled_fine_exchange_time(
    n_neighbors: int,
    ghost_values: int,
    value_bytes: int = 8,
    params: MachineParams = TPU_V5E,
) -> float:
    """Postal-model exchange time of an analytic paper-scale fine level
    (``n_neighbors`` inter-region messages carrying ``ghost_values`` values
    in total) — for benchmark rows where the matrix is never materialized
    and no plan exists to run :func:`plan_time` on."""
    return (
        n_neighbors * params.alpha_inter
        + ghost_values * value_bytes / params.beta_inter
    )


def exposed_exchange_seconds(exchange_s: float, local_s: float) -> float:
    """Exchange time left exposed when local compute runs concurrently."""
    return max(0.0, float(exchange_s) - float(local_s))


def hidden_fraction(exchange_s: float, local_s: float) -> float:
    """Fraction of the exchange hidden behind local compute (0 when there
    is no exchange)."""
    tx = float(exchange_s)
    if tx <= 0.0:
        return 0.0
    return min(tx, float(local_s)) / tx


# ---------------------------------------------------------------------------
# Fit-from-samples: turn measured exchange timings into a MachineParams.
#
# The max-rate model is piecewise linear in
#   theta = (alpha_intra, 1/beta_intra, alpha_inter, 1/beta_inter,
#            1/region_injection_bw)
# with the active piece determined by which process (or which region's
# injection cap) is the bottleneck of each step.  Fitting therefore
# alternates (a) selecting each sample's bottleneck rows under the current
# theta with (b) a nonnegative least-squares solve over the resulting
# linear features — a majorize-style loop that recovers the generating
# params exactly when samples were synthesized from this very model (the
# round-trip property tested in tests/test_profile_calibration.py).
# ``eager_bytes`` is not a rate and is held fixed at the reference value.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RateSample:
    """One fitting observation: exact plan traffic + a measured time."""

    stats: PlanStats
    topo: Topology
    seconds: float
    label: str = ""


THETA_FIELDS = (
    "alpha_intra", "inv_beta_intra", "alpha_inter", "inv_beta_inter",
    "inv_injection_bw",
)


def _theta_of(params: MachineParams) -> np.ndarray:
    return np.array([
        params.alpha_intra,
        1.0 / params.beta_intra,
        params.alpha_inter,
        1.0 / params.beta_inter,
        1.0 / params.region_injection_bw,
    ])


def _params_of(theta: np.ndarray, name: str, ref: MachineParams,
               excited: np.ndarray) -> MachineParams:
    """theta -> MachineParams; columns the samples never excited (or that
    fit to zero rate) fall back to the reference so the result is always a
    finite, usable parameter set."""
    t = np.where(excited, theta, _theta_of(ref))

    def inv(x: float, fallback: float) -> float:
        return 1.0 / x if x > 0 else fallback

    return MachineParams(
        name=name,
        alpha_intra=float(max(t[0], 0.0)),
        beta_intra=inv(float(t[1]), ref.beta_intra),
        alpha_inter=float(max(t[2], 0.0)),
        beta_inter=inv(float(t[3]), ref.beta_inter),
        region_injection_bw=inv(float(t[4]), ref.region_injection_bw),
        eager_bytes=ref.eager_bytes,  # not a rate: held fixed (see ISSUE 4)
    )


def _nnls(A: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Lawson-Hanson nonnegative least squares (tiny: n <= 5 here)."""
    m, n = A.shape
    x = np.zeros(n)
    passive = np.zeros(n, dtype=bool)
    w = A.T @ (b - A @ x)
    tol = 1e-12 * (float(np.abs(A).sum()) + 1.0)
    budget = 10 * (n + 1)
    while budget > 0 and (~passive).any() and \
            float(np.max(np.where(~passive, w, -np.inf))) > tol:
        budget -= 1
        j = int(np.argmax(np.where(~passive, w, -np.inf)))
        passive[j] = True
        while True:
            s = np.zeros(n)
            s[passive] = np.linalg.lstsq(A[:, passive], b, rcond=None)[0]
            if not passive.any() or float(s[passive].min()) > tol:
                break
            neg = passive & (s <= tol)
            denom = x[neg] - s[neg]
            ratios = np.where(denom > 0, x[neg] / np.maximum(denom, 1e-300),
                              0.0)
            alpha = float(ratios.min()) if len(ratios) else 0.0
            x = x + alpha * (s - x)
            passive &= x > tol
            budget -= 1
            if budget <= 0:
                break
        x = s
        w = A.T @ (b - A @ x)
    return np.maximum(x, 0.0)


def _step_feature(step, topo: Topology, value_bytes: int,
                  theta: np.ndarray) -> np.ndarray:
    """Bottleneck feature row of one step under ``theta``.

    Candidates are each process's (msgs, bytes) row and each region's
    injection row — exactly the max() arms of :func:`step_time`."""
    P = topo.n_procs
    intra_b = step.intra_vals * value_bytes
    inter_b = step.inter_vals * value_bytes
    proc_rows = np.stack([
        step.intra_msgs, intra_b, step.inter_msgs, inter_b,
        np.zeros(P),
    ], axis=1).astype(float)
    R = topo.n_regions
    per_region = inter_b.reshape(R, topo.procs_per_region).sum(axis=1)
    inj_rows = np.zeros((R, 5))
    inj_rows[:, 4] = per_region
    rows = np.concatenate([proc_rows, inj_rows], axis=0)
    return rows[int(np.argmax(rows @ theta))]


def _sample_feature(sample: RateSample, theta: np.ndarray) -> np.ndarray:
    """Feature row of a whole sample: mirrors :func:`stats_time`'s
    max(l, s + g + r) composition under the current ``theta``."""
    vb = sample.stats.value_bytes
    by_name = {
        s.name: _step_feature(s, sample.topo, vb, theta)
        for s in sample.stats.steps
    }
    if set(by_name) == {"p2p"}:
        return by_name["p2p"]
    if not set(by_name) <= {"p2p", "l", "s", "g", "r"}:
        # generic round schedules (dense d0..dk): serial sum, mirroring
        # stats_time's composition so the fit sees the same arithmetic.
        return np.sum(list(by_name.values()), axis=0)
    zero = np.zeros(5)
    serial = (by_name.get("s", zero) + by_name.get("g", zero)
              + by_name.get("r", zero))
    overlap = by_name.get("l", zero)
    return overlap if overlap @ theta >= serial @ theta else serial


def fit_machine_params(
    samples: Sequence[RateSample],
    name: str = "fitted",
    ref: MachineParams = TPU_V5E,
    max_outer: int = 50,
    rel_tol: float = 1e-9,
) -> Tuple[MachineParams, Dict[str, float]]:
    """Least-squares fit of MachineParams from measured exchange samples.

    Returns ``(params, gof)`` where ``gof`` carries ``residual`` (l2 of
    seconds), ``rel_rmse`` (rms of per-sample relative error over nonzero
    samples), ``r2``, ``n_samples``, ``outer_iters`` and ``converged``
    (1.0/0.0).  ``ref`` seeds the bottleneck selection and backfills any
    rate the samples do not excite.
    """
    samples = [s for s in samples if s.seconds > 0.0]
    if not samples:
        raise ValueError("fit_machine_params needs at least one sample "
                         "with seconds > 0")
    t = np.array([s.seconds for s in samples])
    theta = _theta_of(ref)
    converged = False
    outer = 0
    best = (np.inf, theta, np.zeros((len(samples), 5)))
    stale = 0
    for outer in range(1, max_outer + 1):
        F = np.stack([_sample_feature(s, theta) for s in samples])
        col = np.linalg.norm(F, axis=0)
        excited = col > 0
        theta_new = _theta_of(ref).copy()
        if excited.any():
            scale = np.where(excited, col, 1.0)
            theta_new[excited] = (
                _nnls(F[:, excited] / scale[excited], t) / scale[excited]
            )
        denom = np.maximum(np.abs(theta), 1e-300)
        delta = float(np.max(np.abs(theta_new - theta) / denom))
        theta = theta_new
        resid_now = float(np.linalg.norm(F @ theta - t))
        if resid_now < best[0] * (1.0 - 1e-6) - 1e-300:
            best = (resid_now, theta.copy(), F.copy())
            stale = 0
        else:
            stale += 1
        if delta < rel_tol:
            converged = True
            break
        if stale >= 2:
            # objective plateaued: noisy measurements can cycle between
            # near-tied bottleneck selections — accept the best iterate
            converged = True
            break
    if np.isfinite(best[0]):
        theta, F = best[1], best[2]
    pred = F @ theta
    resid = pred - t
    nz = t > 0
    ss_tot = float(np.sum((t - t.mean()) ** 2))
    gof = {
        "residual": float(np.linalg.norm(resid)),
        "rel_rmse": float(np.sqrt(np.mean((resid[nz] / t[nz]) ** 2))),
        "r2": (1.0 - float(np.sum(resid ** 2)) / ss_tot) if ss_tot > 0
        else 1.0,
        "n_samples": float(len(samples)),
        "outer_iters": float(outer),
        "converged": 1.0 if converged else 0.0,
    }
    excited = np.linalg.norm(F, axis=0) > 0
    return _params_of(theta, name, ref, excited), gof
