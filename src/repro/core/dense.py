"""Plan-based dense collectives: allreduce / allgatherv / reduce_scatter.

The paper's locality-aware aggregation is not specific to sparse
neighborhoods: Traff et al. (1606.07676) show message-combining for
isomorphic sparse collectives, and Jocksch et al. (2006.13112) show the
same hierarchical intra-/inter-node decomposition winning for the dense
collectives distributed training runs on.  This module brings those dense
collectives onto the repo's planning stack: every collective is an
explicit, host-built **round schedule** (each round one ``lax.ppermute``),
scored by the same Section-5 cost model that picks the sparse transports,
verified by ``repro.verify`` (conflict-free rounds + contribution-exact
conservation), cached in a ``PlanCache`` namespace under a content
fingerprint, and timed through the same ``obs``/``profile`` calibration
bridge.

Data model
----------
The global vector is split into ``P`` *segments*, one per device
(``counts[p]`` values each — ragged counts are first-class, which is what
makes allgather*v* a v).  A :class:`DenseRound` moves whole segments
between devices; segment identity is preserved on the wire (segment ``s``
always lands in slot ``s``), so a schedule is fully described by
``(pairs, segments, reduce?)`` per round, which is what the verifier
executes symbolically and the device interpreter executes with one
``ppermute`` + gather/scatter per round.

Variants
--------
* ``ring`` — single-level ring: reduce_scatter / allgather pipelines over
  all ``P`` devices (``P-1`` rounds each; allreduce = RS + AG).
* ``rd``   — recursive doubling allreduce (``log2 P`` rounds, full-vector
  exchanges; power-of-two process counts only).
* ``hier`` — the locality-aware decomposition: intra-region ring
  reduce_scatter, inter-region exchange among per-chunk leaders (the
  same-local-rank groups; for allgatherv the region leaders proper plus a
  doubling intra-region broadcast), intra-region ring allgather.  Fewer,
  larger inter-region messages — exactly the paper's aggregation trade.

``select_dense`` mirrors ``core.selection.select_plan``: build the
candidate schedules, score each with ``costmodel.stats_time`` under
calibrated ``MachineParams``, pick the cheapest, and report the full table
in a :class:`DenseSelection` — the record every consumer (trainer grad
sync, AMG coarse gather, MoE expert gather) attaches the way ``DistOp``
records ``kern=``/``ov=``.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from types import SimpleNamespace
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..obs import default_obs, now as _now
from .costmodel import MachineParams, TPU_V5E, stats_time
from .plan import Message, PlanStats, StepStats, Topology

_OBS = default_obs()

DENSE_COLLECTIVES = ("allreduce", "allgatherv", "reduce_scatter")


# ---------------------------------------------------------------------------
# plan structures
# ---------------------------------------------------------------------------


@dataclass
class DenseRound:
    """One ppermute round: disjoint (src, dst) pairs moving whole segments.

    ``segs[i]`` are the segment ids pair ``i`` moves; ``reduce`` selects
    add-into vs overwrite at the destination (segment identity is
    preserved, so destination slots equal source segment ids).
    """

    pairs: List[Tuple[int, int]]
    segs: List[np.ndarray]
    reduce: bool
    phase: str = ""

    def width_segments(self) -> int:
        return max((len(s) for s in self.segs), default=0)


@dataclass
class DensePlan:
    """A fully-resolved dense collective schedule (the persistent init).

    Exposes the same duck-type surface ``profile.TraceRecorder.record_plan``
    reads off a ``CommPlan`` (``strategy`` / ``topo`` / ``stats`` /
    ``steps``), so measured dense exchanges flow into the same calibration
    fit as the sparse transports (each round is one stats step, composed
    serially by ``costmodel.stats_time``).
    """

    collective: str
    variant: str
    topo: Topology
    counts: np.ndarray            # [P] per-segment value counts
    rounds: List[DenseRound]
    value_bytes: int = 8
    fingerprint: str = ""
    _stats: Optional[PlanStats] = field(default=None, repr=False)

    def __post_init__(self):
        self.counts = np.asarray(self.counts, dtype=np.int64)
        if len(self.counts) != self.topo.n_procs:
            raise ValueError(
                f"dense plans carry one segment per device: "
                f"{len(self.counts)} counts vs {self.topo.n_procs} procs"
            )
        if not self.fingerprint:
            self.fingerprint = dense_fingerprint(
                self.collective, self.counts, self.topo, self.variant,
                self.value_bytes,
            )

    # ------------------------------------------------------------ derived
    @property
    def n(self) -> int:
        """Total logical values."""
        return int(self.counts.sum())

    @property
    def cmax(self) -> int:
        """Padded on-device segment width."""
        return int(self.counts.max()) if len(self.counts) else 0

    @property
    def n_rounds(self) -> int:
        return len(self.rounds)

    @property
    def strategy(self) -> str:
        return f"{self.collective}/{self.variant}"

    @property
    def stats(self) -> PlanStats:
        """Exact per-round traffic, one ``StepStats`` per round (round
        names are ``d0..dk``: ``costmodel.stats_time`` composes unknown
        step names serially, which is exactly a round schedule)."""
        if self._stats is None:
            steps = [
                _round_stats(r, self.counts, self.topo, f"d{i}")
                for i, r in enumerate(self.rounds)
            ]
            self._stats = PlanStats(steps, self.value_bytes)
        return self._stats

    @property
    def steps(self):
        """Trace-recorder view: one message per pair, at *segment*
        granularity (sizes for fitting come from :attr:`stats`; these
        messages only carry pairing / round structure)."""
        return [
            SimpleNamespace(
                name=f"d{i}",
                messages=[
                    Message(src, dst, segs, segs)
                    for (src, dst), segs in zip(r.pairs, r.segs)
                ],
            )
            for i, r in enumerate(self.rounds)
        ]

    def modeled_time(self, params: MachineParams) -> float:
        return dense_time(self, params)

    def describe(self) -> str:
        t = self.stats.totals()
        return (
            f"DensePlan({self.strategy}, procs={self.topo.n_procs}, "
            f"regions={self.topo.n_regions}, n={self.n}, "
            f"rounds={self.n_rounds}, totals={t})"
        )

    # ------------------------------------------------------------- oracle
    def execute_numpy(
        self, local_vals: Sequence[np.ndarray]
    ) -> List[np.ndarray]:
        """Host-side reference execution of the *schedule* (not the
        mathematical collective): interprets the rounds exactly as the
        device executor does, so device == oracle == schedule.

        Inputs per collective: ``allreduce`` / ``reduce_scatter`` take the
        per-device full contribution vector ``[n]``; ``allgatherv`` takes
        the per-device owned segment ``[counts[p]]``.  Outputs: allreduce
        -> per-device ``[n]`` (all equal), reduce_scatter -> per-device
        ``[counts[p]]``, allgatherv -> per-device ``[n]``.
        """
        P = self.topo.n_procs
        bounds = np.cumsum(self.counts)[:-1]
        if self.collective == "allgatherv":
            state = [
                [
                    np.array(local_vals[p], copy=True)
                    if s == p
                    else np.zeros(int(self.counts[s]),
                                  dtype=local_vals[p].dtype)
                    for s in range(P)
                ]
                for p in range(P)
            ]
        else:
            state = [
                [seg.copy() for seg in np.split(
                    np.asarray(local_vals[p]), bounds)]
                for p in range(P)
            ]
        for rnd in self.rounds:
            payloads = [
                (dst, segs, [state[src][int(s)].copy() for s in segs])
                for (src, dst), segs in zip(rnd.pairs, rnd.segs)
            ]
            for dst, segs, pay in payloads:
                for s, v in zip(segs, pay):
                    if rnd.reduce:
                        state[dst][int(s)] = state[dst][int(s)] + v
                    else:
                        state[dst][int(s)] = v
        if self.collective == "reduce_scatter":
            return [state[p][p] for p in range(P)]
        return [np.concatenate(state[p]) for p in range(P)]


def _round_stats(
    rnd: DenseRound, counts: np.ndarray, topo: Topology, name: str
) -> StepStats:
    P = topo.n_procs
    im = np.zeros(P, dtype=np.int64)
    xm = np.zeros(P, dtype=np.int64)
    iv = np.zeros(P, dtype=np.int64)
    xv = np.zeros(P, dtype=np.int64)
    for (src, dst), segs in zip(rnd.pairs, rnd.segs):
        size = int(counts[segs].sum())
        if src == dst or size == 0:
            continue
        if topo.same_region(src, dst):
            im[src] += 1
            iv[src] += size
        else:
            xm[src] += 1
            xv[src] += size
    return StepStats(name, im, xm, iv, xv)


def dense_time(plan: DensePlan, params: MachineParams) -> float:
    """Modeled time: rounds are bulk-synchronous and serial, so the round
    schedule composes as a plain sum of :func:`costmodel.step_time` —
    which is what ``stats_time`` does for non-sparse step names."""
    return stats_time(plan.stats, plan.topo, params)


# ---------------------------------------------------------------------------
# fingerprints / cache keys
# ---------------------------------------------------------------------------


def dense_fingerprint(
    collective: str,
    counts: np.ndarray,
    topo: Topology,
    variant: str,
    value_bytes: int,
) -> str:
    """Content hash of a dense plan's identity — same framing discipline
    as ``cache.pattern_fingerprint`` (name/dtype/shape-framed arrays, no
    ``PYTHONHASHSEED`` dependence anywhere)."""
    from .cache import _hash_array

    h = hashlib.blake2b(digest_size=16)
    h.update(f"dense:{collective}:{variant}".encode())
    h.update(b"\x00")
    _hash_array(h, "counts", np.asarray(counts, dtype=np.int64))
    h.update(
        np.asarray(
            [topo.n_procs, topo.procs_per_region, value_bytes],
            dtype=np.int64,
        ).tobytes()
    )
    return h.hexdigest()


def dense_cache_key(
    collective: str,
    counts: np.ndarray,
    topo: Topology,
    variant: str,
    value_bytes: int,
    params: MachineParams,
) -> Tuple:
    """Everything ``select_dense`` depends on (params included: ``auto``
    selects per machine model, exactly like the sparse plan key)."""
    return (
        dense_fingerprint(collective, counts, topo, variant, value_bytes),
        variant,
        params,
    )


def even_counts(n: int, n_procs: int) -> np.ndarray:
    """Uniform segment counts covering >= n values (the padded chunking
    the inline executors use: ``P * ceil(n / P)`` total)."""
    c = -(-int(n) // int(n_procs)) if n > 0 else 0
    return np.full(n_procs, max(c, 1), dtype=np.int64)


# ---------------------------------------------------------------------------
# schedule builders
# ---------------------------------------------------------------------------

Group = Tuple[List[int], List[np.ndarray]]   # (ring members, target segments)


def _ring_rs_rounds(groups: Sequence[Group], phase: str) -> List[DenseRound]:
    """Pipelined ring reduce-scatter over each group: after ``m-1`` rounds
    member ``i`` holds the group-wide sum of its target segments.  At step
    ``t`` member ``i`` forwards the accumulated partial of member
    ``(i-t-1) mod m``'s segments to ``i+1``, which adds it in."""
    if not groups:
        return []
    m = len(groups[0][0])
    out = []
    for t in range(m - 1):
        pairs: List[Tuple[int, int]] = []
        segs: List[np.ndarray] = []
        for members, seglists in groups:
            for i, src in enumerate(members):
                pairs.append((src, members[(i + 1) % m]))
                segs.append(seglists[(i - t - 1) % m])
        out.append(DenseRound(pairs, segs, True, phase))
    return out


def _ring_ag_rounds(groups: Sequence[Group], phase: str) -> List[DenseRound]:
    """Pipelined ring allgather: member ``i`` starts holding its target
    segments; after ``m-1`` rounds every member holds every group
    segment.  At step ``t`` member ``i`` forwards member ``(i-t) mod m``'s
    segments to ``i+1``, which overwrites its (empty) slots."""
    if not groups:
        return []
    m = len(groups[0][0])
    out = []
    for t in range(m - 1):
        pairs: List[Tuple[int, int]] = []
        segs: List[np.ndarray] = []
        for members, seglists in groups:
            for i, src in enumerate(members):
                pairs.append((src, members[(i + 1) % m]))
                segs.append(seglists[(i - t) % m])
        out.append(DenseRound(pairs, segs, False, phase))
    return out


def _seg(p: int) -> np.ndarray:
    return np.asarray([p], dtype=np.int64)


def _hier_groups(topo: Topology) -> Tuple[List[Group], List[Group]]:
    """(intra-region groups at chunk-group granularity, inter-region
    same-local-rank groups at single-segment granularity)."""
    ppr, R = topo.procs_per_region, topo.n_regions
    intra: List[Group] = []
    for reg in range(R):
        members = list(topo.procs_in_region(reg))
        seglists = [
            np.asarray([rp * ppr + r for rp in range(R)], dtype=np.int64)
            for r in range(ppr)
        ]
        intra.append((members, seglists))
    inter: List[Group] = []
    for r in range(ppr):
        members = [reg * ppr + r for reg in range(R)]
        inter.append((members, [_seg(m) for m in members]))
    return intra, inter


def build_dense_rounds(
    collective: str, topo: Topology, variant: str
) -> List[DenseRound]:
    """Emit the round schedule for one (collective, variant)."""
    P = topo.n_procs
    ppr, R = topo.procs_per_region, topo.n_regions
    if collective not in DENSE_COLLECTIVES:
        raise ValueError(f"unknown dense collective {collective!r}")

    if variant == "ring":
        flat: List[Group] = [(list(range(P)), [_seg(p) for p in range(P)])]
        if collective == "allgatherv":
            return _ring_ag_rounds(flat, "ring_ag")
        rounds = _ring_rs_rounds(flat, "ring_rs")
        if collective == "allreduce":
            rounds += _ring_ag_rounds(flat, "ring_ag")
        return rounds

    if variant == "rd":
        if collective != "allreduce":
            raise ValueError("recursive doubling is an allreduce variant")
        if P & (P - 1):
            raise ValueError(f"recursive doubling needs 2^k procs, got {P}")
        allsegs = np.arange(P, dtype=np.int64)
        rounds = []
        j = 1
        while j < P:
            pairs = [(p, p ^ j) for p in range(P)]
            rounds.append(DenseRound(pairs, [allsegs] * P, True, "rd"))
            j <<= 1
        return rounds

    if variant != "hier":
        raise ValueError(f"unknown dense variant {variant!r}")

    if collective in ("allreduce", "reduce_scatter"):
        # intra-region ring RS over chunk groups -> inter-region ring RS
        # among same-local-rank devices (the per-chunk leaders); allreduce
        # runs the mirror-image allgather back out.
        intra, inter = _hier_groups(topo)
        rounds = _ring_rs_rounds(intra, "intra_rs")
        rounds += _ring_rs_rounds(inter, "inter_rs")
        if collective == "allreduce":
            rounds += _ring_ag_rounds(inter, "inter_ag")
            rounds += _ring_ag_rounds(intra, "intra_ag")
        return rounds

    # hier allgatherv: intra-region ring allgather, one inter-region ring
    # over the region *leaders* (whole region blocks per message), then a
    # doubling broadcast down each region.
    intra_ag: List[Group] = []
    for reg in range(R):
        members = list(topo.procs_in_region(reg))
        intra_ag.append((members, [_seg(m) for m in members]))
    leaders = [reg * ppr for reg in range(R)]
    leader_group: List[Group] = [(
        leaders,
        [np.arange(reg * ppr, (reg + 1) * ppr, dtype=np.int64)
         for reg in range(R)],
    )]
    rounds = _ring_ag_rounds(intra_ag, "intra_ag")
    rounds += _ring_ag_rounds(leader_group, "leader_ag")
    j = 1
    while j < ppr:
        pairs: List[Tuple[int, int]] = []
        segs: List[np.ndarray] = []
        for reg in range(R):
            others = np.concatenate([
                np.arange(0, reg * ppr, dtype=np.int64),
                np.arange((reg + 1) * ppr, P, dtype=np.int64),
            ])
            if not len(others):
                continue
            for s in range(j):
                if s + j < ppr:
                    base = reg * ppr
                    pairs.append((base + s, base + s + j))
                    segs.append(others)
        if pairs:
            rounds.append(DenseRound(pairs, segs, False, "bcast"))
        j <<= 1
    return rounds


def build_dense_plan(
    collective: str,
    counts: np.ndarray,
    topo: Topology,
    variant: str,
    value_bytes: int = 8,
) -> DensePlan:
    counts = np.asarray(counts, dtype=np.int64)
    return DensePlan(
        collective=collective,
        variant=variant,
        topo=topo,
        counts=counts,
        rounds=build_dense_rounds(collective, topo, variant),
        value_bytes=value_bytes,
    )


# ---------------------------------------------------------------------------
# Section-5 selection
# ---------------------------------------------------------------------------


@dataclass
class DenseSelection:
    """The dense analogue of ``SelectionReport`` — attached by every
    consumer next to its other choices (``DistOp``-style)."""

    collective: str
    chosen: str
    modeled_times: Dict[str, float]
    planning_seconds: Dict[str, float]

    def __str__(self) -> str:
        rows = ", ".join(
            f"{k}={v * 1e6:.1f}us"
            for k, v in sorted(self.modeled_times.items())
        )
        return f"dense/{self.collective}: selected={self.chosen} ({rows})"


def dense_variants(collective: str, topo: Topology) -> List[str]:
    """The variants worth scoring for this geometry."""
    out = ["ring"]
    if collective == "allreduce" and topo.n_procs & (topo.n_procs - 1) == 0:
        out.append("rd")
    if topo.procs_per_region > 1 and topo.n_regions > 1:
        out.append("hier")
    return out


def select_dense(
    collective: str,
    counts: np.ndarray,
    topo: Topology,
    variant: str = "auto",
    value_bytes: int = 8,
    params: MachineParams = TPU_V5E,
) -> Tuple[DensePlan, DenseSelection]:
    """Build candidate schedules, score with the calibrated cost model,
    pick the cheapest (``variant="auto"``) or pin one."""
    candidates = (
        dense_variants(collective, topo) if variant == "auto" else [variant]
    )
    plans: Dict[str, DensePlan] = {}
    times: Dict[str, float] = {}
    walls: Dict[str, float] = {}
    with _OBS.span("dense/select", collective=collective,
                   n_procs=topo.n_procs, variant=variant) as sp:
        for cand in candidates:
            t0 = _now()
            plan = build_dense_plan(collective, counts, topo, cand,
                                    value_bytes)
            walls[cand] = _now() - t0
            plans[cand] = plan
            times[cand] = dense_time(plan, params)
        chosen = min(times, key=lambda k: times[k])
        sp.set(chosen=chosen)
    return plans[chosen], DenseSelection(collective, chosen, times, walls)


# ---------------------------------------------------------------------------
# device execution: a round interpreter under shard_map
# ---------------------------------------------------------------------------


def _pack_device_rounds(plan: DensePlan):
    """Freeze rounds into [P, w] gather/scatter segment-id arrays (pad =
    the sentinel row ``n_seg``) + the ppermute perm, in round order."""
    P = plan.topo.n_procs
    sentinel = len(plan.counts)
    packed = []
    for rnd in plan.rounds:
        w = rnd.width_segments()
        g = np.full((P, w), sentinel, dtype=np.int32)
        s = np.full((P, w), sentinel, dtype=np.int32)
        for (src, dst), segs in zip(rnd.pairs, rnd.segs):
            g[src, : len(segs)] = segs
            s[dst, : len(segs)] = segs
        packed.append((tuple(rnd.pairs), g, s, rnd.reduce))
    return packed


def dense_round_runner(plan: DensePlan, axis_name: str) -> Callable:
    """The inline form: ``run(buf) -> buf`` for use *inside* a caller's
    ``shard_map`` over ``axis_name`` (how the trainer fuses grad sync into
    its own mapped step).

    ``buf``: per-device ``[n_seg, cmax]`` segment buffer (zero padding
    beyond ``counts[s]``).  Each plan round executes as gather -> one
    ``ppermute`` -> scatter-add/set; per-device index rows are selected
    from closed-over ``[P, w]`` constants by ``lax.axis_index``.
    """
    import jax
    import jax.numpy as jnp

    packed = _pack_device_rounds(plan)

    def run(buf):
        rank = jax.lax.axis_index(axis_name)
        pad = jnp.zeros((1,) + buf.shape[1:], buf.dtype)
        buf = jnp.concatenate([buf, pad], axis=0)   # sentinel row
        for perm, g, s, red in packed:
            grow = jnp.asarray(g)[rank]
            srow = jnp.asarray(s)[rank]
            recv = jax.lax.ppermute(buf[grow], axis_name, perm)
            if red:
                buf = buf.at[srow].add(recv)
            else:
                buf = buf.at[srow].set(recv)
        return buf[:-1]

    return run


def bind_dense(plan: DensePlan, mesh, axis_name: str) -> Callable:
    """Bind a plan to a mesh axis: the standalone executor.

    Global shapes (leading dim sharded over ``axis_name``):

    * allreduce:       ``[P, n_seg, cmax] -> [P, n_seg, cmax]`` (all rows
      hold the full sums)
    * reduce_scatter:  ``[P, n_seg, cmax] -> [P, cmax]`` (device p's row is
      its summed segment, zero-padded past ``counts[p]``)
    * allgatherv:      ``[P, cmax] -> [P, n_seg, cmax]`` (own segment in,
      every segment out)

    Use :func:`pack_dense_input` / :func:`unpack_dense_output` to move
    between global vectors and the padded segment layout.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from ..compat import shard_map

    run = dense_round_runner(plan, axis_name)
    n_seg, cmax = len(plan.counts), plan.cmax

    if plan.collective == "allgatherv":

        def per_device(x_blk):          # [1, cmax] own segment
            rank = jax.lax.axis_index(axis_name)
            buf = jnp.zeros((n_seg, cmax), x_blk.dtype)
            zero = jnp.zeros((), rank.dtype)
            buf = jax.lax.dynamic_update_slice(buf, x_blk, (rank, zero))
            return run(buf)[None]

    elif plan.collective == "reduce_scatter":

        def per_device(x_blk):          # [1, n_seg, cmax] contributions
            rank = jax.lax.axis_index(axis_name)
            buf = run(x_blk[0])
            zero = jnp.zeros((), rank.dtype)
            return jax.lax.dynamic_slice(buf, (rank, zero), (1, cmax))

    else:                               # allreduce

        def per_device(x_blk):
            return run(x_blk[0])[None]

    spec = P(axis_name)
    return shard_map(
        per_device, mesh=mesh, in_specs=(spec,), out_specs=spec,
        check_rep=False,
    )


def pack_dense_input(plan: DensePlan, vals: Sequence[np.ndarray]) -> np.ndarray:
    """Per-device inputs -> the executor's padded global array.

    allreduce / reduce_scatter: ``vals[p]`` is the device's full ``[n]``
    contribution -> ``[P, n_seg, cmax]``; allgatherv: ``vals[p]`` is the
    owned segment ``[counts[p]]`` -> ``[P, cmax]``.
    """
    P = plan.topo.n_procs
    cmax = plan.cmax
    if plan.collective == "allgatherv":
        out = np.zeros((P, cmax), dtype=vals[0].dtype)
        for p in range(P):
            out[p, : int(plan.counts[p])] = vals[p]
        return out
    bounds = np.cumsum(plan.counts)[:-1]
    out = np.zeros((P, len(plan.counts), cmax), dtype=vals[0].dtype)
    for p in range(P):
        for s, seg in enumerate(np.split(np.asarray(vals[p]), bounds)):
            out[p, s, : len(seg)] = seg
    return out


def unpack_dense_output(plan: DensePlan, out: np.ndarray) -> List[np.ndarray]:
    """Executor output -> per-device logical results (unpadded)."""
    P = plan.topo.n_procs
    out = np.asarray(out)
    if plan.collective == "reduce_scatter":
        return [out[p, : int(plan.counts[p])] for p in range(P)]
    return [
        np.concatenate(
            [out[p, s, : int(plan.counts[s])] for s in range(len(plan.counts))]
        )
        for p in range(P)
    ]


# ---------------------------------------------------------------------------
# measurement (the calibration feed)
# ---------------------------------------------------------------------------


def measure_dense_seconds(
    plan: DensePlan,
    mesh,
    axis_name: str,
    dtype=np.float64,
    iters: int = 20,
    warmup: int = 3,
    seed: int = 0,
    tracer=None,
    executor: Optional[Callable] = None,
) -> float:
    """Measured wall seconds per collective execution (the shared
    jit + compile + warmup + timed-loop protocol of
    ``core.collectives.time_executor``).

    With ``tracer`` (a ``profile.TraceRecorder``) the timing is recorded
    against the plan as a ``pure_exchange`` sample under the plan's dense
    fingerprint; without one, the obs span bridge forwards the same sample
    to any tracer attached to the enabled obs layer — dense exchanges feed
    the NNLS rate fit exactly like the sparse transports.
    """
    import jax
    import jax.numpy as jnp

    P = plan.topo.n_procs
    n_seg, cmax = len(plan.counts), plan.cmax
    if plan.collective == "allgatherv":
        shape = (P, cmax)
    else:
        shape = (P, n_seg, cmax)
    fn = jax.jit(executor if executor is not None
                 else bind_dense(plan, mesh, axis_name))
    x = jnp.asarray(
        np.random.default_rng(seed).normal(size=shape).astype(dtype)
    )
    if x.dtype != np.dtype(dtype):
        raise RuntimeError(
            f"requested {np.dtype(dtype)} but device materialized {x.dtype};"
            " enable jax_enable_x64 (or pass the narrower dtype explicitly)"
        )
    with _OBS.span("dense/measure", collective=plan.collective,
                   variant=plan.variant, n_procs=P) as sp:
        fn(x).block_until_ready()   # compile
        for _ in range(warmup):
            fn(x).block_until_ready()
        t0 = _now()
        for _ in range(iters):
            fn(x).block_until_ready()
        secs = (_now() - t0) / iters
        if tracer is not None:
            tracer.record_plan(plan, secs, label=f"dense/{plan.strategy}",
                               pure_exchange=True,
                               fingerprint=plan.fingerprint)
        else:
            sp.set(plan=plan, pure_exchange=True, seconds=secs,
                   fingerprint=plan.fingerprint)
    return secs
