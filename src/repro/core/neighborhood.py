"""Persistent neighborhood collective facade (the MPI_Neighbor_alltoallv_init
analogue).

    coll = NeighborAlltoallV.init(pattern, topo, strategy="auto")
    ghosts = coll(x)            # start+wait, host (numpy) path
    exec_fn = coll.bind(mesh, axis_name="proc")
    ghosts = jax.jit(exec_fn)(x_global)   # device path

``init`` is the expensive once-per-pattern step (plan construction, load
balancing, dedup); calls are the cheap per-iteration start/wait.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence

import numpy as np

from ..obs import now as _now
from .collectives import DevicePlan, build_device_plan, make_executor
from .costmodel import MachineParams, TPU_V5E, plan_time
from .locality import build_plan
from .plan import CommPattern, CommPlan, Topology
from .selection import SelectionReport, select_plan


@dataclass
class NeighborAlltoallV:
    plan: CommPlan
    device_plan: DevicePlan
    init_seconds: float
    selection: Optional[SelectionReport] = None

    @classmethod
    def init(
        cls,
        pattern: CommPattern,
        topo: Topology,
        strategy: str = "auto",
        value_bytes: int = 8,
        params: MachineParams = TPU_V5E,
    ) -> "NeighborAlltoallV":
        t0 = _now()
        report = None
        if strategy == "auto":
            plan, report = select_plan(
                pattern, topo, params=params, value_bytes=value_bytes
            )
        else:
            plan = build_plan(pattern, topo, strategy, value_bytes=value_bytes)
        dplan = build_device_plan(plan)
        return cls(plan, dplan, _now() - t0, report)

    # host-side start/wait (oracle + small-scale use)
    def __call__(self, local_vals: Sequence[np.ndarray]) -> List[np.ndarray]:
        return self.plan.execute_numpy(local_vals)

    # device-side start/wait
    def bind(self, mesh, axis_name: str) -> Callable:
        return make_executor(self.device_plan, mesh, axis_name)

    def modeled_time(self, params: MachineParams = TPU_V5E) -> float:
        return plan_time(self.plan, params)

    @property
    def strategy(self) -> str:
        return self.plan.strategy
