from .controller import (
    ElasticController,
    RebalanceEvent,
    ResizeEvent,
    cache_delta_event,
)
from .checkpoint import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from .elastic import (
    HeartbeatMonitor,
    MeshRequirements,
    choose_mesh_shape,
    make_mesh_from_devices,
    reshard_state,
)
from .straggler import StragglerConfig, StragglerDetector, rebalance_shards

__all__ = [
    "CheckpointManager", "latest_step", "restore_checkpoint",
    "save_checkpoint",
    "HeartbeatMonitor", "MeshRequirements", "choose_mesh_shape",
    "make_mesh_from_devices", "reshard_state",
    "StragglerConfig", "StragglerDetector", "rebalance_shards",
    "ElasticController", "RebalanceEvent", "ResizeEvent",
    "cache_delta_event",
]
