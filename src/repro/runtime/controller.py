"""Elastic/straggler control loop — wires ``runtime`` into the planners.

This is the coordinator the seed's dormant pieces were waiting for: one
object owning the :class:`~repro.runtime.elastic.HeartbeatMonitor`, the
:class:`~repro.runtime.straggler.StragglerDetector`, the (shared)
``core.cache.PlanCache`` and an optional ``repro.profile.TraceRecorder``,
so device-set changes and persistent stragglers turn into *re-planning*
instead of cold restarts:

* **Device-set change** (heartbeat timeout, or an explicit resize
  request): the surviving count goes through
  ``elastic.choose_mesh_shape`` / ``make_mesh_from_devices``; the caller
  rebuilds via ``DistributedHierarchy.repartition`` or
  ``ServeEngine.resize``, both of which re-plan every pattern through the
  shared plan cache — warm-starting from surviving entries, so growing
  back to a previously seen geometry re-plans nothing.  Each rebuild is
  recorded as a :class:`ResizeEvent` carrying the re-plan wall time and
  the plan-cache miss/hit delta (cold vs warm is *observable*).
* **Straggler**: per-host step seconds (launcher wall clocks, or
  ``TraceRecorder.per_proc_step_seconds`` — the per-partner exchange
  samples the profiler already records, attributed to hosts by traffic
  share) feed :meth:`observe_step_times`.  When the detector flags a host
  for ``patience`` consecutive steps, :meth:`mitigate_hierarchy` applies
  ``straggler.rebalance_shards`` to the row-block partition and re-fits
  ``MachineParams`` from the trace (``profile.calibrate.fit_trace``) so
  Section-5 transport selection reflects the degraded rates — one
  :class:`RebalanceEvent`, then detector reset + cooldown so a handled
  episode cannot storm.

Units: step times are **seconds per host per step**; heartbeat steps and
cooldown are dimensionless observation counts.  See docs/OPERATIONS.md
for what the events look like in logs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..obs import default_obs
from .elastic import HeartbeatMonitor, MeshRequirements, choose_mesh_shape
from .straggler import StragglerConfig, StragglerDetector

_OBS = default_obs()


@dataclasses.dataclass
class ResizeEvent:
    """One device-set change, with its re-planning cost made observable."""

    reason: str                # "heartbeat" | "requested" | "rebalance"
    old_n: int                 # procs/devices before
    new_n: int                 # procs/devices after
    replan_seconds: float      # wall time of the rebuild (plans + binds)
    plan_misses: int           # plans built fresh during the rebuild
    plan_hits: int             # plans warm-started from the cache
    exec_misses: int = 0       # executors bound fresh
    exec_hits: int = 0         # executors reused

    @property
    def warm(self) -> bool:
        """True when the rebuild re-planned nothing (pure cache warm
        start — the grow-back-to-seen-geometry contract)."""
        return self.plan_misses == 0

    def __str__(self) -> str:
        w = "warm" if self.warm else "cold"
        return (f"resize[{self.reason}] {self.old_n}->{self.new_n} procs: "
                f"{w}, {self.replan_seconds * 1e3:.1f}ms, "
                f"plan misses={self.plan_misses} hits={self.plan_hits}, "
                f"exec misses={self.exec_misses} hits={self.exec_hits}")


@dataclasses.dataclass
class RebalanceEvent:
    """One straggler mitigation: row-block rebalance (+ optional refit)."""

    hosts: List[int]           # flagged hosts
    step: int                  # observation index that triggered it
    weights: np.ndarray        # EWMA step seconds fed to rebalance_shards
    refit: bool                # MachineParams were re-fitted from the trace
    params_name: str = ""      # fitted params name ("" when refit=False)
    rel_rmse: float = float("nan")   # fit goodness (nan when refit=False)
    resize: Optional[ResizeEvent] = None  # the rebuild this triggered

    def __str__(self) -> str:
        fit = (f", refit params='{self.params_name}' "
               f"rel_rmse={self.rel_rmse:.3f}" if self.refit else "")
        return (f"rebalance@obs{self.step}: hosts={self.hosts} "
                f"weights={np.round(self.weights, 4).tolist()}{fit}")


@dataclasses.dataclass
class RefitEvent:
    """One online re-calibration: ``MachineParams`` re-fitted from
    production-step pure-exchange samples (``ServeEngine(observe=True)``
    periodic refits, next to the rebalance-triggered refits above)."""

    step: int                  # decode step / observation that triggered it
    params_name: str           # name of the fitted MachineParams
    rel_rmse: float            # fit goodness
    n_samples: int             # merged rate samples that entered the fit

    def __str__(self) -> str:
        return (f"refit@step{self.step}: params='{self.params_name}' "
                f"rel_rmse={self.rel_rmse:.3f} n={self.n_samples}")


class ElasticController:
    """Liveness + straggler bookkeeping, feeding the re-planning stack.

    The controller never touches devices itself: it decides *when* to act
    and *what geometry/weights* to act with; the rebuilds are carried out
    by ``DistributedHierarchy.repartition`` / ``ServeEngine.resize``,
    which share its plan cache and report back their :class:`ResizeEvent`.
    """

    def __init__(
        self,
        n_hosts: int,
        cache=None,
        tracer=None,
        timeout_steps: int = 3,
        straggler_cfg: Optional[StragglerConfig] = None,
        cooldown: int = 8,
    ):
        self.cache = cache
        self.tracer = tracer
        self.monitor = HeartbeatMonitor(n_hosts, timeout_steps)
        self.detector = StragglerDetector(n_hosts, straggler_cfg)
        self.cooldown = int(cooldown)
        self._cooldown_left = 0
        self._obs = 0
        self.resize_events: List[ResizeEvent] = []
        self.rebalance_events: List[RebalanceEvent] = []

    # ------------------------------------------------------------ liveness
    def beat(self, host: int) -> None:
        """Record a heartbeat from ``host`` at the current step."""
        self.monitor.beat(host)

    def advance(self) -> List[int]:
        """Advance one heartbeat step; returns hosts presumed dead (silent
        for more than ``timeout_steps`` consecutive advances)."""
        return self.monitor.advance()

    # ----------------------------------------------------------- straggler
    def observe_step_times(self, step_times) -> List[int]:
        """Feed per-host step *seconds*; returns hosts due for mitigation.

        Empty during the post-mitigation cooldown window (hysteresis: a
        freshly rebalanced fleet gets ``cooldown`` observations to settle
        before the detector may trigger again)."""
        self._obs += 1
        flagged = self.detector.update(np.asarray(step_times, dtype=float))
        if self._cooldown_left > 0:
            self._cooldown_left -= 1
            return []
        return flagged

    def mitigate_hierarchy(
        self,
        dh,
        hosts: List[int],
        refit: bool = True,
        refit_ref=None,
    ) -> Tuple[object, RebalanceEvent]:
        """Apply the straggler mitigation to a ``DistributedHierarchy``.

        Rebalances every level's row blocks inversely to the detector's
        EWMA step seconds (``straggler.rebalance_shards``) and — when a
        tracer with pure exchange samples is attached — re-fits
        ``MachineParams`` from the recorded per-partner rates so the
        rebuilt hierarchy's Section-5 selection runs under the *measured*
        (degraded) rates.  Returns ``(new_hierarchy, event)``; the
        detector is reset and a cooldown started, so one slow episode
        yields exactly one event."""
        weights = self.detector.times.copy()
        fitted = None
        name = ""
        rel_rmse = float("nan")
        if refit and self.tracer is not None:
            try:
                from ..profile.calibrate import fit_trace

                result = fit_trace(self.tracer, name="straggler-refit",
                                   ref=refit_ref if refit_ref is not None
                                   else dh.params)
                fitted = result.params
                name = fitted.name
                rel_rmse = result.gof.get("rel_rmse", float("nan"))
            except ValueError:
                fitted = None   # no pure samples recorded yet: skip refit
        new_dh = dh.repartition(
            dh.mesh, row_weights=weights, params=fitted,
            reason="rebalance",
        )
        event = RebalanceEvent(
            hosts=[int(h) for h in hosts],
            step=self._obs,
            weights=weights,
            refit=fitted is not None,
            params_name=name,
            rel_rmse=rel_rmse,
            resize=new_dh.last_resize,
        )
        self.rebalance_events.append(event)
        _OBS.event("runtime/rebalance", step=event.step,
                   hosts=[int(h) for h in hosts], refit=event.refit,
                   params_name=name)
        if new_dh.last_resize is not None:
            self.resize_events.append(new_dh.last_resize)
        # hysteresis: the rebalance changed the work distribution, so the
        # old EWMA is stale — reseed it and make the episode re-accumulate
        self.detector.reset(reseed_times=True)
        self._cooldown_left = self.cooldown
        return new_dh, event

    # -------------------------------------------------------------- resize
    def plan_mesh(
        self,
        n_devices: int,
        req: MeshRequirements,
        multi_pod_size: int = 256,
    ) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
        """Mesh factorization for a surviving device count (thin wrapper
        over ``elastic.choose_mesh_shape`` so callers go through one
        controller surface)."""
        return choose_mesh_shape(n_devices, req, multi_pod_size)

    def note_resize(self, event: ResizeEvent) -> None:
        """Record a rebuild performed by a planner on our behalf."""
        self.resize_events.append(event)

    # ------------------------------------------------------------- summary
    def summary(self) -> Dict[str, int]:
        return {
            "observations": self._obs,
            "resize_events": len(self.resize_events),
            "rebalance_events": len(self.rebalance_events),
            "cooldown_left": self._cooldown_left,
        }


def cache_delta_event(
    cache, before: Dict[str, int], reason: str,
    old_n: int, new_n: int, seconds: float,
) -> ResizeEvent:
    """Build a :class:`ResizeEvent` from a plan-cache counter snapshot
    (the flat ``PlanCache.counters()`` view of ``PlanCache.snapshot()``)
    taken before the rebuild.  The one choke point every resize flows
    through, so it also emits the ``runtime/resize`` obs instant event."""
    after = cache.counters()
    event = ResizeEvent(
        reason=reason,
        old_n=int(old_n),
        new_n=int(new_n),
        replan_seconds=float(seconds),
        plan_misses=after["misses"] - before["misses"],
        plan_hits=after["hits"] - before["hits"],
        exec_misses=after["exec_misses"] - before["exec_misses"],
        exec_hits=after["exec_hits"] - before["exec_hits"],
    )
    _OBS.event("runtime/resize", reason=event.reason, old_n=event.old_n,
               new_n=event.new_n, warm=event.warm,
               plan_misses=event.plan_misses, plan_hits=event.plan_hits)
    return event
