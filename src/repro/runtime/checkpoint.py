"""Checkpoint/restart: atomic, checksummed, double-buffered, async.

Units and contracts (the operator-facing surface, see docs/OPERATIONS.md):

* :func:`save_checkpoint` serializes a pytree under ``step_<N>`` (steps
  are dimensionless training/solver iterations) and only then atomically
  repoints ``LATEST`` — a crashed writer leaves at most a ``*.tmp-*``
  directory, never a corrupt ``LATEST`` target.
* :func:`restore_checkpoint` restores into the *structure* of a template
  pytree: leaf count, per-leaf shape, and recorded dtype must match, and
  every leaf's sha256 is verified (``IOError`` on mismatch) unless
  ``validate=False``.
* :meth:`CheckpointManager.save` snapshots device arrays to host BEFORE
  returning, so with ``async_save=True`` training may mutate buffers
  immediately; a failed background save surfaces as an exception on the
  next :meth:`CheckpointManager.wait` / ``save`` / ``restore_latest``.
* :meth:`CheckpointManager.restore_latest` waits for any in-flight save
  first, then restores the newest *complete* checkpoint: partial
  ``*.tmp-*`` directories from an interrupted async save are invisible to
  ``LATEST`` and to garbage collection, so a crash mid-save falls back to
  the previous step.

Layout (one directory per step)::

    <dir>/step_000042/
        manifest.json      # tree structure, shapes, dtypes, sha256 per leaf
        leaf_00000.bin     # raw bytes per leaf (bfloat16-safe)
        ...
    <dir>/LATEST           # atomic pointer file

Design for 1000+ nodes (documented here, exercised single-host): each
process writes only the leaves it owns (addressable shards) under
``leaf_XXXXX.shard_YYY.bin``; the manifest is written by process 0 after a
barrier; restore re-shards onto whatever mesh the elastic layer chose —
enabled by storing *global* arrays per leaf here (single-host container).

Write protocol: serialize to ``step_N.tmp-<nonce>`` then ``os.rename`` —
a crashed writer never corrupts LATEST.  ``CheckpointManager`` keeps the
last ``keep`` checkpoints and can run saves on a background thread
(double-buffered: the step's arrays are snapshotted to host first, so
training continues while bytes hit disk).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _leaf_bytes(x) -> Tuple[bytes, str, Tuple[int, ...]]:
    arr = np.asarray(jax.device_get(x))
    return arr.tobytes(), str(arr.dtype), tuple(arr.shape)


def _restore_leaf(raw: bytes, dtype: str, shape) -> np.ndarray:
    if dtype == "bfloat16":
        import ml_dtypes
        dt = ml_dtypes.bfloat16
    else:
        dt = np.dtype(dtype)
    return np.frombuffer(raw, dtype=dt).reshape(shape).copy()


def save_checkpoint(ckpt_dir: str, step: int, tree: Any) -> str:
    """Atomic checksummed save; returns the final directory path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:09d}")
    tmp = final + f".tmp-{os.getpid()}-{int(time.time() * 1e6) % 100000}"
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = jax.tree.flatten(tree)
    manifest: Dict[str, Any] = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "leaves": [],
    }
    for i, leaf in enumerate(leaves):
        raw, dtype, shape = _leaf_bytes(leaf)
        fn = f"leaf_{i:05d}.bin"
        with open(os.path.join(tmp, fn), "wb") as f:
            f.write(raw)
        manifest["leaves"].append({
            "file": fn,
            "dtype": dtype,
            "shape": list(shape),
            "sha256": hashlib.sha256(raw).hexdigest(),
            "bytes": len(raw),
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    # atomic LATEST pointer
    ptr_tmp = os.path.join(ckpt_dir, f".LATEST.tmp-{os.getpid()}")
    with open(ptr_tmp, "w") as f:
        f.write(os.path.basename(final))
    os.replace(ptr_tmp, os.path.join(ckpt_dir, "LATEST"))
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    ptr = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(ckpt_dir, name)):
        return None
    return int(name.split("_")[1])


def restore_checkpoint(
    ckpt_dir: str,
    template: Any,
    step: Optional[int] = None,
    validate: bool = True,
) -> Tuple[int, Any]:
    """Restore into the structure of ``template`` (shapes must match).
    Integrity: every leaf's sha256 is verified unless validate=False."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_t, treedef = jax.tree.flatten(template)
    if manifest["n_leaves"] != len(leaves_t):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, template "
            f"{len(leaves_t)} — incompatible structure"
        )
    out: List[np.ndarray] = []
    for i, (meta, tleaf) in enumerate(zip(manifest["leaves"], leaves_t)):
        with open(os.path.join(path, meta["file"]), "rb") as f:
            raw = f.read()
        if validate:
            digest = hashlib.sha256(raw).hexdigest()
            if digest != meta["sha256"]:
                raise IOError(
                    f"checksum mismatch in {meta['file']} "
                    f"(checkpoint corrupt)"
                )
        arr = _restore_leaf(raw, meta["dtype"], meta["shape"])
        tshape = tuple(getattr(tleaf, "shape", ()) or ())
        if tshape != arr.shape:
            raise ValueError(
                f"leaf {i}: checkpoint shape {arr.shape} != template "
                f"{tshape}"
            )
        out.append(arr)
    return step, jax.tree.unflatten(treedef, out)


class CheckpointManager:
    """keep-last-k + optional async background writer."""

    def __init__(self, ckpt_dir: str, keep: int = 3, async_save: bool = True):
        self.dir = ckpt_dir
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def save(self, step: int, tree: Any):
        self.wait()
        # snapshot to host NOW so training can mutate buffers after return
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def work():
            try:
                save_checkpoint(self.dir, step, host_tree)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()
            if self._error:
                e, self._error = self._error, None
                raise e

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.dir)
            if d.startswith("step_") and ".tmp" not in d
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    def restore_latest(self, template: Any) -> Optional[Tuple[int, Any]]:
        self.wait()
        if latest_step(self.dir) is None:
            return None
        return restore_checkpoint(self.dir, template)
