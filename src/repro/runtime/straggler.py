"""Straggler detection + mitigation.

Three mechanisms, composable:

1. **Plan-level balancing** (always on): the locality planner's LPT
   assignment (core.locality.balance_assignments) equalizes per-rank
   inter-region responsibility, removing the structural stragglers the
   paper's load balancing targets.
2. **Step-time outlier detection** (this module): EWMA per-host step times;
   hosts persistently slower than ``threshold`` x the fleet median are
   flagged.
3. **Mitigation**: (a) shrink the straggler's data shard via
   ``rebalance_shards`` (exact, thanks to the seekable pipeline);
   (b) if it persists, evict the host and trigger the elastic re-mesh
   (runtime.elastic) — backup-step execution is intentionally NOT used:
   with synchronous SPMD collectives a backup replica cannot overlap a
   straggling collective participant (documented trade-off).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class StragglerConfig:
    ewma: float = 0.3
    threshold: float = 1.5       # x fleet median
    patience: int = 5            # consecutive flagged steps before action


class StragglerDetector:
    def __init__(self, n_hosts: int, cfg: StragglerConfig = StragglerConfig()):
        self.cfg = cfg
        self.times = np.zeros(n_hosts)
        self.flags = np.zeros(n_hosts, dtype=int)
        self.initialized = False

    def update(self, step_times: np.ndarray) -> List[int]:
        """Feed per-host step times; returns hosts needing mitigation."""
        a = self.cfg.ewma
        if not self.initialized:
            self.times = step_times.astype(float).copy()
            self.initialized = True
        else:
            self.times = (1 - a) * self.times + a * step_times
        med = np.median(self.times)
        slow = self.times > self.cfg.threshold * med
        self.flags = np.where(slow, self.flags + 1, 0)
        return [int(h) for h in np.flatnonzero(
            self.flags >= self.cfg.patience
        )]


def rebalance_shards(
    weights: np.ndarray, total_rows: int
) -> np.ndarray:
    """Assign per-host row counts inversely proportional to EWMA step time
    (a slow host gets less data).  Returns integer counts summing to
    total_rows."""
    speed = 1.0 / np.maximum(weights, 1e-9)
    frac = speed / speed.sum()
    counts = np.floor(frac * total_rows).astype(int)
    # distribute the remainder to the fastest hosts
    rem = total_rows - counts.sum()
    order = np.argsort(-speed)
    for i in range(rem):
        counts[order[i % len(order)]] += 1
    return counts
