"""Straggler detection + mitigation.

Units and contracts (the operator-facing surface, see docs/OPERATIONS.md):

* :meth:`StragglerDetector.update` takes per-host step **seconds** (one
  wall-clock step time per host, ``np.ndarray [n_hosts]``) and returns the
  list of host indices that have been flagged slow for
  ``StragglerConfig.patience`` *consecutive* updates.  A host is "slow"
  when its EWMA step time exceeds ``threshold`` x the fleet median EWMA.
  The detector never returns the whole fleet: if every host trips the
  threshold simultaneously (possible only for even fleets with an exact
  half split) the update returns ``[]`` — a uniformly slow fleet is a
  machine-rate problem for ``repro.profile.calibrate``, not an eviction.
* :func:`rebalance_shards` takes per-host **weights in step-seconds**
  (typically ``StragglerDetector.times``, the EWMA) and a row total, and
  returns integer per-host row counts summing exactly to ``total_rows``,
  inversely proportional to the weights — a 2x-slower host gets half the
  rows.  Feed the result to ``DistributedHierarchy.repartition(...,
  row_weights=)`` (which calls this internally) to apply the mitigation.

Three mechanisms, composable:

1. **Plan-level balancing** (always on): the locality planner's LPT
   assignment (core.locality.balance_assignments) equalizes per-rank
   inter-region responsibility, removing the structural stragglers the
   paper's load balancing targets.
2. **Step-time outlier detection** (this module): EWMA per-host step times;
   hosts persistently slower than ``threshold`` x the fleet median are
   flagged.  The measured feed comes either from launcher wall clocks or
   from ``repro.profile.TraceRecorder.per_proc_step_seconds`` (per-partner
   exchange samples attributed to hosts by their traffic share).
3. **Mitigation** (driven by ``runtime.controller.ElasticController``):
   (a) shrink the straggler's row shard via :func:`rebalance_shards`
   (exact, thanks to the seekable pipeline) and re-fit ``MachineParams``
   from the recorded trace so Section-5 transport selection reflects the
   degraded rates; (b) if it persists, evict the host and trigger the
   elastic re-mesh (runtime.elastic) — backup-step execution is
   intentionally NOT used: with synchronous SPMD collectives a backup
   replica cannot overlap a straggling collective participant (documented
   trade-off).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional

import numpy as np


@dataclasses.dataclass
class StragglerConfig:
    """Detector knobs.  ``ewma`` is the smoothing factor on step seconds
    (1.0 = trust only the newest sample); ``threshold`` is the slow cutoff
    as a multiple of the fleet median EWMA; ``patience`` is how many
    consecutive flagged updates a host survives before mitigation."""

    ewma: float = 0.3
    threshold: float = 1.5       # x fleet median
    patience: int = 5            # consecutive flagged steps before action


class StragglerDetector:
    """EWMA step-time outlier detector (see module docstring for units).

    ``times`` holds the per-host EWMA step seconds — the weight vector
    :func:`rebalance_shards` consumes at mitigation time.  ``flags`` holds
    consecutive-slow counters; :meth:`reset` clears them (and optionally
    re-seeds the EWMA) after a mitigation so the already-handled episode
    cannot re-trigger on stale state.
    """

    def __init__(self, n_hosts: int,
                 cfg: Optional[StragglerConfig] = None):
        # per-instance config: a shared default instance would alias
        # mutations (e.g. one detector tuning `patience`) across detectors
        self.cfg = cfg if cfg is not None else StragglerConfig()
        self.times = np.zeros(n_hosts)
        self.flags = np.zeros(n_hosts, dtype=int)
        self.initialized = False

    @property
    def n_hosts(self) -> int:
        return len(self.times)

    def update(self, step_times: np.ndarray) -> List[int]:
        """Feed per-host step *seconds*; returns hosts needing mitigation
        (flagged ``patience`` consecutive updates; never the whole fleet).
        """
        step_times = np.asarray(step_times, dtype=float).reshape(-1)
        if len(step_times) != self.n_hosts:
            raise ValueError(
                f"got {len(step_times)} step times for {self.n_hosts} hosts"
            )
        a = self.cfg.ewma
        if not self.initialized:
            self.times = step_times.astype(float).copy()
            self.initialized = True
        else:
            self.times = (1 - a) * self.times + a * step_times
        med = np.median(self.times)
        slow = self.times > self.cfg.threshold * med
        self.flags = np.where(slow, self.flags + 1, 0)
        flagged = [int(h) for h in np.flatnonzero(
            self.flags >= self.cfg.patience
        )]
        if len(flagged) >= self.n_hosts:
            # a "fleet" of stragglers has no one to migrate work to —
            # uniformly degraded rates are a calibration problem instead
            return []
        return flagged

    def reset(self, hosts: Optional[Iterable[int]] = None,
              reseed_times: bool = False) -> None:
        """Clear consecutive-slow counters after a mitigation (hysteresis:
        the handled episode must re-accumulate ``patience`` updates before
        it can trigger again).  ``hosts=None`` clears every host;
        ``reseed_times=True`` also resets the EWMA to the fleet median —
        use it when the mitigation changed the per-host work distribution,
        which invalidates the old step-time estimates."""
        if hosts is None:
            self.flags[:] = 0
        else:
            for h in hosts:
                self.flags[int(h)] = 0
        if reseed_times and self.initialized:
            self.times[:] = np.median(self.times)


def rebalance_shards(
    weights: np.ndarray, total_rows: int
) -> np.ndarray:
    """Per-host row counts inversely proportional to EWMA step seconds.

    ``weights`` are step-time weights in seconds (a slow host gets less
    data); the returned integer counts sum exactly to ``total_rows``, with
    the rounding remainder distributed to the fastest hosts.  A single
    host degenerates to the identity rebalance ``[total_rows]``."""
    weights = np.asarray(weights, dtype=float).reshape(-1)
    speed = 1.0 / np.maximum(weights, 1e-9)
    frac = speed / speed.sum()
    counts = np.floor(frac * total_rows).astype(int)
    # distribute the remainder to the fastest hosts
    rem = total_rows - counts.sum()
    order = np.argsort(-speed)
    for i in range(rem):
        counts[order[i % len(order)]] += 1
    return counts
