"""Elastic scaling: mesh (re)selection after device loss + state re-shard.

Units and contracts (the operator-facing surface, see docs/OPERATIONS.md):

* :meth:`HeartbeatMonitor.beat` records liveness for one host at the
  *current* step; :meth:`HeartbeatMonitor.advance` advances the step
  counter by one and returns the hosts that have now been silent for
  MORE than ``timeout_steps`` consecutive advances (a host that beat on
  step ``s`` is declared dead on the first advance where
  ``step - s > timeout_steps``).  Steps are dimensionless engine/solver
  iterations, not seconds — the caller owns the cadence.
* :func:`choose_mesh_shape` takes a surviving *device count* and returns
  ``(shape, axis_names)`` whose product is exactly that count;
  :func:`make_mesh_from_devices` materializes it over an explicit device
  list (first ``prod(shape)`` of ``jax.devices()`` by default).
* :func:`reshard_state` takes a pytree of arrays (host numpy or device
  arrays from the *old* mesh), a matching pytree of ``PartitionSpec`` s,
  and the new mesh; it returns the same values placed under
  ``NamedSharding(new_mesh, spec)`` per leaf — dtypes and shapes are
  preserved exactly (placement only, never a cast or reshape).

Recovery protocol (1000+-node design, exercised here on host devices):

1. A heartbeat/membership layer (the launcher, or
   ``runtime.controller.ElasticController`` in-process) detects failed
   hosts and reports the surviving device count.
2. ``choose_mesh_shape`` picks the largest valid (pod, data, model)
   factorization that still divides the model's TP requirements —
   preferring to keep 'model' fixed (TP degree is baked into layouts) and
   shrinking 'data' first (pure throughput loss, no re-layout).
3. The persistent collectives are re-planned through the surviving
   ``core.cache.PlanCache`` entries (plans are cheap relative to lost
   work — the paper's init-vs-iteration amortization argument — and a
   grow-back to a previously seen geometry re-plans *nothing*), via
   ``amg.distributed.DistributedHierarchy.repartition`` and
   ``serve.engine.ServeEngine.resize``; solver/model state moves with
   :func:`reshard_state` or the last checkpoint restored with the *new*
   shardings.

Straggler mitigation lives in ``straggler.py``; data re-sharding is exact
because the pipeline is stateless/seekable (see train/data.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MeshRequirements:
    model_divisors: int            # TP degree must divide this (heads, ...)
    prefer_model: int = 16
    min_model: int = 1


def choose_mesh_shape(
    n_devices: int, req: MeshRequirements, multi_pod_size: int = 256
) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Largest usable mesh from surviving devices.

    Keeps TP ('model') at the largest power-of-two <= prefer_model that
    divides the model; uses whole pods when n_devices spans several."""
    model = req.prefer_model
    while model > req.min_model and (
        req.model_divisors % model != 0 or n_devices % model != 0
    ):
        model //= 2
    model = max(model, 1)
    rest = n_devices // model
    if rest >= 2 and n_devices > multi_pod_size:
        pods = max(1, n_devices // multi_pod_size)
        while rest % pods != 0:
            pods -= 1
        return (pods, rest // pods, model), ("pod", "data", "model")
    return (rest, model), ("data", "model")


def make_mesh_from_devices(
    shape: Tuple[int, ...], axes: Tuple[str, ...],
    devices: Optional[List] = None,
) -> Mesh:
    devices = devices if devices is not None else jax.devices()
    n = int(np.prod(shape))
    dev = np.asarray(devices[:n]).reshape(shape)
    return Mesh(dev, axes)


def reshard_state(state, specs, new_mesh: Mesh):
    """Place a (host or differently-sharded) state onto a new mesh."""

    def put(x, spec):
        return jax.device_put(x, NamedSharding(new_mesh, spec))

    return jax.tree.map(put, state, specs,
                        is_leaf=lambda s: isinstance(s, P))


class HeartbeatMonitor:
    """Launcher-side liveness bookkeeping (host simulation).

    Real deployment: every host POSTs a heartbeat each step; the
    coordinator declares hosts dead after ``timeout_steps`` silent steps
    and triggers the elastic restart above."""

    def __init__(self, n_hosts: int, timeout_steps: int = 3):
        self.last_seen = {h: 0 for h in range(n_hosts)}
        self.timeout = timeout_steps
        self.step = 0

    def beat(self, host: int):
        self.last_seen[host] = self.step

    def advance(self) -> List[int]:
        """Advance one step; return hosts presumed dead."""
        self.step += 1
        return [
            h for h, s in self.last_seen.items()
            if self.step - s > self.timeout
        ]
