"""Label-aware metrics registry: counters, gauges, fixed-bucket histograms.

The runtime stack (PlanCache, DistributedHierarchy, ServeEngine) reports
into one process-wide :class:`MetricsRegistry` owned by ``repro.obs.Obs``.
Design constraints, in order:

* **Near-zero overhead when disabled.**  Every mutator checks one shared
  boolean first and returns without allocating.  The enabled flag lives in
  a one-element list shared by reference with every metric, so
  ``Obs.enable()`` flips all of them at once without a registry walk.
* **Deterministic export.**  Snapshots sort by metric name and label
  tuple, so two runs of the same program produce byte-identical JSON —
  that is what lets ``benchmarks/compare.py`` exact-gate ``obs/*`` rows.
* **Fixed buckets.**  Histogram bucket edges are chosen at declaration
  time (no dynamic rebinning); bucket ``i`` counts observations with
  ``value <= edges[i]``, the last bucket is the +inf overflow.

Labels are passed as keyword arguments and keyed internally by the sorted
``(key, value)`` tuple, so ``c.inc(ns="collective")`` and a hypothetical
``c.inc(**{"ns": "collective"})`` hit the same series.
"""
from __future__ import annotations

import bisect
import json
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

LabelKey = Tuple[Tuple[str, str], ...]

# Default histogram edges: wall-clock seconds from 10us to ~100s, roughly
# half-decade steps — wide enough for both a decode step and a cold
# hierarchy build.
DEFAULT_TIME_BUCKETS: Tuple[float, ...] = (
    1e-5, 3e-5, 1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2,
    1e-1, 3e-1, 1.0, 3.0, 10.0, 30.0, 100.0,
)


def _label_key(labels: Dict[str, object]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class Counter:
    """Monotonically increasing per-label float counter."""

    __slots__ = ("name", "help", "_enabled", "_series")

    def __init__(self, name: str, help: str, enabled_ref: List[bool]):
        self.name = name
        self.help = help
        self._enabled = enabled_ref
        self._series: Dict[LabelKey, float] = {}

    def inc(self, amount: float = 1.0, **labels) -> None:
        if not self._enabled[0]:
            return
        key = _label_key(labels)
        self._series[key] = self._series.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        return self._series.get(_label_key(labels), 0.0)

    def clear(self) -> None:
        self._series.clear()


class Gauge:
    """Last-write-wins per-label value (queue depth, device count, ...)."""

    __slots__ = ("name", "help", "_enabled", "_series")

    def __init__(self, name: str, help: str, enabled_ref: List[bool]):
        self.name = name
        self.help = help
        self._enabled = enabled_ref
        self._series: Dict[LabelKey, float] = {}

    def set(self, value: float, **labels) -> None:
        if not self._enabled[0]:
            return
        self._series[_label_key(labels)] = float(value)

    def value(self, **labels) -> float:
        return self._series.get(_label_key(labels), 0.0)

    def clear(self) -> None:
        self._series.clear()


class _HistSeries:
    __slots__ = ("counts", "sum", "count", "min", "max")

    def __init__(self, n_buckets: int):
        self.counts = [0] * n_buckets
        self.sum = 0.0
        self.count = 0
        self.min = float("inf")
        self.max = float("-inf")


class Histogram:
    """Fixed-bucket histogram.  ``edges`` are upper bounds; one implicit
    +inf overflow bucket is appended, so ``len(counts) == len(edges)+1``."""

    __slots__ = ("name", "help", "edges", "_enabled", "_series")

    def __init__(self, name: str, help: str, enabled_ref: List[bool],
                 buckets: Sequence[float] = DEFAULT_TIME_BUCKETS):
        self.name = name
        self.help = help
        self.edges = tuple(sorted(float(b) for b in buckets))
        if not self.edges:
            raise ValueError(f"histogram {name!r}: need at least one edge")
        self._enabled = enabled_ref
        self._series: Dict[LabelKey, _HistSeries] = {}

    def observe(self, value: float, **labels) -> None:
        if not self._enabled[0]:
            return
        key = _label_key(labels)
        s = self._series.get(key)
        if s is None:
            s = self._series[key] = _HistSeries(len(self.edges) + 1)
        # bucket i holds value <= edges[i]; bisect_left gives the first
        # edge >= value, i.e. exactly that bucket, and len(edges) (the
        # overflow bucket) when value exceeds every edge.
        s.counts[bisect.bisect_left(self.edges, value)] += 1
        s.sum += value
        s.count += 1
        if value < s.min:
            s.min = value
        if value > s.max:
            s.max = value

    def series(self, **labels) -> Optional[_HistSeries]:
        return self._series.get(_label_key(labels))

    def clear(self) -> None:
        self._series.clear()


class MetricsRegistry:
    """Process-wide named metric store; one per :class:`repro.obs.Obs`."""

    def __init__(self, enabled_ref: Optional[List[bool]] = None):
        self._enabled = enabled_ref if enabled_ref is not None else [False]
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    @property
    def enabled(self) -> bool:
        return self._enabled[0]

    # -- declaration (idempotent: re-declaring returns the same object) --

    def counter(self, name: str, help: str = "") -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name, help, self._enabled)
        return c

    def gauge(self, name: str, help: str = "") -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name, help, self._enabled)
        return g

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_TIME_BUCKETS,
                  ) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(
                name, help, self._enabled, buckets=buckets)
        return h

    # -- export --

    def snapshot(self) -> Dict:
        """Deterministic plain-dict view of every series (sorted)."""

        def dump_scalar(metrics) -> Dict:
            out = {}
            for name in sorted(metrics):
                m = metrics[name]
                out[name] = [
                    {"labels": dict(key), "value": m._series[key]}
                    for key in sorted(m._series)
                ]
            return out

        hists = {}
        for name in sorted(self._histograms):
            h = self._histograms[name]
            hists[name] = {
                "edges": list(h.edges),
                "series": [
                    {
                        "labels": dict(key),
                        "counts": list(h._series[key].counts),
                        "sum": h._series[key].sum,
                        "count": h._series[key].count,
                        "min": h._series[key].min,
                        "max": h._series[key].max,
                    }
                    for key in sorted(h._series)
                ],
            }
        return {"counters": dump_scalar(self._counters),
                "gauges": dump_scalar(self._gauges),
                "histograms": hists}

    @staticmethod
    def delta(before: Dict, after: Dict) -> Dict:
        """Counter/histogram-count differences between two snapshots
        (gauges are last-write-wins: the *after* value is reported)."""

        def index(rows: Iterable[Dict]) -> Dict[LabelKey, Dict]:
            return {_label_key(r["labels"]): r for r in rows}

        out: Dict = {"counters": {}, "gauges": dict(after.get("gauges", {})),
                     "histograms": {}}
        for name, rows in after.get("counters", {}).items():
            prev = index(before.get("counters", {}).get(name, []))
            diff = []
            for r in rows:
                base = prev.get(_label_key(r["labels"]), {}).get("value", 0.0)
                d = r["value"] - base
                if d:
                    diff.append({"labels": r["labels"], "value": d})
            if diff:
                out["counters"][name] = diff
        for name, h in after.get("histograms", {}).items():
            prev = index(before.get("histograms", {}).get(name, {})
                         .get("series", []))
            diff = []
            for r in h["series"]:
                base = prev.get(_label_key(r["labels"]))
                d_count = r["count"] - (base["count"] if base else 0)
                if d_count:
                    diff.append({"labels": r["labels"], "count": d_count,
                                 "sum": r["sum"] - (base["sum"] if base
                                                    else 0.0)})
            if diff:
                out["histograms"][name] = {"edges": h["edges"],
                                           "series": diff}
        return out

    def to_json(self, indent: Optional[int] = 1) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)

    def clear(self) -> None:
        for m in (*self._counters.values(), *self._gauges.values(),
                  *self._histograms.values()):
            m.clear()
