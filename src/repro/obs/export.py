"""Perfetto / Chrome ``trace_event`` export and the ``obs.report()`` table.

``to_perfetto`` lowers the span ring into the JSON object format both
``ui.perfetto.dev`` and ``chrome://tracing`` load directly:

* closed spans   → complete events (``"ph": "X"``, ``ts``/``dur`` in µs),
* instants       → ``"ph": "i"`` thread-scoped markers,
* counter samples→ ``"ph": "C"`` counter-track points,
* thread names   → ``"ph": "M"`` metadata rows.

Timestamps are ``time.perf_counter`` seconds rebased to the earliest
event so traces start at ``ts=0`` regardless of process uptime.  Span
attributes become the event's ``args`` after :func:`_json_safe`
sanitisation — plan objects and other rich values are stringified, never
serialized structurally (a CommPlan in ``args`` would bloat the trace by
orders of magnitude).

``save_perfetto`` writes atomically (tmp file + ``os.replace``) for the
same reason ``TraceRecorder.save`` does: a serve process killed mid-write
must not leave a truncated JSON behind.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

from .spans import SpanEvent

SCHEMA_VERSION = 1

_JSON_SCALARS = (bool, int, float, str, type(None))


def _json_safe(attrs: Dict) -> Dict:
    out = {}
    for k, v in attrs.items():
        if isinstance(v, _JSON_SCALARS):
            out[k] = v
        elif isinstance(v, (list, tuple)) and all(
                isinstance(x, _JSON_SCALARS) for x in v):
            out[k] = list(v)
        else:
            out[k] = f"<{type(v).__name__}>"
    return out


def to_perfetto(events: List[SpanEvent], process_name: str = "repro",
                pid: int = 0) -> Dict:
    """Lower ring events to the Chrome trace_event JSON object format."""
    if events:
        t_base = min(e.t0 for e in events)
    else:
        t_base = 0.0
    trace: List[Dict] = [{
        "ph": "M", "name": "process_name", "pid": pid, "tid": 0,
        "args": {"name": process_name},
    }]
    tids = sorted({e.tid for e in events})
    tid_map = {t: i for i, t in enumerate(tids)}
    for t, i in tid_map.items():
        trace.append({"ph": "M", "name": "thread_name", "pid": pid,
                      "tid": i, "args": {"name": f"thread-{i}"}})
    for e in events:
        ts_us = (e.t0 - t_base) * 1e6
        tid = tid_map.get(e.tid, 0)
        if e.kind == "span":
            trace.append({
                "ph": "X", "name": e.name, "cat": e.name.split("/", 1)[0],
                "pid": pid, "tid": tid, "ts": ts_us,
                "dur": (e.t1 - e.t0) * 1e6, "args": _json_safe(e.attrs),
            })
        elif e.kind == "instant":
            trace.append({
                "ph": "i", "name": e.name, "cat": e.name.split("/", 1)[0],
                "pid": pid, "tid": tid, "ts": ts_us, "s": "t",
                "args": _json_safe(e.attrs),
            })
        elif e.kind == "counter":
            trace.append({
                "ph": "C", "name": e.name, "pid": pid, "tid": tid,
                "ts": ts_us,
                "args": {"value": float(e.attrs.get("value", 0.0))},
            })
    return {"traceEvents": trace, "displayTimeUnit": "ms",
            "otherData": {"schema_version": SCHEMA_VERSION}}


def save_perfetto(events: List[SpanEvent], path, process_name: str = "repro",
                  ) -> None:
    """Atomic write of :func:`to_perfetto` output (tmp + rename)."""
    path = os.fspath(path)
    tmp = f"{path}.tmp-{os.getpid()}"
    try:
        with open(tmp, "w") as f:
            json.dump(to_perfetto(events, process_name=process_name), f)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def report(events: List[SpanEvent], metrics_snapshot: Dict) -> str:
    """Human-readable summary: per-span-name timing rollup, then
    counters, then histogram quantile-ish lines (count/mean/max)."""
    rows: Dict[str, List[float]] = {}
    for e in events:
        if e.kind == "span":
            rows.setdefault(e.name, []).append(e.duration)
    lines = [f"{'span':<40s} {'count':>6s} {'total_ms':>10s} "
             f"{'mean_ms':>9s} {'max_ms':>9s}"]
    for name in sorted(rows):
        ds = rows[name]
        lines.append(
            f"{name:<40s} {len(ds):>6d} {sum(ds) * 1e3:>10.3f} "
            f"{sum(ds) / len(ds) * 1e3:>9.3f} {max(ds) * 1e3:>9.3f}"
        )
    if not rows:
        lines.append("(no spans recorded)")

    counters = metrics_snapshot.get("counters", {})
    if any(counters.values()):
        lines.append("")
        lines.append(f"{'counter':<52s} {'value':>12s}")
        for name in sorted(counters):
            for row in counters[name]:
                lbl = ",".join(f"{k}={v}" for k, v in
                               sorted(row["labels"].items()))
                full = f"{name}{{{lbl}}}" if lbl else name
                lines.append(f"{full:<52s} {row['value']:>12g}")

    hists = metrics_snapshot.get("histograms", {})
    if any(h["series"] for h in hists.values()):
        lines.append("")
        lines.append(f"{'histogram':<52s} {'count':>6s} {'mean':>10s} "
                     f"{'max':>10s}")
        for name in sorted(hists):
            for row in hists[name]["series"]:
                lbl = ",".join(f"{k}={v}" for k, v in
                               sorted(row["labels"].items()))
                full = f"{name}{{{lbl}}}" if lbl else name
                mean = row["sum"] / row["count"] if row["count"] else 0.0
                lines.append(f"{full:<52s} {row['count']:>6d} "
                             f"{mean:>10.4g} {row['max']:>10.4g}")
    return "\n".join(lines)
