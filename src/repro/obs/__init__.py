"""repro.obs — unified metrics/span telemetry for plan → exchange →
kernel → serve.

One process-wide :class:`Obs` instance (``default_obs()``) owns a
:class:`~repro.obs.metrics.MetricsRegistry` and a
:class:`~repro.obs.spans.SpanRecorder`.  Everything is **off by
default**: until ``enable()`` is called, ``span()`` returns the shared
:data:`~repro.obs.spans.NULL_SPAN` and every counter mutator early-outs
on one boolean — instrumented hot paths (decode steps, cache lookups)
cost one attribute read + one branch.

Usage::

    from repro.obs import default_obs

    obs = default_obs()
    obs.enable()
    ...  # run instrumented code: solves, decode steps, resizes
    print(obs.report())                  # rollup table
    obs.export_perfetto("trace.json")    # load in ui.perfetto.dev

**TraceRecorder bridge** (the online-calibration pipe): attach a
``repro.profile.TraceRecorder`` via ``enable(tracer=...)`` and every
closing span whose attributes carry ``plan=<CommPlan>`` and
``pure_exchange=True`` is forwarded to ``tracer.record_plan`` — the same
samples ``fit_trace`` consumes.  ``ServeEngine(observe=True)`` uses
exactly this path to refit ``MachineParams`` from production decode
steps (see ``docs/OPERATIONS.md`` § Observability).

The blessed wall clock is :func:`now` (``time.perf_counter``); rule R4
of ``tools/lint_repro.py`` keeps ad-hoc ``perf_counter`` calls out of
``src/repro`` so all timing flows through here or ``repro.profile``.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from .export import report as _report
from .export import save_perfetto, to_perfetto
from .metrics import (  # noqa: F401  (re-exported API)
    Counter,
    DEFAULT_TIME_BUCKETS,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from .spans import (  # noqa: F401
    DEFAULT_RING_SIZE,
    NULL_SPAN,
    Span,
    SpanEvent,
    SpanRecorder,
    now,
)

__all__ = [
    "Obs", "default_obs", "now", "NULL_SPAN", "SpanEvent",
    "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "DEFAULT_TIME_BUCKETS", "DEFAULT_RING_SIZE",
]


class Obs:
    """Metrics registry + span ring + optional TraceRecorder bridge."""

    def __init__(self, ring_size: int = DEFAULT_RING_SIZE):
        self._enabled_ref: List[bool] = [False]
        self.metrics = MetricsRegistry(self._enabled_ref)
        self.spans = SpanRecorder(ring_size=ring_size)
        self.spans.on_close = self._on_span_close
        self._tracer = None     # Optional[repro.profile.TraceRecorder]

    # ------------------------------------------------------------ state
    @property
    def enabled(self) -> bool:
        return self._enabled_ref[0]

    @property
    def tracer(self):
        """The attached TraceRecorder, or None (always None when
        disabled — callers may use this to gate bridge-only work)."""
        return self._tracer if self.enabled else None

    def enable(self, tracer=None, ring_size: Optional[int] = None) -> "Obs":
        if ring_size is not None and ring_size != self.spans.ring.maxlen:
            self.spans = SpanRecorder(ring_size=ring_size)
            self.spans.on_close = self._on_span_close
        if tracer is not None:
            self._tracer = tracer
        self._enabled_ref[0] = True
        return self

    def disable(self) -> "Obs":
        self._enabled_ref[0] = False
        return self

    def attach_tracer(self, tracer) -> "Obs":
        self._tracer = tracer
        return self

    def reset(self) -> "Obs":
        """Drop all recorded data (registry declarations survive)."""
        self.metrics.clear()
        self.spans.clear()
        return self

    # ------------------------------------------------------- recording
    def span(self, name: str, **attrs):
        """Open a span; ``with obs.span("amg/solve", levels=3): ...``.
        Disabled fast path: returns the shared NULL_SPAN singleton."""
        if not self._enabled_ref[0]:
            return NULL_SPAN
        return self.spans.span(name, **attrs)

    def event(self, name: str, **attrs) -> None:
        """Record an instant event (replan, resize, refit, ...)."""
        if self._enabled_ref[0]:
            self.spans.event(name, **attrs)

    def counter(self, name: str, help: str = "") -> Counter:
        return self.metrics.counter(name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self.metrics.gauge(name, help)

    def histogram(self, name: str, help: str = "", **kw) -> Histogram:
        return self.metrics.histogram(name, help, **kw)

    # --------------------------------------------------------- bridge
    def _on_span_close(self, ev: SpanEvent) -> None:
        # pure-exchange spans feed the calibration trace: same samples
        # fit_trace consumes, so production steps calibrate like benches.
        if self._tracer is not None and ev.attrs.get("pure_exchange"):
            plan = ev.attrs.get("plan")
            if plan is not None:
                self._tracer.record_plan(
                    plan,
                    float(ev.attrs.get("seconds", ev.duration)),
                    label=ev.name,
                    pure_exchange=True,
                    fingerprint=ev.attrs.get("fingerprint"),
                )
        # top-level span close = natural counter-track sample point
        if ev.depth == 0:
            for name, c in sorted(self.metrics._counters.items()):
                if c._series:
                    self.spans.counter_sample(name, sum(c._series.values()))

    # --------------------------------------------------------- export
    def snapshot(self) -> Dict:
        return self.metrics.snapshot()

    def delta(self, before: Dict) -> Dict:
        return MetricsRegistry.delta(before, self.metrics.snapshot())

    def report(self) -> str:
        return _report(self.spans.events(), self.metrics.snapshot())

    def span_tree(self) -> str:
        return self.spans.tree()

    def to_perfetto(self, process_name: str = "repro") -> Dict:
        return to_perfetto(self.spans.events(), process_name=process_name)

    def export_perfetto(self, path, process_name: str = "repro") -> None:
        save_perfetto(self.spans.events(), path, process_name=process_name)


_DEFAULT: Obs = Obs()


def default_obs() -> Obs:
    """The process-wide instance every instrumented module reports to."""
    return _DEFAULT
