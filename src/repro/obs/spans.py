"""Structured wall-clock spans over a bounded in-memory ring buffer.

A span is one timed region of the plan → exchange → kernel → serve path::

    with obs.span("amg/solve", levels=3) as sp:
        ...
        sp.set(iters=it)            # attach attributes mid-flight

Spans nest per-thread (a thread-local stack supplies depth and parent
identity), survive exceptions (the ``with`` protocol closes them and tags
``error=...``), and land as :class:`SpanEvent` records in a
``collections.deque(maxlen=...)`` ring — old events fall off the back, a
long-lived serve process never grows without bound.

Two non-span record kinds share the ring so the Perfetto exporter can
interleave them on the same clock:

* ``instant`` — a point event (``obs.event("serve/replan", ...)``);
* ``counter`` — a metric sample for Perfetto counter tracks, emitted by
  ``Obs`` when a top-level span closes.

The **disabled fast path** returns the module singleton :data:`NULL_SPAN`
— no ``Span`` object, no ring append, no clock read.  Tests assert the
identity (``obs.span(...) is NULL_SPAN``) so the fast path cannot
silently regress into an allocating one.

The clock is ``time.perf_counter`` re-exported as :func:`now` — the one
blessed timing call site outside ``repro.profile`` (see
``tools/lint_repro.py`` rule R4).
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, Optional

now = time.perf_counter

DEFAULT_RING_SIZE = 65536


@dataclass
class SpanEvent:
    """One closed span (or instant/counter record) in the ring."""

    name: str
    t0: float                       # perf_counter seconds
    t1: float
    depth: int = 0
    tid: int = 0
    kind: str = "span"              # "span" | "instant" | "counter"
    attrs: Dict[str, Any] = field(default_factory=dict)

    @property
    def duration(self) -> float:
        return self.t1 - self.t0


class _NullSpan:
    """Shared no-op stand-in returned while tracing is disabled."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> "_NullSpan":
        return self


NULL_SPAN = _NullSpan()


class Span:
    """An open span; close it via the ``with`` protocol."""

    __slots__ = ("name", "attrs", "t0", "_rec", "_depth", "_closed")

    def __init__(self, recorder: "SpanRecorder", name: str,
                 attrs: Dict[str, Any]):
        self.name = name
        self.attrs = attrs
        self._rec = recorder
        self._depth = 0
        self._closed = False
        self.t0 = 0.0

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        stack = self._rec._stack()
        self._depth = len(stack)
        stack.append(self)
        self.t0 = now()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        t1 = now()          # clock first: exclude our own bookkeeping
        if self._closed:    # defensive: double-exit records once
            return False
        self._closed = True
        stack = self._rec._stack()
        if stack and stack[-1] is self:
            stack.pop()
        elif self in stack:         # mis-nested close: drop through to us
            while stack and stack[-1] is not self:
                stack.pop()
            if stack:
                stack.pop()
        if exc is not None:
            self.attrs["error"] = repr(exc)
        self._rec._close(self, t1)
        return False


class SpanRecorder:
    """Ring buffer + per-thread span stacks.

    ``on_close`` (set by ``Obs``) observes every closed *span* event —
    the hook point for the TraceRecorder bridge and counter sampling.
    """

    def __init__(self, ring_size: int = DEFAULT_RING_SIZE):
        self.ring: Deque[SpanEvent] = deque(maxlen=ring_size)
        self._local = threading.local()
        self.on_close = None        # Optional[Callable[[SpanEvent], None]]
        self.dropped = 0            # ring evictions (ring full)

    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    @property
    def depth(self) -> int:
        return len(self._stack())

    def span(self, name: str, **attrs) -> Span:
        return Span(self, name, attrs)

    def event(self, name: str, **attrs) -> None:
        t = now()
        self._append(SpanEvent(name=name, t0=t, t1=t,
                               depth=len(self._stack()),
                               tid=threading.get_ident(),
                               kind="instant", attrs=attrs))

    def counter_sample(self, name: str, value: float) -> None:
        t = now()
        self._append(SpanEvent(name=name, t0=t, t1=t, kind="counter",
                               tid=threading.get_ident(),
                               attrs={"value": float(value)}))

    def _append(self, ev: SpanEvent) -> None:
        if len(self.ring) == self.ring.maxlen:
            self.dropped += 1
        self.ring.append(ev)

    def _close(self, span: Span, t1: float) -> None:
        ev = SpanEvent(name=span.name, t0=span.t0, t1=t1,
                       depth=span._depth, tid=threading.get_ident(),
                       kind="span", attrs=span.attrs)
        self._append(ev)
        if self.on_close is not None:
            self.on_close(ev)

    def events(self, kind: Optional[str] = None) -> list:
        evs = list(self.ring)
        if kind is not None:
            evs = [e for e in evs if e.kind == kind]
        return evs

    def clear(self) -> None:
        self.ring.clear()
        self.dropped = 0

    def tree(self) -> str:
        """Indented close-order listing of spans — the quick-look view
        (``check_obs.py`` asserts against this)."""
        lines = []
        for ev in self.ring:
            if ev.kind != "span":
                continue
            lines.append(f"{'  ' * ev.depth}{ev.name} "
                         f"{ev.duration * 1e3:.3f}ms")
        return "\n".join(lines)
