"""seamless-m4t-medium [audio]: enc-dec, 12 encoder + 12 decoder layers,
d1024 16H ff4096 vocab=256206; audio frontend = STUB (input_specs supply
precomputed frame embeddings) (arXiv:2308.11596)."""
from ..models.common import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="seamless-m4t-medium",
        family="audio",
        n_layers=24,
        n_enc_layers=12,
        n_dec_layers=12,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=4096,
        # 256,206 padded to 256,256 (= 256*1001) for TP-friendly sharding
        vocab=256256,
        act="gelu",
        frontend_stub=True,
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="seamless-m4t-medium-smoke",
        family="audio",
        n_layers=4,
        n_enc_layers=2,
        n_dec_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=512,
        act="gelu",
        frontend_stub=True,
    )
