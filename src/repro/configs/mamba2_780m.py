"""mamba2-780m [ssm]: 48L d1536, attn-free, vocab=50280, ssm_state=128,
SSD head_dim=64 (arXiv:2405.21060)."""
from ..models.common import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="mamba2-780m",
        family="ssm",
        n_layers=48,
        d_model=1536,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        # 50,280 padded to 50,432 (= 256*197): embedding tables are padded
        # to a TP-friendly multiple, standard practice; pad logits unused
        vocab=50432,
        tie_embeddings=True,
        ssm_state=128,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_groups=1,
        d_conv=4,
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="mamba2-780m-smoke",
        family="ssm",
        n_layers=3,
        d_model=64,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab=512,
        tie_embeddings=True,
        ssm_state=16,
        ssm_head_dim=16,
        ssm_expand=2,
        ssm_groups=1,
        d_conv=4,
    )
