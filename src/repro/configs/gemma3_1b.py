"""gemma3-1b [dense]: 26L d1152 4H (MQA kv=1, d_head=256) ff6912
vocab=262144; 5 local(512-window):1 global, qk-norm, sandwich norms,
tied embeddings (hf:google/gemma-3-1b-pt)."""
from ..models.common import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="gemma3-1b",
        family="dense",
        n_layers=26,
        d_model=1152,
        n_heads=4,
        n_kv_heads=1,
        d_head=256,
        d_ff=6912,
        vocab=262144,
        act="gelu",
        rope_theta=1_000_000.0,
        qk_norm=True,
        sandwich_norm=True,
        tie_embeddings=True,
        window=512,
        local_global_period=6,
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="gemma3-1b-smoke",
        family="dense",
        n_layers=6,          # one full 5:1 local:global period
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        d_head=16,
        d_ff=128,
        vocab=512,
        act="gelu",
        qk_norm=True,
        sandwich_norm=True,
        tie_embeddings=True,
        window=16,
        local_global_period=6,
    )
