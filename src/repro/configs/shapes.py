"""Assigned input shapes x applicability rules (see DESIGN.md).

Every arch is paired with four shapes; ``long_500k`` requires sub-quadratic
attention and therefore runs only for SSM/hybrid/sliding-window archs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

from ..models.common import ArchConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# archs whose every attention layer is full (quadratic) attention: skip 500k
_FULL_ATTN_ONLY = {
    "nemotron-4-15b", "qwen1.5-0.5b", "qwen2-0.5b", "qwen2-vl-2b",
    "deepseek-v2-lite-16b", "seamless-m4t-medium",
}


def applicable(arch_name: str, shape_name: str) -> bool:
    if shape_name == "long_500k" and arch_name in _FULL_ATTN_ONLY:
        return False
    return True


def skip_reason(arch_name: str, shape_name: str) -> Optional[str]:
    if not applicable(arch_name, shape_name):
        return ("long_500k requires sub-quadratic attention; "
                f"{arch_name} is pure full-attention (see DESIGN.md)")
    return None


def all_cells() -> List:
    from . import ARCHS
    return [(a, s) for a in ARCHS for s in SHAPES]
