"""qwen2-vl-2b [vlm]: 28L d1536 12H (GQA kv=2) ff8960 vocab=151936,
M-RoPE (sections 16/24/24), dynamic-resolution vision frontend = STUB:
input_specs provide precomputed patch embeddings (arXiv:2409.12191)."""
from ..models.common import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen2-vl-2b",
        family="vlm",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        d_ff=8960,
        vocab=151936,
        qkv_bias=True,
        tie_embeddings=True,
        rope_theta=1_000_000.0,
        mrope_sections=(16, 24, 24),
        frontend_stub=True,
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="qwen2-vl-2b-smoke",
        family="vlm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=512,
        qkv_bias=True,
        tie_embeddings=True,
        mrope_sections=(2, 3, 3),
        frontend_stub=True,
    )
