"""zamba2-7b [hybrid]: 81 Mamba2 layers d3584 (d_inner=7168, ssm_state=64,
head_dim=64 -> 112 SSD heads) + 2 alternating shared attention blocks
(32H over concat(x, x_emb)=2d) applied every 6 SSM layers, ff=14336,
vocab=32000 (arXiv:2411.15242)."""
from ..models.common import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="zamba2-7b",
        family="hybrid",
        n_layers=81,
        d_model=3584,
        n_heads=32,
        n_kv_heads=32,
        d_ff=14336,
        vocab=32000,
        ssm_state=64,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_groups=1,
        d_conv=4,
        shared_attn_period=6,
        n_shared_attn_blocks=2,
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="zamba2-7b-smoke",
        family="hybrid",
        n_layers=5,          # 2 segments of 2 + tail 1
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=512,
        ssm_state=16,
        ssm_head_dim=16,
        ssm_expand=2,
        ssm_groups=1,
        d_conv=4,
        shared_attn_period=2,
        n_shared_attn_blocks=2,
    )
