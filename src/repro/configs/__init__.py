"""Architecture registry: one module per assigned arch (+ paper workload).

``get(name)`` -> full ArchConfig (the assignment's exact numbers);
``reduced(name)`` -> same family, tiny dims (CPU smoke tests).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

ARCHS = [
    "nemotron-4-15b",
    "gemma3-1b",
    "qwen1.5-0.5b",
    "qwen2-0.5b",
    "mamba2-780m",
    "qwen2-vl-2b",
    "deepseek-v2-lite-16b",
    "mixtral-8x7b",
    "zamba2-7b",
    "seamless-m4t-medium",
]

_MODULES = {
    "nemotron-4-15b": "nemotron_4_15b",
    "gemma3-1b": "gemma3_1b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "qwen2-0.5b": "qwen2_0_5b",
    "mamba2-780m": "mamba2_780m",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "mixtral-8x7b": "mixtral_8x7b",
    "zamba2-7b": "zamba2_7b",
    "seamless-m4t-medium": "seamless_m4t_medium",
}


def _mod(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {ARCHS}")
    return importlib.import_module(f".{_MODULES[name]}", __package__)


def get(name: str):
    return _mod(name).config()


def reduced(name: str):
    return _mod(name).reduced()


def list_archs() -> List[str]:
    return list(ARCHS)
