"""nemotron-4-15b [dense]: 32L d6144 48H (GQA kv=8) ff24576 vocab=256000.
GQA + squared-ReLU MLP + partial rotary (arXiv:2402.16819)."""
from ..models.common import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="nemotron-4-15b",
        family="dense",
        n_layers=32,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=24576,
        vocab=256000,
        act="relu2",
        gated_mlp=False,
        partial_rotary=0.5,
        rope_theta=10000.0,
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="nemotron-4-15b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=512,
        act="relu2",
        gated_mlp=False,
        partial_rotary=0.5,
    )
