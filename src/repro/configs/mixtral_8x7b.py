"""mixtral-8x7b [moe]: 32L d4096 32H (GQA kv=8) expert ff=14336
vocab=32000, 8 experts top-2, sliding-window attention
(arXiv:2401.04088)."""
from ..models.common import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="mixtral-8x7b",
        family="moe",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=0,
        d_ff_expert=14336,
        vocab=32000,
        n_experts=8,
        top_k=2,
        window=4096,
        rope_theta=1_000_000.0,
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="mixtral-8x7b-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=0,
        d_ff_expert=64,
        vocab=512,
        n_experts=4,
        top_k=2,
        window=16,
    )
