"""deepseek-v2-lite-16b [moe]: 27L d2048 16H, MLA kv_lora=512
(nope=128, rope=64, v=128), MoE 64 routed top-6 + 2 shared (expert
ff=1408), first layer dense (ff=10944), vocab=102400 (arXiv:2405.04434).

Assignment note: the pool line reads "2 shared+160 routed"; 160 is full
V2 — V2-*Lite* is 64 routed (matching the leading "MoE 64e top-6"),
which is what we implement (see DESIGN.md)."""
from ..models.common import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v2-lite-16b",
        family="moe",
        n_layers=27,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=10944,            # the single leading dense layer
        d_ff_expert=1408,
        vocab=102400,
        n_experts=64,
        n_shared_experts=2,
        top_k=6,
        first_dense_layers=1,
        mla=True,
        kv_lora=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v2-lite-16b-smoke",
        family="moe",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=160,
        d_ff_expert=32,
        vocab=512,
        n_experts=8,
        n_shared_experts=2,
        top_k=3,
        first_dense_layers=1,
        mla=True,
        kv_lora=32,
        qk_nope_dim=16,
        qk_rope_dim=8,
        v_head_dim=16,
    )
