"""Version-compatibility shims for the range of JAX releases this repo meets.

``jax.sharding.AxisType`` (and the matching ``axis_types=`` kwarg of
``jax.make_mesh``) only exist in newer JAX releases; older ones (e.g. the
0.4.x line installed in the CPU container) have neither.  Likewise
``shard_map`` moved from ``jax.experimental.shard_map`` to ``jax.shard_map``.
Model code that wants Auto axis semantics goes through :func:`make_mesh_auto`
instead of touching ``AxisType`` directly; anything that needs the enum
imports :data:`AxisType` from here (``None`` when unavailable), and all
``shard_map`` users import it from here.

Importing this module does not initialize any jax backend, so it is safe to
import before ``XLA_FLAGS`` is finalized (the dry-run sets flags before the
first device query).
"""
from __future__ import annotations

from typing import Optional, Sequence

try:  # JAX >= 0.6-ish: explicit/auto/manual axis types
    from jax.sharding import AxisType  # type: ignore[attr-defined]
except ImportError:  # older JAX: no axis types — meshes are implicitly Auto
    AxisType = None

try:  # new home (jax.shard_map, JAX >= 0.5)
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:  # old home
    from jax.experimental.shard_map import shard_map as _shard_map

import inspect as _inspect

_SHARD_MAP_PARAMS = frozenset(_inspect.signature(_shard_map).parameters)


def shard_map(f=None, **kwargs):
    """``shard_map`` with the replication-check kwarg normalized.

    New JAX calls it ``check_vma``, old JAX ``check_rep``; callers may pass
    either and the one the installed JAX understands is forwarded.
    """
    if "check_vma" in kwargs and "check_vma" not in _SHARD_MAP_PARAMS:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    elif "check_rep" in kwargs and "check_rep" not in _SHARD_MAP_PARAMS:
        kwargs["check_vma"] = kwargs.pop("check_rep")
    if f is None:
        return _shard_map(**kwargs)
    return _shard_map(f, **kwargs)


def cost_analysis_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a flat dict across JAX versions.

    Old JAX returns a one-element list of dicts (one per device assignment);
    new JAX returns the dict directly.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def pallas_tpu_compiler_params(**kwargs):
    """Pallas TPU compiler params across the class rename.

    New JAX: ``pallas.tpu.CompilerParams``; old JAX: ``TPUCompilerParams``.
    """
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)


def make_mesh_auto(
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
    *,
    devices: Optional[Sequence] = None,
):
    """``jax.make_mesh`` with every axis marked Auto where supported.

    On JAX without ``AxisType`` the plain mesh already behaves as Auto, so
    the kwarg is simply dropped.
    """
    import inspect

    import jax

    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if (
        AxisType is not None
        and "axis_types" in inspect.signature(jax.make_mesh).parameters
    ):
        kwargs["axis_types"] = (AxisType.Auto,) * len(tuple(axis_names))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)
