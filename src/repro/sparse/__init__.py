from .csr import CSR
from .partition import (
    PartitionedCSR,
    block_offsets,
    distributed_spmv_numpy,
    partition_csr,
    partition_rect_csr,
    partitioned_from_blocks,
    split_rows,
    stack_blocks,
)
from .device import (
    DeviceEll,
    distributed_spmv,
    make_distributed_spmv,
    pack_vector,
    partitioned_to_ell,
    unpack_vector,
)
from .spgemm import (
    RapResult,
    RowGather,
    gather_remote_rows,
    merge_row_sets,
    spgemm_local,
    spgemm_rap,
)

__all__ = [
    "CSR", "PartitionedCSR", "block_offsets", "distributed_spmv_numpy",
    "partition_csr", "partition_rect_csr", "partitioned_from_blocks",
    "split_rows", "stack_blocks",
    "DeviceEll", "distributed_spmv", "make_distributed_spmv",
    "pack_vector", "partitioned_to_ell", "unpack_vector",
    "RapResult", "RowGather", "gather_remote_rows", "merge_row_sets",
    "spgemm_local", "spgemm_rap",
]
