from .csr import CSR
from .partition import (
    PartitionedCSR,
    block_offsets,
    distributed_spmv_numpy,
    partition_csr,
)

__all__ = [
    "CSR", "PartitionedCSR", "block_offsets", "distributed_spmv_numpy",
    "partition_csr",
]
