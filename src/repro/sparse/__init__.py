from .csr import CSR
from .partition import (
    PartitionedCSR,
    block_offsets,
    distributed_spmv_numpy,
    partition_csr,
    partition_rect_csr,
    partitioned_from_blocks,
    split_rows,
    stack_blocks,
)
from .device import (
    DeviceEll,
    DeviceEllBlocked,
    KernelSelection,
    OverlapSelection,
    default_spmv_vmem_limit,
    distributed_spmv,
    make_distributed_spmv,
    overlap_decision,
    pack_vector,
    partitioned_to_device,
    partitioned_to_ell,
    partitioned_to_ell_blocked,
    row_block_bucket_map,
    select_spmv_kernel,
    select_spmv_overlap,
    spmv_blocked_vmem_bytes,
    spmv_flat_vmem_bytes,
    unpack_vector,
)
from .spgemm import (
    RapResult,
    RowGather,
    gather_remote_rows,
    merge_row_sets,
    spgemm_local,
    spgemm_rap,
)

__all__ = [
    "CSR", "PartitionedCSR", "block_offsets", "distributed_spmv_numpy",
    "partition_csr", "partition_rect_csr", "partitioned_from_blocks",
    "split_rows", "stack_blocks",
    "DeviceEll", "DeviceEllBlocked", "KernelSelection", "OverlapSelection",
    "default_spmv_vmem_limit", "distributed_spmv", "make_distributed_spmv",
    "overlap_decision", "pack_vector", "partitioned_to_device",
    "partitioned_to_ell", "partitioned_to_ell_blocked",
    "row_block_bucket_map", "select_spmv_kernel", "select_spmv_overlap",
    "spmv_blocked_vmem_bytes", "spmv_flat_vmem_bytes", "unpack_vector",
    "RapResult", "RowGather", "gather_remote_rows", "merge_row_sets",
    "spgemm_local", "spgemm_rap",
]
