from .csr import CSR
from .partition import (
    PartitionedCSR,
    block_offsets,
    distributed_spmv_numpy,
    partition_csr,
    partition_rect_csr,
)
from .device import (
    DeviceEll,
    distributed_spmv,
    make_distributed_spmv,
    pack_vector,
    partitioned_to_ell,
    unpack_vector,
)

__all__ = [
    "CSR", "PartitionedCSR", "block_offsets", "distributed_spmv_numpy",
    "partition_csr", "partition_rect_csr",
    "DeviceEll", "distributed_spmv", "make_distributed_spmv",
    "pack_vector", "partitioned_to_ell", "unpack_vector",
]
