"""Minimal CSR sparse-matrix substrate (numpy; scipy-free).

Supports everything the AMG pipeline needs: SpMV, SpGEMM (CSR x CSR),
transpose, diagonal extraction, row scaling, and pruning.  Row-major CSR with
int64 indptr / int32 indices / float64 data.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np


@dataclass
class CSR:
    shape: Tuple[int, int]
    indptr: np.ndarray
    indices: np.ndarray
    data: np.ndarray

    # ------------------------------------------------------------ basics
    @property
    def nnz(self) -> int:
        return int(self.indptr[-1])

    @property
    def nrows(self) -> int:
        return self.shape[0]

    @property
    def ncols(self) -> int:
        return self.shape[1]

    def row(self, i: int) -> Tuple[np.ndarray, np.ndarray]:
        lo, hi = int(self.indptr[i]), int(self.indptr[i + 1])
        return self.indices[lo:hi], self.data[lo:hi]

    @staticmethod
    def from_coo(
        rows: np.ndarray, cols: np.ndarray, vals: np.ndarray, shape
    ) -> "CSR":
        rows = np.asarray(rows, dtype=np.int64)
        cols = np.asarray(cols, dtype=np.int32)
        vals = np.asarray(vals, dtype=np.float64)
        order = np.lexsort((cols, rows))
        rows, cols, vals = rows[order], cols[order], vals[order]
        # merge duplicates
        if len(rows):
            key_new = np.ones(len(rows), dtype=bool)
            key_new[1:] = (rows[1:] != rows[:-1]) | (cols[1:] != cols[:-1])
            groups = np.cumsum(key_new) - 1
            merged_vals = np.zeros(groups[-1] + 1 if len(groups) else 0)
            np.add.at(merged_vals, groups, vals)
            rows, cols, vals = rows[key_new], cols[key_new], merged_vals
        indptr = np.zeros(shape[0] + 1, dtype=np.int64)
        np.add.at(indptr, rows + 1, 1)
        indptr = np.cumsum(indptr)
        return CSR(tuple(shape), indptr, cols.astype(np.int32), vals)

    @staticmethod
    def eye(n: int) -> "CSR":
        return CSR(
            (n, n),
            np.arange(n + 1, dtype=np.int64),
            np.arange(n, dtype=np.int32),
            np.ones(n),
        )

    def row_indices(self) -> np.ndarray:
        """COO row array: row index of every stored entry."""
        return np.repeat(
            np.arange(self.nrows, dtype=np.int64), np.diff(self.indptr)
        )

    def to_dense(self) -> np.ndarray:
        out = np.zeros(self.shape)
        np.add.at(out, (self.row_indices(), self.indices), self.data)
        return out

    # ------------------------------------------------------------ ops
    def matvec(self, x: np.ndarray) -> np.ndarray:
        # segment-sum SpMV
        prod = self.data * x[self.indices]
        out = np.add.reduceat(
            np.concatenate([prod, [0.0]]),
            np.minimum(self.indptr[:-1], len(prod)),
        )[: self.nrows]
        # rows with zero nnz: reduceat duplicates next segment; fix by masking
        empty = self.indptr[:-1] == self.indptr[1:]
        out[empty] = 0.0
        return out

    def diagonal(self) -> np.ndarray:
        d = np.zeros(self.nrows)
        rows = self.row_indices()
        mask = self.indices == rows
        d[rows[mask]] = self.data[mask]
        return d

    def transpose(self) -> "CSR":
        return CSR.from_coo(
            self.indices.astype(np.int64),
            self.row_indices().astype(np.int32),
            self.data,
            (self.ncols, self.nrows),
        )

    def scale_rows(self, s: np.ndarray) -> "CSR":
        return CSR(self.shape, self.indptr.copy(), self.indices.copy(),
                   self.data * s[self.row_indices()])

    def prune(self, tol: float = 0.0) -> "CSR":
        keep = np.abs(self.data) > tol
        rows = self.row_indices()[keep]
        return CSR.from_coo(rows, self.indices[keep], self.data[keep], self.shape)

    def take_rows(self, rows: np.ndarray) -> "CSR":
        """Row-subset CSR: the given rows, in the given order (entries keep
        their in-row order, so downstream merge sums are reproducible)."""
        rows = np.asarray(rows, dtype=np.int64)
        lens = np.diff(self.indptr)[rows]
        total = int(lens.sum())
        indptr = np.concatenate(
            [[0], np.cumsum(lens)]
        ).astype(np.int64)
        if total == 0:
            return CSR((len(rows), self.ncols), indptr,
                       np.zeros(0, dtype=np.int32), np.zeros(0))
        starts = self.indptr[rows]
        seg_off = np.concatenate([[0], np.cumsum(lens)[:-1]])
        flat = (
            np.repeat(starts, lens)
            + np.arange(total, dtype=np.int64)
            - np.repeat(seg_off, lens)
        )
        return CSR((len(rows), self.ncols), indptr,
                   self.indices[flat], self.data[flat])

    def matmat(self, other: "CSR") -> "CSR":
        """CSR x CSR, fully vectorized: expand every (i,j,v) of A against row
        j of B, then merge duplicates via from_coo's lexsort."""
        assert self.ncols == other.nrows, (self.shape, other.shape)
        A, B = self, other
        ai = A.row_indices()
        aj = A.indices.astype(np.int64)
        av = A.data
        b_len = np.diff(B.indptr)
        counts = b_len[aj]
        total = int(counts.sum())
        if total == 0:
            return CSR(
                (A.nrows, B.ncols),
                np.zeros(A.nrows + 1, dtype=np.int64),
                np.zeros(0, dtype=np.int32),
                np.zeros(0),
            )
        starts = B.indptr[aj]
        seg_off = np.concatenate([[0], np.cumsum(counts)[:-1]])
        flat = (
            np.repeat(starts, counts)
            + np.arange(total, dtype=np.int64)
            - np.repeat(seg_off, counts)
        )
        rows = np.repeat(ai, counts)
        cols = B.indices[flat].astype(np.int64)
        vals = np.repeat(av, counts) * B.data[flat]
        return CSR.from_coo(rows, cols, vals, (A.nrows, B.ncols))
