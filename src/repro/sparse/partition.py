"""Row partitioning of sparse matrices + communication-pattern extraction.

This is the bridge from the workload (a sparse matrix) to the paper's
collective: in a distributed SpMV y = A x with block row partition, process
``p`` owns rows/vector entries [off[p], off[p+1]) and must *receive* x-values
for every nonzero column outside its block — exactly a CommPattern over
globally-indexed values (column index = global value index).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import numpy as np

from ..core.plan import CommPattern
from .csr import CSR


def block_offsets(n: int, n_procs: int) -> np.ndarray:
    """Balanced contiguous row offsets, len n_procs+1."""
    base, rem = divmod(n, n_procs)
    sizes = np.full(n_procs, base, dtype=np.int64)
    sizes[:rem] += 1
    return np.concatenate([[0], np.cumsum(sizes)])


@dataclass
class PartitionedCSR:
    """A row-partitioned CSR: per-process local blocks split into on-process
    (columns within the block) and off-process (ghost) parts, Hypre-style."""

    n_procs: int
    offsets: np.ndarray            # [P+1] row/col ownership
    local: List[CSR]               # per-proc on-process block (local cols)
    ghost: List[CSR]               # per-proc off-process block (ghost cols)
    needs: List[np.ndarray]        # per-proc sorted unique off-proc columns
    pattern: CommPattern

    @property
    def shape(self):
        n = int(self.offsets[-1])
        return (n, n)


def partition_csr(A: CSR, n_procs: int) -> PartitionedCSR:
    assert A.nrows == A.ncols, "square matrices only (SpMV exchange)"
    off = block_offsets(A.nrows, n_procs)
    local, ghost, needs = [], [], []
    for p in range(n_procs):
        lo, hi = int(off[p]), int(off[p + 1])
        sl = slice(int(A.indptr[lo]), int(A.indptr[hi]))
        cols = A.indices[sl].astype(np.int64)
        vals = A.data[sl]
        rows = (
            np.repeat(np.arange(hi - lo, dtype=np.int64),
                      np.diff(A.indptr[lo:hi + 1]))
        )
        on = (cols >= lo) & (cols < hi)
        loc = CSR.from_coo(rows[on], cols[on] - lo, vals[on],
                           (hi - lo, hi - lo))
        ghost_cols_global = cols[~on]
        uniq = np.unique(ghost_cols_global)
        gmap = {int(g): k for k, g in enumerate(uniq)}
        gcols = np.array(
            [gmap[int(c)] for c in ghost_cols_global], dtype=np.int64
        )
        gh = CSR.from_coo(rows[~on], gcols, vals[~on], (hi - lo, len(uniq)))
        local.append(loc)
        ghost.append(gh)
        needs.append(uniq)
    pattern = CommPattern.from_block_partition(needs, off)
    return PartitionedCSR(n_procs, off, local, ghost, needs, pattern)


def distributed_spmv_numpy(
    part: PartitionedCSR, plan, x: np.ndarray
) -> np.ndarray:
    """Host-oracle distributed SpMV using a CommPlan for the halo exchange."""
    xs = [
        x[int(part.offsets[p]): int(part.offsets[p + 1])]
        for p in range(part.n_procs)
    ]
    ghosts = plan.execute_numpy(xs)
    ys = []
    for p in range(part.n_procs):
        y = part.local[p].matvec(xs[p])
        if part.ghost[p].ncols:
            y = y + part.ghost[p].matvec(ghosts[p])
        ys.append(y)
    return np.concatenate(ys)
