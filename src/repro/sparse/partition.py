"""Row partitioning of sparse matrices + communication-pattern extraction.

This is the bridge from the workload (a sparse matrix) to the paper's
collective: in a distributed SpMV y = A x with block row partition, process
``p`` owns rows/vector entries [off[p], off[p+1]) and must *receive* x-values
for every nonzero column outside its block — exactly a CommPattern over
globally-indexed values (column index = global value index).

Square operators (:func:`partition_csr`) and rectangular ones
(:func:`partition_rect_csr` — AMG restriction/prolongation, whose row and
column ownerships differ) share the same machinery; the pattern is always
over the *input* (column) vector.  The device-resident ELL form and the
device SpMV live in :mod:`repro.sparse.device`.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import numpy as np

from ..core.plan import CommPattern
from .csr import CSR


def block_offsets(n: int, n_procs: int) -> np.ndarray:
    """Balanced contiguous row offsets, len n_procs+1."""
    base, rem = divmod(n, n_procs)
    sizes = np.full(n_procs, base, dtype=np.int64)
    sizes[:rem] += 1
    return np.concatenate([[0], np.cumsum(sizes)])


@dataclass
class PartitionedCSR:
    """A partitioned CSR: per-process row blocks split into on-process
    (columns within the owned column block) and off-process (ghost) parts,
    Hypre-style.  ``offsets`` is row ownership; ``col_offsets`` is input
    vector ownership (identical for square SpMV operators)."""

    n_procs: int
    offsets: np.ndarray            # [P+1] row ownership
    col_offsets: np.ndarray        # [P+1] column / input-vector ownership
    local: List[CSR]               # per-proc on-process block (local cols)
    ghost: List[CSR]               # per-proc off-process block (ghost cols)
    needs: List[np.ndarray]        # per-proc sorted unique off-proc columns
    pattern: CommPattern

    @property
    def shape(self):
        return (int(self.offsets[-1]), int(self.col_offsets[-1]))


def split_rows(A: CSR, row_offsets: np.ndarray) -> List[CSR]:
    """Cut a CSR into contiguous row blocks that keep GLOBAL column indices.

    This is the on-rank storage of a block row distribution (Hypre's
    ParCSR before the local/ghost split): block ``p`` holds global rows
    [row_offsets[p], row_offsets[p+1]) as local rows 0..m_p-1.
    """
    row_offsets = np.asarray(row_offsets, dtype=np.int64)
    assert int(row_offsets[-1]) == A.nrows, (row_offsets[-1], A.nrows)
    blocks = []
    for p in range(len(row_offsets) - 1):
        rlo, rhi = int(row_offsets[p]), int(row_offsets[p + 1])
        sl = slice(int(A.indptr[rlo]), int(A.indptr[rhi]))
        blocks.append(
            CSR(
                (rhi - rlo, A.ncols),
                A.indptr[rlo:rhi + 1] - A.indptr[rlo],
                A.indices[sl].copy(),
                A.data[sl].copy(),
            )
        )
    return blocks


def stack_blocks(blocks: List[CSR], ncols: int | None = None) -> CSR:
    """Vertically stack row blocks (global columns) back into one CSR.

    The inverse of :func:`split_rows`; used to validate distributed setup
    products against their host counterparts.
    """
    ncols = int(blocks[0].ncols if ncols is None else ncols)
    indptrs = [np.asarray(b.indptr, dtype=np.int64) for b in blocks]
    offs = np.concatenate([[0], np.cumsum([ip[-1] for ip in indptrs])])
    indptr = np.concatenate(
        [[0]] + [ip[1:] + off for ip, off in zip(indptrs, offs)]
    ).astype(np.int64)
    return CSR(
        (int(sum(b.nrows for b in blocks)), ncols),
        indptr,
        np.concatenate([b.indices for b in blocks]).astype(np.int32)
        if indptr[-1] else np.zeros(0, dtype=np.int32),
        np.concatenate([b.data for b in blocks])
        if indptr[-1] else np.zeros(0),
    )


def partitioned_from_blocks(
    blocks: List[CSR], row_offsets: np.ndarray, col_offsets: np.ndarray
) -> PartitionedCSR:
    """Build a :class:`PartitionedCSR` from per-rank row blocks directly.

    The block form (global column indices, as produced by distributed setup
    or :func:`split_rows`) is split into on-process / ghost parts without
    ever assembling the global operator — the entry point that keeps the
    distributed AMG setup's products device-bound end to end.
    """
    row_offsets = np.asarray(row_offsets, dtype=np.int64)
    col_offsets = np.asarray(col_offsets, dtype=np.int64)
    n_procs = len(blocks)
    assert len(row_offsets) == n_procs + 1
    assert len(col_offsets) == n_procs + 1
    local, ghost, needs = [], [], []
    for p, blk in enumerate(blocks):
        assert blk.nrows == int(row_offsets[p + 1] - row_offsets[p])
        clo, chi = int(col_offsets[p]), int(col_offsets[p + 1])
        rows = blk.row_indices()
        cols = blk.indices.astype(np.int64)
        vals = blk.data
        on = (cols >= clo) & (cols < chi)
        loc = CSR.from_coo(rows[on], cols[on] - clo, vals[on],
                           (blk.nrows, chi - clo))
        uniq = np.unique(cols[~on])
        gcols = np.searchsorted(uniq, cols[~on])
        gh = CSR.from_coo(rows[~on], gcols, vals[~on], (blk.nrows, len(uniq)))
        local.append(loc)
        ghost.append(gh)
        needs.append(uniq)
    pattern = CommPattern.from_block_partition(needs, col_offsets)
    return PartitionedCSR(
        n_procs, row_offsets, col_offsets, local, ghost, needs, pattern
    )


def partitioned_to_global(part: PartitionedCSR) -> CSR:
    """Reassemble the global CSR from a :class:`PartitionedCSR`.

    The inverse of :func:`partition_rect_csr`: merges each rank's local
    (column-shifted back by ``col_offsets[p]``) and ghost (columns mapped
    back through ``needs[p]``) blocks and stacks the row blocks.  Values
    are carried bit-exactly; used by the elastic path to repartition a
    hierarchy that was built distributed (``setup_partitioned``) and so
    never had a global operator to begin with.
    """
    blocks: List[CSR] = []
    for p in range(part.n_procs):
        clo = int(part.col_offsets[p])
        loc, gh = part.local[p], part.ghost[p]
        rows = np.concatenate([loc.row_indices(), gh.row_indices()])
        cols = np.concatenate([
            loc.indices.astype(np.int64) + clo,
            part.needs[p][gh.indices.astype(np.int64)]
            if len(gh.indices) else np.zeros(0, dtype=np.int64),
        ])
        vals = np.concatenate([loc.data, gh.data])
        blocks.append(
            CSR.from_coo(rows, cols, vals,
                         (loc.nrows, int(part.col_offsets[-1])))
        )
    return stack_blocks(blocks, ncols=int(part.col_offsets[-1]))


def partition_rect_csr(
    A: CSR, row_offsets: np.ndarray, col_offsets: np.ndarray
) -> PartitionedCSR:
    """Partition a (possibly rectangular) CSR operator.

    Process ``p`` owns output rows [row_offsets[p], row_offsets[p+1]) and
    input vector entries [col_offsets[p], col_offsets[p+1]).  The returned
    pattern describes the halo exchange of input values.
    """
    row_offsets = np.asarray(row_offsets, dtype=np.int64)
    col_offsets = np.asarray(col_offsets, dtype=np.int64)
    n_procs = len(row_offsets) - 1
    assert len(col_offsets) == n_procs + 1
    assert int(col_offsets[-1]) == A.ncols, (col_offsets[-1], A.ncols)
    return partitioned_from_blocks(
        split_rows(A, row_offsets), row_offsets, col_offsets
    )


def partition_csr(A: CSR, n_procs: int) -> PartitionedCSR:
    """Square-operator partition: rows and input entries share one blocking."""
    assert A.nrows == A.ncols, "use partition_rect_csr for rectangular ops"
    off = block_offsets(A.nrows, n_procs)
    return partition_rect_csr(A, off, off)


def distributed_spmv_numpy(
    part: PartitionedCSR, plan, x: np.ndarray
) -> np.ndarray:
    """Host-oracle distributed SpMV using a CommPlan for the halo exchange."""
    xs = [
        x[int(part.col_offsets[p]): int(part.col_offsets[p + 1])]
        for p in range(part.n_procs)
    ]
    ghosts = plan.execute_numpy(xs)
    ys = []
    for p in range(part.n_procs):
        y = part.local[p].matvec(xs[p])
        if part.ghost[p].ncols:
            y = y + part.ghost[p].matvec(ghosts[p])
        ys.append(y)
    return np.concatenate(ys)
