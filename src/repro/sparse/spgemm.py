"""Distributed SpGEMM building blocks: remote-row gather + local merge products.

The AMG setup phase's Galerkin triple product ``A_c = R @ A @ P`` is the
irregular-communication SpGEMM the paper targets in Hypre BoomerAMG: with a
block row distribution, a rank multiplying its local ``R`` rows references
``A`` (and then ``P``) rows owned elsewhere.  The remote rows are fetched by

1. *partner discovery* — ``core.dynexchange.SparseDynamicExchange.discover``
   (allreduce-on-counts, arXiv 2308.13869): owners learn who requests what;
2. a *metadata exchange* over the row-index space (row length + global nnz
   start per requested row), through a cached ``NeighborAlltoallV``;
3. the *payload exchange* over the global nnz-slot space ((column, value)
   pairs), through a second cached ``NeighborAlltoallV`` whose plan is keyed
   by pattern fingerprint in :class:`~repro.core.cache.PlanCache` — a
   repeated setup on the same grid re-plans nothing.

The local half is merge-based SpGEMM on CSR blocks
(:func:`spgemm_local`), and :func:`spgemm_rap` composes gather + multiply
into the full distributed ``R @ A @ P`` by coarse row blocks.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..core.cache import PlanCache, default_plan_cache
from ..core.costmodel import MachineParams, TPU_V5E
from ..core.dynexchange import DiscoveryStats, SparseDynamicExchange
from ..core.plan import CommPattern, Topology
from .csr import CSR
from .partition import stack_blocks


@dataclass
class RowGather:
    """Result of one distributed remote-row fetch.

    ``rows[p]`` holds the rows ``needs[p]`` (sorted global ids) with global
    column indices; the two patterns are the cached-collective keys of the
    metadata and payload exchanges, exposed so benchmarks can re-plan them
    under different strategies (standard vs aggregated setup exchange).
    """

    rows: List[CSR]
    needs: List[np.ndarray]
    row_pattern: CommPattern
    payload_pattern: CommPattern
    discovery: DiscoveryStats

    @property
    def total_rows(self) -> int:
        return int(sum(len(n) for n in self.needs))

    @property
    def total_values(self) -> int:
        return self.payload_pattern.total_ghosts()


def gather_remote_rows(
    blocks: Sequence[CSR],
    row_offsets: np.ndarray,
    needs: Sequence[np.ndarray],
    topo: Topology,
    cache: Optional[PlanCache] = None,
    strategy: str = "auto",
    value_bytes: int = 8,
    params: MachineParams = TPU_V5E,
) -> RowGather:
    """Fetch remote CSR rows of a block row-distributed operator.

    ``blocks[p]`` are rank ``p``'s rows (global columns), ``needs[p]`` the
    sorted unique global row ids it must fetch (all outside its own block).
    Both exchanges run through ``cache.collective`` so their plans are
    persistent across AMG levels and repeated setups.
    """
    row_offsets = np.asarray(row_offsets, dtype=np.int64)
    n_procs = len(blocks)
    cache = cache if cache is not None else default_plan_cache()
    needs = [np.asarray(n, dtype=np.int64) for n in needs]

    # 1. partner discovery (the dynamic part)
    row_pattern, disc = SparseDynamicExchange.discover(needs, row_offsets)
    meta_coll = cache.collective(
        row_pattern, topo, strategy, value_bytes=value_bytes, params=params
    )

    # 2. metadata exchange: (row length, global nnz start) per owned row.
    # Global nnz slots are contiguously block-partitioned by construction:
    # rank p owns slots [nnz_offsets[p], nnz_offsets[p+1]).
    nnz_offsets = np.concatenate(
        [[0], np.cumsum([b.nnz for b in blocks])]
    ).astype(np.int64)
    meta_local = [
        np.stack(
            [np.diff(b.indptr).astype(np.float64),
             (nnz_offsets[p] + b.indptr[:-1]).astype(np.float64)],
            axis=-1,
        )
        for p, b in enumerate(blocks)
    ]
    meta_ghost = meta_coll(meta_local)

    # 3. payload exchange over nnz slots: (column, value) pairs.
    needs_nnz: List[np.ndarray] = []
    row_lens: List[np.ndarray] = []
    for p in range(n_procs):
        lens = meta_ghost[p][:, 0].astype(np.int64)
        starts = meta_ghost[p][:, 1].astype(np.int64)
        row_lens.append(lens)
        total = int(lens.sum())
        if total == 0:
            needs_nnz.append(np.zeros(0, dtype=np.int64))
            continue
        seg_off = np.concatenate([[0], np.cumsum(lens)[:-1]])
        needs_nnz.append(
            np.repeat(starts, lens)
            + np.arange(total, dtype=np.int64)
            - np.repeat(seg_off, lens)
        )
    payload_pattern = CommPattern.from_block_partition(needs_nnz, nnz_offsets)
    payload_coll = cache.collective(
        payload_pattern, topo, strategy, value_bytes=value_bytes, params=params
    )
    payload_local = [
        np.stack([b.indices.astype(np.float64), b.data], axis=-1)
        for b in blocks
    ]
    payload_ghost = payload_coll(payload_local)

    ncols = int(blocks[0].ncols)
    rows: List[CSR] = []
    for p in range(n_procs):
        lens = row_lens[p]
        got = payload_ghost[p]
        indptr = np.concatenate([[0], np.cumsum(lens)]).astype(np.int64)
        rows.append(
            CSR(
                (len(needs[p]), ncols),
                indptr,
                got[:, 0].astype(np.int64).astype(np.int32),
                got[:, 1].copy(),
            )
        )
    return RowGather(rows, needs, row_pattern, payload_pattern, disc)


# ---------------------------------------------------------------------------
# local merge-based SpGEMM on row subsets
# ---------------------------------------------------------------------------


def merge_row_sets(
    ids_a: np.ndarray, rows_a: CSR, ids_b: np.ndarray, rows_b: CSR
) -> Tuple[np.ndarray, CSR]:
    """Merge two disjoint row subsets into one sorted-by-global-id subset."""
    ids = np.concatenate(
        [np.asarray(ids_a, dtype=np.int64), np.asarray(ids_b, dtype=np.int64)]
    )
    stacked = stack_blocks([rows_a, rows_b])
    order = np.argsort(ids, kind="stable")
    return ids[order], stacked.take_rows(order)


def spgemm_local(left: CSR, avail_ids: np.ndarray, avail: CSR) -> CSR:
    """Merge-based product of a local block against a row subset.

    ``left`` is an ``(m, N)`` block with global column indices; ``avail``
    holds rows ``avail_ids`` (sorted global ids) of the right operand, with
    the right operand's global columns.  Every column of ``left`` must be in
    ``avail_ids`` — i.e. the gather already fetched everything referenced.
    """
    avail_ids = np.asarray(avail_ids, dtype=np.int64)
    if left.nnz:
        pos = np.searchsorted(avail_ids, left.indices)
        pos_c = np.minimum(pos, max(len(avail_ids) - 1, 0))
        if len(avail_ids) == 0 or np.any(avail_ids[pos_c] != left.indices):
            missing = (
                left.indices[avail_ids[pos_c] != left.indices]
                if len(avail_ids) else left.indices
            )
            raise ValueError(
                f"spgemm_local: {len(np.unique(missing))} referenced rows "
                "missing from the gathered set"
            )
    else:
        pos = np.zeros(0, dtype=np.int64)
    remapped = CSR(
        (left.nrows, len(avail_ids)),
        left.indptr.copy(),
        pos.astype(np.int32),
        left.data,
    )
    return remapped.matmat(avail)


@dataclass
class RapResult:
    """Distributed Galerkin product output + its exchange accounting."""

    Ac_blocks: List[CSR]
    gather_A: RowGather
    gather_P: RowGather


def spgemm_rap(
    R_blocks: Sequence[CSR],
    A_blocks: Sequence[CSR],
    P_blocks: Sequence[CSR],
    fine_offsets: np.ndarray,
    topo: Topology,
    cache: Optional[PlanCache] = None,
    strategy: str = "auto",
    value_bytes: int = 8,
    params: MachineParams = TPU_V5E,
) -> RapResult:
    """Distributed ``A_c = (R @ A) @ P`` by coarse row blocks.

    Rank ``p`` owns the coarse rows matching its ``R`` block: it fetches the
    remote ``A`` rows referenced by its local ``R`` column indices, forms
    ``R_p @ A`` by merge-based SpGEMM, then fetches the remote ``P`` rows
    referenced by the intermediate product and completes ``A_c``'s block.
    No rank ever materializes a global operator.
    """
    fine_offsets = np.asarray(fine_offsets, dtype=np.int64)
    n_procs = len(R_blocks)
    cache = cache if cache is not None else default_plan_cache()

    def ghost_cols(blk: CSR, p: int) -> np.ndarray:
        lo, hi = int(fine_offsets[p]), int(fine_offsets[p + 1])
        cols = blk.indices.astype(np.int64)
        return np.unique(cols[(cols < lo) | (cols >= hi)])

    needs_A = [ghost_cols(R_blocks[p], p) for p in range(n_procs)]
    ga = gather_remote_rows(
        A_blocks, fine_offsets, needs_A, topo, cache,
        strategy=strategy, value_bytes=value_bytes, params=params,
    )
    RA_blocks: List[CSR] = []
    for p in range(n_procs):
        own_ids = np.arange(fine_offsets[p], fine_offsets[p + 1])
        avail_ids, avail = merge_row_sets(
            own_ids, A_blocks[p], ga.needs[p], ga.rows[p]
        )
        RA_blocks.append(spgemm_local(R_blocks[p], avail_ids, avail))

    needs_P = [ghost_cols(RA_blocks[p], p) for p in range(n_procs)]
    gp = gather_remote_rows(
        P_blocks, fine_offsets, needs_P, topo, cache,
        strategy=strategy, value_bytes=value_bytes, params=params,
    )
    Ac_blocks: List[CSR] = []
    for p in range(n_procs):
        own_ids = np.arange(fine_offsets[p], fine_offsets[p + 1])
        avail_ids, avail = merge_row_sets(
            own_ids, P_blocks[p], gp.needs[p], gp.rows[p]
        )
        Ac_blocks.append(spgemm_local(RA_blocks[p], avail_ids, avail))
    return RapResult(Ac_blocks, ga, gp)
