"""Device-resident distributed SpMV: padded ELL blocks + plan executor.

This is the device half of the paper's workload: the persistent neighborhood
collective (``core.collectives``) delivers ghost values and the ``spmv_ell``
kernels multiply the per-device local and ghost blocks.  Everything is
static-shape SPMD: each process's blocks are padded to uniform sizes so one
``shard_map`` program serves all devices.

Two device layouts, selected per operator by VMEM footprint
(:func:`select_spmv_kernel`):

* **flat** (:class:`DeviceEll`): ``cols``/``vals`` ``[P, row_pad, K]`` with
  padding entries pointing at a sentinel slot (index ``in_pad`` resp.
  ``ghost_pad``) that the per-device program materializes as an appended
  zero.  The whole per-device x (local + ghost) is VMEM-resident in the
  kernel — right for coarse levels and small blocks.

* **column-blocked** (:class:`DeviceEllBlocked`): each row's nonzeros are
  reordered into column buckets of ``block_cols`` x entries; local columns
  fill the leading buckets, ghost columns the *trailing* buckets, so the
  halo-dependent partial products land in the last accumulation steps of
  the kernel's sequential column-bucket grid dim.  Per-bucket nonzero
  widths (``bucket_K``) are padded to one uniform K so a single BlockSpec
  serves every grid step; padding entries are (in-bucket col 0, val 0.0).
  VMEM residency is then independent of the x length — the production path
  for paper-scale fine levels.

Vectors are ``[P, pad]`` as produced by :func:`pack_vector` /
``core.collectives.pack_local_values`` — zero-padded per block.

Entry points:

* :func:`partitioned_to_ell` / :func:`partitioned_to_ell_blocked` —
  ``PartitionedCSR ->`` device form conversions;
* :func:`select_spmv_kernel` — modeled-VMEM flat-vs-blocked choice
  (threshold overridable via ``REPRO_SPMV_VMEM_LIMIT_BYTES`` or argument);
* :func:`make_distributed_spmv` — build ``fn(x [P, in_pad]) -> y [P,
  row_pad]`` composing exchange + ELL matvec(s) for either layout.  With
  ``overlap=True`` the schedule is split: the exchange is issued first,
  the local buckets (which do not depend on it) accumulate while the
  ``NeighborAlltoallV`` rounds are in flight, and a second carried-output
  kernel consumes the ghost buckets — structured so XLA's async collective
  latency hiding can actually overlap the two;
* :func:`select_spmv_overlap` — cost-model overlap on/off choice
  (:class:`OverlapSelection`), the Section-5-style companion of
  :func:`select_spmv_kernel`;
* :func:`row_block_bucket_map` — per-row-block live-bucket lists for the
  bucket-skipping kernel (shared by the fused and overlapped schedules);
* :func:`distributed_spmv` — one-shot convenience on a numpy vector.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Callable, Optional, Union

import numpy as np

from ..kernels.spmv_ell import DEFAULT_BLOCK_COLS, DEFAULT_BLOCK_ROWS
from .csr import CSR
from .partition import PartitionedCSR


@dataclass
class DeviceEll:
    """Stacked per-process padded-ELL blocks of a partitioned operator."""

    n_procs: int
    row_pad: int     # uniform padded rows per process (== output vector pad)
    in_pad: int      # uniform padded input-vector block size
    ghost_pad: int   # uniform padded ghost count (0 => no exchange needed)
    local_cols: np.ndarray   # [P, row_pad, Kl] int32; pad -> in_pad sentinel
    local_vals: np.ndarray   # [P, row_pad, Kl]
    ghost_cols: np.ndarray   # [P, row_pad, Kg] int32; pad -> ghost_pad
    ghost_vals: np.ndarray   # [P, row_pad, Kg]


def _ell_block(
    m: CSR, row_pad: int, K: int, pad_col: int, dtype
) -> tuple:
    cols = np.full((row_pad, K), pad_col, dtype=np.int32)
    vals = np.zeros((row_pad, K), dtype=dtype)
    if m.nnz:
        rows = m.row_indices()
        pos = np.arange(m.nnz, dtype=np.int64) - m.indptr[rows]
        cols[rows, pos] = m.indices
        vals[rows, pos] = m.data
    return cols, vals


def partitioned_to_ell(part: PartitionedCSR, dtype=np.float64) -> DeviceEll:
    """Convert each process's local/ghost CSR blocks to uniformly padded ELL.

    Row padding matches the owning vector layout (max block size), so the
    output of the matvec IS the next op's input vector — no repacking
    between levels of a solve.
    """
    P_ = part.n_procs
    row_pad = int(np.diff(part.offsets).max())
    in_pad = int(np.diff(part.col_offsets).max())
    ghost_pad = int(max((len(n) for n in part.needs), default=0))
    Kl = max(
        max((int(np.diff(m.indptr).max()) for m in part.local if m.nnz),
            default=0), 1,
    )
    Kg = max(
        max((int(np.diff(m.indptr).max()) for m in part.ghost if m.nnz),
            default=0), 1,
    )
    lc = np.empty((P_, row_pad, Kl), dtype=np.int32)
    lv = np.empty((P_, row_pad, Kl), dtype=dtype)
    gc = np.empty((P_, row_pad, Kg), dtype=np.int32)
    gv = np.empty((P_, row_pad, Kg), dtype=dtype)
    for p in range(P_):
        lc[p], lv[p] = _ell_block(part.local[p], row_pad, Kl, in_pad, dtype)
        gc[p], gv[p] = _ell_block(part.ghost[p], row_pad, Kg, ghost_pad, dtype)
    return DeviceEll(P_, row_pad, in_pad, ghost_pad, lc, lv, gc, gv)


@dataclass
class DeviceEllBlocked:
    """Column-bucketed padded-ELL blocks for the blocked SpMV kernel.

    One structure covers local *and* ghost columns: the per-device gather
    space is ``[local values | zero-fill to bucket edge | ghost values |
    zero-fill]`` of length ``n_buckets * block_cols``; bucket ``j`` of
    ``cols``/``vals`` (columns [j*K, (j+1)*K)) holds in-bucket indices into
    x slice ``j``.  Ghost columns occupy the trailing ``n_ghost_buckets``
    buckets, so halo-dependent work runs in the kernel's last accumulation
    steps.
    """

    n_procs: int
    row_pad: int     # uniform padded rows per process (== output vector pad)
    in_pad: int      # uniform padded input-vector block size
    ghost_pad: int   # uniform padded ghost count (0 => no exchange needed)
    block_cols: int
    n_local_buckets: int
    n_ghost_buckets: int
    K: int                   # uniform per-bucket padded width (max bucket_K)
    cols: np.ndarray         # [P, row_pad, n_buckets*K] int32 in-bucket idx
    vals: np.ndarray         # [P, row_pad, n_buckets*K]
    bucket_K: np.ndarray     # [n_buckets] max nnz of each bucket pre-padding

    @property
    def n_buckets(self) -> int:
        return self.n_local_buckets + self.n_ghost_buckets

    @property
    def x_len(self) -> int:
        return self.n_buckets * self.block_cols


def _bucket_positions(rows: np.ndarray, buckets: np.ndarray, n_buckets: int):
    """Occurrence index of each entry within its (row, bucket) group."""
    key = rows.astype(np.int64) * n_buckets + buckets
    order = np.argsort(key, kind="stable")
    ks = key[order]
    new = np.concatenate([[True], ks[1:] != ks[:-1]])
    starts = np.flatnonzero(new)
    group = np.cumsum(new) - 1
    pos_sorted = np.arange(len(key)) - starts[group]
    pos = np.empty(len(key), dtype=np.int64)
    pos[order] = pos_sorted
    return pos


def _bucketed(m: CSR, bc: int, bucket0: int):
    """CSR block entries as (rows, buckets, in-bucket cols, vals)."""
    if not m.nnz:
        z = np.zeros(0, dtype=np.int64)
        return z, z, z, np.zeros(0)
    rows = m.row_indices().astype(np.int64)
    cols = m.indices.astype(np.int64)
    return rows, bucket0 + cols // bc, cols % bc, m.data


def partitioned_to_ell_blocked(
    part: PartitionedCSR,
    block_cols: int = DEFAULT_BLOCK_COLS,
    dtype=np.float64,
) -> DeviceEllBlocked:
    """Convert a partition to the column-bucketed blocked-ELL device form.

    Row padding matches :func:`partitioned_to_ell` so the two layouts are
    interchangeable level by level.  Each row's nonzeros are reordered into
    column buckets (local buckets first, ghost buckets trailing); per-bucket
    widths are recorded in ``bucket_K`` and padded to their max so one
    BlockSpec serves all grid steps of the blocked kernel.
    """
    P_ = part.n_procs
    bc = int(block_cols)
    assert bc > 0, bc
    row_pad = int(np.diff(part.offsets).max())
    in_pad = int(np.diff(part.col_offsets).max())
    ghost_pad = int(max((len(n) for n in part.needs), default=0))
    Cl = max(-(-in_pad // bc), 1)
    Cg = -(-ghost_pad // bc)
    C = Cl + Cg

    entries = []
    bucket_K = np.zeros(C, dtype=np.int64)
    for p in range(P_):
        rows_l, b_l, c_l, v_l = _bucketed(part.local[p], bc, 0)
        rows_g, b_g, c_g, v_g = _bucketed(part.ghost[p], bc, Cl)
        rows = np.concatenate([rows_l, rows_g])
        buckets = np.concatenate([b_l, b_g])
        incols = np.concatenate([c_l, c_g])
        vals = np.concatenate([v_l, v_g])
        entries.append((rows, buckets, incols, vals))
        if len(rows):
            cnt = np.bincount(rows * C + buckets, minlength=row_pad * C)
            bucket_K = np.maximum(bucket_K, cnt.reshape(row_pad, C).max(0))
    K = max(int(bucket_K.max()), 1)

    cols = np.zeros((P_, row_pad, C * K), dtype=np.int32)
    vals_out = np.zeros((P_, row_pad, C * K), dtype=dtype)
    for p, (rows, buckets, incols, vals) in enumerate(entries):
        if not len(rows):
            continue
        pos = _bucket_positions(rows, buckets, C)
        slot = buckets * K + pos
        cols[p, rows, slot] = incols
        vals_out[p, rows, slot] = vals
    return DeviceEllBlocked(
        P_, row_pad, in_pad, ghost_pad, bc, Cl, Cg, K, cols, vals_out,
        bucket_K,
    )


# --------------------------------------------------------------- selection
#: Usable VMEM per TPU core; the working budget defaults to half of it
#: (double buffering + headroom for the rest of the fused program).
VMEM_BYTES_PER_CORE = 16 * 2 ** 20
_IDX_BYTES = 4  # int32 column indices


def default_spmv_vmem_limit() -> int:
    """Flat-vs-blocked threshold; ``REPRO_SPMV_VMEM_LIMIT_BYTES`` overrides."""
    env = os.environ.get("REPRO_SPMV_VMEM_LIMIT_BYTES")
    return int(env) if env else VMEM_BYTES_PER_CORE // 2


def spmv_flat_vmem_bytes(
    *,
    in_pad: int,
    ghost_pad: int,
    k_local: int,
    k_ghost: int,
    value_bytes: int = 8,
    rows: Optional[int] = None,
    block_rows: int = DEFAULT_BLOCK_ROWS,
) -> int:
    """Modeled per-device VMEM residency of the flat SpMV path.

    The flat path is two kernels (local + ghost matvec); this budget sums
    both deliberately — inside the fused jitted program XLA is free to
    schedule them concurrently (exchange/compute overlap is the point of
    the design), so near the threshold the conservative assumption is that
    both x vectors and both double-buffered cols/vals streams are resident
    at once.  ``rows`` clamps the row block exactly like the kernel does
    (``min(block_rows, R)``).
    """
    br = min(int(block_rows), int(rows)) if rows else int(block_rows)
    x_bytes = (in_pad + 1 + ghost_pad + (1 if ghost_pad else 0)) * value_bytes
    stream = 2 * br * (k_local + k_ghost) * (_IDX_BYTES + value_bytes)
    out = br * value_bytes
    return int(x_bytes + stream + out)


def spmv_blocked_vmem_bytes(
    *,
    bucket_k: int,
    value_bytes: int = 8,
    rows: Optional[int] = None,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    block_cols: int = DEFAULT_BLOCK_COLS,
) -> int:
    """Modeled per-device VMEM residency of the column-blocked SpMV path:
    one x bucket + one cols/vals bucket block, double-buffered — independent
    of the x length."""
    br = min(int(block_rows), int(rows)) if rows else int(block_rows)
    bc = int(block_cols)
    x_bytes = 2 * bc * value_bytes
    stream = 2 * br * bucket_k * (_IDX_BYTES + value_bytes)
    out = br * value_bytes
    return int(x_bytes + stream + out)


@dataclass(frozen=True)
class KernelSelection:
    """The flat-vs-blocked choice for one operator, recorded alongside the
    plan's Section-5 transport choice so both selections are inspectable."""

    variant: str            # "flat" | "blocked"
    flat_bytes: int         # modeled flat footprint
    blocked_bytes: int      # modeled blocked footprint (bucket-K upper bound)
    limit_bytes: int        # threshold the choice was made against
    forced: bool = False    # True when the variant was pinned, not selected

    def __str__(self) -> str:
        how = "forced" if self.forced else "auto"
        return (
            f"kernel={self.variant} ({how}) "
            f"flat={self.flat_bytes / 2**10:.0f}KiB "
            f"blocked={self.blocked_bytes / 2**10:.0f}KiB "
            f"limit={self.limit_bytes / 2**10:.0f}KiB"
        )


def _ell_widths(part: PartitionedCSR) -> tuple:
    kl = max(
        max((int(np.diff(m.indptr).max()) for m in part.local if m.nnz),
            default=0), 1,
    )
    kg = max(
        max((int(np.diff(m.indptr).max()) for m in part.ghost if m.nnz),
            default=0), 1,
    )
    return kl, kg


def select_spmv_kernel(
    part: PartitionedCSR,
    *,
    variant: str = "auto",
    vmem_limit_bytes: Optional[int] = None,
    value_bytes: int = 8,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    block_cols: int = DEFAULT_BLOCK_COLS,
) -> KernelSelection:
    """Choose the SpMV device layout for one partitioned operator.

    ``variant="auto"`` compares the modeled flat footprint (whole x
    VMEM-resident) against the threshold and falls over to the blocked
    kernel when it does not fit; ``"flat"``/``"blocked"`` pin the choice
    (recorded as forced).  The blocked estimate uses the max row width as a
    bucket-K upper bound — packing can only shrink it.
    """
    limit = (default_spmv_vmem_limit()
             if vmem_limit_bytes is None else int(vmem_limit_bytes))
    row_pad = int(np.diff(part.offsets).max())
    in_pad = int(np.diff(part.col_offsets).max())
    ghost_pad = int(max((len(n) for n in part.needs), default=0))
    kl, kg = _ell_widths(part)
    flat = spmv_flat_vmem_bytes(
        in_pad=in_pad, ghost_pad=ghost_pad, k_local=kl, k_ghost=kg,
        value_bytes=value_bytes, rows=row_pad, block_rows=block_rows,
    )
    blocked = spmv_blocked_vmem_bytes(
        bucket_k=max(kl, kg), value_bytes=value_bytes,
        rows=row_pad, block_rows=block_rows, block_cols=block_cols,
    )
    if variant == "auto":
        return KernelSelection(
            "flat" if flat <= limit else "blocked", flat, blocked, limit
        )
    if variant not in ("flat", "blocked"):
        raise ValueError(f"unknown spmv variant {variant!r}")
    return KernelSelection(variant, flat, blocked, limit, forced=True)


def row_block_bucket_map(
    ell: DeviceEllBlocked,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    bucket_lo: int = 0,
    bucket_hi: Optional[int] = None,
) -> tuple:
    """Per-row-block live-bucket lists for the bucket-skipping kernel.

    Returns ``(lists [P, NRB, M] int32, counts [P, NRB] int32)`` where row
    block ``i`` of process ``p`` touches exactly the buckets
    ``lists[p, i, :counts[p, i]]`` (absolute bucket ids, ascending) within
    the window [bucket_lo, bucket_hi).  ``M`` is the global max count
    (min 1); padding entries hold ``bucket_lo`` and are masked by the
    kernel.  The row blocking mirrors the kernel's
    (``min(block_rows, row_pad)`` with a padded trailing block), so the
    lists line up with its grid.  The overlap schedule builds one map per
    phase from the same call with the phase's bucket window.
    """
    C, K = ell.n_buckets, ell.K
    lo = int(bucket_lo)
    hi = C if bucket_hi is None else int(bucket_hi)
    assert 0 <= lo < hi <= C, (lo, hi, C)
    R = ell.row_pad
    br = min(int(block_rows), R)
    pad = (-R) % br
    nrb = (R + pad) // br
    W = hi - lo
    live = (ell.vals.reshape(ell.n_procs, R, C, K) != 0).any(-1)[:, :, lo:hi]
    if pad:
        live = np.concatenate(
            [live, np.zeros((ell.n_procs, pad, W), bool)], axis=1
        )
    live_rb = live.reshape(ell.n_procs, nrb, br, W).any(2)   # [P, NRB, W]
    counts = live_rb.sum(-1).astype(np.int32)
    M = max(int(counts.max()), 1)
    lists = np.full((ell.n_procs, nrb, M), lo, dtype=np.int32)
    for p in range(ell.n_procs):
        for rb in range(nrb):
            idx = np.flatnonzero(live_rb[p, rb])
            lists[p, rb, : len(idx)] = idx + lo
    return lists, counts


@dataclass(frozen=True)
class OverlapSelection:
    """The exchange/compute-overlap choice for one operator, recorded on
    ``DistOp`` next to the Section-5 transport and flat-vs-blocked kernel
    selections.  Times are cost-model estimates unless the caller passed a
    measured exchange time."""

    mode: str              # "on" | "off"
    exchange_s: float      # exchange time tx (full collective)
    local_s: float         # local-bucket compute time tl
    exposed_s: float       # exchange time left exposed by this choice
    hidden_frac: float     # fraction of tx hidden behind local compute
    overhead_s: float      # split cost (carried-y traffic + extra launch)
    forced: bool = False   # True when the mode was pinned, not selected

    def __str__(self) -> str:
        how = "forced" if self.forced else "auto"
        return (
            f"overlap={self.mode} ({how}) "
            f"tx={self.exchange_s * 1e6:.1f}us "
            f"local={self.local_s * 1e6:.1f}us "
            f"exposed={self.exposed_s * 1e6:.1f}us "
            f"hidden={self.hidden_frac:.0%} "
            f"overhead={self.overhead_s * 1e6:.1f}us"
        )


def overlap_decision(
    exchange_s: float,
    local_s: float,
    *,
    rows: int,
    value_bytes: int = 8,
    mode: str = "auto",
    has_ghost: bool = True,
) -> OverlapSelection:
    """Decide overlap on/off from an exchange time and a local compute time.

    The split schedule hides ``min(tx, tl)`` of the exchange but pays
    ``overlap_split_overhead`` (the carried output makes one extra HBM
    round trip, plus a kernel launch).  ``auto`` turns overlap on iff the
    hidden time beats that overhead; a fully local operator (no ghosts)
    has nothing to hide and is always ``off``.
    """
    from ..core.costmodel import (
        exposed_exchange_seconds,
        hidden_fraction,
        overlap_split_overhead,
    )

    tx, tl = float(exchange_s), float(local_s)
    overhead = overlap_split_overhead(rows, value_bytes=value_bytes)
    if mode not in ("auto", "on", "off"):
        raise ValueError(f"unknown overlap mode {mode!r}")
    if mode == "auto":
        on = has_ghost and (tx - exposed_exchange_seconds(tx, tl)) > overhead
    else:
        on = mode == "on" and has_ghost
    if on:
        return OverlapSelection(
            "on", tx, tl, exposed_exchange_seconds(tx, tl),
            hidden_fraction(tx, tl), overhead, forced=(mode != "auto"),
        )
    return OverlapSelection(
        "off", tx, tl, tx if has_ghost else 0.0, 0.0, overhead,
        forced=(mode != "auto"),
    )


def select_spmv_overlap(
    part: PartitionedCSR,
    exchange_seconds: float,
    *,
    mode: str = "auto",
    value_bytes: int = 8,
) -> OverlapSelection:
    """Choose the overlap schedule for one partitioned operator.

    ``exchange_seconds`` is the modeled (``core.costmodel.plan_time``) or
    measured full-exchange time; the local compute time comes from the
    roofline compute model over the worst per-process local block.
    """
    from ..core.costmodel import spmv_compute_time

    row_pad = int(np.diff(part.offsets).max())
    in_pad = int(np.diff(part.col_offsets).max())
    ghost_pad = int(max((len(n) for n in part.needs), default=0))
    nnz_local = max((m.nnz for m in part.local), default=0)
    local_s = spmv_compute_time(
        nnz_local, row_pad, in_pad, value_bytes=value_bytes
    )
    return overlap_decision(
        float(exchange_seconds), local_s, rows=row_pad,
        value_bytes=value_bytes, mode=mode, has_ghost=ghost_pad > 0,
    )


def partitioned_to_device(
    part: PartitionedCSR,
    selection: KernelSelection,
    dtype=np.float64,
    block_cols: int = DEFAULT_BLOCK_COLS,
) -> Union[DeviceEll, "DeviceEllBlocked"]:
    """Convert a partition to the device form the selection calls for."""
    if selection.variant == "blocked":
        return partitioned_to_ell_blocked(part, block_cols, dtype)
    return partitioned_to_ell(part, dtype)


def pack_vector(offsets: np.ndarray, pad: int, x: np.ndarray) -> np.ndarray:
    """Global vector -> [P, pad] block layout (zero padding)."""
    P_ = len(offsets) - 1
    out = np.zeros((P_, pad), dtype=x.dtype)
    for p in range(P_):
        lo, hi = int(offsets[p]), int(offsets[p + 1])
        out[p, : hi - lo] = x[lo:hi]
    return out


def unpack_vector(offsets: np.ndarray, y: np.ndarray) -> np.ndarray:
    """[P, pad] block layout -> global vector."""
    P_ = len(offsets) - 1
    return np.concatenate(
        [
            np.asarray(y[p, : int(offsets[p + 1]) - int(offsets[p])])
            for p in range(P_)
        ]
    )


def make_distributed_spmv(
    ell: Union[DeviceEll, DeviceEllBlocked],
    mesh,
    axis_name: str,
    exchange: Optional[Callable] = None,
    overlap: bool = False,
) -> Callable:
    """Build the device distributed SpMV ``fn(x [P, in_pad]) -> [P, row_pad]``.

    ``exchange`` is a bound plan executor (``NeighborAlltoallV.bind`` /
    ``PlanCache.executor``) mapping ``[P, in_pad, 1] -> [P, ghost_pad, 1]``;
    required unless ``ell.ghost_pad == 0`` (fully local operator).  The
    matvecs go through ``kernels.spmv_ell.ops`` and therefore dispatch to
    the Pallas kernels on TPU and the jnp references on CPU.  A
    :class:`DeviceEllBlocked` selects the column-blocked kernel: local and
    ghost values are concatenated into the bucketed gather space and one
    accumulating kernel covers both (ghost buckets trail, so halo-dependent
    work lands in the last accumulation steps).

    ``overlap=True`` splits the schedule into (local matvec || exchange)
    followed by a carried-output ghost matvec: the exchange is issued
    first, the local phase takes no data from it, and only the final phase
    consumes the ghost values — the dependence structure XLA's async
    collective scheduling needs to hide the ``NeighborAlltoallV`` rounds
    behind the local compute.  Both phases accumulate buckets in the same
    ascending order as the fused schedule.  No-ghost operators ignore the
    flag (there is nothing to overlap).
    """
    if isinstance(ell, DeviceEllBlocked):
        return _make_distributed_spmv_blocked(
            ell, mesh, axis_name, exchange, overlap
        )

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..compat import shard_map
    from ..kernels.spmv_ell.ops import spmv

    if ell.ghost_pad and exchange is None:
        raise ValueError("operator has ghost columns: exchange required")

    spec = P(axis_name)
    consts = [
        jax.device_put(a, NamedSharding(mesh, spec))
        for a in (ell.local_cols, ell.local_vals,
                  ell.ghost_cols, ell.ghost_vals)
    ]
    has_ghost = ell.ghost_pad > 0

    if overlap and has_ghost:
        def per_device_local(x_blk, lc, lv):
            x = jnp.concatenate(
                [x_blk[0], jnp.zeros((1,), x_blk.dtype)]
            )  # sentinel slot at index in_pad
            return spmv(lc[0], lv[0], x)[None]

        def per_device_ghost(y_blk, gh_blk, gc, gv):
            gh = jnp.concatenate(
                [gh_blk[0], jnp.zeros((1,), gh_blk.dtype)]
            )
            return (y_blk[0] + spmv(gc[0], gv[0], gh))[None]

        mm_local = shard_map(
            per_device_local, mesh=mesh, in_specs=(spec,) * 3,
            out_specs=spec, check_rep=False,
        )
        mm_ghost = shard_map(
            per_device_ghost, mesh=mesh, in_specs=(spec,) * 4,
            out_specs=spec, check_rep=False,
        )

        def spmv_fn(x):
            gh = exchange(x[..., None])[..., 0]   # issued before local work
            y = mm_local(x, *consts[:2])          # no data dep on gh
            return mm_ghost(y, gh, *consts[2:])

        return spmv_fn

    def per_device(x_blk, gh_blk, lc, lv, gc, gv):
        # blocks arrive with a leading device dim of 1
        x = jnp.concatenate(
            [x_blk[0], jnp.zeros((1,), x_blk.dtype)]
        )  # sentinel slot at index in_pad
        y = spmv(lc[0], lv[0], x)
        if has_ghost:
            gh = jnp.concatenate(
                [gh_blk[0], jnp.zeros((1,), gh_blk.dtype)]
            )
            y = y + spmv(gc[0], gv[0], gh)
        return y[None]

    mm = shard_map(
        per_device,
        mesh=mesh,
        in_specs=(spec,) * 6,
        out_specs=spec,
        check_rep=False,
    )

    def spmv_fn(x):
        if has_ghost:
            gh = exchange(x[..., None])[..., 0]
        else:
            gh = jnp.zeros((ell.n_procs, 0), x.dtype)
        return mm(x, gh, *consts)

    return spmv_fn


def _make_distributed_spmv_blocked(
    ell: DeviceEllBlocked,
    mesh,
    axis_name: str,
    exchange: Optional[Callable] = None,
    overlap: bool = False,
) -> Callable:
    """Blocked-layout counterpart of :func:`make_distributed_spmv`.

    Both the fused and the overlapped schedule go through the
    bucket-skipping kernel whenever :func:`row_block_bucket_map` shows at
    least one row block skipping at least one bucket of its window (banded
    operators touch few buckets per row block); otherwise the dense
    blocked/partial kernels stream every bucket.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..compat import shard_map
    from ..kernels.spmv_ell.ops import (
        spmv_blocked,
        spmv_blocked_partial,
        spmv_blocked_skip,
    )

    if ell.ghost_pad and exchange is None:
        raise ValueError("operator has ghost columns: exchange required")

    spec = P(axis_name)

    def shard(a):
        return jax.device_put(a, NamedSharding(mesh, spec))

    consts = [shard(ell.cols), shard(ell.vals)]
    has_ghost = ell.ghost_pad > 0
    bc = ell.block_cols
    C, Cl = ell.n_buckets, ell.n_local_buckets
    local_fill = Cl * bc - ell.in_pad
    ghost_fill = ell.n_ghost_buckets * bc - ell.ghost_pad

    if overlap and has_ghost:
        llists, lcounts = row_block_bucket_map(ell, bucket_hi=Cl)
        glists, gcounts = row_block_bucket_map(ell, bucket_lo=Cl)
        local_skip = llists.shape[2] < Cl
        ghost_skip = glists.shape[2] < C - Cl
        consts_l = consts + (
            [shard(llists), shard(lcounts)] if local_skip else []
        )
        consts_g = consts + (
            [shard(glists), shard(gcounts)] if ghost_skip else []
        )

        def per_device_local(x_blk, cols, vals, *sk):
            xl = jnp.concatenate(
                [x_blk[0], jnp.zeros((local_fill,), x_blk.dtype)]
            )
            if local_skip:
                bl, cnt = sk
                y = spmv_blocked_skip(
                    cols[0], vals[0], xl, bl[0], cnt[0],
                    n_buckets=C, block_cols=bc,
                )
            else:
                y0 = jnp.zeros((ell.row_pad,), x_blk.dtype)
                y = spmv_blocked_partial(
                    cols[0], vals[0], xl, y0,
                    bucket_lo=0, bucket_hi=Cl, n_buckets=C, block_cols=bc,
                )
            return y[None]

        def per_device_ghost(y_blk, gh_blk, cols, vals, *sk):
            xg = jnp.concatenate(
                [gh_blk[0], jnp.zeros((ghost_fill,), gh_blk.dtype)]
            )
            if ghost_skip:
                bl, cnt = sk
                y = spmv_blocked_skip(
                    cols[0], vals[0], xg, bl[0], cnt[0],
                    n_buckets=C, block_cols=bc, bucket_base=Cl, y0=y_blk[0],
                )
            else:
                y = spmv_blocked_partial(
                    cols[0], vals[0], xg, y_blk[0],
                    bucket_lo=Cl, bucket_hi=C, n_buckets=C, block_cols=bc,
                )
            return y[None]

        mm_local = shard_map(
            per_device_local, mesh=mesh,
            in_specs=(spec,) * (3 + 2 * local_skip),
            out_specs=spec, check_rep=False,
        )
        mm_ghost = shard_map(
            per_device_ghost, mesh=mesh,
            in_specs=(spec,) * (4 + 2 * ghost_skip),
            out_specs=spec, check_rep=False,
        )

        def spmv_fn(x):
            gh = exchange(x[..., None])[..., 0]   # issued before local work
            y = mm_local(x, *consts_l)            # no data dep on gh
            return mm_ghost(y, gh, *consts_g)

        return spmv_fn

    lists, counts = row_block_bucket_map(ell)
    use_skip = lists.shape[2] < C
    if use_skip:
        consts += [shard(lists), shard(counts)]

    def per_device(x_blk, gh_blk, cols, vals, *sk):
        x = x_blk[0]
        parts = [x, jnp.zeros((local_fill,), x.dtype)]
        if has_ghost:
            parts += [gh_blk[0], jnp.zeros((ghost_fill,), x.dtype)]
        xcat = jnp.concatenate(parts)     # [n_buckets * block_cols]
        if use_skip:
            bl, cnt = sk
            y = spmv_blocked_skip(
                cols[0], vals[0], xcat, bl[0], cnt[0],
                n_buckets=C, block_cols=bc,
            )
        else:
            y = spmv_blocked(cols[0], vals[0], xcat, bc)
        return y[None]

    mm = shard_map(
        per_device,
        mesh=mesh,
        in_specs=(spec,) * (4 + 2 * use_skip),
        out_specs=spec,
        check_rep=False,
    )

    def spmv_fn(x):
        if has_ghost:
            gh = exchange(x[..., None])[..., 0]
        else:
            gh = jnp.zeros((ell.n_procs, 0), x.dtype)
        return mm(x, gh, *consts)

    return spmv_fn


def distributed_spmv(
    part: PartitionedCSR,
    coll,
    mesh,
    axis_name: str,
    x: np.ndarray,
    dtype=np.float64,
    variant: str = "flat",
    block_cols: int = DEFAULT_BLOCK_COLS,
    overlap: str = "off",
) -> np.ndarray:
    """One-shot device distributed SpMV of a numpy vector (convenience).

    ``variant`` is ``"flat"``, ``"blocked"``, or ``"auto"`` (modeled-VMEM
    selection); ``overlap`` is ``"on"``, ``"off"``, or ``"auto"``
    (cost-model split-schedule selection against the plan's modeled
    exchange time).  For repeated products build the function once with
    :func:`make_distributed_spmv` and jit it.
    """
    import jax

    sel = select_spmv_kernel(part, variant=variant, block_cols=block_cols)
    ell = partitioned_to_device(part, sel, dtype, block_cols)
    exchange = coll.bind(mesh, axis_name) if ell.ghost_pad else None
    if overlap == "auto":
        from ..core.costmodel import TPU_V5E, plan_time

        osel = select_spmv_overlap(part, plan_time(coll.plan, TPU_V5E))
        ov = osel.mode == "on"
    else:
        osel = None
        if overlap not in ("on", "off"):
            raise ValueError(f"unknown overlap mode {overlap!r}")
        ov = overlap == "on" and ell.ghost_pad > 0
    fn = jax.jit(
        make_distributed_spmv(ell, mesh, axis_name, exchange, overlap=ov)
    )
    xg = pack_vector(part.col_offsets, ell.in_pad, x.astype(dtype))
    y = fn(xg)
    return unpack_vector(part.offsets, np.asarray(y))
