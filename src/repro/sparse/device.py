"""Device-resident distributed SpMV: padded ELL blocks + plan executor.

This is the device half of the paper's workload: the persistent neighborhood
collective (``core.collectives``) delivers ghost values and the ``spmv_ell``
kernel multiplies the per-device local and ghost blocks.  Everything is
static-shape SPMD: each process's blocks are padded to uniform sizes so one
``shard_map`` program serves all devices.

Layouts (all leading dim ``P`` = processes, sharded over the mesh axis):

* vectors: ``[P, pad]`` as produced by :func:`pack_vector` /
  ``core.collectives.pack_local_values`` — zero-padded per block;
* ELL blocks: ``cols``/``vals`` ``[P, row_pad, K]`` with padding entries
  pointing at a sentinel slot (index ``in_pad`` resp. ``ghost_pad``) that the
  per-device program materializes as an appended zero.

Entry points:

* :func:`partitioned_to_ell` — ``PartitionedCSR -> DeviceEll`` conversion;
* :func:`make_distributed_spmv` — build ``fn(x [P, in_pad]) -> y [P, row_pad]``
  composing exchange + local/ghost ELL matvecs (jit it, or fuse into a larger
  jitted program — that is how exchange/compute overlap materializes);
* :func:`distributed_spmv` — one-shot convenience on a numpy vector.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

from .csr import CSR
from .partition import PartitionedCSR


@dataclass
class DeviceEll:
    """Stacked per-process padded-ELL blocks of a partitioned operator."""

    n_procs: int
    row_pad: int     # uniform padded rows per process (== output vector pad)
    in_pad: int      # uniform padded input-vector block size
    ghost_pad: int   # uniform padded ghost count (0 => no exchange needed)
    local_cols: np.ndarray   # [P, row_pad, Kl] int32; pad -> in_pad sentinel
    local_vals: np.ndarray   # [P, row_pad, Kl]
    ghost_cols: np.ndarray   # [P, row_pad, Kg] int32; pad -> ghost_pad
    ghost_vals: np.ndarray   # [P, row_pad, Kg]


def _ell_block(
    m: CSR, row_pad: int, K: int, pad_col: int, dtype
) -> tuple:
    cols = np.full((row_pad, K), pad_col, dtype=np.int32)
    vals = np.zeros((row_pad, K), dtype=dtype)
    if m.nnz:
        rows = m.row_indices()
        pos = np.arange(m.nnz, dtype=np.int64) - m.indptr[rows]
        cols[rows, pos] = m.indices
        vals[rows, pos] = m.data
    return cols, vals


def partitioned_to_ell(part: PartitionedCSR, dtype=np.float64) -> DeviceEll:
    """Convert each process's local/ghost CSR blocks to uniformly padded ELL.

    Row padding matches the owning vector layout (max block size), so the
    output of the matvec IS the next op's input vector — no repacking
    between levels of a solve.
    """
    P_ = part.n_procs
    row_pad = int(np.diff(part.offsets).max())
    in_pad = int(np.diff(part.col_offsets).max())
    ghost_pad = int(max((len(n) for n in part.needs), default=0))
    Kl = max(
        max((int(np.diff(m.indptr).max()) for m in part.local if m.nnz),
            default=0), 1,
    )
    Kg = max(
        max((int(np.diff(m.indptr).max()) for m in part.ghost if m.nnz),
            default=0), 1,
    )
    lc = np.empty((P_, row_pad, Kl), dtype=np.int32)
    lv = np.empty((P_, row_pad, Kl), dtype=dtype)
    gc = np.empty((P_, row_pad, Kg), dtype=np.int32)
    gv = np.empty((P_, row_pad, Kg), dtype=dtype)
    for p in range(P_):
        lc[p], lv[p] = _ell_block(part.local[p], row_pad, Kl, in_pad, dtype)
        gc[p], gv[p] = _ell_block(part.ghost[p], row_pad, Kg, ghost_pad, dtype)
    return DeviceEll(P_, row_pad, in_pad, ghost_pad, lc, lv, gc, gv)


def pack_vector(offsets: np.ndarray, pad: int, x: np.ndarray) -> np.ndarray:
    """Global vector -> [P, pad] block layout (zero padding)."""
    P_ = len(offsets) - 1
    out = np.zeros((P_, pad), dtype=x.dtype)
    for p in range(P_):
        lo, hi = int(offsets[p]), int(offsets[p + 1])
        out[p, : hi - lo] = x[lo:hi]
    return out


def unpack_vector(offsets: np.ndarray, y: np.ndarray) -> np.ndarray:
    """[P, pad] block layout -> global vector."""
    P_ = len(offsets) - 1
    return np.concatenate(
        [
            np.asarray(y[p, : int(offsets[p + 1]) - int(offsets[p])])
            for p in range(P_)
        ]
    )


def make_distributed_spmv(
    ell: DeviceEll,
    mesh,
    axis_name: str,
    exchange: Optional[Callable] = None,
) -> Callable:
    """Build the device distributed SpMV ``fn(x [P, in_pad]) -> [P, row_pad]``.

    ``exchange`` is a bound plan executor (``NeighborAlltoallV.bind`` /
    ``PlanCache.executor``) mapping ``[P, in_pad, 1] -> [P, ghost_pad, 1]``;
    required unless ``ell.ghost_pad == 0`` (fully local operator).  The local
    and ghost matvecs go through ``kernels.spmv_ell.ops.spmv`` and therefore
    dispatch to the Pallas kernel on TPU and the jnp reference on CPU.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..compat import shard_map
    from ..kernels.spmv_ell.ops import spmv

    if ell.ghost_pad and exchange is None:
        raise ValueError("operator has ghost columns: exchange required")

    spec = P(axis_name)
    consts = [
        jax.device_put(a, NamedSharding(mesh, spec))
        for a in (ell.local_cols, ell.local_vals,
                  ell.ghost_cols, ell.ghost_vals)
    ]
    has_ghost = ell.ghost_pad > 0

    def per_device(x_blk, gh_blk, lc, lv, gc, gv):
        # blocks arrive with a leading device dim of 1
        x = jnp.concatenate(
            [x_blk[0], jnp.zeros((1,), x_blk.dtype)]
        )  # sentinel slot at index in_pad
        y = spmv(lc[0], lv[0], x)
        if has_ghost:
            gh = jnp.concatenate(
                [gh_blk[0], jnp.zeros((1,), gh_blk.dtype)]
            )
            y = y + spmv(gc[0], gv[0], gh)
        return y[None]

    mm = shard_map(
        per_device,
        mesh=mesh,
        in_specs=(spec,) * 6,
        out_specs=spec,
        check_rep=False,
    )

    def spmv_fn(x):
        if has_ghost:
            gh = exchange(x[..., None])[..., 0]
        else:
            gh = jnp.zeros((ell.n_procs, 0), x.dtype)
        return mm(x, gh, *consts)

    return spmv_fn


def distributed_spmv(
    part: PartitionedCSR,
    coll,
    mesh,
    axis_name: str,
    x: np.ndarray,
    dtype=np.float64,
) -> np.ndarray:
    """One-shot device distributed SpMV of a numpy vector (convenience).

    For repeated products build the function once with
    :func:`make_distributed_spmv` and jit it.
    """
    import jax

    ell = partitioned_to_ell(part, dtype)
    exchange = coll.bind(mesh, axis_name) if ell.ghost_pad else None
    fn = jax.jit(make_distributed_spmv(ell, mesh, axis_name, exchange))
    xg = pack_vector(part.col_offsets, ell.in_pad, x.astype(dtype))
    y = fn(xg)
    return unpack_vector(part.offsets, np.asarray(y))
