import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (including jax and
# repro.*): jax locks the device count at first initialization, and the
# multi-pod dry-run needs 512 placeholder host devices.  Do not set this
# flag anywhere global — smoke tests and benchmarks see 1 device.
#
# Multi-pod dry-run driver (deliverable e):
#   for every (architecture x input shape x mesh) cell, build the jitted
#   step (train_step / prefill / serve_step), .lower().compile() it on the
#   production mesh, and record memory_analysis / cost_analysis /
#   collective bytes into benchmarks/results/dryrun/<cell>.json.
#
# Usage:
#   python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k \
#       --mesh multi
#   python -m repro.launch.dryrun --all        # sweep (subprocess per cell)

import argparse
import json
import subprocess
import sys
import time
import traceback

RESULTS_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))),
    "benchmarks", "results", "dryrun",
)


def cell_path(arch: str, shape: str, mesh: str, moe_mode: str,
              fsdp: bool = False, remat: bool = True,
              variant: str = "") -> str:
    tag = f"{arch}__{shape}__{mesh}"
    if moe_mode != "hier":
        tag += f"__{moe_mode}"
    if fsdp:
        tag += "__fsdp"
    if not remat:
        tag += "__noremat"
    if variant:
        tag += f"__{variant}"
    return os.path.join(RESULTS_DIR, tag + ".json")


def run_cell(arch: str, shape_name: str, mesh_kind: str, moe_mode: str,
             fsdp: bool = False, remat: bool = True,
             cache_shard: str = "auto", seq_shard: bool = False) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from .. import configs
    from ..compat import cost_analysis_dict
    from ..configs.shapes import SHAPES, skip_reason
    from ..models import Model, serving
    from ..train import TrainerConfig, jit_train_step, make_train_state
    from ..train.trainer import batch_specs, state_specs
    from .mesh import make_production_mesh, mesh_axis_sizes
    from .roofline import (
        analytic_attention_flops,
        analytic_memory_estimate,
        collective_bytes_from_hlo,
        dci_bytes_from_hlo,
        dci_message_count_from_hlo,
        model_flops,
        roofline_terms,
    )

    t_start = time.time()
    spec = SHAPES[shape_name]
    reason = skip_reason(arch, shape_name)
    if reason:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": reason}

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    axes = mesh_axis_sizes(mesh)
    chips = int(np.prod(list(axes.values())))
    cfg = configs.get(arch)
    spec_kind = SHAPES[shape_name].kind
    model = Model(cfg, mesh=mesh, moe_mode=moe_mode, ep_over_pods=True,
                  remat=remat, fsdp=fsdp,
                  scan_layers=(spec_kind == "train"), seq_shard=seq_shard)

    B, S = spec.global_batch, spec.seq_len
    n_batch_dev = int(np.prod([axes[a] for a in model.batch_axes]))
    b_ax = (model.batch_axes if len(model.batch_axes) > 1
            else model.batch_axes[0])
    b_spec = b_ax if B % n_batch_dev == 0 else None

    def sds(shape, dtype):
        return jax.ShapeDtypeStruct(shape, dtype)

    def sh(spec_):
        return NamedSharding(mesh, spec_)

    def batch_sds(T, with_labels):
        d = {}
        if cfg.family == "audio":
            d["enc_embeds"] = sds((B, T, cfg.d_model), cfg.dtype)
            d["tokens"] = sds((B, T), jnp.int32)
        elif cfg.family == "vlm":
            d["embeds"] = sds((B, T, cfg.d_model), cfg.dtype)
            d["positions"] = sds((B, 3, T), jnp.int32)
        else:
            d["tokens"] = sds((B, T), jnp.int32)
        if with_labels:
            d["labels"] = sds((B, T), jnp.int32)
        return d

    def batch_shardings(d):
        out = {}
        for k, v in d.items():
            lead = (b_spec,) + (None,) * (len(v.shape) - 1)
            out[k] = sh(P(*lead))
        return out

    def cache_sharding_rule(leaf):
        """Pick shardable dims for cache leaves: dim0 over batch axes when
        divisible, then one more dim over 'model'.  cache_shard policy:
        'auto' = first divisible dim; 'dh' = prefer the LAST dim (head_dim
        stays local per chip, attention reduces over it); 'seq' = prefer
        the sequence dim (forces gather/permute at use)."""
        shp = leaf.shape
        entries = [None] * len(shp)
        if len(shp) and B % n_batch_dev == 0 and shp[0] == B:
            entries[0] = b_ax
        m = axes.get("model", 1)
        order = range(1, len(shp))
        if cache_shard == "dh":
            order = range(len(shp) - 1, 0, -1)
        for i in order:
            if shp[i] % m == 0 and shp[i] >= m:
                entries[i] = "model"
                break
        return sh(P(*entries))

    pspecs = model.param_specs()
    pshard = jax.tree.map(lambda s: sh(s), pspecs,
                          is_leaf=lambda s: isinstance(s, P))
    params_sds = model.init_params(abstract=True)

    ladder = None
    if spec.kind == "train":
        # FULL model compiles with scanned layers (fast; proves sharding
        # coherence + gives memory_analysis).  Exact per-layer costs come
        # from a 2-point "ladder" of small UNROLLED variants (1 and 2
        # layer-periods) and extrapolate linearly — exact for identical
        # layers, +/- a few % for mixed-period archs (gemma3/zamba tail).
        tcfg = TrainerConfig()
        step_jit, _ = jit_train_step(model, tcfg)
        state_sds = make_train_state(model, tcfg, abstract=True)
        lowered = step_jit.lower(state_sds, batch_sds(S, True))
        tokens = B * S

        import dataclasses as _dc

        def _ladder_cfgs():
            fam = cfg.family
            if fam == "audio":
                c1 = _dc.replace(cfg, n_layers=2, n_enc_layers=1,
                                 n_dec_layers=1)
                c2 = _dc.replace(cfg, n_layers=4, n_enc_layers=2,
                                 n_dec_layers=2)
                units = cfg.n_enc_layers  # enc+dec pairs
                return c1, c2, units
            per = (cfg.local_global_period
                   or (cfg.shared_attn_period if fam == "hybrid" else 0)
                   or 1)
            off = cfg.first_dense_layers
            c1 = _dc.replace(cfg, n_layers=off + per)
            c2 = _dc.replace(cfg, n_layers=off + 2 * per)
            units = (cfg.n_layers - off) / per
            return c1, c2, units

        def _train_costs(cfg_x):
            m_x = Model(cfg_x, mesh=mesh, moe_mode=moe_mode,
                        ep_over_pods=True, remat=remat, fsdp=fsdp,
                        scan_layers=False, seq_shard=seq_shard)
            sj, _ = jit_train_step(m_x, tcfg)
            st = make_train_state(m_x, tcfg, abstract=True)
            comp = sj.lower(st, batch_sds(S, True)).compile()
            c = cost_analysis_dict(comp)
            txt = comp.as_text()
            cl = collective_bytes_from_hlo(txt)
            dc = (dci_bytes_from_hlo(txt) if mesh_kind == "multi"
                  else {"ici": 0, "dci": 0})
            dm = (dci_message_count_from_hlo(txt) if mesh_kind == "multi"
                  else 0)
            return (float(c.get("flops", 0.0)),
                    float(c.get("bytes accessed", 0.0)), cl, dc, dm)

        c1, c2, units = _ladder_cfgs()
        f1, b1, cl1, dc1, dm1 = _train_costs(c1)
        f2, b2, cl2, dc2, dm2 = _train_costs(c2)
        ladder = {
            "flops": f1 + (units - 1) * (f2 - f1),
            "bytes": b1 + (units - 1) * (b2 - b1),
            "coll": {k: cl1[k] + (units - 1) * (cl2[k] - cl1[k])
                     for k in cl1},
            "dci": {k: dc1[k] + (units - 1) * (dc2[k] - dc1[k])
                    for k in dc1},
            "dci_msgs": dm1 + (units - 1) * (dm2 - dm1),
            "units": units,
        }
    elif spec.kind == "prefill":
        bsds = batch_sds(S, False)
        fn = jax.jit(
            lambda p, i: serving.prefill(model, p, i, max_len=S),
            in_shardings=(pshard, batch_shardings(bsds)),
        )
        lowered = fn.lower(params_sds, bsds)
        tokens = B * S
    cache_bytes_dev = 0.0
    if spec.kind == "decode":
        prompt = batch_sds(8, False)
        cache_sds = jax.eval_shape(
            lambda p, i: serving.prefill(model, p, i, max_len=S)[1],
            params_sds, prompt,
        )
        cache_shardings = jax.tree.map(cache_sharding_rule, cache_sds)
        isds = batch_sds(1, False)
        fn = jax.jit(
            lambda p, i, c, n: serving.decode_step(model, p, i, c, n),
            in_shardings=(pshard, batch_shardings(isds), cache_shardings,
                          None),
        )
        lowered = fn.lower(params_sds, isds, cache_sds,
                           sds((), jnp.int32))
        tokens = B  # one new token per sequence
        # exact per-device cache bytes under the chosen shardings
        for leaf, shd in zip(jax.tree.leaves(cache_sds),
                             jax.tree.leaves(cache_shardings)):
            import math as _m
            total = _m.prod(leaf.shape) * leaf.dtype.itemsize
            spec_ = shd.spec
            shards = 1
            for e in spec_:
                if e is None:
                    continue
                for ax in (e if isinstance(e, tuple) else (e,)):
                    shards *= axes[ax]
            cache_bytes_dev += total / shards

    t_lower = time.time()
    compiled = lowered.compile()
    t_compile = time.time()

    mem = compiled.memory_analysis()
    cost = cost_analysis_dict(compiled)
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)
    dci = dci_bytes_from_hlo(hlo) if mesh_kind == "multi" else None
    dci_msgs = (dci_message_count_from_hlo(hlo) if mesh_kind == "multi"
                else None)

    # per-device quantities (the compiled module is the SPMD program)
    if ladder is not None:  # train: ladder-extrapolated exact per-layer costs
        flops = ladder["flops"]
        hbm_bytes = ladder["bytes"]
        coll = {k: float(v) for k, v in ladder["coll"].items()}
        if mesh_kind == "multi":
            dci = {k: float(v) for k, v in ladder["dci"].items()}
            dci_msgs = float(ladder["dci_msgs"])
    else:
        flops = float(cost.get("flops", 0.0))
        hbm_bytes = float(cost.get("bytes accessed", 0.0))
    coll_total = float(sum(coll.values()))
    # attention runs as a chunked scan (flash dataflow): XLA counts its body
    # once, so add the analytic attention FLOPs (x3 for fwd+bwd in training)
    if spec.kind == "train":
        attn_fl = 3.0 * analytic_attention_flops(cfg, B, S, S)
        # the chunked xent counts the lm_head projection once per scan:
        # add the missing (nb-1)/nb of 3*2*T*d*V analytically
        nb = S // 512 if S % 512 == 0 and S > 512 else 1
        attn_fl += 6.0 * B * S * cfg.d_model * cfg.vocab * (nb - 1) / nb
    elif spec.kind == "prefill":
        attn_fl = analytic_attention_flops(cfg, B, S, S)
    else:
        attn_fl = analytic_attention_flops(cfg, B, 1, S, decode=True)
    flops_corr = flops + attn_fl / chips
    terms = roofline_terms(flops_corr, hbm_bytes, coll_total, chips)
    mfl = model_flops(cfg, spec.kind, tokens)  # global
    mfl_dev = mfl / chips

    def mem_attr(name):
        return int(getattr(mem, name, 0) or 0)

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "moe_mode": moe_mode,
        "fsdp": fsdp,
        "seq_shard": seq_shard,
        "cache_shard": cache_shard,
        "status": "ok",
        "chips": chips,
        "tokens_per_step": tokens,
        "cost_method": ("scan+ladder-extrapolation" if ladder is not None
                        else "full-unrolled"),
        "ladder_units": (ladder or {}).get("units"),
        "lower_s": round(t_lower - t_start, 1),
        "compile_s": round(t_compile - t_lower, 1),
        "memory": {
            "argument_bytes": mem_attr("argument_size_in_bytes"),
            "output_bytes": mem_attr("output_size_in_bytes"),
            "temp_bytes": mem_attr("temp_size_in_bytes"),
            "peak_bytes": (
                mem_attr("argument_size_in_bytes")
                + mem_attr("temp_size_in_bytes")
            ),
        },
        "memory_analytic": analytic_memory_estimate(
            cfg, spec.kind, B, S, axes, fsdp, cache_bytes_dev,
            seq_shard=seq_shard),
        "hlo_flops_per_device": flops,
        "attn_flops_analytic_per_device": attn_fl / chips,
        "flops_per_device_corrected": flops_corr,
        "hlo_bytes_per_device": hbm_bytes,
        "collective_bytes_per_device": coll,
        "ici_dci_bytes_per_device": dci,
        "dci_msgs_per_device": dci_msgs,
        "collective_bytes_total_per_device": coll_total,
        "model_flops_global": mfl,
        "model_flops_per_device": mfl_dev,
        "useful_flops_ratio": (mfl_dev / flops_corr) if flops_corr else 0.0,
        **terms,
    }
    return result


def write_cell(result: dict, path: str):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(result, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--moe-mode", default="hier",
                    choices=["dense", "a2a", "hier", "hier_dedup"])
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--cache-shard", default="auto",
                    choices=["auto", "dh", "seq"])
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=3000)
    args = ap.parse_args()

    if args.all:
        from ..configs.shapes import SHAPES
        # cheapest-to-compile first so an interrupted sweep still covers
        # the most cells; single-pod first (it feeds the roofline table)
        order = ["qwen1.5-0.5b", "qwen2-0.5b", "gemma3-1b",
                 "seamless-m4t-medium", "mamba2-780m", "qwen2-vl-2b",
                 "deepseek-v2-lite-16b", "mixtral-8x7b", "nemotron-4-15b",
                 "zamba2-7b"]
        todo = [
            (a, s, m)
            for m in ("single", "multi") for a in order for s in SHAPES
        ]
        failures = []
        for a, s, m in todo:
            path = cell_path(a, s, m, args.moe_mode)
            if os.path.exists(path) and not args.force:
                try:
                    with open(path) as f:
                        prev = json.load(f)
                except Exception:
                    prev = {}
                if prev.get("status") in ("ok", "skipped"):
                    print(f"[cached] {a} {s} {m}")
                    continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", a, "--shape", s, "--mesh", m,
                   "--moe-mode", args.moe_mode]
            if args.fsdp:
                cmd.append("--fsdp")
            if args.no_remat:
                cmd.append("--no-remat")
            print(f"[run] {a} {s} {m} ...", flush=True)
            t0 = time.time()
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout)
            dt = time.time() - t0
            if r.returncode != 0:
                failures.append((a, s, m))
                write_cell({"arch": a, "shape": s, "mesh": m,
                            "status": "error",
                            "error": r.stderr[-3000:]}, path)
                print(f"  FAILED in {dt:.0f}s")
            else:
                print(f"  ok in {dt:.0f}s")
        print(f"done; {len(failures)} failures: {failures}")
        sys.exit(1 if failures else 0)

    result = run_cell(args.arch, args.shape, args.mesh, args.moe_mode,
                      fsdp=args.fsdp, remat=not args.no_remat,
                      cache_shard=args.cache_shard,
                      seq_shard=args.seq_shard)
    variant = "" if args.cache_shard == "auto" else f"cache{args.cache_shard}"
    if args.seq_shard:
        variant = (variant + "_" if variant else "") + "seqshard"
    path = cell_path(args.arch, args.shape, args.mesh, args.moe_mode,
                     fsdp=args.fsdp, remat=not args.no_remat,
                     variant=variant)
    write_cell(result, path)
    print(json.dumps(result, indent=1))
    if result["status"] == "error":
        sys.exit(1)


if __name__ == "__main__":
    try:
        main()
    except SystemExit:
        raise
    except BaseException:
        traceback.print_exc()
        sys.exit(1)
