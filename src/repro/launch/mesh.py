"""Production mesh construction.

A function (NOT a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before any jax
initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips as (data=16, model=16).
    Multi-pod: 2 pods x 256 chips as (pod=2, data=16, model=16)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
