from .mesh import make_production_mesh, mesh_axis_sizes

__all__ = ["make_production_mesh", "mesh_axis_sizes"]
