"""Serving launcher: batched prefill + greedy decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \
        --batch 4 --prompt-len 16 --new-tokens 24
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=0)
    ap.add_argument("--moe-mode", default="auto",
                help="MoE dispatch: auto (Section-5 selection) | a2a | hier | hier_dedup | dense")
    args = ap.parse_args()

    from .. import configs
    from ..models import Model, serving

    cfg = configs.reduced(args.arch) if args.reduced else configs.get(args.arch)
    cfg = dataclasses.replace(cfg, dtype=jnp.float32)
    model = Model(cfg, moe_mode=args.moe_mode, remat=False)
    params = model.init_params(seed=0)
    max_len = args.max_len or (args.prompt_len + args.new_tokens + 8)

    rng = np.random.default_rng(0)
    B, T = args.batch, args.prompt_len
    inputs = {}
    if cfg.family == "audio":
        inputs["enc_embeds"] = jnp.asarray(
            rng.normal(size=(B, T, cfg.d_model)).astype(np.float32))
        inputs["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, size=(B, T)).astype(np.int32))
    elif cfg.family == "vlm":
        inputs["embeds"] = jnp.asarray(
            rng.normal(size=(B, T, cfg.d_model)).astype(np.float32))
        pos = np.broadcast_to(np.arange(T, dtype=np.int32), (B, T))
        inputs["positions"] = jnp.asarray(
            np.broadcast_to(pos[:, None, :], (B, 3, T)).copy())
    else:
        inputs["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, size=(B, T)).astype(np.int32))

    t0 = time.time()
    prefill_fn = jax.jit(
        lambda p, i: serving.prefill(model, p, i, max_len=max_len))
    logits, caches = prefill_fn(params, inputs)
    logits.block_until_ready()
    print(f"[serve] prefill {B}x{T} in {time.time() - t0:.2f}s "
          f"({B * T / (time.time() - t0):,.0f} tok/s)")

    decode_fn = jax.jit(
        lambda p, i, c, n: serving.decode_step(model, p, i, c, n))
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out_tokens = [np.asarray(tok)]
    t0 = time.time()
    for step in range(args.new_tokens):
        if cfg.family == "vlm":
            emb = params["embed"][tok[:, 0]][:, None]
            step_in = {"embeds": emb}
        else:
            step_in = {"tokens": tok}
        logits, caches = decode_fn(params, step_in, caches,
                                   jnp.asarray(T + step, jnp.int32))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(np.asarray(tok))
    jax.block_until_ready(logits)
    dt = time.time() - t0
    print(f"[serve] decoded {args.new_tokens} tokens x {B} seqs in "
          f"{dt:.2f}s ({B * args.new_tokens / dt:,.1f} tok/s)")
    gen = np.concatenate(out_tokens, axis=1)
    print(f"[serve] sample row 0: {gen[0][:24].tolist()}")


if __name__ == "__main__":
    main()
