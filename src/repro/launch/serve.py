"""Serving launcher: batched prefill + greedy decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \
        --batch 4 --prompt-len 16 --new-tokens 24

Elastic demo (``--elastic``): drives a ``ServeEngine(elastic=True)``
through a mid-decode shrink to ``--shrink-to`` devices at step
``--shrink-at`` and a grow-back, printing each ``ResizeEvent`` with its
plan-cache delta (the grow-back is warm — see docs/OPERATIONS.md):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b \
        --reduced --elastic --batch 2 --new-tokens 12 --shrink-at 4
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=0)
    ap.add_argument("--moe-mode", default="auto",
                help="MoE dispatch: auto (Section-5 selection) | a2a | hier | hier_dedup | dense")
    ap.add_argument("--elastic", action="store_true",
                    help="drive ServeEngine(elastic=True) through a "
                    "mid-decode shrink/grow (see module docstring)")
    ap.add_argument("--shrink-at", type=int, default=4,
                    help="engine step at which half the devices 'time out'")
    ap.add_argument("--shrink-to", type=int, default=0,
                    help="surviving device count (default: half)")
    args = ap.parse_args()

    if args.elastic:
        return _main_elastic(args)

    from .. import configs
    from ..models import Model, serving

    cfg = configs.reduced(args.arch) if args.reduced else configs.get(args.arch)
    cfg = dataclasses.replace(cfg, dtype=jnp.float32)
    model = Model(cfg, moe_mode=args.moe_mode, remat=False)
    params = model.init_params(seed=0)
    max_len = args.max_len or (args.prompt_len + args.new_tokens + 8)

    rng = np.random.default_rng(0)
    B, T = args.batch, args.prompt_len
    inputs = {}
    if cfg.family == "audio":
        inputs["enc_embeds"] = jnp.asarray(
            rng.normal(size=(B, T, cfg.d_model)).astype(np.float32))
        inputs["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, size=(B, T)).astype(np.int32))
    elif cfg.family == "vlm":
        inputs["embeds"] = jnp.asarray(
            rng.normal(size=(B, T, cfg.d_model)).astype(np.float32))
        pos = np.broadcast_to(np.arange(T, dtype=np.int32), (B, T))
        inputs["positions"] = jnp.asarray(
            np.broadcast_to(pos[:, None, :], (B, 3, T)).copy())
    else:
        inputs["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, size=(B, T)).astype(np.int32))

    t0 = time.time()
    prefill_fn = jax.jit(
        lambda p, i: serving.prefill(model, p, i, max_len=max_len))
    logits, caches = prefill_fn(params, inputs)
    logits.block_until_ready()
    print(f"[serve] prefill {B}x{T} in {time.time() - t0:.2f}s "
          f"({B * T / (time.time() - t0):,.0f} tok/s)")

    decode_fn = jax.jit(
        lambda p, i, c, n: serving.decode_step(model, p, i, c, n))
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out_tokens = [np.asarray(tok)]
    t0 = time.time()
    for step in range(args.new_tokens):
        if cfg.family == "vlm":
            emb = params["embed"][tok[:, 0]][:, None]
            step_in = {"embeds": emb}
        else:
            step_in = {"tokens": tok}
        logits, caches = decode_fn(params, step_in, caches,
                                   jnp.asarray(T + step, jnp.int32))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(np.asarray(tok))
    jax.block_until_ready(logits)
    dt = time.time() - t0
    print(f"[serve] decoded {args.new_tokens} tokens x {B} seqs in "
          f"{dt:.2f}s ({B * args.new_tokens / dt:,.1f} tok/s)")
    gen = np.concatenate(out_tokens, axis=1)
    print(f"[serve] sample row 0: {gen[0][:24].tolist()}")


def _main_elastic(args):
    """Mid-decode shrink/grow through ``ServeEngine(elastic=True)``."""
    from .. import configs
    from ..models import Model
    from ..serve import Request, ServeEngine

    cfg = configs.reduced(args.arch) if args.reduced \
        else configs.get(args.arch)
    cfg = dataclasses.replace(cfg, dtype=jnp.float32)
    n_dev = jax.device_count()
    shrink_to = args.shrink_to or max(1, n_dev // 2)
    max_len = args.max_len or (args.prompt_len + args.new_tokens + 8)

    mesh = jax.make_mesh((1, n_dev), ("data", "model"))
    model = Model(cfg, mesh=mesh, moe_mode=args.moe_mode, remat=False)
    params = model.init_params(seed=0)
    eng = ServeEngine(model, params, batch_slots=args.batch,
                      max_len=max_len, elastic=True)
    print(f"[serve/elastic] engine up on {n_dev} devices "
          f"(mesh {dict(zip(mesh.axis_names, mesh.devices.shape))})")

    rng = np.random.default_rng(0)
    for rid in range(args.batch):
        prompt = rng.integers(0, cfg.vocab,
                              size=(args.prompt_len,)).astype(np.int32)
        eng.submit(Request(rid=rid, prompt=prompt,
                           max_new_tokens=args.new_tokens))

    t0 = time.time()
    done = []
    for step in range(args.new_tokens + 1):
        if step == args.shrink_at:
            print(f"[serve/elastic] step {step}: {n_dev - shrink_to} "
                  f"devices time out -> shrink to {shrink_to}")
            ev = eng.resize(shrink_to, reason="heartbeat")
            print(f"[serve/elastic]   {ev}")
        done.extend(eng.step())
    print(f"[serve/elastic] decoded {args.new_tokens} tokens x "
          f"{args.batch} seqs in {time.time() - t0:.2f}s "
          f"(shrink at step {args.shrink_at})")

    ev = eng.resize(n_dev, reason="requested")
    print(f"[serve/elastic] devices return -> grow back: {ev}")
    print(f"[serve/elastic]   warm resize: {ev.warm} "
          f"(plans for the seen geometry survived in the cache)")
    done.extend(eng.run_until_drained())
    for req in done:
        print(f"[serve/elastic] rid {req.rid} generated: "
              f"{req.generated[:16]}")
    print(f"[serve/elastic] drained {len(done)} request(s); "
          f"resize events: {len(eng.resize_events)}")


if __name__ == "__main__":
    main()
