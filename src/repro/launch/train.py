"""Training launcher: config -> mesh -> data -> jitted step -> checkpoints.

Single-host it runs real steps on the local devices; the same entry point
is what each host of a multi-pod fleet would execute (jax.distributed
initialization is the only per-deployment addition).  Includes heartbeat
bookkeeping, straggler detection, elastic restart from the latest
checkpoint, and periodic async checkpointing.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --steps 50 --batch 8 --seq 256 --reduced --ckpt /tmp/ck
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--vocab", type=int, default=0,
                    help="override vocab (speeds up CPU demos)")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--moe-mode", default="auto",
                help="MoE dispatch: auto (Section-5 selection) | a2a | hier | hier_dedup | dense")
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()

    from .. import configs
    from ..models import Model
    from ..runtime import CheckpointManager, StragglerDetector
    from ..train import (
        AdamWConfig, DataConfig, TokenStream, TrainerConfig,
        make_train_state, make_train_step,
    )

    cfg = configs.reduced(args.arch) if args.reduced else configs.get(args.arch)
    if args.vocab:
        import dataclasses
        cfg = dataclasses.replace(cfg, vocab=args.vocab)
    import dataclasses
    cfg = dataclasses.replace(cfg, dtype=jnp.float32)

    model = Model(cfg, moe_mode=args.moe_mode)
    tcfg = TrainerConfig(
        opt=AdamWConfig(lr=args.lr, warmup_steps=max(2, args.steps // 20),
                        total_steps=args.steps),
        microbatches=args.microbatches,
        compress_grads=args.compress_grads,
    )
    data = TokenStream(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  global_batch=args.batch))
    step_fn = jax.jit(make_train_step(model, tcfg))
    state = make_train_state(model, tcfg, seed=0)
    start = 0
    mgr = None
    if args.ckpt:
        mgr = CheckpointManager(args.ckpt, keep=3)
        got = mgr.restore_latest(state)
        if got is not None:
            start, state = got
            state = jax.tree.map(jnp.asarray, state)
            print(f"[train] resumed from step {start}")

    det = StragglerDetector(n_hosts=1)
    n_params = cfg.param_count()
    print(f"[train] arch={cfg.name} params={n_params:,} steps={args.steps}")
    t_last = time.time()
    for i in range(start, args.steps):
        batch = jax.tree.map(jnp.asarray, data.global_batch_at(i))
        state, metrics = step_fn(state, batch)
        if mgr and (i + 1) % args.ckpt_every == 0:
            mgr.save(i + 1, state)
        if (i + 1) % args.log_every == 0 or i == start:
            loss = float(metrics["loss"])
            dt = time.time() - t_last
            t_last = time.time()
            det.update(np.array([dt]))
            tps = args.batch * args.seq * args.log_every / max(dt, 1e-9)
            print(f"[train] step {i + 1:5d} loss={loss:.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['gnorm']):.2f} tok/s={tps:,.0f}")
    if mgr:
        mgr.save(args.steps, state)
        mgr.wait()
    print("[train] done")


if __name__ == "__main__":
    main()
