"""Roofline-term derivation from compiled dry-run artifacts.

Terms (seconds), per (arch x shape x mesh) cell on TPU v5e.

IMPORTANT semantics (measured against a calibration program): the compiled
module is the per-device SPMD program, so ``cost_analysis()`` FLOPs/bytes
and the HLO collective shapes are all PER-DEVICE quantities:

    compute    = HLO_FLOPs_dev / 197e12          [bf16 peak / chip]
    memory     = HLO_bytes_dev / 819e9           [HBM bw / chip]
    collective = collective_bytes_dev / (2 * 50e9) [ICI links / chip]

collective_bytes is parsed from the compiled HLO text: the summed
result-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute (+ their async -start forms) — a
documented proxy for per-device on-wire volume.  Scan bodies are counted
once by XLA's analysis, so the dry-run lowers models with UNROLLED layer
loops (Model(scan_layers=False)).
"""
from __future__ import annotations

import re
from typing import Dict, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW_PER_LINK = 50e9       # B/s
ICI_LINKS = 2                # effective links engaged per chip (conservative)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective op kind."""
    out: Dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        for op in COLLECTIVE_OPS:
            # match " all-gather(" / " all-gather-start(" as the op token
            if f" {op}(" in line or f" {op}-start(" in line:
                lhs = line.split(f" {op}", 1)[0]
                for dtype, dims in _SHAPE_RE.findall(lhs):
                    if dtype in _DTYPE_BYTES:
                        out[op] += _shape_bytes(dtype, dims)
                break
    return out


def roofline_terms(
    flops_dev: float,
    hbm_bytes_dev: float,
    collective_bytes_dev: float,
    chips: int,
) -> Dict[str, float]:
    """All inputs are per-device quantities (see module docstring)."""
    compute = flops_dev / PEAK_FLOPS
    memory = hbm_bytes_dev / HBM_BW
    collective = collective_bytes_dev / (ICI_LINKS * ICI_BW_PER_LINK)
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    terms["bottleneck"] = max(terms, key=lambda k: terms[k])[:-2]
    terms["step_s_lower_bound"] = max(compute, memory, collective)
    return terms


def active_param_count(cfg) -> int:
    """Active params for 6*N_active*D MoE model-FLOPs accounting."""
    total = cfg.param_count()
    if cfg.family != "moe" or cfg.n_experts == 0:
        return total
    ffe = 3 * cfg.d_model * cfg.d_ff_expert
    moe_layers = cfg.n_layers - cfg.first_dense_layers
    routed_total = moe_layers * cfg.n_experts * ffe
    routed_active = moe_layers * cfg.top_k * ffe
    return total - routed_total + routed_active


def model_flops(cfg, shape_kind: str, tokens: int) -> float:
    """6*N*D train / 2*N*D inference forward (MoE: N_active)."""
    n = active_param_count(cfg)
    return (6.0 if shape_kind == "train" else 2.0) * n * tokens


def mfu_fraction(model_fl: float, seconds: float, chips: int) -> float:
    if seconds <= 0:
        return 0.0
    return model_fl / (seconds * chips * PEAK_FLOPS)


def analytic_attention_flops(cfg, B: int, Tq: int, Tk: int,
                             windows=None, decode: bool = False) -> float:
    """Global attention FLOPs (scores + PV) across all layers.

    XLA counts a scan body once, and Pallas kernels appear as custom calls
    with no cost, so attention FLOPs are accounted analytically:
        2 * 2 * B * Hq * Tq * Tk_eff * dh   per attention layer,
    with Tk_eff halved for causal self-attention over a fresh sequence and
    clipped to the window for sliding-window layers.  Backward (train)
    multiplies by 3 at the call site via model_flops conventions.
    """
    fam = cfg.family
    if fam == "ssm":
        return 0.0

    def layer_flops(win, tq, tk, hq, dh, causal_fresh):
        tk_eff = tk
        if win and win > 0:
            tk_eff = min(tk, win)
        elif causal_fresh:
            tk_eff = tk / 2.0
        return 4.0 * B * hq * tq * tk_eff * dh

    if fam == "hybrid":
        n_attn = (cfg.n_layers // cfg.shared_attn_period)
        hq, dh = cfg.n_heads, cfg.head_dim
        return n_attn * layer_flops(0, Tq, Tk, hq, dh, not decode)
    if fam == "audio":
        hq, dh = cfg.n_heads, cfg.head_dim
        enc = cfg.n_enc_layers * layer_flops(0, Tk, Tk, hq, dh, False)
        if decode:
            enc = 0.0
        dec_self = cfg.n_dec_layers * layer_flops(0, Tq, Tk, hq, dh,
                                                  not decode)
        dec_cross = cfg.n_dec_layers * layer_flops(0, Tq, Tk, hq, dh, False)
        return enc + dec_self + dec_cross
    if cfg.mla:
        hq = cfg.n_heads
        dh = cfg.qk_nope_dim + cfg.qk_rope_dim + cfg.v_head_dim
        return cfg.n_layers * layer_flops(0, Tq, Tk, hq, dh / 2 * 2,
                                          not decode)
    hq, dh = cfg.n_heads, cfg.head_dim
    total = 0.0
    for i in range(cfg.n_layers):
        win = cfg.window if (cfg.window and not cfg.layer_is_global(i)) \
            else (cfg.window if cfg.window and not cfg.local_global_period
                  else 0)
        total += layer_flops(win, Tq, Tk, hq, dh, not decode)
    return total


def analytic_memory_estimate(cfg, kind: str, B: int, S: int,
                             axes: dict, fsdp: bool,
                             cache_bytes_dev: float = 0.0,
                             seq_shard: bool = False) -> dict:
    """Per-device HBM estimate for the TPU target (bytes).

    The XLA-CPU backend has no memory-aware scheduling, so its
    memory_analysis() keeps one recomputed attention buffer alive per layer
    (measured: temp grows ~1.8 GB/layer on CPU, constant on TPU-style
    schedules).  This analytic model is the "fits on v5e" evidence and is
    reported next to the raw CPU numbers:

      params(bf16/TP)  + ZeRO-1 moments(fp32/TPxDP) + grads(bf16/TP)
      + layer-input residuals (remat) + a bounded transient working set
      + (serving) exact sharded cache bytes.
    """
    n = cfg.param_count()
    tp = axes.get("model", 1)
    dp = axes.get("data", 1) * axes.get("pod", 1)
    params_dev = 2.0 * n / tp / (dp if fsdp else 1)
    d = cfg.d_model
    b_dev = max(1, B // dp)
    out = {"params_bytes": params_dev}
    if kind == "train":
        out["moments_bytes"] = 8.0 * n / tp / axes.get("data", 1)
        out["grads_bytes"] = 2.0 * n / tp / (dp if fsdp else 1)
        layers = cfg.n_layers
        res = layers * b_dev * S * d * 2.0
        if seq_shard:
            res /= tp  # sequence-sharded residual stream
        out["residual_bytes"] = res
        # transient: few activation-sized f32 buffers + one attention chunk;
        # sequence sharding also shards the transients outside the gathered
        # attention/mlp interiors
        hq = max(1, cfg.n_heads)
        trans = (8.0 * b_dev * S * d * 4.0
                 + 2.0 * b_dev * max(1, hq // tp) * S * 512 * 4.0)
        if seq_shard:
            trans = trans / tp + 2.0 * b_dev * S * d * 4.0 / max(tp // 4, 1)
        out["transient_bytes"] = trans
    else:
        out["cache_bytes"] = cache_bytes_dev
        out["transient_bytes"] = 8.0 * b_dev * max(S if kind == "prefill"
                                                   else 1, 1) * d * 4.0
    out["total_bytes"] = float(sum(out.values()))
    out["fits_16gb_v5e"] = bool(out["total_bytes"] < 16e9)
    return out


_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{(\{[0-9,{} ]*\})\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\](T\(\d+,\d+\))?<=\[(\d+)\]"
)


def _line_crosses_pods(line: str, pod_size: int) -> bool:
    """Does this collective's replica grouping span pod boundaries?

    Handles explicit ``replica_groups={{0,256},{1,257},...}`` and iota
    forms ``replica_groups=[G,N]<=[512]`` (contiguous groups of N) /
    ``[G,N]T(1,0)<=[512]`` (strided groups)."""
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        g, n, transpose, total = (int(m.group(1)), int(m.group(2)),
                                  m.group(3), int(m.group(4)))
        if total <= pod_size:
            return False
        if transpose:
            # groups pick every (total//n)-th device: stride g
            return (n - 1) * g >= pod_size
        return n > pod_size
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        for grp in m.group(1).split("},"):
            ids = [int(x) for x in re.findall(r"\d+", grp)]
            if ids and (max(ids) // pod_size) != (min(ids) // pod_size):
                return True
        return False
    return False  # no groups -> all devices; caller decides


def dci_bytes_from_hlo(hlo_text: str, pod_size: int = 256) -> Dict[str, int]:
    """Split per-device collective bytes into intra-pod (ICI) vs
    pod-crossing (DCI) by replica-group analysis — the TPU analogue of the
    paper's intra- vs inter-region byte accounting."""
    out = {"ici": 0, "dci": 0}
    for line in hlo_text.splitlines():
        for op in COLLECTIVE_OPS:
            if f" {op}(" in line or f" {op}-start(" in line:
                lhs = line.split(f" {op}", 1)[0]
                nbytes = 0
                for dtype, dims in _SHAPE_RE.findall(lhs):
                    if dtype in _DTYPE_BYTES:
                        nbytes += _shape_bytes(dtype, dims)
                crossing = _line_crosses_pods(line, pod_size) or (
                    "replica_groups" not in line
                )
                out["dci" if crossing else "ici"] += nbytes
                break
    return out


def dci_message_count_from_hlo(hlo_text: str, pod_size: int = 256) -> int:
    """Per-device count of pod-crossing peer messages (the paper's
    inter-region message count).  For an all-to-all over a group, each
    device sends one message to every OTHER-POD member of its group; for
    gather/reduce-style collectives a ring crosses the pod boundary twice.
    This is the alpha-term the 3-step aggregation minimizes — byte counts
    alone cannot distinguish flat from hierarchical transports."""
    total = 0
    for line in hlo_text.splitlines():
        op_kind = None
        for op in COLLECTIVE_OPS:
            if f" {op}(" in line or f" {op}-start(" in line:
                op_kind = op
                break
        if op_kind is None:
            continue
        other = 0
        m = _GROUPS_IOTA_RE.search(line)
        if m:
            g, n, transpose, tot = (int(m.group(1)), int(m.group(2)),
                                    m.group(3), int(m.group(4)))
            if tot > pod_size:
                if transpose and (n - 1) * g >= pod_size:
                    other = n // 2
                elif not transpose and n > pod_size:
                    other = n // 2
        else:
            m = _GROUPS_EXPL_RE.search(line)
            if m:
                first = re.findall(r"\d+", m.group(1).split("},")[0])
                ids = [int(x) for x in first]
                if ids:
                    pods = [i // pod_size for i in ids]
                    other = sum(1 for p in pods if p != pods[0])
        if other:
            total += other if op_kind == "all-to-all" else 2
    return total
